//! Serving scenario: the activation-accelerator coordinator under a
//! bursty multi-tenant load — mixed methods, mixed request sizes,
//! many client threads — reporting throughput, latency and batching
//! efficiency, plus a backpressure demonstration.
//!
//! ```sh
//! make artifacts && cargo run --release --example accelerator_serve
//! ```

use std::sync::Arc;
use std::time::Instant;

use tanh_vlsi::approx::MethodId;
use tanh_vlsi::backend::{EvalBackend, GoldenBackend, PjrtBackend};
use tanh_vlsi::coordinator::{BatcherConfig, Coordinator, CoordinatorConfig};
use tanh_vlsi::util::prng::Prng;

fn run_load(coord: Arc<Coordinator>, clients: usize, reqs_per_client: usize) -> f64 {
    let start = Instant::now();
    let handles: Vec<_> = (0..clients)
        .map(|c| {
            let coord = coord.clone();
            std::thread::spawn(move || {
                let mut g = Prng::new(c as u64 + 1);
                for i in 0..reqs_per_client {
                    let method = MethodId::all()[(c + i) % 6];
                    // bursty sizes: mostly small, occasionally large
                    let n = if g.bool(0.9) { 8 + g.usize_below(56) } else { 512 };
                    let values: Vec<f32> =
                        (0..n).map(|_| g.f64_in(-6.0, 6.0) as f32).collect();
                    match coord.submit(method, values) {
                        Ok(rx) => {
                            let _ = rx.recv();
                        }
                        Err(_) => {
                            // backpressure: shed + retry once after a beat
                            std::thread::sleep(std::time::Duration::from_micros(100));
                        }
                    }
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    start.elapsed().as_secs_f64()
}

fn main() -> anyhow::Result<()> {
    // Prefer the compiled-PJRT backend; fall back to the golden models
    // when it is unavailable (missing artifacts or stubbed xla
    // bindings — PjrtBackend reports, it never panics), so the example
    // always runs.
    let pjrt = PjrtBackend::with_default_artifacts(1024);
    let backend: Arc<dyn EvalBackend> = if pjrt.availability().is_available() {
        println!("PJRT platform: {}", pjrt.platform().unwrap_or("?"));
        Arc::new(pjrt)
    } else {
        println!("pjrt unavailable — using golden-model backend");
        Arc::new(GoldenBackend::new())
    };
    let backend_name = backend.name();

    let coord = Arc::new(
        Coordinator::start(
            backend,
            CoordinatorConfig {
                batcher: BatcherConfig {
                    max_wait: std::time::Duration::from_micros(300),
                    ..Default::default()
                },
                // Two worker shards per method, fed round-robin.
                ..Default::default()
            },
        )
        .map_err(|e| anyhow::anyhow!("{e}"))?,
    );

    let clients = 8;
    let reqs = 400;
    println!("\ndriving {clients} client threads × {reqs} requests on '{backend_name}' ...");
    let secs = run_load(coord.clone(), clients, reqs);

    let m = coord.metrics();
    println!("\n== results ==");
    println!("requests completed : {}", m.requests);
    println!("activations        : {}", m.elements);
    println!("wall time          : {secs:.3} s");
    println!("request throughput : {:.0} req/s", m.requests as f64 / secs);
    println!("activation rate    : {:.2} Mact/s", m.elements as f64 / secs / 1e6);
    println!("batches executed   : {} ({:.1} req/batch)", m.batches, m.requests as f64 / m.batches.max(1) as f64);
    println!("batch efficiency   : {:.1} %", 100.0 * m.batch_efficiency());
    println!("mean latency       : {:.0} µs", m.mean_latency_us());
    println!("latency p50/p95/p99: {:.0} / {:.0} / {:.0} µs", m.p50_us(), m.p95_us(), m.p99_us());
    println!("max latency        : {} µs", m.latency_us_max());
    println!("rejected (backpressure): {}", m.rejected);
    println!("errors             : {}", m.errors);
    assert_eq!(m.errors, 0);
    assert!(m.requests > 0);

    Arc::try_unwrap(coord).ok().map(|c| c.shutdown());
    Ok(())
}
