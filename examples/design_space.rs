//! Design-space exploration: the paper's §IV.H assessment, computed.
//!
//! Sweeps every method over its parameter range, measures exhaustive
//! error and prices the hardware, prints the Pareto frontier over
//! (error, area, latency), and checks the paper's qualitative claims:
//!
//! - PWL is simplest but its LUT dominates area at high accuracy;
//! - quadratic Taylor is the sweet spot for medium accuracy;
//! - Lambert scales to high accuracy with the smallest *incremental*
//!   cost but the deepest pipeline;
//! - rational methods have higher latency than polynomial ones.
//!
//! ```sh
//! cargo run --release --example design_space
//! ```

use tanh_vlsi::approx::MethodId;
use tanh_vlsi::explore::{explore, pareto_frontier, ExploreConfig};
use tanh_vlsi::util::table::TextTable;

fn main() {
    let cfg = ExploreConfig { stride: 4, ..Default::default() };
    println!("sweeping 6 methods × parameter ranges (stride {}) ...\n", cfg.stride);
    let points = explore(cfg);
    let frontier = pareto_frontier(&points);

    let mut t = TextTable::new(&["method", "param", "max err", "area GE", "latency", "FO4"]);
    for p in &frontier {
        t.row(vec![
            p.id.name().to_string(),
            format!("{}", p.param),
            format!("{:.2e}", p.max_err),
            format!("{:.0}", p.area_ge),
            p.latency_cycles.to_string(),
            format!("{:.1}", p.stage_delay_fo4),
        ]);
    }
    println!("Pareto frontier over (max error, area, latency) — {} of {} points:\n", frontier.len(), points.len());
    println!("{}", t.render());

    // ---- paper §IV.H claims, checked quantitatively ----

    // (1) Among ≤2e-5-error designs, PWL pays the largest LUT-driven area.
    let accurate: Vec<_> = points.iter().filter(|p| p.max_err < 2.0e-5).collect();
    if let (Some(pwl), Some(taylor)) = (
        accurate.iter().filter(|p| p.id == MethodId::Pwl).map(|p| p.area_ge).reduce(f64::min),
        accurate
            .iter()
            .filter(|p| p.id == MethodId::TaylorQuadratic)
            .map(|p| p.area_ge)
            .reduce(f64::min),
    ) {
        println!("claim 1 — high accuracy (≤2e-5): cheapest PWL {pwl:.0} GE vs Taylor-quad {taylor:.0} GE");
        assert!(taylor < pwl, "Taylor should beat PWL on area at high accuracy");
    }

    // (2) Rational methods are deeper-pipelined than polynomial ones.
    let poly_max_lat = points
        .iter()
        .filter(|p| {
            matches!(
                p.id,
                MethodId::Pwl | MethodId::TaylorQuadratic | MethodId::TaylorCubic | MethodId::CatmullRom
            )
        })
        .map(|p| p.latency_cycles)
        .max()
        .unwrap();
    let rational_min_lat = points
        .iter()
        .filter(|p| matches!(p.id, MethodId::Velocity | MethodId::Lambert))
        .map(|p| p.latency_cycles)
        .min()
        .unwrap();
    println!(
        "claim 2 — latency: deepest polynomial {poly_max_lat} cyc vs shallowest rational {rational_min_lat} cyc"
    );
    assert!(rational_min_lat > poly_max_lat);

    // (3) "Lambert's continued function can be scaled for better
    //     accuracy compared to other approximations": across the K
    //     sweep, error collapses by orders of magnitude while area
    //     grows by a much smaller factor (each extra term is one more
    //     identical pipeline stage — albeit with the paper's "larger
    //     multipliers", whose width grows with K in this model).
    let mut lambert: Vec<_> = points.iter().filter(|p| p.id == MethodId::Lambert).collect();
    lambert.sort_by(|a, b| a.param.partial_cmp(&b.param).unwrap());
    let (first, last) = (lambert.first().unwrap(), lambert.last().unwrap());
    let err_gain = first.max_err / last.max_err.max(1e-12);
    let area_growth = last.area_ge / first.area_ge;
    println!(
        "claim 3 — Lambert scaling K={}→{}: error ÷{:.0}, area ×{:.1}",
        first.param, last.param, err_gain, area_growth
    );
    assert!(err_gain > 50.0, "error should collapse with K (got ÷{err_gain:.0})");
    assert!(area_growth < err_gain / 5.0, "area must grow far slower than error shrinks");

    println!("\n✓ all §IV.H claims hold on the swept design space");
}
