//! End-to-end driver: LSTM cell steps served through the full stack.
//!
//! The default path is **integer-only** and needs no build artifacts:
//! the cell-step graph (`tanh_vlsi::graph`) routes every gate
//! nonlinearity through the paper's fixed-point approximations — tanh
//! directly, sigmoid via `σ(x) = (1 + tanh(x/2))/2` — and the
//! elementwise state update through the saturating Q-format datapath.
//! The driver:
//!
//! 1. builds the canonical LSTM cell at the Table I operating point and
//!    runs the rewrite pipeline (sigmoid-into-tanh fusion, requant
//!    merge, dedup, prune);
//! 2. asserts the fused graph is **bit-identical** to the unfused one
//!    on random pre-activations;
//! 3. serves whole cell-step recurrences through a 2-shard coordinator
//!    (golden backend) and checks every gate output against the f64
//!    reference under the cell's error budget.
//!
//! When `make artifacts` has produced the AOT'd PJRT graphs (and the
//! xla bindings are linked), an optional second act loads the trained
//! sign-of-running-sum LSTM and reports accuracy/agreement of the
//! approximated activations — the paper's §I motivating scenario.
//! Without artifacts that act is skipped, not a failure.
//!
//! ```sh
//! cargo run --release --example lstm_inference            # integer-only
//! make artifacts && cargo run --release --example lstm_inference
//! ```

use std::time::Instant;

use tanh_vlsi::backend;
use tanh_vlsi::coordinator::{Coordinator, CoordinatorConfig};
use tanh_vlsi::fixed::Fx;
use tanh_vlsi::graph::{
    execute_raw, lstm_cell, optimize, run_lstm_cells, CellConfig, CellGraph, CellRunConfig,
    FreshKernelSink,
};
use tanh_vlsi::runtime::{ArtifactDir, Engine, TensorValue};
use tanh_vlsi::util::prng::Prng;

const LANES: usize = 32;

/// One random input set for the cell graph: pre-activations across the
/// tanh domain, plus a mid-range carried state.
fn random_inputs(g: &CellGraph, p: &mut Prng) -> Vec<(String, Vec<i64>)> {
    g.inputs()
        .into_iter()
        .map(|(name, _, fmt)| {
            let range = if name.ends_with("_pre") { 6.0 } else { 1.5 };
            let lanes = (0..LANES)
                .map(|_| Fx::from_f64(p.f64_in(-range, range), fmt).raw())
                .collect();
            (name.to_string(), lanes)
        })
        .collect()
}

fn integer_only() -> Result<(), String> {
    let cfg = CellConfig::table1_lstm();
    let unfused = lstm_cell(&cfg)?;
    let (fused, rw) = optimize(&unfused)?;
    println!(
        "LSTM cell graph: gate spec {} (budget {:.1e})\n\
         rewrites: {} sigmoids fused onto shared tanh kernels, \
         {} requants merged, {} nodes deduped, {} pruned \
         ({} nodes -> {})",
        cfg.spec,
        cfg.budget,
        rw.fused_sigmoids,
        rw.merged_requants,
        rw.deduped_nodes,
        rw.pruned_nodes,
        unfused.len(),
        fused.len(),
    );

    // Act 1: fused and unfused graphs are bit-identical. The fusion is
    // line-for-line the integer datapath of SigmoidFromTanh, so this
    // must hold exactly, not approximately.
    let mut p = Prng::new(0xFEED);
    let owned = random_inputs(&unfused, &mut p);
    let inputs: Vec<(&str, Vec<i64>)> =
        owned.iter().map(|(n, v)| (n.as_str(), v.clone())).collect();
    let a = execute_raw(&unfused, &inputs, &FreshKernelSink::for_graph(&unfused))?;
    let b = execute_raw(&fused, &inputs, &FreshKernelSink::for_graph(&fused))?;
    if a != b {
        return Err("fused graph diverged bit-wise from the unfused cell".into());
    }
    println!("fused == unfused bit-for-bit on {LANES} random lanes across all 6 outputs");

    // Act 2: whole cell-step recurrences through the live coordinator,
    // every step verified against the direct golden execution and the
    // f64 reference.
    let eval = backend::by_name("golden", 256)?;
    let coord = Coordinator::start(
        eval,
        CoordinatorConfig { shards: 2, specs: fused.activation_specs(), ..Default::default() },
    )
    .map_err(|e| e.to_string())?;
    let run = CellRunConfig { sequences: 2, steps: 8, lanes: LANES, seed: 0xFEED };
    let t0 = Instant::now();
    let stats = run_lstm_cells(&coord, &cfg, &fused, &run)?;
    let secs = t0.elapsed().as_secs_f64().max(1e-9);
    coord.shutdown();
    println!(
        "served {} cell steps ({} activation requests, {} elements) through \
         2 shards in {:.3}s ({:.0} steps/s)",
        stats.cell_steps,
        stats.requests,
        stats.elements,
        secs,
        stats.cell_steps as f64 / secs,
    );
    println!(
        "per-gate max |served - f64 reference| = {:.3e} (budget {:.1e})",
        stats.gate_max_err, cfg.budget,
    );
    Ok(())
}

// ---- optional PJRT act: the trained model, when artifacts exist ----

const BATCH: usize = 32;
const SEQ: usize = 16;
const DIM: usize = 4;

/// Synthetic test batch matching `model.make_toy_batch`.
fn make_batch(g: &mut Prng) -> (Vec<f32>, Vec<i32>) {
    let mut seq = Vec::with_capacity(BATCH * SEQ * DIM);
    let mut labels = Vec::with_capacity(BATCH);
    for _ in 0..BATCH {
        let mut sum = 0.0f32;
        for _ in 0..SEQ * DIM {
            let v = if g.bool(0.5) { 1.0 } else { -1.0 };
            sum += v;
            seq.push(v);
        }
        labels.push(if sum > 0.0 { 1 } else { 0 });
    }
    (seq, labels)
}

fn accuracy(logits: &[f32], labels: &[i32]) -> f64 {
    let correct = labels
        .iter()
        .enumerate()
        .filter(|(i, &l)| {
            let pred = if logits[2 * i + 1] > logits[2 * i] { 1 } else { 0 };
            pred == l
        })
        .count();
    correct as f64 / labels.len() as f64
}

fn trained_model(artifacts: ArtifactDir) -> Result<(), String> {
    let err = |e: tanh_vlsi::util::error::RtError| e.to_string();
    let engine = Engine::cpu(artifacts).map_err(err)?;
    println!("\nPJRT platform: {}", engine.platform());
    for name in ["lstm_logits_ref", "lstm_logits_pwl", "lstm_logits_taylor1"] {
        engine.load(name).map_err(err)?;
    }

    let batches = 32;
    let mut stats: Vec<(String, f64, f64, f64)> = Vec::new(); // (name, acc, agree, ms)

    for method in ["ref", "pwl", "taylor1"] {
        let name = format!("lstm_logits_{method}");
        let mut g2 = Prng::new(0xFEED); // same test set for every method
        let mut acc_sum = 0.0;
        let mut agree_sum = 0.0;
        let mut elapsed = 0.0;
        for _ in 0..batches {
            let (seq, labels) = make_batch(&mut g2);
            let t0 = Instant::now();
            let out = engine
                .load(&name)
                .map_err(err)?
                .execute(&[TensorValue::F32(seq.clone())])
                .map_err(err)?;
            elapsed += t0.elapsed().as_secs_f64();
            let logits = out[0].as_f32().map_err(err)?;
            acc_sum += accuracy(logits, &labels);
            // agreement vs exact-tanh model on the same batch
            let ref_out = engine
                .load("lstm_logits_ref")
                .map_err(err)?
                .execute(&[TensorValue::F32(seq)])
                .map_err(err)?;
            let ref_logits = ref_out[0].as_f32().map_err(err)?;
            let agree = labels
                .iter()
                .enumerate()
                .filter(|(i, _)| {
                    (logits[2 * i + 1] > logits[2 * i])
                        == (ref_logits[2 * i + 1] > ref_logits[2 * i])
                })
                .count();
            agree_sum += agree as f64 / labels.len() as f64;
        }
        stats.push((
            method.to_string(),
            acc_sum / batches as f64,
            agree_sum / batches as f64,
            1e3 * elapsed / batches as f64,
        ));
    }

    println!(
        "\nLSTM sign-of-running-sum classification, {} batches × {} sequences (seq len {}):\n",
        batches, BATCH, SEQ
    );
    println!(
        "{:10} {:>9} {:>18} {:>14}",
        "tanh", "accuracy", "agreement w/ ref", "latency/batch"
    );
    for (name, acc, agree, ms) in &stats {
        println!("{name:10} {:>8.1}% {:>17.1}% {:>11.2} ms", 100.0 * acc, 100.0 * agree, ms);
    }

    let ref_acc = stats[0].1;
    for (name, acc, agree, _) in &stats[1..] {
        assert!(
            (acc - ref_acc).abs() < 0.02,
            "{name}: accuracy drop {:.3} vs ref {:.3}",
            acc,
            ref_acc
        );
        assert!(*agree > 0.97, "{name}: agreement {agree}");
    }
    println!(
        "\n✓ approximated activations preserve model quality \
         (Δaccuracy < 2%, agreement > 97%)"
    );
    Ok(())
}

fn main() -> Result<(), String> {
    integer_only()?;
    // The trained-model comparison needs `make artifacts` plus linked
    // xla bindings; absent either, report and move on — the integer
    // path above has already exercised the serving stack.
    match ArtifactDir::open(ArtifactDir::default_path()) {
        Ok(artifacts) => trained_model(artifacts)?,
        Err(e) => println!(
            "\nskipping trained-model PJRT comparison ({e}); \
             run `make artifacts` to enable it"
        ),
    }
    Ok(())
}
