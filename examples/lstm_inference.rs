//! End-to-end driver: LSTM sequence classification served through the
//! full three-layer stack.
//!
//! The LSTM was trained at build time (`make artifacts`) with exact f32
//! tanh on the sign-of-running-sum task (see `python/compile/model.py`);
//! here the rust runtime loads the AOT'd inference graphs — one with
//! exact tanh, one with every tanh/sigmoid routed through the PWL
//! approximation kernel — generates a fresh synthetic test set, and
//! reports accuracy, prediction agreement and serving latency. This is
//! the paper's motivating scenario (§I: LSTMs need hardware tanh) made
//! concrete.
//!
//! ```sh
//! make artifacts && cargo run --release --example lstm_inference
//! ```

use std::time::Instant;

use tanh_vlsi::runtime::{ArtifactDir, Engine, TensorValue};
use tanh_vlsi::util::prng::Prng;

const BATCH: usize = 32;
const SEQ: usize = 16;
const DIM: usize = 4;

/// Synthetic test batch matching `model.make_toy_batch`.
fn make_batch(g: &mut Prng) -> (Vec<f32>, Vec<i32>) {
    let mut seq = Vec::with_capacity(BATCH * SEQ * DIM);
    let mut labels = Vec::with_capacity(BATCH);
    for _ in 0..BATCH {
        let mut sum = 0.0f32;
        for _ in 0..SEQ * DIM {
            let v = if g.bool(0.5) { 1.0 } else { -1.0 };
            sum += v;
            seq.push(v);
        }
        labels.push(if sum > 0.0 { 1 } else { 0 });
    }
    (seq, labels)
}

fn accuracy(logits: &[f32], labels: &[i32]) -> f64 {
    let correct = labels
        .iter()
        .enumerate()
        .filter(|(i, &l)| {
            let pred = if logits[2 * i + 1] > logits[2 * i] { 1 } else { 0 };
            pred == l
        })
        .count();
    correct as f64 / labels.len() as f64
}

fn main() -> anyhow::Result<()> {
    // Single-threaded driver: use runtime::Engine directly (the
    // engine-thread indirection lives in backend::PjrtBackend, which
    // the serving stack uses).
    let engine = Engine::cpu(ArtifactDir::open(ArtifactDir::default_path())?)?;
    println!("PJRT platform: {}", engine.platform());
    for name in ["lstm_logits_ref", "lstm_logits_pwl", "lstm_logits_taylor1"] {
        engine.load(name)?;
    }

    let mut g = Prng::new(0xFEED);
    let batches = 32;
    let mut stats: Vec<(String, f64, f64, f64)> = Vec::new(); // (name, acc, agree, ms)

    for method in ["ref", "pwl", "taylor1"] {
        let name = format!("lstm_logits_{method}");
        let mut g2 = Prng::new(0xFEED); // same test set for every method
        let mut acc_sum = 0.0;
        let mut agree_sum = 0.0;
        let mut elapsed = 0.0;
        for _ in 0..batches {
            let (seq, labels) = make_batch(&mut g2);
            let t0 = Instant::now();
            let out = engine.load(&name)?.execute(&[TensorValue::F32(seq.clone())])?;
            elapsed += t0.elapsed().as_secs_f64();
            let logits = out[0].as_f32()?;
            acc_sum += accuracy(logits, &labels);
            // agreement vs exact-tanh model on the same batch
            let ref_out =
                engine.load("lstm_logits_ref")?.execute(&[TensorValue::F32(seq)])?;
            let ref_logits = ref_out[0].as_f32()?;
            let agree = labels
                .iter()
                .enumerate()
                .filter(|(i, _)| {
                    (logits[2 * i + 1] > logits[2 * i])
                        == (ref_logits[2 * i + 1] > ref_logits[2 * i])
                })
                .count();
            agree_sum += agree as f64 / labels.len() as f64;
        }
        stats.push((
            method.to_string(),
            acc_sum / batches as f64,
            agree_sum / batches as f64,
            1e3 * elapsed / batches as f64,
        ));
        let _ = g.next_u64();
    }

    println!(
        "\nLSTM sign-of-running-sum classification, {} batches × {} sequences (seq len {}):\n",
        batches, BATCH, SEQ
    );
    println!(
        "{:10} {:>9} {:>18} {:>14}",
        "tanh", "accuracy", "agreement w/ ref", "latency/batch"
    );
    for (name, acc, agree, ms) in &stats {
        println!("{name:10} {:>8.1}% {:>17.1}% {:>11.2} ms", 100.0 * acc, 100.0 * agree, ms);
    }

    let ref_acc = stats[0].1;
    for (name, acc, agree, _) in &stats[1..] {
        assert!(
            (acc - ref_acc).abs() < 0.02,
            "{name}: accuracy drop {:.3} vs ref {:.3}",
            acc,
            ref_acc
        );
        assert!(*agree > 0.97, "{name}: agreement {agree}");
    }
    println!(
        "\n✓ approximated activations preserve model quality \
         (Δaccuracy < 2%, agreement > 97%)"
    );
    Ok(())
}
