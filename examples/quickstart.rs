//! Quickstart: evaluate all six approximations, inspect their errors,
//! hardware inventories and pipelined datapaths — the library's public
//! API in one page.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use tanh_vlsi::approx::{table1_suite, IoSpec, TanhApprox};
use tanh_vlsi::cost::{CostModel, UnitLibrary};
use tanh_vlsi::error::{measure, InputGrid};
use tanh_vlsi::fixed::{Fx, QFormat};
use tanh_vlsi::hw::table1_pipeline;

fn main() {
    let io = IoSpec::table1(); // S3.12 in → S.15 out, the paper's setup
    let x = Fx::from_f64(1.25, io.input);
    println!("tanh({}) = {:.9}\n", x.to_f64(), x.to_f64().tanh());

    // 1. Evaluate each Table I configuration through its bit-exact
    //    fixed-point datapath model.
    println!("== datapath evaluation ==");
    for m in table1_suite() {
        let y = m.eval_fx(x, io.output);
        println!(
            "{:28} -> {:.9}  (error {:+.2e})",
            m.describe(),
            y.to_f64(),
            y.to_f64() - x.to_f64().tanh()
        );
    }

    // 2. Exhaustive error metrics over the analysis grid (Table I).
    println!("\n== exhaustive error (|x| < 6, every S3.12 point) ==");
    let grid = InputGrid::table1();
    for m in table1_suite() {
        let e = measure(m.as_ref(), grid, io.output);
        println!(
            "{:28} max {:.2e} @ x={:+.3}   rms {:.2e}   ({} points)",
            m.describe(),
            e.max_abs,
            e.argmax,
            e.rms,
            e.points
        );
    }

    // 3. Hardware cost (paper §IV): component inventory priced by the
    //    unit gate library.
    println!("\n== hardware cost (unit gate library) ==");
    let model = CostModel::new();
    for m in table1_suite() {
        let inv = m.inventory(io);
        let cost = model.price(&inv);
        println!(
            "{:28} {} add, {} mul, {} div, {} LUT entries -> {:.0} GE",
            m.describe(),
            inv.adders,
            inv.multipliers,
            inv.dividers,
            inv.lut_entries,
            cost.area_ge
        );
    }

    // 4. The cycle-level pipelined datapath (Figs 3/4/5).
    println!("\n== pipelined datapaths ==");
    let lib = UnitLibrary::default();
    for m in table1_suite() {
        let pipe = table1_pipeline(m.id(), io.output);
        let y = pipe.eval(x);
        assert_eq!(y.raw(), m.eval_fx(x, io.output).raw(), "pipeline != golden");
        println!(
            "{:20} latency {:2} cycles, critical stage {:.1} FO4, bit-exact ✓",
            pipe.name,
            pipe.latency(),
            pipe.critical_delay(&lib)
        );
    }
}
