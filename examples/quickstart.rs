//! Quickstart: name design points as specs, evaluate all six
//! approximations, inspect their errors, hardware inventories and
//! pipelined datapaths — the library's public API in one page.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use tanh_vlsi::approx::{MethodSpec, Registry};
use tanh_vlsi::cost::{CostModel, UnitLibrary};
use tanh_vlsi::error::measure_spec;
use tanh_vlsi::fixed::Fx;
use tanh_vlsi::hw::table1_pipeline;

fn main() {
    // Design points are named by spec strings: method + parameter +
    // I/O formats (+ domain). `table1:A` … `table1:E` are the paper's
    // six rows; any other (method × parameter × format) point is one
    // parse away.
    let specs = MethodSpec::table1_all();
    let custom = MethodSpec::parse("pwl:step=1/32:in=s2.13:out=s.15").unwrap();
    let x_f64 = 1.25;
    println!("tanh({x_f64}) = {:.9}\n", x_f64.tanh());

    // 1. Evaluate each Table I configuration through its bit-exact
    //    fixed-point datapath model (spec.build() → TanhApprox).
    println!("== datapath evaluation ==");
    for spec in &specs {
        let m = spec.build();
        let x = Fx::from_f64(x_f64, spec.io.input);
        let y = m.eval_fx(x, spec.io.output);
        println!(
            "{:44} -> {:.9}  (error {:+.2e})",
            spec.to_string(),
            y.to_f64(),
            y.to_f64() - x_f64.tanh()
        );
    }

    // 2. Exhaustive error metrics per spec — kernels come from the
    //    shared Registry cache, so re-measuring is compile-free.
    println!("\n== exhaustive error (every input word in the spec's domain) ==");
    for spec in specs.iter().chain(std::iter::once(&custom)) {
        let e = measure_spec(spec);
        println!(
            "{:44} max {:.2e} @ x={:+.3}   rms {:.2e}   ({} points)",
            spec.to_string(),
            e.max_abs,
            e.argmax,
            e.rms,
            e.points
        );
    }
    let stats = Registry::global().stats();
    println!("   (kernel cache: {} compiles, {} hits)", stats.compiles, stats.hits);

    // 3. Hardware cost (paper §IV): component inventory priced by the
    //    unit gate library.
    println!("\n== hardware cost (unit gate library) ==");
    let model = CostModel::new();
    for spec in &specs {
        let m = spec.build();
        let inv = m.inventory(spec.io);
        let cost = model.price(&inv);
        println!(
            "{:28} {} add, {} mul, {} div, {} LUT entries -> {:.0} GE",
            m.describe(),
            inv.adders,
            inv.multipliers,
            inv.dividers,
            inv.lut_entries,
            cost.area_ge
        );
    }

    // 4. The cycle-level pipelined datapath (Figs 3/4/5).
    println!("\n== pipelined datapaths ==");
    let lib = UnitLibrary::default();
    for spec in &specs {
        let m = spec.build();
        let x = Fx::from_f64(x_f64, spec.io.input);
        let pipe = table1_pipeline(spec.method_id(), spec.io.output);
        let y = pipe.eval(x);
        assert_eq!(y.raw(), m.eval_fx(x, spec.io.output).raw(), "pipeline != golden");
        println!(
            "{:20} latency {:2} cycles, critical stage {:.1} FO4, bit-exact ✓",
            pipe.name,
            pipe.latency(),
            pipe.critical_delay(&lib)
        );
    }
}
