"""AOT pipeline: lower every L2 graph to HLO **text** artifacts.

HLO text — NOT ``lowered.compiler_ir("hlo").as_hlo_module().serialize()``
— is the interchange format: jax ≥ 0.5 emits HloModuleProto with 64-bit
instruction ids which the image's xla_extension 0.5.1 (behind the
published ``xla`` 0.1.6 crate) rejects; the text parser reassigns ids
(see /opt/xla-example/README.md).

Usage::

    cd python && python -m compile.aot --out-dir ../artifacts

Emits:

- ``tanh_<method>_<n>.hlo.txt``  — activation graphs, 6 methods + ref;
- ``tanh_pwl_raw_<n>.hlo.txt``   — bit-exact int32 PWL graph;
- ``lstm_cell_<m>.hlo.txt``      — single-step LSTM, exact + pwl tanh;
- ``lstm_logits_<m>.hlo.txt``    — full-sequence LSTM classifier;
- ``manifest.json``              — shapes/dtypes/metadata for the rust
  runtime loader;
- ``test_vectors.json``          — input/output probes for the rust
  integration tests (the cross-language bit-exactness check).
"""

from __future__ import annotations

import argparse
import json
import pathlib
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as M

#: Serving batch for the activation graphs (multiple of the kernel block).
TANH_N = 1024
#: LSTM export shape.
LSTM_BATCH = 32
LSTM_SEQ = 16
LSTM_INPUT = 4
LSTM_HIDDEN = 64


def to_hlo_text(fn, example_args) -> str:
    """jit → lower → StableHLO → XlaComputation → HLO text
    (``return_tuple=True`` so the rust side unwraps a tuple)."""
    lowered = jax.jit(fn).lower(*example_args)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # print_large_constants=True is load-bearing: the default printer
    # elides dense array literals as `constant({...})`, which the old
    # text parser silently reads back as zeros — every baked LUT would
    # vanish (guarded by test_aot::test_no_elided_constants).
    return comp.as_hlo_text(print_large_constants=True)


def emit(out_dir: pathlib.Path, name: str, fn, args, manifest: dict):
    """Lowers one graph and records its manifest entry."""
    text = to_hlo_text(fn, args)
    path = out_dir / f"{name}.hlo.txt"
    path.write_text(text)
    manifest[name] = {
        "file": path.name,
        "inputs": [
            {"shape": list(a.shape), "dtype": str(a.dtype)} for a in args
        ],
    }
    print(f"  wrote {path.name} ({len(text)} chars)")


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--out-dir", default="../artifacts")
    parser.add_argument("--train-steps", type=int, default=300)
    parser.add_argument("--seed", type=int, default=42)
    args = parser.parse_args()
    out_dir = pathlib.Path(args.out_dir)
    out_dir.mkdir(parents=True, exist_ok=True)
    manifest: dict = {}
    t0 = time.time()

    # --- activation graphs -------------------------------------------------
    print("[aot] activation graphs")
    for method in list(M.KERNELS) + ["ref"]:
        fn, a = M.tanh_graph(method, TANH_N)
        emit(out_dir, f"tanh_{method}_{TANH_N}", fn, a, manifest)
    fn, a = M.tanh_raw_graph(TANH_N)
    emit(out_dir, f"tanh_pwl_raw_{TANH_N}", fn, a, manifest)

    # --- build-time training ----------------------------------------------
    print(f"[aot] training toy LSTM ({args.train_steps} steps)")
    params, curve, acc = M.train_toy_lstm(
        seed=args.seed, steps=args.train_steps, hidden=LSTM_HIDDEN,
        seq_len=LSTM_SEQ, input_dim=LSTM_INPUT, verbose=True,
    )
    print(f"  final train-dist accuracy (exact tanh): {acc:.3f}")

    # --- LSTM graphs (exact + the Table I flagship approximations) ---------
    print("[aot] LSTM graphs")
    for method in ["ref", "pwl", "taylor1"]:
        fn, a = M.lstm_cell_graph(params, method, LSTM_BATCH, LSTM_INPUT, LSTM_HIDDEN)
        emit(out_dir, f"lstm_cell_{method}", fn, a, manifest)
        fn, a = M.lstm_logits_graph(params, method, LSTM_BATCH, LSTM_SEQ, LSTM_INPUT)
        emit(out_dir, f"lstm_logits_{method}", fn, a, manifest)

    # --- test vectors for the rust integration suite -----------------------
    print("[aot] test vectors")
    rng = np.random.default_rng(7)
    xs = rng.uniform(-7, 7, TANH_N).astype(np.float32)
    vectors = {
        "tanh_input_f32": xs.tolist(),
        "tanh_expected": {},
        "lstm": {},
        "training": {
            "loss_curve": curve,
            "final_accuracy": acc,
            "steps": args.train_steps,
        },
    }
    for method in list(M.KERNELS) + ["ref"]:
        fn, _ = M.tanh_graph(method, TANH_N)
        vectors["tanh_expected"][method] = np.asarray(fn(jnp.asarray(xs))[0]).tolist()
    raws = rng.integers(-32768, 32768, TANH_N).astype(np.int32)
    fn, _ = M.tanh_raw_graph(TANH_N)
    vectors["tanh_raw_input"] = raws.tolist()
    vectors["tanh_raw_expected"] = np.asarray(fn(jnp.asarray(raws))[0]).tolist()

    seq, labels = M.make_toy_batch(rng, LSTM_BATCH, LSTM_SEQ, LSTM_INPUT)
    vectors["lstm"]["seq"] = seq.reshape(-1).tolist()
    vectors["lstm"]["labels"] = labels.tolist()
    for method in ["ref", "pwl"]:
        fn, _ = M.lstm_logits_graph(params, method, LSTM_BATCH, LSTM_SEQ, LSTM_INPUT)
        logits = np.asarray(fn(jnp.asarray(seq))[0])
        vectors["lstm"][f"logits_{method}"] = logits.reshape(-1).tolist()

    (out_dir / "test_vectors.json").write_text(json.dumps(vectors))
    (out_dir / "manifest.json").write_text(json.dumps(manifest, indent=2))
    print(f"[aot] done in {time.time() - t0:.1f}s — {len(manifest)} artifacts")


if __name__ == "__main__":
    main()
