"""L1 — Pallas kernels for the six tanh approximations.

One module per method (mirroring ``rust/src/approx/``), a shared
fixed-point emulation layer, the pure-jnp oracles in :mod:`.ref`, and a
dispatch table used by the L2 model and the AOT pipeline.
"""

from __future__ import annotations

from .catmull_rom import catmull_rom_tanh_f32
from .lambert import lambert_tanh_f32
from .pwl import pwl_tanh_f32, pwl_tanh_raw
from .taylor import taylor_tanh_f32
from .velocity import velocity_tanh_f32

#: Table I kernel configurations, keyed by the method names the rust
#: coordinator uses in artifact filenames.
KERNELS = {
    "pwl": lambda x: pwl_tanh_f32(x, step=1.0 / 64.0),
    "taylor1": lambda x: taylor_tanh_f32(x, step=1.0 / 16.0, terms=3),
    "taylor2": lambda x: taylor_tanh_f32(x, step=1.0 / 8.0, terms=4),
    "catmull_rom": lambda x: catmull_rom_tanh_f32(x, step=1.0 / 16.0),
    "velocity": lambda x: velocity_tanh_f32(x, threshold=1.0 / 128.0),
    "lambert": lambda x: lambert_tanh_f32(x, k_terms=7),
}

__all__ = [
    "KERNELS",
    "catmull_rom_tanh_f32",
    "lambert_tanh_f32",
    "pwl_tanh_f32",
    "pwl_tanh_raw",
    "taylor_tanh_f32",
    "velocity_tanh_f32",
]
