"""Method C — Catmull-Rom spline as a Pallas kernel (float math model).

Control points tanh(i·step) live in a broadcast LUT; the negative-index
point of the first segment uses odd reflection (P_{−1} = −P_1) exactly
like the rust datapath. The 4-element dot product against the cubic
basis is the paper's eq. (17) MAC.
"""

from __future__ import annotations

import math

import jax.numpy as jnp
import numpy as np

from .common import DEFAULT_BLOCK, elementwise_call


def make_point_lut(step: float, domain_max: float, guard: int = 2) -> np.ndarray:
    """Control points tanh(i·step), two guard points past the domain."""
    n = math.ceil(domain_max / step) + 1 + guard
    return np.tanh(np.arange(n) * step).astype(np.float32)


def make_catmull_rom_kernel(step: float = 1.0 / 16.0, domain_max: float = 6.0):
    """Builds the kernel body.

    Perf (EXPERIMENTS.md §Perf iter 3): all four control points come
    from ONE one-hot matmul against a pre-reflected [segments, 4] table
    (row k = [P_{k−1}, P_k, P_{k+1}, P_{k+2}], odd reflection baked in)
    instead of four separate lookups — 4× fewer LUT fetch FLOPs and the
    MXU-shaped access pattern.
    """
    lut = make_point_lut(step, domain_max)
    n_lut = int(lut.shape[0])
    n_seg = n_lut - 2  # need k+2 ≤ n_lut-1

    def p(i: int) -> float:
        return -float(lut[-i]) if i < 0 else float(lut[i])

    quad_table = jnp.asarray(
        np.array(
            [[p(k - 1), p(k), p(k + 1), p(k + 2)] for k in range(n_seg)],
            dtype=np.float32,
        )
    )
    inv_step = 1.0 / step

    def kernel(x_ref, table_ref, o_ref):
        x = x_ref[...]
        table_v = table_ref[...]
        neg = x < 0
        mag = jnp.abs(x)
        sat = mag >= domain_max
        k = jnp.clip(jnp.floor(mag * inv_step).astype(jnp.int32), 0, n_seg - 1)
        t = mag * inv_step - k.astype(jnp.float32)
        t2, t3 = t * t, t * t * t
        b0 = 0.5 * (-t3 + 2.0 * t2 - t)
        b1 = 0.5 * (3.0 * t3 - 5.0 * t2 + 2.0)
        b2 = 0.5 * (-3.0 * t3 + 4.0 * t2 + t)
        b3 = 0.5 * (t3 - t2)
        iota = jnp.arange(n_seg, dtype=jnp.int32)
        onehot = (k[:, None] == iota[None, :]).astype(jnp.float32)
        pts = onehot @ table_v  # [block, 4]
        y = b0 * pts[:, 0] + b1 * pts[:, 1] + b2 * pts[:, 2] + b3 * pts[:, 3]
        y = jnp.clip(y, 0.0, 1.0)
        y = jnp.where(sat, 1.0, y)
        o_ref[...] = jnp.where(neg, -y, y).astype(jnp.float32)

    return kernel, quad_table


def catmull_rom_tanh_f32(x, step: float = 1.0 / 16.0, domain_max: float = 6.0,
                         block: int = DEFAULT_BLOCK):
    """Applies the Catmull-Rom kernel to an f32 batch."""
    kernel, lut = make_catmull_rom_kernel(step, domain_max)
    return elementwise_call(kernel, jnp.asarray(x, jnp.float32), jnp.float32, block,
                            consts=(lut,))
