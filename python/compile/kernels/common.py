"""Shared Pallas glue: elementwise-map kernel launcher.

Every approximation kernel is an elementwise map over a batch vector,
tiled along the batch dimension by BlockSpec — the TPU-shaped analogue of
the paper's streaming datapath (HBM→VMEM tiles instead of input
registers; see DESIGN.md §5 Hardware-Adaptation).

``interpret=True`` everywhere: real-TPU lowering emits Mosaic
custom-calls the CPU PJRT plugin cannot execute (see
/opt/xla-example/README.md), and correctness is validated through the
interpret path.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

def lut_lookup(lut, idx):
    """LUT fetch as a one-hot matmul instead of a gather.

    Two reasons: (a) on TPU a small-table lookup via one-hot × table on
    the MXU beats a serialized gather — this is the idiomatic Pallas
    shape for the paper's hardwired LUTs; (b) the deployment bridge
    (HLO text → xla_extension 0.5.1) mis-executes `gather`, so emitted
    graphs must avoid it entirely (guarded by test_aot's no-gather
    check).

    Float tables go through a dot; integer tables through an exact
    masked sum (both bit-preserving for the paper's word widths).
    """
    n = lut.shape[0]
    iota = jnp.arange(n, dtype=jnp.int32)
    onehot = idx[:, None] == iota[None, :]
    if jnp.issubdtype(lut.dtype, jnp.integer):
        return jnp.sum(jnp.where(onehot, lut[None, :], 0), axis=1, dtype=lut.dtype)
    return onehot.astype(lut.dtype) @ lut


#: Default block (tile) length along the batch dimension. 256 elements
#: keeps each tile's I/O + the broadcast LUT well under VMEM (~16 MiB):
#: the largest Table I LUT is 387 × int32 ≈ 1.5 KiB, tiles are 1-4 KiB.
DEFAULT_BLOCK = 256


def elementwise_call(kernel_fn, x, out_dtype, block: int = DEFAULT_BLOCK, consts=()):
    """Launches ``kernel_fn(x_ref, *const_refs, o_ref)`` tiled over a 1-D
    batch.

    ``consts`` are whole-array inputs (LUTs / register files) broadcast
    into every block — the VMEM-resident tables of the paper's datapaths
    (Pallas kernels cannot capture traced constants; tables enter as
    explicit operands with a constant index map).

    The batch length must be a multiple of ``block`` (the AOT pipeline
    pads to this; the rust coordinator batches to fixed shapes anyway —
    one compiled executable per batch size).
    """
    n = x.shape[0]
    if n % block != 0:
        raise ValueError(f"batch {n} not a multiple of block {block}")
    grid = (n // block,)
    in_specs = [pl.BlockSpec((block,), lambda i: (i,))]
    for c in consts:
        ndim = c.ndim
        in_specs.append(pl.BlockSpec(c.shape, lambda i, _n=ndim: (0,) * _n))
    return pl.pallas_call(
        kernel_fn,
        out_shape=jax.ShapeDtypeStruct((n,), out_dtype),
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        interpret=True,
    )(x, *consts)
