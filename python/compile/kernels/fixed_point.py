"""Fixed-point (Q-format) emulation in JAX — mirrors ``rust/src/fixed/``.

The rust layer-3 golden models compute in signed Q-formats; these helpers
reproduce the same semantics (two's-complement raw words, saturating
quantization, round-half-away / round-half-even right shifts) on int32
words so the PWL Pallas kernel is *bit-exact* against the rust datapath.

All functions are jittable and usable inside Pallas kernels (they are
pure jnp ops).
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class QFormat:
    """Signed fixed-point format: 1 sign bit + int_bits + frac_bits.

    Mirrors ``rust/src/fixed/format.rs``.
    """

    int_bits: int
    frac_bits: int

    @property
    def width(self) -> int:
        return 1 + self.int_bits + self.frac_bits

    @property
    def max_raw(self) -> int:
        return (1 << (self.int_bits + self.frac_bits)) - 1

    @property
    def min_raw(self) -> int:
        return -(1 << (self.int_bits + self.frac_bits))

    @property
    def ulp(self) -> float:
        return 2.0 ** (-self.frac_bits)

    def __str__(self) -> str:  # pragma: no cover - cosmetic
        return f"S{self.int_bits or ''}.{self.frac_bits}"


#: The paper's formats (Table I / Table III).
S3_12 = QFormat(3, 12)
S2_13 = QFormat(2, 13)
S_15 = QFormat(0, 15)
S2_5 = QFormat(2, 5)
S_7 = QFormat(0, 7)


def quantize(values, fmt: QFormat, dtype=jnp.int32):
    """f64/f32 → raw words, round-half-away-from-zero, saturating.

    Matches ``Fx::from_f64`` (Round::NearestAway) in rust. Computation
    stays in the input dtype: f32 is exact here because all paper
    formats have raw magnitudes < 2^24.
    """
    scaled = jnp.asarray(values) * float(1 << fmt.frac_bits)
    # jnp.round is half-to-even; implement half-away explicitly.
    r = jnp.where(scaled >= 0, jnp.floor(scaled + 0.5), jnp.ceil(scaled - 0.5))
    r = jnp.clip(r, fmt.min_raw, fmt.max_raw)
    return r.astype(dtype)


def dequantize(raw, fmt: QFormat, dtype=jnp.float64):
    """raw words → real values (exact)."""
    return raw.astype(dtype) * fmt.ulp


def shift_right_nearest_away(v, sh: int):
    """Arithmetic right shift with round-half-away-from-zero.

    Matches ``Round::NearestAway.shift_right`` in rust. ``sh`` must be a
    static python int ≥ 0.
    """
    if sh == 0:
        return v
    half = 1 << (sh - 1)
    pos = (v + half) >> sh
    neg = -((-v + half) >> sh)
    return jnp.where(v >= 0, pos, neg)


def shift_right_nearest_even(v, sh: int):
    """Arithmetic right shift with round-half-to-even.

    Matches ``Round::NearestEven.shift_right`` in rust.
    """
    if sh == 0:
        return v
    floor = v >> sh
    rem = v - (floor << sh)
    half = 1 << (sh - 1)
    round_up = (rem > half) | ((rem == half) & ((floor & 1) == 1))
    return floor + round_up.astype(v.dtype)


def saturate(raw, fmt: QFormat):
    """Clamp raw words into the format's representable range."""
    return jnp.clip(raw, fmt.min_raw, fmt.max_raw)
