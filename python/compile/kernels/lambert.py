"""Method E — Lambert continued fraction as a Pallas kernel (float model).

The eq. (15) recurrence unrolled K times (the Fig 5 pipeline stages),
followed by the finite-NR division. The T values reach ~2×10⁶ for K=7 at
the domain edge; f32's 24-bit mantissa keeps the quotient within the
Table I error band (the rust wide-format datapath is the bit-accurate
authority — this kernel is the TPU compute model).
"""

from __future__ import annotations

import jax.numpy as jnp

from .common import DEFAULT_BLOCK, elementwise_call
from .velocity import div_nr_f32


def make_lambert_kernel(k_terms: int = 7, domain_max: float = 6.0):
    """Builds the kernel body for K fraction terms."""
    if not 1 <= k_terms <= 16:
        raise ValueError(f"K must be 1..16, got {k_terms}")
    kk = 2 * k_terms + 1

    def kernel(x_ref, o_ref):
        x = x_ref[...]
        neg = x < 0
        mag = jnp.abs(x)
        sat = mag >= domain_max
        x2 = mag * mag
        tm1 = jnp.ones_like(mag)
        t0 = jnp.full_like(mag, float(kk))
        for n in range(1, k_terms + 1):  # Fig 5: one stage per term
            c = float(kk - 2 * n)
            t = c * t0 + x2 * tm1
            tm1, t0 = t0, t
        y = div_nr_f32(mag * tm1, t0)
        y = jnp.clip(y, 0.0, 1.0)
        y = jnp.where(sat, 1.0, y)
        o_ref[...] = jnp.where(neg, -y, y).astype(jnp.float32)

    return kernel


def lambert_tanh_f32(x, k_terms: int = 7, domain_max: float = 6.0,
                     block: int = DEFAULT_BLOCK):
    """Applies the Lambert kernel to an f32 batch."""
    kernel = make_lambert_kernel(k_terms, domain_max)
    return elementwise_call(kernel, jnp.asarray(x, jnp.float32), jnp.float32, block)
