"""Method A — PWL interpolation as a *bit-exact* int32 Pallas kernel.

This is the flagship kernel: it reproduces the rust fixed-point datapath
(``rust/src/approx/pwl.rs`` / ``rust/src/hw/poly_dp.rs``) raw-word for
raw-word. Inputs are S3.12 raw words, outputs S.15 raw words; the LUT is
generated at trace time with the same round-half-even quantization as
``UniformLut::sample``.

TPU adaptation (DESIGN.md §5): the endpoint LUT (387 × int32 ≈ 1.5 KiB)
is embedded as a constant and broadcast into every block — the VMEM
analogue of the paper's hardwired bitmapped LUT (§IV.B). The gather +
integer MAC is the VPU analogue of the paper's two-bank fetch + one
multiplier datapath (Fig 3).
"""

from __future__ import annotations

import math

import jax.numpy as jnp
import numpy as np

from . import fixed_point as fp
from .common import DEFAULT_BLOCK, elementwise_call


def make_lut(step: float, domain_max: float, guard: int = 1) -> np.ndarray:
    """Endpoint LUT: tanh(i·step) quantized to S.15, round-half-even —
    mirrors ``UniformLut::sample`` (guard entry included)."""
    n = math.ceil(domain_max / step) + 1 + guard
    xs = np.arange(n) * step
    vals = np.tanh(xs) * (1 << fp.S_15.frac_bits)
    raw = np.clip(np.round(vals), fp.S_15.min_raw, fp.S_15.max_raw)  # np.round = half-even
    return raw.astype(np.int32)


def make_pwl_kernel(
    step: float = 1.0 / 64.0,
    domain_max: float = 6.0,
    in_fmt: fp.QFormat = fp.S3_12,
    out_fmt: fp.QFormat = fp.S_15,
):
    """Builds the kernel body. Returns ``(kernel, lut)`` where the LUT
    enters the pallas_call as a broadcast operand."""
    inv = 1.0 / step
    if inv != int(inv) or (int(inv) & (int(inv) - 1)):
        raise ValueError(f"step {step} must be a reciprocal power of two")
    step_shift = int(inv).bit_length() - 1
    t_bits = in_fmt.frac_bits - step_shift
    if t_bits < 0:
        raise ValueError("input precision coarser than LUT step")
    domain_raw = int(domain_max * (1 << in_fmt.frac_bits))

    # Perf (EXPERIMENTS.md §Perf iter 2): both interpolation endpoints
    # come from ONE one-hot matmul against a stacked [N-1, 2] table
    # (columns = lut[i], lut[i+1]) instead of two masked-sum lookups —
    # the MXU-shaped form, exact in f32 because |raw| < 2^24.
    import numpy as _np

    lut_np = make_lut(step, domain_max)
    n_lut = int(lut_np.shape[0])
    pair_table = jnp.asarray(
        _np.stack([lut_np[:-1], lut_np[1:]], axis=1).astype(_np.float32)
    )

    def kernel(x_ref, lut_ref, o_ref):
        x = x_ref[...]
        pair_v = lut_ref[...]
        neg = x < 0
        # |x| with two's-complement min clamped (Fx::abs saturates).
        mag = jnp.minimum(jnp.abs(x), in_fmt.max_raw)
        sat = mag >= domain_raw
        idx = jnp.clip(mag >> t_bits, 0, n_lut - 2)
        t = mag & ((1 << t_bits) - 1)
        iota = jnp.arange(n_lut - 1, dtype=jnp.int32)
        onehot = (idx[:, None] == iota[None, :]).astype(jnp.float32)
        pair = onehot @ pair_v  # [block, 2] — exact (values < 2^24)
        y0 = pair[:, 0].astype(jnp.int32)
        y1 = pair[:, 1].astype(jnp.int32)
        # y = y0 + (y1-y0)·t, product kept wide (frac 15+t_bits), one
        # round-half-even narrow — identical to the rust FxWide path.
        acc = (y0.astype(jnp.int32) << t_bits) + (y1 - y0) * t
        y = fp.shift_right_nearest_even(acc, t_bits)
        y = jnp.clip(y, 0, out_fmt.max_raw)
        y = jnp.where(sat, out_fmt.max_raw, y)
        o_ref[...] = jnp.where(neg, -y, y).astype(jnp.int32)

    return kernel, pair_table


def pwl_tanh_raw(x_raw, step: float = 1.0 / 64.0, domain_max: float = 6.0,
                 block: int = DEFAULT_BLOCK):
    """Applies the bit-exact PWL kernel to a batch of S3.12 raw words."""
    kernel, table = make_pwl_kernel(step, domain_max)
    return elementwise_call(kernel, x_raw, jnp.int32, block, consts=(table,))


def pwl_tanh_f32(x, step: float = 1.0 / 64.0, domain_max: float = 6.0,
                 block: int = DEFAULT_BLOCK):
    """Float front-end: quantize → fixed-point kernel → dequantize.
    This is what the L2 model graphs call (the accelerator's fixed-point
    boundary made explicit)."""
    x_raw = fp.quantize(x, fp.S3_12)
    y_raw = pwl_tanh_raw(x_raw, step, domain_max, block)
    return fp.dequantize(y_raw, fp.S_15, jnp.float32)
