"""Pure-jnp oracles for every approximation — the correctness yardstick.

Each ``*_ref`` mirrors the rust ``eval_f64`` math model (same anchor
placement, same saturation, same linear-NR divider model where the rust
model uses one), evaluated vectorized in float64. The pytest suite
asserts (a) kernel ↔ oracle agreement and (b) oracle ↔ numpy-tanh error
bands matching the paper's Table I.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

#: NR iteration count shared with the rust divider model
#: (``approx::newton::NR_ITERS``).
NR_ITERS = 3


def tanh_ref(x):
    """The reference: numpy/XLA tanh in float64 (paper §III.C)."""
    return jnp.tanh(jnp.asarray(x, jnp.float64))


def _odd_saturating(x, domain_max, core):
    """Odd symmetry + domain saturation + output clamp shared by all
    methods (a fixed-point output format cannot exceed ±1, so every
    hardware datapath clamps; low-K continued fractions overshoot near
    the domain edge without it)."""
    x = jnp.asarray(x, jnp.float64)
    mag = jnp.abs(x)
    y = jnp.clip(core(jnp.minimum(mag, domain_max)), 0.0, 1.0)
    y = jnp.where(mag >= domain_max, 1.0, y)
    return jnp.sign(x) * y


def div_nr(num, den, iters: int = NR_ITERS):
    """The finite-iteration Newton-Raphson divider model
    (``approx::newton::div_f64``): normalize → linear seed → NR steps."""
    e = jnp.floor(jnp.log2(den)) + 1.0
    m = den / jnp.exp2(e)
    xk = 48.0 / 17.0 - 32.0 / 17.0 * m
    for _ in range(iters):
        xk = xk * (2.0 - m * xk)
    return num * xk / jnp.exp2(e)


def pwl_ref(x, step: float, domain_max: float = 6.0):
    """Method A: piecewise-linear interpolation (paper eq. 2)."""

    def core(mag):
        k = jnp.floor(mag / step)
        a = k * step
        t = (mag - a) / step
        y0 = jnp.tanh(a)
        y1 = jnp.tanh(a + step)
        return y0 + (y1 - y0) * t

    return _odd_saturating(x, domain_max, core)


def taylor_ref(x, step: float, terms: int, domain_max: float = 6.0):
    """Methods B1/B2: Taylor expansion around interval centres with
    runtime-derived coefficients (paper eqs. 3-7)."""

    def core(mag):
        k = jnp.floor(mag / step)
        xc = (k + 0.5) * step
        dx = mag - xc
        t = jnp.tanh(xc)
        d1 = 1.0 - t * t
        c2 = -t * d1
        c3 = -d1 * (1.0 - 3.0 * t * t) / 3.0
        acc = jnp.zeros_like(mag)
        if terms >= 4:
            acc = c3
        if terms >= 3:
            acc = c2 + dx * acc
        acc = d1 + dx * acc
        return t + dx * acc

    return _odd_saturating(x, domain_max, core)


def catmull_rom_ref(x, step: float, domain_max: float = 6.0):
    """Method C: uniform cubic Catmull-Rom spline (paper eqs. 8/17)."""

    def core(mag):
        k = jnp.floor(mag / step)
        t = mag / step - k
        t2, t3 = t * t, t * t * t
        b0 = 0.5 * (-t3 + 2.0 * t2 - t)
        b1 = 0.5 * (3.0 * t3 - 5.0 * t2 + 2.0)
        b2 = 0.5 * (-3.0 * t3 + 4.0 * t2 + t)
        b3 = 0.5 * (t3 - t2)
        p = lambda i: jnp.tanh((k + i) * step)  # noqa: E731
        return b0 * p(-1.0) + b1 * p(0.0) + b2 * p(1.0) + b3 * p(2.0)

    return _odd_saturating(x, domain_max, core)


def velocity_ref(x, threshold: float, domain_max: float = 6.0):
    """Method D: velocity-factor expansion (paper eqs. 9-13) with the
    eq. (10) linear compensation below ``threshold``."""

    def core(mag):
        scale = 1.0 / threshold
        a = jnp.floor(mag * scale) / scale
        b = mag - a
        f = jnp.exp(2.0 * a)  # product of stored factors = e^{2a}
        t = div_nr(f - 1.0, f + 1.0)
        return t + b * (1.0 - t * t)

    return _odd_saturating(x, domain_max, core)


def lambert_ref(x, k_terms: int, domain_max: float = 6.0):
    """Method E: Lambert continued fraction via the eq. (15) recurrence."""

    def core(mag):
        x2 = mag * mag
        kk = 2 * k_terms + 1
        tm1 = jnp.ones_like(mag)
        t0 = jnp.full_like(mag, float(kk))
        for n in range(1, k_terms + 1):
            c = float(kk - 2 * n)
            t = c * t0 + x2 * tm1
            tm1, t0 = t0, t
        return div_nr(mag * tm1, t0)

    return _odd_saturating(x, domain_max, core)


def sigmoid_ref(x):
    """Reference sigmoid (for the LSTM model tests)."""
    return 1.0 / (1.0 + np.exp(-np.asarray(x, np.float64)))


#: Table I configurations: (name, ref_fn, kwargs) — mirrors
#: ``approx::table1_suite`` in rust.
TABLE1 = [
    ("pwl", pwl_ref, {"step": 1.0 / 64.0}),
    ("taylor1", taylor_ref, {"step": 1.0 / 16.0, "terms": 3}),
    ("taylor2", taylor_ref, {"step": 1.0 / 8.0, "terms": 4}),
    ("catmull_rom", catmull_rom_ref, {"step": 1.0 / 16.0}),
    ("velocity", velocity_ref, {"threshold": 1.0 / 128.0}),
    ("lambert", lambert_ref, {"k_terms": 7}),
]
