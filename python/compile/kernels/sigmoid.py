"""Sigmoid via the tanh identity σ(x) = (1 + tanh(x/2))/2 — the gate
nonlinearity the L2 LSTM uses, derived from any approximation kernel
(mirrors ``rust/src/approx/sigmoid.rs``)."""

from __future__ import annotations

import jax.numpy as jnp

from . import KERNELS


def make_sigmoid_kernel(method: str = "pwl"):
    """Returns σ(x) built on the named tanh approximation kernel."""
    tanh_fn = KERNELS[method]

    def sigmoid(x):
        x = jnp.asarray(x, jnp.float32)
        return 0.5 * (1.0 + tanh_fn(0.5 * x))

    return sigmoid


def sigmoid_f32(x, method: str = "pwl"):
    """One-shot sigmoid evaluation."""
    return make_sigmoid_kernel(method)(x)
