"""Methods B1/B2 — Taylor expansion as a Pallas kernel (float math model).

The anchor LUT stores tanh at interval *centres* (matching the rust
model); coefficients are derived in-kernel from the stored value via the
paper's eqs. (5)-(7) — the datapath trick that keeps the LUT at one word
per anchor. Computation is f32 (the TPU VPU's native width); bit-exact
fixed-point is exercised by the PWL kernel, and this kernel is validated
against the f64 oracle within the f32 rounding band.
"""

from __future__ import annotations

import math

import jax.numpy as jnp
import numpy as np

from .common import DEFAULT_BLOCK, elementwise_call, lut_lookup


def make_anchor_lut(step: float, domain_max: float, guard: int = 1) -> np.ndarray:
    """Anchors tanh((i + ½)·step) in f32 — mirrors the rust LUT."""
    n = math.ceil(domain_max / step) + 1 + guard
    xs = (np.arange(n) + 0.5) * step
    return np.tanh(xs).astype(np.float32)


def make_taylor_kernel(step: float = 1.0 / 16.0, terms: int = 3,
                       domain_max: float = 6.0):
    """Builds the kernel body for a (step, terms) configuration."""
    if terms not in (2, 3, 4):
        raise ValueError(f"terms must be 2..4, got {terms}")
    lut = jnp.asarray(make_anchor_lut(step, domain_max))
    n_lut = int(lut.shape[0])
    inv_step = 1.0 / step

    def kernel(x_ref, lut_ref, o_ref):
        x = x_ref[...]
        lut_v = lut_ref[...]
        neg = x < 0
        mag = jnp.abs(x)
        sat = mag >= domain_max
        k = jnp.clip(jnp.floor(mag * inv_step).astype(jnp.int32), 0, n_lut - 1)
        xc = (k.astype(jnp.float32) + 0.5) * step
        dx = mag - xc
        # Runtime coefficients from the stored tanh value (eqs. 5-7).
        t = lut_lookup(lut_v, k)
        d1 = 1.0 - t * t
        c2 = -t * d1
        acc = jnp.zeros_like(mag)
        if terms >= 4:
            acc = -d1 * (1.0 - 3.0 * t * t) * (1.0 / 3.0)
        if terms >= 3:
            acc = c2 + dx * acc
        acc = d1 + dx * acc
        y = t + dx * acc
        y = jnp.clip(y, 0.0, 1.0)
        y = jnp.where(sat, 1.0, y)
        o_ref[...] = jnp.where(neg, -y, y).astype(jnp.float32)

    return kernel, lut


def taylor_tanh_f32(x, step: float = 1.0 / 16.0, terms: int = 3,
                    domain_max: float = 6.0, block: int = DEFAULT_BLOCK):
    """Applies the Taylor kernel to an f32 batch."""
    kernel, lut = make_taylor_kernel(step, terms, domain_max)
    return elementwise_call(kernel, jnp.asarray(x, jnp.float32), jnp.float32, block,
                            consts=(lut,))
