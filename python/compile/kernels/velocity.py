"""Method D — velocity-factor expansion as a Pallas kernel (float model).

The stored registers hold f_{2^k} = e^{2·2^k}; the kernel selects and
multiplies them per input bit (paper Fig 4), recovers tanh with the
eq. (12) division through the same finite-NR divider model as the rust
datapath, and applies the eq. (10) linear compensation. Unlike the f64
oracle (which collapses the product to exp(2a)), this kernel performs
the actual per-bit register product — the Fig 4 structure — so the
register quantization story carries over.
"""

from __future__ import annotations

import math

import jax.numpy as jnp
import numpy as np

from .common import DEFAULT_BLOCK, elementwise_call
from .ref import NR_ITERS


def make_vf_registers(threshold: float, domain_max: float) -> tuple[np.ndarray, int, int]:
    """Registers e^{2·2^k} for k = kmax … −m, highest weight first —
    mirrors ``Velocity::new``. Returns (registers, m, kmax)."""
    m = int(round(-math.log2(threshold)))
    kmax = math.ceil(math.log2(domain_max)) - 1
    ks = list(range(kmax, -m - 1, -1))
    regs = np.exp([2.0 * (2.0 ** k) for k in ks]).astype(np.float32)
    return regs, m, kmax


def div_nr_f32(num, den, iters: int = NR_ITERS):
    """f32 finite-NR divider (same seed/iteration schedule as rust)."""
    e = jnp.floor(jnp.log2(den)) + 1.0
    scale = jnp.exp2(-e)
    mant = den * scale
    xk = jnp.float32(48.0 / 17.0) - jnp.float32(32.0 / 17.0) * mant
    for _ in range(iters):
        xk = xk * (2.0 - mant * xk)
    return num * xk * scale


def make_velocity_kernel(threshold: float = 1.0 / 128.0, domain_max: float = 6.0,
                         frac_bits: int = 12):
    """Builds the kernel body; inputs are treated on the S?.frac_bits
    grid (matching the fixed-point front end)."""
    regs, m, kmax = make_vf_registers(threshold, domain_max)
    regs = jnp.asarray(regs)
    scale = float(1 << frac_bits)
    res_bits = max(frac_bits - m, 0)

    def kernel(x_ref, regs_ref, o_ref):
        x = x_ref[...]
        regs_v = regs_ref[...]
        neg = x < 0
        mag = jnp.abs(x)
        sat = mag >= domain_max
        raw = jnp.floor(mag * scale).astype(jnp.int32)
        coarse = raw >> res_bits  # units of θ
        # Residue kept in f32 (not truncated to the S?.frac grid): for
        # float inputs the sub-ulp part still participates in the
        # eq. (10) compensation, mirroring b = x − a in the paper.
        a = (coarse << res_bits).astype(jnp.float32) / scale
        residue = mag - a
        # Per-bit register product (Fig 4 mux + multiplier chain).
        f = jnp.ones_like(mag)
        for i, k in enumerate(range(kmax, -m - 1, -1)):
            bitpos = k + m  # bit position within `coarse`
            bit = (coarse >> bitpos) & 1
            f = f * jnp.where(bit == 1, regs_v[i], jnp.float32(1.0))
        t = div_nr_f32(f - 1.0, f + 1.0)
        y = t + residue * (1.0 - t * t)
        y = jnp.clip(y, 0.0, 1.0)
        y = jnp.where(sat, 1.0, y)
        o_ref[...] = jnp.where(neg, -y, y).astype(jnp.float32)

    return kernel, regs


def velocity_tanh_f32(x, threshold: float = 1.0 / 128.0, domain_max: float = 6.0,
                      block: int = DEFAULT_BLOCK):
    """Applies the velocity-factor kernel to an f32 batch."""
    kernel, regs = make_velocity_kernel(threshold, domain_max)
    return elementwise_call(kernel, jnp.asarray(x, jnp.float32), jnp.float32, block,
                            consts=(regs,))
