"""L2 — JAX compute graphs built on the L1 kernels.

Two graph families, both AOT-lowered to HLO text by :mod:`compile.aot`:

1. **Batched activation graphs** — ``tanh_graph(method, n)``: the
   activation-accelerator surface the rust coordinator serves (one
   compiled executable per (method, batch) pair), plus the bit-exact
   int32 PWL raw-word graph used for the rust↔pallas cross-check.

2. **LSTM inference graphs** — the paper's motivating workload (§I:
   "some applications require sequence modelling and use RNNs and LSTM
   topologies. Tanh is still an integral part of these"). A small LSTM
   is *trained at build time* with exact f32 tanh (the usual
   train-in-float, deploy-fixed-point flow), then exported twice: with
   the exact tanh and with an approximation kernel in every tanh/sigmoid
   position — so the rust layer can measure end-to-end accuracy impact
   and serving throughput of each approximation.

The toy task is sign-of-running-sum sequence classification: inputs are
random ±1 steps, the label is whether the final prefix sum is positive —
learnable by a small LSTM in a few hundred SGD steps, and sensitive to
the tanh path (both gates and cell output use it).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import KERNELS, pwl_tanh_raw

# ---------------------------------------------------------------------------
# Elementwise adaptation: the 1-D block kernels over arbitrary 2-D tensors.
# ---------------------------------------------------------------------------

BLOCK = 256


def apply_elementwise(fn1d, x):
    """Applies a 1-D batch kernel to a tensor of any shape by
    flattening + padding to the kernel's block multiple."""
    flat = x.reshape(-1)
    n = flat.shape[0]
    padded = (n + BLOCK - 1) // BLOCK * BLOCK
    if padded != n:
        flat = jnp.pad(flat, (0, padded - n))
    out = fn1d(flat)
    return out[:n].reshape(x.shape)


def make_tanh_fn(method: str | None):
    """Returns an elementwise tanh callable: the exact jnp.tanh for
    ``None``/"ref", or the named approximation kernel."""
    if method in (None, "ref"):
        return jnp.tanh
    kernel = KERNELS[method]
    return functools.partial(apply_elementwise, kernel)


def make_sigmoid_fn(tanh_fn):
    """σ(x) = (1 + tanh(x/2))/2 — the hardware identity
    (``approx::sigmoid`` in rust): gates reuse the tanh unit."""

    def sigmoid(x):
        return 0.5 * (1.0 + tanh_fn(0.5 * x))

    return sigmoid


# ---------------------------------------------------------------------------
# Activation graphs (the serving surface).
# ---------------------------------------------------------------------------


def tanh_graph(method: str, n: int):
    """f32[n] → (f32[n],) activation graph for one method."""
    tanh_fn = make_tanh_fn(method)

    def fn(x):
        return (tanh_fn(x),)

    return fn, (jax.ShapeDtypeStruct((n,), jnp.float32),)


def tanh_raw_graph(n: int):
    """int32[n] → (int32[n],) bit-exact PWL raw-word graph (S3.12 →
    S.15) — the rust↔pallas cross-validation surface."""

    def fn(x_raw):
        return (pwl_tanh_raw(x_raw),)

    return fn, (jax.ShapeDtypeStruct((n,), jnp.int32),)


# ---------------------------------------------------------------------------
# LSTM (paper §I motivation).
# ---------------------------------------------------------------------------


def init_lstm_params(seed: int, input_dim: int, hidden: int, out_dim: int):
    """Glorot-ish LSTM + readout parameters as a flat dict of f32."""
    rng = np.random.default_rng(seed)

    def mat(shape, scale):
        return (rng.standard_normal(shape) * scale).astype(np.float32)

    d, h = input_dim, hidden
    s_in = 1.0 / np.sqrt(d + h)
    return {
        # gates packed [i, f, g, o] along the output axis.
        "w_x": mat((d, 4 * h), s_in),
        "w_h": mat((h, 4 * h), s_in),
        "b": np.zeros(4 * h, np.float32),
        "w_out": mat((h, out_dim), 1.0 / np.sqrt(h)),
        "b_out": np.zeros(out_dim, np.float32),
    }


def lstm_cell(params, x, h, c, tanh_fn):
    """One LSTM step. ``x``: [b, d], ``h``/``c``: [b, hidden].

    All four gates and the cell nonlinearity route through ``tanh_fn``
    (sigmoid via the tanh identity) — every nonlinear op in the cell
    exercises the approximation under test.
    """
    sigmoid = make_sigmoid_fn(tanh_fn)
    hidden = h.shape[-1]
    z = x @ params["w_x"] + h @ params["w_h"] + params["b"]
    i = sigmoid(z[:, 0 * hidden : 1 * hidden])
    f = sigmoid(z[:, 1 * hidden : 2 * hidden])
    g = tanh_fn(z[:, 2 * hidden : 3 * hidden])
    o = sigmoid(z[:, 3 * hidden : 4 * hidden])
    c_new = f * c + i * g
    h_new = o * tanh_fn(c_new)
    return h_new, c_new


def lstm_logits(params, seq, tanh_fn):
    """Runs the LSTM over ``seq`` [b, t, d] and returns logits [b, out]."""
    b, t, _ = seq.shape
    hidden = params["w_h"].shape[0]
    h = jnp.zeros((b, hidden), jnp.float32)
    c = jnp.zeros((b, hidden), jnp.float32)
    for step in range(t):  # static unroll: kernels stay traceable
        h, c = lstm_cell(params, seq[:, step, :], h, c, tanh_fn)
    return h @ params["w_out"] + params["b_out"]


def lstm_cell_graph(params, method: str | None, batch: int, input_dim: int, hidden: int):
    """(x, h, c) → (h', c') single-step graph with baked weights — the
    serving artifact (decode-step shape, the LSTM analogue of a
    KV-cache-style stepwise server)."""
    tanh_fn = make_tanh_fn(method)
    p = {k: jnp.asarray(v) for k, v in params.items()}

    def fn(x, h, c):
        h2, c2 = lstm_cell(p, x, h, c, tanh_fn)
        return (h2, c2)

    args = (
        jax.ShapeDtypeStruct((batch, input_dim), jnp.float32),
        jax.ShapeDtypeStruct((batch, hidden), jnp.float32),
        jax.ShapeDtypeStruct((batch, hidden), jnp.float32),
    )
    return fn, args


def lstm_logits_graph(params, method: str | None, batch: int, seq_len: int, input_dim: int):
    """seq → logits full-sequence graph with baked weights."""
    tanh_fn = make_tanh_fn(method)
    p = {k: jnp.asarray(v) for k, v in params.items()}

    def fn(seq):
        return (lstm_logits(p, seq, tanh_fn),)

    return fn, (jax.ShapeDtypeStruct((batch, seq_len, input_dim), jnp.float32),)


# ---------------------------------------------------------------------------
# Build-time training on the toy task.
# ---------------------------------------------------------------------------


def make_toy_batch(rng, batch: int, seq_len: int, input_dim: int):
    """Sign-of-running-sum task: ±1 step sequences, binary label."""
    steps = rng.choice([-1.0, 1.0], size=(batch, seq_len, input_dim)).astype(np.float32)
    labels = (steps.sum(axis=(1, 2)) > 0).astype(np.int32)
    return steps, labels


def train_toy_lstm(
    seed: int = 42,
    steps: int = 300,
    batch: int = 64,
    seq_len: int = 16,
    input_dim: int = 4,
    hidden: int = 64,
    lr: float = 0.05,
    log_every: int = 50,
    verbose: bool = False,
):
    """Trains the toy LSTM with exact tanh; returns (params, loss_curve,
    final_accuracy). A few hundred SGD steps reach >95% accuracy."""
    params = init_lstm_params(seed, input_dim, hidden, out_dim=2)
    params = {k: jnp.asarray(v) for k, v in params.items()}
    rng = np.random.default_rng(seed)

    def loss_fn(p, seq, labels):
        logits = lstm_logits(p, seq, jnp.tanh)
        logp = jax.nn.log_softmax(logits)
        return -jnp.mean(jnp.take_along_axis(logp, labels[:, None], axis=1))

    grad_fn = jax.jit(jax.value_and_grad(loss_fn))
    curve = []
    for step in range(steps):
        seq, labels = make_toy_batch(rng, batch, seq_len, input_dim)
        loss, grads = grad_fn(params, jnp.asarray(seq), jnp.asarray(labels))
        params = jax.tree.map(lambda p, g: p - lr * g, params, grads)
        curve.append(float(loss))
        if verbose and (step % log_every == 0 or step == steps - 1):
            print(f"  step {step:4d} loss {float(loss):.4f}")
    # final eval
    seq, labels = make_toy_batch(rng, 512, seq_len, input_dim)
    logits = lstm_logits(params, jnp.asarray(seq), jnp.tanh)
    acc = float(jnp.mean((jnp.argmax(logits, axis=1) == jnp.asarray(labels))))
    return {k: np.asarray(v) for k, v in params.items()}, curve, acc
