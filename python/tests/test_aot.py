"""AOT pipeline tests: HLO text is parseable-shaped, manifest matches
emitted files, and the lowering round-trips through the XLA client the
same way the rust loader will."""

import json
import pathlib

import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model as M

ARTIFACTS = pathlib.Path(__file__).resolve().parents[2] / "artifacts"


class TestToHloText:
    def test_tanh_graph_lowering(self):
        fn, args = M.tanh_graph("taylor1", 256)
        text = aot.to_hlo_text(fn, args)
        assert text.startswith("HloModule")
        assert "ENTRY" in text
        # return_tuple=True → root is a tuple
        assert "tuple(" in text.replace(" ", "")

    def test_raw_graph_lowering_is_integer(self):
        fn, args = M.tanh_raw_graph(256)
        text = aot.to_hlo_text(fn, args)
        assert "s32[256]" in text

    def test_lowered_graph_still_executes(self):
        # The jitted fn used for lowering must agree with eager.
        fn, _ = M.tanh_graph("pwl", 256)
        x = jnp.linspace(-3, 3, 256, dtype=jnp.float32)
        import jax

        (eager,) = fn(x)
        (jitted,) = jax.jit(fn)(x)
        np.testing.assert_array_equal(np.asarray(eager), np.asarray(jitted))


@pytest.mark.skipif(not (ARTIFACTS / "manifest.json").exists(),
                    reason="run `make artifacts` first")
class TestEmittedArtifacts:
    def test_manifest_files_exist(self):
        manifest = json.loads((ARTIFACTS / "manifest.json").read_text())
        assert len(manifest) >= 14
        for name, entry in manifest.items():
            assert (ARTIFACTS / entry["file"]).exists(), name

    def test_expected_artifact_set(self):
        manifest = json.loads((ARTIFACTS / "manifest.json").read_text())
        for method in ["pwl", "taylor1", "taylor2", "catmull_rom", "velocity", "lambert", "ref"]:
            assert f"tanh_{method}_{aot.TANH_N}" in manifest
        assert f"tanh_pwl_raw_{aot.TANH_N}" in manifest
        for m in ["ref", "pwl", "taylor1"]:
            assert f"lstm_cell_{m}" in manifest
            assert f"lstm_logits_{m}" in manifest

    def test_test_vectors_consistency(self):
        v = json.loads((ARTIFACTS / "test_vectors.json").read_text())
        xs = np.asarray(v["tanh_input_f32"], np.float32)
        ref = np.asarray(v["tanh_expected"]["ref"])
        np.testing.assert_allclose(ref, np.tanh(xs), atol=1e-6)
        # approximations stay within the paper band of the reference
        for method, band in [("pwl", 2e-4), ("taylor1", 5e-5), ("lambert", 1e-4)]:
            approx = np.asarray(v["tanh_expected"][method])
            assert np.max(np.abs(approx - np.tanh(xs))) < band, method

    def test_no_elided_constants(self):
        # The default HLO printer elides big dense literals as
        # `constant({...})`; the deployment parser reads those back as
        # ZEROS. aot.to_hlo_text must print full constants.
        for f in ARTIFACTS.glob("*.hlo.txt"):
            assert "{...}" not in f.read_text(), f"{f.name} has elided constants"

    def test_no_gather_in_emitted_hlo(self):
        # The deployment bridge (HLO text → xla_extension 0.5.1)
        # mis-executes `gather`; LUT fetches must lower to the one-hot
        # matmul form instead (see kernels/common.py::lut_lookup).
        for f in ARTIFACTS.glob("*.hlo.txt"):
            text = f.read_text()
            assert " gather(" not in text, f"{f.name} contains a gather op"

    def test_training_record(self):
        v = json.loads((ARTIFACTS / "test_vectors.json").read_text())
        tr = v["training"]
        assert tr["final_accuracy"] > 0.85
        assert tr["loss_curve"][0] > tr["loss_curve"][-1]


if __name__ == "__main__":
    pytest.main([__file__, "-q"])
