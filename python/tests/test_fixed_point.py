"""Tests for the jnp fixed-point emulation layer against numpy goldens
and against the semantics documented for the rust ``fixed`` module."""

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.kernels import fixed_point as fp


class TestQFormat:
    def test_paper_formats(self):
        assert fp.S3_12.width == 16
        assert fp.S2_13.width == 16
        assert fp.S_15.width == 16
        assert fp.S2_5.width == 8
        assert fp.S_7.width == 8

    def test_ranges(self):
        assert fp.S3_12.min_raw == -(1 << 15)
        assert fp.S3_12.max_raw == (1 << 15) - 1
        assert fp.S_15.ulp == 2.0**-15


class TestQuantize:
    def test_exact_values(self):
        raw = np.asarray(fp.quantize(np.array([0.0, 0.5, -0.5, 1.0]), fp.S3_12))
        np.testing.assert_array_equal(raw, [0, 2048, -2048, 4096])

    def test_saturates(self):
        raw = np.asarray(fp.quantize(np.array([100.0, -100.0]), fp.S3_12))
        np.testing.assert_array_equal(raw, [fp.S3_12.max_raw, fp.S3_12.min_raw])

    def test_half_away_rounding(self):
        # 0.5 ulp cases round away from zero (rust Round::NearestAway).
        ulp = fp.S3_12.ulp
        raw = np.asarray(fp.quantize(np.array([0.5 * ulp, -0.5 * ulp, 1.5 * ulp]), fp.S3_12))
        np.testing.assert_array_equal(raw, [1, -1, 2])

    @settings(max_examples=200, deadline=None)
    @given(st.floats(min_value=-7.9, max_value=7.9, allow_nan=False))
    def test_roundtrip_error_half_ulp(self, v):
        raw = np.asarray(fp.quantize(np.array([v], np.float64), fp.S3_12))
        back = float(np.asarray(fp.dequantize(raw, fp.S3_12))[0])
        # jnp computes in f32 (x64 disabled): allow the f32
        # representation error of v on top of the half-ulp bound.
        f32_eps = abs(v) * 2.0**-23
        assert abs(back - v) <= fp.S3_12.ulp / 2 + f32_eps + 1e-12


class TestShifts:
    def test_nearest_away_halfway(self):
        import jax.numpy as jnp

        v = jnp.array([5, -5, 7, -7], jnp.int32)
        out = np.asarray(fp.shift_right_nearest_away(v, 1))
        np.testing.assert_array_equal(out, [3, -3, 4, -4])

    def test_nearest_even_halfway(self):
        import jax.numpy as jnp

        v = jnp.array([5, 7, -5], jnp.int32)
        out = np.asarray(fp.shift_right_nearest_even(v, 1))
        # 2.5 -> 2 (even), 3.5 -> 4 (even), -2.5 -> -2 (even)
        np.testing.assert_array_equal(out, [2, 4, -2])

    def test_zero_shift_identity(self):
        import jax.numpy as jnp

        v = jnp.array([3, -3], jnp.int32)
        np.testing.assert_array_equal(np.asarray(fp.shift_right_nearest_away(v, 0)), [3, -3])
        np.testing.assert_array_equal(np.asarray(fp.shift_right_nearest_even(v, 0)), [3, -3])

    @settings(max_examples=200, deadline=None)
    @given(
        st.integers(min_value=-(1 << 24), max_value=(1 << 24) - 1),
        st.integers(min_value=1, max_value=8),
    )
    def test_shift_matches_float_rounding(self, v, sh):
        import jax.numpy as jnp

        got = int(np.asarray(fp.shift_right_nearest_away(jnp.array([v], jnp.int32), sh))[0])
        exact = v / (1 << sh)
        # round half away from zero
        want = int(np.floor(exact + 0.5)) if exact >= 0 else int(np.ceil(exact - 0.5))
        assert got == want, f"v={v} sh={sh}: {got} vs {want}"


class TestSaturate:
    def test_clamps_both_ends(self):
        import jax.numpy as jnp

        v = jnp.array([1 << 20, -(1 << 20), 5], jnp.int32)
        out = np.asarray(fp.saturate(v, fp.S_15))
        np.testing.assert_array_equal(out, [fp.S_15.max_raw, fp.S_15.min_raw, 5])


if __name__ == "__main__":
    pytest.main([__file__, "-q"])
