"""L1 kernel correctness: every Pallas kernel against its pure-jnp
oracle, against numpy tanh (paper Table I error bands), and — for the
bit-exact PWL kernel — against a numpy reimplementation of the rust
integer datapath.

The hypothesis sweeps vary batch shapes, parameter settings and input
distributions, asserting ``assert_allclose`` against ref.py exactly as
the session architecture prescribes.
"""

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.kernels import (
    KERNELS,
    catmull_rom_tanh_f32,
    lambert_tanh_f32,
    pwl_tanh_raw,
    taylor_tanh_f32,
    velocity_tanh_f32,
)
from compile.kernels import fixed_point as fp
from compile.kernels import ref
from compile.kernels.pwl import make_lut

RNG = np.random.default_rng(0xC0FFEE)


def grid(n=2048, lo=-7.0, hi=7.0):
    return np.linspace(lo, hi, n).astype(np.float32)


class TestAgainstTanh:
    """Paper Table I error bands (float path: no 15-bit output
    quantization, so bands are the algorithmic error + saturation-to-1
    at the domain edge ≈ 1.23e-5)."""

    BANDS = {
        "pwl": 1.5e-4,  # includes S3.12 input-quantization boundary
        "taylor1": 3e-5,
        "taylor2": 3e-5,
        "catmull_rom": 3e-5,
        "velocity": 5e-5,
        "lambert": 7e-5,
    }

    @pytest.mark.parametrize("name", list(KERNELS))
    def test_error_band(self, name):
        x = grid()
        y = np.asarray(KERNELS[name](x))
        err = np.max(np.abs(y - np.tanh(x.astype(np.float64))))
        assert err < self.BANDS[name], f"{name}: {err:.3e}"

    @pytest.mark.parametrize("name", list(KERNELS))
    def test_odd_symmetry(self, name):
        x = grid(512)
        y_pos = np.asarray(KERNELS[name](x))
        y_neg = np.asarray(KERNELS[name](-x))
        np.testing.assert_allclose(y_pos, -y_neg, atol=1e-7)

    @pytest.mark.parametrize("name", list(KERNELS))
    def test_saturation_beyond_domain(self, name):
        x = np.full(256, 6.5, np.float32)
        y = np.asarray(KERNELS[name](x))
        np.testing.assert_allclose(y, 1.0, atol=4e-5)  # S.15 max = 1 − 2^-15


class TestAgainstOracles:
    """Kernel ↔ ref.py agreement (the f64 oracle, f32 rounding band)."""

    def test_taylor_matches_ref(self):
        x = grid()
        y = np.asarray(taylor_tanh_f32(x, step=1 / 16, terms=3))
        want = np.asarray(ref.taylor_ref(x, step=1 / 16, terms=3))
        np.testing.assert_allclose(y, want, atol=3e-6)

    def test_taylor_cubic_matches_ref(self):
        x = grid()
        y = np.asarray(taylor_tanh_f32(x, step=1 / 8, terms=4))
        want = np.asarray(ref.taylor_ref(x, step=1 / 8, terms=4))
        np.testing.assert_allclose(y, want, atol=3e-6)

    def test_catmull_rom_matches_ref(self):
        x = grid()
        y = np.asarray(catmull_rom_tanh_f32(x, step=1 / 16))
        want = np.asarray(ref.catmull_rom_ref(x, step=1 / 16))
        np.testing.assert_allclose(y, want, atol=3e-6)

    def test_velocity_matches_ref(self):
        x = grid()
        y = np.asarray(velocity_tanh_f32(x, threshold=1 / 128))
        want = np.asarray(ref.velocity_ref(x, threshold=1 / 128))
        # The kernel does the per-bit register product in f32 (Fig 4);
        # the oracle collapses it to exp(2a) in f64.
        np.testing.assert_allclose(y, want, atol=1e-5)

    def test_lambert_matches_ref(self):
        x = grid()
        y = np.asarray(lambert_tanh_f32(x, k_terms=7))
        want = np.asarray(ref.lambert_ref(x, k_terms=7))
        # f32 recurrence vs f64: T_K reaches ~2e6, so ~1e-5 relative.
        np.testing.assert_allclose(y, want, atol=5e-5)

    @settings(max_examples=20, deadline=None)
    @given(
        terms=st.sampled_from([2, 3, 4]),
        log_inv_step=st.integers(min_value=3, max_value=6),
        n_blocks=st.integers(min_value=1, max_value=4),
    )
    def test_taylor_hypothesis_sweep(self, terms, log_inv_step, n_blocks):
        step = 2.0**-log_inv_step
        n = 256 * n_blocks
        x = RNG.uniform(-7, 7, n).astype(np.float32)
        y = np.asarray(taylor_tanh_f32(x, step=step, terms=terms))
        want = np.asarray(ref.taylor_ref(x, step=step, terms=terms))
        np.testing.assert_allclose(y, want, atol=1e-5)

    @settings(max_examples=15, deadline=None)
    @given(k=st.integers(min_value=2, max_value=9))
    def test_lambert_hypothesis_sweep(self, k):
        x = RNG.uniform(-6.5, 6.5, 512).astype(np.float32)
        y = np.asarray(lambert_tanh_f32(x, k_terms=k))
        want = np.asarray(ref.lambert_ref(x, k_terms=k))
        np.testing.assert_allclose(y, want, atol=2e-4)

    @settings(max_examples=15, deadline=None)
    @given(log_inv_thr=st.integers(min_value=4, max_value=9))
    def test_velocity_hypothesis_sweep(self, log_inv_thr):
        thr = 2.0**-log_inv_thr
        x = RNG.uniform(-6.5, 6.5, 512).astype(np.float32)
        y = np.asarray(velocity_tanh_f32(x, threshold=thr))
        want = np.asarray(ref.velocity_ref(x, threshold=thr))
        np.testing.assert_allclose(y, want, atol=1e-4)


def pwl_numpy_golden(x_raw, step=1 / 64, domain_max=6.0):
    """Numpy reimplementation of the rust PWL integer datapath
    (``approx::pwl`` with S3.12 → S.15) for bit-exactness checks."""
    lut = make_lut(step, domain_max).astype(np.int64)
    t_bits = 12 - int(round(np.log2(1.0 / step)))
    neg = x_raw < 0
    mag = np.minimum(np.abs(x_raw.astype(np.int64)), fp.S3_12.max_raw)
    sat = mag >= int(domain_max * 4096)
    idx = np.clip(mag >> t_bits, 0, len(lut) - 2)
    t = mag & ((1 << t_bits) - 1)
    y0, y1 = lut[idx], lut[idx + 1]
    acc = (y0 << t_bits) + (y1 - y0) * t
    # round-half-even shift
    floor = acc >> t_bits
    rem = acc - (floor << t_bits)
    half = 1 << (t_bits - 1)
    y = floor + ((rem > half) | ((rem == half) & (floor & 1 == 1)))
    y = np.clip(y, 0, fp.S_15.max_raw)
    y = np.where(sat, fp.S_15.max_raw, y)
    return np.where(neg, -y, y).astype(np.int32)


class TestPwlBitExact:
    """The flagship claim: the Pallas PWL kernel is bit-identical to the
    rust fixed-point datapath (via the shared numpy golden)."""

    def test_exhaustive_grid(self):
        # Every S3.12 raw word in (−6, 6) — padded to a block multiple.
        raws = np.arange(-6 * 4096, 6 * 4096 + 1, dtype=np.int32)
        pad = (-len(raws)) % 256
        raws = np.concatenate([raws, np.zeros(pad, np.int32)])
        got = np.asarray(pwl_tanh_raw(raws))
        want = pwl_numpy_golden(raws)
        np.testing.assert_array_equal(got, want)

    def test_saturated_region(self):
        raws = np.array([32767, -32768, 30000, -30000] * 64, np.int32)
        got = np.asarray(pwl_tanh_raw(raws))
        want = pwl_numpy_golden(raws)
        np.testing.assert_array_equal(got, want)
        assert got[0] == fp.S_15.max_raw
        assert got[1] == -fp.S_15.max_raw  # symmetric saturation

    @settings(max_examples=25, deadline=None)
    @given(
        log_inv_step=st.integers(min_value=3, max_value=8),
        n_blocks=st.integers(min_value=1, max_value=3),
        seed=st.integers(min_value=0, max_value=2**31 - 1),
    )
    def test_hypothesis_random_raws(self, log_inv_step, n_blocks, seed):
        step = 2.0**-log_inv_step
        rng = np.random.default_rng(seed)
        raws = rng.integers(-32768, 32768, 256 * n_blocks).astype(np.int32)
        got = np.asarray(pwl_tanh_raw(raws, step=step))
        want = pwl_numpy_golden(raws, step=step)
        np.testing.assert_array_equal(got, want)


class TestOracleErrorBands:
    """ref.py itself must reproduce the paper's Table I max errors (the
    float-domain algorithmic component)."""

    @pytest.mark.parametrize(
        "name,fn,kwargs,band",
        [(n, f, kw, b) for (n, f, kw), b in zip(
            ref.TABLE1, [2.5e-5, 1.5e-5, 1.5e-5, 1.5e-5, 3e-5, 5e-5])],
    )
    def test_table1_band(self, name, fn, kwargs, band):
        # dense f64 grid, inside the domain (no saturation component)
        x = np.linspace(-5.99, 5.99, 200_001)
        y = np.asarray(fn(x, **kwargs))
        err = np.max(np.abs(y - np.tanh(x)))
        assert err < band, f"{name}: {err:.3e}"

    def test_velocity_factor_identity(self):
        # eq. 13: f_{a+b} = f_a·f_b — sanity of the oracle's exp form.
        a, b = 0.7, 0.45
        fa = np.exp(2 * a)
        fb = np.exp(2 * b)
        np.testing.assert_allclose(fa * fb, np.exp(2 * (a + b)), rtol=1e-12)


if __name__ == "__main__":
    pytest.main([__file__, "-q"])
