"""L2 model tests: LSTM cell/sequence shapes, the sigmoid identity,
approximation-swap behaviour, and the toy-task learnability that the
end-to-end example depends on."""

import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile.kernels.ref import sigmoid_ref


@pytest.fixture(scope="module")
def params():
    return {k: jnp.asarray(v) for k, v in
            M.init_lstm_params(0, 4, 64, 2).items()}


class TestElementwiseAdapter:
    def test_pads_and_reshapes(self):
        x = jnp.arange(10, dtype=jnp.float32).reshape(2, 5)
        y = M.apply_elementwise(lambda v: v * 2.0, x)
        np.testing.assert_allclose(np.asarray(y), np.asarray(x) * 2)

    def test_kernel_through_adapter_matches_direct(self):
        from compile.kernels import KERNELS

        x2d = np.linspace(-3, 3, 512).astype(np.float32).reshape(16, 32)
        got = np.asarray(M.apply_elementwise(KERNELS["taylor1"], jnp.asarray(x2d)))
        want = np.asarray(KERNELS["taylor1"](x2d.reshape(-1))).reshape(16, 32)
        np.testing.assert_array_equal(got, want)


class TestSigmoidIdentity:
    def test_exact_tanh_sigmoid(self):
        f = M.make_sigmoid_fn(jnp.tanh)
        x = jnp.linspace(-8, 8, 100)
        np.testing.assert_allclose(np.asarray(f(x)), sigmoid_ref(np.asarray(x)), atol=1e-6)

    def test_approx_tanh_sigmoid_close(self):
        f = M.make_sigmoid_fn(M.make_tanh_fn("pwl"))
        x = jnp.linspace(-8, 8, 512)
        np.testing.assert_allclose(np.asarray(f(x)), sigmoid_ref(np.asarray(x)), atol=2e-4)


class TestLstm:
    def test_cell_shapes(self, params):
        b, d, h = 8, 4, 64
        x = jnp.zeros((b, d))
        hh = jnp.zeros((b, h))
        cc = jnp.zeros((b, h))
        h2, c2 = M.lstm_cell(params, x, hh, cc, jnp.tanh)
        assert h2.shape == (b, h) and c2.shape == (b, h)

    def test_logits_shape(self, params):
        seq = jnp.zeros((8, 16, 4))
        logits = M.lstm_logits(params, seq, jnp.tanh)
        assert logits.shape == (8, 2)

    def test_cell_state_bounded(self, params):
        # |h| ≤ 1 by construction (o·tanh(c)); a sane-dataflow check.
        rng = np.random.default_rng(1)
        x = jnp.asarray(rng.normal(size=(8, 4)).astype(np.float32))
        h = jnp.zeros((8, 64))
        c = jnp.zeros((8, 64))
        for _ in range(20):
            h, c = M.lstm_cell(params, x, h, c, jnp.tanh)
        assert float(jnp.max(jnp.abs(h))) <= 1.0 + 1e-6

    def test_approx_tanh_close_to_exact_on_cell(self, params):
        rng = np.random.default_rng(2)
        x = jnp.asarray(rng.normal(size=(8, 4)).astype(np.float32))
        h0 = jnp.asarray(rng.uniform(-0.5, 0.5, (8, 64)).astype(np.float32))
        c0 = jnp.asarray(rng.uniform(-0.5, 0.5, (8, 64)).astype(np.float32))
        h_ref, c_ref = M.lstm_cell(params, x, h0, c0, jnp.tanh)
        h_pwl, c_pwl = M.lstm_cell(params, x, h0, c0, M.make_tanh_fn("pwl"))
        # Single-step divergence bounded by a few approximation ulps.
        assert float(jnp.max(jnp.abs(h_ref - h_pwl))) < 1e-3
        assert float(jnp.max(jnp.abs(c_ref - c_pwl))) < 1e-3


class TestToyTask:
    def test_task_labels_are_balanced(self):
        rng = np.random.default_rng(3)
        _, labels = M.make_toy_batch(rng, 2048, 16, 4)
        frac = labels.mean()
        assert 0.4 < frac < 0.6

    def test_short_training_reduces_loss(self):
        # 60 steps is enough to move the loss visibly (full 300-step run
        # happens in `make artifacts`).
        _, curve, _ = M.train_toy_lstm(steps=60, hidden=32, batch=32)
        first = np.mean(curve[:10])
        last = np.mean(curve[-10:])
        assert last < first - 0.02, f"{first:.4f} -> {last:.4f}"


class TestGraphBuilders:
    def test_tanh_graph_runs(self):
        fn, args = M.tanh_graph("lambert", 256)
        x = jnp.linspace(-2, 2, 256, dtype=jnp.float32)
        (y,) = fn(x)
        assert y.shape == (256,)

    def test_raw_graph_dtype(self):
        fn, args = M.tanh_raw_graph(256)
        assert args[0].dtype == jnp.int32
        (y,) = fn(jnp.zeros(256, jnp.int32))
        assert y.dtype == jnp.int32

    def test_lstm_cell_graph_bakes_weights(self, params):
        np_params = {k: np.asarray(v) for k, v in params.items()}
        fn, args = M.lstm_cell_graph(np_params, "ref", 4, 4, 64)
        h2, c2 = fn(jnp.zeros((4, 4)), jnp.zeros((4, 64)), jnp.zeros((4, 64)))
        assert h2.shape == (4, 64)


if __name__ == "__main__":
    pytest.main([__file__, "-q"])
