"""Sigmoid-kernel tests: the tanh identity, reference agreement, range
and complementary symmetry — per approximation method."""

import numpy as np
import pytest

from compile.kernels import KERNELS
from compile.kernels.ref import sigmoid_ref
from compile.kernels.sigmoid import sigmoid_f32


@pytest.mark.parametrize("method", list(KERNELS))
class TestSigmoid:
    def test_matches_reference(self, method):
        x = np.linspace(-10, 10, 1024).astype(np.float32)
        y = np.asarray(sigmoid_f32(x, method))
        err = np.max(np.abs(y - sigmoid_ref(x)))
        # half the tanh band (the ½ scaling) + f32 rounding
        assert err < 1.5e-4, f"{method}: {err:.3e}"

    def test_range_0_1(self, method):
        x = np.linspace(-20, 20, 512).astype(np.float32)
        y = np.asarray(sigmoid_f32(x, method))
        assert np.all(y >= 0.0) and np.all(y <= 1.0)

    def test_midpoint_half(self, method):
        y = np.asarray(sigmoid_f32(np.zeros(256, np.float32), method))
        np.testing.assert_allclose(y, 0.5, atol=2e-4)

    def test_complementary_symmetry(self, method):
        x = np.linspace(0.1, 6, 512).astype(np.float32)
        yp = np.asarray(sigmoid_f32(x, method))
        yn = np.asarray(sigmoid_f32(-x, method))
        np.testing.assert_allclose(yp + yn, 1.0, atol=3e-4)


if __name__ == "__main__":
    pytest.main([__file__, "-q"])
