//! Ablation benches for the design choices DESIGN.md calls out:
//!
//! 1. **Taylor anchor placement** (centred vs left) — explains why this
//!    repo's B1/B2 errors land below the paper's Table I values;
//! 2. **Output rounding mode** (trunc vs nearest) — the cheapest
//!    hardware option costs ~half an ulp of worst-case error;
//! 3. **Newton-Raphson iteration count** — divider accuracy vs pipeline
//!    depth for the rational methods;
//! 4. **Velocity-factor register organization** (single-bit vs Table II
//!    paired) — area/multiplier trade at identical numerics.

use tanh_vlsi::approx::reference::tanh_ref;
use tanh_vlsi::approx::taylor::{AnchorMode, Taylor};
use tanh_vlsi::approx::velocity::{Velocity, VfLookupMode};
use tanh_vlsi::approx::{newton, IoSpec, TanhApprox};
use tanh_vlsi::cost::CostModel;
use tanh_vlsi::error::{measure, InputGrid};
use tanh_vlsi::fixed::{Fx, QFormat, Round};

fn main() {
    let grid = InputGrid::table1();
    let out = QFormat::S_15;

    // ---- 1. anchor placement -------------------------------------------
    println!("=== ablation 1: Taylor anchor placement (step 1/16, quadratic) ===");
    let centered = Taylor::with_anchor(1.0 / 16.0, 3, 6.0, AnchorMode::Centered);
    let left = Taylor::with_anchor(1.0 / 16.0, 3, 6.0, AnchorMode::Left);
    let ec = measure(&centered, grid, out);
    let el = measure(&left, grid, out);
    println!("centered: max {:.2e}  rms {:.2e}", ec.max_abs, ec.rms);
    println!("left:     max {:.2e}  rms {:.2e}   (paper Table I B1: 3.65e-5 / 1.16e-5)", el.max_abs, el.rms);
    assert!(el.max_abs > ec.max_abs * 1.5, "centred must win clearly");
    // Left-anchored lands in the paper's band — the likely original setup.
    assert!(
        el.max_abs > 2.5e-5 && el.max_abs < 9.0e-5,
        "left-anchor error {:.2e} should bracket the paper's 3.65e-5",
        el.max_abs
    );

    // ---- 2. output rounding mode ----------------------------------------
    println!("\n=== ablation 2: PWL output rounding (step 1/64) ===");
    // Same datapath, different final-narrow rounding: emulate by
    // re-quantizing the ideal f64 PWL output under each mode.
    for mode in [Round::Trunc, Round::NearestAway, Round::NearestEven] {
        let pwl = tanh_vlsi::approx::pwl::Pwl::table1();
        let mut max_err: f64 = 0.0;
        for x in grid.iter() {
            let ideal = pwl.eval_f64(x.to_f64());
            let y = Fx::from_f64_round(ideal, out, mode);
            max_err = max_err.max((y.to_f64() - tanh_ref(x.to_f64())).abs());
        }
        println!("{:13} max {:.2e}", mode.name(), max_err);
        if mode == Round::Trunc {
            // truncation adds up to one extra ulp of bias
            assert!(max_err < 2.4e-5 + out.ulp() * 1.5);
        }
    }

    // ---- 3. NR iteration count ------------------------------------------
    println!("\n=== ablation 3: Newton-Raphson iterations (Lambert K=7 divider) ===");
    let mut prev = f64::INFINITY;
    for iters in 0..=4 {
        // measure divider-only error on representative quotients
        let mut max_rel: f64 = 0.0;
        for i in 1..500 {
            let den = 0.5 + (i as f64) * 0.123;
            let num = 0.77;
            let q = newton::div_f64(num, den, iters);
            max_rel = max_rel.max(((q - num / den) / (num / den)).abs());
        }
        println!("iters {iters}: max rel err {max_rel:.2e}  (pipeline +{} stages)", 2 * iters);
        assert!(max_rel <= prev, "NR must converge monotonically");
        prev = max_rel;
    }

    // ---- 4. VF register organization --------------------------------------
    println!("\n=== ablation 4: velocity-factor register file (θ=1/128, ±6) ===");
    let io = IoSpec::table1();
    let model = CostModel::new();
    let single = Velocity::table1().inventory(io);
    let paired = Velocity::table1().with_lookup_mode(VfLookupMode::PairedBits).inventory(io);
    let (cs, cp) = (model.price(&single), model.price(&paired));
    println!(
        "single-bit: {} mult, {} mux2, {} entries -> {:.0} GE",
        single.multipliers, single.mux2, single.lut_entries, cs.area_ge
    );
    println!(
        "paired:     {} mult, {} mux4, {} entries -> {:.0} GE",
        paired.multipliers, paired.mux4, paired.lut_entries, cp.area_ge
    );
    assert!(paired.multipliers < single.multipliers, "pairing must halve the chain");
    assert!(paired.lut_entries > single.lut_entries, "pairing costs storage");
    assert!(cp.area_ge < cs.area_ge, "paper's optimization should save area overall");

    println!("\n✓ all ablations behave as DESIGN.md documents");
}
