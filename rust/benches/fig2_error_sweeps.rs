//! Bench/regeneration target for **Fig 2**: sweeps every method's
//! tunable parameter on the full Table I grid, prints all six panels,
//! writes the CSV series, and validates the figure's shape (error is
//! monotone-improving in the parameter for every method).

use tanh_vlsi::approx::MethodId;
use tanh_vlsi::fixed::QFormat;
use tanh_vlsi::report::fig2;

fn main() {
    println!("=== FIG 2 regeneration (full grid) ===\n");
    // The sweeps run on the compiled kernels, chunked across threads
    // (error::measure); the wall-clock line tracks that in CI output.
    let start = std::time::Instant::now();
    let series = fig2::compute();
    println!(
        "(all six panels swept in {:.2}s on {} threads)\n",
        start.elapsed().as_secs_f64(),
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    );
    println!("{}", fig2::render(&series));

    // Anchored to the crate root so the CSVs land under rust/target/
    // regardless of the directory `cargo bench` was launched from.
    let out = std::path::Path::new(concat!(env!("CARGO_MANIFEST_DIR"), "/target/paper/fig2"));
    fig2::write_csv(&series, out).expect("writing CSVs");
    println!("CSV series written to {}", out.display());

    // Shape validation. The table-driven methods (A-D) must improve
    // monotonically as the parameter refines (modulo the quantization
    // floor). Lambert is different in kind: the truncated continued
    // fraction is a Padé approximant whose domain-edge error
    // *oscillates* with K while it converges (the clamped overshoot
    // flips sign each term) — so for E the check is convergence rate,
    // not pairwise monotonicity.
    let floor = 1.5 * QFormat::S_15.ulp();
    for s in &series {
        let first = s.points.first().unwrap().metrics.max_abs;
        let last = s.points.last().unwrap().metrics.max_abs;
        if s.id == MethodId::Lambert {
            // K: 2 → 10 must collapse the error by ≥ 2 orders of
            // magnitude overall, and the geometric trend must be
            // downward (every point beats the one two steps earlier).
            assert!(last < first / 100.0, "Lambert converges: {first} -> {last}");
            for w in s.points.windows(3) {
                assert!(
                    w[2].metrics.max_abs <= w[0].metrics.max_abs + floor,
                    "Lambert 2-step trend broken at K={}",
                    w[0].param
                );
            }
            continue;
        }
        for w in s.points.windows(2) {
            assert!(
                w[1].metrics.max_abs <= w[0].metrics.max_abs + floor,
                "{:?}: error increased {} -> {} at param {} -> {}",
                s.id,
                w[0].metrics.max_abs,
                w[1].metrics.max_abs,
                w[0].param,
                w[1].param
            );
        }
        // and the finest point is meaningfully better than the coarsest
        assert!(
            last < first,
            "{:?}: no improvement across the sweep ({first} -> {last})",
            s.id
        );
    }
    // Cross-panel check the paper's Table I relies on: at the Table I
    // parameters the six methods land in the same error band.
    let t1 = |id: MethodId, param: f64| {
        series
            .iter()
            .find(|s| s.id == id)
            .and_then(|s| s.points.iter().find(|p| (p.param - param).abs() < 1e-12))
            .map(|p| p.metrics.max_abs)
    };
    if let (Some(a), Some(e)) = (t1(MethodId::Pwl, 1.0 / 64.0), Some(4.9e-5)) {
        assert!(a < 2.0 * e, "PWL@1/64 out of band: {a}");
    }
    println!("\n✓ Fig 2 shape checks passed (monotone improvement, Table I band)");
}
