//! Bench/regeneration target for the paper's **§IV design-complexity
//! analysis** (the block diagrams of Figs 3-5 and the component counts
//! in the text): prints the priced inventory table, validates the
//! §IV.H orderings, and measures the cycle-level datapath simulator's
//! streaming throughput per method.

use tanh_vlsi::approx::{table1_suite, IoSpec, MethodId};
use tanh_vlsi::bench::bench_n;
use tanh_vlsi::cost::CostModel;
use tanh_vlsi::fixed::{Fx, QFormat};
use tanh_vlsi::hw::table1_pipeline;
use tanh_vlsi::report::complexity;

fn main() {
    println!("=== §IV complexity regeneration ===\n");
    println!("{}", complexity::render());

    // §IV.H orderings.
    let io = IoSpec::table1();
    let model = CostModel::new();
    let price =
        |id: MethodId| {
            let m = table1_suite().into_iter().find(|m| m.id() == id).unwrap();
            model.price(&m.inventory(io))
        };
    let pwl = price(MethodId::Pwl);
    let b1 = price(MethodId::TaylorQuadratic);
    let lam = price(MethodId::Lambert);
    let vf = price(MethodId::Velocity);
    assert!(pwl.lut_area_ge > b1.lut_area_ge, "PWL LUT must dominate Taylor's");
    assert!(lam.area_ge > b1.area_ge && vf.area_ge > b1.area_ge, "rational area higher");
    println!("✓ §IV.H area/LUT orderings hold\n");

    // Streaming throughput of the cycle-level datapath simulator: one
    // result per cycle once the pipe fills (Fig 5's pipelining claim).
    println!("=== datapath simulator streaming (1024-element batches) ===");
    let inputs: Vec<Fx> = (0..1024)
        .map(|i| Fx::from_f64((i as f64) * 0.0117 - 6.0, QFormat::S3_12))
        .collect();
    for id in MethodId::all() {
        let pipe = table1_pipeline(id, QFormat::S_15);
        let res = pipe.simulate(&inputs);
        assert_eq!(res.cycles, pipe.latency() + inputs.len() - 1, "throughput must be 1/cycle");
        bench_n(&format!("simulate/{}", pipe.name), inputs.len(), || {
            pipe.simulate(&inputs).outputs.len()
        });
    }
    println!("\n✓ every datapath sustains one result per cycle when streamed");
}
