//! Bench/regeneration target for **Table I**: computes the exhaustive
//! error metrics of the six selected configurations, prints the
//! ours-vs-paper table, and times a full-grid sweep per method.

use tanh_vlsi::approx::table1_suite;
use tanh_vlsi::bench::bench_n;
use tanh_vlsi::error::{measure, InputGrid};
use tanh_vlsi::fixed::QFormat;
use tanh_vlsi::report::table1;

fn main() {
    println!("=== TABLE I regeneration ===\n");
    let rows = table1::compute();
    println!("{}", table1::render(&rows));

    // Reproduction check: every row within 2× of the paper in both
    // metrics (exact agreement is not expected: our LUT quantization
    // and anchor placement choices differ in the two Taylor rows).
    let mut ok = true;
    for r in &rows {
        let fits = r.max_err < 2.0 * r.paper_max && r.rms < 2.0 * r.paper_mse;
        println!(
            "  {}  max {:>8.2e} vs paper {:>8.2e}  ({})",
            r.label,
            r.max_err,
            r.paper_max,
            if fits { "within 2x" } else { "OUT OF BAND" }
        );
        ok &= fits;
    }
    assert!(ok, "Table I reproduction out of band");

    println!("\n=== sweep timing (full S3.12 grid, 49k points) ===");
    let grid = InputGrid::table1();
    for m in table1_suite() {
        let n = grid.len();
        bench_n(&format!("sweep/{}", m.describe()), n, || {
            measure(m.as_ref(), grid, QFormat::S_15).max_abs
        });
    }
}
