//! Bench/regeneration target for **Table III**: for each of the paper's
//! four I/O-format rows, search the cheapest parameter per method that
//! achieves ≤ 1 output ulp, and compare the shape against the paper's
//! values (same order of magnitude; finer formats need finer
//! parameters; B2 ≥ B1 step; row 4 is cheap for everything).

use tanh_vlsi::approx::MethodId;
use tanh_vlsi::error::table3_rows;
use tanh_vlsi::report::table3::{self, PAPER_VALUES};

fn main() {
    println!("=== TABLE III regeneration (exhaustive 1-ulp searches) ===\n");
    let mut rows = Vec::new();
    for spec in table3_rows() {
        eprintln!("  row {} -> {} ±{} ...", spec.input, spec.output, spec.range);
        rows.push(table3::compute_table3_row(spec, 1.0));
    }
    println!("{}", table3::render(&rows));

    // Shape checks.
    // (1) every method finds a passing parameter in every row;
    for (r, row) in rows.iter().enumerate() {
        for (i, p) in row.params.iter().enumerate() {
            assert!(
                p.is_some(),
                "row {r}: {:?} found no passing parameter",
                MethodId::all()[i]
            );
        }
    }
    // (2) the 8-bit row (row 4) passes with coarser-or-equal parameters
    //     than the 16-bit rows for every method;
    for (i, id) in MethodId::all().into_iter().enumerate() {
        let p8 = rows[3].params[i].unwrap();
        let p16 = rows[1].params[i].unwrap();
        match id {
            MethodId::Lambert => assert!(
                p8 <= p16,
                "{id:?}: 8-bit K {p8} > 16-bit K {p16}"
            ),
            _ => assert!(
                p8 >= p16,
                "{id:?}: 8-bit step {p8} finer than 16-bit {p16}"
            ),
        }
    }
    // (3) within each row, cubic Taylor allows a coarser-or-equal step
    //     than quadratic (paper rows 1-3: 1/16 vs 1/32);
    for row in &rows {
        let (b1, b2) = (row.params[1].unwrap(), row.params[2].unwrap());
        assert!(b2 >= b1, "B2 step {b2} finer than B1 {b1}");
    }
    // (4) never *finer* than ~4x the paper's parameter (our search may
    //     legitimately find coarser/cheaper passing parameters — e.g.
    //     quadratic Taylor's 1-ulp bound for a 7-bit output is met at
    //     step 1/2, far coarser than the paper's conservative 1/32; the
    //     reproduction claim is that we never need *more* hardware).
    for (r, row) in rows.iter().enumerate() {
        for (i, id) in MethodId::all().into_iter().enumerate() {
            let ours = row.params[i].unwrap();
            let paper = PAPER_VALUES[r][i];
            match id {
                MethodId::Lambert => assert!(
                    ours <= paper + 2.0,
                    "row {r} {id:?}: needs K={ours} vs paper {paper}"
                ),
                _ => assert!(
                    ours >= paper / 4.0,
                    "row {r} {id:?}: needs step {ours} finer than paper {paper}/4"
                ),
            }
        }
    }
    println!("✓ Table III shape checks passed");
}
