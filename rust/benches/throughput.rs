//! End-to-end serving benchmark: scalar golden-model evaluation rates
//! (the L3 hot path), PJRT batched-graph execution rates, and the full
//! coordinator pipeline under load — the numbers EXPERIMENTS.md §Perf
//! tracks.

use std::sync::Arc;

use tanh_vlsi::approx::{table1_suite, MethodId, TanhApprox};
use tanh_vlsi::bench::{bench_n, Bencher};
use tanh_vlsi::coordinator::{Coordinator, CoordinatorConfig, GoldenBackend, GraphBackend};
use tanh_vlsi::fixed::{Fx, QFormat};
use tanh_vlsi::runtime::{ArtifactDir, EngineServer};
use tanh_vlsi::util::prng::Prng;

fn main() {
    // --- L3 scalar hot path: evals/s per method -------------------------
    println!("=== golden-model scalar evaluation (S3.12 -> S.15) ===");
    let inputs: Vec<Fx> = {
        let mut g = Prng::new(1);
        (0..4096).map(|_| Fx::from_f64(g.f64_in(-6.0, 6.0), QFormat::S3_12)).collect()
    };
    for m in table1_suite() {
        bench_n(&format!("eval_fx/{}", m.describe()), inputs.len(), || {
            let mut acc = 0i64;
            for &x in &inputs {
                acc = acc.wrapping_add(m.eval_fx(x, QFormat::S_15).raw());
            }
            acc
        });
    }
    // Production compiled fast path (PWL): integer-only closure over a
    // dense table — the serving backend's per-activation cost.
    {
        let fast = tanh_vlsi::approx::pwl::Pwl::table1().compile_raw();
        let raws: Vec<i64> = inputs.iter().map(|x| x.raw()).collect();
        bench_n("eval_raw/PWL(compiled)", raws.len(), || {
            let mut acc = 0i64;
            for &r in &raws {
                acc = acc.wrapping_add(fast(r));
            }
            acc
        });
    }

    // --- PJRT batched graphs --------------------------------------------
    let Ok(dir) = ArtifactDir::open(ArtifactDir::default_path()) else {
        println!("\n(artifacts missing — skipping PJRT + coordinator benches; run `make artifacts`)");
        return;
    };
    println!("\n=== PJRT compiled activation graphs (batch 1024) ===");
    let engine = Arc::new(EngineServer::spawn(dir).expect("engine"));
    let flat: Vec<f32> = {
        let mut g = Prng::new(2);
        (0..1024).map(|_| g.f64_in(-6.0, 6.0) as f32).collect()
    };
    for method in ["pwl", "taylor1", "taylor2", "catmull_rom", "velocity", "lambert", "ref"] {
        let name = format!("tanh_{method}_1024");
        engine.preload(&[&name]).expect("preload");
        let e = engine.clone();
        let b = Bencher::quick();
        let r = b.run(&format!("pjrt/{name}"), || {
            e.run_f32(&name, flat.clone()).unwrap().len()
        });
        println!("{}  [{:.2} Mact/s]", r.report(), 1024.0 * r.per_second() / 1e6);
    }

    // --- full coordinator under load --------------------------------------
    println!("\n=== coordinator end-to-end (8 clients, mixed methods) ===");
    for (label, backend) in [
        ("golden", Arc::new(GoldenBackend::table1(1024)) as Arc<dyn tanh_vlsi::coordinator::ExecBackend>),
        ("pjrt", Arc::new(GraphBackend::load_all(engine.clone(), 1024).expect("backend")) as Arc<dyn tanh_vlsi::coordinator::ExecBackend>),
    ] {
        let coord = Arc::new(Coordinator::start(backend, CoordinatorConfig::default()));
        let start = std::time::Instant::now();
        let clients = 8;
        let per_client = 200;
        let window = 32; // pipelined load: keep 32 requests in flight
        let handles: Vec<_> = (0..clients)
            .map(|c| {
                let coord = coord.clone();
                std::thread::spawn(move || {
                    let mut g = Prng::new(c as u64);
                    let mut inflight = Vec::with_capacity(window);
                    for i in 0..per_client {
                        let method = MethodId::all()[(c + i) % 6];
                        let values: Vec<f32> =
                            (0..64).map(|_| g.f64_in(-6.0, 6.0) as f32).collect();
                        if let Ok(rx) = coord.submit(method, values) {
                            inflight.push(rx);
                        }
                        if inflight.len() >= window {
                            for rx in inflight.drain(..) {
                                let _ = rx.recv();
                            }
                        }
                    }
                    for rx in inflight {
                        let _ = rx.recv();
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        let secs = start.elapsed().as_secs_f64();
        let m = coord.metrics();
        println!(
            "coordinator/{label:6}  {:.0} req/s  {:.2} Mact/s  {} batches (eff {:.1}%)  mean lat {:.0} µs",
            m.requests as f64 / secs,
            m.elements as f64 / secs / 1e6,
            m.batches,
            100.0 * m.batch_efficiency(),
            m.mean_latency_us()
        );
    }
}
