//! End-to-end serving benchmark: scalar golden-model evaluation rates,
//! the compiled integer kernels for all six methods (the L3 hot path),
//! parallel exhaustive error sweeps, PJRT batched-graph execution, and
//! the full coordinator pipeline under load — the numbers EXPERIMENTS.md
//! §Perf tracks.
//!
//! Alongside the stdout tables the run writes `BENCH_throughput.json`
//! (name, evals/s, elements, wall ns per iteration) so the perf
//! trajectory is diffable across PRs.
//!
//! `TANH_SMOKE=1` runs a shortened profile (quick bencher, coarse sweep
//! grid, lighter coordinator load) — used by `scripts/tier1.sh`.

use std::sync::Arc;
use std::time::Duration;

use tanh_vlsi::approx::{table1_suite, IoSpec, MethodId, TanhApprox};
use tanh_vlsi::backend::{EvalBackend, GoldenBackend, HwBackend, PjrtBackend};
use tanh_vlsi::bench::{BenchLog, BenchResult, Bencher};
use tanh_vlsi::coordinator::{Coordinator, CoordinatorConfig};
use tanh_vlsi::error::{measure_with_threads, InputGrid};
use tanh_vlsi::fixed::{Fx, QFormat};
use tanh_vlsi::util::json::Json;
use tanh_vlsi::util::prng::Prng;

// Anchored to the crate root so the log lands in rust/ regardless of
// the directory `cargo bench` was launched from.
const LOG_PATH: &str = concat!(env!("CARGO_MANIFEST_DIR"), "/BENCH_throughput.json");

fn main() {
    let smoke = std::env::var("TANH_SMOKE").is_ok();
    let bencher = if smoke { Bencher::quick() } else { Bencher::default() };
    let mut log = BenchLog::new();

    // --- L3 scalar hot path: generic eval_fx vs compiled kernels -------
    println!("=== golden-model evaluation (S3.12 -> S.15, {} inputs) ===", 4096);
    let inputs: Vec<Fx> = {
        let mut g = Prng::new(1);
        (0..4096).map(|_| Fx::from_f64(g.f64_in(-6.0, 6.0), QFormat::S3_12)).collect()
    };
    let raws: Vec<i64> = inputs.iter().map(|x| x.raw()).collect();
    let mut out_raws = vec![0i64; raws.len()];
    for m in table1_suite() {
        let generic = bencher.run(&format!("eval_fx/{}", m.describe()), || {
            let mut acc = 0i64;
            for &x in &inputs {
                acc = acc.wrapping_add(m.eval_fx(x, QFormat::S_15).raw());
            }
            acc
        });
        println!(
            "{}  [{:.2} M evals/s]",
            generic.report(),
            raws.len() as f64 * generic.per_second() / 1e6
        );
        log.record(raws.len(), &generic);

        // Compile outside the timed region: serving compiles once at
        // startup, sweeps once per configuration.
        let kernel = m.compile(IoSpec::table1());
        let compiled = bencher.run(&format!("kernel/{}", m.describe()), || {
            kernel.eval_slice_raw(&raws, &mut out_raws);
            out_raws[0]
        });
        let speedup = generic.ns_per_iter() / compiled.ns_per_iter();
        println!(
            "{}  [{:.2} M evals/s, {:.1}x vs eval_fx]",
            compiled.report(),
            raws.len() as f64 * compiled.per_second() / 1e6,
            speedup
        );
        log.record(raws.len(), &compiled);

        // Packed (SWAR) entry point on the same kernel and inputs.
        // Every Table I spec fits 16-bit lanes, so this exercises the
        // 4-lane path; the speedup row is what tier1.sh schema-checks.
        assert!(
            kernel.lane_width().is_some(),
            "Table I spec must qualify for packed lanes: {}",
            m.describe()
        );
        let packed = bencher.run(&format!("kernel-packed/{}", m.describe()), || {
            kernel.eval_slice_packed(&raws, &mut out_raws);
            out_raws[0]
        });
        let packed_speedup = compiled.ns_per_iter() / packed.ns_per_iter();
        println!(
            "{}  [{:.2} M evals/s, {:.2}x vs scalar kernel]",
            packed.report(),
            raws.len() as f64 * packed.per_second() / 1e6,
            packed_speedup
        );
        log.record(raws.len(), &packed);
        log.push_row(Json::obj(vec![
            ("name", Json::s(format!("kernel-packed-speedup/{}", m.describe()))),
            ("speedup", Json::n(packed_speedup)),
            ("scalar_ns", Json::n(compiled.ns_per_iter())),
            ("packed_ns", Json::n(packed.ns_per_iter())),
        ]));
    }

    // --- exhaustive error sweeps: sequential vs parallel ----------------
    let grid =
        if smoke { InputGrid::ranged(QFormat::new(3, 8), 6.0) } else { InputGrid::table1() };
    println!("\n=== exhaustive error sweep ({} grid points) ===", grid.len());
    let sweep_bencher = Bencher::quick();
    // "seq" pins the sweep to one worker; compilation inside measure is
    // not thread-bounded (Lambert's table build parallelizes in both
    // arms), so the ratio understates the sweep-only scaling for E.
    for id in [MethodId::Pwl, MethodId::Velocity, MethodId::Lambert] {
        let m = table1_suite().into_iter().find(|m| m.id() == id).unwrap();
        let seq = sweep_bencher.run(&format!("measure-seq/{}", m.describe()), || {
            measure_with_threads(m.as_ref(), grid, QFormat::S_15, 1).max_abs
        });
        log.record(grid.len(), &seq);
        let par = sweep_bencher.run(&format!("measure-par/{}", m.describe()), || {
            measure_with_threads(
                m.as_ref(),
                grid,
                QFormat::S_15,
                std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1),
            )
            .max_abs
        });
        log.record(grid.len(), &par);
        println!("{}", seq.report());
        println!(
            "{}  [{:.2}x vs 1 thread]",
            par.report(),
            seq.ns_per_iter() / par.ns_per_iter()
        );
    }

    // --- full coordinator under load ------------------------------------
    println!("\n=== coordinator end-to-end (8 clients, mixed methods) ===");
    run_coordinator("golden", Arc::new(GoldenBackend::new()), smoke, &mut log);
    // Same load on the cycle-accurate hw datapaths: wall-clock is the
    // simulator's cost, but the run also logs the simulated-cycle
    // column the serve rows carry.
    run_coordinator("hw", Arc::new(HwBackend::new()), smoke, &mut log);

    // --- PJRT sections (need compiled artifacts + linked PJRT) ----------
    // One backend (one engine thread, one graph cache) serves both the
    // per-graph micro-benches and the coordinator run; both failure
    // modes — missing artifacts/ dir, and artifacts present but PJRT
    // stubbed out (runtime::xla_shim) — surface as Unavailable and
    // fall through to the log write below.
    let pjrt = PjrtBackend::with_default_artifacts(1024);
    match pjrt.availability() {
        tanh_vlsi::backend::Availability::Available => {
            println!("\n=== PJRT compiled activation graphs (batch 1024) ===");
            let flat: Vec<f32> = {
                let mut g = Prng::new(2);
                (0..1024).map(|_| g.f64_in(-6.0, 6.0) as f32).collect()
            };
            for method in
                ["pwl", "taylor1", "taylor2", "catmull_rom", "velocity", "lambert", "ref"]
            {
                let name = format!("tanh_{method}_1024");
                // Preload outside the timed region; a graph missing
                // from the artifact set is a warning, not a panic.
                if let Err(e) = pjrt.run_graph_f32(&name, flat.clone()) {
                    println!("(skipping pjrt/{name}: preload failed: {e})");
                    continue;
                }
                let r = Bencher::quick().run(&format!("pjrt/{name}"), || {
                    pjrt.run_graph_f32(&name, flat.clone()).unwrap().len()
                });
                println!("{}  [{:.2} Mact/s]", r.report(), 1024.0 * r.per_second() / 1e6);
                log.record(1024, &r);
            }
            run_coordinator("pjrt", Arc::new(pjrt), smoke, &mut log);
        }
        tanh_vlsi::backend::Availability::Unavailable(e) => {
            println!("\n(skipping PJRT benches: {e} — run `make artifacts` with xla linked)");
        }
    }

    log.write(LOG_PATH).expect("writing bench log");
    println!("\nwrote {} benchmark rows to {LOG_PATH}", log.len());
}

/// Drives the coordinator with 8 pipelined clients and prints/logs the
/// served throughput, batch fill rate and latency.
fn run_coordinator(label: &str, backend: Arc<dyn EvalBackend>, smoke: bool, log: &mut BenchLog) {
    let coord = Arc::new(
        Coordinator::start(backend, CoordinatorConfig::with_batch(1024))
            .expect("coordinator starts on an available backend"),
    );
    let start = std::time::Instant::now();
    let clients = 8;
    let per_client = if smoke { 50 } else { 200 };
    let window = 32; // pipelined load: keep 32 requests in flight
    let handles: Vec<_> = (0..clients)
        .map(|c| {
            let coord = coord.clone();
            std::thread::spawn(move || {
                let mut g = Prng::new(c as u64);
                let mut inflight = Vec::with_capacity(window);
                for i in 0..per_client {
                    let method = MethodId::all()[(c + i) % 6];
                    let values: Vec<f32> = (0..64).map(|_| g.f64_in(-6.0, 6.0) as f32).collect();
                    if let Ok(rx) = coord.submit(method, values) {
                        inflight.push(rx);
                    }
                    if inflight.len() >= window {
                        for rx in inflight.drain(..) {
                            let _ = rx.recv();
                        }
                    }
                }
                for rx in inflight {
                    let _ = rx.recv();
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    let elapsed = start.elapsed();
    let secs = elapsed.as_secs_f64();
    let m = coord.metrics();
    println!(
        "coordinator/{label:6}  {:.0} req/s  {:.2} Mact/s  {} batches (fill {:.1}%, eff {:.1}%)  lat µs p50 {:.0} / p99 {:.0} / max {}",
        m.requests as f64 / secs,
        m.elements as f64 / secs / 1e6,
        m.batches,
        100.0 * m.fill_rate(),
        100.0 * m.batch_efficiency(),
        m.p50_us(),
        m.p99_us(),
        m.latency_us_max()
    );
    if m.sim_cycles > 0 {
        println!(
            "coordinator/{label:6}  simulated hw cycles: {} total ({:.1}/batch)",
            m.sim_cycles,
            m.sim_cycles as f64 / m.batches.max(1) as f64
        );
    }
    log.record(
        m.elements as usize,
        &BenchResult {
            name: format!("coordinator/{label}"),
            median: elapsed,
            mad: Duration::ZERO,
            iters_per_sample: 1,
            samples: 1,
        },
    );
}
