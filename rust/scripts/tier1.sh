#!/usr/bin/env bash
# Tier-1 verification (referenced from ROADMAP.md): release build, full
# test suite, then a throughput smoke bench so hot-path regressions and
# bench-target bitrot are caught even though `cargo test` never builds
# the bench binaries.
#
# Usage: rust/scripts/tier1.sh   (from anywhere; cd's to the crate root)
set -euo pipefail
cd "$(dirname "$0")/.."

echo "== tier-1: cargo build --release =="
cargo build --release

echo "== tier-1: cargo test -q =="
cargo test -q

echo "== tier-1: throughput smoke bench (TANH_SMOKE=1) =="
TANH_SMOKE=1 cargo bench --bench throughput

echo "== tier-1: OK =="
