#!/usr/bin/env bash
# Tier-1 verification (referenced from ROADMAP.md): release build, full
# test suite, a throughput smoke bench, and a serve-scenario smoke so
# hot-path, bench-target and serving-harness regressions are caught even
# though `cargo test` never builds the bench binaries or drives the CLI.
#
# Usage: rust/scripts/tier1.sh   (from anywhere; cd's to the crate root)
set -euo pipefail
cd "$(dirname "$0")/.."

echo "== tier-1: cargo build --release =="
cargo build --release

echo "== tier-1: cargo test -q =="
cargo test -q

echo "== tier-1: throughput smoke bench (TANH_SMOKE=1) =="
TANH_SMOKE=1 cargo bench --bench throughput

# Packed-kernel schema check: the bench must log a kernel-packed row
# and a packed-vs-scalar speedup row per Table I method, and the SWAR
# path must actually pay off on the PWL kernel (acceptance: >= 2.0x).
for key in '"kernel-packed/' '"kernel-packed-speedup/' '"speedup"'; do
  grep -q "$key" BENCH_throughput.json \
    || { echo "tier-1 FAIL: BENCH_throughput.json missing $key rows"; exit 1; }
done
PWL_SPEEDUP=$(grep -o '"name": "kernel-packed-speedup/PWL[^}]*' BENCH_throughput.json \
              | grep -o '"speedup": [0-9.eE+-]*' | head -1 | awk '{print $2}')
[ -n "$PWL_SPEEDUP" ] \
  || { echo "tier-1 FAIL: no packed speedup row for the PWL kernel"; exit 1; }
awk -v s="$PWL_SPEEDUP" 'BEGIN { exit !(s >= 2.0) }' \
  || { echo "tier-1 FAIL: PWL packed speedup $PWL_SPEEDUP < 2.0x"; exit 1; }
echo "(PWL packed speedup: ${PWL_SPEEDUP}x)"

echo "== tier-1: serve-scenario smoke (TANH_SMOKE=1) =="
# All five deterministic scenarios in one run, shortened by TANH_SMOKE
# (scale 0.1), on >= 2 shards per method; the binary verifies every
# reply bit-exact against the compiled golden kernels and validates the
# report schema, exiting nonzero on any failure. Writes the canonical
# BENCH_serve.json tracked across PRs.
BIN=target/release/tanh-vlsi
TANH_SMOKE=1 "$BIN" serve --scenario all --seed 42 --shards 2 --out BENCH_serve.json

# Belt-and-braces schema check independent of the binary's validator:
# nonzero throughput and every required key present in the report
# (including the backend-era keys: which backend served, and its
# simulated-hardware-latency column).
for key in scenario seed backend shards requests elements verified fill_rate \
           sim_cycles sim_cycles_per_element p50_us p95_us p99_us max_us evals_per_s \
           packed_batches; do
  grep -q "\"$key\"" BENCH_serve.json \
    || { echo "tier-1 FAIL: BENCH_serve.json missing key '$key'"; exit 1; }
done
if grep -Eq '"requests": 0(,|$)' BENCH_serve.json; then
  echo "tier-1 FAIL: BENCH_serve.json has a zero-request scenario"; exit 1
fi
# Golden serving of the Table I suite runs the SWAR packed kernels, so
# every scenario row must count at least one packed batch. (The hw
# smoke below legitimately reports 0 — the check is golden-only.)
if grep -Eq '"packed_batches": 0(,|$)' BENCH_serve.json; then
  echo "tier-1 FAIL: golden serve ran no packed batches"; exit 1
fi

echo "== tier-1: concurrent-socket serve smoke =="
# The zipf scenario replayed over 8 REAL TCP connections against the
# nonblocking front-end — mixed framing (even connections JSON lines,
# odd binary frames), pipelined, every reply verified bit-exact against
# freshly compiled golden kernels by the binary itself. The report row
# must carry the socket columns: the connection fan-out, the server's
# byte gauges, and per-connection round-trip percentiles.
TANH_SMOKE=1 "$BIN" serve --scenario zipf --seed 42 --shards 2 \
  --sockets 8 --framing mixed --out BENCH_serve_sockets.json
for key in framing connections accepted_conns active_conns bytes_in bytes_out \
           conn_p50_us conn_p95_us conn_p99_us conn_max_us; do
  grep -q "\"$key\"" BENCH_serve_sockets.json \
    || { echo "tier-1 FAIL: BENCH_serve_sockets.json missing key '$key'"; exit 1; }
done
grep -q '"framing": "mixed"' BENCH_serve_sockets.json \
  || { echo "tier-1 FAIL: socket smoke did not run mixed framing"; exit 1; }
grep -q '"connections": 8' BENCH_serve_sockets.json \
  || { echo "tier-1 FAIL: socket smoke did not use 8 connections"; exit 1; }
for key in bytes_in bytes_out conn_p99_us; do
  if grep -Eq "\"$key\": 0(\.0)?(,|\$)" BENCH_serve_sockets.json; then
    echo "tier-1 FAIL: socket smoke reports zero $key"; exit 1
  fi
done
if grep -Eq '"verified": 0(,|$)' BENCH_serve_sockets.json; then
  echo "tier-1 FAIL: socket smoke verified zero replies"; exit 1
fi
# The canonical BENCH_serve.json also carries the socket columns (as
# inproc sentinels) so the row schema is uniform across drivers.
grep -q '"framing": "inproc"' BENCH_serve.json \
  || { echo "tier-1 FAIL: BENCH_serve.json rows lack the socket columns"; exit 1; }
rm -f BENCH_serve_sockets.json

echo "== tier-1: wire-protocol regression probes (netcheck) =="
# The three protocol bugfixes, exercised against a live loopback
# server: (1) non-numeric values entries are rejected by index (never
# silently dropped into a misaligned reply), (2) bare NaN tokens are
# invalid JSON and refused at the parser, (3) oversized frames — JSON
# line or binary header — answer bad_request and close instead of
# buffering without bound.
"$BIN" netcheck > netcheck.txt
cat netcheck.txt
grep -q 'non-numeric-entry.*bad_request' netcheck.txt \
  || { echo "tier-1 FAIL: non-numeric values entry not rejected as bad_request"; exit 1; }
grep -q 'non-numeric-entry.*values\[1\]' netcheck.txt \
  || { echo "tier-1 FAIL: rejection does not name the offending index"; exit 1; }
grep -q 'nan-entry.*bad_request' netcheck.txt \
  || { echo "tier-1 FAIL: NaN payload not rejected as bad_request"; exit 1; }
grep -q 'oversized-line.*bad_request' netcheck.txt \
  || { echo "tier-1 FAIL: oversized JSON line not rejected as bad_request"; exit 1; }
grep -q 'oversized-bin-frame.*bad_request' netcheck.txt \
  || { echo "tier-1 FAIL: oversized binary frame not rejected as bad_request"; exit 1; }
# Wire-truncation regressions: (4) a reply body past the length-prefix
# cap must be refused by the checked frame builder (never encoded with
# a wrapped u32 prefix), naming the limit; (5) a served-spec list past
# the u16 binary address space must fail spec-id table construction
# (never alias ids via `as u16`), naming both sizes.
grep -q 'reply-frame-cap.*bad_request' netcheck.txt \
  || { echo "tier-1 FAIL: oversized reply body not refused by the frame builder"; exit 1; }
grep -q 'reply-frame-cap.*4096-byte limit' netcheck.txt \
  || { echo "tier-1 FAIL: reply-frame rejection does not name the cap"; exit 1; }
grep -q 'spec-id-overflow.*bad_request' netcheck.txt \
  || { echo "tier-1 FAIL: oversized spec list not refused at id-table build"; exit 1; }
grep -q 'spec-id-overflow.*65537' netcheck.txt \
  || { echo "tier-1 FAIL: spec-id rejection does not name the overflowing size"; exit 1; }
rm -f netcheck.txt

echo "== tier-1: non-Table-I spec smoke =="
# Serve a design point the pre-spec API could not even name (PWL at
# step 1/32 with an S2.13 input) through a 2-shard coordinator
# scenario. The binary verifies every reply BIT-EXACT against a
# freshly compiled golden kernel (the scenario verifier deliberately
# bypasses the shared Registry cache the serving backend uses), and
# the report row must carry the spec string.
SPEC='pwl:step=1/32:in=s2.13:out=s.15'
TANH_SMOKE=1 "$BIN" serve --scenario steady --seed 7 --shards 2 \
  --spec "$SPEC" --out BENCH_serve_spec.json
grep -q 'pwl:step=1/32:in=S2.13:out=S.15' BENCH_serve_spec.json \
  || { echo "tier-1 FAIL: BENCH_serve_spec.json does not carry the spec string"; exit 1; }
grep -q '"verified"' BENCH_serve_spec.json \
  || { echo "tier-1 FAIL: spec smoke row has no verified count"; exit 1; }
if grep -Eq '"verified": 0(,|$)' BENCH_serve_spec.json; then
  echo "tier-1 FAIL: spec smoke verified zero replies"; exit 1
fi
# And the spec grammar must reject garbage with a helpful message.
if "$BIN" sweep --spec 'pwl:step=1/3' 2>err.txt; then
  echo "tier-1 FAIL: invalid spec was accepted"; exit 1
fi
grep -qi 'spec grammar' err.txt \
  || { echo "tier-1 FAIL: spec error does not show the grammar"; exit 1; }
rm -f err.txt BENCH_serve_spec.json

echo "== tier-1: cell-graph serve smoke (lstm scenario) =="
# Whole LSTM cell steps served through a 2-shard coordinator via the
# graph layer: sigmoid gates fused onto shared tanh Registry kernels by
# the rewrite passes, every step verified by the binary bit-exact
# against a direct golden execution AND against the f64 reference
# within the per-gate error budget. The row schema is the same
# BENCH_serve.json schema plus the cell columns (cell_steps,
# gate_max_err) — validated by the binary, belt-and-braces here.
TANH_SMOKE=1 "$BIN" serve --scenario lstm --seed 42 --shards 2 \
  --out BENCH_serve_lstm.json
for key in cell_steps gate_max_err; do
  grep -q "\"$key\"" BENCH_serve_lstm.json \
    || { echo "tier-1 FAIL: BENCH_serve_lstm.json missing key '$key'"; exit 1; }
done
if grep -Eq '"cell_steps": 0(,|$)' BENCH_serve_lstm.json; then
  echo "tier-1 FAIL: lstm smoke served zero cell steps"; exit 1
fi
if grep -Eq '"gate_max_err": 0(\.0)?(,|$)' BENCH_serve_lstm.json; then
  echo "tier-1 FAIL: lstm smoke reports a zero gate error observable"; exit 1
fi
if grep -Eq '"requests": 0(,|$)' BENCH_serve_lstm.json; then
  echo "tier-1 FAIL: lstm smoke served zero activation requests"; exit 1
fi
# The flat-scenario rows must keep carrying the cell columns as zeros
# (uniform schema): spot-check the canonical log written above.
grep -q '"cell_steps": 0' BENCH_serve.json \
  || { echo "tier-1 FAIL: flat scenario rows lack the cell columns"; exit 1; }
rm -f BENCH_serve_lstm.json

echo "== tier-1: streaming-session serve smoke =="
# Session-stateful pulse streaming over 4 real TCP connections in mixed
# framing: sessions open against served specs (binary 0xB9/0xBA/0xBB or
# JSON open/pulse/close), pulses stream through pinned warm state, and
# the binary verifies every pulse reply bit-exact against a cold golden
# replay. The row schema is the same BENCH_serve.json schema plus the
# session columns (sessions, pulses, pulse percentiles,
# stream_cycles_per_element — the last legitimately 0.0 on the golden
# backend, so only presence is checked for it here; the hw
# cycles-per-element win is pinned by tests/streaming.rs).
TANH_SMOKE=1 "$BIN" serve --scenario stream-steady --seed 42 --shards 2 \
  --sockets 4 --framing mixed --out BENCH_serve_stream.json
for key in sessions pulses pulse_p50_us pulse_p95_us pulse_p99_us \
           stream_cycles_per_element; do
  grep -q "\"$key\"" BENCH_serve_stream.json \
    || { echo "tier-1 FAIL: BENCH_serve_stream.json missing key '$key'"; exit 1; }
done
if grep -Eq '"sessions": 0(,|$)' BENCH_serve_stream.json; then
  echo "tier-1 FAIL: streaming smoke opened zero sessions"; exit 1
fi
if grep -Eq '"pulses": 0(,|$)' BENCH_serve_stream.json; then
  echo "tier-1 FAIL: streaming smoke streamed zero pulses"; exit 1
fi
if grep -Eq '"pulse_p99_us": 0(\.0)?(,|$)' BENCH_serve_stream.json; then
  echo "tier-1 FAIL: streaming smoke reports a zero pulse latency tail"; exit 1
fi
if grep -Eq '"verified": 0(,|$)' BENCH_serve_stream.json; then
  echo "tier-1 FAIL: streaming smoke verified zero pulse replies"; exit 1
fi
# The per-request rows must keep carrying the session columns as zeros
# (uniform schema): spot-check the canonical log written above.
grep -q '"sessions": 0' BENCH_serve.json \
  || { echo "tier-1 FAIL: per-request rows lack the session columns"; exit 1; }
rm -f BENCH_serve_stream.json

echo "== tier-1: hw-backend serve smoke =="
# The same steady scenario on the cycle-accurate hw backend: every
# reply is verified BIT-EXACT against independently compiled golden
# kernels by the binary itself (Verify::Exact for --backend hw), and
# the report row must carry the backend name and a nonzero
# simulated-cycle column.
TANH_SMOKE=1 "$BIN" serve --backend hw --scenario steady --seed 42 --shards 2 \
  --batch 256 --out BENCH_serve_hw.json
grep -q '"backend": "hw"' BENCH_serve_hw.json \
  || { echo "tier-1 FAIL: hw serve row does not name its backend"; exit 1; }
grep -q '"sim_cycles"' BENCH_serve_hw.json \
  || { echo "tier-1 FAIL: hw serve row has no sim_cycles column"; exit 1; }
if grep -Eq '"sim_cycles": 0(,|$)' BENCH_serve_hw.json; then
  echo "tier-1 FAIL: hw serve reported zero simulated cycles"; exit 1
fi
if grep -Eq '"verified": 0(,|$)' BENCH_serve_hw.json; then
  echo "tier-1 FAIL: hw smoke verified zero replies"; exit 1
fi
# Steady-state streaming check: the warm hw worker retires ~1 result
# per cycle per fed element (pipeline fills amortized across the run),
# so cycles/fed-element must sit just above 1.0 — a per-batch re-fill
# regression inflates it by (latency-1)/batch on every batch.
grep -q '"sim_cycles_per_element"' BENCH_serve_hw.json \
  || { echo "tier-1 FAIL: hw serve row has no sim_cycles_per_element column"; exit 1; }
CPE=$(grep -o '"sim_cycles_per_element": [0-9.eE+-]*' BENCH_serve_hw.json | head -1 \
      | awk '{print $2}')
awk -v cpe="$CPE" 'BEGIN { exit !(cpe > 0.0 && cpe < 8.0) }' \
  || { echo "tier-1 FAIL: steady-state sim cycles/element '$CPE' out of band"; exit 1; }
rm -f BENCH_serve_hw.json

echo "== tier-1: hw-backend explore smoke =="
# Measured-cost exploration: the full (method × parameter) sweep at a
# coarse stride, costed off the lowered hw pipelines with a custom
# objective set. Schema: the frontier table must carry the measured
# columns (cyc/elt, cost source) and at least one row must actually be
# measured (not an analytic fallback).
"$BIN" explore --backend hw --stride 64 --objectives err,cycles,area > explore_hw.txt
grep -q "on 'hw' costs" explore_hw.txt \
  || { echo "tier-1 FAIL: explore did not run on the hw cost probe"; exit 1; }
grep -q 'cyc/elt' explore_hw.txt \
  || { echo "tier-1 FAIL: explore table lacks the cycles/element column"; exit 1; }
# A frontier row ending in the cost-source label (not just the summary
# line, which always contains the word "costs").
grep -Eq 'measured *$|analytic *$' explore_hw.txt \
  || { echo "tier-1 FAIL: explore rows lack the cost-source column"; exit 1; }
# ">= 1 genuinely measured frontier point" — the summary line counts
# them, so a zero count is the failure signal (the bare word
# "measured" appears even in an all-analytic run).
if grep -q '(0 measured' explore_hw.txt; then
  echo "tier-1 FAIL: frontier has zero measured points"; exit 1
fi
# The objective grammar rejects unknown axes with the axis list.
if "$BIN" explore --stride 64 --objectives err,wattage 2>err.txt; then
  echo "tier-1 FAIL: invalid objective was accepted"; exit 1
fi
grep -q 'cyc/elt' err.txt \
  || { echo "tier-1 FAIL: objective error does not list the axes"; exit 1; }
rm -f err.txt explore_hw.txt

echo "== tier-1: pjrt fail-fast smoke =="
# Without linked xla bindings the pjrt backend must fail fast with the
# stable backend_unavailable code — not panic, not serve garbage. (On a
# box with real bindings + artifacts this serve succeeds; accept both,
# but a failure must carry the code.)
if TANH_SMOKE=1 "$BIN" serve --backend pjrt --scenario steady --seed 42 \
     --out BENCH_serve_pjrt.json 2>err.txt; then
  echo "(pjrt backend available on this box — served for real)"
else
  grep -q 'backend_unavailable' err.txt \
    || { echo "tier-1 FAIL: pjrt failure lacks the backend_unavailable code"; \
         cat err.txt; exit 1; }
fi
rm -f err.txt BENCH_serve_pjrt.json

echo "== tier-1: rtl netlist smoke =="
# The verilog command now elaborates any supported datapath through the
# rtl netlist subsystem (one printer for all six, self-parsing header).
"$BIN" verilog --spec pwl:step=1/32:in=s2.13:out=s.15 --out rtl_smoke.v
grep -q 'module tanh_rtl (clk, x, y);' rtl_smoke.v \
  || { echo "tier-1 FAIL: verilog emission lacks the netlist module"; exit 1; }
grep -q '// stages: ' rtl_smoke.v \
  || { echo "tier-1 FAIL: verilog emission lacks the netlist header"; exit 1; }
# Unsupported datapaths answer typed errors, not silently broken RTL.
if "$BIN" verilog --spec pwl:step=1/3 2>err.txt; then
  echo "tier-1 FAIL: bogus verilog spec was accepted"; exit 1
fi
grep -q 'reciprocal power of two' err.txt \
  || { echo "tier-1 FAIL: verilog rejection lost its typed message"; exit 1; }
# The netlist cost tier: every explored point is elaborated to its RTL
# cell graph, audited bit-exact against its golden kernel (the probe
# refuses to price a divergent netlist — including the smoke spec's
# pwl:step=1/32:in=s2.13 shape swept above), and priced cell by cell.
"$BIN" explore --backend hw --cost netlist --stride 64 > explore_rtl.txt
grep -q "on 'netlist' costs" explore_rtl.txt \
  || { echo "tier-1 FAIL: explore did not run on the netlist cost tier"; exit 1; }
grep -Eq 'netlist *$' explore_rtl.txt \
  || { echo "tier-1 FAIL: explore rows lack the netlist cost source"; exit 1; }
if grep -q ', 0 netlist' explore_rtl.txt; then
  echo "tier-1 FAIL: frontier has zero netlist-costed points"; exit 1
fi
rm -f err.txt rtl_smoke.v explore_rtl.txt

echo "== tier-1: OK =="
