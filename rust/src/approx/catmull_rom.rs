//! Method C — uniform cubic Catmull-Rom spline (paper §II.C, §IV.D).
//!
//! An interpolating spline through uniformly spaced control points
//! `P_i = tanh(i·s)`. For `x` in segment k with local parameter
//! `t ∈ [0, 1)` the paper's eq. (17) form is a dot product
//!
//! ```text
//! f(x) = [P_{k−1} P_k P_{k+1} P_{k+2}] · ½[−t³+2t²−t, 3t³−5t²+2,
//!                                          −3t³+4t²+t, t³−t²]ᵀ
//! ```
//!
//! i.e. a 4-element MAC against a "t-vector" that is either computed by
//! a small cubic-polynomial circuit or pre-stored in a LUT (the paper's
//! performance/area trade-off). Catmull-Rom's integer basis coefficients
//! (−1, 2, −5, 3, 4…) make the circuit multiplier-free shifts/adds.
//!
//! The first segment needs `P_{−1} = tanh(−s) = −P_1` (odd symmetry);
//! the top segments need two guard points beyond the domain.

use super::compiled::{CompiledKernel, KernelBody};
use super::lut::UniformLut;
use super::reference::tanh_ref;
use super::{IoSpec, MethodId, TanhApprox};
use crate::cost::Inventory;
use crate::fixed::{fx_mul, fx_mul_wide, Fx, FxWide, QFormat, Round};

/// Internal format for basis evaluation: basis values lie in (−1, 1.2],
/// t powers in [0, 1); 2 integer bits cover every intermediate. Public
/// for the hw pipeline's register sizing.
pub const INT_FMT: QFormat = QFormat::new(2, 26);

/// Whether the t-vector (4 cubic basis values) is computed by logic or
/// fetched from a LUT addressed by the t bits (paper §IV.D).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TVectorMode {
    /// Evaluate the four cubic polynomials in logic (smaller area).
    Computed,
    /// Store the t-vector in a LUT (higher frequency, more area).
    Stored,
}

/// Catmull-Rom spline approximator.
#[derive(Clone, Debug)]
pub struct CatmullRom {
    lut: UniformLut,
    step: f64,
    domain_max: f64,
    tvec_mode: TVectorMode,
}

impl CatmullRom {
    /// Builds with control points every `step` over `[0, domain_max]`
    /// plus the two guard points the last segments need.
    pub fn new(step: f64, domain_max: f64) -> CatmullRom {
        let lut = UniformLut::sample(tanh_ref, step, domain_max, 2, QFormat::new(0, 17));
        CatmullRom { lut, step, domain_max, tvec_mode: TVectorMode::Computed }
    }

    /// Table I row "C": step 1/16, domain (-6, 6).
    pub fn table1() -> CatmullRom {
        CatmullRom::new(1.0 / 16.0, 6.0)
    }

    /// Selects t-vector realization (inventory only; numerics identical).
    pub fn with_tvector_mode(mut self, mode: TVectorMode) -> CatmullRom {
        self.tvec_mode = mode;
        self
    }

    /// Control-point spacing.
    pub fn step(&self) -> f64 {
        self.step
    }

    /// Control-point LUT.
    pub fn lut(&self) -> &UniformLut {
        &self.lut
    }

    /// Signed control-point fetch: `P_{−i} = −P_i` (odd function).
    /// Public for the hw pipeline's fetch stage.
    #[inline]
    pub fn p(&self, i: isize) -> Fx {
        if i < 0 {
            self.lut.at((-i) as usize).neg()
        } else {
            self.lut.at(i as usize)
        }
    }

    /// The four basis values at parameter `t` — f64 model.
    pub fn basis_f64(t: f64) -> [f64; 4] {
        let t2 = t * t;
        let t3 = t2 * t;
        [
            0.5 * (-t3 + 2.0 * t2 - t),
            0.5 * (3.0 * t3 - 5.0 * t2 + 2.0),
            0.5 * (-3.0 * t3 + 4.0 * t2 + t),
            0.5 * (t3 - t2),
        ]
    }

    /// Fixed-point basis evaluation in [`INT_FMT`] — the "t-vector"
    /// computation circuit of Fig 3's Catmull-Rom variant. Public so the
    /// hw pipeline stage reuses the identical arithmetic.
    pub fn basis_fx(t: Fx) -> [Fx; 4] {
        let t = t.convert(INT_FMT, Round::NearestEven);
        let t2 = fx_mul(t, t, INT_FMT, Round::NearestAway);
        let t3 = fx_mul(t2, t, INT_FMT, Round::NearestAway);
        let half = |w: FxWide| w.narrow(INT_FMT, Round::NearestAway);
        // All coefficients are small integers — shifts and adds in hw.
        let c = |v: f64| Fx::from_f64(v, INT_FMT);
        [
            half(
                fx_mul_wide(t3, c(-0.5))
                    .add(fx_mul_wide(t2, c(1.0)))
                    .add(fx_mul_wide(t, c(-0.5))),
            ),
            half(
                fx_mul_wide(t3, c(1.5))
                    .add(fx_mul_wide(t2, c(-2.5)))
                    .add(FxWide::from_fx(c(1.0))),
            ),
            half(
                fx_mul_wide(t3, c(-1.5))
                    .add(fx_mul_wide(t2, c(2.0)))
                    .add(fx_mul_wide(t, c(0.5))),
            ),
            half(fx_mul_wide(t3, c(0.5)).add(fx_mul_wide(t2, c(-0.5)))),
        ]
    }
}

impl TanhApprox for CatmullRom {
    fn id(&self) -> MethodId {
        MethodId::CatmullRom
    }

    fn describe(&self) -> String {
        format!("CatmullRom(step={})", crate::util::table::step_str(self.step))
    }

    fn eval_f64(&self, x: f64) -> f64 {
        let neg = x < 0.0;
        let x = x.abs();
        let y = if x >= self.domain_max {
            1.0
        } else {
            let k = (x / self.step).floor();
            let t = x / self.step - k;
            let k = k as isize;
            let b = Self::basis_f64(t);
            let p = |i: isize| {
                let xi = i as f64 * self.step;
                tanh_ref(xi)
            };
            b[0] * p(k - 1) + b[1] * p(k) + b[2] * p(k + 1) + b[3] * p(k + 2)
        };
        if neg {
            -y
        } else {
            y
        }
    }

    fn eval_positive_fx(&self, x: Fx, out: QFormat) -> Fx {
        let (idx, t) = self.lut.split_index(x);
        let k = idx as isize;
        let b = Self::basis_fx(t);
        let p = [self.p(k - 1), self.p(k), self.p(k + 1), self.p(k + 2)];
        // 4-element MAC kept wide; single rounding into the output.
        let mut acc = fx_mul_wide(b[0], p[0].convert(INT_FMT, Round::NearestEven));
        for i in 1..4 {
            acc = acc.add(fx_mul_wide(b[i], p[i].convert(INT_FMT, Round::NearestEven)));
        }
        acc.narrow(out, Round::NearestEven)
    }

    fn domain_max(&self) -> f64 {
        self.domain_max
    }

    /// Compiled form: the paper's §IV.D stored-t-vector variant — the
    /// four basis polynomials take only `2^t_bits` distinct values, so
    /// they are tabulated at compile time and each input is a 4-wide
    /// integer MAC against pre-converted control points.
    fn compile(&self, io: IoSpec) -> CompiledKernel {
        let step_shift = (1.0 / self.step).log2() as u32;
        if io.input.frac_bits < step_shift {
            return CompiledKernel::tabulate(self, io);
        }
        let t_bits = io.input.frac_bits - step_shift;
        if t_bits > 16 {
            // A 4 × 2^t_bits basis LUT stops being a win; tabulate.
            return CompiledKernel::tabulate(self, io);
        }
        let basis: Vec<[i64; 4]> = (0..1usize << t_bits)
            .map(|t_raw| {
                let t = Fx::from_raw_unchecked(t_raw as i64, QFormat::new(0, t_bits));
                let b = Self::basis_fx(t);
                [b[0].raw(), b[1].raw(), b[2].raw(), b[3].raw()]
            })
            .collect();
        let points: Vec<i64> = (0..self.lut.len())
            .map(|i| self.lut.at(i).convert(INT_FMT, Round::NearestEven).raw())
            .collect();
        let body = KernelBody::SplineMac { basis, points, t_bits, int_frac: INT_FMT.frac_bits };
        CompiledKernel::with_body(io, self.domain_max, body).debug_check(self)
    }

    fn inventory(&self, io: IoSpec) -> Inventory {
        // Dot product: 4 multipliers + 3 adders (paper: "a simple MAC and
        // vector computation units").
        let mac = Inventory {
            adders: 3,
            multipliers: 4,
            mult_width: io.output.width().max(INT_FMT.width()),
            add_width: INT_FMT.width(),
            pipeline_stages: 4, // fetch | t-vector | multiply | reduce
            ..Default::default()
        };
        let points = Inventory {
            lut_entries: self.lut.len() as u32,
            lut_bits: self.lut.total_bits(),
            ..Default::default()
        };
        match self.tvec_mode {
            TVectorMode::Computed => {
                // t², t³ + four 3-term integer-coefficient polynomials:
                // coefficients are shifts/adds, counted as adders.
                mac.plus(points).plus(Inventory {
                    adders: 8,
                    squarers: 1,
                    multipliers: 1, // t³ = t²·t
                    ..Default::default()
                })
            }
            TVectorMode::Stored => {
                // Paper: store the 4 basis values per t in a LUT indexed
                // by the t bits (t resolution = input frac − step bits).
                let t_bits = io.input.frac_bits - (1.0 / self.step).log2() as u32;
                let entries = (1u32 << t_bits) * 4;
                mac.plus(points).plus(Inventory {
                    lut_entries: entries,
                    lut_bits: entries * INT_FMT.width(),
                    ..Default::default()
                })
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::approx::eval_odd_saturating;

    const OUT: QFormat = QFormat::S_15;
    const INP: QFormat = QFormat::S3_12;

    #[test]
    fn basis_partition_of_unity() {
        // Catmull-Rom basis sums to 1 for every t (affine invariance).
        let mut t = 0.0;
        while t < 1.0 {
            let b = CatmullRom::basis_f64(t);
            let sum: f64 = b.iter().sum();
            assert!((sum - 1.0).abs() < 1e-12, "t={t} sum={sum}");
            t += 0.01;
        }
    }

    #[test]
    fn interpolates_control_points() {
        // At t=0 the spline passes through P_k exactly.
        let b = CatmullRom::basis_f64(0.0);
        assert_eq!(b, [0.0, 1.0, 0.0, 0.0]);
        let cr = CatmullRom::table1();
        for i in [0usize, 1, 16, 40] {
            let x = Fx::from_f64(i as f64 / 16.0, INP);
            let y = cr.eval_fx(x, OUT);
            let want = tanh_ref(x.to_f64());
            assert!(
                (y.to_f64() - want).abs() <= OUT.ulp() + 1e-9,
                "i={i}: {} vs {want}",
                y.to_f64()
            );
        }
    }

    #[test]
    fn table1_error_bounds() {
        // Paper Table I row C: step 1/16 → max err 3.63e-5.
        let cr = CatmullRom::table1();
        let mut max_err: f64 = 0.0;
        for raw in -(INP.max_raw())..=INP.max_raw() {
            let x = Fx::from_raw(raw, INP);
            let y = eval_odd_saturating(&cr, x, OUT);
            max_err = max_err.max((y.to_f64() - tanh_ref(x.to_f64())).abs());
        }
        assert!(max_err < 5.5e-5, "max_err {max_err} (paper 3.63e-5)");
        assert!(max_err > 1.0e-5);
    }

    #[test]
    fn fx_basis_matches_f64_basis() {
        for tv in [0.0, 0.25, 0.5, 0.875] {
            let t = Fx::from_f64(tv, QFormat::new(0, 8));
            let bf = CatmullRom::basis_fx(t);
            let bd = CatmullRom::basis_f64(t.to_f64());
            for i in 0..4 {
                assert!(
                    (bf[i].to_f64() - bd[i]).abs() < 1e-6,
                    "t={tv} i={i}: {} vs {}",
                    bf[i].to_f64(),
                    bd[i]
                );
            }
        }
    }

    #[test]
    fn first_segment_uses_odd_reflection() {
        // Near x=0 the spline needs P_{-1} = -tanh(step); the result must
        // still track tanh closely (and pass through 0 at 0).
        let cr = CatmullRom::table1();
        let y0 = cr.eval_fx(Fx::zero(INP), OUT);
        assert_eq!(y0.raw(), 0);
        let x = Fx::from_f64(0.02, INP);
        let y = cr.eval_fx(x, OUT);
        assert!((y.to_f64() - tanh_ref(x.to_f64())).abs() < 1e-4);
    }

    #[test]
    fn compiled_kernel_bit_matches_scalar() {
        // Stored-basis MAC kernel vs the golden datapath, including the
        // first segment (odd reflection) and the guard-entry top end.
        let cr = CatmullRom::table1();
        let k = cr.compile(IoSpec::table1());
        for raw in (-(INP.max_raw())..=INP.max_raw()).step_by(13) {
            let x = Fx::from_raw(raw, INP);
            assert_eq!(k.eval_raw(raw), cr.eval_fx(x, OUT).raw(), "raw {raw}");
        }
        for raw in [0, 1, 15, 16, 17, 24575, 24576, 24577] {
            let x = Fx::from_raw(raw, INP);
            assert_eq!(k.eval_raw(raw), cr.eval_fx(x, OUT).raw(), "edge raw {raw}");
        }
    }

    #[test]
    fn stored_tvector_trades_lut_for_logic() {
        let io = IoSpec::table1();
        let computed = CatmullRom::table1().inventory(io);
        let stored = CatmullRom::table1().with_tvector_mode(TVectorMode::Stored).inventory(io);
        assert!(stored.lut_bits > computed.lut_bits);
        assert!(stored.adders < computed.adders);
        // Both share the 4-mult MAC core.
        assert!(computed.multipliers >= 4 && stored.multipliers >= 4);
    }
}
