//! Method E — Lambert's continued fraction (paper §II.E, §IV.F).
//!
//! ```text
//! tanh(x) = x / (1 + x²/(3 + x²/(7 + …)))
//! ```
//!
//! truncated at K division terms and evaluated bottom-up with the
//! paper's eq. (15) recurrence (after Beebe), which maps directly onto a
//! pipeline of identical stages (Fig 5):
//!
//! ```text
//! T_{−1} = 1,  T_0 = 2K+1
//! T_n = (2K+1−2n)·T_{n−1} + x²·T_{n−2}     1 ≤ n ≤ K
//! f(x) ≈ x·T_{K−1} / T_K
//! ```
//!
//! The T values grow like (2K+1)!!·cosh(x), so the datapath needs the
//! paper's "larger multipliers": the model sizes a wide internal format
//! from K and the domain at construction time (a real implementation
//! would instead block-normalize per stage; the width model upper-bounds
//! that design — see DESIGN.md §3). The final division reuses the shared
//! Newton-Raphson divider.

use super::compiled::CompiledKernel;
use super::newton::{div_f64, fx_div, NR_ITERS};
use super::{IoSpec, MethodId, TanhApprox};
use crate::cost::Inventory;
use crate::fixed::{fx_mul, fx_mul_wide, Fx, QFormat, Round};

/// Lambert continued-fraction approximator.
#[derive(Clone, Debug)]
pub struct Lambert {
    /// Number of continued-fraction division terms K.
    k: usize,
    domain_max: f64,
    /// Wide internal format sized for the T recurrence at this (K, domain).
    wide_fmt: QFormat,
}

impl Lambert {
    /// Builds a K-term continued-fraction evaluator over `[0, domain_max]`.
    pub fn new(k: usize, domain_max: f64) -> Lambert {
        assert!((1..=16).contains(&k), "K must be 1..=16, got {k}");
        // Size the internal format by running the recurrence in f64 at
        // the worst-case |x| = domain_max and adding 2 bits of margin.
        let tk = Self::recurrence_f64(k, domain_max * domain_max);
        let max_t = tk.0.abs().max(tk.1.abs());
        let int_bits = (max_t.log2().ceil() as u32 + 2).min(44);
        let wide_fmt = QFormat::new(int_bits, 18);
        Lambert { k, domain_max, wide_fmt }
    }

    /// Table I row "E": K = 7 fraction terms, domain (-6, 6).
    pub fn table1() -> Lambert {
        Lambert::new(7, 6.0)
    }

    /// Number of continued-fraction terms.
    pub fn terms(&self) -> usize {
        self.k
    }

    /// The wide internal format (for the cost model / hw simulator).
    pub fn wide_format(&self) -> QFormat {
        self.wide_fmt
    }

    /// Runs the T recurrence in f64; returns (T_{K−1}, T_K).
    fn recurrence_f64(k: usize, x2: f64) -> (f64, f64) {
        let kk = (2 * k + 1) as f64;
        let mut tm1 = 1.0; // T_{-1}
        let mut t0 = kk; // T_0
        for n in 1..=k {
            let c = kk - 2.0 * n as f64;
            let t = c * t0 + x2 * tm1;
            tm1 = t0;
            t0 = t;
        }
        (tm1, t0)
    }
}

impl TanhApprox for Lambert {
    fn id(&self) -> MethodId {
        MethodId::Lambert
    }

    fn describe(&self) -> String {
        format!("Lambert(K={})", self.k)
    }

    fn eval_f64(&self, x: f64) -> f64 {
        let neg = x < 0.0;
        let x = x.abs();
        let y = if x >= self.domain_max {
            1.0
        } else {
            let (tkm1, tk) = Self::recurrence_f64(self.k, x * x);
            div_f64(x * tkm1, tk, NR_ITERS)
        };
        if neg {
            -y
        } else {
            y
        }
    }

    fn eval_positive_fx(&self, x: Fx, out: QFormat) -> Fx {
        let wf = self.wide_fmt;
        // x² via the input squarer, renormalized into the wide format.
        let x2 = fx_mul_wide(x, x).narrow(wf, Round::NearestAway);
        let kk = 2 * self.k as i64 + 1;

        // T_{-1} = 1, T_0 = 2K+1 — exact constants in the wide format.
        let mut tm1 = Fx::one(wf);
        let mut t0 = Fx::from_f64(kk as f64, wf);
        for n in 1..=self.k {
            // T_n = c_n·T_{n-1} + x²·T_{n-2}; c_n is a small odd constant
            // (shift-add in hardware). Wide MAC, one rounding per stage —
            // exactly what a pipeline register between stages does.
            let c = Fx::from_f64((kk - 2 * n as i64) as f64, wf);
            let t = fx_mul_wide(c, t0)
                .add(fx_mul_wide(x2, tm1))
                .narrow(wf, Round::NearestAway);
            tm1 = t0;
            t0 = t;
        }

        // f = x·T_{K-1} / T_K via the NR divider.
        let num = fx_mul(x, tm1, wf, Round::NearestAway);
        if t0.raw() <= 0 {
            // Cannot happen for x in domain (T_K > 0); defensive clamp.
            return Fx::max(out);
        }
        fx_div(num, t0, out, NR_ITERS)
    }

    fn domain_max(&self) -> f64 {
        self.domain_max
    }

    /// Compiled form: the continued fraction is K serial MAC stages
    /// feeding an NR divider — there is no per-input sub-structure to
    /// hoist, so the compiled kernel is the §IV.H "the circuit runs
    /// faster if LUTs are used" trade: a dense magnitude table (≤ 2^15
    /// entries for the paper's 16-bit inputs), built in parallel from
    /// the golden datapath and bit-exact by construction.
    fn compile(&self, io: IoSpec) -> CompiledKernel {
        CompiledKernel::tabulate(self, io)
    }

    fn inventory(&self, _io: IoSpec) -> Inventory {
        // Paper §IV.F: "two adders and two multipliers in each stage
        // except the first two. … The last step requires one divider and
        // one multiplier."
        let stages = self.k as u32;
        let per_stage = Inventory {
            adders: 2,
            multipliers: 2,
            mult_width: self.wide_fmt.width(),
            add_width: self.wide_fmt.width(),
            pipeline_stages: 1,
            ..Default::default()
        };
        let mut inv = Inventory {
            squarers: 1, // x²
            pipeline_stages: 1,
            ..Default::default()
        };
        for _ in 0..stages.saturating_sub(2) {
            inv = inv.plus(per_stage);
        }
        // First two stages are constant-fed (T_{-1}, T_0 constants):
        // single multiplier + adder each.
        inv = inv.plus(Inventory {
            adders: 2,
            multipliers: 2,
            pipeline_stages: 2,
            ..Default::default()
        });
        // Final: one multiplier (x·T_{K-1}) + one NR divider.
        inv.plus(Inventory {
            multipliers: 1,
            dividers: 1,
            mult_width: self.wide_fmt.width(),
            add_width: self.wide_fmt.width(),
            pipeline_stages: 1 + 2 * (NR_ITERS as u32),
            ..Default::default()
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::approx::eval_odd_saturating;
    use crate::approx::reference::tanh_ref;

    const OUT: QFormat = QFormat::S_15;
    const INP: QFormat = QFormat::S3_12;

    #[test]
    fn recurrence_equals_continued_fraction() {
        // Direct top-down CF evaluation vs the eq. (15) recurrence.
        for &x in &[0.1, 0.5, 1.0, 2.0, 4.0] {
            for k in 1..=8 {
                let x2 = x * x;
                // top-down: start at the innermost denominator 2K+1? The
                // K-term truncation uses denominators 1, 3, 5, …, 2K+1.
                let mut d = (2 * k + 1) as f64;
                for n in (1..=k).rev() {
                    d = (2 * n - 1) as f64 + x2 / d;
                }
                let topdown = x / d;
                let (tkm1, tk) = Lambert::recurrence_f64(k, x2);
                let rec = x * tkm1 / tk;
                assert!(
                    (topdown - rec).abs() < 1e-9,
                    "x={x} K={k}: {topdown} vs {rec}"
                );
            }
        }
    }

    #[test]
    fn converges_with_k() {
        // More fraction terms → strictly smaller math-model error.
        let probe = |k: usize| {
            let m = Lambert::new(k, 6.0);
            let mut e: f64 = 0.0;
            let mut x = 0.0;
            while x < 6.0 {
                e = e.max((m.eval_f64(x) - tanh_ref(x)).abs());
                x += 0.01;
            }
            e
        };
        let (e3, e5, e7) = (probe(3), probe(5), probe(7));
        assert!(e3 > e5 && e5 > e7, "{e3} {e5} {e7}");
    }

    #[test]
    fn table1_error_bounds() {
        // Paper Table I row E: K = 7 → max err 4.87e-5.
        let m = Lambert::table1();
        let mut max_err: f64 = 0.0;
        for raw in -(INP.max_raw())..=INP.max_raw() {
            let x = Fx::from_raw(raw, INP);
            let y = eval_odd_saturating(&m, x, OUT);
            max_err = max_err.max((y.to_f64() - tanh_ref(x.to_f64())).abs());
        }
        assert!(max_err < 8.0e-5, "max_err {max_err} (paper 4.87e-5)");
        assert!(max_err > 1.0e-5);
    }

    #[test]
    fn small_x_nearly_exact() {
        // CF truncation error vanishes for small x; only quantization
        // remains.
        let m = Lambert::table1();
        for xv in [0.01, 0.1, 0.3] {
            let x = Fx::from_f64(xv, INP);
            let y = m.eval_fx(x, OUT);
            let err = (y.to_f64() - tanh_ref(x.to_f64())).abs();
            assert!(err <= 2.0 * OUT.ulp(), "x={xv} err={err}");
        }
    }

    #[test]
    fn wide_format_is_wide_enough() {
        // The sized format must hold the worst-case T_K without
        // saturating: evaluate at the domain edge and check against f64.
        let m = Lambert::table1();
        let x = Fx::from_f64(5.999, INP);
        let y = m.eval_fx(x, OUT);
        let err = (y.to_f64() - tanh_ref(x.to_f64())).abs();
        assert!(err < 1e-3, "edge err {err}");
    }

    #[test]
    fn inventory_scales_with_k() {
        // Paper §IV.F: pipelined implementation scales with fraction
        // count; stage cost is constant.
        let io = IoSpec::table1();
        let i5 = Lambert::new(5, 6.0).inventory(io);
        let i7 = Lambert::new(7, 6.0).inventory(io);
        assert_eq!(i7.multipliers - i5.multipliers, 4); // 2 per stage
        assert_eq!(i7.adders - i5.adders, 4);
        assert!(i7.pipeline_stages > i5.pipeline_stages);
        assert_eq!(i7.dividers, 1);
    }

    #[test]
    fn scaling_headroom_for_k_up_to_10() {
        // §IV.H: "Lambert's continued function can be scaled for better
        // accuracy" — the model must stay numerically sound as K grows.
        for k in [8, 9, 10] {
            let m = Lambert::new(k, 6.0);
            let x = Fx::from_f64(1.5, INP);
            let y = m.eval_fx(x, OUT);
            let err = (y.to_f64() - tanh_ref(1.5f64)).abs();
            assert!(err < 1e-4, "K={k} err={err}");
        }
    }
}
