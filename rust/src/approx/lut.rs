//! Lookup-table generation shared by the table-driven methods (A, B, C)
//! and the velocity-factor registers (D).
//!
//! The paper stores function values at uniformly spaced sample points
//! (`step` apart) in hardwired LUTs (§IV.B: "we can use bitmapping
//! (combinatorial) logic instead of a memory cut"). This module builds
//! those tables from the f64 reference, quantized once into the storage
//! format — exactly what a synthesis script would emit.

use crate::fixed::{Fx, QFormat, Round};

/// A uniformly sampled LUT of a scalar function over `[0, x_max]`.
#[derive(Clone, Debug)]
pub struct UniformLut {
    /// Quantized entries; `entries[i]` holds `f(i * step)`.
    entries: Vec<Fx>,
    /// Sample spacing (a power of two in all paper configurations).
    step: f64,
    /// log2(1/step) when step is a reciprocal power of two.
    step_shift: u32,
    /// Storage format of each entry.
    fmt: QFormat,
}

impl UniformLut {
    /// Samples `f` at `0, step, 2·step, …, n·step ≥ x_max` (inclusive of
    /// one point at/above `x_max`, plus `guard` extra points beyond — the
    /// Catmull-Rom datapath needs P_{k+2}).
    ///
    /// `step` must be a reciprocal power of two (all paper configs are),
    /// so that LUT addressing is a pure bit-slice of the input word.
    pub fn sample(
        f: impl Fn(f64) -> f64,
        step: f64,
        x_max: f64,
        guard: usize,
        fmt: QFormat,
    ) -> UniformLut {
        let inv = 1.0 / step;
        assert!(
            inv.fract() == 0.0 && (inv as u64).is_power_of_two(),
            "step {step} must be a reciprocal power of two"
        );
        let step_shift = (inv as u64).trailing_zeros();
        let n = (x_max / step).ceil() as usize + 1 + guard;
        let entries = (0..n)
            .map(|i| Fx::from_f64_round(f(i as f64 * step), fmt, Round::NearestEven))
            .collect();
        UniformLut { entries, step, step_shift, fmt }
    }

    /// Number of stored entries.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    /// True if the table is empty (never the case for valid configs).
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Sample spacing.
    pub fn step(&self) -> f64 {
        self.step
    }

    /// Entry storage format.
    pub fn format(&self) -> QFormat {
        self.fmt
    }

    /// Total storage in bits (entries × word width) — the cost model's
    /// LUT size input.
    pub fn total_bits(&self) -> u32 {
        self.len() as u32 * self.fmt.width()
    }

    /// Direct indexed access (clamped to the last entry, which models the
    /// saturated guard region).
    #[inline]
    pub fn at(&self, idx: usize) -> Fx {
        self.entries[idx.min(self.entries.len() - 1)]
    }

    /// Splits a non-negative input into (LUT index, interpolation
    /// fraction) exactly the way the datapath does: the top bits of the
    /// input word address the LUT, the remaining LSBs are the fraction
    /// `t ∈ [0, 1)` with `frac_bits(x) - step_shift` bits (paper Fig 3).
    ///
    /// Returns `(index, t)` where `t` is expressed in the given fraction
    /// format (fraction-only, non-negative).
    #[inline]
    pub fn split_index(&self, x: Fx) -> (usize, Fx) {
        debug_assert!(!x.is_negative());
        let in_frac = x.format().frac_bits;
        assert!(
            in_frac >= self.step_shift,
            "input precision 2^-{in_frac} coarser than LUT step 2^-{}",
            self.step_shift
        );
        let t_bits = in_frac - self.step_shift;
        let idx = (x.raw() >> t_bits) as usize;
        let t_raw = x.raw() & ((1i64 << t_bits) - 1);
        // t as a fraction in [0,1): t_raw * 2^-t_bits, stored in S.t_bits.
        let t = Fx::from_raw_unchecked(t_raw, QFormat::new(0, t_bits));
        (idx, t)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::approx::reference::tanh_ref;

    #[test]
    fn samples_tanh_grid() {
        let lut = UniformLut::sample(tanh_ref, 1.0 / 64.0, 6.0, 0, QFormat::S_15);
        assert_eq!(lut.len(), 6 * 64 + 1);
        assert_eq!(lut.at(0).raw(), 0);
        // entry 64 = tanh(1.0)
        let want = tanh_ref(1.0);
        assert!((lut.at(64).to_f64() - want).abs() <= QFormat::S_15.ulp() / 2.0 + 1e-12);
    }

    #[test]
    fn split_index_reassembles_input() {
        let lut = UniformLut::sample(tanh_ref, 1.0 / 64.0, 6.0, 0, QFormat::S_15);
        let x = Fx::from_f64(3.14159, QFormat::S3_12);
        let (idx, t) = lut.split_index(x);
        // x == idx*step + t*step exactly.
        let rebuilt = idx as f64 / 64.0 + t.to_f64() / 64.0;
        assert!((rebuilt - x.to_f64()).abs() < 1e-12);
        assert!(t.to_f64() < 1.0);
    }

    #[test]
    fn guard_entries_extend_table() {
        let plain = UniformLut::sample(tanh_ref, 1.0 / 16.0, 6.0, 0, QFormat::S_15);
        let guarded = UniformLut::sample(tanh_ref, 1.0 / 16.0, 6.0, 2, QFormat::S_15);
        assert_eq!(guarded.len(), plain.len() + 2);
    }

    #[test]
    fn at_clamps_past_end() {
        let lut = UniformLut::sample(tanh_ref, 1.0 / 16.0, 2.0, 0, QFormat::S_15);
        let last = lut.at(lut.len() - 1);
        assert_eq!(lut.at(lut.len() + 100).raw(), last.raw());
    }

    #[test]
    #[should_panic(expected = "reciprocal power of two")]
    fn non_pow2_step_rejected() {
        UniformLut::sample(tanh_ref, 0.3, 6.0, 0, QFormat::S_15);
    }

    #[test]
    fn total_bits_matches_paper_pwl_sizing() {
        // Paper §IV.B: step 1/64 over (0,6) — 384 intervals, 385 sampled
        // endpoints, 16-bit entries.
        let lut = UniformLut::sample(tanh_ref, 1.0 / 64.0, 6.0, 0, QFormat::S_15);
        assert_eq!(lut.len(), 385);
        assert_eq!(lut.total_bits(), 385 * 16);
    }
}
