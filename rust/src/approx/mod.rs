//! The six tanh approximations compared by the paper, as bit-exact
//! fixed-point datapath golden models plus f64 math models.
//!
//! Every method implements [`TanhApprox`]:
//!
//! - `eval_f64` — the *math model*: the approximation computed in f64,
//!   isolating algorithmic error from quantization error;
//! - `eval_fx` — the *datapath model*: every intermediate uses the
//!   fixed-point widths a synthesized implementation would, built only
//!   from [`crate::fixed`] primitives, so the result is bit-exact
//!   reproducible (and matches the Pallas kernels' int32 emulation);
//! - `inventory` — the hardware component inventory used by the cost
//!   model ([`crate::cost`]) to reproduce the paper's §IV analysis.
//!
//! All methods exploit tanh's odd symmetry (paper §IV: "the main
//! algorithm can be implemented for positive values only") via
//! [`eval_odd_saturating`], and saturate to the output format's max
//! beyond the configured domain (paper §III.A).
//!
//! Every method additionally **compiles** ([`TanhApprox::compile`]) into
//! an integer-only batch kernel ([`compiled::CompiledKernel`]) that is
//! bit-exact against `eval_fx` but one to two orders of magnitude
//! faster: the serving backend and the exhaustive error sweeps run on
//! compiled kernels, the scalar datapath models stay the auditable
//! golden reference. See [`compiled`] for the per-method kernel shapes
//! and when to use which path.
//!
//! Configurations are *named* by [`spec::MethodSpec`]: a typed,
//! parse/Display round-trippable design point (method × parameter ×
//! I/O formats × domain) that keys the process-wide compiled-kernel
//! cache ([`spec::Registry`]). [`table1_suite`] and [`build`] are thin
//! wrappers over specs.

pub mod catmull_rom;
pub mod compiled;
pub(crate) mod swar;
pub mod lambert;
pub mod lut;
pub mod newton;
pub mod pwl;
pub mod pwl_nonuniform;
pub mod reference;
pub mod regions;
pub mod sigmoid;
pub mod spec;
pub mod taylor;
pub mod velocity;

pub use compiled::CompiledKernel;
pub use sigmoid::{sigmoid_ref, SigmoidFromTanh, SigmoidKernel};
pub use spec::{ActKind, ActSpec, CacheStats, MethodParams, MethodSpec, Registry};

use crate::cost::Inventory;
use crate::fixed::{Fx, QFormat};

/// Paper method identifiers (Table I heading row).
#[derive(Clone, Copy, PartialEq, Eq, Debug, Hash)]
pub enum MethodId {
    /// A — piecewise linear interpolation.
    Pwl,
    /// B1 — Taylor series, quadratic (3 terms).
    TaylorQuadratic,
    /// B2 — Taylor series, cubic (4 terms).
    TaylorCubic,
    /// C — uniform cubic Catmull-Rom spline.
    CatmullRom,
    /// D — trigonometric expansion via velocity factors.
    Velocity,
    /// E — Lambert continued fraction.
    Lambert,
}

impl MethodId {
    /// The paper's single-letter label.
    pub fn label(self) -> &'static str {
        match self {
            MethodId::Pwl => "A",
            MethodId::TaylorQuadratic => "B1",
            MethodId::TaylorCubic => "B2",
            MethodId::CatmullRom => "C",
            MethodId::Velocity => "D",
            MethodId::Lambert => "E",
        }
    }

    /// Human-readable method name as used in Table I.
    pub fn name(self) -> &'static str {
        match self {
            MethodId::Pwl => "PWL",
            MethodId::TaylorQuadratic => "Taylor 1",
            MethodId::TaylorCubic => "Taylor 2",
            MethodId::CatmullRom => "Catmull Rom",
            MethodId::Velocity => "Trig Expansion",
            MethodId::Lambert => "Lambert",
        }
    }

    /// All six methods in paper order.
    pub fn all() -> [MethodId; 6] {
        [
            MethodId::Pwl,
            MethodId::TaylorQuadratic,
            MethodId::TaylorCubic,
            MethodId::CatmullRom,
            MethodId::Velocity,
            MethodId::Lambert,
        ]
    }

    /// Parses CLI names: `pwl|taylor1|taylor2|catmull|velocity|lambert`
    /// or the paper letters `A|B1|B2|C|D|E`.
    pub fn parse(s: &str) -> Option<MethodId> {
        match s.to_ascii_lowercase().as_str() {
            "a" | "pwl" => Some(MethodId::Pwl),
            "b1" | "taylor1" | "taylor-quadratic" => Some(MethodId::TaylorQuadratic),
            "b2" | "taylor2" | "taylor-cubic" => Some(MethodId::TaylorCubic),
            "c" | "catmull" | "catmull-rom" => Some(MethodId::CatmullRom),
            "d" | "velocity" | "trig" => Some(MethodId::Velocity),
            "e" | "lambert" => Some(MethodId::Lambert),
            _ => None,
        }
    }

    /// [`MethodId::parse`] with the canonical error message: one
    /// helper used by every CLI subcommand and the net front-end, so
    /// unknown-method errors always list the accepted names, the paper
    /// letters, and the full-spec alternative.
    pub fn parse_or_err(s: &str) -> Result<MethodId, String> {
        MethodId::parse(s).ok_or_else(|| {
            format!(
                "unknown method '{s}' — accepted: pwl|taylor1|taylor2|catmull|velocity|lambert \
                 (or letters A|B1|B2|C|D|E); full design points use the spec grammar, \
                 e.g. pwl:step=1/64:in=S3.12:out=S.15"
            )
        })
    }
}

/// Common interface over the six approximations.
pub trait TanhApprox: Send + Sync {
    /// Which paper method this is.
    fn id(&self) -> MethodId;

    /// A descriptive name including the configuration, e.g. `PWL(step=1/64)`.
    fn describe(&self) -> String;

    /// The math model: approximation computed in f64 over the full real
    /// line (odd symmetry + saturation applied).
    fn eval_f64(&self, x: f64) -> f64;

    /// The datapath model: bit-exact fixed-point evaluation for
    /// non-negative in-domain `x` (sign and saturation handled by
    /// [`eval_odd_saturating`], which `eval_fx` routes through).
    fn eval_positive_fx(&self, x: Fx, out: QFormat) -> Fx;

    /// Upper edge of the approximation domain; inputs at or beyond this
    /// magnitude return the saturated output (paper §III.A).
    fn domain_max(&self) -> f64;

    /// Hardware component inventory for the cost model (paper §IV).
    fn inventory(&self, io: IoSpec) -> Inventory;

    /// Full datapath evaluation: sign split + saturation + positive core.
    fn eval_fx(&self, x: Fx, out: QFormat) -> Fx {
        eval_odd_saturating(self, x, out)
    }

    /// Compiles this configuration into an integer-only batch kernel
    /// for the given I/O formats — the production hot path.
    ///
    /// The kernel is bit-exact against [`TanhApprox::eval_fx`] on every
    /// representable input raw (asserted by a strided cross-check in
    /// debug builds and exhaustively by the property tests). The
    /// default tabulates the golden datapath densely (exact by
    /// construction); the six paper methods override it with structured
    /// kernels — see [`compiled`] for the shapes and trade-offs.
    fn compile(&self, io: IoSpec) -> CompiledKernel {
        CompiledKernel::tabulate(self, io)
    }
}

/// Boxed trait objects are themselves approximators, so code that is
/// generic over `M: TanhApprox` (notably [`SigmoidFromTanh`]) accepts
/// the `Box<dyn TanhApprox>` that [`MethodSpec::build`] returns.
/// `eval_fx` and `compile` delegate explicitly so a concrete method's
/// overrides are preserved rather than re-deriving the trait defaults.
impl TanhApprox for Box<dyn TanhApprox> {
    fn id(&self) -> MethodId {
        (**self).id()
    }

    fn describe(&self) -> String {
        (**self).describe()
    }

    fn eval_f64(&self, x: f64) -> f64 {
        (**self).eval_f64(x)
    }

    fn eval_positive_fx(&self, x: Fx, out: QFormat) -> Fx {
        (**self).eval_positive_fx(x, out)
    }

    fn domain_max(&self) -> f64 {
        (**self).domain_max()
    }

    fn inventory(&self, io: IoSpec) -> Inventory {
        (**self).inventory(io)
    }

    fn eval_fx(&self, x: Fx, out: QFormat) -> Fx {
        (**self).eval_fx(x, out)
    }

    fn compile(&self, io: IoSpec) -> CompiledKernel {
        (**self).compile(io)
    }
}

/// Input/output format pair used for inventory sizing.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct IoSpec {
    /// Input fixed-point format (e.g. S3.12).
    pub input: QFormat,
    /// Output fixed-point format (e.g. S.15).
    pub output: QFormat,
}

impl IoSpec {
    /// The Table I analysis spec: S3.12 in, S.15 out, domain (-6, 6).
    pub fn table1() -> IoSpec {
        IoSpec { input: QFormat::S3_12, output: QFormat::S_15 }
    }
}

/// Applies tanh's odd symmetry and output saturation around a method's
/// positive-domain core — the shared front/back-end every datapath in
/// the paper has (sign bit peel-off + clamp beyond the domain).
pub fn eval_odd_saturating<M: TanhApprox + ?Sized>(m: &M, x: Fx, out: QFormat) -> Fx {
    let neg = x.is_negative();
    let mag = x.abs();
    let y = if mag.to_f64() >= m.domain_max() {
        Fx::max(out) // ±(1 - 2^-b), paper §III.A
    } else {
        m.eval_positive_fx(mag, out)
    };
    // Clamp to [0, max]: approximation wiggle must never exceed ±1.
    let y = if y.is_negative() { Fx::zero(out) } else { y };
    if neg {
        y.neg()
    } else {
        y
    }
}

/// Builds the Table I configuration of every method, in paper order —
/// a thin wrapper over [`MethodSpec::table1_all`]. These are the six
/// rows of Table I (max input 6.0, 12-bit input precision, 15-bit
/// output precision).
pub fn table1_suite() -> Vec<Box<dyn TanhApprox>> {
    MethodSpec::table1_all().iter().map(|s| s.build()).collect()
}

/// Builds a method with an explicit tunable parameter:
/// step size for A/B1/B2/C, threshold for D, term count for E.
///
/// A thin wrapper over [`MethodSpec::with_param`] (validated against
/// the Table I I/O formats): out-of-range steps and non-integer or
/// non-positive Lambert term counts are errors now, where the old
/// signature silently truncated `param as usize`.
pub fn build(id: MethodId, param: f64, domain_max: f64) -> Result<Box<dyn TanhApprox>, String> {
    Ok(MethodSpec::with_param(id, param, IoSpec::table1(), domain_max)?.build())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn method_labels_match_paper() {
        let labels: Vec<&str> = MethodId::all().iter().map(|m| m.label()).collect();
        assert_eq!(labels, vec!["A", "B1", "B2", "C", "D", "E"]);
    }

    #[test]
    fn parse_accepts_letters_and_names() {
        assert_eq!(MethodId::parse("A"), Some(MethodId::Pwl));
        assert_eq!(MethodId::parse("b2"), Some(MethodId::TaylorCubic));
        assert_eq!(MethodId::parse("velocity"), Some(MethodId::Velocity));
        assert_eq!(MethodId::parse("nope"), None);
        // The canonical error lists every accepted spelling.
        let err = MethodId::parse_or_err("nope").unwrap_err();
        for needle in ["pwl", "taylor1", "lambert", "B1", "spec grammar"] {
            assert!(err.contains(needle), "'{needle}' missing from: {err}");
        }
    }

    #[test]
    fn build_validates_lambert_terms_instead_of_truncating() {
        // Regression for the lossy `param as usize` path: 2.7 used to
        // build K=2 silently; now it is a validation error.
        let err = build(MethodId::Lambert, 2.7, 6.0).unwrap_err();
        assert!(err.contains("integer"), "{err}");
        assert!(build(MethodId::Lambert, 0.0, 6.0).is_err());
        let m = build(MethodId::Lambert, 3.0, 6.0).unwrap();
        assert_eq!(m.describe(), "Lambert(K=3)");
    }

    #[test]
    fn table1_suite_has_six_methods_in_order() {
        let suite = table1_suite();
        assert_eq!(suite.len(), 6);
        let ids: Vec<MethodId> = suite.iter().map(|m| m.id()).collect();
        assert_eq!(ids, MethodId::all().to_vec());
    }

    #[test]
    fn odd_symmetry_holds_for_every_method() {
        let io = IoSpec::table1();
        for m in table1_suite() {
            for v in [0.1, 0.5, 1.0, 2.5, 5.9] {
                let xp = Fx::from_f64(v, io.input);
                let xn = Fx::from_f64(-v, io.input);
                let yp = m.eval_fx(xp, io.output);
                let yn = m.eval_fx(xn, io.output);
                assert_eq!(yp.raw(), -yn.raw(), "{} at {v}", m.describe());
            }
        }
    }

    #[test]
    fn saturates_beyond_domain() {
        let io = IoSpec::table1();
        for m in table1_suite() {
            let x = Fx::from_f64(7.5, io.input);
            let y = m.eval_fx(x, io.output);
            assert_eq!(y.raw(), io.output.max_raw(), "{}", m.describe());
            // Paper §III.A: the saturated output is ±(1 − 2^-b), i.e.
            // symmetric ±max_raw (not the asymmetric two's-complement min).
            let y = m.eval_fx(x.neg(), io.output);
            assert_eq!(y.raw(), -io.output.max_raw(), "{}", m.describe());
        }
    }

    #[test]
    fn zero_maps_to_zero() {
        let io = IoSpec::table1();
        for m in table1_suite() {
            let y = m.eval_fx(Fx::zero(io.input), io.output);
            assert_eq!(y.raw(), 0, "{}", m.describe());
        }
    }
}
