//! Newton-Raphson reciprocal and division — the divider substrate the
//! rational methods (D, E) share (paper §IV.E eq. 19 / §IV.F).
//!
//! The paper's divider "can be implemented by multiplying numerator with
//! the reciprocal of denominator which can be computed using Newton
//! Raphson method": x_{i+1} = x_i (2 - b·x_i), which doubles the number
//! of correct bits per iteration. The hardware realization normalizes
//! the denominator into [0.5, 1) with a leading-zero count + barrel
//! shift, runs a fixed number of multiply-subtract iterations in an
//! internal S1.30 format, and denormalizes.

use crate::fixed::{fx_mul_wide, Fx, FxWide, QFormat, Round};

/// Internal format for NR iterations: 32-bit word, 30 fraction bits.
/// The reciprocal of a mantissa in [0.5, 1) lies in (1, 2], so one
/// integer bit suffices.
pub const NR_FMT: QFormat = QFormat::new(1, 30);

/// Default iteration count. The linear seed is accurate to ~2^-4.8;
/// with quadratic convergence 3 iterations reach ~2^-38, beyond the
/// S1.30 internal precision — matching a 3-stage pipelined divider.
pub const NR_ITERS: usize = 3;

/// The linear NR seed `x0 = 48/17 − 32/17·m` for a normalized mantissa
/// `m ∈ [0.5, 1)` — the standard hardware choice (max seed error 1/17).
/// One constant multiplier + one adder in the datapath.
pub fn nr_seed(m: Fx) -> Fx {
    debug_assert_eq!(m.format(), NR_FMT);
    let c1 = Fx::from_f64(48.0 / 17.0, QFormat::new(2, 29));
    let c2 = Fx::from_f64(32.0 / 17.0, QFormat::new(2, 29));
    FxWide::from_fx(c1)
        .add(fx_mul_wide(c2, m).mul(FxWide { raw: -1, frac: 0 }))
        .narrow(NR_FMT, Round::NearestAway)
}

/// One NR refinement `x ← x·(2 − m·x)` — two dependent multiplies, i.e.
/// two pipeline stages in the hw model.
pub fn nr_step(m: Fx, x: Fx) -> Fx {
    let two = FxWide { raw: 2i128 << NR_FMT.frac_bits, frac: NR_FMT.frac_bits };
    let bx = fx_mul_wide(m, x);
    let corr = two
        .add(bx.mul(FxWide { raw: -1, frac: 0 }))
        .narrow(QFormat::new(2, 29), Round::NearestAway);
    fx_mul_wide(x, corr).narrow(NR_FMT, Round::NearestAway)
}

/// Normalizes a positive denominator into a mantissa `m ∈ [0.5, 1)` in
/// [`NR_FMT`] and the exponent `e` with `den = m·2^e` — the
/// leading-zero-count + barrel-shift front end of the divider.
pub fn normalize_den(den: Fx) -> (Fx, i32) {
    debug_assert!(den.raw() > 0);
    let raw = den.raw();
    let p = 63 - raw.leading_zeros(); // msb index
    let mut e = p as i32 + 1 - den.format().frac_bits as i32;
    let mut m_raw = if p + 1 <= NR_FMT.frac_bits {
        raw << (NR_FMT.frac_bits - (p + 1))
    } else {
        let sh = p + 1 - NR_FMT.frac_bits;
        Round::NearestAway.shift_right(raw as i128, sh) as i64
    };
    // Rounding in the narrow can carry all the way up to m == 1.0
    // (e.g. raw = 2^(p+1) − 1): renormalize into [0.5, 1) by bumping
    // the exponent, exactly what the hardware's carry-out path does.
    if m_raw >= 1i64 << NR_FMT.frac_bits {
        m_raw >>= 1;
        e += 1;
    }
    (Fx::from_raw_unchecked(m_raw, NR_FMT), e)
}

/// Back end of the divider: `num·(1/m)·2^−e` narrowed once into `out`.
pub fn finish_div(num: Fx, recip: Fx, e: i32, out: QFormat) -> Fx {
    let wide = fx_mul_wide(num, recip);
    let shifted = if e >= 0 {
        FxWide { raw: wide.raw, frac: wide.frac + e as u32 }
    } else {
        FxWide { raw: wide.raw << (-e) as u32, frac: wide.frac }
    };
    shifted.narrow(out, Round::NearestAway)
}

/// Newton-Raphson reciprocal of a *normalized* mantissa `m ∈ [0.5, 1)`
/// held in [`NR_FMT`]. Returns `1/m ∈ (1, 2]` in [`NR_FMT`].
pub fn recip_mantissa(m: Fx, iters: usize) -> Fx {
    debug_assert!(m.to_f64() >= 0.5 && m.to_f64() < 1.0, "m={} not normalized", m.to_f64());
    let mut x = nr_seed(m);
    for _ in 0..iters {
        x = nr_step(m, x);
    }
    x
}

/// Full fixed-point division `num / den` via normalize → NR reciprocal →
/// multiply → denormalize, rounded once into `out`.
///
/// `den` must be strictly positive. This is the divider block instanced
/// by the velocity-factor (D) and Lambert (E) datapaths.
pub fn fx_div(num: Fx, den: Fx, out: QFormat, iters: usize) -> Fx {
    assert!(den.raw() > 0, "fx_div: denominator must be positive, got {den:?}");
    let (m, e) = normalize_den(den);
    let r = recip_mantissa(m, iters); // 1/m in (1,2]
    finish_div(num, r, e, out)
}

/// f64 math model of the same divider (seed + `iters` NR refinements) —
/// used by `eval_f64` paths so math and datapath models share the
/// algorithmic error of a finite-iteration divider.
pub fn div_f64(num: f64, den: f64, iters: usize) -> f64 {
    debug_assert!(den > 0.0);
    let e = den.log2().floor() as i32 + 1;
    let m = den / (2f64).powi(e); // in [0.5, 1)
    let mut x = 48.0 / 17.0 - 32.0 / 17.0 * m;
    for _ in 0..iters {
        x = x * (2.0 - m * x);
    }
    num * x / (2f64).powi(e)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{prop_check, Prng};

    #[test]
    fn recip_mantissa_converges() {
        for &mv in &[0.5, 0.6, 0.75, 0.9, 0.999] {
            let m = Fx::from_f64(mv, NR_FMT);
            let r = recip_mantissa(m, NR_ITERS);
            let err = (r.to_f64() - 1.0 / m.to_f64()).abs();
            assert!(err < 1e-8, "m={mv} err={err}");
        }
    }

    #[test]
    fn recip_fewer_iters_less_accurate() {
        let m = Fx::from_f64(0.7, NR_FMT);
        let e0 = (recip_mantissa(m, 0).to_f64() - 1.0 / 0.7).abs();
        let e1 = (recip_mantissa(m, 1).to_f64() - 1.0 / 0.7).abs();
        let e2 = (recip_mantissa(m, 2).to_f64() - 1.0 / 0.7).abs();
        assert!(e0 > e1 && e1 > e2, "{e0} {e1} {e2}");
    }

    #[test]
    fn fx_div_basic() {
        let f = QFormat::S7_24;
        let num = Fx::from_f64(1.0, f);
        let den = Fx::from_f64(3.0, f);
        let q = fx_div(num, den, QFormat::S_15, NR_ITERS);
        assert!((q.to_f64() - 1.0 / 3.0).abs() <= QFormat::S_15.ulp(), "{}", q.to_f64());
    }

    #[test]
    fn prop_fx_div_accurate_to_out_ulp() {
        prop_check("fx_div error ≤ 1 out-ulp", 2000, |g: &mut Prng| {
            let f = QFormat::S7_24;
            let out = QFormat::new(1, 20);
            let den_v = g.f64_in(0.01, 100.0);
            // keep quotient in out's range (-2, 2)
            let q_target = g.f64_in(-1.9, 1.9);
            let num_v = q_target * den_v;
            if num_v.abs() >= f.max_value() {
                return Ok(());
            }
            let num = Fx::from_f64(num_v, f);
            let den = Fx::from_f64(den_v, f);
            if den.raw() <= 0 {
                return Ok(());
            }
            let q = fx_div(num, den, out, NR_ITERS);
            let exact = num.to_f64() / den.to_f64();
            let err = (q.to_f64() - exact).abs();
            if err > out.ulp() {
                return Err(format!("num={num_v} den={den_v} q={} exact={exact} err={err}", q.to_f64()));
            }
            Ok(())
        });
    }

    #[test]
    fn div_f64_matches_exact_division() {
        prop_check("div_f64 ≈ /", 1000, |g: &mut Prng| {
            let num = g.f64_in(-10.0, 10.0);
            let den = g.f64_in(0.01, 1000.0);
            let q = div_f64(num, den, NR_ITERS);
            let rel = ((q - num / den) / (num / den).abs().max(1e-30)).abs();
            if rel > 1e-9 {
                return Err(format!("num={num} den={den} rel={rel}"));
            }
            Ok(())
        });
    }

    #[test]
    #[should_panic(expected = "denominator must be positive")]
    fn div_by_nonpositive_panics() {
        let f = QFormat::S7_24;
        fx_div(Fx::from_f64(1.0, f), Fx::zero(f), QFormat::S_15, 3);
    }
}
