//! Method A — piecewise-linear interpolation (paper §II.A, §IV.B).
//!
//! The function is sampled uniformly every `step`; between samples the
//! datapath computes `y = y0 + (y1 - y0)·t` where `t` is the low bits of
//! the input word (Fig 3). No divider is needed because `b - a = step`
//! is a power of two. Hardware: two LUT fetches (split odd/even banks to
//! fetch both endpoints in one cycle — §IV.B), one subtractor, one
//! multiplier, one adder.

use super::compiled::{CompiledKernel, KernelBody};
use super::lut::UniformLut;
use super::reference::tanh_ref;
use super::{IoSpec, MethodId, TanhApprox};
use crate::cost::Inventory;
use crate::fixed::{fx_mul_wide, Fx, FxWide, QFormat, Round};

/// PWL approximator: uniform step, LUT of endpoint values.
#[derive(Clone, Debug)]
pub struct Pwl {
    lut: UniformLut,
    step: f64,
    domain_max: f64,
}

impl Pwl {
    /// Builds a PWL approximator with the given step (a reciprocal power
    /// of two) over `[0, domain_max]`. LUT entries stored in `S.15` plus
    /// two guard integer bits headroom is unnecessary — tanh ≤ 1, so the
    /// paper's `S.15` output format is also the storage format.
    pub fn new(step: f64, domain_max: f64) -> Pwl {
        // One guard entry so the interval containing domain_max has an
        // upper endpoint.
        let lut = UniformLut::sample(tanh_ref, step, domain_max, 1, QFormat::S_15);
        Pwl { lut, step, domain_max }
    }

    /// Table I row "A": step 1/64, domain (-6, 6).
    pub fn table1() -> Pwl {
        Pwl::new(1.0 / 64.0, 6.0)
    }

    /// The endpoint LUT (exposed for the hw datapath simulator).
    pub fn lut(&self) -> &UniformLut {
        &self.lut
    }

    /// Step size.
    pub fn step(&self) -> f64 {
        self.step
    }
}

impl TanhApprox for Pwl {
    fn id(&self) -> MethodId {
        MethodId::Pwl
    }

    fn describe(&self) -> String {
        format!("PWL(step={})", crate::util::table::step_str(self.step))
    }

    fn eval_f64(&self, x: f64) -> f64 {
        let neg = x < 0.0;
        let x = x.abs();
        let y = if x >= self.domain_max {
            1.0
        } else {
            let k = (x / self.step).floor();
            let a = k * self.step;
            let t = (x - a) / self.step;
            let y0 = tanh_ref(a);
            let y1 = tanh_ref(a + self.step);
            y0 + (y1 - y0) * t
        };
        if neg {
            -y
        } else {
            y
        }
    }

    fn eval_positive_fx(&self, x: Fx, out: QFormat) -> Fx {
        let (idx, t) = self.lut.split_index(x);
        let y0 = self.lut.at(idx);
        let y1 = self.lut.at(idx + 1);
        // delta = y1 - y0 (exact in storage format: both are S.15).
        let delta = Fx::from_raw(y1.raw() - y0.raw(), y0.format());
        // y = y0 + delta * t, multiply kept wide, single rounding at the end.
        let prod = fx_mul_wide(delta, t);
        let y = FxWide::from_fx(y0).add(prod).narrow(out, Round::NearestEven);
        y
    }

    fn domain_max(&self) -> f64 {
        self.domain_max
    }

    /// Compiled form (superseding the old `compile_raw` closure, which
    /// was hardwired to S3.12 → S.15): the endpoint LUT as raw words
    /// plus an integer lerp on the low t bits, for any I/O formats the
    /// step can address. ~5× the generic `eval_fx` rate — EXPERIMENTS.md
    /// §Perf.
    fn compile(&self, io: IoSpec) -> CompiledKernel {
        let step_shift = (1.0 / self.step).log2() as u32;
        if io.input.frac_bits < step_shift {
            // Step finer than the input ulp: the bit-slice decode does
            // not exist (the scalar path rejects this too).
            return CompiledKernel::tabulate(self, io);
        }
        let t_bits = io.input.frac_bits - step_shift;
        let lut: Vec<i64> = (0..self.lut.len()).map(|i| self.lut.at(i).raw()).collect();
        let body = KernelBody::Pwl { lut, lut_frac: self.lut.format().frac_bits, t_bits };
        CompiledKernel::with_body(io, self.domain_max, body).debug_check(self)
    }

    fn inventory(&self, io: IoSpec) -> Inventory {
        // Paper §IV.B: two adders (delta subtract + final add), one
        // multiplier, LUT split in two banks with alternate entries.
        let t_bits = io.input.frac_bits - (1.0 / self.step).log2() as u32;
        Inventory {
            adders: 2,
            multipliers: 1,
            lut_entries: self.lut.len() as u32,
            lut_bits: self.lut.total_bits(),
            mult_width: io.output.width().max(t_bits),
            add_width: io.output.width(),
            // fetch | subtract | multiply | add  (Fig 3 pipeline)
            pipeline_stages: 4,
            ..Default::default()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::approx::eval_odd_saturating;

    const OUT: QFormat = QFormat::S_15;
    const INP: QFormat = QFormat::S3_12;

    #[test]
    fn exact_at_lut_points() {
        let pwl = Pwl::table1();
        for i in [0usize, 1, 64, 128, 300] {
            let x = Fx::from_f64(i as f64 / 64.0, INP);
            let y = pwl.eval_fx(x, OUT);
            let want = tanh_ref(x.to_f64());
            assert!(
                (y.to_f64() - want).abs() <= OUT.ulp() / 2.0 + 1e-12,
                "i={i} y={} want={want}",
                y.to_f64()
            );
        }
    }

    #[test]
    fn table1_error_bounds() {
        // Paper Table I row A: step 1/64 → max err 4.65e-5.
        let pwl = Pwl::table1();
        let mut max_err: f64 = 0.0;
        for raw in -(INP.max_raw())..=INP.max_raw() {
            let x = Fx::from_raw(raw, INP);
            let y = eval_odd_saturating(&pwl, x, OUT);
            max_err = max_err.max((y.to_f64() - tanh_ref(x.to_f64())).abs());
        }
        assert!(max_err < 6.0e-5, "max_err {max_err} (paper: 4.65e-5)");
        assert!(max_err > 1.0e-5, "suspiciously small {max_err}");
    }

    #[test]
    fn math_model_is_above_datapath_accuracy() {
        // f64 model has no quantization: its error is the pure PWL
        // interpolation error h²/8·max|f''| ≈ 2.3e-5 for h=1/64.
        let pwl = Pwl::table1();
        let mut max_err: f64 = 0.0;
        let mut x = -6.0;
        while x < 6.0 {
            max_err = max_err.max((pwl.eval_f64(x) - tanh_ref(x)).abs());
            x += 1e-3;
        }
        assert!(max_err < 2.5e-5, "math-model err {max_err}");
    }

    #[test]
    fn monotone_on_grid() {
        // tanh is monotone; PWL interpolation of a monotone function is
        // monotone, and quantization can only flatten, never invert.
        let pwl = Pwl::table1();
        let mut prev = i64::MIN;
        for raw in 0..INP.max_raw() {
            let y = eval_odd_saturating(&pwl, Fx::from_raw(raw, INP), OUT);
            assert!(y.raw() >= prev, "non-monotone at raw {raw}");
            prev = y.raw();
        }
    }

    #[test]
    fn coarser_step_more_error() {
        let fine = Pwl::new(1.0 / 128.0, 6.0);
        let coarse = Pwl::new(1.0 / 16.0, 6.0);
        let probe = |m: &Pwl| {
            let mut e: f64 = 0.0;
            for raw in 0..INP.max_raw() {
                let x = Fx::from_raw(raw, INP);
                let y = m.eval_fx(x, OUT);
                e = e.max((y.to_f64() - tanh_ref(x.to_f64())).abs());
            }
            e
        };
        assert!(probe(&coarse) > probe(&fine) * 4.0);
    }

    #[test]
    fn compiled_kernel_bit_matches_eval_fx() {
        // The production fast path must agree with the golden model on
        // every S3.12 word (full exhaustive check).
        let pwl = Pwl::table1();
        let kernel = pwl.compile(IoSpec::table1());
        for raw in -(INP.max_raw() + 1)..=INP.max_raw() {
            let x = Fx::from_raw(raw, INP);
            assert_eq!(
                kernel.eval_raw(raw),
                pwl.eval_fx(x, OUT).raw(),
                "raw {raw}"
            );
        }
    }

    #[test]
    fn compiled_kernel_generalizes_to_other_formats() {
        // The old compile_raw was hardwired to S3.12 → S.15; the kernel
        // must stay bit-exact on the Table III formats too.
        for (input, output, domain) in [
            (QFormat::S2_13, QFormat::S_15, 4.0),
            (QFormat::S2_5, QFormat::S_7, 4.0),
        ] {
            let pwl = Pwl::new(1.0 / 16.0, domain);
            let kernel = pwl.compile(IoSpec { input, output });
            for raw in input.min_raw()..=input.max_raw() {
                let x = Fx::from_raw(raw, input);
                assert_eq!(
                    kernel.eval_raw(raw),
                    pwl.eval_fx(x, output).raw(),
                    "{input} -> {output} raw {raw}"
                );
            }
        }
    }

    #[test]
    fn inventory_matches_paper_iv_b() {
        let inv = Pwl::table1().inventory(IoSpec::table1());
        assert_eq!(inv.adders, 2);
        assert_eq!(inv.multipliers, 1);
        // Paper: 2 banks × 384 entries = 768 endpoints ≈ our 385+guard
        // sampled points for step 1/64... the paper sizes at step 1/128
        // in §IV.B text (128×6/2 per bank); our table is entry-exact for
        // the Table I configuration (6·64 + 1 + guard).
        assert_eq!(inv.lut_entries, 6 * 64 + 2);
        assert_eq!(inv.dividers, 0);
    }
}
