//! Non-uniform PWL segmentation (paper §II.A: "The domain may be
//! divided uniformly or non-uniformly. The uniform division simplifies
//! the implementation while the non-uniform division reduces storage
//! requirement. Algorithms are available for selecting most significant
//! points given error tolerance.").
//!
//! This module implements that algorithm: a greedy maximal-segment
//! sweep that, given an error tolerance ε, emits the fewest breakpoints
//! such that linear interpolation between stored tanh values stays
//! within ε everywhere. The hardware realization stores breakpoints +
//! values and finds the segment with a small binary-search comparator
//! tree (range-addressable LUT, the Leboeuf et al. [3] structure),
//! which the inventory prices accordingly.

use super::reference::tanh_ref;
use super::{IoSpec, MethodId, TanhApprox};
use crate::cost::Inventory;
use crate::fixed::{fx_mul_wide, Fx, FxWide, QFormat, Round};

/// Non-uniform PWL approximator with greedily-chosen breakpoints.
#[derive(Clone, Debug)]
pub struct PwlNonUniform {
    /// Breakpoints x_i (ascending, starting at 0, ending ≥ domain_max),
    /// stored in the input format.
    breaks: Vec<Fx>,
    /// tanh(x_i) quantized to the storage format.
    values: Vec<Fx>,
    /// Per-segment reciprocal slope scale: precomputed
    /// (y_{i+1} − y_i) / (x_{i+1} − x_i) in a wide format, so the
    /// datapath needs no divider.
    slopes: Vec<Fx>,
    tolerance: f64,
    domain_max: f64,
}

/// Wide slope format (slope ≤ 1 for tanh; 24 fraction bits).
const SLOPE_FMT: QFormat = QFormat::new(1, 24);

impl PwlNonUniform {
    /// Greedy segmentation: from each breakpoint, extend the segment as
    /// far as the chord error stays ≤ `tolerance` (checked on the input
    /// grid), then place the next breakpoint.
    pub fn build(tolerance: f64, domain_max: f64, input: QFormat, storage: QFormat) -> Self {
        assert!(tolerance > 0.0);
        let step = input.ulp();
        let n_grid = (domain_max / step).ceil() as i64;
        let mut breaks_raw = vec![0i64];
        let mut cur = 0i64;
        while cur < n_grid {
            // Exponential probe + binary search for the farthest end
            // whose chord error is within tolerance.
            let mut lo = cur + 1;
            let mut hi = (cur + 2).min(n_grid);
            while hi < n_grid && Self::chord_ok(cur, hi, step, tolerance) {
                lo = hi;
                hi = (hi * 2 - cur).min(n_grid);
            }
            // binary search in (lo, hi]
            while lo < hi {
                let mid = (lo + hi + 1) / 2;
                if Self::chord_ok(cur, mid, step, tolerance) {
                    lo = mid;
                } else {
                    hi = mid - 1;
                }
            }
            cur = lo.max(cur + 1);
            breaks_raw.push(cur);
        }
        let breaks: Vec<Fx> = breaks_raw.iter().map(|&r| Fx::from_raw(r, input)).collect();
        let values: Vec<Fx> = breaks
            .iter()
            .map(|b| Fx::from_f64_round(tanh_ref(b.to_f64()), storage, Round::NearestEven))
            .collect();
        let slopes: Vec<Fx> = breaks
            .windows(2)
            .map(|w| {
                let dx = w[1].to_f64() - w[0].to_f64();
                let dy = tanh_ref(w[1].to_f64()) - tanh_ref(w[0].to_f64());
                Fx::from_f64(dy / dx, SLOPE_FMT)
            })
            .collect();
        PwlNonUniform { breaks, values, slopes, tolerance, domain_max }
    }

    /// Max deviation of the chord from tanh over [a, b] (grid points).
    fn chord_ok(a_raw: i64, b_raw: i64, step: f64, tol: f64) -> bool {
        let (a, b) = (a_raw as f64 * step, b_raw as f64 * step);
        let (ya, yb) = (tanh_ref(a), tanh_ref(b));
        let slope = (yb - ya) / (b - a);
        // tanh is concave on [0, ∞): the max chord error is at the
        // interior point where tanh'(x) == slope ⇒ x = atanh(sqrt(1 −
        // slope)); cheaper and exact vs sampling.
        if slope >= 1.0 {
            return true;
        }
        let x_star = (1.0 - slope).sqrt().atanh();
        if x_star <= a || x_star >= b {
            return true;
        }
        let err = (tanh_ref(x_star) - (ya + slope * (x_star - a))).abs();
        err <= tol
    }

    /// Number of segments (storage cost driver).
    pub fn segments(&self) -> usize {
        self.slopes.len()
    }

    /// The chosen breakpoints.
    pub fn breakpoints(&self) -> &[Fx] {
        &self.breaks
    }

    /// Locates the segment containing `x` (binary search — the
    /// comparator tree of the range-addressable LUT).
    fn locate(&self, x: Fx) -> usize {
        match self.breaks.binary_search_by(|b| b.raw().cmp(&x.raw())) {
            Ok(i) => i.min(self.slopes.len() - 1),
            Err(i) => (i - 1).min(self.slopes.len() - 1),
        }
    }
}

impl TanhApprox for PwlNonUniform {
    fn id(&self) -> MethodId {
        MethodId::Pwl // variants share the paper's method family A
    }

    fn describe(&self) -> String {
        format!("PWL-nonuniform(tol={:.1e}, {} segs)", self.tolerance, self.segments())
    }

    fn eval_f64(&self, x: f64) -> f64 {
        let neg = x < 0.0;
        let x = x.abs();
        let y = if x >= self.domain_max {
            1.0
        } else {
            let i = self.locate(Fx::from_f64(x, self.breaks[0].format()));
            let a = self.breaks[i].to_f64();
            tanh_ref(a) + (tanh_ref(self.breaks[i + 1].to_f64()) - tanh_ref(a))
                / (self.breaks[i + 1].to_f64() - a)
                * (x - a)
        };
        if neg {
            -y
        } else {
            y
        }
    }

    fn eval_positive_fx(&self, x: Fx, out: QFormat) -> Fx {
        let i = self.locate(x);
        // y = y_i + slope_i · (x − x_i): one subtract, one multiply,
        // one add — same arithmetic as uniform PWL, but the segment
        // index comes from the comparator tree instead of a bit-slice.
        let dx = Fx::from_raw(x.raw() - self.breaks[i].raw(), x.format());
        fx_mul_wide(self.slopes[i], dx)
            .add(FxWide::from_fx(self.values[i]))
            .narrow(out, Round::NearestEven)
    }

    fn domain_max(&self) -> f64 {
        self.domain_max
    }

    fn inventory(&self, io: IoSpec) -> Inventory {
        let n = self.segments() as u32;
        // Range-addressable LUT: n breakpoints (input width), n values
        // (output width), n slopes (SLOPE_FMT width) + a log2(n)-deep
        // comparator tree (priced as adders).
        let cmp_depth = 32 - n.leading_zeros();
        Inventory {
            adders: 2 + cmp_depth,
            multipliers: 1,
            lut_entries: 3 * n,
            lut_bits: n * (io.input.width() + io.output.width() + SLOPE_FMT.width()),
            mult_width: SLOPE_FMT.width(),
            add_width: io.output.width(),
            pipeline_stages: 2 + cmp_depth, // locate | subtract | mac
            ..Default::default()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::approx::pwl::Pwl;
    use crate::error::{measure, InputGrid};

    const INP: QFormat = QFormat::S3_12;
    const OUT: QFormat = QFormat::S_15;

    fn build_t1() -> PwlNonUniform {
        PwlNonUniform::build(2.0e-5, 6.0, INP, QFormat::new(0, 17))
    }

    #[test]
    fn respects_tolerance() {
        let m = build_t1();
        let e = measure(&m, InputGrid::table1(), OUT);
        // algorithmic tolerance + output quantization half-ulp
        assert!(
            e.max_abs <= 2.0e-5 + OUT.ulp(),
            "max err {} vs tolerance 2e-5",
            e.max_abs
        );
    }

    #[test]
    fn fewer_segments_than_uniform_at_same_accuracy() {
        // The paper's §II.A claim: non-uniform division reduces storage.
        let nonuni = build_t1();
        let uniform = Pwl::new(1.0 / 64.0, 6.0);
        let e_n = measure(&nonuni, InputGrid::table1(), OUT);
        let e_u = measure(&uniform, InputGrid::table1(), OUT);
        assert!(e_n.max_abs <= e_u.max_abs * 1.2, "{} vs {}", e_n.max_abs, e_u.max_abs);
        // uniform stores 385 endpoint entries; non-uniform should need
        // far fewer segments for the same tolerance.
        assert!(
            nonuni.segments() < 180,
            "{} segments — no storage win over 385 uniform entries",
            nonuni.segments()
        );
    }

    #[test]
    fn segments_shrink_with_looser_tolerance() {
        let tight = PwlNonUniform::build(1.0e-5, 6.0, INP, QFormat::new(0, 17));
        let loose = PwlNonUniform::build(1.0e-3, 6.0, INP, QFormat::new(0, 17));
        assert!(loose.segments() < tight.segments() / 3);
    }

    #[test]
    fn breakpoints_dense_near_zero_sparse_in_tail() {
        // tanh curves hardest near 0: the greedy algorithm must place
        // most breakpoints there (the motivation for non-uniform LUTs).
        let m = build_t1();
        let below_1 = m.breakpoints().iter().filter(|b| b.to_f64() < 1.0).count();
        let above_3 = m.breakpoints().iter().filter(|b| b.to_f64() > 3.0).count();
        assert!(below_1 > 4 * above_3, "below1={below_1} above3={above_3}");
    }

    #[test]
    fn odd_and_saturating_like_all_methods() {
        let m = build_t1();
        let x = Fx::from_f64(1.234, INP);
        assert_eq!(m.eval_fx(x, OUT).raw(), -m.eval_fx(x.neg(), OUT).raw());
        assert_eq!(m.eval_fx(Fx::from_f64(7.0, INP), OUT).raw(), OUT.max_raw());
    }

    #[test]
    fn locate_finds_correct_segment() {
        let m = build_t1();
        for v in [0.0, 0.013, 0.5, 2.7, 5.9] {
            let x = Fx::from_f64(v, INP);
            let i = m.locate(x);
            assert!(m.breaks[i].raw() <= x.raw(), "v={v}");
            assert!(m.breaks[i + 1].raw() > x.raw() || i == m.slopes.len() - 1, "v={v}");
        }
    }
}
