//! The f64 tanh reference the paper measures against (numpy's tanh; rust
//! libm agrees to < 1 ulp of f64 — cross-checked by the pytest suite).

use crate::fixed::{Fx, QFormat, Round};

/// Reference tanh in f64.
#[inline]
pub fn tanh_ref(x: f64) -> f64 {
    x.tanh()
}

/// The *ideal quantized* tanh: tanh computed in f64 then rounded to the
/// output format. No approximation can beat this; its max error is
/// ulp/2 and it is the yardstick for the paper's "error ≤ 1 ulp" target
/// (Table III).
#[inline]
pub fn tanh_ideal_fx(x: Fx, out: QFormat) -> Fx {
    Fx::from_f64_round(tanh_ref(x.to_f64()), out, Round::NearestEven)
}

/// Derivatives of tanh expressed through the function value itself —
/// paper eqs. (5)-(7). Given `t = tanh(x)` returns (f', f'', f''').
///
/// f'   = 1 - t²
/// f''  = -2 t (1 - t²)          = 2(t³ - t)
/// f''' = -2 (1 - 4t² + 3t⁴)
#[inline]
pub fn tanh_derivatives(t: f64) -> (f64, f64, f64) {
    let t2 = t * t;
    let d1 = 1.0 - t2;
    let d2 = -2.0 * t * d1;
    let d3 = -2.0 * (1.0 - 4.0 * t2 + 3.0 * t2 * t2);
    (d1, d2, d3)
}

/// Velocity factor (paper eq. 11): `f_a = (1 + tanh a) / (1 - tanh a)`.
/// Algebraically `f_a = e^{2a}`, which is how we generate LUT entries.
#[inline]
pub fn velocity_factor(a: f64) -> f64 {
    (2.0 * a).exp()
}

/// Inverse of the velocity factor map (paper eq. 12):
/// `tanh a = (f_a - 1) / (f_a + 1)`.
#[inline]
pub fn tanh_from_velocity(f: f64) -> f64 {
    (f - 1.0) / (f + 1.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{prop_check, Prng};

    #[test]
    fn derivative_identities_match_numeric_differentiation() {
        for &x in &[0.0, 0.25, 0.5, 1.0, 2.0, 3.5] {
            let t = tanh_ref(x);
            let (d1, d2, d3) = tanh_derivatives(t);
            let h = 1e-5;
            let num_d1 = (tanh_ref(x + h) - tanh_ref(x - h)) / (2.0 * h);
            let num_d2 = (tanh_ref(x + h) - 2.0 * t + tanh_ref(x - h)) / (h * h);
            // f''' needs a larger step: the O(h³) denominator amplifies
            // f64 roundoff below h ≈ 1e-3.
            let h3 = 1e-3;
            let num_d3 = (tanh_ref(x + 2.0 * h3) - 2.0 * tanh_ref(x + h3)
                + 2.0 * tanh_ref(x - h3)
                - tanh_ref(x - 2.0 * h3))
                / (2.0 * h3 * h3 * h3);
            assert!((d1 - num_d1).abs() < 1e-8, "f' at {x}: {d1} vs {num_d1}");
            assert!((d2 - num_d2).abs() < 1e-5, "f'' at {x}: {d2} vs {num_d2}");
            assert!((d3 - num_d3).abs() < 1e-4, "f''' at {x}: {d3} vs {num_d3}");
        }
    }

    #[test]
    fn velocity_factor_roundtrip() {
        prop_check("tanh_from_velocity(velocity_factor(a)) == tanh(a)", 1000, |g: &mut Prng| {
            let a = g.f64_in(-5.0, 5.0);
            let t = tanh_from_velocity(velocity_factor(a));
            if (t - tanh_ref(a)).abs() > 1e-12 {
                return Err(format!("a={a}"));
            }
            Ok(())
        });
    }

    #[test]
    fn velocity_factor_is_multiplicative() {
        // Paper eq. (13): f_{a+b} = f_a * f_b.
        prop_check("f_{a+b} = f_a f_b", 1000, |g: &mut Prng| {
            let a = g.f64_in(-2.0, 2.0);
            let b = g.f64_in(-2.0, 2.0);
            let lhs = velocity_factor(a + b);
            let rhs = velocity_factor(a) * velocity_factor(b);
            if ((lhs - rhs) / lhs).abs() > 1e-12 {
                return Err(format!("a={a} b={b}"));
            }
            Ok(())
        });
    }

    #[test]
    fn ideal_quantizer_error_is_half_ulp() {
        let out = QFormat::S_15;
        let inp = QFormat::S3_12;
        let mut max_err: f64 = 0.0;
        for raw in 0..(1 << 14) {
            let x = Fx::from_raw(raw, inp);
            let y = tanh_ideal_fx(x, out);
            max_err = max_err.max((y.to_f64() - tanh_ref(x.to_f64())).abs());
        }
        assert!(max_err <= out.ulp() / 2.0 + 1e-15, "max_err {max_err}");
    }
}
