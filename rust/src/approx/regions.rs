//! Three-region tanh implementation — the Zamanlooy & Mirhassani [5]
//! baseline the paper's related-work section describes: "designed the
//! hardware by dividing it in three regions and optimizing the design
//! specific to each of them".
//!
//! Regions for positive x:
//!
//! 1. **pass region** `x < a`: tanh(x) ≈ x (error < x³/3 — free: the
//!    output is the wired-through input);
//! 2. **processing region** `a ≤ x < b`: any inner approximation (we
//!    parameterize over a [`TanhApprox`], default PWL);
//! 3. **saturation region** `x ≥ b`: constant 1 − 2⁻ᵇ.
//!
//! The region bounds are chosen from the error budget: the pass bound
//! from x − tanh(x) ≤ ε (a ≈ (3ε)^{1/3}) and the saturation bound from
//! 1 − tanh(b) ≤ ε. The win: the inner LUT only covers [a, b), so the
//! baseline quantifies how much of the paper's LUT budget the regions
//! trick saves.

use super::{IoSpec, MethodId, TanhApprox};
use crate::cost::Inventory;
use crate::fixed::{Fx, QFormat};

/// Region-split wrapper around an inner approximation.
pub struct ThreeRegion<M: TanhApprox> {
    inner: M,
    /// Pass-region bound a (in f64; compared on raw words).
    pass_bound: f64,
    /// Saturation bound b.
    sat_bound: f64,
}

/// Solves x − tanh(x) = ε for the pass bound (cube-root seed + a couple
/// of Newton steps; the function is monotone).
pub fn pass_bound_for(eps: f64) -> f64 {
    let mut x = (3.0 * eps).cbrt();
    for _ in 0..20 {
        let f = x - x.tanh() - eps;
        let df = x.tanh().powi(2); // 1 − (1 − tanh²) = tanh²
        if df.abs() < 1e-30 {
            break;
        }
        x -= f / df;
        if x < 0.0 {
            x = 1e-6;
        }
    }
    x
}

/// Solves 1 − tanh(b) = ε: b = atanh(1 − ε).
pub fn sat_bound_for(eps: f64) -> f64 {
    (1.0f64 - eps).atanh()
}

impl<M: TanhApprox> ThreeRegion<M> {
    /// Builds with bounds derived from the error budget ε.
    pub fn new(inner: M, eps: f64) -> Self {
        ThreeRegion { inner, pass_bound: pass_bound_for(eps), sat_bound: sat_bound_for(eps) }
    }

    /// The pass/processing boundary.
    pub fn pass_bound(&self) -> f64 {
        self.pass_bound
    }

    /// The processing/saturation boundary.
    pub fn sat_bound(&self) -> f64 {
        self.sat_bound
    }

    /// Fraction of the ±domain covered by the processing region — the
    /// share of the domain that still needs the inner LUT.
    pub fn processing_fraction(&self, domain: f64) -> f64 {
        ((self.sat_bound.min(domain) - self.pass_bound) / domain).max(0.0)
    }
}

impl<M: TanhApprox> TanhApprox for ThreeRegion<M> {
    fn id(&self) -> MethodId {
        self.inner.id()
    }

    fn describe(&self) -> String {
        format!(
            "ThreeRegion(pass<{:.3}, sat≥{:.3}, inner={})",
            self.pass_bound,
            self.sat_bound,
            self.inner.describe()
        )
    }

    fn eval_f64(&self, x: f64) -> f64 {
        let neg = x < 0.0;
        let m = x.abs();
        let y = if m < self.pass_bound {
            m
        } else if m >= self.sat_bound {
            1.0
        } else {
            self.inner.eval_f64(m)
        };
        if neg {
            -y
        } else {
            y
        }
    }

    fn eval_positive_fx(&self, x: Fx, out: QFormat) -> Fx {
        let v = x.to_f64();
        if v < self.pass_bound {
            // pass region: wire-through (format conversion only)
            x.convert(out, crate::fixed::Round::NearestEven)
        } else if v >= self.sat_bound {
            Fx::max(out)
        } else {
            self.inner.eval_positive_fx(x, out)
        }
    }

    fn domain_max(&self) -> f64 {
        self.inner.domain_max()
    }

    fn inventory(&self, io: IoSpec) -> Inventory {
        // Inner inventory shrunk by the processing fraction (its LUT
        // only spans [a, b)) + two comparators (adders) for the region
        // select + a 4:1 output mux.
        let inner = self.inner.inventory(io);
        let frac = self.processing_fraction(self.inner.domain_max());
        Inventory {
            lut_entries: (inner.lut_entries as f64 * frac).ceil() as u32,
            lut_bits: (inner.lut_bits as f64 * frac).ceil() as u32,
            adders: inner.adders + 2,
            mux4: inner.mux4 + 1,
            ..inner
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::approx::pwl::Pwl;
    use crate::error::{measure, InputGrid};

    const OUT: QFormat = QFormat::S_15;

    #[test]
    fn bounds_match_closed_forms() {
        let eps = 3.05e-5; // 1 ulp of S.15
        let a = pass_bound_for(eps);
        // check the defining equation
        assert!((a - a.tanh() - eps).abs() < 1e-9, "a={a}");
        let b = sat_bound_for(eps);
        assert!((1.0 - b.tanh() - eps).abs() < 1e-9, "b={b}");
        // the paper's §III.A numbers: atanh(1 − 2^-15) ≈ 5.55
        assert!((sat_bound_for(2f64.powi(-15)) - 5.55).abs() < 0.01);
    }

    #[test]
    fn error_stays_within_budget() {
        let eps = 3.05e-5;
        let m = ThreeRegion::new(Pwl::table1(), eps);
        let e = measure(&m, InputGrid::table1(), OUT);
        // inner PWL band + region-boundary budget + quantization
        assert!(e.max_abs < 6.0e-5, "max err {}", e.max_abs);
    }

    #[test]
    fn pass_region_is_exact_wire_through() {
        let m = ThreeRegion::new(Pwl::table1(), 3.05e-5);
        let x = Fx::from_f64(0.01, QFormat::S3_12);
        let y = m.eval_fx(x, OUT);
        // y == x converted (identity), not a LUT interpolation
        assert_eq!(y.raw(), x.convert(OUT, crate::fixed::Round::NearestEven).raw());
    }

    #[test]
    fn saves_lut_versus_plain_inner() {
        let io = IoSpec::table1();
        let plain = Pwl::table1().inventory(io);
        let split = ThreeRegion::new(Pwl::table1(), 3.05e-5).inventory(io);
        assert!(
            split.lut_bits < plain.lut_bits,
            "region split must shrink the LUT: {} vs {}",
            split.lut_bits,
            plain.lut_bits
        );
        // and the processing window is a strict sub-interval
        let m = ThreeRegion::new(Pwl::table1(), 3.05e-5);
        assert!(m.pass_bound() > 0.01);
        assert!(m.sat_bound() < 6.0);
    }

    #[test]
    fn odd_symmetry_via_wrapper() {
        let m = ThreeRegion::new(Pwl::table1(), 3.05e-5);
        for v in [0.005, 0.5, 5.9] {
            let x = Fx::from_f64(v, QFormat::S3_12);
            assert_eq!(m.eval_fx(x, OUT).raw(), -m.eval_fx(x.neg(), OUT).raw(), "v={v}");
        }
    }
}
