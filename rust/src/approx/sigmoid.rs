//! Sigmoid via tanh — the paper's conclusion notes the analysis "can be
//! easily adapted to other applications"; LSTM gates need sigmoid, and
//! hardware implementations derive it from the tanh unit through the
//! identity
//!
//! ```text
//! σ(x) = (1 + tanh(x/2)) / 2
//! ```
//!
//! which costs one right-shift on the input, one increment and one
//! right-shift on the output — no extra multipliers. This wrapper turns
//! any [`TanhApprox`] into a sigmoid evaluator and is what the L2 LSTM
//! model's gate nonlinearities lower to.

use super::{IoSpec, TanhApprox};
use crate::cost::Inventory;
use crate::fixed::{Fx, QFormat, Round};

/// Sigmoid evaluator wrapping a tanh approximation.
pub struct SigmoidFromTanh<M: TanhApprox> {
    inner: M,
}

impl<M: TanhApprox> SigmoidFromTanh<M> {
    /// Wraps a tanh approximator.
    pub fn new(inner: M) -> Self {
        SigmoidFromTanh { inner }
    }

    /// The wrapped tanh method.
    pub fn inner(&self) -> &M {
        &self.inner
    }

    /// f64 math model.
    pub fn eval_f64(&self, x: f64) -> f64 {
        0.5 * (1.0 + self.inner.eval_f64(0.5 * x))
    }

    /// Bit-exact datapath model. The output format must leave one
    /// integer bit of headroom during the internal add; the final result
    /// lies in (0, 1) so any fraction-only output format works.
    pub fn eval_fx(&self, x: Fx, out: QFormat) -> Fx {
        // x/2: arithmetic shift right by one — in Fx terms, reinterpret
        // with one more fraction bit (exact, no rounding).
        let half_fmt = QFormat::new(
            x.format().int_bits.saturating_sub(1),
            x.format().frac_bits + 1,
        );
        let half_x = Fx::from_raw(x.raw(), half_fmt);
        // tanh(x/2) in an internal format with an integer bit for the +1.
        let t_fmt = QFormat::new(1, out.frac_bits + 1);
        let t = self.inner.eval_fx(half_x, t_fmt);
        // (1 + t) / 2: increment then shift right once.
        let raw = (1i64 << t_fmt.frac_bits) + t.raw();
        let shifted = Round::NearestEven.shift_right(raw as i128, 1 + t_fmt.frac_bits - out.frac_bits) as i64;
        Fx::from_raw(shifted, out)
    }

    /// Hardware inventory: the tanh core plus the shift/increment glue
    /// (one adder; shifts are wiring).
    pub fn inventory(&self, io: IoSpec) -> Inventory {
        self.inner.inventory(io).plus(Inventory { adders: 1, ..Default::default() })
    }

    /// Description string.
    pub fn describe(&self) -> String {
        format!("Sigmoid[{}]", self.inner.describe())
    }
}

/// Reference sigmoid in f64.
#[inline]
pub fn sigmoid_ref(x: f64) -> f64 {
    1.0 / (1.0 + (-x).exp())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::approx::pwl::Pwl;
    use crate::approx::taylor::Taylor;

    const INP: QFormat = QFormat::S3_12;
    const OUT: QFormat = QFormat::S_15;

    #[test]
    fn identity_holds_in_f64() {
        for &x in &[-4.0, -1.0, 0.0, 0.5, 3.0] {
            let direct = sigmoid_ref(x);
            let via = 0.5 * (1.0 + (0.5 * x).tanh());
            assert!((direct - via).abs() < 1e-15);
        }
    }

    #[test]
    fn sigmoid_from_pwl_tracks_reference() {
        let s = SigmoidFromTanh::new(Pwl::table1());
        let mut max_err: f64 = 0.0;
        for raw in (-(INP.max_raw())..=INP.max_raw()).step_by(5) {
            let x = Fx::from_raw(raw, INP);
            let y = s.eval_fx(x, OUT);
            max_err = max_err.max((y.to_f64() - sigmoid_ref(x.to_f64())).abs());
        }
        // Half the tanh error (the ½ scaling) plus rounding.
        assert!(max_err < 4.0e-5, "max_err {max_err}");
    }

    #[test]
    fn sigmoid_range_is_0_1() {
        let s = SigmoidFromTanh::new(Taylor::table1_quadratic());
        for raw in (-(INP.max_raw())..=INP.max_raw()).step_by(101) {
            let y = s.eval_fx(Fx::from_raw(raw, INP), OUT);
            assert!(y.raw() >= 0, "sigmoid must be non-negative");
        }
        // Tails: σ(7.99) = 0.99966… (x/2 = 3.995 is still inside the
        // tanh domain, so this is a computed value, not a clamp) and
        // σ(−7.99) = 3.4e-4.
        let hi = s.eval_fx(Fx::from_f64(7.99, INP), OUT).to_f64();
        assert!((hi - sigmoid_ref(7.99)).abs() < 1e-4, "hi={hi}");
        let lo = s.eval_fx(Fx::from_f64(-7.99, INP), OUT).to_f64();
        assert!((lo - sigmoid_ref(-7.99)).abs() < 1e-4, "lo={lo}");
    }

    #[test]
    fn midpoint_is_half() {
        let s = SigmoidFromTanh::new(Pwl::table1());
        let y = s.eval_fx(Fx::zero(INP), OUT);
        assert!((y.to_f64() - 0.5).abs() <= OUT.ulp());
    }

    #[test]
    fn complementary_symmetry() {
        // σ(−x) = 1 − σ(x) up to rounding.
        let s = SigmoidFromTanh::new(Pwl::table1());
        for v in [0.3, 1.1, 2.4] {
            let yp = s.eval_fx(Fx::from_f64(v, INP), OUT).to_f64();
            let yn = s.eval_fx(Fx::from_f64(-v, INP), OUT).to_f64();
            assert!((yp + yn - 1.0).abs() <= 3.0 * OUT.ulp(), "v={v}");
        }
    }
}
