//! Sigmoid via tanh — the paper's conclusion notes the analysis "can be
//! easily adapted to other applications"; LSTM gates need sigmoid, and
//! hardware implementations derive it from the tanh unit through the
//! identity
//!
//! ```text
//! σ(x) = (1 + tanh(x/2)) / 2
//! ```
//!
//! which costs one right-shift on the input, one increment and one
//! right-shift on the output — no extra multipliers. This wrapper turns
//! any [`TanhApprox`] into a sigmoid evaluator and is what the L2 LSTM
//! model's gate nonlinearities lower to.

use std::sync::Arc;

use super::compiled::CompiledKernel;
use super::spec::{MethodSpec, Registry};
use super::{IoSpec, TanhApprox};
use crate::cost::Inventory;
use crate::fixed::{Fx, QFormat, Round};

/// Sigmoid evaluator wrapping a tanh approximation.
pub struct SigmoidFromTanh<M: TanhApprox> {
    inner: M,
}

impl<M: TanhApprox> SigmoidFromTanh<M> {
    /// Wraps a tanh approximator.
    pub fn new(inner: M) -> Self {
        SigmoidFromTanh { inner }
    }

    /// The wrapped tanh method.
    pub fn inner(&self) -> &M {
        &self.inner
    }

    /// f64 math model.
    pub fn eval_f64(&self, x: f64) -> f64 {
        0.5 * (1.0 + self.inner.eval_f64(0.5 * x))
    }

    /// Bit-exact datapath model. The output format must leave one
    /// integer bit of headroom during the internal add; the final result
    /// lies in (0, 1) so any fraction-only output format works.
    pub fn eval_fx(&self, x: Fx, out: QFormat) -> Fx {
        // x/2: arithmetic shift right by one — in Fx terms, reinterpret
        // with one more fraction bit (exact, no rounding).
        let half_fmt = QFormat::new(
            x.format().int_bits.saturating_sub(1),
            x.format().frac_bits + 1,
        );
        let half_x = Fx::from_raw(x.raw(), half_fmt);
        // tanh(x/2) in an internal format with an integer bit for the +1.
        let t_fmt = QFormat::new(1, out.frac_bits + 1);
        let t = self.inner.eval_fx(half_x, t_fmt);
        // (1 + t) / 2: increment then shift right once.
        let raw = (1i64 << t_fmt.frac_bits) + t.raw();
        let shifted = Round::NearestEven.shift_right(raw as i128, 1 + t_fmt.frac_bits - out.frac_bits) as i64;
        Fx::from_raw(shifted, out)
    }

    /// Hardware inventory: the tanh core plus the shift/increment glue
    /// (one adder; shifts are wiring).
    pub fn inventory(&self, io: IoSpec) -> Inventory {
        self.inner.inventory(io).plus(Inventory { adders: 1, ..Default::default() })
    }

    /// Description string.
    pub fn describe(&self) -> String {
        format!("Sigmoid[{}]", self.inner.describe())
    }
}

/// Reference sigmoid in f64.
#[inline]
pub fn sigmoid_ref(x: f64) -> f64 {
    1.0 / (1.0 + (-x).exp())
}

/// Cache-sharing sigmoid evaluator: the raw-word equivalent of
/// [`SigmoidFromTanh`] whose tanh core is a *compiled kernel resolved
/// through a [`Registry`]* instead of a fresh per-call model.
///
/// [`SigmoidFromTanh::eval_fx`] rebuilds the datapath model on every
/// wrapper construction and evaluates scalar `Fx`; serving-path sigmoid
/// (LSTM/GRU gates, the graph executor) wants the same spec-keyed
/// sharing tanh enjoys. `resolve` maps the *sigmoid's* spec (its
/// declared I/O formats) to the derived tanh spec the identity actually
/// evaluates — input reinterpreted one fraction bit finer (the exact
/// `x/2`), output one integer bit + one fraction bit wider (room for
/// the `1 + t` increment) — and pulls that kernel from the registry, so
/// any number of sigmoid nodes across any number of graphs share one
/// compiled tanh table per derived spec.
///
/// Bit-exactness: `eval_raw` is line-for-line the integer steps of
/// [`SigmoidFromTanh::eval_fx`] with the kernel standing in for
/// `inner.eval_fx` (which is the compiled-kernel contract), so the two
/// agree on every representable input — asserted by tests here and by
/// the fused-vs-unfused graph identity in `tests/property.rs`.
pub struct SigmoidKernel {
    inner: Arc<CompiledKernel>,
    inner_spec: MethodSpec,
    out: QFormat,
}

impl SigmoidKernel {
    /// The tanh spec the sigmoid identity evaluates for a sigmoid with
    /// `spec`'s parameters and I/O formats. Errors if the derived
    /// formats fail [`MethodSpec::new`] validation (e.g. a step too
    /// fine for the halved input format).
    pub fn derived_tanh_spec(spec: &MethodSpec) -> Result<MethodSpec, String> {
        let io = IoSpec {
            input: QFormat::new(
                spec.io.input.int_bits.saturating_sub(1),
                spec.io.input.frac_bits + 1,
            ),
            output: QFormat::new(1, spec.io.output.frac_bits + 1),
        };
        MethodSpec::new(spec.params, io, spec.domain)
            .map_err(|e| format!("sigmoid over {spec}: derived tanh spec invalid: {e}"))
    }

    /// Resolves through the process-wide registry.
    pub fn resolve(spec: &MethodSpec) -> Result<SigmoidKernel, String> {
        SigmoidKernel::resolve_in(Registry::global(), spec)
    }

    /// Resolves through a specific registry (tests use private ones for
    /// deterministic cache counters).
    pub fn resolve_in(registry: &Registry, spec: &MethodSpec) -> Result<SigmoidKernel, String> {
        let inner_spec = SigmoidKernel::derived_tanh_spec(spec)?;
        Ok(SigmoidKernel {
            inner: registry.kernel(&inner_spec),
            inner_spec,
            out: spec.io.output,
        })
    }

    /// The derived tanh spec this kernel shares through the cache.
    pub fn inner_spec(&self) -> MethodSpec {
        self.inner_spec
    }

    /// The sigmoid's output format.
    pub fn output(&self) -> QFormat {
        self.out
    }

    /// σ of one raw word (in the sigmoid spec's input format).
    #[inline]
    pub fn eval_raw(&self, x: i64) -> i64 {
        // x's raw word *is* x/2 in the derived input format — no shift.
        let t_fmt = self.inner_spec.io.output;
        let t = self.inner.eval_raw(x);
        let raw = (1i64 << t_fmt.frac_bits) + t;
        let shifted =
            Round::NearestEven.shift_right(raw as i128, 1 + t_fmt.frac_bits - self.out.frac_bits)
                as i64;
        Fx::from_raw(shifted, self.out).raw()
    }

    /// σ over a slice of raw words.
    pub fn eval_slice_raw(&self, input: &[i64], output: &mut [i64]) {
        assert_eq!(input.len(), output.len());
        for (o, &x) in output.iter_mut().zip(input) {
            *o = self.eval_raw(x);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::approx::pwl::Pwl;
    use crate::approx::taylor::Taylor;

    const INP: QFormat = QFormat::S3_12;
    const OUT: QFormat = QFormat::S_15;

    #[test]
    fn identity_holds_in_f64() {
        for &x in &[-4.0, -1.0, 0.0, 0.5, 3.0] {
            let direct = sigmoid_ref(x);
            let via = 0.5 * (1.0 + (0.5 * x).tanh());
            assert!((direct - via).abs() < 1e-15);
        }
    }

    #[test]
    fn sigmoid_from_pwl_tracks_reference() {
        let s = SigmoidFromTanh::new(Pwl::table1());
        let mut max_err: f64 = 0.0;
        for raw in (-(INP.max_raw())..=INP.max_raw()).step_by(5) {
            let x = Fx::from_raw(raw, INP);
            let y = s.eval_fx(x, OUT);
            max_err = max_err.max((y.to_f64() - sigmoid_ref(x.to_f64())).abs());
        }
        // Half the tanh error (the ½ scaling) plus rounding.
        assert!(max_err < 4.0e-5, "max_err {max_err}");
    }

    #[test]
    fn sigmoid_range_is_0_1() {
        let s = SigmoidFromTanh::new(Taylor::table1_quadratic());
        for raw in (-(INP.max_raw())..=INP.max_raw()).step_by(101) {
            let y = s.eval_fx(Fx::from_raw(raw, INP), OUT);
            assert!(y.raw() >= 0, "sigmoid must be non-negative");
        }
        // Tails: σ(7.99) = 0.99966… (x/2 = 3.995 is still inside the
        // tanh domain, so this is a computed value, not a clamp) and
        // σ(−7.99) = 3.4e-4.
        let hi = s.eval_fx(Fx::from_f64(7.99, INP), OUT).to_f64();
        assert!((hi - sigmoid_ref(7.99)).abs() < 1e-4, "hi={hi}");
        let lo = s.eval_fx(Fx::from_f64(-7.99, INP), OUT).to_f64();
        assert!((lo - sigmoid_ref(-7.99)).abs() < 1e-4, "lo={lo}");
    }

    #[test]
    fn midpoint_is_half() {
        let s = SigmoidFromTanh::new(Pwl::table1());
        let y = s.eval_fx(Fx::zero(INP), OUT);
        assert!((y.to_f64() - 0.5).abs() <= OUT.ulp());
    }

    #[test]
    fn sigmoid_kernel_is_bit_identical_to_scalar_wrapper() {
        // The Registry-shared compiled form must agree with the fresh
        // per-call wrapper on every representable input — this is the
        // contract the graph fusion pass relies on.
        for spec_str in ["pwl:step=1/64", "pwl:step=1/16:in=s2.5:out=s.7", "lambert:terms=7"] {
            let spec = crate::approx::MethodSpec::parse(spec_str).unwrap();
            let reg = crate::approx::Registry::new();
            let k = SigmoidKernel::resolve_in(&reg, &spec).unwrap();
            let scalar = SigmoidFromTanh::new(spec.build());
            let fmt = spec.io.input;
            let stride = ((fmt.max_raw() / 4096) as usize).max(1);
            for raw in (fmt.min_raw()..=fmt.max_raw()).step_by(stride) {
                let want = scalar.eval_fx(Fx::from_raw(raw, fmt), spec.io.output).raw();
                assert_eq!(k.eval_raw(raw), want, "{spec_str} raw {raw}");
            }
        }
    }

    #[test]
    fn sigmoid_kernels_share_one_registry_kernel() {
        let spec = crate::approx::MethodSpec::parse("pwl:step=1/64").unwrap();
        let reg = crate::approx::Registry::new();
        let a = SigmoidKernel::resolve_in(&reg, &spec).unwrap();
        let b = SigmoidKernel::resolve_in(&reg, &spec).unwrap();
        assert!(std::sync::Arc::ptr_eq(&a.inner, &b.inner), "tanh core must be cache-shared");
        assert_eq!(reg.stats().compiles, 1);
        assert_eq!(reg.stats().hits, 1);
        // The derived spec halves the input and widens the output.
        assert_eq!(a.inner_spec().io.input, QFormat::new(2, 13));
        assert_eq!(a.inner_spec().io.output, QFormat::new(1, 16));
        // A direct tanh user of the *derived* spec shares it too.
        let direct = reg.kernel(&a.inner_spec());
        assert!(std::sync::Arc::ptr_eq(&direct, &a.inner));
    }

    #[test]
    fn sigmoid_kernel_slice_matches_scalar_calls() {
        let spec = crate::approx::MethodSpec::parse("pwl:step=1/64").unwrap();
        let reg = crate::approx::Registry::new();
        let k = SigmoidKernel::resolve_in(&reg, &spec).unwrap();
        let input: Vec<i64> = (-20..20).map(|i| i * 997).collect();
        let mut out = vec![0i64; input.len()];
        k.eval_slice_raw(&input, &mut out);
        for (&x, &y) in input.iter().zip(&out) {
            assert_eq!(y, k.eval_raw(x));
        }
    }

    #[test]
    fn complementary_symmetry() {
        // σ(−x) = 1 − σ(x) up to rounding.
        let s = SigmoidFromTanh::new(Pwl::table1());
        for v in [0.3, 1.1, 2.4] {
            let yp = s.eval_fx(Fx::from_f64(v, INP), OUT).to_f64();
            let yn = s.eval_fx(Fx::from_f64(-v, INP), OUT).to_f64();
            assert!((yp + yn - 1.0).abs() <= 3.0 * OUT.ulp(), "v={v}");
        }
    }
}
