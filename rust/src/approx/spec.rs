//! Typed, serializable method configurations — the crate's design-point
//! naming layer — plus the shared compiled-kernel cache.
//!
//! The paper compares six configurations at one operating point
//! (Table I); everything the ROADMAP points at — design-space sweeps,
//! serving arbitrary precision/parameter mixes — needs *any*
//! (method × parameter × I/O-format × domain) point to be a
//! first-class, addressable value. [`MethodSpec`] is that value:
//!
//! - **typed**: per-method parameters live in [`MethodParams`]
//!   (step / threshold / term count), validated at construction — no
//!   more `param: f64` being silently truncated into Lambert's term
//!   count;
//! - **serializable**: `Display` and [`MethodSpec::parse`] round-trip
//!   through a compact grammar (see [`GRAMMAR`]), so specs travel
//!   through CLIs, `BENCH_*.json` rows and network requests as plain
//!   strings;
//! - **hashable**: specs key the shared kernel cache ([`Registry`]) and
//!   the coordinator's shard pools.
//!
//! ## Grammar
//!
//! ```text
//! <spec>   := <method> (':' <key>=<value>)*        e.g. pwl:step=1/64:in=s3.12:out=s.15
//!           | table1:<A|B1|B2|C|D|E>               the six Table I rows
//! <act>    := ['sig:'] <spec>                      sigmoid wrapper ([`ActSpec::parse`])
//! <method> := pwl|taylor1|taylor2|catmull|velocity|lambert  (or a|b1|b2|c|d|e)
//! keys     := step=<v>       A/B1/B2/C: step size, a reciprocal power of two (1/64 or 0.015625)
//!             threshold=<v>  D: linear-compensation threshold, reciprocal power of two
//!             terms=<n>      E: continued-fraction terms, integer 1..=16
//!             in=<fmt>       input Q-format (default S3.12)
//!             out=<fmt>      output Q-format (default S.15)
//!             dom=<x>        approximation domain bound (default 6)
//! ```
//!
//! Omitted keys default to the method's Table I configuration, so
//! `pwl` alone is Table I row A and `pwl:step=1/32:in=s2.13` names a
//! near neighbour no previous API could express.
//!
//! ## The kernel cache
//!
//! [`Registry`] maps a spec to its compiled kernel
//! ([`crate::approx::CompiledKernel`]) exactly once per process:
//! the serving backend, the exhaustive error sweeps and the explorer
//! all resolve kernels through [`Registry::global`], so a configuration
//! is compiled once no matter how many shards, scenarios or report
//! sections evaluate it. Cache traffic is observable
//! ([`Registry::stats`], surfaced through the serve metrics endpoint).
//! The scenario verifier deliberately does **not** use the cache — see
//! [`crate::bench::scenario::GoldenVerifier`].

use std::collections::HashMap;
use std::fmt;
use std::hash::{Hash, Hasher};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

use super::compiled::CompiledKernel;
use super::{catmull_rom, lambert, pwl, taylor, velocity};
use super::{IoSpec, MethodId, TanhApprox};
use crate::fixed::QFormat;
use crate::util::table::step_str;

/// One-line grammar reminder for CLI error messages (the full grammar
/// is in the module docs).
pub const GRAMMAR: &str = "spec grammar: <method>[:step=1/64|:threshold=1/128|:terms=7][:in=S3.12][:out=S.15][:dom=6]\n\
     methods: pwl|taylor1|taylor2|catmull|velocity|lambert (letters A|B1|B2|C|D|E); shorthand table1:<A|B1|B2|C|D|E>\n\
     activations: prefix sig: derives sigmoid from the tanh spec via (1+tanh(x/2))/2\n\
     examples: pwl:step=1/64:in=s3.12:out=s.15   lambert:terms=9   table1:B2   sig:pwl";

/// Typed per-method tunable parameters (the paper's Fig 2 axes).
#[derive(Clone, Copy, Debug)]
pub enum MethodParams {
    /// A — piecewise linear: sample step (reciprocal power of two).
    Pwl {
        /// Sample spacing.
        step: f64,
    },
    /// B1/B2 — Taylor expansion: anchor step + series terms (3 = B1
    /// quadratic, 4 = B2 cubic).
    Taylor {
        /// Anchor spacing.
        step: f64,
        /// Series terms (3 or 4).
        terms: usize,
    },
    /// C — Catmull-Rom spline: control-point step.
    CatmullRom {
        /// Control-point spacing.
        step: f64,
    },
    /// D — velocity factors: linear-compensation threshold θ.
    Velocity {
        /// Compensation threshold (reciprocal power of two).
        threshold: f64,
    },
    /// E — Lambert continued fraction: number of fraction terms K.
    /// Typed as `usize` — the old `build(id, param: f64, ..)` silently
    /// truncated non-integer counts (`2.7` → 2).
    Lambert {
        /// Continued-fraction terms, 1..=16.
        terms: usize,
    },
}

/// A fully specified design point: method parameters, I/O formats and
/// the approximation domain. Construct via [`MethodSpec::new`] (which
/// validates), [`MethodSpec::table1`], or [`MethodSpec::parse`].
#[derive(Clone, Copy, Debug)]
pub struct MethodSpec {
    /// Method + tunable parameter.
    pub params: MethodParams,
    /// Input/output fixed-point formats.
    pub io: IoSpec,
    /// Domain bound: inputs at or beyond ±domain saturate (§III.A).
    pub domain: f64,
}

/// Checks that `v` is a reciprocal power of two in `[2^-24, 1]`.
fn check_recip_pow2(name: &str, v: f64) -> Result<u32, String> {
    if !v.is_finite() || v <= 0.0 || v > 1.0 {
        return Err(format!("{name} {v} out of range (need a reciprocal power of two in (0, 1])"));
    }
    let inv = 1.0 / v;
    if inv.fract() != 0.0 || !(inv as u64).is_power_of_two() || inv > (1u64 << 24) as f64 {
        return Err(format!("{name} {v} is not a reciprocal power of two (1/2 … 1/2^24)"));
    }
    Ok((inv as u64).trailing_zeros())
}

impl MethodSpec {
    /// Builds a validated spec. Errors (with a message naming the bad
    /// field) on: a step/threshold that is not a reciprocal power of
    /// two, a step too fine for the input format to address, a Taylor
    /// term count outside 3..=4, a Lambert term count outside 1..=16,
    /// or a non-positive/absurd domain.
    pub fn new(params: MethodParams, io: IoSpec, domain: f64) -> Result<MethodSpec, String> {
        if !domain.is_finite() || domain <= 0.0 || domain > 64.0 {
            return Err(format!("domain {domain} out of range (need 0 < dom <= 64)"));
        }
        match params {
            MethodParams::Pwl { step } | MethodParams::CatmullRom { step } => {
                let shift = check_recip_pow2("step", step)?;
                if shift > io.input.frac_bits {
                    return Err(format!(
                        "step {} is finer than the {} input resolution",
                        step_str(step),
                        io.input
                    ));
                }
            }
            MethodParams::Taylor { step, terms } => {
                let shift = check_recip_pow2("step", step)?;
                // Centred anchors need at least one t bit below the step.
                if shift >= io.input.frac_bits {
                    return Err(format!(
                        "step {} leaves no expansion bits in {} (need step > input ulp)",
                        step_str(step),
                        io.input
                    ));
                }
                if !(3..=4).contains(&terms) {
                    return Err(format!("Taylor terms must be 3 (B1) or 4 (B2), got {terms}"));
                }
            }
            MethodParams::Velocity { threshold } => {
                check_recip_pow2("threshold", threshold)?;
            }
            MethodParams::Lambert { terms } => {
                if !(1..=16).contains(&terms) {
                    return Err(format!("Lambert terms must be 1..=16, got {terms}"));
                }
            }
        }
        Ok(MethodSpec { params, io, domain })
    }

    /// The Table I configuration of a method (paper defaults: S3.12 in,
    /// S.15 out, domain 6, the six hand-picked parameters).
    pub fn table1(id: MethodId) -> MethodSpec {
        let params = match id {
            MethodId::Pwl => MethodParams::Pwl { step: 1.0 / 64.0 },
            MethodId::TaylorQuadratic => MethodParams::Taylor { step: 1.0 / 16.0, terms: 3 },
            MethodId::TaylorCubic => MethodParams::Taylor { step: 1.0 / 8.0, terms: 4 },
            MethodId::CatmullRom => MethodParams::CatmullRom { step: 1.0 / 16.0 },
            MethodId::Velocity => MethodParams::Velocity { threshold: 1.0 / 128.0 },
            MethodId::Lambert => MethodParams::Lambert { terms: 7 },
        };
        MethodSpec { params, io: IoSpec::table1(), domain: 6.0 }
    }

    /// All six Table I specs, in paper order.
    pub fn table1_all() -> Vec<MethodSpec> {
        MethodId::all().into_iter().map(MethodSpec::table1).collect()
    }

    /// Typed bridge from the legacy `(id, param: f64)` convention:
    /// `param` is the step (A/B1/B2/C), threshold (D) or term count (E).
    /// Unlike the old `param as usize` truncation, a non-integer or
    /// non-positive Lambert count is a validation error.
    pub fn with_param(
        id: MethodId,
        param: f64,
        io: IoSpec,
        domain: f64,
    ) -> Result<MethodSpec, String> {
        let params = match id {
            MethodId::Pwl => MethodParams::Pwl { step: param },
            MethodId::TaylorQuadratic => MethodParams::Taylor { step: param, terms: 3 },
            MethodId::TaylorCubic => MethodParams::Taylor { step: param, terms: 4 },
            MethodId::CatmullRom => MethodParams::CatmullRom { step: param },
            MethodId::Velocity => MethodParams::Velocity { threshold: param },
            MethodId::Lambert => {
                if !param.is_finite() || param.fract() != 0.0 || param < 1.0 {
                    return Err(format!(
                        "Lambert terms must be a positive integer, got {param}"
                    ));
                }
                MethodParams::Lambert { terms: param as usize }
            }
        };
        MethodSpec::new(params, io, domain)
    }

    /// Which paper method this spec configures.
    pub fn method_id(&self) -> MethodId {
        match self.params {
            MethodParams::Pwl { .. } => MethodId::Pwl,
            MethodParams::Taylor { terms: 3, .. } => MethodId::TaylorQuadratic,
            MethodParams::Taylor { .. } => MethodId::TaylorCubic,
            MethodParams::CatmullRom { .. } => MethodId::CatmullRom,
            MethodParams::Velocity { .. } => MethodId::Velocity,
            MethodParams::Lambert { .. } => MethodId::Lambert,
        }
    }

    /// The tunable parameter as f64 (step / threshold / term count) —
    /// the Fig 2 axis value, kept for table renderers and
    /// [`crate::explore::DesignPoint`] compatibility.
    pub fn param(&self) -> f64 {
        match self.params {
            MethodParams::Pwl { step }
            | MethodParams::Taylor { step, .. }
            | MethodParams::CatmullRom { step } => step,
            MethodParams::Velocity { threshold } => threshold,
            MethodParams::Lambert { terms } => terms as f64,
        }
    }

    /// Instantiates the golden datapath model. Infallible: every
    /// constructor precondition was checked by [`MethodSpec::new`].
    pub fn build(&self) -> Box<dyn TanhApprox> {
        match self.params {
            MethodParams::Pwl { step } => Box::new(pwl::Pwl::new(step, self.domain)),
            MethodParams::Taylor { step, terms } => {
                Box::new(taylor::Taylor::new(step, terms, self.domain))
            }
            MethodParams::CatmullRom { step } => {
                Box::new(catmull_rom::CatmullRom::new(step, self.domain))
            }
            MethodParams::Velocity { threshold } => {
                Box::new(velocity::Velocity::new(threshold, self.domain))
            }
            MethodParams::Lambert { terms } => Box::new(lambert::Lambert::new(terms, self.domain)),
        }
    }

    /// Parses the spec grammar (see module docs / [`GRAMMAR`]).
    pub fn parse(s: &str) -> Result<MethodSpec, String> {
        let mut parts = s.trim().split(':');
        let head = parts.next().unwrap_or("").trim();
        if head.is_empty() {
            return Err("empty spec".to_string());
        }
        if head.eq_ignore_ascii_case("table1") {
            let label = parts.next().ok_or("table1 shorthand needs a row label, e.g. table1:B2")?;
            let id = MethodId::parse(label)
                .ok_or_else(|| format!("unknown Table I row '{label}' (A|B1|B2|C|D|E)"))?;
            if let Some(extra) = parts.next() {
                return Err(format!("table1:<row> takes no further fields, got ':{extra}'"));
            }
            return Ok(MethodSpec::table1(id));
        }
        let id = MethodId::parse_or_err(head)?;
        let mut spec = MethodSpec::table1(id);
        for field in parts {
            let (key, value) = field
                .split_once('=')
                .ok_or_else(|| format!("field '{field}' is not key=value"))?;
            let (key, value) = (key.trim(), value.trim());
            match key {
                "step" => {
                    let v = parse_fraction(value)?;
                    spec.params = match spec.params {
                        MethodParams::Pwl { .. } => MethodParams::Pwl { step: v },
                        MethodParams::Taylor { terms, .. } => MethodParams::Taylor { step: v, terms },
                        MethodParams::CatmullRom { .. } => MethodParams::CatmullRom { step: v },
                        _ => {
                            return Err(format!(
                                "'step' does not apply to {head} (use threshold= for velocity, terms= for lambert)"
                            ))
                        }
                    };
                }
                "threshold" => {
                    let v = parse_fraction(value)?;
                    spec.params = match spec.params {
                        MethodParams::Velocity { .. } => MethodParams::Velocity { threshold: v },
                        _ => return Err(format!("'threshold' only applies to velocity, not {head}")),
                    };
                }
                "terms" => {
                    let n: usize = value
                        .parse()
                        .map_err(|_| format!("terms must be a positive integer, got '{value}'"))?;
                    spec.params = match spec.params {
                        MethodParams::Lambert { .. } => MethodParams::Lambert { terms: n },
                        _ => return Err(format!("'terms' only applies to lambert, not {head}")),
                    };
                }
                "in" => {
                    spec.io.input = QFormat::parse(value)
                        .ok_or_else(|| format!("bad input format '{value}' (e.g. S3.12)"))?;
                }
                "out" => {
                    spec.io.output = QFormat::parse(value)
                        .ok_or_else(|| format!("bad output format '{value}' (e.g. S.15)"))?;
                }
                "dom" => {
                    spec.domain = value
                        .parse()
                        .map_err(|_| format!("bad domain '{value}'"))?;
                }
                other => {
                    return Err(format!(
                        "unknown spec field '{other}' (step|threshold|terms|in|out|dom)"
                    ))
                }
            }
        }
        // Re-validate: field overrides may have broken an invariant.
        MethodSpec::new(spec.params, spec.io, spec.domain)
    }

    /// Canonical-form equality/hash key: method discriminant +
    /// parameter bits (Taylor carries its exact term count, so a spec
    /// built by bypassing [`MethodSpec::new`]'s validation can never
    /// alias a *different* configuration in the kernel cache) +
    /// formats + domain bits. Bit equality equals semantic equality
    /// here because validation pins every float to an exact binary
    /// value (reciprocal powers of two) or a finite parsed literal.
    fn key(&self) -> (u8, u64, u64, u32, u32, u32, u32, u64) {
        let (d, p, q) = match self.params {
            MethodParams::Pwl { step } => (0u8, step.to_bits(), 0u64),
            MethodParams::Taylor { step, terms } => (1, step.to_bits(), terms as u64),
            MethodParams::CatmullRom { step } => (2, step.to_bits(), 0),
            MethodParams::Velocity { threshold } => (3, threshold.to_bits(), 0),
            MethodParams::Lambert { terms } => (4, terms as u64, 0),
        };
        (
            d,
            p,
            q,
            self.io.input.int_bits,
            self.io.input.frac_bits,
            self.io.output.int_bits,
            self.io.output.frac_bits,
            self.domain.to_bits(),
        )
    }
}

impl PartialEq for MethodSpec {
    fn eq(&self, other: &Self) -> bool {
        self.key() == other.key()
    }
}

impl Eq for MethodSpec {}

impl Hash for MethodSpec {
    fn hash<H: Hasher>(&self, state: &mut H) {
        self.key().hash(state);
    }
}

impl fmt::Display for MethodSpec {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let (name, param) = match self.params {
            MethodParams::Pwl { step } => ("pwl", format!("step={}", step_str(step))),
            MethodParams::Taylor { step, terms } => (
                if terms == 3 { "taylor1" } else { "taylor2" },
                format!("step={}", step_str(step)),
            ),
            MethodParams::CatmullRom { step } => ("catmull", format!("step={}", step_str(step))),
            MethodParams::Velocity { threshold } => {
                ("velocity", format!("threshold={}", step_str(threshold)))
            }
            MethodParams::Lambert { terms } => ("lambert", format!("terms={terms}")),
        };
        write!(f, "{name}:{param}:in={}:out={}", self.io.input, self.io.output)?;
        if self.domain != 6.0 {
            write!(f, ":dom={}", self.domain)?;
        }
        Ok(())
    }
}

/// Which nonlinearity an activation spec names.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum ActKind {
    Tanh,
    /// σ(x) = (1 + tanh(x/2)) / 2, derived from the tanh spec — see
    /// [`crate::approx::sigmoid`].
    Sigmoid,
}

impl ActKind {
    pub fn name(self) -> &'static str {
        match self {
            ActKind::Tanh => "tanh",
            ActKind::Sigmoid => "sigmoid",
        }
    }
}

/// An activation design point: a nonlinearity kind over a tanh
/// [`MethodSpec`]. The grammar extends the spec grammar with a `sig:`
/// wrapper — `sig:pwl:step=1/64:in=s3.12:out=s.15` is the sigmoid
/// derived (via the `(1 + tanh(x/2)) / 2` identity) from that tanh
/// spec; an unwrapped spec is tanh itself. The I/O formats are the
/// *activation's* formats: for sigmoid the underlying tanh kernel runs
/// on the derived half-input/wide-output formats
/// ([`crate::approx::SigmoidKernel::derived_tanh_spec`]), which is how
/// gate nonlinearities share the spec-keyed [`Registry`] cache.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct ActSpec {
    pub kind: ActKind,
    pub spec: MethodSpec,
}

impl ActSpec {
    pub fn tanh(spec: MethodSpec) -> ActSpec {
        ActSpec { kind: ActKind::Tanh, spec }
    }

    pub fn sigmoid(spec: MethodSpec) -> ActSpec {
        ActSpec { kind: ActKind::Sigmoid, spec }
    }

    /// Parses `[sig:]<spec>` (case-insensitive wrapper; the rest is
    /// [`MethodSpec::parse`]).
    pub fn parse(s: &str) -> Result<ActSpec, String> {
        let t = s.trim();
        if t.len() >= 4 && t[..4].eq_ignore_ascii_case("sig:") {
            Ok(ActSpec::sigmoid(MethodSpec::parse(&t[4..])?))
        } else {
            Ok(ActSpec::tanh(MethodSpec::parse(t)?))
        }
    }

    /// The ideal f64 nonlinearity (not the approximation).
    pub fn reference(&self, x: f64) -> f64 {
        match self.kind {
            ActKind::Tanh => x.tanh(),
            ActKind::Sigmoid => super::sigmoid::sigmoid_ref(x),
        }
    }
}

impl fmt::Display for ActSpec {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self.kind {
            ActKind::Tanh => write!(f, "{}", self.spec),
            ActKind::Sigmoid => write!(f, "sig:{}", self.spec),
        }
    }
}

/// Parses `1/64`-style fractions or plain decimals.
fn parse_fraction(s: &str) -> Result<f64, String> {
    if let Some((num, den)) = s.split_once('/') {
        let num: f64 = num.trim().parse().map_err(|_| format!("bad fraction '{s}'"))?;
        let den: f64 = den.trim().parse().map_err(|_| format!("bad fraction '{s}'"))?;
        if den == 0.0 {
            return Err(format!("zero denominator in '{s}'"));
        }
        Ok(num / den)
    } else {
        s.parse().map_err(|_| format!("bad number '{s}'"))
    }
}

/// Cache-traffic counters of a [`Registry`].
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CacheStats {
    /// Kernel lookups answered from the cache.
    pub hits: u64,
    /// Kernel compilations performed (== distinct specs resolved).
    pub compiles: u64,
}

/// Spec-keyed compiled-kernel cache.
///
/// Every layer that needs a configuration's integer kernel — the
/// serving backend, `error::measure_spec`, the explorer — resolves it
/// here, so a spec is compiled once per process regardless of shard
/// count, sweep repetition or report section. Use [`Registry::global`]
/// for the process-wide instance; tests construct private registries to
/// get deterministic counters.
///
/// The cache lock is held across a compile: a second thread asking for
/// the same spec blocks until the first compile finishes rather than
/// duplicating the work (compiles fan out internally via scoped
/// threads, which never touch the registry, so this cannot deadlock).
#[derive(Default)]
pub struct Registry {
    kernels: Mutex<HashMap<MethodSpec, Arc<CompiledKernel>>>,
    hits: AtomicU64,
    compiles: AtomicU64,
}

impl Registry {
    /// An empty registry.
    pub fn new() -> Registry {
        Registry::default()
    }

    /// The process-wide shared registry.
    pub fn global() -> &'static Registry {
        static GLOBAL: OnceLock<Registry> = OnceLock::new();
        GLOBAL.get_or_init(Registry::new)
    }

    /// Resolves the compiled kernel for a spec, compiling at most once
    /// per spec per registry.
    pub fn kernel(&self, spec: &MethodSpec) -> Arc<CompiledKernel> {
        let mut map = self.kernels.lock().unwrap();
        if let Some(k) = map.get(spec) {
            self.hits.fetch_add(1, Ordering::Relaxed);
            return k.clone();
        }
        let k = Arc::new(spec.build().compile(spec.io));
        self.compiles.fetch_add(1, Ordering::Relaxed);
        map.insert(*spec, k.clone());
        k
    }

    /// Cumulative cache counters.
    pub fn stats(&self) -> CacheStats {
        CacheStats {
            hits: self.hits.load(Ordering::Relaxed),
            compiles: self.compiles.load(Ordering::Relaxed),
        }
    }

    /// Number of cached kernels.
    pub fn len(&self) -> usize {
        self.kernels.lock().unwrap().len()
    }

    /// True when nothing has been compiled yet.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Drops every cached kernel (counters are kept — they are
    /// lifetime totals). For long-running processes that sweep huge
    /// spec spaces.
    pub fn clear(&self) {
        self.kernels.lock().unwrap().clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fixed::Fx;

    #[test]
    fn table1_specs_display_canonically_and_round_trip() {
        let want = [
            "pwl:step=1/64:in=S3.12:out=S.15",
            "taylor1:step=1/16:in=S3.12:out=S.15",
            "taylor2:step=1/8:in=S3.12:out=S.15",
            "catmull:step=1/16:in=S3.12:out=S.15",
            "velocity:threshold=1/128:in=S3.12:out=S.15",
            "lambert:terms=7:in=S3.12:out=S.15",
        ];
        for (spec, want) in MethodSpec::table1_all().into_iter().zip(want) {
            assert_eq!(spec.to_string(), want);
            assert_eq!(MethodSpec::parse(want).unwrap(), spec);
        }
    }

    #[test]
    fn shorthands_and_defaults_parse() {
        for id in MethodId::all() {
            let full = MethodSpec::table1(id);
            assert_eq!(MethodSpec::parse(&format!("table1:{}", id.label())).unwrap(), full);
            // Bare method name defaults every field to Table I.
            let name = full.to_string();
            let bare = name.split(':').next().unwrap();
            assert_eq!(MethodSpec::parse(bare).unwrap(), full);
        }
        // Letters work as method heads too, case-insensitively.
        assert_eq!(MethodSpec::parse("b2").unwrap(), MethodSpec::table1(MethodId::TaylorCubic));
        assert_eq!(MethodSpec::parse("table1:d").unwrap(), MethodSpec::table1(MethodId::Velocity));
    }

    #[test]
    fn non_table1_points_parse_with_overrides() {
        let s = MethodSpec::parse("pwl:step=1/32:in=s2.13:out=s.15").unwrap();
        assert_eq!(s.method_id(), MethodId::Pwl);
        assert_eq!(s.param(), 1.0 / 32.0);
        assert_eq!(s.io.input, QFormat::S2_13);
        assert_eq!(s.io.output, QFormat::S_15);
        assert_eq!(s.domain, 6.0);
        // Decimal spelling of the same step parses to the same spec.
        assert_eq!(MethodSpec::parse("pwl:step=0.03125:in=s2.13").unwrap(), s);
        // Domain override round-trips.
        let d = MethodSpec::parse("lambert:terms=9:dom=4").unwrap();
        assert_eq!(d.domain, 4.0);
        assert_eq!(MethodSpec::parse(&d.to_string()).unwrap(), d);
    }

    #[test]
    fn invalid_specs_are_rejected_with_field_names() {
        for (bad, needle) in [
            ("", "empty"),
            ("sinh", "unknown method"),
            ("table1:Z", "unknown Table I row"),
            ("table1:A:step=1/4", "no further fields"),
            ("pwl:step=3", "step"),
            ("pwl:step=1/3", "step"),
            ("pwl:step=0", "step"),
            ("pwl:step=-0.25", "step"),
            ("pwl:step=1/8192", "finer"),          // finer than S3.12
            ("taylor1:step=1/4096", "expansion"),  // no t bits left
            ("taylor1:terms=5", "terms"),
            ("velocity:step=1/64", "threshold"),
            ("lambert:terms=0", "terms"),
            ("lambert:terms=2.5", "terms"),
            ("lambert:terms=17", "1..=16"),
            ("pwl:in=x3.2", "input format"),
            ("pwl:out=S.0", "output format"),
            ("pwl:dom=-1", "domain"),
            ("pwl:dom=nope", "domain"),
            ("pwl:step", "key=value"),
            ("pwl:color=red", "unknown spec field"),
        ] {
            let err = MethodSpec::parse(bad).unwrap_err();
            assert!(err.contains(needle), "'{bad}' -> '{err}' (wanted '{needle}')");
        }
    }

    #[test]
    fn with_param_rejects_fractional_lambert_terms() {
        // Regression: the old build() truncated 2.7 -> 2 silently.
        let err =
            MethodSpec::with_param(MethodId::Lambert, 2.7, IoSpec::table1(), 6.0).unwrap_err();
        assert!(err.contains("integer"), "{err}");
        assert!(MethodSpec::with_param(MethodId::Lambert, 0.0, IoSpec::table1(), 6.0).is_err());
        let ok = MethodSpec::with_param(MethodId::Lambert, 7.0, IoSpec::table1(), 6.0).unwrap();
        assert!(matches!(ok.params, MethodParams::Lambert { terms: 7 }));
    }

    #[test]
    fn specs_hash_and_compare_by_value() {
        use std::collections::HashSet;
        let mut set = HashSet::new();
        for s in MethodSpec::table1_all() {
            assert!(set.insert(s));
            assert!(!set.insert(s), "{s} double-inserted");
        }
        assert_eq!(set.len(), 6);
        // Different io, same params: distinct key.
        let a = MethodSpec::parse("pwl").unwrap();
        let b = MethodSpec::parse("pwl:out=s.7").unwrap();
        assert_ne!(a, b);
        assert!(set.contains(&a) && !set.contains(&b));
        // A validation-bypassing struct literal (pub fields) with a
        // bogus Taylor term count must NOT alias a valid spec's cache
        // key — the key carries the exact term count.
        let bogus = MethodSpec {
            params: MethodParams::Taylor { step: 1.0 / 8.0, terms: 9 },
            io: IoSpec::table1(),
            domain: 6.0,
        };
        assert_ne!(bogus, MethodSpec::table1(MethodId::TaylorCubic));
        assert!(!set.contains(&bogus));
    }

    #[test]
    fn act_specs_parse_and_round_trip() {
        let tanh = ActSpec::parse("pwl:step=1/64").unwrap();
        assert_eq!(tanh.kind, ActKind::Tanh);
        assert_eq!(tanh.spec, MethodSpec::table1(MethodId::Pwl));
        assert_eq!(tanh.to_string(), "pwl:step=1/64:in=S3.12:out=S.15");

        let sig = ActSpec::parse("SIG:table1:A").unwrap();
        assert_eq!(sig.kind, ActKind::Sigmoid);
        assert_eq!(sig.spec, MethodSpec::table1(MethodId::Pwl));
        assert_eq!(sig.to_string(), "sig:pwl:step=1/64:in=S3.12:out=S.15");
        assert_eq!(ActSpec::parse(&sig.to_string()).unwrap(), sig);
        assert_ne!(sig, tanh, "kind participates in equality");

        // References: tanh is odd, sigmoid is its affine image.
        assert!((sig.reference(0.0) - 0.5).abs() < 1e-15);
        assert!((tanh.reference(1.0) - 1.0f64.tanh()).abs() < 1e-15);
        assert!((sig.reference(2.0) - 0.5 * (1.0 + 1.0f64.tanh())).abs() < 1e-15);

        // Bad inner specs surface the MethodSpec error.
        assert!(ActSpec::parse("sig:sinh").is_err());
        assert!(ActSpec::parse("").is_err());
    }

    #[test]
    fn registry_compiles_once_and_counts_traffic() {
        let reg = Registry::new();
        let spec = MethodSpec::table1(MethodId::Pwl);
        let k1 = reg.kernel(&spec);
        let k2 = reg.kernel(&spec);
        assert!(Arc::ptr_eq(&k1, &k2), "second lookup must be the cached kernel");
        assert_eq!(reg.stats(), CacheStats { hits: 1, compiles: 1 });
        let other = MethodSpec::parse("pwl:step=1/32").unwrap();
        let _ = reg.kernel(&other);
        assert_eq!(reg.stats(), CacheStats { hits: 1, compiles: 2 });
        assert_eq!(reg.len(), 2);
        reg.clear();
        assert!(reg.is_empty());
        // Counters survive clear (lifetime totals), kernels recompile.
        let _ = reg.kernel(&spec);
        assert_eq!(reg.stats(), CacheStats { hits: 1, compiles: 3 });
    }

    #[test]
    fn cached_kernel_is_bit_exact_against_fresh_build() {
        let reg = Registry::new();
        let spec = MethodSpec::parse("pwl:step=1/32:in=s2.13:out=s.15").unwrap();
        let cached = reg.kernel(&spec);
        let fresh = spec.build();
        for raw in (spec.io.input.min_raw()..=spec.io.input.max_raw()).step_by(97) {
            assert_eq!(
                cached.eval_raw(raw),
                fresh.eval_fx(Fx::from_raw(raw, spec.io.input), spec.io.output).raw(),
                "raw {raw}"
            );
        }
    }

    #[test]
    fn built_methods_match_legacy_constructors() {
        // The spec layer is a naming change, not a numerics change: the
        // Table I specs build the exact objects table1() constructors do.
        let io = IoSpec::table1();
        let pairs: Vec<(Box<dyn TanhApprox>, Box<dyn TanhApprox>)> = vec![
            (MethodSpec::table1(MethodId::Pwl).build(), Box::new(pwl::Pwl::table1())),
            (
                MethodSpec::table1(MethodId::TaylorQuadratic).build(),
                Box::new(taylor::Taylor::table1_quadratic()),
            ),
            (
                MethodSpec::table1(MethodId::Velocity).build(),
                Box::new(velocity::Velocity::table1()),
            ),
            (MethodSpec::table1(MethodId::Lambert).build(), Box::new(lambert::Lambert::table1())),
        ];
        for (a, b) in pairs {
            assert_eq!(a.describe(), b.describe());
            for raw in [0, 1, 777, 4096, 20000] {
                let x = Fx::from_raw(raw, io.input);
                assert_eq!(a.eval_fx(x, io.output).raw(), b.eval_fx(x, io.output).raw());
            }
        }
    }
}
