//! SWAR (SIMD-within-a-register) lane primitives for the packed kernel
//! path ([`super::CompiledKernel::eval_slice_packed`]).
//!
//! A `u64` word holds `64 / W` independent two's-complement lanes of
//! `W` bits (W = 16 for the paper's 16-bit formats, W = 8 for the
//! Table III row-4 formats). The primitives below implement per-lane
//! arithmetic with plain integer ops — no `std::simd`, no intrinsics —
//! by masking carries at lane boundaries (the Hacker's Delight
//! carry-containment identities) and spreading per-lane condition bits
//! into full-lane select masks with a single multiply.
//!
//! Everything here is branch-free; the compiled-kernel front end
//! (sign peel, magnitude clamp, saturation select) runs entirely on
//! these, which is what makes the packed path profitable even though
//! the per-method MAC cores stay per-lane (a true packed multiply is
//! impossible in SWAR: cross-lane partial products pollute neighbours).
//!
//! All functions are generic over `const W: u32` and assume `W`
//! divides 64. Derivations and the masking scheme are documented in
//! EXPERIMENTS.md §Packed kernels.

/// All-ones mask of one `W`-bit lane (lane 0).
pub(crate) const fn lane_mask(w: u32) -> u64 {
    if w >= 64 {
        u64::MAX
    } else {
        (1u64 << w) - 1
    }
}

/// One bit set at the LSB of every lane (`0x0001_0001_…` for W=16).
pub(crate) const fn lsb_mask(w: u32) -> u64 {
    u64::MAX / lane_mask(w)
}

/// One bit set at the MSB (sign bit) of every lane.
pub(crate) const fn msb_mask(w: u32) -> u64 {
    lsb_mask(w) << (w - 1)
}

/// Broadcasts a `W`-bit value into every lane.
#[inline(always)]
pub(crate) fn bc<const W: u32>(v: u64) -> u64 {
    debug_assert!(v <= lane_mask(W));
    v.wrapping_mul(lsb_mask(W))
}

/// Spreads per-lane sign bits of `m` into full-lane masks: a lane with
/// its MSB set becomes all-ones, others all-zeros. The multiply cannot
/// carry across lanes because each contribution `lane_mask << (i·W)`
/// occupies exactly lane `i`'s bits.
#[inline(always)]
pub(crate) fn spread<const W: u32>(m: u64) -> u64 {
    ((m & msb_mask(W)) >> (W - 1)).wrapping_mul(lane_mask(W))
}

/// Per-lane wrapping addition with carries contained at lane
/// boundaries: add the low `W−1` bits with the sign bits masked off
/// (so a carry out of a lane dies in its own cleared MSB slot), then
/// restore the sign-bit XOR.
#[inline(always)]
pub(crate) fn add<const W: u32>(x: u64, y: u64) -> u64 {
    let h = msb_mask(W);
    ((x & !h).wrapping_add(y & !h)) ^ ((x ^ y) & h)
}

/// Per-lane wrapping subtraction with borrows contained at lane
/// boundaries (dual of [`add`]).
#[inline(always)]
pub(crate) fn sub<const W: u32>(x: u64, y: u64) -> u64 {
    let h = msb_mask(W);
    ((x | h).wrapping_sub(y & !h)) ^ ((x ^ !y) & h)
}

/// Full-lane mask of per-lane **unsigned** `x < y` over all `W` bits
/// (no spare bit needed — magnitudes can legitimately reach `2^(W−1)`,
/// e.g. `abs(min_raw)` and the saturation sentinel `max_raw + 1`).
///
/// The lane-local difference `d = x − y` from [`sub`] exposes the
/// borrow *into* each MSB as `x ^ y ^ d`; one more full-subtractor step
/// reconstructs the borrow *out* of the lane, which is exactly the
/// unsigned less-than predicate.
#[inline(always)]
pub(crate) fn lt_u<const W: u32>(x: u64, y: u64) -> u64 {
    let h = msb_mask(W);
    let d = sub::<W>(x, y);
    let borrow = ((!x & y) | ((!x | y) & (x ^ y ^ d))) & h;
    spread::<W>(borrow)
}

/// Per-lane select: lane from `a` where `mask` is all-ones, from `b`
/// where all-zeros. `mask` must be a full-lane mask.
#[inline(always)]
pub(crate) fn select(mask: u64, a: u64, b: u64) -> u64 {
    (a & mask) | (b & !mask)
}

/// Per-lane unsigned minimum.
#[inline(always)]
pub(crate) fn min_u<const W: u32>(x: u64, y: u64) -> u64 {
    select(lt_u::<W>(x, y), x, y)
}

/// Per-lane absolute value of two's-complement lanes, returned with
/// the full-lane negative mask (the sign peel the odd-symmetry front
/// end needs). `abs(lane_min) = 2^(W−1)` stays representable as an
/// unsigned lane magnitude, mirroring the scalar path's saturating
/// `x.abs().min(in_max)`.
#[inline(always)]
pub(crate) fn abs<const W: u32>(w: u64) -> (u64, u64) {
    let neg = spread::<W>(w);
    (add::<W>(w ^ neg, neg & lsb_mask(W)), neg)
}

/// Two's-complement negation of the lanes selected by the full-lane
/// mask `neg` (the sign re-apply on the way out). Lane values must be
/// `< 2^(W−1)` so the negation cannot overflow the lane.
#[inline(always)]
pub(crate) fn negate_masked<const W: u32>(w: u64, neg: u64) -> u64 {
    add::<W>(w ^ neg, neg & lsb_mask(W))
}

/// Packs up to `64 / W` signed lane values (each in the lane's
/// two's-complement range) into one word, lane 0 in the low bits.
#[inline(always)]
pub(crate) fn pack<const W: u32>(xs: &[i64]) -> u64 {
    let mut w = 0u64;
    for (i, &x) in xs.iter().enumerate() {
        w |= ((x as u64) & lane_mask(W)) << (i as u32 * W);
    }
    w
}

/// Extracts lane `i` as an unsigned value.
#[inline(always)]
pub(crate) fn lane_u<const W: u32>(w: u64, i: u32) -> u64 {
    (w >> (i * W)) & lane_mask(W)
}

/// Unpacks lanes as sign-extended `i64`s, lane 0 first.
#[inline(always)]
pub(crate) fn unpack<const W: u32>(w: u64, out: &mut [i64]) {
    for (i, o) in out.iter_mut().enumerate() {
        let v = lane_u::<W>(w, i as u32);
        *o = ((v << (64 - W)) as i64) >> (64 - W);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Prng;

    fn lanes<const W: u32>(w: u64) -> Vec<i64> {
        let n = (64 / W) as usize;
        let mut out = vec![0i64; n];
        unpack::<W>(w, &mut out);
        out
    }

    fn ulanes<const W: u32>(w: u64) -> Vec<u64> {
        (0..64 / W).map(|i| lane_u::<W>(w, i)).collect()
    }

    fn check_lane_algebra<const W: u32>(g: &mut Prng) {
        let lm = lane_mask(W) as i64;
        let half = 1i64 << (W - 1);
        let n = (64 / W) as usize;
        for _ in 0..2000 {
            let xs: Vec<i64> = (0..n).map(|_| g.i64_in(-half, half - 1)).collect();
            let ys: Vec<i64> = (0..n).map(|_| g.i64_in(-half, half - 1)).collect();
            let (wx, wy) = (pack::<W>(&xs), pack::<W>(&ys));
            // pack/unpack round-trips two's-complement lanes.
            assert_eq!(lanes::<W>(wx), xs);
            // add/sub wrap per lane, never crossing boundaries.
            let sum = lanes::<W>(add::<W>(wx, wy));
            let dif = lanes::<W>(sub::<W>(wx, wy));
            for i in 0..n {
                let wrap = |v: i64| ((v & lm) << (64 - W)) >> (64 - W);
                assert_eq!(sum[i], wrap(xs[i].wrapping_add(ys[i])), "add lane {i}");
                assert_eq!(dif[i], wrap(xs[i].wrapping_sub(ys[i])), "sub lane {i}");
            }
            // Unsigned compare / min over the full W-bit lane range.
            let (ux, uy) = (ulanes::<W>(wx), ulanes::<W>(wy));
            let lt = ulanes::<W>(lt_u::<W>(wx, wy));
            let mn = ulanes::<W>(min_u::<W>(wx, wy));
            for i in 0..n {
                let want = if ux[i] < uy[i] { lane_mask(W) } else { 0 };
                assert_eq!(lt[i], want, "lt_u lane {i}: {} vs {}", ux[i], uy[i]);
                assert_eq!(mn[i], ux[i].min(uy[i]), "min_u lane {i}");
            }
            // abs + sign mask: the saturating magnitude of every lane,
            // including lane_min whose magnitude is 2^(W-1).
            let (a, neg) = abs::<W>(wx);
            let (ua, un) = (ulanes::<W>(a), ulanes::<W>(neg));
            for i in 0..n {
                assert_eq!(ua[i], xs[i].unsigned_abs(), "abs lane {i} of {}", xs[i]);
                assert_eq!(un[i], if xs[i] < 0 { lane_mask(W) } else { 0 });
            }
            // negate_masked inverts the sign peel exactly — abs then
            // re-negate reproduces the input, lane_min included.
            assert_eq!(lanes::<W>(negate_masked::<W>(a, neg)), xs);
        }
    }

    #[test]
    fn lane_algebra_matches_scalar_w16() {
        check_lane_algebra::<16>(&mut Prng::new(7));
    }

    #[test]
    fn lane_algebra_matches_scalar_w8() {
        check_lane_algebra::<8>(&mut Prng::new(8));
    }

    #[test]
    fn masks_and_broadcast() {
        assert_eq!(lane_mask(16), 0xFFFF);
        assert_eq!(lsb_mask(16), 0x0001_0001_0001_0001);
        assert_eq!(msb_mask(16), 0x8000_8000_8000_8000);
        assert_eq!(lsb_mask(8), 0x0101_0101_0101_0101);
        assert_eq!(bc::<16>(0x1234), 0x1234_1234_1234_1234);
        assert_eq!(spread::<16>(0x8000_0000_8000_0000), 0xFFFF_0000_FFFF_0000);
    }

    #[test]
    fn edge_magnitudes_compare_correctly() {
        // The values the kernel front end actually compares: magnitudes
        // up to 2^(W-1) (abs of lane_min) against in_max = 2^(W-1)-1 and
        // the saturation sentinel max_raw+1 = 2^(W-1).
        let edges: [u64; 5] = [0, 1, 0x7FFE, 0x7FFF, 0x8000];
        for &a in &edges {
            for &b in &edges {
                let wa = bc::<16>(a);
                let wb = bc::<16>(b);
                let want = if a < b { u64::MAX } else { 0 };
                assert_eq!(lt_u::<16>(wa, wb), want, "{a:#x} < {b:#x}");
            }
        }
    }
}
