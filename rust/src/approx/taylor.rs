//! Methods B1/B2 — Taylor series expansion (paper §II.B, §IV.C).
//!
//! tanh is expanded around the nearest stored anchor point; the paper's
//! key observation is eqs. (5)-(7): every derivative of tanh is a
//! polynomial in tanh itself, so the LUT need only store the function
//! value `T = tanh(x_c)` and the datapath derives the Taylor
//! coefficients at runtime:
//!
//! ```text
//! f'       = 1 − T²
//! f''/2!   = −T·(1 − T²)
//! f'''/3!  = −(1 − T²)(1 − 3T²)/3
//! ```
//!
//! Anchors are placed at interval *centres* `(i + ½)·h` so the expansion
//! distance is at most `h/2` (this is what makes B1 at step 1/16 match
//! PWL at step 1/64 — paper Table I). Evaluation uses Horner form
//! (paper eq. 16), one adder + one multiplier per degree.

use super::compiled::{CompiledKernel, KernelBody};
use super::lut::UniformLut;
use super::reference::{tanh_derivatives, tanh_ref};
use super::{IoSpec, MethodId, TanhApprox};
use crate::cost::Inventory;
use crate::fixed::{fx_mul, fx_mul_wide, fx_sub, Fx, FxWide, QFormat, Round};

/// Where the expansion anchor sits within each step interval — an
/// ablation axis: centred anchors halve the worst-case expansion
/// distance (|dx| ≤ h/2 instead of h), which is why this repo's B1/B2
/// errors land below the paper's Table I values. `Left` reproduces the
/// paper's numbers (see the ablations bench).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AnchorMode {
    /// Anchor at the interval centre (i + ½)·h — this repo's default.
    Centered,
    /// Anchor at the interval start i·h — the straightforward reading
    /// of the paper's "msbs address the LUT" description.
    Left,
}

/// Whether Taylor coefficients are derived at runtime from the stored
/// tanh value (paper's preferred trick) or pre-stored per anchor.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CoeffMode {
    /// Compute 1−T², −T(1−T²), … in the datapath (small LUT, more logic).
    Runtime,
    /// Store each coefficient alongside T (bigger LUT, runs faster —
    /// paper §IV.H: "the circuit runs faster if LUTs are used … however,
    /// the area is larger").
    Stored,
}

/// Internal computation format for the Horner chain: 2 integer bits
/// (coefficients are in (−2, 2)) and 26 fraction bits. Public so the hw
/// datapath simulator instantiates registers of the same width.
pub const INT_FMT: QFormat = QFormat::new(2, 26);

/// Taylor-series approximator.
#[derive(Clone, Debug)]
pub struct Taylor {
    /// Anchor tanh values at interval centres, high-precision storage.
    lut: UniformLut,
    step: f64,
    /// Number of series terms: 3 = quadratic (B1), 4 = cubic (B2).
    terms: usize,
    domain_max: f64,
    coeff_mode: CoeffMode,
    anchor_mode: AnchorMode,
}

impl Taylor {
    /// Builds a Taylor approximator with anchors every `step` (reciprocal
    /// power of two) and `terms` series terms (3 or 4 in the paper).
    pub fn new(step: f64, terms: usize, domain_max: f64) -> Taylor {
        Taylor::with_anchor(step, terms, domain_max, AnchorMode::Centered)
    }

    /// Builds with an explicit anchor placement (ablation axis).
    pub fn with_anchor(
        step: f64,
        terms: usize,
        domain_max: f64,
        anchor_mode: AnchorMode,
    ) -> Taylor {
        assert!((2..=4).contains(&terms), "terms must be 2..=4, got {terms}");
        // The LUT is indexed by the interval number but stores the value
        // at the anchor point (centre or left edge).
        // UniformLut samples f(i*step); shift the function for centres.
        let offset = match anchor_mode {
            AnchorMode::Centered => step / 2.0,
            AnchorMode::Left => 0.0,
        };
        let lut = UniformLut::sample(
            move |x| tanh_ref(x + offset),
            step,
            domain_max,
            1,
            // Store anchors with 2 extra fraction bits over S.15: the
            // anchor is the zeroth Horner coefficient and its
            // quantization error passes straight through to the output.
            QFormat::new(0, 17),
        );
        Taylor { lut, step, terms, domain_max, coeff_mode: CoeffMode::Runtime, anchor_mode }
    }

    /// Table I row "B1": quadratic, step 1/16.
    pub fn table1_quadratic() -> Taylor {
        Taylor::new(1.0 / 16.0, 3, 6.0)
    }

    /// Table I row "B2": cubic, step 1/8.
    pub fn table1_cubic() -> Taylor {
        Taylor::new(1.0 / 8.0, 4, 6.0)
    }

    /// Selects stored-vs-runtime coefficient mode (affects inventory
    /// only; numerics are identical by construction in this model).
    pub fn with_coeff_mode(mut self, mode: CoeffMode) -> Taylor {
        self.coeff_mode = mode;
        self
    }

    /// Series term count (3 = quadratic, 4 = cubic).
    pub fn terms(&self) -> usize {
        self.terms
    }

    /// Anchor spacing.
    pub fn step(&self) -> f64 {
        self.step
    }

    /// Anchor LUT (for the hw simulator).
    pub fn lut(&self) -> &UniformLut {
        &self.lut
    }

    /// Taylor coefficients (c0..c3) at anchor value `t` — f64 model.
    fn coeffs_f64(&self, t: f64) -> [f64; 4] {
        let (d1, d2, d3) = tanh_derivatives(t);
        [t, d1, d2 / 2.0, d3 / 6.0]
    }

    /// Splits a positive input into (LUT index, signed expansion distance
    /// dx = x − centre) — the address/offset decode of Fig 3. Shared by
    /// `eval_positive_fx` and the hw pipeline so they stay bit-identical.
    pub fn split_fx(&self, x: Fx) -> (usize, Fx) {
        let (idx, t_frac) = self.lut.split_index(x);
        let t_bits = t_frac.format().frac_bits;
        let dx_raw = match self.anchor_mode {
            AnchorMode::Centered => t_frac.raw() - (1i64 << (t_bits - 1)),
            AnchorMode::Left => t_frac.raw(),
        };
        let step_shift = (1.0 / self.step).log2() as u32;
        (idx, Fx::from_raw(dx_raw, QFormat::new(0, t_bits + step_shift)))
    }

    /// Runtime coefficient derivation from the stored anchor value
    /// (paper eqs. 5-7), in [`INT_FMT`]: returns `(T, c1, c2, c3)` with
    /// `c3 = 0` for the quadratic configuration.
    pub fn coeffs_fx(&self, anchor: Fx) -> (Fx, Fx, Fx, Fx) {
        let t = anchor.convert(INT_FMT, Round::NearestEven);
        let one = Fx::from_raw_unchecked(1i64 << INT_FMT.frac_bits, INT_FMT);
        let t2 = fx_mul(t, t, INT_FMT, Round::NearestAway); // squarer
        let d1 = fx_sub(one, t2, INT_FMT, Round::NearestAway); // 1 − T²
        let c2 = fx_mul(t, d1, INT_FMT, Round::NearestAway).neg(); // −T(1−T²)
        let c3 = if self.terms == 4 {
            // f'''/3! = −(1−T²)(1−3T²)/3.
            let three_t2 = fx_mul(Fx::from_f64(3.0, INT_FMT), t2, INT_FMT, Round::NearestAway);
            let g = fx_sub(one, three_t2, INT_FMT, Round::NearestAway); // 1 − 3T²
            let c3 = fx_mul(d1, g, INT_FMT, Round::NearestAway);
            let third = Fx::from_f64(1.0 / 3.0, INT_FMT);
            fx_mul(c3, third, INT_FMT, Round::NearestAway).neg()
        } else {
            Fx::zero(INT_FMT)
        };
        (t, d1, c2, c3)
    }

    /// One Horner stage `acc ← c + dx·acc` in [`INT_FMT`] (wide multiply,
    /// single rounding — what a pipeline register boundary does).
    pub fn horner_step(dx: Fx, acc: Fx, c: Fx) -> Fx {
        fx_mul_wide(dx, acc).add(FxWide::from_fx(c)).narrow(INT_FMT, Round::NearestAway)
    }

    /// Final Horner stage `y = T + dx·acc`, rounded once into `out`.
    pub fn horner_final(dx: Fx, acc: Fx, t: Fx, out: QFormat) -> Fx {
        fx_mul_wide(dx, acc).add(FxWide::from_fx(t)).narrow(out, Round::NearestEven)
    }
}

impl TanhApprox for Taylor {
    fn id(&self) -> MethodId {
        if self.terms == 3 {
            MethodId::TaylorQuadratic
        } else {
            MethodId::TaylorCubic
        }
    }

    fn describe(&self) -> String {
        format!(
            "Taylor(step={}, terms={})",
            crate::util::table::step_str(self.step),
            self.terms
        )
    }

    fn eval_f64(&self, x: f64) -> f64 {
        let neg = x < 0.0;
        let x = x.abs();
        let y = if x >= self.domain_max {
            1.0
        } else {
            let k = (x / self.step).floor();
            let frac = match self.anchor_mode {
                AnchorMode::Centered => 0.5,
                AnchorMode::Left => 0.0,
            };
            let xc = (k + frac) * self.step;
            let dx = x - xc;
            let c = self.coeffs_f64(tanh_ref(xc));
            let mut acc = c[self.terms - 1];
            for i in (0..self.terms - 1).rev() {
                acc = c[i] + dx * acc;
            }
            acc
        };
        if neg {
            -y
        } else {
            y
        }
    }

    fn eval_positive_fx(&self, x: Fx, out: QFormat) -> Fx {
        // Address/offset decode (Fig 3), anchor fetch, runtime
        // coefficient derivation (eqs. 5-7), then the Horner chain —
        // all through the helpers shared with the hw pipeline.
        let (idx, dx) = self.split_fx(x);
        let (t, d1, c2, c3) = self.coeffs_fx(self.lut.at(idx));
        let mut acc = match self.terms {
            4 => c3,
            3 => c2,
            _ => Fx::zero(INT_FMT), // linear: y = T + dx·d1
        };
        if self.terms == 4 {
            acc = Self::horner_step(dx, acc, c2);
        }
        acc = Self::horner_step(dx, acc, d1);
        Self::horner_final(dx, acc, t, out)
    }

    fn domain_max(&self) -> f64 {
        self.domain_max
    }

    /// Compiled form: the runtime coefficient derivation (eqs. 5-7)
    /// depends only on the anchor, so it is hoisted to compile time —
    /// one `[T, f', f''/2!, f'''/3!]` raw set per anchor — leaving an
    /// integer Horner chain per input.
    fn compile(&self, io: IoSpec) -> CompiledKernel {
        let step_shift = (1.0 / self.step).log2() as u32;
        if io.input.frac_bits < step_shift {
            return CompiledKernel::tabulate(self, io);
        }
        let t_bits = io.input.frac_bits - step_shift;
        if t_bits == 0 && self.anchor_mode == AnchorMode::Centered {
            // Centred anchors need at least one t bit to express the
            // half-step offset; fall back to exact tabulation.
            return CompiledKernel::tabulate(self, io);
        }
        let coeffs: Vec<[i64; 4]> = (0..self.lut.len())
            .map(|i| {
                let (t, d1, c2, c3) = self.coeffs_fx(self.lut.at(i));
                [t.raw(), d1.raw(), c2.raw(), c3.raw()]
            })
            .collect();
        let dx_bias = match self.anchor_mode {
            AnchorMode::Centered => 1i64 << (t_bits - 1),
            AnchorMode::Left => 0,
        };
        let body =
            KernelBody::Horner { coeffs, terms: self.terms, t_bits, dx_bias, acc_fmt: INT_FMT };
        CompiledKernel::with_body(io, self.domain_max, body).debug_check(self)
    }

    fn inventory(&self, io: IoSpec) -> Inventory {
        let degree = (self.terms - 1) as u32;
        // Horner: one adder + one multiplier per degree (paper eq. 16).
        let horner = Inventory {
            adders: degree,
            multipliers: degree,
            mult_width: io.output.width().max(INT_FMT.width()),
            add_width: INT_FMT.width(),
            pipeline_stages: 1 + 2 * degree, // fetch + (mul, add) per degree
            ..Default::default()
        };
        match self.coeff_mode {
            CoeffMode::Runtime => {
                // Coefficient derivation: T² (squarer), 1−T² (adder),
                // −T·d1 (multiplier); cubic adds 3T² (const-mult folded
                // into the squarer tree), 1−3T² (adder), d1·g (multiplier)
                // and the ⅓ constant multiplier.
                let coeff = if self.terms == 3 {
                    Inventory { adders: 1, multipliers: 1, squarers: 1, ..Default::default() }
                } else {
                    Inventory { adders: 2, multipliers: 3, squarers: 1, ..Default::default() }
                };
                horner.plus(coeff).plus(Inventory {
                    lut_entries: self.lut.len() as u32,
                    lut_bits: self.lut.total_bits(),
                    ..Default::default()
                })
            }
            CoeffMode::Stored => {
                // Each anchor stores T plus (terms−1) coefficients.
                let words = self.terms as u32;
                horner.plus(Inventory {
                    lut_entries: self.lut.len() as u32 * words,
                    lut_bits: self.lut.total_bits() * words,
                    ..Default::default()
                })
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::approx::eval_odd_saturating;

    const OUT: QFormat = QFormat::S_15;
    const INP: QFormat = QFormat::S3_12;

    fn sweep_max_err(m: &Taylor) -> f64 {
        let mut max_err: f64 = 0.0;
        for raw in -(INP.max_raw())..=INP.max_raw() {
            let x = Fx::from_raw(raw, INP);
            let y = eval_odd_saturating(m, x, OUT);
            max_err = max_err.max((y.to_f64() - tanh_ref(x.to_f64())).abs());
        }
        max_err
    }

    #[test]
    fn b1_table1_error_bounds() {
        // Paper Table I row B1: step 1/16, quadratic → max err 3.65e-5.
        let e = sweep_max_err(&Taylor::table1_quadratic());
        assert!(e < 5.5e-5, "B1 max_err {e} (paper 3.65e-5)");
        assert!(e > 1.0e-5);
    }

    #[test]
    fn b2_table1_error_bounds() {
        // Paper Table I row B2: step 1/8, cubic → max err 3.23e-5.
        let e = sweep_max_err(&Taylor::table1_cubic());
        assert!(e < 5.5e-5, "B2 max_err {e} (paper 3.23e-5)");
        assert!(e > 1.0e-5);
    }

    #[test]
    fn quadratic_beats_linear_taylor() {
        let lin = sweep_max_err(&Taylor::new(1.0 / 16.0, 2, 6.0));
        let quad = sweep_max_err(&Taylor::new(1.0 / 16.0, 3, 6.0));
        assert!(quad < lin, "quad {quad} vs lin {lin}");
    }

    #[test]
    fn math_model_tracks_series_order() {
        // Pure-f64 error should shrink ~(h/2)^K with term count K.
        let t3 = Taylor::new(1.0 / 16.0, 3, 6.0);
        let t4 = Taylor::new(1.0 / 16.0, 4, 6.0);
        let probe = |m: &Taylor| {
            let mut e: f64 = 0.0;
            let mut x = 0.0;
            while x < 6.0 {
                e = e.max((m.eval_f64(x) - tanh_ref(x)).abs());
                x += 1e-3;
            }
            e
        };
        let (e3, e4) = (probe(&t3), probe(&t4));
        assert!(e4 < e3 / 4.0, "e3={e3} e4={e4}");
    }

    #[test]
    fn lut_sizes_match_paper_iv_c() {
        // Paper §IV.C: 96 entries (B1, step 1/16 over 6) / 48 (B2, 1/8).
        // We carry one guard entry for the boundary interval.
        assert_eq!(Taylor::table1_quadratic().lut().len(), 96 + 2);
        assert_eq!(Taylor::table1_cubic().lut().len(), 48 + 2);
    }

    #[test]
    fn inventory_matches_paper_counts() {
        // Paper: "two adders, two multipliers and an LUT of 96 entries,
        // or three adders, three multipliers and an LUT of 48 entries"
        // (Horner datapath; runtime coefficient derivation adds logic).
        let b1 = Taylor::table1_quadratic()
            .with_coeff_mode(CoeffMode::Stored)
            .inventory(IoSpec::table1());
        assert_eq!(b1.adders, 2);
        assert_eq!(b1.multipliers, 2);
        let b2 = Taylor::table1_cubic()
            .with_coeff_mode(CoeffMode::Stored)
            .inventory(IoSpec::table1());
        assert_eq!(b2.adders, 3);
        assert_eq!(b2.multipliers, 3);
        // Runtime mode trades LUT bits for arithmetic.
        let rt = Taylor::table1_quadratic().inventory(IoSpec::table1());
        let st = b1;
        assert!(rt.lut_bits < st.lut_bits);
        assert!(rt.multipliers + rt.squarers > st.multipliers);
    }

    #[test]
    fn compiled_kernel_bit_matches_both_anchor_modes() {
        // Centred (the default) and Left (the paper-literal ablation)
        // both compile; the precomputed-coefficient Horner chain must
        // reproduce the scalar datapath raw-for-raw.
        let io = IoSpec::table1();
        for m in [
            Taylor::table1_quadratic(),
            Taylor::table1_cubic(),
            Taylor::with_anchor(1.0 / 16.0, 3, 6.0, AnchorMode::Left),
        ] {
            let k = m.compile(io);
            for raw in (-(INP.max_raw())..=INP.max_raw()).step_by(11) {
                let x = Fx::from_raw(raw, INP);
                assert_eq!(
                    k.eval_raw(raw),
                    m.eval_fx(x, OUT).raw(),
                    "{} raw {raw}",
                    m.describe()
                );
            }
        }
    }

    #[test]
    fn stored_and_runtime_modes_agree_numerically() {
        let rt = Taylor::table1_quadratic();
        let st = Taylor::table1_quadratic().with_coeff_mode(CoeffMode::Stored);
        for v in [0.01, 0.7, 1.9, 4.2] {
            let x = Fx::from_f64(v, INP);
            assert_eq!(rt.eval_fx(x, OUT).raw(), st.eval_fx(x, OUT).raw());
        }
    }
}
