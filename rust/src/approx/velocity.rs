//! Method D — trigonometric expansion via velocity factors
//! (paper §II.D, §IV.E; after Doerfler's fast-approximation method).
//!
//! Instead of tanh values the registers store *velocity factors*
//! `f_a = (1 + tanh a)/(1 − tanh a) = e^{2a}` (eq. 11) for the powers of
//! two `2^k` down to a threshold θ. Because `f_{a+b} = f_a·f_b`
//! (eq. 13), the factor for the top bits of the input is a product of
//! the stored registers selected by the input's bit pattern (Fig 4's
//! multiplexer network); tanh is recovered with one division,
//! `tanh a = (F − 1)/(F + 1)` (eq. 12), and the sub-threshold residue
//! `b < θ` is compensated linearly with eq. (10):
//! `tanh(a+b) ≈ T + b·(1 − T²)`.
//!
//! The divider is the shared Newton-Raphson unit ([`super::newton`]).
//! Table II's multi-bit (paired) lookup halves the multiplier chain at
//! the cost of 4-to-1 muxes and more stored entries; it is numerically
//! identical here (pair entries are exact products of the singles) and
//! is exposed through [`VfLookupMode`] for the cost model and the hw
//! simulator.

use super::compiled::{self, CompiledKernel, KernelBody};
use super::newton::{div_f64, fx_div, NR_ITERS};
use super::reference::velocity_factor;
use super::{IoSpec, MethodId, TanhApprox};
use crate::cost::Inventory;
use crate::fixed::{fx_add, fx_mul, fx_mul_wide, fx_sub, Fx, FxWide, QFormat, Round};

/// Single-bit vs Table II paired-bit register file organization.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum VfLookupMode {
    /// One register + one multiplier per input bit (Fig 4).
    SingleBit,
    /// Table II: one 4-to-1 mux per *pair* of bits, halving the
    /// multiplier chain (20 entries / 4 multipliers at θ = 1/256).
    PairedBits,
}

/// Internal format of the divider output T and the refinement operand
/// 1−T² (stages 2-3).
const T_FMT: QFormat = QFormat::new(1, 24);

/// Velocity-factor tanh approximator.
#[derive(Clone, Debug)]
pub struct Velocity {
    /// Linear-compensation threshold θ = 2^-m.
    threshold: f64,
    /// m: bit position of the threshold.
    m: u32,
    domain_max: f64,
    /// Highest power-of-two bit weight covered (2^kmax ≤ domain_max).
    kmax: i32,
    /// Stored VF registers: `vf[i]` = quantized e^{2·2^(kmax−i)}.
    vf: Vec<Fx>,
    /// Internal wide format for the factor product.
    wide_fmt: QFormat,
    mode: VfLookupMode,
}

impl Velocity {
    /// Builds with linear threshold `threshold = 2^-m` over
    /// `[0, domain_max]`.
    pub fn new(threshold: f64, domain_max: f64) -> Velocity {
        let inv = 1.0 / threshold;
        assert!(
            inv.fract() == 0.0 && (inv as u64).is_power_of_two(),
            "threshold {threshold} must be a reciprocal power of two"
        );
        let m = (inv as u64).trailing_zeros();
        // Highest bit weight needed to cover values < domain_max:
        // 2^(kmax+1) ≥ domain_max ⇒ kmax = ceil(log2(domain)) − 1.
        let kmax = domain_max.log2().ceil() as i32 - 1;
        // Wide format: F ≤ e^(2·domain_max) ⇒ int bits = ceil(2·domain·log2 e) + 1.
        let int_bits = (2.0 * domain_max * std::f64::consts::LOG2_E).ceil() as u32 + 1;
        let wide_fmt = QFormat::new(int_bits, 24);
        let vf = (-(m as i32)..=kmax)
            .rev()
            .map(|k| {
                Fx::from_f64_round(velocity_factor((2f64).powi(k)), wide_fmt, Round::NearestEven)
            })
            .collect();
        Velocity { threshold, m, domain_max, kmax, vf, wide_fmt, mode: VfLookupMode::SingleBit }
    }

    /// Table I row "D": threshold 1/128, domain (-6, 6).
    pub fn table1() -> Velocity {
        Velocity::new(1.0 / 128.0, 6.0)
    }

    /// Selects the Table II paired-bit register organization (inventory /
    /// hw-simulator concern; numerics are identical).
    pub fn with_lookup_mode(mut self, mode: VfLookupMode) -> Velocity {
        self.mode = mode;
        self
    }

    /// The compensation threshold θ.
    pub fn threshold(&self) -> f64 {
        self.threshold
    }

    /// Number of stored velocity-factor registers (paper: 10 for θ=1/128
    /// covering 2^-7 … 2^2 — we store up to 2^kmax within the domain).
    pub fn register_count(&self) -> usize {
        self.vf.len()
    }

    /// The threshold bit position m (θ = 2^-m).
    pub fn threshold_shift(&self) -> u32 {
        self.m
    }

    /// Highest stored bit weight exponent (registers cover 2^kmax … θ).
    pub fn kmax(&self) -> i32 {
        self.kmax
    }

    /// The stored velocity-factor registers, highest weight first.
    pub fn registers(&self) -> &[Fx] {
        &self.vf
    }

    /// The wide internal format of the factor product.
    pub fn wide_format(&self) -> QFormat {
        self.wide_fmt
    }

    /// Splits a non-negative input into (coarse bits ≥ θ, residue < θ)
    /// in raw input-format terms. Public for the hw pipeline.
    #[inline]
    pub fn split(&self, x: Fx) -> (i64, i64) {
        let frac = x.format().frac_bits;
        // A threshold finer than the input resolution means every input
        // bit is covered by a stored register — residue is always zero.
        let res_bits = frac.saturating_sub(self.m);
        let mask = (1i64 << res_bits) - 1;
        (x.raw() & !mask, x.raw() & mask)
    }

    /// Stages 1-2 plus the T-dependent part of stage 3: the multiplexed
    /// register product (Fig 4), the NR divider (eq. 12) and the 1−T²
    /// derivation — all a function of the *coarse* bits only. Shared by
    /// the scalar datapath and [`Velocity::compile`]'s table builder so
    /// the two cannot diverge.
    fn coarse_t_d1(&self, coarse: i64, frac: u32) -> (Fx, Fx) {
        let wf = self.wide_fmt;
        // --- Stage 1: multiplexed product of velocity-factor registers.
        // Walk bit weights 2^kmax … 2^-m; multiply in the register when
        // the input bit is set (Fig 4's mux + multiplier chain).
        let mut f = Fx::one(wf);
        for (i, k) in (-(self.m as i32)..=self.kmax).rev().enumerate() {
            let bitpos = k + frac as i32; // position in the raw word
            if bitpos < 0 {
                continue;
            }
            if (coarse >> bitpos) & 1 == 1 {
                f = fx_mul(f, self.vf[i], wf, Round::NearestAway);
            }
        }
        // --- Stage 2: tanh a = (F − 1)/(F + 1) (eq. 12), NR divider.
        let one = Fx::one(wf);
        let num = fx_sub(f, one, wf, Round::NearestAway);
        let den = fx_add(f, one, wf, Round::NearestAway);
        let t = if num.raw() == 0 {
            Fx::zero(T_FMT)
        } else {
            fx_div(num, den, T_FMT, NR_ITERS)
        };
        let t2 = fx_mul(t, t, T_FMT, Round::NearestAway); // square unit
        let d1 = fx_sub(Fx::one(T_FMT), t2, T_FMT, Round::NearestAway);
        (t, d1)
    }
}

impl TanhApprox for Velocity {
    fn id(&self) -> MethodId {
        MethodId::Velocity
    }

    fn describe(&self) -> String {
        format!(
            "Velocity(threshold={})",
            crate::util::table::step_str(self.threshold)
        )
    }

    fn eval_f64(&self, x: f64) -> f64 {
        let neg = x < 0.0;
        let x = x.abs();
        let y = if x >= self.domain_max {
            1.0
        } else {
            // Quantize to the bit grid of the datapath: a = bits ≥ θ.
            let scale = (2f64).powi(self.m as i32);
            let a = (x * scale).floor() / scale;
            let b = x - a;
            // F = product of stored factors for set bits = e^{2a} exactly.
            let f = velocity_factor(a);
            // Divider shares the finite-NR model.
            let t = div_f64(f - 1.0, f + 1.0, NR_ITERS);
            t + b * (1.0 - t * t)
        };
        if neg {
            -y
        } else {
            y
        }
    }

    fn eval_positive_fx(&self, x: Fx, out: QFormat) -> Fx {
        let (coarse, residue) = self.split(x);
        let frac = x.format().frac_bits;
        let (t, d1) = self.coarse_t_d1(coarse, frac);

        // --- Stage 3: linear compensation (eq. 10): y = T + b·(1 − T²).
        let b = Fx::from_raw(residue, QFormat::new(0, frac)); // b < θ, ≥ 0
        fx_mul_wide(b, d1)
            .add(FxWide::from_fx(t))
            .narrow(out, Round::NearestEven)
    }

    fn domain_max(&self) -> f64 {
        self.domain_max
    }

    /// Compiled form: the register-product chain *and* the NR divider
    /// take at most one value per coarse-bit pattern, so both collapse
    /// into a `(T, 1−T²)` table at compile time; only the linear
    /// residue compensation (eq. 10) runs per input.
    fn compile(&self, io: IoSpec) -> CompiledKernel {
        let frac = io.input.frac_bits;
        let res_bits = frac.saturating_sub(self.m);
        let domain_raw = compiled::saturation_raw(io.input, self.domain_max);
        let max_ci: i64 = if domain_raw > 0 { (domain_raw - 1) >> res_bits } else { 0 };
        let pairs: Vec<(i64, i64)> = (0..=max_ci)
            .map(|ci| {
                let (t, d1) = self.coarse_t_d1(ci << res_bits, frac);
                (t.raw(), d1.raw())
            })
            .collect();
        let body = KernelBody::VelocityLut { pairs, res_bits, t_frac: T_FMT.frac_bits };
        CompiledKernel::with_body(io, self.domain_max, body).debug_check(self)
    }

    fn inventory(&self, _io: IoSpec) -> Inventory {
        let n = self.vf.len() as u32;
        let core = match self.mode {
            VfLookupMode::SingleBit => Inventory {
                // Paper §IV.E: one register per bit, mux2 selects
                // {1.0, VF}, n−1 multipliers chain the product.
                multipliers: n.saturating_sub(1),
                mux2: n,
                lut_entries: n,
                lut_bits: n * self.wide_fmt.width(),
                ..Default::default()
            },
            VfLookupMode::PairedBits => {
                // Table II: pairs of bits share a 4-to-1 mux whose
                // entries are {1, f_lsb, f_msb, f_lsb·f_msb}; the "1"
                // needs no storage ⇒ ~3 stored per pair plus the chain.
                let pairs = n.div_ceil(2);
                Inventory {
                    multipliers: pairs.saturating_sub(1),
                    mux4: pairs,
                    lut_entries: pairs * 4,
                    lut_bits: pairs * 4 * self.wide_fmt.width(),
                    ..Default::default()
                }
            }
        };
        core.plus(Inventory {
            // (F−1), (F+1), NR divider, then eq. 10: 2 adders, 1 mult,
            // 1 squarer.
            adders: 4,
            multipliers: 1,
            squarers: 1,
            dividers: 1,
            mult_width: self.wide_fmt.width(),
            add_width: self.wide_fmt.width(),
            // mux/product chain + add | divide (NR: 3 iter × 2 mult) | refine
            pipeline_stages: core.multipliers + 1 + 2 * (NR_ITERS as u32) + 2,
            ..Default::default()
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::approx::eval_odd_saturating;
    use crate::approx::reference::tanh_ref;

    const OUT: QFormat = QFormat::S_15;
    const INP: QFormat = QFormat::S3_12;

    #[test]
    fn register_count_matches_paper() {
        // Paper §IV.E: θ = 1/128 stores VF for 2^k, −7 ≤ k ≤ 2 → 10
        // registers. Our domain (−6,6) also tops out at 2^2.
        assert_eq!(Velocity::table1().register_count(), 10);
    }

    #[test]
    fn exact_on_coarse_grid() {
        // For inputs with no sub-threshold bits the only errors are VF
        // quantization + divider truncation — well under 1.5 output ulp.
        let v = Velocity::table1();
        for xv in [0.5, 1.0, 1.5, 2.25, 3.0, 5.0] {
            let x = Fx::from_f64(xv, INP);
            let y = v.eval_fx(x, OUT);
            let err = (y.to_f64() - tanh_ref(x.to_f64())).abs();
            assert!(err <= 1.5 * OUT.ulp(), "x={xv} err={err}");
        }
    }

    #[test]
    fn table1_error_bounds() {
        // Paper Table I row D: θ = 1/128 → max err 3.85e-5.
        let v = Velocity::table1();
        let mut max_err: f64 = 0.0;
        for raw in -(INP.max_raw())..=INP.max_raw() {
            let x = Fx::from_raw(raw, INP);
            let y = eval_odd_saturating(&v, x, OUT);
            max_err = max_err.max((y.to_f64() - tanh_ref(x.to_f64())).abs());
        }
        assert!(max_err < 6.0e-5, "max_err {max_err} (paper 3.85e-5)");
        assert!(max_err > 1.0e-5);
    }

    #[test]
    fn smaller_threshold_less_error() {
        let coarse = Velocity::new(1.0 / 32.0, 6.0);
        let fine = Velocity::new(1.0 / 256.0, 6.0);
        let probe = |m: &Velocity| {
            let mut e: f64 = 0.0;
            for raw in (0..INP.max_raw()).step_by(7) {
                let x = Fx::from_raw(raw, INP);
                e = e.max((m.eval_fx(x, OUT).to_f64() - tanh_ref(x.to_f64())).abs());
            }
            e
        };
        assert!(probe(&coarse) > 2.0 * probe(&fine));
    }

    #[test]
    fn split_reassembles() {
        let v = Velocity::table1();
        let x = Fx::from_f64(2.71828, INP);
        let (a, b) = v.split(x);
        assert_eq!(a + b, x.raw());
        // residue strictly below threshold
        assert!((b as f64) * INP.ulp() < v.threshold());
        // coarse part has no sub-threshold bits
        assert_eq!(a & ((1 << (INP.frac_bits - v.m)) - 1), 0);
    }

    #[test]
    fn compiled_kernel_bit_matches_scalar() {
        // The coarse-table kernel replaces the whole multiplier chain +
        // NR divider per eval; it must stay raw-exact, including on
        // pure-coarse inputs (residue 0) and threshold boundaries.
        let v = Velocity::table1();
        let k = v.compile(IoSpec::table1());
        for raw in (-(INP.max_raw())..=INP.max_raw()).step_by(17) {
            let x = Fx::from_raw(raw, INP);
            assert_eq!(k.eval_raw(raw), v.eval_fx(x, OUT).raw(), "raw {raw}");
        }
        for raw in [0, 1, 31, 32, 33, 4096, 24575, 24576] {
            let x = Fx::from_raw(raw, INP);
            assert_eq!(k.eval_raw(raw), v.eval_fx(x, OUT).raw(), "edge raw {raw}");
        }
    }

    #[test]
    fn paired_mode_inventory_matches_table2_shape() {
        // Paper: "This scheme requires 20 LUT entries and 4 multipliers
        // (for 1/256 threshold)" — θ=1/256 over ±4 ⇒ bits 2^-8..2^1 = 10
        // registers ⇒ 5 pairs ⇒ 20 entries, 4 chain multipliers.
        let v = Velocity::new(1.0 / 256.0, 4.0).with_lookup_mode(VfLookupMode::PairedBits);
        let inv = v.inventory(IoSpec::table1());
        assert_eq!(inv.mux4, 5);
        assert_eq!(inv.lut_entries, 20);
        // 4 chain multipliers + 1 refinement multiplier.
        assert_eq!(inv.multipliers, 5);
        assert_eq!(inv.dividers, 1);
    }

    #[test]
    fn single_bit_inventory_matches_paper_counts() {
        // Paper §IV.E basic implementation: 10 registers, 9 multipliers.
        let inv = Velocity::table1().inventory(IoSpec::table1());
        assert_eq!(inv.lut_entries, 10);
        assert_eq!(inv.mux2, 10);
        // 9 chain multipliers + 1 refinement multiplier.
        assert_eq!(inv.multipliers, 10);
        assert_eq!(inv.dividers, 1);
        assert_eq!(inv.squarers, 1);
    }

    #[test]
    fn math_model_close_to_datapath() {
        let v = Velocity::table1();
        for xv in [0.1, 0.77, 1.3, 2.9, 4.5] {
            let x = Fx::from_f64(xv, INP);
            let fx = v.eval_fx(x, OUT).to_f64();
            let f64v = v.eval_f64(x.to_f64());
            assert!((fx - f64v).abs() < 4.0 * OUT.ulp(), "x={xv}: {fx} vs {f64v}");
        }
    }
}
