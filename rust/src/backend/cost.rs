//! Cost probing: where a design point's hardware numbers come from.
//!
//! The paper's §IV comparison ranks methods with an *analytic*
//! complexity model — component inventories priced by the unit library
//! ([`crate::cost::CostModel`]). Since the hw backend lowers every spec
//! to its cycle-accurate Fig 3/4/5 datapath, the latency, critical path
//! and instantiated units can instead be *measured* off the lowered
//! [`crate::hw::Pipeline`]. [`CostProbe`] abstracts over the two
//! answers: the golden backend replies with the analytic §IV model
//! (unchanged from the original reproduction), the hw backend with
//! lowered measurements, and every [`DesignCost`] carries a typed
//! [`CostSource`] so consumers — the explorer's frontier rows, the
//! report's measured-vs-analytic table — can never mislabel a
//! fallback as a measurement.

use std::fmt;

use crate::approx::MethodSpec;
use crate::cost::CostModel;

use super::BackendError;

/// Provenance of a [`DesignCost`]'s numbers.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum CostSource {
    /// The analytic §IV model: component inventory priced by the unit
    /// library ([`crate::cost::CostModel::price`]).
    Analytic,
    /// Measured off a lowered [`crate::hw::Pipeline`]: depth and
    /// critical path read from the stages, area summed over the
    /// instantiated units, cycles/element from a streaming probe.
    Measured,
    /// Derived from the elaborated RTL netlist
    /// ([`crate::rtl::elaborate`]): area summed cell by cell, critical
    /// path as the longest combinational path between register ranks,
    /// latency as the registered stage count. The finest-grained tier —
    /// it prices the actual emitted structure, not a stage-level model.
    Netlist,
}

impl CostSource {
    /// Stable report/CLI spelling (`analytic` / `measured` / `netlist`).
    pub fn as_str(self) -> &'static str {
        match self {
            CostSource::Analytic => "analytic",
            CostSource::Measured => "measured",
            CostSource::Netlist => "netlist",
        }
    }
}

impl fmt::Display for CostSource {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.as_str())
    }
}

/// The hardware-cost coordinates of one design point, plus their
/// provenance. The field set mirrors the analytic
/// [`crate::cost::CostEstimate`] so the two sources are directly
/// comparable axis by axis.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct DesignCost {
    /// Where these numbers came from.
    pub source: CostSource,
    /// Pipeline depth: latency in cycles at full throughput.
    pub latency_cycles: u32,
    /// Critical stage delay (FO4) — reciprocal of achievable frequency.
    pub stage_delay_fo4: f64,
    /// Area in gate equivalents.
    pub area_ge: f64,
    /// Steady-state cycles per element. The analytic model *assumes*
    /// 1.0 (one result per cycle, §IV.H); the hw probe *measures* it by
    /// streaming a warm batch through the lowered pipeline.
    pub cycles_per_element: f64,
}

/// How an execution backend prices a design point. Implemented by
/// [`super::GoldenBackend`] (analytic §IV model) and
/// [`super::HwBackend`] (measured off the lowered pipeline); the
/// explorer resolves every [`crate::explore::DesignPoint`]'s cost
/// columns through this trait.
pub trait CostProbe {
    /// Resolves the cost of one design point. Errors `unknown_spec`
    /// when this probe cannot express the spec (e.g. a configuration
    /// the hw block diagrams cannot lower) — callers that fall back to
    /// [`analytic_cost`] must keep the returned [`CostSource`] honest.
    fn probe_cost(&self, spec: &MethodSpec) -> Result<DesignCost, BackendError>;
}

/// The analytic §IV cost of a spec: the inventory of the golden
/// datapath model priced by the default unit library. This is what
/// [`super::GoldenBackend`]'s probe answers, and the *labeled* fallback
/// for specs a measuring probe cannot express.
pub fn analytic_cost(spec: &MethodSpec) -> Result<DesignCost, BackendError> {
    // Re-validate first (MethodSpec fields are public): a structurally
    // invalid spec errors typed instead of panicking in build().
    MethodSpec::new(spec.params, spec.io, spec.domain)
        .map_err(|e| BackendError::unknown_spec(format!("invalid spec '{spec}': {e}")))?;
    let c = CostModel::new().price(&spec.build().inventory(spec.io));
    Ok(DesignCost {
        source: CostSource::Analytic,
        latency_cycles: c.latency_cycles,
        stage_delay_fo4: c.stage_delay_fo4,
        area_ge: c.area_ge,
        cycles_per_element: 1.0 / c.throughput_per_cycle,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::approx::{IoSpec, MethodId, MethodParams};
    use crate::backend::ErrorCode;

    #[test]
    fn analytic_cost_matches_the_priced_inventory() {
        let spec = MethodSpec::table1(MethodId::Pwl);
        let cost = analytic_cost(&spec).unwrap();
        let want = CostModel::new().price(&spec.build().inventory(spec.io));
        assert_eq!(cost.source, CostSource::Analytic);
        assert_eq!(cost.latency_cycles, want.latency_cycles);
        assert_eq!(cost.stage_delay_fo4, want.stage_delay_fo4);
        assert_eq!(cost.area_ge, want.area_ge);
        assert_eq!(cost.cycles_per_element, 1.0);
    }

    #[test]
    fn analytic_cost_rejects_bogus_specs_typed() {
        let bogus = MethodSpec {
            params: MethodParams::Taylor { step: 1.0 / 8.0, terms: 9 },
            io: IoSpec::table1(),
            domain: 6.0,
        };
        let err = analytic_cost(&bogus).unwrap_err();
        assert_eq!(err.code, ErrorCode::UnknownSpec);
        assert!(err.message.contains("invalid spec"), "{err}");
    }

    #[test]
    fn cost_source_spellings_are_stable() {
        assert_eq!(CostSource::Analytic.to_string(), "analytic");
        assert_eq!(CostSource::Measured.to_string(), "measured");
        assert_eq!(CostSource::Netlist.to_string(), "netlist");
    }
}
