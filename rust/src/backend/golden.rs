//! Golden-model execution: the compiled integer kernels, resolved
//! through the shared [`Registry`](crate::approx::Registry) cache.

use std::collections::HashMap;
use std::sync::{Arc, RwLock};

use crate::approx::{CompiledKernel, MethodSpec};

use super::{
    analytic_cost, golden_kernel, Availability, BackendError, CostProbe, DesignCost, EvalBackend,
    EvalStats, EvalStream,
};

/// The reference backend: serves any spec through its compiled integer
/// kernel (bit-exact against the scalar `eval_fx` datapath models, one
/// to two orders of magnitude faster). Kernels come from the shared
/// [`Registry`](crate::approx::Registry), so a spec is compiled once
/// per process no matter how many backends, coordinators or shards
/// serve it.
///
/// Strictness: [`EvalBackend::eval_raw`] only accepts specs that were
/// [`EvalBackend::ensure`]d on *this* backend — a routing bug must
/// surface as `unknown_spec`, not silently trigger a compile on the
/// hot path.
#[derive(Default)]
pub struct GoldenBackend {
    kernels: RwLock<HashMap<MethodSpec, Arc<CompiledKernel>>>,
}

impl GoldenBackend {
    /// An empty backend; specs are admitted via `ensure`.
    pub fn new() -> GoldenBackend {
        GoldenBackend::default()
    }

    /// Convenience: a backend with the six Table I specs pre-ensured.
    pub fn table1() -> GoldenBackend {
        GoldenBackend::for_specs(&MethodSpec::table1_all())
    }

    /// Convenience: a backend with `specs` pre-ensured.
    pub fn for_specs(specs: &[MethodSpec]) -> GoldenBackend {
        let b = GoldenBackend::new();
        for s in specs {
            b.ensure(s).expect("golden backend serves every valid spec");
        }
        b
    }

    fn kernel(&self, spec: &MethodSpec) -> Result<Arc<CompiledKernel>, BackendError> {
        self.kernels.read().unwrap().get(spec).cloned().ok_or_else(|| {
            BackendError::unknown_spec(format!("spec '{spec}' not ensured on the golden backend"))
        })
    }
}

impl EvalBackend for GoldenBackend {
    fn name(&self) -> &'static str {
        "golden"
    }

    fn availability(&self) -> Availability {
        Availability::Available
    }

    fn ensure(&self, spec: &MethodSpec) -> Result<(), BackendError> {
        let kernel = golden_kernel(spec)?;
        self.kernels.write().unwrap().insert(*spec, kernel);
        Ok(())
    }

    fn eval_raw(
        &self,
        spec: &MethodSpec,
        input: &[i64],
        out: &mut [i64],
    ) -> Result<EvalStats, BackendError> {
        super::check_slice_lens(input, out)?;
        let kernel = self.kernel(spec)?;
        // The packed entry point auto-selects: SWAR lanes when the
        // spec's formats qualify (every Table I spec does), the scalar
        // loop otherwise. Which path ran is reported so the serve
        // metrics can count packed batches.
        kernel.eval_slice_packed(input, out);
        Ok(EvalStats { packed: kernel.lane_width().is_some(), ..EvalStats::default() })
    }

    fn native_stream(
        &self,
        spec: &MethodSpec,
    ) -> Result<Option<Box<dyn EvalStream>>, BackendError> {
        // Kernels are pure functions, so a golden "stream" carries no
        // state and zero delay — but holding the kernel Arc directly
        // skips the per-pulse map lookup the stateless fallback would
        // pay, and enforces the same ensure-first strictness.
        Ok(Some(Box::new(GoldenStream { kernel: self.kernel(spec)? })))
    }
}

/// Zero-delay stream over one compiled kernel: every pulse is an
/// independent (packed, when the formats qualify) slice evaluation.
struct GoldenStream {
    kernel: Arc<CompiledKernel>,
}

impl EvalStream for GoldenStream {
    fn delay(&self) -> usize {
        0
    }

    fn feed(
        &mut self,
        input: &[i64],
        out: &mut Vec<i64>,
    ) -> Result<EvalStats, BackendError> {
        let start = out.len();
        out.resize(start + input.len(), 0);
        self.kernel.eval_slice_packed(input, &mut out[start..]);
        Ok(EvalStats { packed: self.kernel.lane_width().is_some(), ..EvalStats::default() })
    }
}

impl CostProbe for GoldenBackend {
    /// The golden backend has no datapath to measure: it answers with
    /// the analytic §IV model, exactly as the pre-probe explorer did.
    fn probe_cost(&self, spec: &MethodSpec) -> Result<DesignCost, BackendError> {
        analytic_cost(spec)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::approx::{MethodId, TanhApprox};
    use crate::backend::{eval_f32, ErrorCode};
    use crate::fixed::{Fx, QFormat};

    #[test]
    fn golden_backend_evaluates_all_methods() {
        let b = GoldenBackend::table1();
        for method in MethodId::all() {
            let spec = MethodSpec::table1(method);
            let (out, _) =
                eval_f32(&b, &spec, &[0.0, 0.5, -0.5, 2.0, -2.0, 6.5, -6.5, 0.1]).unwrap();
            assert_eq!(out.len(), 8);
            assert_eq!(out[0], 0.0);
            assert!((out[1] - 0.46).abs() < 0.01, "{method:?}: {}", out[1]);
            assert_eq!(out[1], -out[2]);
            assert!(out[5] > 0.9999);
        }
    }

    #[test]
    fn golden_backend_matches_scalar_datapath() {
        // Slice-wise raw execution must agree with per-element eval_fx
        // (including the f32 → S3.12 quantization step).
        let b = GoldenBackend::table1();
        let inputs: Vec<f32> = (0..16).map(|i| (i as f32) * 0.41 - 3.3).collect();
        for m in crate::approx::table1_suite() {
            let spec = MethodSpec::table1(m.id());
            let (out, _) = eval_f32(&b, &spec, &inputs).unwrap();
            for (&v, &y) in inputs.iter().zip(&out) {
                let x = Fx::from_f64(v as f64, QFormat::S3_12);
                let want = m.eval_fx(x, QFormat::S_15).to_f64() as f32;
                assert_eq!(y, want, "{:?} x={v}", m.id());
            }
        }
    }

    #[test]
    fn golden_backend_serves_non_table1_specs() {
        let spec = MethodSpec::parse("catmull:step=1/8:in=s2.13:out=s.15:dom=4").unwrap();
        let b = GoldenBackend::for_specs(&[spec]);
        let golden = spec.build();
        let inputs = [0.25f32, -1.5, 3.9, 0.0];
        let (out, _) = eval_f32(&b, &spec, &inputs).unwrap();
        for (&v, &y) in inputs.iter().zip(&out) {
            let x = Fx::from_f64(v as f64, spec.io.input);
            let want = golden.eval_fx(x, spec.io.output).to_f64() as f32;
            assert_eq!(y, want, "x={v}");
        }
        // Specs never ensured on this backend are typed errors.
        let other = MethodSpec::table1(MethodId::Pwl);
        let err = eval_f32(&b, &other, &inputs).unwrap_err();
        assert_eq!(err.code, ErrorCode::UnknownSpec);
        assert!(err.message.contains("not ensured"), "{err}");
    }

    #[test]
    fn structurally_invalid_specs_error_instead_of_panicking() {
        use crate::approx::{IoSpec, MethodParams};
        // MethodSpec fields are public, so a bogus configuration can
        // reach ensure; it must come back as a typed unknown_spec, not
        // hit the Taylor constructor's assert mid-serving.
        let bogus = MethodSpec {
            params: MethodParams::Taylor { step: 1.0 / 8.0, terms: 9 },
            io: IoSpec::table1(),
            domain: 6.0,
        };
        let b = GoldenBackend::new();
        let err = b.ensure(&bogus).unwrap_err();
        assert_eq!(err.code, ErrorCode::UnknownSpec);
        assert!(err.message.contains("invalid spec"), "{err}");
    }

    #[test]
    fn golden_stream_is_zero_delay_and_matches_eval_raw() {
        let spec = MethodSpec::table1(MethodId::Pwl);
        let b = GoldenBackend::for_specs(&[spec]);
        let mut stream = crate::backend::open_stream(
            &(Arc::new(GoldenBackend::for_specs(&[spec])) as Arc<dyn EvalBackend>),
            &spec,
        )
        .unwrap();
        assert_eq!(stream.delay(), 0);
        let input: Vec<i64> = (-6..6).map(|i| i * 700).collect();
        let mut got = Vec::new();
        // Two pulses concatenate exactly like one flat eval_raw call.
        stream.feed(&input[..5], &mut got).unwrap();
        stream.feed(&input[5..], &mut got).unwrap();
        let mut want = vec![0i64; input.len()];
        b.eval_raw(&spec, &input, &mut want).unwrap();
        assert_eq!(got, want);
        // Streams honor ensure-first strictness like eval_raw does.
        let other = MethodSpec::table1(MethodId::Taylor);
        let backend: Arc<dyn EvalBackend> = Arc::new(GoldenBackend::for_specs(&[spec]));
        let err = crate::backend::open_stream(&backend, &other).unwrap_err();
        assert_eq!(err.code, ErrorCode::UnknownSpec);
    }

    #[test]
    fn mismatched_output_slice_is_a_bad_request() {
        let spec = MethodSpec::table1(MethodId::Pwl);
        let b = GoldenBackend::for_specs(&[spec]);
        let mut out = vec![0i64; 3];
        let err = b.eval_raw(&spec, &[0, 1], &mut out).unwrap_err();
        assert_eq!(err.code, ErrorCode::BadRequest);
    }
}
