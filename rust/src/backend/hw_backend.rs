//! Cycle-accurate hardware execution: specs lowered to the Fig 3/4/5
//! pipelined datapaths and served through the cycle simulator.

use std::collections::HashMap;
use std::sync::{Arc, RwLock};

use crate::approx::MethodSpec;
use crate::fixed::Fx;
use crate::hw::{pipeline_for, Pipeline};

use super::{golden_kernel, Availability, BackendError, EvalBackend, EvalStats};

/// Cross-check stride of [`HwBackend::ensure`]'s lowering audit
/// (~250 probe points across the input range — cheap, runs once per
/// spec per backend).
const AUDIT_PROBES: i64 = 251;

/// The hardware-pipeline backend: every served spec is lowered to its
/// §IV block-diagram datapath ([`pipeline_for`]) and batches stream
/// through the cycle-accurate simulator
/// ([`Pipeline::simulate`]) — one result per cycle once the pipeline
/// fills, exactly the paper's §IV.H "back-to-back computations" story.
///
/// Outputs are **bit-exact** against the golden compiled kernels: the
/// stages are built from the same [`crate::fixed`] primitives as the
/// golden models, and `ensure` audits the lowering against the spec's
/// golden kernel on a strided grid before the spec is admitted — a
/// datapath that diverges never serves.
///
/// Beyond the outputs, [`EvalStats::sim_cycles`] reports how many
/// simulated cycles each batch occupied the pipeline
/// (`latency + N − 1` when saturated), which the serve metrics
/// aggregate into the simulated-hardware-latency column of
/// `BENCH_serve.json`.
#[derive(Default)]
pub struct HwBackend {
    pipelines: RwLock<HashMap<MethodSpec, Arc<Pipeline>>>,
}

impl HwBackend {
    /// An empty backend; specs are lowered via `ensure`.
    pub fn new() -> HwBackend {
        HwBackend::default()
    }

    /// The lowered pipeline of an ensured spec (reports and tests).
    pub fn pipeline(&self, spec: &MethodSpec) -> Option<Arc<Pipeline>> {
        self.pipelines.read().unwrap().get(spec).cloned()
    }
}

impl EvalBackend for HwBackend {
    fn name(&self) -> &'static str {
        "hw"
    }

    fn availability(&self) -> Availability {
        Availability::Available
    }

    fn ensure(&self, spec: &MethodSpec) -> Result<(), BackendError> {
        if self.pipelines.read().unwrap().contains_key(spec) {
            return Ok(());
        }
        // Validation first: golden_kernel re-validates the public-field
        // spec BEFORE any construction (the method constructors
        // assert/allocate on bogus configurations), so a spec that is
        // invalid on *every* backend reports identically to the golden
        // backend; pipeline_for's structural guards then reject
        // anything the block diagrams cannot express with the
        // hw-specific "unsupported by hw backend" message. The kernel
        // doubles as the lowering-audit reference below.
        let kernel = golden_kernel(spec)?;
        let pipeline = pipeline_for(spec).map_err(BackendError::unknown_spec)?;
        // Lowering audit: the datapath must bit-match the golden
        // kernel before it may serve. Strided, not exhaustive — the
        // exhaustive cross-backend property lives in the test suite;
        // this is the cheap runtime guard against a lowering bug
        // serving wrong bits.
        let inp = spec.io.input;
        let (lo, hi) = (inp.min_raw(), inp.max_raw());
        let step = ((hi - lo) / AUDIT_PROBES).max(1) as usize;
        for raw in (lo..=hi).step_by(step) {
            let got = pipeline.eval(Fx::from_raw(raw, inp)).raw();
            let want = kernel.eval_raw(raw);
            if got != want {
                return Err(BackendError::internal(format!(
                    "hw lowering of '{spec}' diverges from the golden kernel at raw \
                     {raw}: pipeline {got} vs golden {want}"
                )));
            }
        }
        self.pipelines.write().unwrap().insert(*spec, Arc::new(pipeline));
        Ok(())
    }

    fn eval_raw(
        &self,
        spec: &MethodSpec,
        input: &[i64],
        out: &mut [i64],
    ) -> Result<EvalStats, BackendError> {
        super::check_slice_lens(input, out)?;
        let pipeline = self.pipeline(spec).ok_or_else(|| {
            BackendError::unknown_spec(format!("spec '{spec}' not ensured on the hw backend"))
        })?;
        if input.is_empty() {
            return Ok(EvalStats::default());
        }
        let inp = spec.io.input;
        let fxs: Vec<Fx> = input.iter().map(|&raw| Fx::from_raw(raw, inp)).collect();
        let sim = pipeline.simulate(&fxs);
        for (slot, y) in out.iter_mut().zip(&sim.outputs) {
            *slot = y.raw();
        }
        Ok(EvalStats { sim_cycles: sim.cycles as u64 })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::approx::{IoSpec, MethodId, MethodParams};
    use crate::backend::{golden_kernel, ErrorCode};

    #[test]
    fn ensure_lowers_and_eval_reports_cycles() {
        let b = HwBackend::new();
        let spec = MethodSpec::table1(MethodId::Pwl);
        b.ensure(&spec).unwrap();
        let pipe = b.pipeline(&spec).expect("ensured pipeline retained");
        let input: Vec<i64> = (-8..8).map(|i| i * 500).collect();
        let mut out = vec![0i64; input.len()];
        let stats = b.eval_raw(&spec, &input, &mut out).unwrap();
        // Saturated streaming: latency + N − 1 cycles for N inputs.
        assert_eq!(stats.sim_cycles, (pipe.latency() + input.len() - 1) as u64);
        // Bit-exact against the golden kernel.
        let kernel = golden_kernel(&spec).unwrap();
        for (&raw, &y) in input.iter().zip(&out) {
            assert_eq!(y, kernel.eval_raw(raw), "raw {raw}");
        }
    }

    #[test]
    fn bogus_specs_surface_through_ensure_as_typed_errors() {
        // A spec that is invalid on EVERY backend reports as such,
        // identically to the golden backend (not as an hw-specific
        // limitation); the "unsupported by hw backend" wording is
        // reserved for pipeline_for's structural guards (pinned by the
        // hw::mod tests).
        let b = HwBackend::new();
        let bogus = MethodSpec {
            params: MethodParams::Taylor { step: 1.0 / 8.0, terms: 7 },
            io: IoSpec::table1(),
            domain: 6.0,
        };
        let err = b.ensure(&bogus).unwrap_err();
        assert_eq!(err.code, ErrorCode::UnknownSpec);
        assert!(err.message.contains("invalid spec"), "{err}");
    }

    #[test]
    fn unensured_spec_is_unknown_and_empty_input_is_benign() {
        let b = HwBackend::new();
        let spec = MethodSpec::table1(MethodId::Lambert);
        let mut out = [0i64; 1];
        let err = b.eval_raw(&spec, &[0], &mut out).unwrap_err();
        assert_eq!(err.code, ErrorCode::UnknownSpec);
        b.ensure(&spec).unwrap();
        let stats = b.eval_raw(&spec, &[], &mut []).unwrap();
        assert_eq!(stats.sim_cycles, 0);
        // ensure is idempotent (second call hits the pipeline cache).
        b.ensure(&spec).unwrap();
    }
}
