//! Cycle-accurate hardware execution: specs lowered to the Fig 3/4/5
//! pipelined datapaths and served through the cycle simulator, with
//! each spec's pipeline kept **warm across batches**.

use std::collections::HashMap;
use std::sync::{Arc, Mutex, RwLock};
use std::thread::ThreadId;

use crate::approx::MethodSpec;
use crate::cost::UnitLibrary;
use crate::fixed::{Fx, QFormat};
use crate::hw::{pipeline_for, Pipeline, StreamState};

use super::{
    golden_kernel, Availability, BackendError, CostProbe, CostSource, DesignCost, EvalBackend,
    EvalStats, EvalStream,
};

/// Cross-check stride of [`HwBackend::ensure`]'s lowering audit
/// (~250 probe points across the input range — cheap, runs once per
/// spec per backend).
const AUDIT_PROBES: i64 = 251;

/// Batch size of the [`CostProbe`] streaming measurement.
const COST_PROBE_BATCH: usize = 64;

/// One ensured spec: its lowered pipeline plus the persistent
/// streaming state that keeps it warm across `eval_raw` calls — **one
/// stream per calling thread**, not one shared stream per spec. A
/// single shared `Mutex<StreamState>` let two coordinator shards
/// interleave their feeds into the same register file: each shard's
/// issue/delivery bookkeeping then counted the *other* shard's
/// elements, so per-batch incremental cycles (and the
/// `sim_cycles_per_element` metric built on them) were corrupted under
/// concurrency. Keying by [`ThreadId`] gives every worker its own
/// warm datapath: same-thread batches still overlap drains (warm
/// feeds cost exactly `N` cycles), and each thread pays its own fill
/// latency exactly once.
struct HwEntry {
    pipeline: Arc<Pipeline>,
    streams: Mutex<HashMap<ThreadId, StreamState>>,
}

/// The hardware-pipeline backend: every served spec is lowered to its
/// §IV block-diagram datapath ([`pipeline_for`]) and batches stream
/// through the cycle-accurate simulator — one result per cycle once
/// the pipeline fills, exactly the paper's §IV.H "back-to-back
/// computations" story.
///
/// Outputs are **bit-exact** against the golden compiled kernels: the
/// stages are built from the same [`crate::fixed`] primitives as the
/// golden models, and `ensure` audits the lowering against the spec's
/// golden kernel on a strided grid before the spec is admitted — a
/// datapath that diverges never serves.
///
/// Batches stream through persistent per-spec state
/// ([`Pipeline::feed`]): the next batch's issue cycles absorb the
/// previous batch's drain, so [`EvalStats::sim_cycles`] reports the
/// *incremental* cycles a batch occupied the pipeline —
/// `latency + N − 1` for the first batch on a cold stream, exactly `N`
/// once warm. Per-batch `simulate` re-filling (the pre-streaming
/// behavior) charged every batch the full `latency + N − 1`.
#[derive(Default)]
pub struct HwBackend {
    entries: RwLock<HashMap<MethodSpec, Arc<HwEntry>>>,
}

impl HwBackend {
    /// An empty backend; specs are lowered via `ensure`.
    pub fn new() -> HwBackend {
        HwBackend::default()
    }

    /// The lowered pipeline of an ensured spec (reports and tests).
    pub fn pipeline(&self, spec: &MethodSpec) -> Option<Arc<Pipeline>> {
        self.entries.read().unwrap().get(spec).map(|e| e.pipeline.clone())
    }

    fn entry(&self, spec: &MethodSpec) -> Result<Arc<HwEntry>, BackendError> {
        self.entries.read().unwrap().get(spec).cloned().ok_or_else(|| {
            BackendError::unknown_spec(format!("spec '{spec}' not ensured on the hw backend"))
        })
    }
}

impl EvalBackend for HwBackend {
    fn name(&self) -> &'static str {
        "hw"
    }

    fn availability(&self) -> Availability {
        Availability::Available
    }

    fn ensure(&self, spec: &MethodSpec) -> Result<(), BackendError> {
        if self.entries.read().unwrap().contains_key(spec) {
            return Ok(());
        }
        // Validation first: golden_kernel re-validates the public-field
        // spec BEFORE any construction (the method constructors
        // assert/allocate on bogus configurations), so a spec that is
        // invalid on *every* backend reports identically to the golden
        // backend; pipeline_for's structural guards then reject
        // anything the block diagrams cannot express with the
        // hw-specific "unsupported by hw backend" message. The kernel
        // doubles as the lowering-audit reference below.
        let kernel = golden_kernel(spec)?;
        let pipeline = pipeline_for(spec).map_err(BackendError::unknown_spec)?;
        // Lowering audit: the datapath must bit-match the golden
        // kernel before it may serve. Strided, not exhaustive — the
        // exhaustive cross-backend property lives in the test suite;
        // this is the cheap runtime guard against a lowering bug
        // serving wrong bits.
        let inp = spec.io.input;
        let (lo, hi) = (inp.min_raw(), inp.max_raw());
        let step = ((hi - lo) / AUDIT_PROBES).max(1) as usize;
        for raw in (lo..=hi).step_by(step) {
            let got = pipeline.eval(Fx::from_raw(raw, inp)).raw();
            let want = kernel.eval_raw(raw);
            if got != want {
                return Err(BackendError::internal(format!(
                    "hw lowering of '{spec}' diverges from the golden kernel at raw \
                     {raw}: pipeline {got} vs golden {want}"
                )));
            }
        }
        // Entry API, not insert: a concurrent ensure for the same spec
        // may have won the race while we audited — keep its (possibly
        // already warm) streams instead of replacing them with cold
        // ones.
        self.entries.write().unwrap().entry(*spec).or_insert_with(|| {
            Arc::new(HwEntry {
                pipeline: Arc::new(pipeline),
                streams: Mutex::new(HashMap::new()),
            })
        });
        Ok(())
    }

    fn eval_raw(
        &self,
        spec: &MethodSpec,
        input: &[i64],
        out: &mut [i64],
    ) -> Result<EvalStats, BackendError> {
        super::check_slice_lens(input, out)?;
        let entry = self.entry(spec)?;
        if input.is_empty() {
            return Ok(EvalStats::default());
        }
        let inp = spec.io.input;
        let fxs: Vec<Fx> = input.iter().map(|&raw| Fx::from_raw(raw, inp)).collect();
        // One stream per calling thread (see HwEntry): take the state
        // out of the map so concurrent workers feed their own streams
        // in parallel — the map lock is held only for the lookup and
        // the put-back, never across the simulation itself.
        let tid = std::thread::current().id();
        let mut stream = entry
            .streams
            .lock()
            .unwrap()
            .remove(&tid)
            .unwrap_or_else(|| entry.pipeline.stream_state());
        let fed = entry.pipeline.feed(&mut stream, &fxs);
        entry.streams.lock().unwrap().insert(tid, stream);
        for (slot, y) in out.iter_mut().zip(&fed.outputs) {
            *slot = y.raw();
        }
        Ok(EvalStats { sim_cycles: fed.cycles, ..EvalStats::default() })
    }

    fn native_stream(
        &self,
        spec: &MethodSpec,
    ) -> Result<Option<Box<dyn EvalStream>>, BackendError> {
        let entry = self.entry(spec)?;
        let st = entry.pipeline.stream_state();
        Ok(Some(Box::new(HwStream {
            input: spec.io.input,
            pipeline: entry.pipeline.clone(),
            st,
        })))
    }
}

/// A private warm pipeline stream: the session-stateful substrate the
/// coordinator's streaming mode hands each client session. Unlike the
/// per-thread serving streams above, this state is owned by exactly
/// one session, so fill latency is paid once per *session* no matter
/// which pulses land on which batches.
struct HwStream {
    input: QFormat,
    pipeline: Arc<Pipeline>,
    st: StreamState,
}

impl EvalStream for HwStream {
    fn delay(&self) -> usize {
        // Outputs lag inputs by the register stages between them: the
        // pulse-model delay of this datapath.
        self.pipeline.latency() - 1
    }

    fn feed(
        &mut self,
        input: &[i64],
        out: &mut Vec<i64>,
    ) -> Result<EvalStats, BackendError> {
        let fxs: Vec<Fx> = input.iter().map(|&raw| Fx::from_raw(raw, self.input)).collect();
        let fed = self.pipeline.feed(&mut self.st, &fxs);
        out.extend(fed.outputs.iter().map(|y| y.raw()));
        Ok(EvalStats { sim_cycles: fed.cycles, ..EvalStats::default() })
    }
}

impl CostProbe for HwBackend {
    /// Measured cost off the lowered pipeline: depth and critical path
    /// read from the stages, area from the unit library summed over
    /// the instantiated blocks, and steady-state cycles/element from a
    /// two-batch streaming probe on a private stream (the serving
    /// stream is not disturbed). The lowering audit in `ensure` runs
    /// first, so a spec the block diagrams cannot express errors
    /// `unknown_spec` here — callers that fall back to the analytic
    /// model must label the point [`CostSource::Analytic`].
    fn probe_cost(&self, spec: &MethodSpec) -> Result<DesignCost, BackendError> {
        self.ensure(spec)?;
        let entry = self.entry(spec)?;
        let pipe = &entry.pipeline;
        let lib = UnitLibrary::default();
        let inp = spec.io.input;
        let step = (2 * inp.max_raw() / COST_PROBE_BATCH as i64).max(1);
        let probe: Vec<Fx> = (0..COST_PROBE_BATCH)
            .map(|i| Fx::from_raw((-inp.max_raw() + i as i64 * step).min(inp.max_raw()), inp))
            .collect();
        let mut st = pipe.stream_state();
        let _fill = pipe.feed(&mut st, &probe);
        let steady = pipe.feed(&mut st, &probe);
        Ok(DesignCost {
            source: CostSource::Measured,
            latency_cycles: pipe.latency() as u32,
            stage_delay_fo4: pipe.critical_delay(&lib),
            area_ge: pipe.area_ge(&lib),
            cycles_per_element: steady.cycles as f64 / probe.len() as f64,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::approx::{IoSpec, MethodId, MethodParams};
    use crate::backend::{golden_kernel, ErrorCode};

    #[test]
    fn ensure_lowers_and_eval_reports_cycles() {
        let b = HwBackend::new();
        let spec = MethodSpec::table1(MethodId::Pwl);
        b.ensure(&spec).unwrap();
        let pipe = b.pipeline(&spec).expect("ensured pipeline retained");
        let input: Vec<i64> = (-8..8).map(|i| i * 500).collect();
        let mut out = vec![0i64; input.len()];
        let stats = b.eval_raw(&spec, &input, &mut out).unwrap();
        // Cold stream: fill latency + one cycle per element.
        assert_eq!(stats.sim_cycles, (pipe.latency() + input.len() - 1) as u64);
        // Bit-exact against the golden kernel.
        let kernel = golden_kernel(&spec).unwrap();
        for (&raw, &y) in input.iter().zip(&out) {
            assert_eq!(y, kernel.eval_raw(raw), "raw {raw}");
        }
        // Warm stream: the next batch overlaps the previous drain and
        // costs exactly one cycle per element — with identical bits.
        let mut out2 = vec![0i64; input.len()];
        let stats2 = b.eval_raw(&spec, &input, &mut out2).unwrap();
        assert_eq!(stats2.sim_cycles, input.len() as u64);
        assert_eq!(out, out2);
    }

    #[test]
    fn concurrent_threads_get_private_streams() {
        // Regression: with one shared Mutex<StreamState> per spec, two
        // concurrent shards interleaved feeds into the same register
        // file — only the globally-first feed was cold, so a thread's
        // own first batch could report warm `N` cycles and the
        // per-shard cycle bookkeeping was corrupted. Per-thread streams
        // restore the invariant: EVERY thread's first feed pays the
        // fill latency, every later same-thread feed costs exactly N,
        // and all bits stay golden.
        let b = Arc::new(HwBackend::new());
        let spec = MethodSpec::table1(MethodId::Pwl);
        b.ensure(&spec).unwrap();
        let latency = b.pipeline(&spec).unwrap().latency();
        let kernel = golden_kernel(&spec).unwrap();
        let barrier = Arc::new(std::sync::Barrier::new(4));
        std::thread::scope(|s| {
            for t in 0..4i64 {
                let b = b.clone();
                let kernel = kernel.clone();
                let barrier = barrier.clone();
                s.spawn(move || {
                    let input: Vec<i64> = (0..16).map(|i| (i + t) * 321 - 2500).collect();
                    let mut out = vec![0i64; input.len()];
                    barrier.wait();
                    for batch in 0..3 {
                        let stats = b.eval_raw(&spec, &input, &mut out).unwrap();
                        let want = if batch == 0 {
                            (latency + input.len() - 1) as u64
                        } else {
                            input.len() as u64
                        };
                        assert_eq!(stats.sim_cycles, want, "thread {t} batch {batch}");
                        for (&raw, &y) in input.iter().zip(&out) {
                            assert_eq!(y, kernel.eval_raw(raw), "thread {t} raw {raw}");
                        }
                    }
                });
            }
        });
    }

    #[test]
    fn native_stream_is_private_and_reports_pipeline_delay() {
        let b = HwBackend::new();
        let spec = MethodSpec::table1(MethodId::Velocity);
        b.ensure(&spec).unwrap();
        let pipe = b.pipeline(&spec).unwrap();
        let mut stream = b.native_stream(&spec).unwrap().expect("hw has native streams");
        assert_eq!(stream.delay(), pipe.latency() - 1);
        let kernel = golden_kernel(&spec).unwrap();
        let pulses: Vec<Vec<i64>> =
            (0..4).map(|p| (0..8).map(|i| (p * 8 + i) * 400 - 6000).collect()).collect();
        let mut got = Vec::new();
        let mut cycles = 0u64;
        for (k, pulse) in pulses.iter().enumerate() {
            let before = got.len();
            let stats = stream.feed(pulse, &mut got).unwrap();
            assert_eq!(got.len() - before, pulse.len());
            cycles += stats.sim_cycles;
            // Fill latency charged to the first pulse only.
            let want = if k == 0 { pipe.latency() as u64 + 7 } else { 8 };
            assert_eq!(stats.sim_cycles, want, "pulse {k}");
        }
        // Total: stages + pulses·P − 1 — the session delay-accounting
        // identity the streaming tests assert end to end.
        assert_eq!(cycles, (pipe.latency() + 4 * 8 - 1) as u64);
        for (&raw, &y) in pulses.iter().flatten().zip(&got) {
            assert_eq!(y, kernel.eval_raw(raw));
        }
        // Opening the stream did not warm the serving streams: this
        // thread's next eval_raw still pays a cold fill.
        let input = [0i64; 4];
        let mut out = [0i64; 4];
        let stats = b.eval_raw(&spec, &input, &mut out).unwrap();
        assert_eq!(stats.sim_cycles, (pipe.latency() + 3) as u64);
    }

    #[test]
    fn bogus_specs_surface_through_ensure_as_typed_errors() {
        // A spec that is invalid on EVERY backend reports as such,
        // identically to the golden backend (not as an hw-specific
        // limitation); the "unsupported by hw backend" wording is
        // reserved for pipeline_for's structural guards (pinned by the
        // hw::mod tests).
        let b = HwBackend::new();
        let bogus = MethodSpec {
            params: MethodParams::Taylor { step: 1.0 / 8.0, terms: 7 },
            io: IoSpec::table1(),
            domain: 6.0,
        };
        let err = b.ensure(&bogus).unwrap_err();
        assert_eq!(err.code, ErrorCode::UnknownSpec);
        assert!(err.message.contains("invalid spec"), "{err}");
        // The cost probe routes through ensure, so it reports (not
        // measures) the same typed rejection.
        let err = b.probe_cost(&bogus).unwrap_err();
        assert_eq!(err.code, ErrorCode::UnknownSpec);
    }

    #[test]
    fn unensured_spec_is_unknown_and_empty_input_is_benign() {
        let b = HwBackend::new();
        let spec = MethodSpec::table1(MethodId::Lambert);
        let mut out = [0i64; 1];
        let err = b.eval_raw(&spec, &[0], &mut out).unwrap_err();
        assert_eq!(err.code, ErrorCode::UnknownSpec);
        b.ensure(&spec).unwrap();
        let stats = b.eval_raw(&spec, &[], &mut []).unwrap();
        assert_eq!(stats.sim_cycles, 0);
        // ensure is idempotent (second call hits the pipeline cache).
        b.ensure(&spec).unwrap();
    }

    #[test]
    fn probe_cost_measures_the_lowered_pipeline() {
        let b = HwBackend::new();
        let spec = MethodSpec::table1(MethodId::Velocity);
        let cost = b.probe_cost(&spec).unwrap();
        let pipe = b.pipeline(&spec).unwrap();
        let lib = UnitLibrary::default();
        assert_eq!(cost.source, CostSource::Measured);
        assert_eq!(cost.latency_cycles as usize, pipe.latency());
        assert_eq!(cost.stage_delay_fo4, pipe.critical_delay(&lib));
        assert_eq!(cost.area_ge, pipe.area_ge(&lib));
        // Steady-state streaming: the §IV.H one-result-per-cycle claim,
        // measured rather than assumed.
        assert_eq!(cost.cycles_per_element, 1.0);
        // The probe ran on a private stream: the serving stream is
        // still cold (first eval pays the fill latency).
        let input = [0i64; 4];
        let mut out = [0i64; 4];
        let stats = b.eval_raw(&spec, &input, &mut out).unwrap();
        assert_eq!(stats.sim_cycles, (pipe.latency() + 3) as u64);
    }
}
