//! The unified execution layer: every way this crate can *run* a tanh
//! design point, behind one API.
//!
//! The paper's point is comparative — the same configuration realized
//! by different implementations (the arithmetic models of §III vs the
//! §IV block diagrams vs an accelerator runtime). This module makes
//! that comparison operational: [`EvalBackend`] is the single trait the
//! coordinator's workers, the CLI (`--backend golden|hw|pjrt`), the
//! error sweeps and the scenario harness all execute through, and
//! three implementations port the crate's formerly siloed execution
//! paths onto it:
//!
//! | backend                  | substrate                                    | fidelity                      | latency model |
//! |--------------------------|----------------------------------------------|-------------------------------|---------------|
//! | [`GoldenBackend`]        | compiled integer kernels (shared [`Registry`])| bit-exact (the reference)     | none          |
//! | [`HwBackend`]            | cycle-level Fig 3/4/5 datapaths ([`crate::hw`])| bit-exact *by construction*  | simulated cycles per batch |
//! | [`PjrtBackend`]          | PJRT-executed AOT graphs ([`crate::runtime`]) | f32 graphs, ±tolerance        | none          |
//!
//! ## The contract
//!
//! - [`EvalBackend::availability`] answers "could this backend serve at
//!   all, in this build, on this machine" — [`PjrtBackend`] reports
//!   [`Availability::Unavailable`] under the [`crate::runtime::xla_shim`]
//!   stub instead of being unreachable code. The coordinator fails fast
//!   at startup on an unavailable backend (`backend_unavailable`), it
//!   never discovers it request-by-request.
//! - [`EvalBackend::ensure`] prepares one spec (compile the kernel,
//!   lower the datapath, preload the graph) and is where per-spec
//!   support surfaces: a spec the Fig 3/4/5 block diagrams cannot
//!   express errors here with an "unsupported by hw backend" message.
//!   [`Coordinator::start`](crate::coordinator::Coordinator::start)
//!   ensures every served spec before accepting traffic.
//! - [`EvalBackend::eval_raw`] is the hot path: raw fixed-point words
//!   in (`spec.io.input`), raw words out (`spec.io.output`), plus
//!   [`EvalStats`] — the hw backend reports the simulated cycle count
//!   a batch occupied the pipeline, which the serve metrics aggregate
//!   into the `sim_cycles` column of `BENCH_serve.json`.
//! - Errors are typed ([`BackendError`]) with the stable wire codes the
//!   net protocol exposes (see [`crate::coordinator`]'s net docs):
//!   `unknown_spec`, `backend_unavailable`, `bad_request`,
//!   `overloaded`, `internal`.
//!
//! f32 traffic (the net protocol, the scenario traces) crosses the raw
//! boundary through one pair of conversions ([`quantize_input`] /
//! [`dequantize_output`]), shared with the scenario verifier's
//! [`kernel_eval_f32`] so the serving path and its checker cannot
//! diverge in conversion semantics.
//!
//! Beyond execution, backends answer *cost* questions through
//! [`CostProbe`] (module [`cost`]): golden replies with the analytic
//! §IV complexity model, hw with latency/critical-path/area measured
//! off the lowered pipeline — each answer labeled with a typed
//! [`CostSource`] so the explorer's frontier rows can never pass an
//! analytic fallback off as a measurement.

mod cost;
mod golden;
mod hw_backend;
mod pjrt;

pub use cost::{analytic_cost, CostProbe, CostSource, DesignCost};
pub use golden::GoldenBackend;
pub use hw_backend::HwBackend;
pub use pjrt::PjrtBackend;

use std::fmt;
use std::sync::Arc;

use crate::approx::{CompiledKernel, MethodSpec, Registry};
use crate::fixed::{Fx, QFormat};

/// The backend registry, in CLI order (`--backend` spellings).
pub const BACKEND_NAMES: [&str; 3] = ["golden", "hw", "pjrt"];

/// Stable error codes crossing the execution/serving boundary. These
/// are the wire codes of the net protocol's `{"ok": false, "code": …}`
/// responses — renaming one is a protocol break.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum ErrorCode {
    /// The spec is well-formed but this coordinator/backend does not
    /// serve or support it.
    UnknownSpec,
    /// The backend cannot run at all in this build/environment (e.g.
    /// PJRT under the xla shim, missing AOT artifacts).
    BackendUnavailable,
    /// The request itself is malformed: bad grammar/JSON, empty
    /// values, oversized for the compiled batch.
    BadRequest,
    /// Load shedding: the routed shard queue is full — retry later.
    Overloaded,
    /// Anything unexpected (execution faults, wedged workers).
    Internal,
}

impl ErrorCode {
    /// The stable wire spelling.
    pub fn as_str(self) -> &'static str {
        match self {
            ErrorCode::UnknownSpec => "unknown_spec",
            ErrorCode::BackendUnavailable => "backend_unavailable",
            ErrorCode::BadRequest => "bad_request",
            ErrorCode::Overloaded => "overloaded",
            ErrorCode::Internal => "internal",
        }
    }

    /// The stable binary-frame status byte (net protocol; 0 is
    /// reserved for "ok"). Like [`ErrorCode::as_str`], renumbering is
    /// a protocol break.
    pub fn as_u8(self) -> u8 {
        match self {
            ErrorCode::UnknownSpec => 1,
            ErrorCode::BackendUnavailable => 2,
            ErrorCode::BadRequest => 3,
            ErrorCode::Overloaded => 4,
            ErrorCode::Internal => 5,
        }
    }

    /// Decodes a binary status byte (`None` for 0/"ok" and unknown
    /// values).
    pub fn from_u8(b: u8) -> Option<ErrorCode> {
        match b {
            1 => Some(ErrorCode::UnknownSpec),
            2 => Some(ErrorCode::BackendUnavailable),
            3 => Some(ErrorCode::BadRequest),
            4 => Some(ErrorCode::Overloaded),
            5 => Some(ErrorCode::Internal),
            _ => None,
        }
    }
}

impl fmt::Display for ErrorCode {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.as_str())
    }
}

/// A typed backend failure: stable code + human message.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct BackendError {
    /// Stable wire code.
    pub code: ErrorCode,
    /// Human-readable detail.
    pub message: String,
}

impl BackendError {
    /// Builds an error with an explicit code.
    pub fn new(code: ErrorCode, message: impl Into<String>) -> BackendError {
        BackendError { code, message: message.into() }
    }

    /// `unknown_spec` convenience.
    pub fn unknown_spec(message: impl Into<String>) -> BackendError {
        BackendError::new(ErrorCode::UnknownSpec, message)
    }

    /// `backend_unavailable` convenience.
    pub fn unavailable(message: impl Into<String>) -> BackendError {
        BackendError::new(ErrorCode::BackendUnavailable, message)
    }

    /// `bad_request` convenience.
    pub fn bad_request(message: impl Into<String>) -> BackendError {
        BackendError::new(ErrorCode::BadRequest, message)
    }

    /// `internal` convenience.
    pub fn internal(message: impl Into<String>) -> BackendError {
        BackendError::new(ErrorCode::Internal, message)
    }
}

impl fmt::Display for BackendError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}: {}", self.code.as_str(), self.message)
    }
}

impl std::error::Error for BackendError {}

/// Whether a backend can serve at all in this build/environment.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Availability {
    /// The backend is operational.
    Available,
    /// The backend cannot run; the reason is user-facing (what is
    /// missing and how to get it).
    Unavailable(String),
}

impl Availability {
    /// True when operational.
    pub fn is_available(&self) -> bool {
        matches!(self, Availability::Available)
    }
}

/// Per-call execution observables a backend can report beyond the
/// outputs themselves.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct EvalStats {
    /// Simulated hardware cycles the call occupied the datapath
    /// (first issue to last retire). Zero for backends without a cycle
    /// model (golden kernels, PJRT).
    pub sim_cycles: u64,
    /// True when the call ran on the SWAR packed-lane kernel path
    /// ([`crate::approx::CompiledKernel::eval_slice_packed`] with a
    /// qualifying [`crate::approx::CompiledKernel::lane_width`]); the
    /// coordinator aggregates this into the `packed_batches` serve
    /// metric.
    pub packed: bool,
}

/// One execution path for tanh design points — the API every consumer
/// (coordinator workers, CLI, sweeps, scenario harness) drives.
///
/// Implementations are shard-shareable (`Send + Sync`): per-spec state
/// is built by [`EvalBackend::ensure`] and read concurrently by
/// [`EvalBackend::eval_raw`].
pub trait EvalBackend: Send + Sync + 'static {
    /// The backend's CLI/report name (`golden`, `hw`, `pjrt`).
    fn name(&self) -> &'static str;

    /// Whether this backend can serve at all in this build (checked
    /// once at coordinator startup, before any `ensure`).
    fn availability(&self) -> Availability;

    /// Prepares a spec for execution: compile its kernel, lower its
    /// datapath, or preload its AOT graph. Must be called (and
    /// succeed) before `eval_raw` sees the spec. Errors:
    /// `unknown_spec` for specs this backend cannot express,
    /// `backend_unavailable` when the substrate is missing.
    fn ensure(&self, spec: &MethodSpec) -> Result<(), BackendError>;

    /// Evaluates a slice of raw input words (`spec.io.input` encoding)
    /// into `out` (`spec.io.output` encoding); `out.len()` must equal
    /// `input.len()`. Only specs previously `ensure`d are valid.
    fn eval_raw(
        &self,
        spec: &MethodSpec,
        input: &[i64],
        out: &mut [i64],
    ) -> Result<EvalStats, BackendError>;

    /// The exact slice length `eval_raw` requires, when the substrate
    /// is fixed-shape (PJRT graphs are compiled per batch shape).
    /// `None` (the default) means any length is accepted. The
    /// coordinator aligns its batcher to this at startup, so a shape
    /// mismatch is impossible rather than a per-batch failure.
    fn fixed_batch(&self) -> Option<usize> {
        None
    }

    /// Backend-native stateful stream for one spec, if this backend
    /// has one — the hook behind [`open_stream`]. The hw backend
    /// returns a stream wrapping a private pipeline [`crate::hw::
    /// StreamState`] (fill latency paid once per stream, registers warm
    /// across pulses, `delay() == latency − 1`); the default `Ok(None)`
    /// lets [`open_stream`] fall back to a stateless zero-delay adapter
    /// over `eval_raw`. Only specs previously `ensure`d are valid.
    fn native_stream(
        &self,
        _spec: &MethodSpec,
    ) -> Result<Option<Box<dyn EvalStream>>, BackendError> {
        Ok(None)
    }
}

/// A stateful evaluation stream — the substrate behind the
/// coordinator's streaming sessions ([`crate::coordinator`]). Repeated
/// [`EvalStream::feed`] calls see *continuing* state, so a long
/// sequence split into pulses pays any fill cost once, not once per
/// pulse. Streams are single-owner (`Send`, not `Sync`): the session
/// layer pins each one to a stable shard worker.
pub trait EvalStream: Send {
    /// How many trailing output elements lag behind the fed input at
    /// any pulse boundary (the pipeline-depth delay of tract's pulse
    /// model). `feed` itself returns every element the substrate
    /// produced — for the hw backend that includes speculatively
    /// drained in-flight slots, so the *session* layer withholds the
    /// last `delay()` elements until close/flush to keep pulse replies
    /// causal. Zero for stateless substrates.
    fn delay(&self) -> usize;

    /// Feeds one pulse of raw input words (`spec.io.input` encoding),
    /// appending the produced output words (`spec.io.output` encoding)
    /// to `out` — exactly `input.len()` of them, in order. The
    /// returned [`EvalStats`] cycle count is incremental: a warm hw
    /// stream reports `input.len()` cycles per pulse, with the
    /// `latency − 1` fill charged to the first pulse only.
    fn feed(&mut self, input: &[i64], out: &mut Vec<i64>)
        -> Result<EvalStats, BackendError>;
}

/// Stateless [`EvalStream`] adapter: every pulse is an independent
/// `eval_raw` call, zero delay. What [`open_stream`] hands out for
/// backends without a native stream (golden kernels are pure functions
/// — "state" would buy nothing).
struct StatelessStream {
    backend: Arc<dyn EvalBackend>,
    spec: MethodSpec,
}

impl EvalStream for StatelessStream {
    fn delay(&self) -> usize {
        0
    }

    fn feed(
        &mut self,
        input: &[i64],
        out: &mut Vec<i64>,
    ) -> Result<EvalStats, BackendError> {
        let mut buf = vec![0i64; input.len()];
        let stats = self.backend.eval_raw(&self.spec, input, &mut buf)?;
        out.extend_from_slice(&buf);
        Ok(stats)
    }
}

/// Opens a stateful evaluation stream for `spec` on `backend`: the
/// backend's native stream when it has one
/// ([`EvalBackend::native_stream`]), a stateless zero-delay `eval_raw`
/// adapter otherwise. Free function (not a trait method) because the
/// fallback must hold the backend beyond this call's borrow — callers
/// already share backends as `Arc<dyn EvalBackend>`.
pub fn open_stream(
    backend: &Arc<dyn EvalBackend>,
    spec: &MethodSpec,
) -> Result<Box<dyn EvalStream>, BackendError> {
    if let Some(native) = backend.native_stream(spec)? {
        return Ok(native);
    }
    Ok(Box::new(StatelessStream { backend: backend.clone(), spec: *spec }))
}

/// Shared `eval_raw` precondition: `out` must be exactly as long as
/// `input`. One helper so the trait-level contract (and its error
/// message) lives in one place across every backend.
pub(crate) fn check_slice_lens(input: &[i64], out: &[i64]) -> Result<(), BackendError> {
    if input.len() != out.len() {
        return Err(BackendError::bad_request(format!(
            "output slice of {} for {} inputs",
            out.len(),
            input.len()
        )));
    }
    Ok(())
}

/// Quantizes f32 activations to raw input words with the golden
/// convention: `Fx::from_f64` (round half away from zero, saturating),
/// matching the scalar datapath bit-for-bit.
pub fn quantize_input(flat: &[f32], fmt: QFormat) -> Vec<i64> {
    flat.iter().map(|&v| Fx::from_f64(v as f64, fmt).raw()).collect()
}

/// Converts raw output words back to f32. Output raws are ≤ 16 bits,
/// so `raw × ulp` is exact in f32.
pub fn dequantize_output(raws: &[i64], fmt: QFormat) -> Vec<f32> {
    let inv = fmt.ulp() as f32;
    raws.iter().map(|&r| r as f32 * inv).collect()
}

/// Evaluates f32 activations through a backend with the shared
/// quantization conventions — the coordinator worker's execute path.
pub fn eval_f32(
    backend: &dyn EvalBackend,
    spec: &MethodSpec,
    flat: &[f32],
) -> Result<(Vec<f32>, EvalStats), BackendError> {
    let raws = quantize_input(flat, spec.io.input);
    let mut out_raws = vec![0i64; raws.len()];
    let stats = backend.eval_raw(spec, &raws, &mut out_raws)?;
    Ok((dequantize_output(&out_raws, spec.io.output), stats))
}

/// Evaluates a flat f32 slice through a compiled kernel with the same
/// conversions as [`eval_f32`]. Used by the scenario verifier
/// ([`crate::bench::scenario::GoldenVerifier`]), whose kernels
/// deliberately bypass the shared cache — sharing the conversion
/// helpers here is what keeps the serving path and its checker from
/// diverging in quantization semantics.
pub fn kernel_eval_f32(kernel: &CompiledKernel, flat: &[f32]) -> Vec<f32> {
    let raws = quantize_input(flat, kernel.input());
    let mut out_raws = vec![0i64; raws.len()];
    kernel.eval_slice_raw(&raws, &mut out_raws);
    dequantize_output(&out_raws, kernel.output())
}

/// Resolves a CLI backend name to an instance. `batch` is the
/// fixed shape PJRT graphs were AOT'd for (ignored by the slice-based
/// golden/hw backends). Construction never fails on a missing
/// substrate — an unusable backend is returned with `Unavailable`
/// availability and rejected by the coordinator at startup, so
/// `--backend pjrt` under the shim fails fast with
/// `backend_unavailable`, not a panic.
pub fn by_name(name: &str, batch: usize) -> Result<Arc<dyn EvalBackend>, String> {
    match name {
        "golden" => Ok(Arc::new(GoldenBackend::new())),
        "hw" => Ok(Arc::new(HwBackend::new())),
        "pjrt" => Ok(Arc::new(PjrtBackend::with_default_artifacts(batch))),
        other => Err(format!("unknown backend '{other}' (have: {})", BACKEND_NAMES.join("|"))),
    }
}

/// Shared `ensure` helper: resolves the golden kernel for a spec
/// through the process-wide [`Registry`] (the bit-exact reference the
/// hw backend cross-checks against). `MethodSpec` fields are public,
/// so the spec is re-validated first — a structurally invalid spec
/// (e.g. a Taylor term count the constructors `assert!` on) surfaces
/// as a typed `unknown_spec` error at ensure time, never as a
/// constructor panic mid-serving.
pub(crate) fn golden_kernel(spec: &MethodSpec) -> Result<Arc<CompiledKernel>, BackendError> {
    MethodSpec::new(spec.params, spec.io, spec.domain)
        .map_err(|e| BackendError::unknown_spec(format!("invalid spec '{spec}': {e}")))?;
    Ok(Registry::global().kernel(spec))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::approx::MethodId;

    #[test]
    fn error_codes_have_stable_wire_spellings() {
        let want = [
            (ErrorCode::UnknownSpec, "unknown_spec"),
            (ErrorCode::BackendUnavailable, "backend_unavailable"),
            (ErrorCode::BadRequest, "bad_request"),
            (ErrorCode::Overloaded, "overloaded"),
            (ErrorCode::Internal, "internal"),
        ];
        for (code, s) in want {
            assert_eq!(code.as_str(), s);
        }
        let e = BackendError::unavailable("no PJRT");
        assert_eq!(e.to_string(), "backend_unavailable: no PJRT");
    }

    #[test]
    fn by_name_builds_all_registered_backends() {
        for name in BACKEND_NAMES {
            let b = by_name(name, 64).unwrap_or_else(|e| panic!("{name}: {e}"));
            assert_eq!(b.name(), name);
        }
        assert!(by_name("tpu", 64).unwrap_err().contains("golden|hw|pjrt"));
    }

    #[test]
    fn error_codes_round_trip_through_the_binary_status_byte() {
        let all = [
            ErrorCode::UnknownSpec,
            ErrorCode::BackendUnavailable,
            ErrorCode::BadRequest,
            ErrorCode::Overloaded,
            ErrorCode::Internal,
        ];
        for code in all {
            assert_ne!(code.as_u8(), 0, "0 is the binary-frame ok status");
            assert_eq!(ErrorCode::from_u8(code.as_u8()), Some(code));
        }
        assert_eq!(ErrorCode::from_u8(0), None);
        assert_eq!(ErrorCode::from_u8(200), None);
    }

    #[test]
    fn f32_conversions_round_trip_through_the_golden_kernel() {
        // eval_f32 over a backend must agree bit-for-bit with
        // kernel_eval_f32 over the same spec's kernel: one conversion
        // convention, two entry points.
        let spec = MethodSpec::table1(MethodId::Pwl);
        let backend = GoldenBackend::new();
        backend.ensure(&spec).unwrap();
        let kernel = golden_kernel(&spec).unwrap();
        let flat = [0.5f32, -0.5, 0.0, 3.25, -6.5, 0.001];
        let (via_backend, stats) = eval_f32(&backend, &spec, &flat).unwrap();
        let via_kernel = kernel_eval_f32(&kernel, &flat);
        assert_eq!(stats.sim_cycles, 0, "golden kernels have no cycle model");
        for (a, b) in via_backend.iter().zip(&via_kernel) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }
}
