//! PJRT-backed execution: the AOT'd activation graphs run by the
//! [`crate::runtime`] engine — cleanly `Unavailable` when the `xla`
//! bindings are stubbed by [`crate::runtime::xla_shim`].

use std::collections::HashSet;
use std::path::Path;
use std::sync::{mpsc, Mutex, RwLock};

use crate::approx::{MethodId, MethodSpec};
use crate::fixed::Fx;
use crate::runtime::{ArtifactDir, Engine, TensorValue};

use super::{Availability, BackendError, EvalBackend, EvalStats};

/// Jobs crossing into the engine thread. The PJRT client and
/// executables are not `Send` (the `xla` crate wraps raw pointers
/// internally), so a single dedicated thread owns them and serves jobs
/// over a channel — one submission context, many logical clients,
/// mirroring how accelerator command queues actually work. (This
/// engine-thread pattern used to live in `runtime::EngineServer`; the
/// backend owns it now that PJRT execution has exactly one consumer.)
enum Job {
    Execute {
        name: String,
        inputs: Vec<TensorValue>,
        reply: mpsc::Sender<Result<Vec<TensorValue>, String>>,
    },
    Preload {
        names: Vec<String>,
        reply: mpsc::Sender<Result<(), String>>,
    },
}

/// The live half of a [`PjrtBackend`]: channel to the engine thread.
struct EngineHandle {
    tx: Mutex<mpsc::Sender<Job>>,
    platform: String,
}

impl EngineHandle {
    fn spawn(artifacts: ArtifactDir) -> Result<EngineHandle, String> {
        let (tx, rx) = mpsc::channel::<Job>();
        let (init_tx, init_rx) = mpsc::channel::<Result<String, String>>();
        std::thread::Builder::new()
            .name("pjrt-engine".into())
            .spawn(move || {
                let engine = match Engine::cpu(artifacts) {
                    Ok(e) => {
                        let _ = init_tx.send(Ok(e.platform()));
                        e
                    }
                    Err(e) => {
                        let _ = init_tx.send(Err(e.to_string()));
                        return;
                    }
                };
                while let Ok(job) = rx.recv() {
                    match job {
                        Job::Execute { name, inputs, reply } => {
                            let result = engine
                                .load(&name)
                                .and_then(|g| g.execute(&inputs))
                                .map_err(|e| e.to_string());
                            let _ = reply.send(result);
                        }
                        Job::Preload { names, reply } => {
                            let mut result = Ok(());
                            for name in names {
                                if let Err(e) = engine.load(&name) {
                                    result = Err(e.to_string());
                                    break;
                                }
                            }
                            let _ = reply.send(result);
                        }
                    }
                }
            })
            .map_err(|e| format!("spawning engine thread: {e}"))?;
        let platform = init_rx
            .recv()
            .map_err(|_| "engine thread died during init".to_string())??;
        Ok(EngineHandle { tx: Mutex::new(tx), platform })
    }

    fn preload(&self, names: Vec<String>) -> Result<(), String> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .lock()
            .unwrap()
            .send(Job::Preload { names, reply })
            .map_err(|_| "engine thread gone".to_string())?;
        rx.recv().map_err(|_| "engine thread gone".to_string())?
    }

    fn run_f32(&self, name: &str, input: Vec<f32>) -> Result<Vec<f32>, String> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .lock()
            .unwrap()
            .send(Job::Execute { name: name.to_string(), inputs: vec![TensorValue::F32(input)], reply })
            .map_err(|_| "engine thread gone".to_string())?;
        let out = rx.recv().map_err(|_| "engine thread gone".to_string())??;
        out.into_iter()
            .next()
            .ok_or_else(|| "empty tuple".to_string())?
            .as_f32()
            .map(|v| v.to_vec())
            .map_err(|e| e.to_string())
    }
}

/// The PJRT backend: each Table I method maps to one AOT'd activation
/// graph (`tanh_<method>_<batch>`, compiled for a fixed batch shape),
/// executed on the engine thread.
///
/// Construction never fails and never panics: when the `xla` bindings
/// are stubbed ([`crate::runtime::xla_shim`]) or the artifact
/// directory is missing, the backend carries
/// [`Availability::Unavailable`] with the reason, every
/// `ensure`/`eval_raw` returns a `backend_unavailable` error, and the
/// coordinator refuses to start on it — `--backend pjrt` fails fast
/// with a clean message instead of dying mid-request.
///
/// Fidelity: the graphs compute in f32 without output quantization, so
/// this backend is **not** bit-exact against the golden kernels —
/// outputs are quantized to `spec.io.output` on the way back and the
/// scenario harness verifies them within a tolerance band, never
/// `Verify::Exact`. Only the six Table I specs have AOT'd graphs; any
/// other spec is `unknown_spec`.
pub struct PjrtBackend {
    engine: Result<EngineHandle, String>,
    batch: usize,
    /// Specs admitted by `ensure` (graph preloaded). `eval_raw` is as
    /// strict as the other backends: an unensured spec is a typed
    /// `unknown_spec` error, never a silent fall-through to the
    /// method's Table I graph.
    ensured: RwLock<HashSet<MethodSpec>>,
}

impl PjrtBackend {
    /// Opens `artifacts` and spawns the engine thread; failures are
    /// recorded as unavailability, not returned.
    pub fn new(artifacts: &Path, batch: usize) -> PjrtBackend {
        let engine = ArtifactDir::open(artifacts)
            .map_err(|e| e.to_string())
            .and_then(EngineHandle::spawn);
        PjrtBackend { engine, batch, ensured: RwLock::new(HashSet::new()) }
    }

    /// [`PjrtBackend::new`] over the default artifact path.
    pub fn with_default_artifacts(batch: usize) -> PjrtBackend {
        PjrtBackend::new(&ArtifactDir::default_path(), batch)
    }

    /// Artifact name for a method's activation graph.
    pub fn artifact_name(method: MethodId, batch: usize) -> String {
        let key = match method {
            MethodId::Pwl => "pwl",
            MethodId::TaylorQuadratic => "taylor1",
            MethodId::TaylorCubic => "taylor2",
            MethodId::CatmullRom => "catmull_rom",
            MethodId::Velocity => "velocity",
            MethodId::Lambert => "lambert",
        };
        format!("tanh_{key}_{batch}")
    }

    /// PJRT platform name, when the engine is up (diagnostics).
    pub fn platform(&self) -> Option<&str> {
        self.engine.as_ref().ok().map(|e| e.platform.as_str())
    }

    /// The fixed batch shape the graphs were AOT'd for.
    pub fn batch(&self) -> usize {
        self.batch
    }

    /// Executes an arbitrary AOT graph by artifact name — the
    /// bench/diagnostics escape hatch (e.g. the `ref` graph or the
    /// LSTM models, which have no spec). Serving goes through
    /// [`EvalBackend::eval_raw`].
    pub fn run_graph_f32(&self, name: &str, input: Vec<f32>) -> Result<Vec<f32>, String> {
        self.engine.as_ref().map_err(|e| e.clone())?.run_f32(name, input)
    }

    fn engine(&self) -> Result<&EngineHandle, BackendError> {
        self.engine.as_ref().map_err(|reason| {
            BackendError::unavailable(format!("pjrt backend unavailable: {reason}"))
        })
    }
}

impl EvalBackend for PjrtBackend {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn availability(&self) -> Availability {
        match &self.engine {
            Ok(_) => Availability::Available,
            Err(reason) => Availability::Unavailable(format!(
                "{reason} (build with the xla bindings linked and run `make artifacts`)"
            )),
        }
    }

    fn ensure(&self, spec: &MethodSpec) -> Result<(), BackendError> {
        let engine = self.engine()?;
        let method = spec.method_id();
        if *spec != MethodSpec::table1(method) {
            return Err(BackendError::unknown_spec(format!(
                "pjrt backend only ships AOT graphs for the Table I specs, not '{spec}' \
                 (serve arbitrary specs on --backend golden or hw)"
            )));
        }
        engine
            .preload(vec![Self::artifact_name(method, self.batch)])
            .map_err(|e| BackendError::unavailable(format!("preloading '{spec}': {e}")))?;
        self.ensured.write().unwrap().insert(*spec);
        Ok(())
    }

    fn eval_raw(
        &self,
        spec: &MethodSpec,
        input: &[i64],
        out: &mut [i64],
    ) -> Result<EvalStats, BackendError> {
        let engine = self.engine()?;
        if !self.ensured.read().unwrap().contains(spec) {
            return Err(BackendError::unknown_spec(format!(
                "spec '{spec}' not ensured on the pjrt backend"
            )));
        }
        super::check_slice_lens(input, out)?;
        if input.len() != self.batch {
            return Err(BackendError::bad_request(format!(
                "pjrt graphs are compiled for batch {}, got {} elements",
                self.batch,
                input.len()
            )));
        }
        // The f32 graphs take real-valued activations: widen the raw
        // words, execute, and re-quantize the f32 results to the output
        // format (the one lossy backend — see the struct docs).
        let in_ulp = spec.io.input.ulp();
        let flat: Vec<f32> = input.iter().map(|&r| (r as f64 * in_ulp) as f32).collect();
        let name = Self::artifact_name(spec.method_id(), self.batch);
        let ys = engine
            .run_f32(&name, flat)
            .map_err(|e| BackendError::internal(format!("executing '{name}': {e}")))?;
        if ys.len() != out.len() {
            return Err(BackendError::internal(format!(
                "'{name}' returned {} outputs for {} inputs",
                ys.len(),
                out.len()
            )));
        }
        for (slot, y) in out.iter_mut().zip(&ys) {
            *slot = Fx::from_f64(*y as f64, spec.io.output).raw();
        }
        Ok(EvalStats::default())
    }

    fn fixed_batch(&self) -> Option<usize> {
        Some(self.batch)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::ErrorCode;

    #[test]
    fn artifact_names_match_aot_convention() {
        assert_eq!(PjrtBackend::artifact_name(MethodId::Pwl, 1024), "tanh_pwl_1024");
        assert_eq!(
            PjrtBackend::artifact_name(MethodId::CatmullRom, 1024),
            "tanh_catmull_rom_1024"
        );
    }

    #[test]
    fn shim_build_reports_unavailable_not_unreachable() {
        // Under runtime::xla_shim (or without artifacts) the backend
        // constructs fine, reports Unavailable with a reason, and every
        // entry point returns the backend_unavailable code — the clean
        // fail-fast path `serve --backend pjrt` relies on.
        let b = PjrtBackend::with_default_artifacts(64);
        // Fixed-shape substrate: the coordinator aligns its batcher to
        // this at startup.
        assert_eq!(b.fixed_batch(), Some(64));
        match b.availability() {
            Availability::Available => {
                // Real bindings + artifacts present: ensure must accept
                // a Table I spec and reject everything else as
                // unknown_spec.
                let custom = MethodSpec::parse("pwl:step=1/32").unwrap();
                assert_eq!(b.ensure(&custom).unwrap_err().code, ErrorCode::UnknownSpec);
            }
            Availability::Unavailable(reason) => {
                assert!(!reason.is_empty());
                let spec = MethodSpec::table1(MethodId::Pwl);
                let err = b.ensure(&spec).unwrap_err();
                assert_eq!(err.code, ErrorCode::BackendUnavailable);
                let mut out = [0i64; 1];
                let err = b.eval_raw(&spec, &[0], &mut out).unwrap_err();
                assert_eq!(err.code, ErrorCode::BackendUnavailable);
            }
        }
    }
}
