//! Minimal benchmarking core: adaptive iteration count, median +
//! median-absolute-deviation statistics, black-box value sinking, and a
//! machine-readable result log ([`BenchLog`]) so the perf trajectory is
//! trackable across PRs instead of living in scrollback.

use std::hint::black_box;
use std::path::Path;
use std::time::{Duration, Instant};

use crate::util::json::Json;

/// Result of one benchmark.
#[derive(Clone, Debug)]
pub struct BenchResult {
    /// Benchmark name.
    pub name: String,
    /// Median wall time per iteration.
    pub median: Duration,
    /// Median absolute deviation.
    pub mad: Duration,
    /// Iterations per sample.
    pub iters_per_sample: u64,
    /// Number of samples.
    pub samples: usize,
}

impl BenchResult {
    /// Nanoseconds per iteration (median).
    pub fn ns_per_iter(&self) -> f64 {
        self.median.as_nanos() as f64
    }

    /// Iterations per second.
    pub fn per_second(&self) -> f64 {
        1e9 / self.ns_per_iter().max(1e-3)
    }

    /// One-line report.
    pub fn report(&self) -> String {
        let ns = self.ns_per_iter();
        let (val, unit) = if ns < 1_000.0 {
            (ns, "ns")
        } else if ns < 1_000_000.0 {
            (ns / 1_000.0, "µs")
        } else {
            (ns / 1_000_000.0, "ms")
        };
        format!(
            "{:40} {:>10.2} {}/iter  (±{:.1}%, {} samples × {} iters)",
            self.name,
            val,
            unit,
            100.0 * self.mad.as_nanos() as f64 / self.median.as_nanos().max(1) as f64,
            self.samples,
            self.iters_per_sample
        )
    }
}

/// Benchmark driver with fixed sample/target-time policy.
pub struct Bencher {
    /// Target wall time per sample.
    pub sample_target: Duration,
    /// Number of samples collected.
    pub samples: usize,
    /// Warmup duration before calibration.
    pub warmup: Duration,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher {
            sample_target: Duration::from_millis(40),
            samples: 11,
            warmup: Duration::from_millis(50),
        }
    }
}

impl Bencher {
    /// Quick profile for slow end-to-end benches.
    pub fn quick() -> Bencher {
        Bencher {
            sample_target: Duration::from_millis(20),
            samples: 5,
            warmup: Duration::from_millis(10),
        }
    }

    /// Runs `f` repeatedly, returning robust per-iteration timing.
    pub fn run<T>(&self, name: &str, mut f: impl FnMut() -> T) -> BenchResult {
        // Warmup + calibration: find iters such that a sample hits the
        // target duration.
        let warm_end = Instant::now() + self.warmup;
        let mut calib_iters: u64 = 0;
        let calib_start = Instant::now();
        while Instant::now() < warm_end {
            black_box(f());
            calib_iters += 1;
        }
        let per_iter = calib_start.elapsed().as_secs_f64() / calib_iters.max(1) as f64;
        let iters = ((self.sample_target.as_secs_f64() / per_iter.max(1e-9)) as u64).max(1);

        let mut times: Vec<Duration> = Vec::with_capacity(self.samples);
        for _ in 0..self.samples {
            let start = Instant::now();
            for _ in 0..iters {
                black_box(f());
            }
            times.push(start.elapsed() / iters as u32);
        }
        times.sort();
        let median = times[times.len() / 2];
        let mut devs: Vec<i128> =
            times.iter().map(|t| (t.as_nanos() as i128 - median.as_nanos() as i128).abs()).collect();
        devs.sort();
        let mad = Duration::from_nanos(devs[devs.len() / 2] as u64);
        BenchResult {
            name: name.to_string(),
            median,
            mad,
            iters_per_sample: iters,
            samples: self.samples,
        }
    }
}

/// Collects bench results into a JSON file written next to the stdout
/// table (e.g. `BENCH_throughput.json`): one row per benchmark with the
/// name, items ("elements") per iteration, derived rate, and raw wall
/// time — everything a later PR needs to diff performance.
#[derive(Default)]
pub struct BenchLog {
    rows: Vec<Json>,
}

impl BenchLog {
    /// Empty log.
    pub fn new() -> BenchLog {
        BenchLog::default()
    }

    /// Records one result; `elements` is the number of items each
    /// iteration processed (1 for plain benches), so `evals_per_s` is
    /// directly comparable across batch sizes.
    pub fn record(&mut self, elements: usize, r: &BenchResult) {
        self.rows.push(Json::obj(vec![
            ("name", Json::s(r.name.clone())),
            ("elements", Json::i(elements as i64)),
            ("wall_ns", Json::n(r.ns_per_iter())),
            ("evals_per_s", Json::n(elements as f64 * r.per_second())),
            ("mad_ns", Json::n(r.mad.as_nanos() as f64)),
            ("samples", Json::i(r.samples as i64)),
            ("iters_per_sample", Json::i(r.iters_per_sample as i64)),
        ]));
    }

    /// Records a pre-built row (the scenario runner's serve reports
    /// carry a wider schema than `record`'s fixed one).
    pub fn push_row(&mut self, row: Json) {
        self.rows.push(row);
    }

    /// Number of recorded rows.
    pub fn len(&self) -> usize {
        self.rows.len()
    }

    /// True when nothing has been recorded.
    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    /// Renders the log as a pretty-printed JSON array.
    pub fn to_json(&self) -> String {
        Json::arr(self.rows.clone()).to_string_pretty()
    }

    /// Writes the log to `path` (overwriting).
    pub fn write(&self, path: impl AsRef<Path>) -> std::io::Result<()> {
        std::fs::write(path, self.to_json() + "\n")
    }
}

/// One-shot bench with default settings; prints the report line.
pub fn bench<T>(name: &str, f: impl FnMut() -> T) -> BenchResult {
    let r = Bencher::default().run(name, f);
    println!("{}", r.report());
    r
}

/// One-shot bench normalizing to `n` items per iteration; prints
/// items/second.
pub fn bench_n<T>(name: &str, n: usize, f: impl FnMut() -> T) -> BenchResult {
    let r = Bencher::default().run(name, f);
    println!(
        "{}  [{:.2} Mitems/s]",
        r.report(),
        n as f64 * r.per_second() / 1e6
    );
    r
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn times_a_trivial_op() {
        let b = Bencher {
            sample_target: Duration::from_micros(200),
            samples: 3,
            warmup: Duration::from_micros(100),
        };
        let r = b.run("noop-add", || std::hint::black_box(1u64) + 1);
        assert!(r.ns_per_iter() < 1_000.0, "{}", r.ns_per_iter());
        assert_eq!(r.samples, 3);
    }

    #[test]
    fn slower_op_times_slower() {
        let b = Bencher {
            sample_target: Duration::from_micros(500),
            samples: 3,
            warmup: Duration::from_micros(100),
        };
        let fast = b.run("fast", || 1u64 + 1);
        let slow = b.run("slow", || (0..1000u64).sum::<u64>());
        assert!(slow.ns_per_iter() > fast.ns_per_iter());
    }

    #[test]
    fn bench_log_round_trips() {
        let r = BenchResult {
            name: "kernel/PWL".into(),
            median: Duration::from_nanos(4000),
            mad: Duration::from_nanos(20),
            iters_per_sample: 1000,
            samples: 11,
        };
        let mut log = BenchLog::new();
        log.record(4096, &r);
        assert_eq!(log.len(), 1);
        let parsed = crate::util::json::parse(&log.to_json()).unwrap();
        let row = &parsed.as_arr().unwrap()[0];
        assert_eq!(row.get("name").unwrap().str().unwrap(), "kernel/PWL");
        assert_eq!(row.get("elements").unwrap().num().unwrap(), 4096.0);
        let rate = row.get("evals_per_s").unwrap().num().unwrap();
        assert!((rate - 4096.0 * 1e9 / 4000.0).abs() < rate * 1e-6);
    }

    #[test]
    fn report_formats() {
        let r = BenchResult {
            name: "x".into(),
            median: Duration::from_nanos(1500),
            mad: Duration::from_nanos(10),
            iters_per_sample: 100,
            samples: 5,
        };
        assert!(r.report().contains("µs/iter"));
        assert!((r.per_second() - 1e9 / 1500.0).abs() < 1.0);
    }
}
