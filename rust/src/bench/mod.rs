//! Self-contained benchmark harness (criterion is not in the offline
//! crate set): warmup + timed iterations + robust statistics, with the
//! paper-table renderers layered on top in `rust/benches/*.rs`, plus
//! the deterministic serving-load scenarios ([`scenario`]) behind
//! `tanh-vlsi serve --scenario` and the tier-1 smoke, their
//! concurrent-socket replay driver ([`sockets`]) that pushes the same
//! traces through real TCP connections in both wire framings, and the
//! streaming-session scenarios ([`stream`]) that pulse long sequences
//! through server-side warm sessions with cold-replay verification.

mod harness;
pub mod scenario;
pub mod sockets;
pub mod stream;

pub use harness::{bench, bench_n, BenchLog, BenchResult, Bencher};
