//! Self-contained benchmark harness (criterion is not in the offline
//! crate set): warmup + timed iterations + robust statistics, with the
//! paper-table renderers layered on top in `rust/benches/*.rs`.

mod harness;

pub use harness::{bench, bench_n, BenchLog, BenchResult, Bencher};
