//! Deterministic scenario-driven load generation for the coordinator.
//!
//! `serve --requests N` replays one synthetic pattern; real activation
//! traffic is shaped — bursts, skewed method popularity, floods of
//! scalar requests, full-batch tensor slabs. This module encodes those
//! shapes as **scenarios**: PRNG-seeded workload generators that expand
//! to a replayable [`Trace`] (an explicit request list with open-loop
//! send offsets), so the same `(scenario, seed)` pair produces the
//! byte-identical workload on every machine and every PR. That is what
//! makes `BENCH_serve.json` rows comparable across commits: timing
//! fields move, the workload never does.
//!
//! The five scenarios (see [`SCENARIO_NAMES`]):
//!
//! | name       | shape                                                    |
//! |------------|----------------------------------------------------------|
//! | `steady`   | constant-rate open loop, fixed 64-element requests       |
//! | `bursty`   | on/off: 16-request bursts, 1 ms silences                 |
//! | `zipf`     | Zipf-skewed method mix, sizes 1–256, heavy-tailed gaps   |
//! | `flood`    | tiny (1–4 element) requests as fast as possible          |
//! | `maxbatch` | every request exactly one full compiled batch            |
//!
//! [`run_trace`] drives a [`Coordinator`] with a trace — paced
//! (open-loop, honoring `at_us`) or closed-loop — while a collector
//! thread drains and **verifies every reply against the compiled
//! golden kernels** ([`GoldenVerifier`]), bit-exact for the golden
//! backend. Backpressure rejections are retried (bounded), so the
//! completion counts in [`ScenarioOutcome`] are deterministic even
//! when the flood scenarios saturate the queues.

use std::collections::HashMap;
use std::sync::mpsc;
use std::time::{Duration, Instant};

use crate::approx::{CompiledKernel, MethodSpec};
use crate::backend::{kernel_eval_f32, ErrorCode};
use crate::coordinator::{Coordinator, LatencyHistogram, MetricsSnapshot, RequestResult};
use crate::util::json::Json;
use crate::util::prng::Prng;

/// The scenario registry, in canonical order.
pub const SCENARIO_NAMES: [&str; 5] = ["steady", "bursty", "zipf", "flood", "maxbatch"];

/// One scheduled request of a workload trace.
#[derive(Clone, Debug, PartialEq)]
pub struct TraceRequest {
    /// Which design point to exercise.
    pub spec: MethodSpec,
    /// Input activations.
    pub values: Vec<f32>,
    /// Open-loop send offset from trace start, in microseconds
    /// (ignored in closed-loop replay).
    pub at_us: u64,
}

/// A fully expanded, replayable workload: the output of
/// [`build_trace`], deterministic in `(name, seed, batch_elements,
/// scale, specs)`.
#[derive(Clone, Debug, PartialEq)]
pub struct Trace {
    /// Scenario name.
    pub name: String,
    /// PRNG seed the trace was expanded from.
    pub seed: u64,
    /// The design points this trace spreads load over, in mix order.
    pub specs: Vec<MethodSpec>,
    /// Requests in schedule order.
    pub requests: Vec<TraceRequest>,
}

impl Trace {
    /// Total activation elements across the trace.
    pub fn total_elements(&self) -> u64 {
        self.requests.iter().map(|r| r.values.len() as u64).sum()
    }

    /// Spec strings for the report row, in mix order.
    pub fn spec_strings(&self) -> Vec<String> {
        self.specs.iter().map(|s| s.to_string()).collect()
    }
}

fn gen_values(g: &mut Prng, len: usize) -> Vec<f32> {
    (0..len.max(1)).map(|_| g.f64_in(-6.0, 6.0) as f32).collect()
}

/// Zipf-style popularity weights (≈ 1/k^1.1) for the spec mix, fixed
/// as literals: `powf` is libm-dependent and not bit-identical across
/// platforms, which would break the byte-identical-workload contract
/// traces promise. Spec sets longer than the table reuse the tail
/// weight.
const ZIPF_WEIGHTS: [f64; 6] = [1.0, 0.4665, 0.2987, 0.2176, 0.1722, 0.1431];

/// Zipf-skewed index in `[0, n)` by CDF inversion over
/// [`ZIPF_WEIGHTS`]. Pure IEEE add/mul/compare on literal constants —
/// deterministic on every platform. For the six-spec Table I mix this
/// reproduces the pre-spec traces draw-for-draw.
fn zipf_index(g: &mut Prng, n: usize) -> usize {
    let w = |i: usize| ZIPF_WEIGHTS[i.min(ZIPF_WEIGHTS.len() - 1)];
    let total: f64 = (0..n).map(w).sum();
    let mut u = g.f64() * total;
    for i in 0..n {
        if u < w(i) {
            return i;
        }
        u -= w(i);
    }
    n - 1
}

/// Expands a scenario into a replayable trace over `specs` (the design
/// points the target coordinator serves — the Table I suite for the
/// classic harness, or any `--spec` list).
///
/// `scale` multiplies the scenario's base request count (1.0 = full
/// profile, tier-1 smoke uses 0.1); every count is clamped to ≥ 1.
/// Request sizes are capped at `batch_elements` so the trace is valid
/// for the compiled batch it will be served on.
pub fn build_trace(
    name: &str,
    seed: u64,
    batch_elements: usize,
    scale: f64,
    specs: &[MethodSpec],
) -> Result<Trace, String> {
    if batch_elements == 0 {
        return Err("batch_elements must be > 0".into());
    }
    if specs.is_empty() {
        return Err("trace needs at least one spec".into());
    }
    let mut g = Prng::new(seed);
    let n = |base: usize| ((base as f64 * scale) as usize).max(1);
    let mut reqs = Vec::new();
    match name {
        "steady" => {
            // Constant-rate open loop: one fixed-size request every
            // 30 µs, specs round-robin.
            let count = n(600);
            for i in 0..count {
                let len = 64.min(batch_elements);
                reqs.push(TraceRequest {
                    spec: specs[i % specs.len()],
                    values: gen_values(&mut g, len),
                    at_us: i as u64 * 30,
                });
            }
        }
        "bursty" => {
            // On/off: bursts of 16 mixed-size requests land together,
            // separated by 1 ms of silence.
            let bursts = n(40);
            let mut at = 0u64;
            for _ in 0..bursts {
                for _ in 0..16 {
                    let len = (16 + g.usize_below(113)).min(batch_elements);
                    reqs.push(TraceRequest {
                        spec: *g.choose(specs),
                        values: gen_values(&mut g, len),
                        at_us: at,
                    });
                }
                at += 1000;
            }
        }
        "zipf" => {
            // Skewed spec popularity (≈ Zipf s=1.1 over the mix
            // order), log-uniform sizes, heavy-tailed inter-arrivals
            // (mostly short gaps, occasional long ones; mean ≈ 29 µs —
            // integer-deterministic, no libm `ln`).
            let count = n(800);
            let mut at = 0u64;
            for _ in 0..count {
                let spec = specs[zipf_index(&mut g, specs.len())];
                let len = (1usize << g.usize_below(9)).min(batch_elements);
                at += if g.bool(0.9) { g.u64_below(20) } else { 100 + g.u64_below(200) };
                reqs.push(TraceRequest { spec, values: gen_values(&mut g, len), at_us: at });
            }
        }
        "flood" => {
            // Tiny-request flood: 1–4 element requests, no pacing —
            // the padding-waste and backpressure stressor.
            let count = n(2000);
            for i in 0..count {
                let len = (1 + g.usize_below(4)).min(batch_elements);
                reqs.push(TraceRequest {
                    spec: specs[i % specs.len()],
                    values: gen_values(&mut g, len),
                    at_us: 0,
                });
            }
        }
        "maxbatch" => {
            // Every request is one full compiled batch: zero padding,
            // zero packing headroom.
            let count = n(48);
            for i in 0..count {
                reqs.push(TraceRequest {
                    spec: specs[i % specs.len()],
                    values: gen_values(&mut g, batch_elements),
                    at_us: 0,
                });
            }
        }
        other => {
            return Err(format!(
                "unknown scenario '{other}' (have: {})",
                SCENARIO_NAMES.join(", ")
            ))
        }
    }
    Ok(Trace { name: name.to_string(), seed, specs: specs.to_vec(), requests: reqs })
}

/// Recomputes expected outputs through **freshly compiled** golden
/// kernels, independent of the serving path: the verifier deliberately
/// bypasses the shared [`crate::approx::Registry`] cache (which the
/// serving backend uses), so a corrupted cache entry — or a bug in the
/// coordinator's slicing or routing — cannot cancel out. Conversion
/// semantics are shared with the serving backends via
/// [`crate::backend::kernel_eval_f32`].
pub struct GoldenVerifier {
    kernels: HashMap<MethodSpec, CompiledKernel>,
}

impl GoldenVerifier {
    /// Fresh-compiles the six Table I kernels.
    pub fn new() -> GoldenVerifier {
        GoldenVerifier::for_specs(&MethodSpec::table1_all())
    }

    /// Fresh-compiles a kernel per spec (cache-bypassing by design).
    pub fn for_specs(specs: &[MethodSpec]) -> GoldenVerifier {
        GoldenVerifier {
            kernels: specs.iter().map(|s| (*s, s.build().compile(s.io))).collect(),
        }
    }

    /// Expected outputs for a request.
    pub fn expected(&self, spec: &MethodSpec, values: &[f32]) -> Result<Vec<f32>, String> {
        let kernel = self
            .kernels
            .get(spec)
            .ok_or_else(|| format!("verifier has no kernel for spec '{spec}'"))?;
        Ok(kernel_eval_f32(kernel, values))
    }
}

impl Default for GoldenVerifier {
    fn default() -> Self {
        GoldenVerifier::new()
    }
}

/// Reply-correctness policy for [`run_trace`].
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Verify {
    /// Bit-exact equality with the compiled golden kernels (the golden
    /// backend serves through the same kernels, so any mismatch is a
    /// batching/routing/slicing bug).
    Exact,
    /// Absolute tolerance (for the PJRT graphs, which compute in f32;
    /// the band absorbs the f32-vs-fixed-point compute difference —
    /// conversions at the raw boundary are the shared golden ones).
    Tolerance(f64),
    /// No verification.
    Off,
}

/// Replay options for [`run_trace`].
#[derive(Clone, Copy, Debug)]
pub struct RunOptions {
    /// Honor the trace's open-loop `at_us` schedule (sleep between
    /// sends) instead of submitting as fast as possible.
    pub pace: bool,
    /// Correctness check applied to every successful reply.
    pub verify: Verify,
    /// Bound on requests in flight (collector channel capacity).
    pub max_inflight: usize,
}

impl Default for RunOptions {
    fn default() -> Self {
        RunOptions { pace: false, verify: Verify::Exact, max_inflight: 512 }
    }
}

/// What a scenario run produced. The load-dependent fields
/// (`submitted`, `completed`, `failed`, `elements`, `verified`) are
/// deterministic for a given trace; `wall`, `retries` and the latency
/// content of `metrics` are timing observables.
#[derive(Clone, Debug)]
pub struct ScenarioOutcome {
    /// Scenario name.
    pub name: String,
    /// Trace seed.
    pub seed: u64,
    /// Spec strings of the trace's design-point mix (report rows carry
    /// them so runs are comparable — and reproducible via `--spec` —
    /// across PRs).
    pub specs: Vec<String>,
    /// Requests accepted by the coordinator.
    pub submitted: u64,
    /// Successful replies.
    pub completed: u64,
    /// Error replies.
    pub failed: u64,
    /// Backpressure retries spent (timing-dependent).
    pub retries: u64,
    /// Elements in successful replies.
    pub elements: u64,
    /// Replies checked against the golden kernels.
    pub verified: u64,
    /// Wall time from first submit to last reply.
    pub wall: Duration,
    /// Coordinator metrics merged across shards at run end.
    pub metrics: MetricsSnapshot,
    /// Socket-level observables when the trace was replayed over real
    /// TCP connections ([`crate::bench::sockets`]); `None` for
    /// in-process replay.
    pub net: Option<SocketNet>,
    /// Cell-graph observables when the run served LSTM cell steps
    /// through the graph layer ([`crate::graph::run_lstm_cells`]);
    /// `None` for flat activation traces.
    pub cells: Option<CellStats>,
    /// Streaming-session observables when the run pulsed open sessions
    /// ([`crate::bench::stream`]); `None` for per-request traces.
    pub stream: Option<StreamStats>,
}

/// What a streaming-session scenario run observed
/// ([`crate::bench::stream`]): session/pulse counts, the per-pulse
/// round-trip histogram merged across sessions, and the steady-state
/// cycles-per-element of the warm streams.
#[derive(Clone, Debug)]
pub struct StreamStats {
    /// Sessions opened (and closed or torn down) by the run.
    pub sessions: u64,
    /// Pulses fed across every session.
    pub pulses: u64,
    /// Per-pulse round-trip latency (µs), merged across sessions
    /// (exact merge, like the shard metrics).
    pub pulse_latency: LatencyHistogram,
    /// Simulated cycles per streamed element across the run's session
    /// work — on the hw backend this must not exceed the per-batch
    /// re-fill baseline `(depth + P − 1) / P`, and sits near 1.0 for
    /// long warm sessions.
    pub stream_cycles_per_element: f64,
    /// Sessions the idle-timeout sweep evicted during the run.
    pub evicted: u64,
}

/// What an `lstm` scenario run observed at the cell-graph layer.
#[derive(Clone, Copy, Debug)]
pub struct CellStats {
    /// Whole cell steps served end to end (each = 5 activation
    /// requests through the coordinator plus the elementwise update).
    pub cell_steps: u64,
    /// Max |fixed − f64 reference| across every gate output of every
    /// step, in value units — must sit within the cell's declared
    /// error budget (enforced by the run itself; reported for trend
    /// tracking).
    pub gate_max_err: f64,
}

/// What a concurrent-socket replay observed at the net layer: the
/// connection fan-out, the server's accept/byte gauges, and the
/// client-side end-to-end latency histogram merged across connections
/// (exact merge, like the shard metrics).
#[derive(Clone, Debug)]
pub struct SocketNet {
    /// Wire framing the connections used: `json`, `binary`, or `mixed`
    /// (even connection indices JSON, odd binary).
    pub framing: String,
    /// Concurrent client connections the trace was split over.
    pub connections: u64,
    /// Server gauge: connections accepted over the server's lifetime.
    pub accepted_conns: u64,
    /// Server gauge: connections still open at snapshot time.
    pub active_conns: u64,
    /// Server gauge: request bytes read.
    pub bytes_in: u64,
    /// Server gauge: reply bytes written.
    pub bytes_out: u64,
    /// Client-observed per-request round-trip latency (µs), merged
    /// across every connection's per-connection histogram.
    pub conn_latency: LatencyHistogram,
}

impl ScenarioOutcome {
    /// One machine-readable `BENCH_serve.json` row. The key set is
    /// [`SERVE_ROW_KEYS`]; tier-1's smoke validates it via
    /// [`validate_serve_log`].
    pub fn to_json(&self, backend: &str, shards: usize, batch_elements: usize) -> Json {
        let m = &self.metrics;
        let secs = self.wall.as_secs_f64().max(1e-9);
        Json::obj(vec![
            ("name", Json::s(format!("serve/{}", self.name))),
            ("scenario", Json::s(self.name.clone())),
            ("seed", Json::i(self.seed as i64)),
            ("specs", Json::arr(self.specs.iter().map(|s| Json::s(s.as_str())).collect())),
            ("backend", Json::s(backend)),
            ("shards", Json::i(shards as i64)),
            ("batch_elements", Json::i(batch_elements as i64)),
            ("requests", Json::i(self.completed as i64)),
            ("failed", Json::i(self.failed as i64)),
            ("elements", Json::i(self.elements as i64)),
            ("verified", Json::i(self.verified as i64)),
            ("wall_ns", Json::n(self.wall.as_nanos() as f64)),
            ("req_per_s", Json::n(self.completed as f64 / secs)),
            ("evals_per_s", Json::n(self.elements as f64 / secs)),
            ("batches", Json::i(m.batches as i64)),
            ("packed_batches", Json::i(m.packed_batches as i64)),
            ("fill_rate", Json::n(m.fill_rate())),
            ("sim_cycles", Json::i(m.sim_cycles as i64)),
            ("sim_cycles_per_element", Json::n(m.sim_cycles_per_element())),
            ("rejected_retries", Json::i(self.retries as i64)),
            ("p50_us", Json::n(m.p50_us())),
            ("p95_us", Json::n(m.p95_us())),
            ("p99_us", Json::n(m.p99_us())),
            ("max_us", Json::i(m.latency_us_max() as i64)),
            // Socket-replay columns: zeros / "inproc" for in-process
            // runs so the row schema is uniform across both drivers.
            (
                "framing",
                Json::s(self.net.as_ref().map(|n| n.framing.as_str()).unwrap_or("inproc")),
            ),
            ("connections", Json::i(self.net.as_ref().map_or(0, |n| n.connections) as i64)),
            (
                "accepted_conns",
                Json::i(self.net.as_ref().map_or(0, |n| n.accepted_conns) as i64),
            ),
            ("active_conns", Json::i(self.net.as_ref().map_or(0, |n| n.active_conns) as i64)),
            ("bytes_in", Json::i(self.net.as_ref().map_or(0, |n| n.bytes_in) as i64)),
            ("bytes_out", Json::i(self.net.as_ref().map_or(0, |n| n.bytes_out) as i64)),
            ("conn_p50_us", Json::n(self.net.as_ref().map_or(0.0, |n| n.conn_latency.p50()))),
            ("conn_p95_us", Json::n(self.net.as_ref().map_or(0.0, |n| n.conn_latency.p95()))),
            ("conn_p99_us", Json::n(self.net.as_ref().map_or(0.0, |n| n.conn_latency.p99()))),
            ("conn_max_us", Json::i(self.net.as_ref().map_or(0, |n| n.conn_latency.max) as i64)),
            // Cell-graph columns: zeros for flat activation traces.
            ("cell_steps", Json::i(self.cells.map_or(0, |c| c.cell_steps) as i64)),
            ("gate_max_err", Json::n(self.cells.map_or(0.0, |c| c.gate_max_err))),
            // Streaming-session columns: zeros for per-request traces.
            ("sessions", Json::i(self.stream.as_ref().map_or(0, |s| s.sessions) as i64)),
            ("pulses", Json::i(self.stream.as_ref().map_or(0, |s| s.pulses) as i64)),
            (
                "pulse_p50_us",
                Json::n(self.stream.as_ref().map_or(0.0, |s| s.pulse_latency.p50())),
            ),
            (
                "pulse_p95_us",
                Json::n(self.stream.as_ref().map_or(0.0, |s| s.pulse_latency.p95())),
            ),
            (
                "pulse_p99_us",
                Json::n(self.stream.as_ref().map_or(0.0, |s| s.pulse_latency.p99())),
            ),
            (
                "stream_cycles_per_element",
                Json::n(self.stream.as_ref().map_or(0.0, |s| s.stream_cycles_per_element)),
            ),
        ])
    }

    /// The seed-deterministic subset of the row: byte-identical across
    /// runs with the same `(scenario, seed, batch, scale)` — the
    /// "modulo timing fields" contract `tests/serving.rs` asserts.
    pub fn deterministic_fields(&self) -> Json {
        Json::obj(vec![
            ("scenario", Json::s(self.name.clone())),
            ("seed", Json::i(self.seed as i64)),
            ("specs", Json::arr(self.specs.iter().map(|s| Json::s(s.as_str())).collect())),
            ("submitted", Json::i(self.submitted as i64)),
            ("requests", Json::i(self.completed as i64)),
            ("failed", Json::i(self.failed as i64)),
            ("elements", Json::i(self.elements as i64)),
            ("verified", Json::i(self.verified as i64)),
        ])
    }
}

/// Keys every `BENCH_serve.json` row must carry. `backend` names the
/// executing [`crate::backend::EvalBackend`]; `sim_cycles` is that
/// backend's simulated-hardware-latency column (total simulated cycles
/// across the run's batches — nonzero only on the hw backend), and
/// `sim_cycles_per_element` the steady-state cycles per fed element
/// ([`MetricsSnapshot::sim_cycles_per_element`]): ≈ 1.0 for the warm
/// streaming hw worker, inflated by the per-batch re-fill latency if
/// streaming ever regresses.
///
/// The socket-replay columns (`framing` through `conn_max_us`) carry
/// the concurrent-connection fan-out, the server's net gauges, and the
/// client-observed round-trip percentiles; in-process rows fill them
/// with `"inproc"` / zeros so every row validates against one schema.
///
/// The cell-graph columns (`cell_steps`, `gate_max_err`) carry the
/// `lstm` scenario's whole-cell-step count and its worst per-gate
/// error against the f64 reference; flat activation rows fill them
/// with zeros.
///
/// The streaming-session columns (`sessions` through
/// `stream_cycles_per_element`) carry the `stream-*` scenarios'
/// session/pulse counts, client-observed per-pulse round-trip
/// percentiles, and the warm streams' steady-state cycles per element;
/// per-request rows fill them with zeros.
pub const SERVE_ROW_KEYS: [&str; 42] = [
    "name",
    "scenario",
    "seed",
    "specs",
    "backend",
    "shards",
    "batch_elements",
    "requests",
    "failed",
    "elements",
    "verified",
    "wall_ns",
    "req_per_s",
    "evals_per_s",
    "batches",
    "packed_batches",
    "fill_rate",
    "sim_cycles",
    "sim_cycles_per_element",
    "rejected_retries",
    "p50_us",
    "p95_us",
    "p99_us",
    "max_us",
    "framing",
    "connections",
    "accepted_conns",
    "active_conns",
    "bytes_in",
    "bytes_out",
    "conn_p50_us",
    "conn_p95_us",
    "conn_p99_us",
    "conn_max_us",
    "cell_steps",
    "gate_max_err",
    "sessions",
    "pulses",
    "pulse_p50_us",
    "pulse_p95_us",
    "pulse_p99_us",
    "stream_cycles_per_element",
];

/// Validates a `BENCH_serve.json` document: a non-empty array whose
/// rows carry every [`SERVE_ROW_KEYS`] key, completed at least one
/// request, and report nonzero throughput. Returns the row count.
pub fn validate_serve_log(text: &str) -> Result<usize, String> {
    let doc = crate::util::json::parse(text).map_err(|e| format!("BENCH_serve.json: {e}"))?;
    let rows = doc.as_arr().ok_or("BENCH_serve.json: top level is not an array")?;
    if rows.is_empty() {
        return Err("BENCH_serve.json: no rows".into());
    }
    for (i, row) in rows.iter().enumerate() {
        for key in SERVE_ROW_KEYS {
            if row.get(key).is_none() {
                return Err(format!("BENCH_serve.json row {i}: missing key '{key}'"));
            }
        }
        let requests = row.get("requests").and_then(Json::num).unwrap_or(0.0);
        if requests <= 0.0 {
            return Err(format!("BENCH_serve.json row {i}: zero requests"));
        }
        let rate = row.get("evals_per_s").and_then(Json::num).unwrap_or(0.0);
        if !(rate > 0.0) {
            return Err(format!("BENCH_serve.json row {i}: zero throughput"));
        }
        // Socket-replay rows must carry real net observables: traffic
        // flowed in both directions and round-trip latency was
        // measured.
        let conns = row.get("connections").and_then(Json::num).unwrap_or(0.0);
        if conns > 0.0 {
            let framing = row.get("framing").and_then(Json::str).unwrap_or("");
            if framing == "inproc" || framing.is_empty() {
                return Err(format!(
                    "BENCH_serve.json row {i}: {conns} connections but framing '{framing}'"
                ));
            }
            for key in ["bytes_in", "bytes_out", "conn_p99_us"] {
                let v = row.get(key).and_then(Json::num).unwrap_or(0.0);
                if !(v > 0.0) {
                    return Err(format!(
                        "BENCH_serve.json row {i}: socket replay with zero {key}"
                    ));
                }
            }
        }
        // Cell-graph rows must carry a real (nonzero) error
        // observable: a cell run whose gates were all bit-exact against
        // the f64 reference means the reference was never consulted.
        let steps = row.get("cell_steps").and_then(Json::num).unwrap_or(0.0);
        if steps > 0.0 {
            let err = row.get("gate_max_err").and_then(Json::num).unwrap_or(0.0);
            if !(err > 0.0) {
                return Err(format!(
                    "BENCH_serve.json row {i}: {steps} cell steps but zero gate_max_err"
                ));
            }
        }
        // Streaming rows must carry real session observables: pulses
        // flowed and their round-trip latency was measured.
        let sessions = row.get("sessions").and_then(Json::num).unwrap_or(0.0);
        if sessions > 0.0 {
            let pulses = row.get("pulses").and_then(Json::num).unwrap_or(0.0);
            if !(pulses > 0.0) {
                return Err(format!(
                    "BENCH_serve.json row {i}: {sessions} sessions but zero pulses"
                ));
            }
            let p99 = row.get("pulse_p99_us").and_then(Json::num).unwrap_or(0.0);
            if !(p99 > 0.0) {
                return Err(format!(
                    "BENCH_serve.json row {i}: streaming run with zero pulse_p99_us"
                ));
            }
        }
    }
    Ok(rows.len())
}

/// Replays a trace against a running coordinator.
///
/// The submit loop (optionally paced to the trace schedule) feeds a
/// bounded channel drained by a collector thread, which waits on every
/// reply and verifies it per `opts.verify`. Backpressure rejections
/// are retried with a short sleep so every trace request eventually
/// completes — that keeps [`ScenarioOutcome`]'s completion counts
/// deterministic while still exercising the shed/fail-fast path (the
/// retry count is reported). Any verification mismatch aborts the run
/// with an error.
pub fn run_trace(
    coord: &Coordinator,
    trace: &Trace,
    opts: &RunOptions,
) -> Result<ScenarioOutcome, String> {
    let verifier = match opts.verify {
        Verify::Off => None,
        _ => Some(GoldenVerifier::for_specs(&trace.specs)),
    };
    let need_values = verifier.is_some();
    let verify = opts.verify;
    type InFlight = (MethodSpec, Vec<f32>, mpsc::Receiver<RequestResult>);
    let (tx, rx) = mpsc::sync_channel::<InFlight>(opts.max_inflight.max(1));

    let collector = std::thread::Builder::new()
        .name("tanh-scenario-collect".into())
        .spawn(move || -> Result<(u64, u64, u64, u64), String> {
            let (mut completed, mut failed, mut elements, mut verified) = (0u64, 0u64, 0u64, 0u64);
            while let Ok((spec, values, reply)) = rx.recv() {
                let result = reply.recv().map_err(|_| "reply channel dropped".to_string())?;
                match result.outcome {
                    Ok(out) => {
                        completed += 1;
                        elements += out.len() as u64;
                        if let Some(v) = &verifier {
                            let want = v.expected(&spec, &values)?;
                            if out.len() != want.len() {
                                return Err(format!(
                                    "{spec}: served {} outputs for {} inputs",
                                    out.len(),
                                    want.len()
                                ));
                            }
                            for (i, (got, exp)) in out.iter().zip(&want).enumerate() {
                                let ok = match verify {
                                    Verify::Exact => got.to_bits() == exp.to_bits(),
                                    Verify::Tolerance(tol) => {
                                        ((got - exp).abs() as f64) <= tol
                                    }
                                    Verify::Off => true,
                                };
                                if !ok {
                                    return Err(format!(
                                        "verification failed: {spec} output[{i}] \
                                         served {got} vs golden kernel {exp}"
                                    ));
                                }
                            }
                            verified += 1;
                        }
                    }
                    Err(_) => failed += 1,
                }
            }
            Ok((completed, failed, elements, verified))
        })
        .map_err(|e| format!("spawning collector: {e}"))?;

    let start = Instant::now();
    let mut submitted = 0u64;
    let mut retries = 0u64;
    for tr in &trace.requests {
        if opts.pace && tr.at_us > 0 {
            let target = start + Duration::from_micros(tr.at_us);
            let now = Instant::now();
            if target > now {
                std::thread::sleep(target - now);
            }
        }
        // Bounded backpressure retry: the collector is continuously
        // draining, so the routed queue frees up; the cap only guards
        // against a wedged coordinator.
        let mut receiver = None;
        for _attempt in 0..500_000u32 {
            match coord.submit_spec(&tr.spec, tr.values.clone()) {
                Ok(r) => {
                    receiver = Some(r);
                    break;
                }
                // Typed backpressure: only `overloaded` is retryable;
                // every other code is a trace/config bug and aborts.
                Err(e) if e.code == ErrorCode::Overloaded => {
                    retries += 1;
                    std::thread::sleep(Duration::from_micros(20));
                }
                Err(e) => {
                    drop(tx);
                    let _ = collector.join();
                    return Err(format!("submit failed: {e}"));
                }
            }
        }
        let reply = match receiver {
            Some(r) => r,
            None => {
                drop(tx);
                let _ = collector.join();
                return Err("backpressure retry budget exhausted".into());
            }
        };
        submitted += 1;
        // Skip the input copy when nothing will verify it.
        let values = if need_values { tr.values.clone() } else { Vec::new() };
        if tx.send((tr.spec, values, reply)).is_err() {
            // The collector exited early — almost always a verification
            // failure; surface its error instead of a generic one.
            drop(tx);
            let joined =
                collector.join().map_err(|_| "collector thread panicked".to_string())?;
            return match joined {
                Err(e) => Err(e),
                Ok(_) => Err("collector thread exited early".into()),
            };
        }
    }
    drop(tx);
    let joined = collector.join().map_err(|_| "collector thread panicked".to_string())?;
    let (completed, failed, elements, verified) = joined?;
    Ok(ScenarioOutcome {
        name: trace.name.clone(),
        seed: trace.seed,
        specs: trace.spec_strings(),
        submitted,
        completed,
        failed,
        retries,
        elements,
        verified,
        wall: start.elapsed(),
        metrics: coord.metrics(),
        net: None,
        cells: None,
        stream: None,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::approx::MethodId;

    fn table1() -> Vec<MethodSpec> {
        MethodSpec::table1_all()
    }

    #[test]
    fn traces_are_seed_deterministic() {
        for name in SCENARIO_NAMES {
            let a = build_trace(name, 7, 256, 0.05, &table1()).unwrap();
            let b = build_trace(name, 7, 256, 0.05, &table1()).unwrap();
            assert_eq!(a, b, "{name}");
            assert!(!a.requests.is_empty(), "{name}");
            let c = build_trace(name, 8, 256, 0.05, &table1()).unwrap();
            assert_ne!(a.requests, c.requests, "{name}: seed must matter");
        }
    }

    #[test]
    fn traces_respect_batch_capacity() {
        for name in SCENARIO_NAMES {
            let t = build_trace(name, 3, 128, 0.1, &table1()).unwrap();
            for r in &t.requests {
                assert!(!r.values.is_empty(), "{name}");
                assert!(r.values.len() <= 128, "{name}: {}", r.values.len());
                for v in &r.values {
                    assert!(v.is_finite() && (-6.0..=6.0).contains(v), "{name}");
                }
            }
        }
    }

    #[test]
    fn maxbatch_requests_fill_the_batch_exactly() {
        let t = build_trace("maxbatch", 1, 64, 0.1, &table1()).unwrap();
        for r in &t.requests {
            assert_eq!(r.values.len(), 64);
        }
    }

    #[test]
    fn zipf_skews_toward_first_specs() {
        let t = build_trace("zipf", 42, 1024, 1.0, &table1()).unwrap();
        let count = |m: MethodId| {
            t.requests.iter().filter(|r| r.spec.method_id() == m).count()
        };
        let first = count(MethodId::Pwl);
        let last = count(MethodId::Lambert);
        assert!(
            first > last,
            "Zipf mix should favor rank 1 over rank 6: {first} vs {last}"
        );
        // …but every spec still appears (coverage for the smoke).
        for m in MethodId::all() {
            assert!(count(m) > 0, "{m:?} absent from zipf mix");
        }
    }

    #[test]
    fn steady_schedule_is_monotone_open_loop() {
        let t = build_trace("steady", 5, 1024, 0.1, &table1()).unwrap();
        let mut prev = 0;
        for (i, r) in t.requests.iter().enumerate() {
            assert!(r.at_us >= prev, "at_us must be non-decreasing at {i}");
            prev = r.at_us;
        }
        assert!(t.requests.last().unwrap().at_us > 0);
    }

    #[test]
    fn unknown_scenario_is_an_error() {
        let err = build_trace("nope", 0, 64, 1.0, &table1()).unwrap_err();
        assert!(err.contains("unknown scenario"));
        assert!(err.contains("steady"), "error should list valid names: {err}");
        // Empty spec sets are rejected too.
        assert!(build_trace("steady", 0, 64, 1.0, &[]).is_err());
    }

    #[test]
    fn single_spec_traces_route_all_load_to_that_spec() {
        let spec = MethodSpec::parse("pwl:step=1/32:in=s2.13:out=s.15").unwrap();
        for name in SCENARIO_NAMES {
            let t = build_trace(name, 9, 64, 0.05, &[spec]).unwrap();
            assert!(t.requests.iter().all(|r| r.spec == spec), "{name}");
            assert_eq!(t.spec_strings(), vec![spec.to_string()], "{name}");
        }
    }

    #[test]
    fn verifier_covers_exactly_its_specs() {
        let spec = MethodSpec::parse("pwl:step=1/32:in=s2.13:out=s.15").unwrap();
        let v = GoldenVerifier::for_specs(&[spec]);
        let got = v.expected(&spec, &[0.5, -0.5]).unwrap();
        assert_eq!(got[0], -got[1]);
        let err = v.expected(&MethodSpec::table1(MethodId::Pwl), &[0.5]).unwrap_err();
        assert!(err.contains("no kernel"), "{err}");
    }

    #[test]
    fn serve_log_validation_accepts_real_rows_and_rejects_broken_ones() {
        let outcome = ScenarioOutcome {
            name: "steady".into(),
            seed: 42,
            specs: vec!["pwl:step=1/64:in=S3.12:out=S.15".into()],
            submitted: 10,
            completed: 10,
            failed: 0,
            retries: 0,
            elements: 640,
            verified: 10,
            wall: Duration::from_millis(5),
            metrics: MetricsSnapshot::default(),
            net: None,
            cells: None,
            stream: None,
        };
        let row = outcome.to_json("golden", 2, 1024);
        let text = Json::arr(vec![row.clone()]).to_string_pretty();
        assert_eq!(validate_serve_log(&text).unwrap(), 1);
        // In-process rows fill the socket columns with the sentinels.
        assert_eq!(row.get("framing").and_then(Json::str), Some("inproc"));
        assert_eq!(row.get("connections").and_then(Json::num), Some(0.0));

        // A socket-replay row validates when the net observables are
        // real…
        let mut socket = outcome.clone();
        socket.net = Some(SocketNet {
            framing: "mixed".into(),
            connections: 8,
            accepted_conns: 8,
            active_conns: 8,
            bytes_in: 4096,
            bytes_out: 8192,
            conn_latency: LatencyHistogram::from_samples(&[120, 250, 900]),
        });
        let srow = socket.to_json("golden", 2, 1024);
        assert_eq!(srow.get("framing").and_then(Json::str), Some("mixed"));
        assert_eq!(srow.get("connections").and_then(Json::num), Some(8.0));
        assert!(srow.get("conn_p99_us").and_then(Json::num).unwrap() > 0.0);
        let text = Json::arr(vec![srow]).to_string_pretty();
        assert_eq!(validate_serve_log(&text).unwrap(), 1);
        // …and is rejected when it claims connections but no traffic.
        let mut hollow = socket.clone();
        hollow.net.as_mut().unwrap().bytes_out = 0;
        let text = Json::arr(vec![hollow.to_json("golden", 2, 1024)]).to_string_compact();
        assert!(validate_serve_log(&text).unwrap_err().contains("bytes_out"));

        // Missing key.
        let Json::Obj(mut map) = row.clone() else { panic!("row is an object") };
        map.remove("p99_us");
        let broken = Json::arr(vec![Json::Obj(map)]).to_string_pretty();
        assert!(validate_serve_log(&broken).unwrap_err().contains("p99_us"));

        // Zero throughput.
        let mut zero = outcome;
        zero.elements = 0;
        let text = Json::arr(vec![zero.to_json("golden", 2, 1024)]).to_string_compact();
        assert!(validate_serve_log(&text).unwrap_err().contains("throughput"));

        // Empty array / non-array.
        assert!(validate_serve_log("[]").is_err());
        assert!(validate_serve_log("{}").is_err());
    }

    #[test]
    fn deterministic_fields_exclude_timing() {
        let outcome = ScenarioOutcome {
            name: "flood".into(),
            seed: 1,
            specs: vec!["table1-mix".into()],
            submitted: 3,
            completed: 3,
            failed: 0,
            retries: 99,
            elements: 9,
            verified: 3,
            wall: Duration::from_secs(1),
            metrics: MetricsSnapshot::default(),
            net: None,
            cells: None,
            stream: None,
        };
        let text = outcome.deterministic_fields().to_string_compact();
        assert!(!text.contains("wall"), "{text}");
        assert!(!text.contains("retries"), "{text}");
        assert!(text.contains("\"verified\":3"), "{text}");
    }
}
