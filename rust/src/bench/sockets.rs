//! Concurrent-socket scenario replay: the same deterministic traces
//! [`super::scenario`] builds, driven over **N real TCP connections**
//! against a running [`NetServer`] instead of in-process submit calls.
//!
//! Each connection gets every Nth trace request (round-robin by trace
//! index) and runs a writer half + reader half joined by a bounded
//! channel, so requests are **pipelined** up to a window per
//! connection while replies are verified strictly in order — the
//! ordering guarantee of the wire protocol is itself under test.
//! Framing is per-connection: all-JSON, all-binary, or `mixed` (even
//! connection indices JSON, odd binary), exercising both protocols
//! against the same workload. Every reply is checked against freshly
//! compiled golden kernels exactly like the in-process driver —
//! bit-exact for `Verify::Exact`, on raw `i64` words for binary
//! connections — and per-connection round-trip latency lands in
//! histograms that merge exactly into the
//! [`SocketNet`] row columns (`conn_p50_us`…`conn_max_us` in
//! `BENCH_serve.json`).

use std::collections::HashMap;
use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;
use std::sync::mpsc;
use std::time::{Duration, Instant};

use crate::approx::MethodSpec;
use crate::backend::quantize_input;
use crate::coordinator::{
    bin_request_frame, reply_values, Coordinator, LatencyHistogram, NetServer,
    BIN_REPLY_MAGIC,
};
use crate::util::json::{self, Json};

use super::scenario::{GoldenVerifier, ScenarioOutcome, SocketNet, Trace, Verify};

/// Per-connection wire framing for a socket replay.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Framing {
    /// Every connection speaks the JSON line protocol.
    Json,
    /// Every connection speaks binary frames.
    Binary,
    /// Even connection indices JSON, odd binary — both protocols under
    /// the same workload.
    Mixed,
}

impl Framing {
    /// Parses a `--framing` argument.
    pub fn parse(s: &str) -> Result<Framing, String> {
        match s {
            "json" => Ok(Framing::Json),
            "binary" => Ok(Framing::Binary),
            "mixed" => Ok(Framing::Mixed),
            other => Err(format!("unknown framing '{other}' (have: json, binary, mixed)")),
        }
    }

    /// The report-row label.
    pub fn as_str(self) -> &'static str {
        match self {
            Framing::Json => "json",
            Framing::Binary => "binary",
            Framing::Mixed => "mixed",
        }
    }

    fn binary_for(self, conn_index: usize) -> bool {
        match self {
            Framing::Json => false,
            Framing::Binary => true,
            Framing::Mixed => conn_index % 2 == 1,
        }
    }
}

/// Options for [`run_trace_sockets`].
#[derive(Clone, Copy, Debug)]
pub struct SocketRunOptions {
    /// Concurrent client connections the trace is split over.
    pub connections: usize,
    /// Wire framing policy.
    pub framing: Framing,
    /// Reply-correctness policy (same semantics as the in-process
    /// driver; `Exact` compares raw words on binary connections).
    pub verify: Verify,
    /// Per-connection pipelining window: how many requests may be on
    /// the wire ahead of the reply cursor.
    pub window: usize,
    /// Honor the trace's open-loop `at_us` schedule per connection.
    pub pace: bool,
}

impl Default for SocketRunOptions {
    fn default() -> Self {
        SocketRunOptions {
            connections: 8,
            framing: Framing::Mixed,
            verify: Verify::Exact,
            window: 32,
            pace: false,
        }
    }
}

struct ConnStats {
    completed: u64,
    failed: u64,
    elements: u64,
    verified: u64,
    latency: LatencyHistogram,
    /// Held so the connection stays open (and counted in the server's
    /// `active_conns` gauge) until the run snapshot is taken.
    _keep: TcpStream,
}

/// Replays a trace over `opts.connections` concurrent TCP connections
/// against `server` (which must front `coord` — its metrics and spec
/// registry fill the outcome). Replies are verified in order per
/// connection; any mismatch aborts the run with an error. The returned
/// outcome carries [`SocketNet`] observables: the server's
/// accept/byte gauges and the exact cross-connection merge of the
/// per-connection round-trip histograms.
pub fn run_trace_sockets(
    coord: &Coordinator,
    server: &NetServer,
    trace: &Trace,
    opts: &SocketRunOptions,
) -> Result<ScenarioOutcome, String> {
    if trace.requests.is_empty() {
        return Err("trace has no requests".into());
    }
    let conns = opts.connections.max(1);
    let verifier = match opts.verify {
        Verify::Off => None,
        _ => Some(GoldenVerifier::for_specs(&trace.specs)),
    };
    // Binary frames address specs by registered id (position in the
    // coordinator's served list); resolve the mapping once, up front,
    // so an unserved trace spec fails the run before any socket opens.
    let spec_ids = spec_id_table(coord.specs())?;
    if opts.framing != Framing::Json {
        for spec in &trace.specs {
            if !spec_ids.contains_key(spec) {
                return Err(format!(
                    "binary framing needs served specs: trace spec '{spec}' is not \
                     registered on the coordinator"
                ));
            }
        }
    }
    let addr = server.addr();
    let start = Instant::now();
    let results: Vec<Result<ConnStats, String>> = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..conns)
            .map(|c| {
                let verifier = verifier.as_ref();
                let spec_ids = &spec_ids;
                scope.spawn(move || {
                    run_conn(
                        addr,
                        trace,
                        c,
                        conns,
                        opts.framing.binary_for(c),
                        spec_ids,
                        verifier,
                        opts,
                        start,
                    )
                })
            })
            .collect();
        handles
            .into_iter()
            .map(|h| {
                h.join()
                    .unwrap_or_else(|_| Err("connection thread panicked".into()))
            })
            .collect()
    });
    // Snapshot the gauges while every connection is still open (the
    // streams live inside the per-connection stats), so `active_conns`
    // reflects the run's true fan-out.
    let gauges = server.gauges();
    let wall = start.elapsed();
    let (mut completed, mut failed, mut elements, mut verified) = (0u64, 0u64, 0u64, 0u64);
    let mut latency = LatencyHistogram::default();
    for r in results {
        let s = r?;
        completed += s.completed;
        failed += s.failed;
        elements += s.elements;
        verified += s.verified;
        latency.merge(&s.latency);
    }
    Ok(ScenarioOutcome {
        name: trace.name.clone(),
        seed: trace.seed,
        specs: trace.spec_strings(),
        submitted: trace.requests.len() as u64,
        completed,
        failed,
        retries: 0,
        elements,
        verified,
        wall,
        metrics: coord.metrics(),
        net: Some(SocketNet {
            framing: opts.framing.as_str().to_string(),
            connections: conns as u64,
            accepted_conns: gauges.accepted_conns,
            active_conns: gauges.active_conns,
            bytes_in: gauges.bytes_in,
            bytes_out: gauges.bytes_out,
            conn_latency: latency,
        }),
        cells: None,
        stream: None,
    })
}

/// One connection's replay: a writer thread streams this connection's
/// share of the trace (request indices `conn, conn + stride, …`) while
/// this thread reads and verifies the replies in order. The bounded
/// meta channel caps the pipelining window; the server's own
/// backpressure (read pausing once its per-connection in-flight cap
/// fills) throttles the writer through TCP beyond that.
#[allow(clippy::too_many_arguments)]
fn run_conn(
    addr: std::net::SocketAddr,
    trace: &Trace,
    conn: usize,
    stride: usize,
    binary: bool,
    spec_ids: &HashMap<MethodSpec, u16>,
    verifier: Option<&GoldenVerifier>,
    opts: &SocketRunOptions,
    start: Instant,
) -> Result<ConnStats, String> {
    let stream =
        TcpStream::connect(addr).map_err(|e| format!("conn {conn}: connect: {e}"))?;
    let _ = stream.set_nodelay(true);
    let wstream = stream.try_clone().map_err(|e| format!("conn {conn}: clone: {e}"))?;
    let rstream = stream.try_clone().map_err(|e| format!("conn {conn}: clone: {e}"))?;
    let (meta_tx, meta_rx) = mpsc::sync_channel::<(usize, Instant)>(opts.window.max(1));
    let pace = opts.pace;
    let verify = opts.verify;

    std::thread::scope(|scope| {
        let writer = scope.spawn(move || -> Result<(), String> {
            let mut w = wstream;
            for i in (conn..trace.requests.len()).step_by(stride) {
                let req = &trace.requests[i];
                if pace && req.at_us > 0 {
                    let target = start + Duration::from_micros(req.at_us);
                    let now = Instant::now();
                    if target > now {
                        std::thread::sleep(target - now);
                    }
                }
                let frame = if binary {
                    let id = *spec_ids
                        .get(&req.spec)
                        .ok_or_else(|| format!("spec '{}' has no registered id", req.spec))?;
                    bin_request_frame(id, &quantize_input(&req.values, req.spec.io.input))
                } else {
                    let doc = Json::obj(vec![
                        ("spec", Json::s(req.spec.to_string())),
                        (
                            "values",
                            Json::arr(
                                req.values.iter().map(|v| Json::n(*v as f64)).collect(),
                            ),
                        ),
                    ]);
                    let mut line = doc.to_string_compact();
                    line.push('\n');
                    line.into_bytes()
                };
                // Meta first (blocks at the window cap), then the
                // bytes: the reader always knows what reply is next.
                meta_tx
                    .send((i, Instant::now()))
                    .map_err(|_| "reader hung up".to_string())?;
                w.write_all(&frame).map_err(|e| format!("conn {conn}: write: {e}"))?;
            }
            Ok(())
        });

        let mut reader = BufReader::new(rstream);
        let mut stats = ConnStats {
            completed: 0,
            failed: 0,
            elements: 0,
            verified: 0,
            latency: LatencyHistogram::default(),
            _keep: stream,
        };
        while let Ok((i, sent_at)) = meta_rx.recv() {
            let req = &trace.requests[i];
            let outcome = if binary {
                read_bin_reply(&mut reader).map_err(|e| format!("conn {conn}: {e}"))?
            } else {
                read_json_reply(&mut reader).map_err(|e| format!("conn {conn}: {e}"))?
            };
            stats.latency.record(sent_at.elapsed().as_micros() as u64);
            match outcome {
                Reply::Err(_) => stats.failed += 1,
                Reply::JsonOk(out) => {
                    stats.completed += 1;
                    stats.elements += out.len() as u64;
                    if let Some(v) = verifier {
                        let want = v.expected(&req.spec, &req.values)?;
                        check_f32(&req.spec, &out, &want, verify)
                            .map_err(|e| format!("conn {conn}: {e}"))?;
                        stats.verified += 1;
                    }
                }
                Reply::BinOk(raws) => {
                    stats.completed += 1;
                    stats.elements += raws.len() as u64;
                    if let Some(v) = verifier {
                        let want = v.expected(&req.spec, &req.values)?;
                        check_raw(&req.spec, &raws, &want, verify)
                            .map_err(|e| format!("conn {conn}: {e}"))?;
                        stats.verified += 1;
                    }
                }
            }
        }
        writer
            .join()
            .map_err(|_| "writer thread panicked".to_string())??;
        Ok(stats)
    })
}

/// Builds the binary-framing spec-id table: id `k` is the k-th entry
/// of the served-spec list. Regression: the table used to be built
/// with an unchecked `i as u16`, so a list past 65536 entries silently
/// aliased spec 65536 onto id 0 (and so on) — every binary frame for
/// the wrapped ids addressed the wrong design point. A list larger
/// than the u16 address space is now a hard error at table build.
pub fn spec_id_table(specs: &[MethodSpec]) -> Result<HashMap<MethodSpec, u16>, String> {
    let cap = u16::MAX as usize + 1;
    if specs.len() > cap {
        return Err(format!(
            "served-spec list of {} entries exceeds the {cap} binary spec ids \
             (u16 address space); serve fewer specs or split the deployment",
            specs.len()
        ));
    }
    Ok(specs.iter().enumerate().map(|(i, s)| (*s, i as u16)).collect())
}

enum Reply {
    JsonOk(Vec<f32>),
    BinOk(Vec<i64>),
    /// Server error reply (`"<code>: <detail>"`), counted as failed.
    Err(String),
}

fn read_json_reply(reader: &mut BufReader<TcpStream>) -> Result<Reply, String> {
    let mut line = String::new();
    let n = reader.read_line(&mut line).map_err(|e| format!("read: {e}"))?;
    if n == 0 {
        return Err("server closed the connection mid-run".into());
    }
    let doc = json::parse(line.trim_end())?;
    match reply_values(&doc) {
        Ok(values) => Ok(Reply::JsonOk(values)),
        Err(e) if e.starts_with("reply values") || e.starts_with("missing") => Err(e),
        Err(e) => Ok(Reply::Err(e)),
    }
}

fn read_bin_reply(reader: &mut BufReader<TcpStream>) -> Result<Reply, String> {
    let mut header = [0u8; 5];
    reader.read_exact(&mut header).map_err(|e| format!("read: {e}"))?;
    if header[0] != BIN_REPLY_MAGIC {
        return Err(format!("bad reply magic 0x{:02x}", header[0]));
    }
    let len = u32::from_le_bytes([header[1], header[2], header[3], header[4]]) as usize;
    if len == 0 {
        return Err("empty reply frame".into());
    }
    let mut body = vec![0u8; len];
    reader.read_exact(&mut body).map_err(|e| format!("read: {e}"))?;
    let (status, payload) = (body[0], &body[1..]);
    if status != 0 {
        return Ok(Reply::Err(format!(
            "status {status}: {}",
            String::from_utf8_lossy(payload)
        )));
    }
    if payload.len() % 8 != 0 {
        return Err(format!("reply payload of {} bytes is not i64-aligned", payload.len()));
    }
    Ok(Reply::BinOk(
        payload
            .chunks_exact(8)
            .map(|w| i64::from_le_bytes(w.try_into().unwrap()))
            .collect(),
    ))
}

fn check_f32(spec: &MethodSpec, got: &[f32], want: &[f32], verify: Verify) -> Result<(), String> {
    if got.len() != want.len() {
        return Err(format!("{spec}: served {} outputs for {} inputs", got.len(), want.len()));
    }
    for (i, (g, w)) in got.iter().zip(want).enumerate() {
        let ok = match verify {
            Verify::Exact => g.to_bits() == w.to_bits(),
            Verify::Tolerance(tol) => ((g - w).abs() as f64) <= tol,
            Verify::Off => true,
        };
        if !ok {
            return Err(format!(
                "verification failed: {spec} output[{i}] served {g} vs golden kernel {w}"
            ));
        }
    }
    Ok(())
}

fn check_raw(spec: &MethodSpec, got: &[i64], want: &[f32], verify: Verify) -> Result<(), String> {
    if got.len() != want.len() {
        return Err(format!("{spec}: served {} outputs for {} inputs", got.len(), want.len()));
    }
    // The golden expectation in raw words: the same output-format
    // quantization the server applies to its f32 results.
    let want_raw = quantize_input(want, spec.io.output);
    let ulp = spec.io.output.ulp();
    for (i, (g, w)) in got.iter().zip(&want_raw).enumerate() {
        let ok = match verify {
            Verify::Exact => g == w,
            Verify::Tolerance(tol) => ((g - w) as f64 * ulp).abs() <= tol,
            Verify::Off => true,
        };
        if !ok {
            return Err(format!(
                "verification failed: {spec} output[{i}] served raw {g} vs golden raw {w}"
            ));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::GoldenBackend;
    use crate::bench::scenario::build_trace;
    use crate::coordinator::CoordinatorConfig;
    use std::sync::Arc;

    fn serve() -> (Arc<Coordinator>, NetServer) {
        let coord = Arc::new(
            Coordinator::start(
                Arc::new(GoldenBackend::new()),
                CoordinatorConfig::with_batch(256),
            )
            .unwrap(),
        );
        let server = NetServer::start(coord.clone(), "127.0.0.1:0").unwrap();
        (coord, server)
    }

    #[test]
    fn framing_parses_and_labels() {
        assert_eq!(Framing::parse("json").unwrap(), Framing::Json);
        assert_eq!(Framing::parse("binary").unwrap(), Framing::Binary);
        assert_eq!(Framing::parse("mixed").unwrap(), Framing::Mixed);
        assert!(Framing::parse("grpc").unwrap_err().contains("json"));
        assert_eq!(Framing::Mixed.as_str(), "mixed");
        // Mixed alternates starting with JSON on connection 0.
        assert!(!Framing::Mixed.binary_for(0));
        assert!(Framing::Mixed.binary_for(1));
        assert!(Framing::Binary.binary_for(0));
        assert!(!Framing::Json.binary_for(7));
    }

    #[test]
    fn socket_replay_verifies_over_mixed_framing() {
        let (coord, server) = serve();
        let trace =
            build_trace("zipf", 11, 256, 0.05, &crate::approx::MethodSpec::table1_all())
                .unwrap();
        let opts = SocketRunOptions { connections: 4, ..SocketRunOptions::default() };
        let out = run_trace_sockets(&coord, &server, &trace, &opts).unwrap();
        assert_eq!(out.submitted, trace.requests.len() as u64);
        assert_eq!(out.completed, out.submitted);
        assert_eq!(out.failed, 0);
        assert_eq!(out.verified, out.completed);
        let net = out.net.as_ref().unwrap();
        assert_eq!(net.framing, "mixed");
        assert_eq!(net.connections, 4);
        assert!(net.accepted_conns >= 4, "{net:?}");
        assert_eq!(net.active_conns, 4, "gauge snapshot must see all conns open");
        assert!(net.bytes_in > 0 && net.bytes_out > 0);
        assert_eq!(net.conn_latency.count, out.completed);
        assert!(net.conn_latency.max > 0);
        // The coordinator saw exactly the socket-submitted load.
        assert_eq!(out.metrics.submitted, out.submitted);
        assert_eq!(out.metrics.requests, out.completed);
        server.stop();
        Arc::try_unwrap(coord).ok().unwrap().shutdown();
    }

    #[test]
    fn spec_id_table_rejects_lists_past_the_u16_address_space() {
        // Regression: `i as u16` truncation — a 65537-entry list used
        // to alias its tail onto ids 0, 1, … silently. The boundary:
        // 65536 entries fill the address space exactly and pass; one
        // more is a hard error naming both sizes.
        let spec = crate::approx::MethodSpec::table1_all()[0];
        assert!(spec_id_table(&vec![spec; 65536]).is_ok());
        let err = spec_id_table(&vec![spec; 65537]).unwrap_err();
        assert!(err.contains("65537"), "must name the list size: {err}");
        assert!(err.contains("65536"), "must name the id space: {err}");
        // The happy path still numbers specs by list position.
        let specs = crate::approx::MethodSpec::table1_all();
        let table = spec_id_table(&specs).unwrap();
        assert_eq!(table.len(), specs.len());
        assert_eq!(table[&specs[0]], 0);
        assert_eq!(table[&specs[specs.len() - 1]], (specs.len() - 1) as u16);
    }

    #[test]
    fn binary_framing_refuses_unserved_trace_specs() {
        let (coord, server) = serve();
        let foreign =
            crate::approx::MethodSpec::parse("pwl:step=1/32:in=s2.13:out=s.15").unwrap();
        let trace = build_trace("steady", 1, 64, 0.02, &[foreign]).unwrap();
        let opts = SocketRunOptions {
            connections: 2,
            framing: Framing::Binary,
            ..SocketRunOptions::default()
        };
        let err = run_trace_sockets(&coord, &server, &trace, &opts).unwrap_err();
        assert!(err.contains("not registered"), "{err}");
        server.stop();
        Arc::try_unwrap(coord).ok().unwrap().shutdown();
    }
}
