//! Streaming-session scenario replay: deterministic pulse scripts fed
//! through the coordinator's session layer
//! ([`crate::coordinator::Coordinator::open_session`]), in-process or
//! over real TCP connections in both wire framings.
//!
//! Where [`super::scenario`] replays independent one-shot requests,
//! these scenarios model the sequence workloads the session layer
//! exists for: a client opens a session, feeds a long input as
//! fixed-size **pulses**, and the server keeps the backend stream warm
//! across pulses — so on the hw backend the pipeline pays its fill
//! latency once per session instead of once per batch. The three
//! shapes (see [`STREAM_SCENARIO_NAMES`]):
//!
//! | name            | shape                                              |
//! |-----------------|----------------------------------------------------|
//! | `stream-steady` | few long sessions, fixed-size pulses               |
//! | `stream-jitter` | ragged pulse widths and lengths per session        |
//! | `stream-many`   | a large fleet of short interleaved sessions        |
//!
//! Every plan is PRNG-seeded and deterministic in `(name, seed,
//! batch_elements, scale, specs)`, like the request traces. Replies
//! are verified **bit-exact against a cold golden replay**: each
//! session's expected output sequence is computed up front through a
//! freshly compiled kernel (cache-bypassing, like
//! [`super::scenario::GoldenVerifier`]), and the concatenation of
//! every pulse's released words plus the close tail must equal it
//! word-for-word — on the golden *and* the hw backend, which is
//! bit-exact by construction. The drivers also assert the session
//! contract itself: the executing shard never changes mid-session, and
//! `issued − delivered` never exceeds the advertised delay until close
//! flushes it to zero.
//!
//! Per-pulse round-trip latency lands in a [`LatencyHistogram`] merged
//! across sessions (and connections, for the socket driver) — the
//! `pulse_p50_us`…`pulse_p99_us` columns of `BENCH_serve.json` — and
//! the summed [`PulseOutcome::sim_cycles`] over the summed fed
//! elements is the `stream_cycles_per_element` column: ≈ 1.0 for warm
//! hw sessions, vs the `(depth + P − 1) / P` per-batch re-fill
//! baseline the steady-state test pins.

use std::time::{Duration, Instant};

use crate::approx::MethodSpec;
use crate::backend::ErrorCode;
use crate::coordinator::{
    BinClient, Coordinator, LatencyHistogram, NetClient, NetServer, PulseOutcome,
};
use crate::util::prng::Prng;

use super::scenario::{ScenarioOutcome, SocketNet, StreamStats};
use super::sockets::{spec_id_table, Framing};

/// The streaming scenario registry, in canonical order.
pub const STREAM_SCENARIO_NAMES: [&str; 3] = ["stream-steady", "stream-jitter", "stream-many"];

/// One session's scripted life: the spec it opens against and the
/// exact pulses it feeds, in order.
#[derive(Clone, Debug, PartialEq)]
pub struct SessionScript {
    /// Design point the session streams through.
    pub spec: MethodSpec,
    /// Raw input words, one inner vec per pulse.
    pub pulses: Vec<Vec<i64>>,
}

impl SessionScript {
    /// Total input words across the script's pulses.
    pub fn elements(&self) -> u64 {
        self.pulses.iter().map(|p| p.len() as u64).sum()
    }
}

/// A fully expanded streaming workload: the output of
/// [`build_stream_plan`], deterministic in `(name, seed,
/// batch_elements, scale, specs)`.
#[derive(Clone, Debug, PartialEq)]
pub struct StreamPlan {
    /// Scenario name (one of [`STREAM_SCENARIO_NAMES`]).
    pub name: String,
    /// PRNG seed the plan was expanded from.
    pub seed: u64,
    /// The design points the sessions spread over, in mix order.
    pub specs: Vec<MethodSpec>,
    /// Session scripts, in open order.
    pub sessions: Vec<SessionScript>,
}

impl StreamPlan {
    /// Total input words across every session.
    pub fn total_elements(&self) -> u64 {
        self.sessions.iter().map(SessionScript::elements).sum()
    }

    /// Total pulses across every session.
    pub fn total_pulses(&self) -> u64 {
        self.sessions.iter().map(|s| s.pulses.len() as u64).sum()
    }

    /// Spec strings for the report row, in mix order.
    pub fn spec_strings(&self) -> Vec<String> {
        self.specs.iter().map(|s| s.to_string()).collect()
    }
}

/// In-range raw input words for a spec: the session layer saturates
/// out-of-range words ([`crate::fixed::Fx::from_raw`] clamps), so
/// staying inside the input format keeps the cold-replay expectation
/// trivially aligned with what the stream actually computed.
fn gen_pulse(g: &mut Prng, spec: &MethodSpec, len: usize) -> Vec<i64> {
    let fmt = spec.io.input;
    (0..len.max(1)).map(|_| g.i64_in(fmt.min_raw(), fmt.max_raw())).collect()
}

/// Expands a streaming scenario into a session/pulse plan over `specs`
/// (round-robin spec assignment, so every served design point streams).
///
/// `scale` multiplies session counts (1.0 = full profile, tier-1 smoke
/// uses a fraction); counts clamp to ≥ 1. Pulse sizes are capped at
/// `batch_elements` so a pulse never exceeds the compiled batch shape
/// it executes on.
pub fn build_stream_plan(
    name: &str,
    seed: u64,
    batch_elements: usize,
    scale: f64,
    specs: &[MethodSpec],
) -> Result<StreamPlan, String> {
    if batch_elements == 0 {
        return Err("batch_elements must be > 0".into());
    }
    if specs.is_empty() {
        return Err("stream plan needs at least one spec".into());
    }
    let mut g = Prng::new(seed);
    let n = |base: usize| ((base as f64 * scale) as usize).max(1);
    let mut sessions = Vec::new();
    match name {
        "stream-steady" => {
            // Few long sessions, fixed-size pulses: the steady-state
            // shape whose warm cycles-per-element the hw test pins.
            let count = n(8);
            let width = 32.min(batch_elements);
            for i in 0..count {
                let spec = specs[i % specs.len()];
                let pulses = (0..32).map(|_| gen_pulse(&mut g, &spec, width)).collect();
                sessions.push(SessionScript { spec, pulses });
            }
        }
        "stream-jitter" => {
            // Ragged feeds: random pulse widths (1–64) and session
            // lengths (4–24 pulses) — the delay window sees every
            // partial-release pattern.
            let count = n(12);
            for i in 0..count {
                let spec = specs[i % specs.len()];
                let pulses = (0..4 + g.usize_below(21))
                    .map(|_| {
                        let width = (1 + g.usize_below(64)).min(batch_elements);
                        gen_pulse(&mut g, &spec, width)
                    })
                    .collect();
                sessions.push(SessionScript { spec, pulses });
            }
        }
        "stream-many" => {
            // A fleet of short sessions, all open at once and pulsed
            // interleaved: the session-table / shard-pinning stressor.
            // Stays under the default 4096-session cap at scale 1.0.
            let count = n(1500);
            for i in 0..count {
                let spec = specs[i % specs.len()];
                let pulses = (0..2 + g.usize_below(3))
                    .map(|_| {
                        let width = (4 + g.usize_below(5)).min(batch_elements);
                        gen_pulse(&mut g, &spec, width)
                    })
                    .collect();
                sessions.push(SessionScript { spec, pulses });
            }
        }
        other => {
            return Err(format!(
                "unknown streaming scenario '{other}' (have: {})",
                STREAM_SCENARIO_NAMES.join(", ")
            ))
        }
    }
    Ok(StreamPlan { name: name.to_string(), seed, specs: specs.to_vec(), sessions })
}

/// Cold golden replay of one session: the full expected output
/// sequence, computed through a **freshly compiled** kernel so the
/// serving path's shared cache cannot mask its own corruption. The hw
/// backend is bit-exact with the golden kernels by construction, so
/// this single expectation covers both serving backends.
pub fn cold_replay(script: &SessionScript) -> Vec<i64> {
    let kernel = script.spec.build().compile(script.spec.io);
    let input: Vec<i64> = script.pulses.iter().flatten().copied().collect();
    let mut out = vec![0i64; input.len()];
    kernel.eval_slice_raw(&input, &mut out);
    out
}

/// Per-session tracking shared by both drivers.
struct SessionRun {
    id: u64,
    delay: u64,
    /// Next pulse index to feed.
    cursor: usize,
    /// Released output words so far, in order.
    got: Vec<i64>,
    /// Expected full output sequence (cold replay).
    want: Vec<i64>,
    /// Shard that executed the first pulse; every later pulse must
    /// match (no-migration contract).
    shard: Option<usize>,
}

/// Checks one pulse outcome against the session contract and the cold
/// replay, updating the run. `last` marks the close/flush reply.
fn absorb_outcome(
    run: &mut SessionRun,
    script: &SessionScript,
    out: &PulseOutcome,
    last: bool,
) -> Result<(), String> {
    match run.shard {
        None => run.shard = Some(out.shard),
        Some(s) if s != out.shard => {
            return Err(format!(
                "session {} migrated from shard {s} to shard {} mid-life",
                run.id, out.shard
            ));
        }
        Some(_) => {}
    }
    let lag = out.issued - out.delivered;
    if last {
        if lag != 0 {
            return Err(format!("session {}: close left {lag} words unflushed", run.id));
        }
    } else if lag > run.delay {
        return Err(format!(
            "session {}: delay window {} exceeded (issued {}, delivered {})",
            run.id, run.delay, out.issued, out.delivered
        ));
    }
    run.got.extend_from_slice(&out.outputs);
    if run.got.len() > run.want.len() {
        return Err(format!(
            "session {}: served {} outputs for {} inputs",
            run.id,
            run.got.len(),
            run.want.len()
        ));
    }
    let n = run.got.len();
    if run.got != run.want[..n] {
        let i = run.got.iter().zip(&run.want).position(|(a, b)| a != b).unwrap_or(0);
        return Err(format!(
            "session {} ({}): streamed output[{i}] = {} but cold golden replay says {}",
            run.id, script.spec, run.got[i], run.want[i]
        ));
    }
    if last && n != run.want.len() {
        return Err(format!(
            "session {}: closed after {n} of {} expected outputs",
            run.id,
            run.want.len()
        ));
    }
    Ok(())
}

/// Sub-microsecond round trips still count: clamp to 1 µs so the
/// percentile columns are nonzero whenever pulses flowed (the schema
/// validator insists).
fn elapsed_us(t: Instant) -> u64 {
    (t.elapsed().as_micros() as u64).max(1)
}

/// Drives a streaming plan **in-process** against a coordinator:
/// opens every session up front, then feeds pulses round-robin across
/// sessions (maximal interleaving — the session-isolation stressor),
/// closes each when its script is exhausted, and verifies every
/// released word bit-exact against the cold golden replay. Backpressure
/// (`overloaded`) is retried bounded, like the request driver.
pub fn run_stream(coord: &Coordinator, plan: &StreamPlan) -> Result<ScenarioOutcome, String> {
    if plan.sessions.is_empty() {
        return Err("stream plan has no sessions".into());
    }
    let start = Instant::now();
    let mut retries = 0u64;
    let mut runs: Vec<SessionRun> = Vec::with_capacity(plan.sessions.len());
    for script in &plan.sessions {
        let info = retry_overloaded(&mut retries, || coord.open_session(&script.spec))
            .map_err(|e| format!("open failed: {e}"))?;
        runs.push(SessionRun {
            id: info.id,
            delay: info.delay as u64,
            cursor: 0,
            got: Vec::new(),
            want: cold_replay(script),
            shard: None,
        });
    }
    let mut latency = LatencyHistogram::default();
    let (mut pulses, mut verified, mut sim_cycles) = (0u64, 0u64, 0u64);
    // Round-robin by pulse index: every session advances one pulse per
    // sweep, so thousands of sessions stay interleaved on the shards.
    let mut live = runs.len();
    while live > 0 {
        for (run, script) in runs.iter_mut().zip(&plan.sessions) {
            if run.cursor >= script.pulses.len() {
                continue;
            }
            let pulse = script.pulses[run.cursor].clone();
            let t = Instant::now();
            let out = retry_overloaded(&mut retries, || {
                coord.session_pulse_blocking(run.id, pulse.clone())
            })
            .map_err(|e| format!("pulse failed: {e}"))?;
            latency.record(elapsed_us(t));
            pulses += 1;
            sim_cycles += out.sim_cycles;
            absorb_outcome(run, script, &out, false)?;
            verified += 1;
            run.cursor += 1;
            if run.cursor == script.pulses.len() {
                let out = coord
                    .session_close_blocking(run.id)
                    .map_err(|e| format!("close failed: {e}"))?;
                sim_cycles += out.sim_cycles;
                absorb_outcome(run, script, &out, true)?;
                live -= 1;
            }
        }
    }
    let elements = plan.total_elements();
    Ok(outcome(plan, coord, start, retries, pulses, verified, elements, latency, sim_cycles, None))
}

/// Bounded `overloaded` retry (the only retryable code — anything else
/// is a plan/config bug and aborts the run).
fn retry_overloaded<T>(
    retries: &mut u64,
    mut f: impl FnMut() -> Result<T, crate::coordinator::RequestError>,
) -> Result<T, crate::coordinator::RequestError> {
    let mut last = None;
    for _ in 0..500_000u32 {
        match f() {
            Ok(v) => return Ok(v),
            Err(e) if e.code == ErrorCode::Overloaded => {
                *retries += 1;
                last = Some(e);
                std::thread::sleep(Duration::from_micros(20));
            }
            Err(e) => return Err(e),
        }
    }
    Err(last.expect("retry loop exits early unless it saw overloaded"))
}

/// Either wire client, so the socket driver is framing-generic.
enum StreamClient {
    Json(NetClient),
    Bin { client: BinClient, ids: Vec<u16> },
}

impl StreamClient {
    fn open(&mut self, script: &SessionScript, session_index: usize) -> Result<(u64, u64), String> {
        match self {
            StreamClient::Json(c) => c.open_session(&script.spec.to_string()),
            StreamClient::Bin { client, ids } => client.open(ids[session_index]),
        }
    }

    fn pulse(&mut self, session: u64, raws: &[i64]) -> Result<Vec<i64>, String> {
        match self {
            StreamClient::Json(c) => c.pulse(session, raws),
            StreamClient::Bin { client, .. } => client.pulse(session, raws),
        }
    }

    fn close(&mut self, session: u64) -> Result<Vec<i64>, String> {
        match self {
            StreamClient::Json(c) => c.close_session(session),
            StreamClient::Bin { client, .. } => client.close(session),
        }
    }
}

/// One connection's streaming share: sessions `conn, conn + stride, …`
/// of the plan, opened over the wire and pulsed interleaved
/// (round-robin across this connection's sessions). The wire protocol
/// carries no shard/cycle observables, so here the contract is pure
/// output correctness: every released word, and the close tail,
/// bit-exact against the cold replay.
fn run_stream_conn(
    addr: std::net::SocketAddr,
    plan: &StreamPlan,
    conn: usize,
    stride: usize,
    binary: bool,
    spec_ids: &std::collections::HashMap<MethodSpec, u16>,
) -> Result<(u64, u64, u64, u64, LatencyHistogram), String> {
    let scripts: Vec<&SessionScript> =
        plan.sessions.iter().skip(conn).step_by(stride.max(1)).collect();
    if scripts.is_empty() {
        return Ok((0, 0, 0, 0, LatencyHistogram::default()));
    }
    let mut client = if binary {
        let ids = scripts
            .iter()
            .map(|s| {
                spec_ids.get(&s.spec).copied().ok_or_else(|| {
                    format!("binary framing needs served specs: '{}' is not registered", s.spec)
                })
            })
            .collect::<Result<Vec<u16>, String>>()?;
        let c = BinClient::connect(addr).map_err(|e| format!("connect: {e}"))?;
        StreamClient::Bin { client: c, ids }
    } else {
        StreamClient::Json(NetClient::connect(addr).map_err(|e| format!("connect: {e}"))?)
    };
    let mut runs: Vec<SessionRun> = Vec::with_capacity(scripts.len());
    for (i, script) in scripts.iter().enumerate() {
        let (id, delay) = client.open(script, i)?;
        runs.push(SessionRun {
            id,
            delay,
            cursor: 0,
            got: Vec::new(),
            want: cold_replay(script),
            shard: None,
        });
    }
    let mut latency = LatencyHistogram::default();
    let (mut pulses, mut verified, mut elements) = (0u64, 0u64, 0u64);
    let mut live = runs.len();
    while live > 0 {
        for (run, script) in runs.iter_mut().zip(&scripts) {
            if run.cursor >= script.pulses.len() {
                continue;
            }
            let pulse = &script.pulses[run.cursor];
            let t = Instant::now();
            let out = client.pulse(run.id, pulse)?;
            latency.record(elapsed_us(t));
            pulses += 1;
            elements += pulse.len() as u64;
            run.got.extend_from_slice(&out);
            let n = run.got.len();
            if n > run.want.len() || run.got != run.want[..n] {
                return Err(format!(
                    "session {} ({}): wire stream diverged from cold golden replay \
                     after {n} words",
                    run.id, script.spec
                ));
            }
            verified += 1;
            run.cursor += 1;
            if run.cursor == script.pulses.len() {
                let tail = client.close(run.id)?;
                run.got.extend_from_slice(&tail);
                if run.got != run.want {
                    return Err(format!(
                        "session {} ({}): flushed sequence differs from cold golden replay",
                        run.id, script.spec
                    ));
                }
                live -= 1;
            }
        }
    }
    Ok((scripts.len() as u64, pulses, verified, elements, latency))
}

/// Drives a streaming plan over `connections` real TCP connections
/// against `server` (fronting `coord`), sessions split round-robin
/// across connections, framing per connection like the request replay
/// ([`super::sockets`]). Per-pulse round trips land in the merged
/// histogram; the outcome carries both [`SocketNet`] and
/// [`StreamStats`] observables.
pub fn run_stream_sockets(
    coord: &Coordinator,
    server: &NetServer,
    plan: &StreamPlan,
    connections: usize,
    framing: Framing,
) -> Result<ScenarioOutcome, String> {
    if plan.sessions.is_empty() {
        return Err("stream plan has no sessions".into());
    }
    let conns = connections.max(1);
    let spec_ids = spec_id_table(coord.specs())?;
    let addr = server.addr();
    let start = Instant::now();
    let results: Vec<Result<(u64, u64, u64, u64, LatencyHistogram), String>> =
        std::thread::scope(|scope| {
            let handles: Vec<_> = (0..conns)
                .map(|c| {
                    let spec_ids = &spec_ids;
                    scope.spawn(move || {
                        run_stream_conn(addr, plan, c, conns, framing.binary_for(c), spec_ids)
                    })
                })
                .collect();
            handles
                .into_iter()
                .map(|h| h.join().unwrap_or_else(|_| Err("connection thread panicked".into())))
                .collect()
        });
    let gauges = server.gauges();
    let mut latency = LatencyHistogram::default();
    let (mut sessions, mut pulses, mut verified, mut elements) = (0u64, 0u64, 0u64, 0u64);
    for r in results {
        let (s, p, v, e, h) = r?;
        sessions += s;
        pulses += p;
        verified += v;
        elements += e;
        latency.merge(&h);
    }
    debug_assert_eq!(sessions, plan.sessions.len() as u64);
    let metrics = coord.metrics();
    let mut out = outcome(
        plan,
        coord,
        start,
        0,
        pulses,
        verified,
        elements,
        latency,
        metrics.sim_cycles,
        Some(SocketNet {
            framing: framing.as_str().to_string(),
            connections: conns as u64,
            accepted_conns: gauges.accepted_conns,
            active_conns: gauges.active_conns,
            bytes_in: gauges.bytes_in,
            bytes_out: gauges.bytes_out,
            conn_latency: LatencyHistogram::default(),
        }),
    );
    // The wire driver measures round trips per pulse; surface the same
    // histogram through the connection columns so socket-replay rows
    // validate (`conn_p99_us > 0` whenever connections > 0).
    if let (Some(net), Some(stream)) = (out.net.as_mut(), out.stream.as_ref()) {
        net.conn_latency = stream.pulse_latency.clone();
    }
    Ok(out)
}

/// Assembles the report row shared by both drivers.
#[allow(clippy::too_many_arguments)]
fn outcome(
    plan: &StreamPlan,
    coord: &Coordinator,
    start: Instant,
    retries: u64,
    pulses: u64,
    verified: u64,
    elements: u64,
    latency: LatencyHistogram,
    sim_cycles: u64,
    net: Option<SocketNet>,
) -> ScenarioOutcome {
    let cpe = if elements > 0 { sim_cycles as f64 / elements as f64 } else { 0.0 };
    ScenarioOutcome {
        name: plan.name.clone(),
        seed: plan.seed,
        specs: plan.spec_strings(),
        submitted: plan.total_pulses(),
        completed: pulses,
        failed: 0,
        retries,
        elements,
        verified,
        wall: start.elapsed(),
        metrics: coord.metrics(),
        net,
        cells: None,
        stream: Some(StreamStats {
            sessions: plan.sessions.len() as u64,
            pulses,
            pulse_latency: latency,
            stream_cycles_per_element: cpe,
            evicted: coord.sessions_evicted(),
        }),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::{GoldenBackend, HwBackend};
    use crate::coordinator::CoordinatorConfig;
    use std::sync::Arc;

    fn golden_coord(batch: usize) -> Coordinator {
        Coordinator::start(Arc::new(GoldenBackend::new()), CoordinatorConfig::with_batch(batch))
            .unwrap()
    }

    #[test]
    fn plans_are_deterministic_and_named() {
        let specs = MethodSpec::table1_all();
        for name in STREAM_SCENARIO_NAMES {
            let a = build_stream_plan(name, 7, 256, 0.05, &specs).unwrap();
            let b = build_stream_plan(name, 7, 256, 0.05, &specs).unwrap();
            assert_eq!(a, b, "{name} plan must be seed-deterministic");
            assert!(a.total_pulses() > 0, "{name}");
            let c = build_stream_plan(name, 8, 256, 0.05, &specs).unwrap();
            assert_ne!(a, c, "{name} plan must move with the seed");
        }
        let err = build_stream_plan("stream-nope", 1, 256, 1.0, &specs).unwrap_err();
        assert!(err.contains("stream-steady"), "{err}");
    }

    #[test]
    fn inproc_streams_verify_bit_exact_on_golden() {
        let coord = golden_coord(256);
        let plan = build_stream_plan("stream-jitter", 11, 256, 0.25, coord.specs()).unwrap();
        let out = run_stream(&coord, &plan).unwrap();
        let stream = out.stream.as_ref().unwrap();
        assert_eq!(stream.sessions, plan.sessions.len() as u64);
        assert_eq!(stream.pulses, plan.total_pulses());
        assert_eq!(out.verified, out.completed);
        assert_eq!(out.elements, plan.total_elements());
        assert!(stream.pulse_latency.p99() > 0.0);
        // Golden streams simulate no hardware: cycle column is zero.
        assert_eq!(stream.stream_cycles_per_element, 0.0);
        // Every session closed; the table is empty again.
        assert_eq!(coord.sessions_open(), 0);
        let row = out.to_json("golden", 2, 256);
        let text = crate::util::json::Json::arr(vec![row]).to_string_pretty();
        assert_eq!(crate::bench::scenario::validate_serve_log(&text).unwrap(), 1);
    }

    #[test]
    fn hw_steady_state_beats_the_per_batch_refill_baseline() {
        use crate::approx::MethodId;
        let spec = MethodSpec::table1(MethodId::Pwl);
        let cfg = CoordinatorConfig { specs: vec![spec], ..CoordinatorConfig::with_batch(64) };
        let coord = Coordinator::start(Arc::new(HwBackend::new()), cfg).unwrap();
        let plan = build_stream_plan("stream-steady", 3, 64, 0.25, coord.specs()).unwrap();
        let out = run_stream(&coord, &plan).unwrap();
        let stream = out.stream.as_ref().unwrap();
        let cpe = stream.stream_cycles_per_element;
        assert!(cpe > 0.0, "hw streams must report simulated cycles");
        // Per-batch re-fill baseline: every P-element batch pays the
        // pipeline depth again, (depth + P − 1) / P cycles/element. A
        // warm session pays depth once across its k pulses,
        // (depth + kP − 1) / kP — strictly less for k > 1. Derive the
        // baseline from the session's own shape: P = 32 words/pulse
        // (the stream-steady width), depth from the advertised delay
        // (delay = depth − 1).
        let info = coord.open_session(&spec).unwrap();
        let depth = info.delay as f64 + 1.0;
        coord.session_abort(info.id);
        let p = 32.0;
        let baseline = (depth + p - 1.0) / p;
        assert!(
            cpe < baseline,
            "warm session cycles/element {cpe} should beat the per-batch \
             re-fill baseline {baseline}"
        );
        // And it approaches 1.0: the whole session pays the depth once.
        assert!(cpe < 1.1, "steady-state cycles/element {cpe} should be near 1.0");
    }

    #[test]
    fn socket_streams_verify_bit_exact_in_both_framings() {
        let coord = Arc::new(golden_coord(256));
        let server = NetServer::start(coord.clone(), "127.0.0.1:0").unwrap();
        let plan = build_stream_plan("stream-many", 5, 256, 0.01, coord.specs()).unwrap();
        let out = run_stream_sockets(&coord, &server, &plan, 4, Framing::Mixed).unwrap();
        let stream = out.stream.as_ref().unwrap();
        assert_eq!(stream.pulses, plan.total_pulses());
        assert_eq!(out.verified, out.completed);
        let net = out.net.as_ref().unwrap();
        assert_eq!(net.connections, 4);
        assert!(net.bytes_in > 0 && net.bytes_out > 0);
        assert!(net.conn_latency.p99() > 0.0);
        assert_eq!(coord.sessions_open(), 0, "wire driver must close every session");
        let row = out.to_json("golden", 2, 256);
        let text = crate::util::json::Json::arr(vec![row]).to_string_pretty();
        assert_eq!(crate::bench::scenario::validate_serve_log(&text).unwrap(), 1);
        server.stop();
        Arc::try_unwrap(coord).ok().unwrap().shutdown();
    }
}
