//! Dynamic batching: packing variable-size requests into the fixed
//! batch shape the compiled executable expects.

use std::time::{Duration, Instant};

use super::request::Request;

/// Batching policy.
#[derive(Clone, Copy, Debug)]
pub struct BatcherConfig {
    /// The compiled executable's batch (element) capacity.
    pub batch_elements: usize,
    /// Flush a partial batch after this long even if not full.
    pub max_wait: Duration,
    /// Backpressure bound: max queued elements per worker shard (a
    /// method's total queue capacity is `shards × max_queue`).
    pub max_queue: usize,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        BatcherConfig {
            batch_elements: 1024,
            max_wait: Duration::from_micros(200),
            max_queue: 64 * 1024,
        }
    }
}

/// A batch under construction: requests packed head-to-tail into the
/// executable's flat input vector.
#[derive(Debug, Default)]
pub struct PendingBatch {
    /// Requests in pack order.
    pub requests: Vec<Request>,
    /// Total packed elements.
    pub elements: usize,
    /// When the oldest member arrived (flush deadline base).
    pub oldest: Option<Instant>,
}

impl PendingBatch {
    /// True if no requests are pending.
    pub fn is_empty(&self) -> bool {
        self.requests.is_empty()
    }

    /// Whether `req` still fits under `capacity`.
    pub fn fits(&self, req: &Request, capacity: usize) -> bool {
        self.elements + req.values.len() <= capacity
    }

    /// Adds a request (caller checked `fits`).
    pub fn push(&mut self, req: Request) {
        self.oldest.get_or_insert(req.enqueued_at);
        self.elements += req.values.len();
        self.requests.push(req);
    }

    /// True once the batch should flush: full enough that the next
    /// typical request won't fit, or the oldest member exceeded
    /// `max_wait`.
    pub fn should_flush(&self, cfg: &BatcherConfig, now: Instant) -> bool {
        if self.is_empty() {
            return false;
        }
        if self.elements >= cfg.batch_elements {
            return true;
        }
        match self.oldest {
            Some(t) => now.duration_since(t) >= cfg.max_wait,
            None => false,
        }
    }

    /// Fraction of `capacity` this batch fills with useful elements;
    /// the remainder becomes zero padding when packed. The worker's
    /// flush feeds the same counts into
    /// [`super::ServerMetrics::record_batch`], whose snapshot
    /// aggregates this ratio across batches
    /// (`MetricsSnapshot::fill_rate`); this per-batch form exists for
    /// introspection and tests.
    pub fn fill_rate(&self, capacity: usize) -> f64 {
        if capacity == 0 {
            1.0
        } else {
            self.elements as f64 / capacity as f64
        }
    }

    /// Packs into the executable's flat input, zero-padded to
    /// `capacity`; returns (flat_input, per-request (offset, len)).
    ///
    /// Requests are packed whole and head-to-tail: a request is never
    /// split across batches, and its span is always a contiguous slice
    /// of the flat vector (the worker slices replies back out with
    /// these spans, discarding the zero padding).
    pub fn pack(&self, capacity: usize) -> (Vec<f32>, Vec<(usize, usize)>) {
        debug_assert!(
            self.elements <= capacity,
            "batch overflow: {} packed elements > capacity {capacity}",
            self.elements
        );
        let mut flat = Vec::with_capacity(capacity.max(self.elements));
        let mut spans = Vec::with_capacity(self.requests.len());
        for req in &self.requests {
            spans.push((flat.len(), req.values.len()));
            flat.extend_from_slice(&req.values);
        }
        // Never shrink: an overfull batch (admission bug) must keep its
        // spans valid rather than silently truncating the tail request.
        if flat.len() < capacity {
            flat.resize(capacity, 0.0);
        }
        (flat, spans)
    }

    /// Takes the batch, leaving an empty one.
    pub fn take(&mut self) -> PendingBatch {
        std::mem::take(self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::approx::{MethodId, MethodSpec};
    use std::sync::mpsc;

    fn req(n: usize) -> Request {
        let (tx, _rx) = mpsc::channel();
        Request {
            id: 0,
            spec: MethodSpec::table1(MethodId::Pwl),
            values: vec![0.5; n],
            enqueued_at: Instant::now(),
            reply: tx,
        }
    }

    #[test]
    fn packs_head_to_tail_with_padding() {
        let mut b = PendingBatch::default();
        b.push(req(3));
        b.push(req(5));
        let (flat, spans) = b.pack(16);
        assert_eq!(flat.len(), 16);
        assert_eq!(spans, vec![(0, 3), (3, 5)]);
        assert_eq!(&flat[8..], &[0.0; 8]);
    }

    #[test]
    fn flushes_when_full() {
        let cfg = BatcherConfig { batch_elements: 8, ..Default::default() };
        let mut b = PendingBatch::default();
        b.push(req(8));
        assert!(b.should_flush(&cfg, Instant::now()));
    }

    #[test]
    fn flushes_on_timeout_only_when_nonempty() {
        let cfg = BatcherConfig { max_wait: Duration::from_millis(1), ..Default::default() };
        let b = PendingBatch::default();
        assert!(!b.should_flush(&cfg, Instant::now() + Duration::from_secs(1)));
        let mut b = PendingBatch::default();
        b.push(req(1));
        assert!(!b.should_flush(&cfg, Instant::now()));
        assert!(b.should_flush(&cfg, Instant::now() + Duration::from_millis(5)));
    }

    #[test]
    fn fits_respects_capacity() {
        let mut b = PendingBatch::default();
        b.push(req(1000));
        assert!(b.fits(&req(24), 1024));
        assert!(!b.fits(&req(25), 1024));
    }

    #[test]
    fn fill_rate_tracks_packed_fraction() {
        let mut b = PendingBatch::default();
        assert_eq!(b.fill_rate(1024), 0.0);
        b.push(req(256));
        assert!((b.fill_rate(1024) - 0.25).abs() < 1e-12);
        b.push(req(768));
        assert_eq!(b.fill_rate(1024), 1.0);
        assert_eq!(b.fill_rate(0), 1.0); // degenerate capacity is benign
    }
}
