//! Log-bucketed latency histogram: the percentile substrate behind
//! [`super::ServerMetrics`].
//!
//! A sum/max pair (the pre-shard metrics) cannot answer the questions a
//! serving layer is tuned by — "what does the p99 do when the batcher
//! config changes?" — so latencies are recorded into fixed log₂ buckets
//! instead: values below 16 µs get exact single-value buckets, larger
//! values share one bucket per power of two up to `u64::MAX`. Bucket
//! counts are exact integers, which gives the two properties the
//! sharded coordinator needs:
//!
//! - **recording is lock-free** (one atomic increment per sample, no
//!   sorted reservoir), so per-shard recording never serializes the
//!   reply path;
//! - **merging shards is exact**: adding two histograms' bucket counts
//!   yields bit-identically the histogram of the combined sample
//!   stream, so the coordinator's merged snapshot is not an
//!   approximation of per-shard state (property-tested below).
//!
//! Percentiles interpolate linearly inside a bucket, clamped to the
//! observed `[min, max]`, so single-sample and all-equal-sample
//! distributions report exact values rather than bucket midpoints.

use std::sync::atomic::{AtomicU64, Ordering};

/// Number of buckets: 16 exact buckets for values 0‥15, then one
/// bucket per power of two (2⁴‥2⁶⁴), covering all of `u64`.
pub const LATENCY_BUCKETS: usize = 76;

/// Bucket index for a value (total order, contiguous coverage).
#[inline]
pub fn bucket_index(v: u64) -> usize {
    if v < 16 {
        v as usize
    } else {
        11 + (64 - v.leading_zeros() as usize)
    }
}

/// Inclusive `(lo, hi)` value bounds of bucket `i`.
pub fn bucket_bounds(i: usize) -> (u64, u64) {
    assert!(i < LATENCY_BUCKETS, "bucket {i} out of range");
    if i < 16 {
        (i as u64, i as u64)
    } else {
        let bits = (i - 11) as u32;
        let lo = 1u64 << (bits - 1);
        let hi = lo.checked_mul(2).map_or(u64::MAX, |x| x - 1);
        (lo, hi)
    }
}

/// Atomic histogram for concurrent recording (one per shard).
#[derive(Debug)]
pub struct AtomicHistogram {
    counts: [AtomicU64; LATENCY_BUCKETS],
    count: AtomicU64,
    sum: AtomicU64,
    min: AtomicU64,
    max: AtomicU64,
}

impl Default for AtomicHistogram {
    fn default() -> Self {
        AtomicHistogram {
            counts: std::array::from_fn(|_| AtomicU64::new(0)),
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
            min: AtomicU64::new(u64::MAX),
            max: AtomicU64::new(0),
        }
    }
}

impl AtomicHistogram {
    /// Records one sample.
    pub fn record(&self, v: u64) {
        self.counts[bucket_index(v)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
        self.min.fetch_min(v, Ordering::Relaxed);
        self.max.fetch_max(v, Ordering::Relaxed);
    }

    /// Point-in-time copy.
    pub fn snapshot(&self) -> LatencyHistogram {
        LatencyHistogram {
            counts: std::array::from_fn(|i| self.counts[i].load(Ordering::Relaxed)),
            count: self.count.load(Ordering::Relaxed),
            sum: self.sum.load(Ordering::Relaxed),
            min: self.min.load(Ordering::Relaxed),
            max: self.max.load(Ordering::Relaxed),
        }
    }
}

/// Immutable histogram snapshot: bucket counts plus exact
/// count/sum/min/max (an empty histogram has `min == u64::MAX`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct LatencyHistogram {
    /// Per-bucket sample counts (see [`bucket_bounds`]).
    pub counts: [u64; LATENCY_BUCKETS],
    /// Total samples.
    pub count: u64,
    /// Exact sum of all samples.
    pub sum: u64,
    /// Smallest recorded sample (`u64::MAX` when empty).
    pub min: u64,
    /// Largest recorded sample.
    pub max: u64,
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        LatencyHistogram {
            counts: [0; LATENCY_BUCKETS],
            count: 0,
            sum: 0,
            min: u64::MAX,
            max: 0,
        }
    }
}

impl LatencyHistogram {
    /// Records one sample (non-atomic builder, used by tests and
    /// reference computations).
    pub fn record(&mut self, v: u64) {
        self.counts[bucket_index(v)] += 1;
        self.count += 1;
        self.sum += v;
        self.min = self.min.min(v);
        self.max = self.max.max(v);
    }

    /// Builds a histogram from a sample slice.
    pub fn from_samples(samples: &[u64]) -> LatencyHistogram {
        let mut h = LatencyHistogram::default();
        for &v in samples {
            h.record(v);
        }
        h
    }

    /// Adds another histogram's samples into this one. Exact: the
    /// result equals [`Self::from_samples`] over the concatenation.
    pub fn merge(&mut self, other: &LatencyHistogram) {
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += *b;
        }
        self.count += other.count;
        self.sum += other.sum;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }

    /// Exact mean (0 when empty).
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum as f64 / self.count as f64
        }
    }

    /// Quantile `q ∈ [0, 1]` with linear interpolation inside the
    /// containing bucket, clamped to the observed `[min, max]`.
    /// Returns 0 for an empty histogram.
    pub fn percentile(&self, q: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let q = if q.is_nan() { 1.0 } else { q.clamp(0.0, 1.0) };
        let target = q * self.count as f64;
        let mut cum = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            if c == 0 {
                continue;
            }
            let next = cum + c;
            if next as f64 >= target {
                let (blo, bhi) = bucket_bounds(i);
                // Clamp to observed extrema so degenerate distributions
                // (one sample, all-equal samples) are exact.
                let lo = blo.max(self.min);
                let hi = bhi.min(self.max);
                if hi <= lo {
                    return lo as f64;
                }
                let frac = ((target - cum as f64) / c as f64).clamp(0.0, 1.0);
                return lo as f64 + (hi - lo) as f64 * frac;
            }
            cum = next;
        }
        self.max as f64
    }

    /// Median.
    pub fn p50(&self) -> f64 {
        self.percentile(0.50)
    }

    /// 95th percentile.
    pub fn p95(&self) -> f64 {
        self.percentile(0.95)
    }

    /// 99th percentile.
    pub fn p99(&self) -> f64 {
        self.percentile(0.99)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Prng;

    #[test]
    fn buckets_are_contiguous_and_cover_u64() {
        // bucket 0 starts at 0, the last ends at u64::MAX, and every
        // boundary is adjacent to the next bucket's start.
        assert_eq!(bucket_bounds(0), (0, 0));
        assert_eq!(bucket_bounds(LATENCY_BUCKETS - 1).1, u64::MAX);
        for i in 0..LATENCY_BUCKETS - 1 {
            let (lo, hi) = bucket_bounds(i);
            assert!(lo <= hi, "bucket {i}");
            assert_eq!(hi + 1, bucket_bounds(i + 1).0, "gap after bucket {i}");
        }
    }

    #[test]
    fn bucket_index_matches_bounds() {
        let mut g = Prng::new(3);
        for _ in 0..10_000 {
            // Exercise all magnitudes, not just uniform-u64 ones.
            let v = g.next_u64() >> g.usize_below(64);
            let i = bucket_index(v);
            let (lo, hi) = bucket_bounds(i);
            assert!(lo <= v && v <= hi, "v={v} bucket {i} [{lo}, {hi}]");
        }
        // Exact small buckets and the first log bucket.
        assert_eq!(bucket_index(0), 0);
        assert_eq!(bucket_index(15), 15);
        assert_eq!(bucket_index(16), 16);
        assert_eq!(bucket_bounds(16), (16, 31));
        assert_eq!(bucket_index(u64::MAX), LATENCY_BUCKETS - 1);
    }

    #[test]
    fn percentile_of_empty_is_zero() {
        let h = LatencyHistogram::default();
        assert_eq!(h.percentile(0.5), 0.0);
        assert_eq!(h.mean(), 0.0);
        assert_eq!(h.count, 0);
    }

    #[test]
    fn percentile_of_single_sample_is_exact() {
        for v in [0u64, 7, 100, 5_000_000] {
            let h = LatencyHistogram::from_samples(&[v]);
            for q in [0.0, 0.5, 0.95, 0.99, 1.0] {
                assert_eq!(h.percentile(q), v as f64, "v={v} q={q}");
            }
            assert_eq!(h.mean(), v as f64);
            assert_eq!(h.min, v);
            assert_eq!(h.max, v);
        }
    }

    #[test]
    fn percentile_of_all_equal_samples_is_exact() {
        // min/max clamping collapses the containing bucket to the one
        // observed value, whatever the bucket's nominal width.
        let h = LatencyHistogram::from_samples(&[421; 1000]);
        for q in [0.01, 0.5, 0.95, 0.99, 1.0] {
            assert_eq!(h.percentile(q), 421.0, "q={q}");
        }
    }

    #[test]
    fn percentiles_are_monotone_and_bounded() {
        let mut g = Prng::new(17);
        let samples: Vec<u64> = (0..5000).map(|_| g.u64_below(1 << 20)).collect();
        let h = LatencyHistogram::from_samples(&samples);
        let mut prev = 0.0;
        for i in 0..=100 {
            let p = h.percentile(i as f64 / 100.0);
            assert!(p >= prev, "non-monotone at q={i}");
            assert!(p >= h.min as f64 && p <= h.max as f64);
            prev = p;
        }
    }

    #[test]
    fn percentile_interpolation_tracks_exact_quantiles() {
        // Log buckets bound the relative error: the reported quantile
        // must land within the true quantile's bucket neighborhood
        // (factor-2 band above 16, exact below).
        let mut g = Prng::new(23);
        let mut samples: Vec<u64> = (0..4096).map(|_| g.u64_below(100_000)).collect();
        let h = LatencyHistogram::from_samples(&samples);
        samples.sort_unstable();
        for q in [0.5, 0.95, 0.99] {
            let exact = samples[((q * samples.len() as f64) as usize).min(samples.len() - 1)];
            let got = h.percentile(q);
            assert!(
                got >= exact as f64 / 2.0 && got <= exact as f64 * 2.0 + 16.0,
                "q={q}: interpolated {got} vs exact {exact}"
            );
        }
    }

    #[test]
    fn small_exact_buckets_give_exact_percentiles() {
        // All samples < 16 land in single-value buckets: every quantile
        // is a real sample value.
        let h = LatencyHistogram::from_samples(&[1, 2, 3, 4, 5, 6, 7, 8, 9, 10]);
        assert_eq!(h.p50(), 5.0);
        assert_eq!(h.percentile(1.0), 10.0);
        assert_eq!(h.percentile(0.1), 1.0);
    }

    #[test]
    fn merge_equals_histogram_of_merged_samples() {
        // The shard-merge property: merging per-shard histograms is
        // bit-identical to histogramming the union of samples.
        let mut g = Prng::new(41);
        for _round in 0..20 {
            let shards = 2 + g.usize_below(5);
            let mut all: Vec<u64> = Vec::new();
            let mut merged = LatencyHistogram::default();
            for _ in 0..shards {
                let n = g.usize_below(400);
                let samples: Vec<u64> =
                    (0..n).map(|_| g.next_u64() >> g.usize_below(56)).collect();
                merged.merge(&LatencyHistogram::from_samples(&samples));
                all.extend_from_slice(&samples);
            }
            assert_eq!(merged, LatencyHistogram::from_samples(&all));
        }
    }

    #[test]
    fn atomic_histogram_matches_reference() {
        let a = AtomicHistogram::default();
        let mut reference = LatencyHistogram::default();
        let mut g = Prng::new(55);
        for _ in 0..2000 {
            let v = g.u64_below(1 << 30);
            a.record(v);
            reference.record(v);
        }
        assert_eq!(a.snapshot(), reference);
    }

    #[test]
    fn merge_with_empty_is_identity() {
        let h = LatencyHistogram::from_samples(&[3, 99, 1024]);
        let mut merged = h;
        merged.merge(&LatencyHistogram::default());
        assert_eq!(merged, h);
        let mut from_empty = LatencyHistogram::default();
        from_empty.merge(&h);
        assert_eq!(from_empty, h);
    }
}
