//! Lock-free-ish serving metrics (atomics; snapshot on demand).

use std::sync::atomic::{AtomicU64, Ordering};

/// Cumulative counters for one coordinator.
#[derive(Debug, Default)]
pub struct ServerMetrics {
    requests: AtomicU64,
    elements: AtomicU64,
    batches: AtomicU64,
    rejected: AtomicU64,
    errors: AtomicU64,
    latency_us_sum: AtomicU64,
    latency_us_max: AtomicU64,
    padded_elements: AtomicU64,
    packed_elements: AtomicU64,
    capacity_elements: AtomicU64,
}

/// A point-in-time copy of the counters.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct MetricsSnapshot {
    /// Completed requests.
    pub requests: u64,
    /// Total activation elements processed.
    pub elements: u64,
    /// Executed batches.
    pub batches: u64,
    /// Requests rejected by backpressure.
    pub rejected: u64,
    /// Failed executions.
    pub errors: u64,
    /// Sum of per-request latency (µs).
    pub latency_us_sum: u64,
    /// Max per-request latency (µs).
    pub latency_us_max: u64,
    /// Zero-pad elements wasted by fixed-shape batching.
    pub padded_elements: u64,
    /// Useful elements packed into executed batches (counted at flush,
    /// so it includes batches whose execution later failed — unlike
    /// `elements`, which only counts completed requests).
    pub packed_elements: u64,
    /// Total element capacity of executed batches (batches × capacity).
    pub capacity_elements: u64,
}

impl MetricsSnapshot {
    /// Mean request latency in microseconds.
    pub fn mean_latency_us(&self) -> f64 {
        if self.requests == 0 {
            0.0
        } else {
            self.latency_us_sum as f64 / self.requests as f64
        }
    }

    /// Mean batch occupancy (useful elements / capacity-elements).
    pub fn batch_efficiency(&self) -> f64 {
        let total = self.elements + self.padded_elements;
        if total == 0 {
            1.0
        } else {
            self.elements as f64 / total as f64
        }
    }

    /// Batch fill rate: packed elements / batch capacity, measured at
    /// flush time. This is the padding-waste observable — a fill rate
    /// of 0.06 means 94% of every executed batch was zero padding
    /// (exactly the pathology the greedy drain fixed, EXPERIMENTS.md
    /// §Perf iteration 1).
    pub fn fill_rate(&self) -> f64 {
        if self.capacity_elements == 0 {
            1.0
        } else {
            self.packed_elements as f64 / self.capacity_elements as f64
        }
    }
}

impl ServerMetrics {
    /// Records a completed request.
    pub fn record_request(&self, elements: usize, latency_us: u64) {
        self.requests.fetch_add(1, Ordering::Relaxed);
        self.elements.fetch_add(elements as u64, Ordering::Relaxed);
        self.latency_us_sum.fetch_add(latency_us, Ordering::Relaxed);
        self.latency_us_max.fetch_max(latency_us, Ordering::Relaxed);
    }

    /// Records an executed batch: how many useful elements were packed
    /// and the batch's element capacity (the difference is padding).
    pub fn record_batch(&self, packed: usize, capacity: usize) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.packed_elements.fetch_add(packed as u64, Ordering::Relaxed);
        self.capacity_elements.fetch_add(capacity as u64, Ordering::Relaxed);
        self.padded_elements.fetch_add(capacity.saturating_sub(packed) as u64, Ordering::Relaxed);
    }

    /// Records a backpressure rejection.
    pub fn record_rejected(&self) {
        self.rejected.fetch_add(1, Ordering::Relaxed);
    }

    /// Records an execution error.
    pub fn record_error(&self) {
        self.errors.fetch_add(1, Ordering::Relaxed);
    }

    /// Snapshots all counters.
    pub fn snapshot(&self) -> MetricsSnapshot {
        MetricsSnapshot {
            requests: self.requests.load(Ordering::Relaxed),
            elements: self.elements.load(Ordering::Relaxed),
            batches: self.batches.load(Ordering::Relaxed),
            rejected: self.rejected.load(Ordering::Relaxed),
            errors: self.errors.load(Ordering::Relaxed),
            latency_us_sum: self.latency_us_sum.load(Ordering::Relaxed),
            latency_us_max: self.latency_us_max.load(Ordering::Relaxed),
            padded_elements: self.padded_elements.load(Ordering::Relaxed),
            packed_elements: self.packed_elements.load(Ordering::Relaxed),
            capacity_elements: self.capacity_elements.load(Ordering::Relaxed),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_snapshots() {
        let m = ServerMetrics::default();
        m.record_request(100, 50);
        m.record_request(50, 150);
        m.record_batch(150, 1024);
        m.record_rejected();
        let s = m.snapshot();
        assert_eq!(s.requests, 2);
        assert_eq!(s.elements, 150);
        assert_eq!(s.batches, 1);
        assert_eq!(s.rejected, 1);
        assert_eq!(s.mean_latency_us(), 100.0);
        assert_eq!(s.latency_us_max, 150);
        assert_eq!(s.padded_elements, 874);
        assert!((s.batch_efficiency() - 150.0 / 1024.0).abs() < 1e-9);
        assert!((s.fill_rate() - 150.0 / 1024.0).abs() < 1e-9);
    }

    #[test]
    fn fill_rate_counts_failed_batches_too() {
        // A batch that packs elements but whose execution errors still
        // consumed capacity: fill_rate sees it, batch_efficiency (built
        // on completed requests) does not.
        let m = ServerMetrics::default();
        m.record_batch(512, 1024);
        m.record_error();
        let s = m.snapshot();
        assert_eq!(s.packed_elements, 512);
        assert_eq!(s.capacity_elements, 1024);
        assert!((s.fill_rate() - 0.5).abs() < 1e-9);
        assert_eq!(s.elements, 0);
    }

    #[test]
    fn empty_snapshot_is_benign() {
        let s = ServerMetrics::default().snapshot();
        assert_eq!(s.mean_latency_us(), 0.0);
        assert_eq!(s.batch_efficiency(), 1.0);
        assert_eq!(s.fill_rate(), 1.0);
    }
}
