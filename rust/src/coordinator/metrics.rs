//! Lock-free-ish serving metrics (atomics; snapshot on demand).
//!
//! Each worker shard owns one [`ServerMetrics`]; the coordinator's
//! merged view is a fold of per-shard [`MetricsSnapshot`]s
//! ([`MetricsSnapshot::merge`]), which is exact — counters add and the
//! latency histogram merge is bit-identical to histogramming the
//! combined sample stream (see [`super::histogram`]).
//!
//! The counters are chosen so conservation laws hold once traffic has
//! drained: `submitted == requests + failed_requests`, `failed_requests
//! == backend_failed_requests + admission_failed_requests`, and every
//! submit attempt that passes input validation is either `submitted` or
//! `rejected` (validation failures — empty or oversized requests,
//! unknown methods — are client errors returned before routing and are
//! deliberately not counted as load shedding). The stress tests
//! (`tests/serving.rs`) assert this per shard and merged.

use std::sync::atomic::{AtomicU64, Ordering};

use super::histogram::{AtomicHistogram, LatencyHistogram};

/// Cumulative counters for one worker shard (or one whole coordinator,
/// after merging).
#[derive(Debug, Default)]
pub struct ServerMetrics {
    submitted: AtomicU64,
    requests: AtomicU64,
    backend_failed_requests: AtomicU64,
    admission_failed_requests: AtomicU64,
    elements: AtomicU64,
    batches: AtomicU64,
    packed_batches: AtomicU64,
    rejected: AtomicU64,
    errors: AtomicU64,
    latency: AtomicHistogram,
    padded_elements: AtomicU64,
    packed_elements: AtomicU64,
    capacity_elements: AtomicU64,
    sim_cycles: AtomicU64,
}

/// A point-in-time copy of the counters.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct MetricsSnapshot {
    /// Requests accepted into a shard queue.
    pub submitted: u64,
    /// Requests completed successfully.
    pub requests: u64,
    /// Requests that received an error reply. `submitted == requests +
    /// failed_requests` once in-flight traffic has drained, and
    /// `failed_requests == backend_failed_requests +
    /// admission_failed_requests` always.
    pub failed_requests: u64,
    /// Requests failed by the worker's backend (execution fault,
    /// unavailable substrate) — `RequestErrorKind::Backend`.
    pub backend_failed_requests: u64,
    /// Requests failed by batcher/router admission after queueing (the
    /// worker's oversized-request guard) —
    /// `RequestErrorKind::Admission`.
    pub admission_failed_requests: u64,
    /// Total simulated hardware cycles reported by the backend
    /// ([`crate::backend::EvalStats::sim_cycles`]) — the hw backend's
    /// simulated-latency column; zero on backends without a cycle
    /// model.
    pub sim_cycles: u64,
    /// Total activation elements processed.
    pub elements: u64,
    /// Executed batches.
    pub batches: u64,
    /// Executed batches the backend evaluated on the SWAR packed-lane
    /// kernel path ([`crate::backend::EvalStats::packed`]) — an
    /// additive per-shard counter like `batches`, of which it is a
    /// subset. `batches − packed_batches` ran scalar (non-qualifying
    /// formats, or a backend without a packed path).
    pub packed_batches: u64,
    /// Requests rejected by backpressure (never entered a queue).
    pub rejected: u64,
    /// Failed batch executions.
    pub errors: u64,
    /// Log-bucketed per-request latency histogram (µs): p50/p95/p99,
    /// exact mean/min/max. Replaces the old sum/max pair.
    pub latency: LatencyHistogram,
    /// Zero-pad elements wasted by fixed-shape batching.
    pub padded_elements: u64,
    /// Useful elements packed into executed batches (counted at flush,
    /// so it includes batches whose execution later failed — unlike
    /// `elements`, which only counts completed requests).
    pub packed_elements: u64,
    /// Total element capacity of executed batches (batches × capacity).
    pub capacity_elements: u64,
    /// Compiled-kernel cache hits — a process-global **gauge** (from
    /// [`crate::approx::Registry::global`]), not a per-shard counter:
    /// filled by `Coordinator::metrics`, zero in per-shard snapshots,
    /// and merged by max (never summed) so folding snapshots that both
    /// carry the global value cannot double-count it.
    pub kernel_cache_hits: u64,
    /// Kernel compilations performed (process-global gauge, max-merged
    /// like `kernel_cache_hits`; the shared-cache win is
    /// `kernel_compiles == distinct specs`, independent of shard
    /// count).
    pub kernel_compiles: u64,
    /// Connections accepted by the net front-end since it started — a
    /// server-global **gauge** (from the event loop's counters), not a
    /// per-shard counter: filled by the net layer, zero in per-shard
    /// snapshots, max-merged like the kernel-cache gauges.
    pub accepted_conns: u64,
    /// Connections currently open on the net front-end (server-global
    /// gauge, max-merged).
    pub active_conns: u64,
    /// Request bytes the net front-end has read off sockets
    /// (server-global gauge, max-merged).
    pub net_bytes_in: u64,
    /// Reply bytes the net front-end has written to sockets
    /// (server-global gauge, max-merged).
    pub net_bytes_out: u64,
    /// Streaming sessions currently open on the coordinator — a
    /// coordinator-global **gauge** (from the session table), filled by
    /// `Coordinator::metrics`, zero in per-shard snapshots, max-merged
    /// like the other gauges.
    pub sessions_open: u64,
    /// Streaming sessions evicted by the idle-timeout sweep since
    /// start (coordinator-global gauge, max-merged). Explicit closes
    /// and connection-drop teardowns are not evictions.
    pub sessions_evicted: u64,
}

impl MetricsSnapshot {
    /// Mean request latency in microseconds (completed + failed).
    pub fn mean_latency_us(&self) -> f64 {
        self.latency.mean()
    }

    /// Max request latency in microseconds.
    pub fn latency_us_max(&self) -> u64 {
        self.latency.max
    }

    /// Median request latency in microseconds.
    pub fn p50_us(&self) -> f64 {
        self.latency.p50()
    }

    /// 95th-percentile request latency in microseconds.
    pub fn p95_us(&self) -> f64 {
        self.latency.p95()
    }

    /// 99th-percentile request latency in microseconds.
    pub fn p99_us(&self) -> f64 {
        self.latency.p99()
    }

    /// Steady-state simulated cycles per element actually fed to the
    /// backend (batch capacity, padding included): the hw backend's
    /// streaming observable. A warm streaming worker approaches 1.0
    /// (one retire per cycle, §IV.H) with the pipeline fill amortized
    /// across the run; a per-batch re-filling worker pays
    /// `(latency − 1) / batch` extra on every batch. Zero on backends
    /// without a cycle model.
    pub fn sim_cycles_per_element(&self) -> f64 {
        if self.capacity_elements == 0 {
            0.0
        } else {
            self.sim_cycles as f64 / self.capacity_elements as f64
        }
    }

    /// Mean batch occupancy (useful elements / capacity-elements).
    pub fn batch_efficiency(&self) -> f64 {
        let total = self.elements + self.padded_elements;
        if total == 0 {
            1.0
        } else {
            self.elements as f64 / total as f64
        }
    }

    /// Batch fill rate: packed elements / batch capacity, measured at
    /// flush time. This is the padding-waste observable — a fill rate
    /// of 0.06 means 94% of every executed batch was zero padding
    /// (exactly the pathology the greedy drain fixed, EXPERIMENTS.md
    /// §Perf iteration 1).
    pub fn fill_rate(&self) -> f64 {
        if self.capacity_elements == 0 {
            1.0
        } else {
            self.packed_elements as f64 / self.capacity_elements as f64
        }
    }

    /// Adds another snapshot's counters into this one (shard merge).
    /// Exact for every field, including the latency histogram.
    pub fn merge(mut self, other: &MetricsSnapshot) -> MetricsSnapshot {
        self.submitted += other.submitted;
        self.requests += other.requests;
        self.failed_requests += other.failed_requests;
        self.backend_failed_requests += other.backend_failed_requests;
        self.admission_failed_requests += other.admission_failed_requests;
        self.sim_cycles += other.sim_cycles;
        self.elements += other.elements;
        self.batches += other.batches;
        self.packed_batches += other.packed_batches;
        self.rejected += other.rejected;
        self.errors += other.errors;
        self.latency.merge(&other.latency);
        self.padded_elements += other.padded_elements;
        self.packed_elements += other.packed_elements;
        self.capacity_elements += other.capacity_elements;
        // Process-global gauges, not additive counters: two snapshots
        // carrying the same global cache state must merge to that
        // state, not double it.
        self.kernel_cache_hits = self.kernel_cache_hits.max(other.kernel_cache_hits);
        self.kernel_compiles = self.kernel_compiles.max(other.kernel_compiles);
        // Net-layer gauges are server-global too (one event loop per
        // server process).
        self.accepted_conns = self.accepted_conns.max(other.accepted_conns);
        self.active_conns = self.active_conns.max(other.active_conns);
        self.net_bytes_in = self.net_bytes_in.max(other.net_bytes_in);
        self.net_bytes_out = self.net_bytes_out.max(other.net_bytes_out);
        // Session gauges live on the coordinator's session table (one
        // per coordinator), same max-merge rationale.
        self.sessions_open = self.sessions_open.max(other.sessions_open);
        self.sessions_evicted = self.sessions_evicted.max(other.sessions_evicted);
        self
    }
}

impl ServerMetrics {
    /// Records a request accepted into the shard queue.
    pub fn record_submitted(&self) {
        self.submitted.fetch_add(1, Ordering::Relaxed);
    }

    /// Records a successfully completed request.
    pub fn record_request(&self, elements: usize, latency_us: u64) {
        self.requests.fetch_add(1, Ordering::Relaxed);
        self.elements.fetch_add(elements as u64, Ordering::Relaxed);
        self.latency.record(latency_us);
    }

    /// Records a request failed by the worker's backend.
    pub fn record_backend_failed_request(&self, latency_us: u64) {
        self.backend_failed_requests.fetch_add(1, Ordering::Relaxed);
        self.latency.record(latency_us);
    }

    /// Records a request failed by batcher admission (post-queue).
    pub fn record_admission_failed_request(&self, latency_us: u64) {
        self.admission_failed_requests.fetch_add(1, Ordering::Relaxed);
        self.latency.record(latency_us);
    }

    /// Records simulated hardware cycles a batch occupied the backend.
    pub fn record_sim_cycles(&self, cycles: u64) {
        self.sim_cycles.fetch_add(cycles, Ordering::Relaxed);
    }

    /// Records an executed batch: how many useful elements were packed
    /// and the batch's element capacity (the difference is padding).
    pub fn record_batch(&self, packed: usize, capacity: usize) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.packed_elements.fetch_add(packed as u64, Ordering::Relaxed);
        self.capacity_elements.fetch_add(capacity as u64, Ordering::Relaxed);
        self.padded_elements.fetch_add(capacity.saturating_sub(packed) as u64, Ordering::Relaxed);
    }

    /// Records a batch the backend executed on the packed kernel path.
    pub fn record_packed_batch(&self) {
        self.packed_batches.fetch_add(1, Ordering::Relaxed);
    }

    /// Records a backpressure rejection.
    pub fn record_rejected(&self) {
        self.rejected.fetch_add(1, Ordering::Relaxed);
    }

    /// Records an execution error.
    pub fn record_error(&self) {
        self.errors.fetch_add(1, Ordering::Relaxed);
    }

    /// Snapshots all counters. `failed_requests` is the sum of the two
    /// failure-kind counters, so the split conservation law holds by
    /// construction.
    pub fn snapshot(&self) -> MetricsSnapshot {
        let backend_failed = self.backend_failed_requests.load(Ordering::Relaxed);
        let admission_failed = self.admission_failed_requests.load(Ordering::Relaxed);
        MetricsSnapshot {
            submitted: self.submitted.load(Ordering::Relaxed),
            requests: self.requests.load(Ordering::Relaxed),
            failed_requests: backend_failed + admission_failed,
            backend_failed_requests: backend_failed,
            admission_failed_requests: admission_failed,
            sim_cycles: self.sim_cycles.load(Ordering::Relaxed),
            elements: self.elements.load(Ordering::Relaxed),
            batches: self.batches.load(Ordering::Relaxed),
            packed_batches: self.packed_batches.load(Ordering::Relaxed),
            rejected: self.rejected.load(Ordering::Relaxed),
            errors: self.errors.load(Ordering::Relaxed),
            latency: self.latency.snapshot(),
            padded_elements: self.padded_elements.load(Ordering::Relaxed),
            packed_elements: self.packed_elements.load(Ordering::Relaxed),
            capacity_elements: self.capacity_elements.load(Ordering::Relaxed),
            // Kernel-cache counters are process-global, not per-shard:
            // `Coordinator::metrics` fills them from Registry::global.
            kernel_cache_hits: 0,
            kernel_compiles: 0,
            // Net gauges are server-global: the net front-end fills
            // them from its event loop's counters.
            accepted_conns: 0,
            active_conns: 0,
            net_bytes_in: 0,
            net_bytes_out: 0,
            // Session gauges are coordinator-global: filled by
            // `Coordinator::metrics` from the session table.
            sessions_open: 0,
            sessions_evicted: 0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_snapshots() {
        let m = ServerMetrics::default();
        m.record_submitted();
        m.record_submitted();
        m.record_request(100, 50);
        m.record_request(50, 150);
        m.record_batch(150, 1024);
        m.record_rejected();
        let s = m.snapshot();
        assert_eq!(s.submitted, 2);
        assert_eq!(s.requests, 2);
        assert_eq!(s.elements, 150);
        assert_eq!(s.batches, 1);
        assert_eq!(s.rejected, 1);
        assert_eq!(s.mean_latency_us(), 100.0);
        assert_eq!(s.latency_us_max(), 150);
        assert_eq!(s.latency.min, 50);
        assert_eq!(s.padded_elements, 874);
        assert!((s.batch_efficiency() - 150.0 / 1024.0).abs() < 1e-9);
        assert!((s.fill_rate() - 150.0 / 1024.0).abs() < 1e-9);
        // Both samples bound the percentiles.
        assert!(s.p50_us() >= 50.0 && s.p50_us() <= 150.0);
        assert!(s.p99_us() >= s.p50_us() && s.p99_us() <= 150.0);
    }

    #[test]
    fn conservation_counters_reconcile() {
        let m = ServerMetrics::default();
        for _ in 0..5 {
            m.record_submitted();
        }
        m.record_request(10, 20);
        m.record_request(10, 30);
        m.record_request(10, 40);
        m.record_backend_failed_request(25);
        m.record_admission_failed_request(35);
        let s = m.snapshot();
        assert_eq!(s.submitted, s.requests + s.failed_requests);
        // The failure-kind split reconciles with the total by
        // construction.
        assert_eq!(s.backend_failed_requests, 1);
        assert_eq!(s.admission_failed_requests, 1);
        assert_eq!(s.failed_requests, s.backend_failed_requests + s.admission_failed_requests);
        // Failed requests still contribute latency samples.
        assert_eq!(s.latency.count, 5);
    }

    #[test]
    fn fill_rate_counts_failed_batches_too() {
        // A batch that packs elements but whose execution errors still
        // consumed capacity: fill_rate sees it, batch_efficiency (built
        // on completed requests) does not.
        let m = ServerMetrics::default();
        m.record_batch(512, 1024);
        m.record_error();
        let s = m.snapshot();
        assert_eq!(s.packed_elements, 512);
        assert_eq!(s.capacity_elements, 1024);
        assert!((s.fill_rate() - 0.5).abs() < 1e-9);
        assert_eq!(s.elements, 0);
    }

    #[test]
    fn merge_is_exact_across_shards() {
        let a = ServerMetrics::default();
        let b = ServerMetrics::default();
        a.record_submitted();
        a.record_request(64, 10);
        a.record_batch(64, 128);
        b.record_submitted();
        b.record_submitted();
        b.record_request(32, 200);
        b.record_backend_failed_request(300);
        b.record_batch(32, 128);
        b.record_rejected();
        b.record_error();
        b.record_sim_cycles(40);
        assert!((b.snapshot().sim_cycles_per_element() - 40.0 / 128.0).abs() < 1e-12);

        let merged = a.snapshot().merge(&b.snapshot());
        assert_eq!(merged.submitted, 3);
        assert_eq!(merged.requests, 2);
        assert_eq!(merged.failed_requests, 1);
        assert_eq!(merged.backend_failed_requests, 1);
        assert_eq!(merged.admission_failed_requests, 0);
        assert_eq!(merged.sim_cycles, 40);
        assert_eq!(merged.elements, 96);
        assert_eq!(merged.batches, 2);
        assert_eq!(merged.rejected, 1);
        assert_eq!(merged.errors, 1);
        assert_eq!(merged.capacity_elements, 256);
        // Histogram merged exactly: same as recording all three samples
        // into one histogram.
        use super::super::histogram::LatencyHistogram;
        assert_eq!(merged.latency, LatencyHistogram::from_samples(&[10, 200, 300]));
        // Merge with an empty snapshot is the identity.
        assert_eq!(merged.merge(&MetricsSnapshot::default()), merged);
    }

    #[test]
    fn packed_batches_count_and_merge_additively() {
        let a = ServerMetrics::default();
        let b = ServerMetrics::default();
        a.record_batch(64, 128);
        a.record_packed_batch();
        b.record_batch(64, 128);
        b.record_batch(32, 128);
        b.record_packed_batch();
        b.record_packed_batch();
        let merged = a.snapshot().merge(&b.snapshot());
        assert_eq!(merged.batches, 3);
        // Per-shard counter, so shards add — unlike the cache gauges.
        assert_eq!(merged.packed_batches, 3);
    }

    #[test]
    fn kernel_cache_gauges_merge_by_max_not_sum() {
        // Two coordinator-level snapshots carry the same process-global
        // cache state; merging them must not double-count it.
        let mut a = MetricsSnapshot { kernel_cache_hits: 10, kernel_compiles: 6, ..Default::default() };
        let b = MetricsSnapshot { kernel_cache_hits: 12, kernel_compiles: 6, ..Default::default() };
        a = a.merge(&b);
        assert_eq!(a.kernel_cache_hits, 12);
        assert_eq!(a.kernel_compiles, 6);
    }

    #[test]
    fn net_gauges_merge_by_max_not_sum() {
        // Same pattern as the cache gauges: one event loop per server,
        // so two snapshots carrying its counters must not double them.
        let a = MetricsSnapshot {
            accepted_conns: 8,
            active_conns: 3,
            net_bytes_in: 1000,
            net_bytes_out: 2000,
            ..Default::default()
        };
        let b = MetricsSnapshot {
            accepted_conns: 10,
            active_conns: 2,
            net_bytes_in: 1500,
            net_bytes_out: 1500,
            ..Default::default()
        };
        let m = a.merge(&b);
        assert_eq!(m.accepted_conns, 10);
        assert_eq!(m.active_conns, 3);
        assert_eq!(m.net_bytes_in, 1500);
        assert_eq!(m.net_bytes_out, 2000);
        // Per-shard snapshots leave them zero.
        let s = ServerMetrics::default().snapshot();
        assert_eq!((s.accepted_conns, s.active_conns, s.net_bytes_in, s.net_bytes_out), (0, 0, 0, 0));
    }

    #[test]
    fn session_gauges_merge_by_max_not_sum() {
        // One session table per coordinator: two snapshots carrying its
        // gauges must not double them.
        let a = MetricsSnapshot { sessions_open: 5, sessions_evicted: 2, ..Default::default() };
        let b = MetricsSnapshot { sessions_open: 3, sessions_evicted: 4, ..Default::default() };
        let m = a.merge(&b);
        assert_eq!(m.sessions_open, 5);
        assert_eq!(m.sessions_evicted, 4);
        // Per-shard snapshots leave them zero.
        let s = ServerMetrics::default().snapshot();
        assert_eq!((s.sessions_open, s.sessions_evicted), (0, 0));
    }

    #[test]
    fn empty_snapshot_is_benign() {
        let s = ServerMetrics::default().snapshot();
        assert_eq!(s.mean_latency_us(), 0.0);
        assert_eq!(s.sim_cycles_per_element(), 0.0);
        assert_eq!(s.batch_efficiency(), 1.0);
        assert_eq!(s.fill_rate(), 1.0);
        assert_eq!(s.p50_us(), 0.0);
        assert_eq!(s.p99_us(), 0.0);
        assert_eq!(s.latency_us_max(), 0);
    }
}
