//! Lock-free-ish serving metrics (atomics; snapshot on demand).

use std::sync::atomic::{AtomicU64, Ordering};

/// Cumulative counters for one coordinator.
#[derive(Debug, Default)]
pub struct ServerMetrics {
    requests: AtomicU64,
    elements: AtomicU64,
    batches: AtomicU64,
    rejected: AtomicU64,
    errors: AtomicU64,
    latency_us_sum: AtomicU64,
    latency_us_max: AtomicU64,
    padded_elements: AtomicU64,
}

/// A point-in-time copy of the counters.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct MetricsSnapshot {
    /// Completed requests.
    pub requests: u64,
    /// Total activation elements processed.
    pub elements: u64,
    /// Executed batches.
    pub batches: u64,
    /// Requests rejected by backpressure.
    pub rejected: u64,
    /// Failed executions.
    pub errors: u64,
    /// Sum of per-request latency (µs).
    pub latency_us_sum: u64,
    /// Max per-request latency (µs).
    pub latency_us_max: u64,
    /// Zero-pad elements wasted by fixed-shape batching.
    pub padded_elements: u64,
}

impl MetricsSnapshot {
    /// Mean request latency in microseconds.
    pub fn mean_latency_us(&self) -> f64 {
        if self.requests == 0 {
            0.0
        } else {
            self.latency_us_sum as f64 / self.requests as f64
        }
    }

    /// Mean batch occupancy (useful elements / capacity-elements).
    pub fn batch_efficiency(&self) -> f64 {
        let total = self.elements + self.padded_elements;
        if total == 0 {
            1.0
        } else {
            self.elements as f64 / total as f64
        }
    }
}

impl ServerMetrics {
    /// Records a completed request.
    pub fn record_request(&self, elements: usize, latency_us: u64) {
        self.requests.fetch_add(1, Ordering::Relaxed);
        self.elements.fetch_add(elements as u64, Ordering::Relaxed);
        self.latency_us_sum.fetch_add(latency_us, Ordering::Relaxed);
        self.latency_us_max.fetch_max(latency_us, Ordering::Relaxed);
    }

    /// Records an executed batch and its padding waste.
    pub fn record_batch(&self, padded: usize) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.padded_elements.fetch_add(padded as u64, Ordering::Relaxed);
    }

    /// Records a backpressure rejection.
    pub fn record_rejected(&self) {
        self.rejected.fetch_add(1, Ordering::Relaxed);
    }

    /// Records an execution error.
    pub fn record_error(&self) {
        self.errors.fetch_add(1, Ordering::Relaxed);
    }

    /// Snapshots all counters.
    pub fn snapshot(&self) -> MetricsSnapshot {
        MetricsSnapshot {
            requests: self.requests.load(Ordering::Relaxed),
            elements: self.elements.load(Ordering::Relaxed),
            batches: self.batches.load(Ordering::Relaxed),
            rejected: self.rejected.load(Ordering::Relaxed),
            errors: self.errors.load(Ordering::Relaxed),
            latency_us_sum: self.latency_us_sum.load(Ordering::Relaxed),
            latency_us_max: self.latency_us_max.load(Ordering::Relaxed),
            padded_elements: self.padded_elements.load(Ordering::Relaxed),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_snapshots() {
        let m = ServerMetrics::default();
        m.record_request(100, 50);
        m.record_request(50, 150);
        m.record_batch(874);
        m.record_rejected();
        let s = m.snapshot();
        assert_eq!(s.requests, 2);
        assert_eq!(s.elements, 150);
        assert_eq!(s.batches, 1);
        assert_eq!(s.rejected, 1);
        assert_eq!(s.mean_latency_us(), 100.0);
        assert_eq!(s.latency_us_max, 150);
        assert!((s.batch_efficiency() - 150.0 / 1024.0).abs() < 1e-9);
    }

    #[test]
    fn empty_snapshot_is_benign() {
        let s = ServerMetrics::default().snapshot();
        assert_eq!(s.mean_latency_us(), 0.0);
        assert_eq!(s.batch_efficiency(), 1.0);
    }
}
