//! The activation-accelerator coordinator — L3 of the stack.
//!
//! The paper positions its tanh units inside neural-network
//! accelerators (§I); this module is the driver such an accelerator
//! ships with: a request **router** that steers work to per-method
//! executors, a **dynamic batcher** that packs scalar/short-vector
//! activation requests into the fixed-batch compiled executables
//! (PJRT graphs are compiled per shape), a **worker pool** holding the
//! hot executables, **metrics**, and **backpressure** via a bounded
//! queue.
//!
//! Design notes:
//! - std-thread + mpsc architecture (tokio is not in the offline crate
//!   set); one batcher/worker pair per method keeps the lock surface
//!   per-queue, not global.
//! - The batch size is the compiled executable's shape (default 1024);
//!   partial batches are padded with zeros and sliced on the way out —
//!   the same trick serving systems use for fixed-shape accelerators.
//! - Backpressure: `submit` fails fast once a method's queue holds
//!   `max_queue` pending elements (the caller sheds load instead of the
//!   coordinator dying of memory).

mod batcher;
mod metrics;
mod net;
mod request;
mod server;
mod worker;

pub use batcher::{BatcherConfig, PendingBatch};
pub use metrics::{MetricsSnapshot, ServerMetrics};
pub use request::{Request, RequestResult};
pub use server::{Coordinator, CoordinatorConfig, ExecBackend};
pub use net::{NetClient, NetServer};
pub use worker::{GoldenBackend, GraphBackend};
