//! The activation-accelerator coordinator — L3 of the stack.
//!
//! The paper positions its tanh units inside neural-network
//! accelerators (§I); this module is the driver such an accelerator
//! ships with: a request **router** that steers work to per-method
//! **worker-shard pools**, a **dynamic batcher** per shard that packs
//! scalar/short-vector activation requests into the fixed-batch
//! compiled executables (PJRT graphs are compiled per shape), a
//! **latency-histogram metrics** pipeline, and **backpressure** via
//! bounded per-shard queues.
//!
//! Design notes:
//! - Serving is keyed by [`crate::approx::MethodSpec`]: the coordinator
//!   runs shard pools for every spec in `CoordinatorConfig::specs`
//!   (default: the six Table I rows), so arbitrary (method × parameter
//!   × I/O-format) design points are servable, addressed by spec string
//!   over the net front-end. The golden backend resolves compiled
//!   kernels through the shared [`crate::approx::Registry`] cache —
//!   compiles scale with distinct specs, never with shard count
//!   (observable via `MetricsSnapshot::{kernel_cache_hits,
//!   kernel_compiles}`).
//! - Execution is **backend-addressed**: workers drive any
//!   [`crate::backend::EvalBackend`] — `golden` (compiled kernels),
//!   `hw` (cycle-accurate Fig 3/4/5 datapaths, whose simulated cycle
//!   counts surface as the `sim_cycles` metric), or `pjrt` (AOT
//!   graphs) — and `Coordinator::start` fails fast with a typed
//!   `backend_unavailable`/`unknown_spec` error when the backend
//!   cannot serve, instead of dying request-by-request. The same
//!   scenario trace can therefore be replayed against any backend and
//!   cross-checked (`tests/serving.rs` does, bit-exact golden vs hw).
//! - Failures are typed end to end: [`RequestError`] carries the
//!   stable net-protocol code ([`crate::backend::ErrorCode`]) plus
//!   *where* the request died ([`RequestErrorKind`]: batcher admission
//!   vs worker-side backend), counted separately in [`ServerMetrics`].
//! - std-thread + mpsc architecture (tokio is not in the offline crate
//!   set); each spec runs `CoordinatorConfig::shards` batcher/worker
//!   pairs, fed round-robin or least-loaded ([`RoutePolicy`]), so the
//!   lock surface is per-shard-queue, not global, and a slow batch on
//!   one shard no longer stalls its whole method.
//! - The batch size is the compiled executable's shape (default 1024);
//!   partial batches are padded with zeros and sliced on the way out —
//!   the same trick serving systems use for fixed-shape accelerators.
//! - Backpressure: `submit` fails fast once the routed shard holds
//!   `max_queue` pending elements (the caller sheds load instead of the
//!   coordinator dying of memory).
//! - **Streaming sessions** ([`session`]): a client opens a session
//!   against a served spec (or an LSTM cell graph), feeds fixed pulses
//!   of a long sequence, and the server keeps per-session state warm —
//!   a backend [`crate::backend::EvalStream`] (hw pipeline registers)
//!   or the cell's carried `c` — across pulses, with explicit delay
//!   accounting (`issued`/`delivered`; `close` flushes the tail). All
//!   of a session's work is pinned to shard `id % shards`, so state
//!   never migrates; the table enforces a max-sessions cap
//!   (`overloaded`) and idle-timeout eviction, observable as the
//!   `sessions_open`/`sessions_evicted` gauges.
//! - The TCP front-end ([`NetServer`]) is a single nonblocking event
//!   thread owning per-connection state machines — many concurrent
//!   clients, pipelined requests with in-order replies, per-connection
//!   backpressure chained to the shard queues, and two framings
//!   negotiated by the first byte: JSON lines and length-prefixed
//!   binary frames of raw `i64` words keyed by registered spec id
//!   (no per-request serde cost). Connection/byte gauges surface in
//!   [`MetricsSnapshot`]. See [`net`]'s module doc for the wire
//!   protocol.
//! - Metrics are per-shard ([`ServerMetrics`]) and merge exactly:
//!   latency lives in a log-bucketed histogram
//!   ([`histogram::LatencyHistogram`]) whose shard merge is
//!   bit-identical to histogramming the combined samples, so
//!   `Coordinator::metrics()` reports true p50/p95/p99 across the
//!   fleet. Conservation holds once traffic drains:
//!   `submitted == requests + failed_requests`.
//!
//! Load generation for this layer lives in [`crate::bench::scenario`]:
//! deterministic PRNG-seeded workload scenarios (steady, bursty, Zipf
//! method mix, tiny-request flood, max-size batches) replayed through
//! `tanh-vlsi serve --scenario`, with every reply verified against the
//! compiled golden kernels.

mod batcher;
pub mod histogram;
mod metrics;
pub mod net;
mod request;
mod server;
mod session;

pub use batcher::{BatcherConfig, PendingBatch};
pub use histogram::LatencyHistogram;
pub use metrics::{MetricsSnapshot, ServerMetrics};
pub use net::{
    bin_close_frame, bin_open_frame, bin_request_frame, reply_raws, reply_values,
    try_bin_pulse_frame, try_bin_reply_frame, try_bin_request_frame, BinClient, NetClient,
    NetConfig, NetGaugesSnapshot, NetServer, BIN_CLOSE_MAGIC, BIN_MAX_BODY, BIN_OPEN_MAGIC,
    BIN_PULSE_MAGIC, BIN_REPLY_MAGIC, BIN_REQUEST_MAGIC,
};
pub use request::{Request, RequestError, RequestErrorKind, RequestResult};
pub use server::{Coordinator, CoordinatorConfig, RoutePolicy};
pub use session::{PulseOutcome, SessionConfig, SessionInfo};
