//! TCP front-end: the coordinator as a network service.
//!
//! Line-delimited JSON over TCP (std::net; tokio is not in the offline
//! crate set — one thread per connection, which is fine for an
//! accelerator-driver control plane):
//!
//! ```text
//! → {"method": "pwl", "values": [0.5, -1.25]}
//! ← {"ok": true, "values": [0.4621, -0.8482], "latency_us": 412}
//! → {"spec": "pwl:step=1/32:in=s2.13:out=s.15", "values": [0.5]}
//! ← {"ok": true, "values": [0.4621], "latency_us": 80}
//! → {"backend": "hw", "spec": "pwl:step=1/64:in=S3.12:out=S.15", "values": [0.5]}
//! ← {"ok": true, "values": [0.4621], "latency_us": 95}
//! → {"cmd": "metrics"}
//! ← {"ok": true, "backend": "golden", "requests": 2, ...}
//! ```
//!
//! A `"spec"` key addresses any served design point by its spec string
//! (must be in the coordinator's served set); `"method"` remains the
//! short form for the method's first served spec. An optional
//! `"backend"` key pins any request — evaluations and commands alike —
//! to an execution backend: a coordinator runs exactly one backend per
//! deployment, so a request naming a *different* backend is refused
//! with `backend_unavailable`
//! (clients use it to assert which implementation is answering — e.g.
//! a verifier that only accepts cycle-accurate `hw` replies).
//!
//! ## Error responses
//!
//! Failures are structured — `{"ok": false, "code": "<code>",
//! "error": "<detail>"}` — with **stable codes** (the `error` text is
//! human-facing and may change; the `code` is the protocol):
//!
//! | code                  | meaning                                                        | retry?            |
//! |-----------------------|----------------------------------------------------------------|-------------------|
//! | `bad_request`         | malformed input: bad JSON, unknown key/cmd, spec-grammar error, unknown method name, empty or oversized `values` | no — fix the request |
//! | `unknown_spec`        | well-formed spec/method that this coordinator does not serve   | no — pick a served spec (`cmd: metrics` lists them) |
//! | `backend_unavailable` | the execution backend cannot run in this build/environment, or the request's `"backend"` pin names one this deployment does not run | no — redeploy with the substrate present, or drop/fix the pin |
//! | `overloaded`          | backpressure: the routed shard queue is full                   | yes — after a backoff |
//! | `internal`            | unexpected failure (execution fault, worker race)              | maybe — and report it |
//!
//! The codes are [`crate::backend::ErrorCode`]; request-path failures
//! additionally distinguish *where* they happened
//! ([`crate::coordinator::RequestErrorKind`]) in the server metrics
//! (`backend_failed_requests` vs `admission_failed_requests`).

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use crate::approx::{MethodId, MethodSpec};
use crate::backend::ErrorCode;
use crate::util::json::{self, Json};

use super::request::RequestError;
use super::server::Coordinator;

/// A running TCP server wrapping a coordinator.
pub struct NetServer {
    addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    accept_thread: Option<std::thread::JoinHandle<()>>,
}

impl NetServer {
    /// Binds `addr` (use port 0 for an ephemeral port) and starts
    /// accepting connections.
    pub fn start(coord: Arc<Coordinator>, addr: &str) -> std::io::Result<NetServer> {
        let listener = TcpListener::bind(addr)?;
        let local = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        listener.set_nonblocking(true)?;
        let accept_thread = std::thread::Builder::new()
            .name("tanh-net-accept".into())
            .spawn(move || {
                while !stop2.load(Ordering::Relaxed) {
                    match listener.accept() {
                        Ok((stream, _peer)) => {
                            let coord = coord.clone();
                            // Connection threads are detached: they end
                            // when the client disconnects. Joining them
                            // from stop() would deadlock against
                            // still-connected clients.
                            let _ = std::thread::Builder::new()
                                .name("tanh-net-conn".into())
                                .spawn(move || handle_conn(stream, coord));
                        }
                        Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            std::thread::sleep(std::time::Duration::from_millis(5));
                        }
                        Err(_) => break,
                    }
                }
            })?;
        Ok(NetServer { addr: local, stop, accept_thread: Some(accept_thread) })
    }

    /// The bound address (for clients when started on port 0).
    pub fn addr(&self) -> std::net::SocketAddr {
        self.addr
    }

    /// Stops accepting and joins the accept thread (open connections
    /// close as clients disconnect).
    pub fn stop(mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
    }
}

fn handle_conn(stream: TcpStream, coord: Arc<Coordinator>) {
    let peer = stream.peer_addr().ok();
    let mut writer = match stream.try_clone() {
        Ok(w) => w,
        Err(_) => return,
    };
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let Ok(line) = line else { break };
        if line.trim().is_empty() {
            continue;
        }
        let response = handle_line(&line, &coord);
        let mut text = response.to_string_compact();
        text.push('\n');
        if writer.write_all(text.as_bytes()).is_err() {
            break;
        }
    }
    let _ = peer; // reserved for per-peer metrics
}

fn handle_line(line: &str, coord: &Coordinator) -> Json {
    let doc = match json::parse(line) {
        Ok(d) => d,
        Err(e) => return err(ErrorCode::BadRequest, format!("bad json: {e}")),
    };
    // Optional backend pin, honored on EVERY request kind (commands
    // included): one backend per deployment, so a request naming a
    // different one is a deployment mismatch, not a routable request.
    // A malformed pin is rejected, never silently treated as absent —
    // the pin exists precisely so clients can assert which
    // implementation answers.
    if let Some(pin) = doc.get("backend") {
        match pin.str() {
            Some(want) if want == coord.backend_name() => {}
            Some(want) => {
                return err(
                    ErrorCode::BackendUnavailable,
                    format!(
                        "this deployment serves backend '{}', not '{want}'",
                        coord.backend_name()
                    ),
                )
            }
            None => {
                return err(
                    ErrorCode::BadRequest,
                    "'backend' must be a backend-name string".into(),
                )
            }
        }
    }
    if let Some(cmd) = doc.get("cmd").and_then(|c| c.str()) {
        return match cmd {
            "metrics" => {
                let m = coord.metrics();
                Json::obj(vec![
                    ("ok", Json::Bool(true)),
                    ("backend", Json::s(coord.backend_name())),
                    ("submitted", Json::i(m.submitted as i64)),
                    ("requests", Json::i(m.requests as i64)),
                    ("failed_requests", Json::i(m.failed_requests as i64)),
                    ("backend_failed_requests", Json::i(m.backend_failed_requests as i64)),
                    ("admission_failed_requests", Json::i(m.admission_failed_requests as i64)),
                    ("elements", Json::i(m.elements as i64)),
                    ("batches", Json::i(m.batches as i64)),
                    ("packed_batches", Json::i(m.packed_batches as i64)),
                    ("rejected", Json::i(m.rejected as i64)),
                    ("errors", Json::i(m.errors as i64)),
                    ("mean_latency_us", Json::n(m.mean_latency_us())),
                    ("p50_us", Json::n(m.p50_us())),
                    ("p95_us", Json::n(m.p95_us())),
                    ("p99_us", Json::n(m.p99_us())),
                    ("max_latency_us", Json::i(m.latency_us_max() as i64)),
                    ("sim_cycles", Json::i(m.sim_cycles as i64)),
                    ("sim_cycles_per_element", Json::n(m.sim_cycles_per_element())),
                    ("shards_per_method", Json::i(coord.shards_per_method() as i64)),
                    ("batch_efficiency", Json::n(m.batch_efficiency())),
                    ("batch_fill_rate", Json::n(m.fill_rate())),
                    ("padded_elements", Json::i(m.padded_elements as i64)),
                    ("kernel_cache_hits", Json::i(m.kernel_cache_hits as i64)),
                    ("kernel_compiles", Json::i(m.kernel_compiles as i64)),
                    (
                        "specs",
                        Json::arr(coord.specs().iter().map(|s| Json::s(s.to_string())).collect()),
                    ),
                ])
            }
            "ping" => Json::obj(vec![("ok", Json::Bool(true)), ("pong", Json::Bool(true))]),
            other => err(ErrorCode::BadRequest, format!("unknown cmd '{other}'")),
        };
    }
    let Some(values) = doc.get("values").and_then(|v| v.as_arr()) else {
        return err(ErrorCode::BadRequest, "missing 'values' array".into());
    };
    let values: Vec<f32> = values.iter().filter_map(|v| v.num()).map(|v| v as f32).collect();
    let t0 = std::time::Instant::now();
    // "spec" addresses an exact design point; "method" is the short
    // form for that method's first served spec. Both use the unified
    // parse errors (accepted names / grammar listed on failure);
    // grammar failures are bad_request, a parsed-but-unserved spec is
    // unknown_spec (from the coordinator).
    let result: Result<Vec<f32>, RequestError> =
        if let Some(spec_str) = doc.get("spec").and_then(|s| s.str()) {
            match MethodSpec::parse(spec_str) {
                Ok(spec) => coord.evaluate_spec(&spec, values),
                Err(e) => Err(RequestError::admission(ErrorCode::BadRequest, e)),
            }
        } else if let Some(name) = doc.get("method").and_then(|m| m.str()) {
            match MethodId::parse_or_err(name) {
                Ok(method) => coord.evaluate(method, values),
                Err(e) => Err(RequestError::admission(ErrorCode::BadRequest, e)),
            }
        } else {
            Err(RequestError::admission(ErrorCode::BadRequest, "missing 'method' or 'spec'"))
        };
    match result {
        Ok(out) => Json::obj(vec![
            ("ok", Json::Bool(true)),
            ("values", Json::arr(out.into_iter().map(|v| Json::n(v as f64)).collect())),
            ("latency_us", Json::i(t0.elapsed().as_micros() as i64)),
        ]),
        Err(e) => err(e.code, e.message),
    }
}

fn err(code: ErrorCode, msg: String) -> Json {
    Json::obj(vec![
        ("ok", Json::Bool(false)),
        ("code", Json::s(code.as_str())),
        ("error", Json::s(msg)),
    ])
}

/// Minimal blocking client for the line protocol (used by the example
/// and the tests).
pub struct NetClient {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl NetClient {
    /// Connects to a server.
    pub fn connect(addr: std::net::SocketAddr) -> std::io::Result<NetClient> {
        let stream = TcpStream::connect(addr)?;
        let writer = stream.try_clone()?;
        Ok(NetClient { reader: BufReader::new(stream), writer })
    }

    /// Sends one request document, waits for the response line.
    pub fn call(&mut self, req: &Json) -> Result<Json, String> {
        let mut text = req.to_string_compact();
        text.push('\n');
        self.writer.write_all(text.as_bytes()).map_err(|e| e.to_string())?;
        let mut line = String::new();
        self.reader.read_line(&mut line).map_err(|e| e.to_string())?;
        json::parse(&line)
    }

    /// Evaluates a batch of activations. Failures format as
    /// `"<code>: <detail>"` using the stable protocol codes.
    pub fn evaluate(&mut self, method: &str, values: &[f32]) -> Result<Vec<f32>, String> {
        let req = Json::obj(vec![
            ("method", Json::s(method)),
            ("values", Json::arr(values.iter().map(|v| Json::n(*v as f64)).collect())),
        ]);
        let resp = self.call(&req)?;
        if resp.get("ok").map(|o| *o == Json::Bool(true)) != Some(true) {
            let code = resp.get("code").and_then(|c| c.str()).unwrap_or("internal");
            let detail = resp.get("error").and_then(|e| e.str()).unwrap_or("unknown error");
            return Err(format!("{code}: {detail}"));
        }
        Ok(resp
            .get("values")
            .and_then(|v| v.as_arr())
            .ok_or("missing values")?
            .iter()
            .filter_map(|v| v.num())
            .map(|v| v as f32)
            .collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::GoldenBackend;
    use crate::coordinator::CoordinatorConfig;

    fn start_server() -> (NetServer, Arc<Coordinator>) {
        let coord = Arc::new(
            Coordinator::start(
                Arc::new(GoldenBackend::new()),
                CoordinatorConfig::with_batch(256),
            )
            .unwrap(),
        );
        let server = NetServer::start(coord.clone(), "127.0.0.1:0").unwrap();
        (server, coord)
    }

    fn assert_code(resp: &Json, code: &str) {
        assert_eq!(resp.get("ok"), Some(&Json::Bool(false)), "{resp:?}");
        assert_eq!(resp.get("code").and_then(|c| c.str()), Some(code), "{resp:?}");
        assert!(
            resp.get("error").and_then(|e| e.str()).is_some_and(|e| !e.is_empty()),
            "{resp:?}"
        );
    }

    #[test]
    fn roundtrip_evaluate() {
        let (server, _coord) = start_server();
        let mut client = NetClient::connect(server.addr()).unwrap();
        let out = client.evaluate("pwl", &[0.5, -0.5, 0.0]).unwrap();
        assert_eq!(out.len(), 3);
        assert!((out[0] - 0.4621f32).abs() < 1e-3);
        assert_eq!(out[2], 0.0);
        server.stop();
    }

    #[test]
    fn metrics_and_ping() {
        let (server, _coord) = start_server();
        let mut client = NetClient::connect(server.addr()).unwrap();
        let pong = client.call(&Json::obj(vec![("cmd", Json::s("ping"))])).unwrap();
        assert_eq!(pong.get("pong"), Some(&Json::Bool(true)));
        client.evaluate("lambert", &[1.0]).unwrap();
        let m = client.call(&Json::obj(vec![("cmd", Json::s("metrics"))])).unwrap();
        assert!(m.get("requests").unwrap().num().unwrap() >= 1.0);
        assert!(m.get("submitted").unwrap().num().unwrap() >= 1.0);
        assert!(m.get("p50_us").is_some() && m.get("p99_us").is_some());
        assert!(m.get("shards_per_method").unwrap().num().unwrap() >= 2.0);
        // Backend-era observables: which backend served, the failure
        // split, and the simulated-cycle column (zero on golden).
        assert_eq!(m.get("backend").and_then(|b| b.str()), Some("golden"));
        assert_eq!(m.get("backend_failed_requests").unwrap().num(), Some(0.0));
        assert_eq!(m.get("admission_failed_requests").unwrap().num(), Some(0.0));
        assert_eq!(m.get("sim_cycles").unwrap().num(), Some(0.0));
        // The shared-cache observables and the served spec list are on
        // the metrics endpoint.
        assert!(m.get("kernel_compiles").unwrap().num().unwrap() >= 6.0);
        assert!(m.get("kernel_cache_hits").is_some());
        assert_eq!(m.get("specs").unwrap().as_arr().unwrap().len(), 6);
        server.stop();
    }

    #[test]
    fn spec_addressed_requests_roundtrip() {
        let (server, _coord) = start_server();
        let mut client = NetClient::connect(server.addr()).unwrap();
        let req = Json::obj(vec![
            ("spec", Json::s("pwl:step=1/64:in=S3.12:out=S.15")),
            ("values", Json::arr(vec![Json::n(0.5)])),
        ]);
        let resp = client.call(&req).unwrap();
        assert_eq!(resp.get("ok"), Some(&Json::Bool(true)), "{resp:?}");
        // A valid but unserved spec fails with unknown_spec + the
        // served list.
        let req = Json::obj(vec![
            ("spec", Json::s("pwl:step=1/32")),
            ("values", Json::arr(vec![Json::n(0.5)])),
        ]);
        let resp = client.call(&req).unwrap();
        assert_code(&resp, "unknown_spec");
        assert!(resp.get("error").unwrap().str().unwrap().contains("not served"));
        // A malformed spec fails with bad_request + a grammar-ish error.
        let req = Json::obj(vec![
            ("spec", Json::s("pwl:step=1/3")),
            ("values", Json::arr(vec![Json::n(0.5)])),
        ]);
        let resp = client.call(&req).unwrap();
        assert_code(&resp, "bad_request");
        server.stop();
    }

    #[test]
    fn error_paths_carry_stable_codes() {
        let (server, _coord) = start_server();
        let mut client = NetClient::connect(server.addr()).unwrap();
        // bad json
        let resp = client.call(&Json::s("not an object")).unwrap();
        assert_code(&resp, "bad_request");
        // unknown cmd
        let resp = client.call(&Json::obj(vec![("cmd", Json::s("reboot"))])).unwrap();
        assert_code(&resp, "bad_request");
        // missing values
        let resp = client.call(&Json::obj(vec![("method", Json::s("pwl"))])).unwrap();
        assert_code(&resp, "bad_request");
        // unknown method (the client folds code + detail into the Err)
        let err = client.evaluate("sinh", &[1.0]).unwrap_err();
        assert!(err.starts_with("bad_request:"), "{err}");
        assert!(err.contains("method"), "{err}");
        // empty values
        let err = client.evaluate("pwl", &[]).unwrap_err();
        assert!(err.starts_with("bad_request:"), "{err}");
        assert!(err.contains("empty"), "{err}");
        // oversized values → bad_request from admission
        let err = client.evaluate("pwl", &vec![0.0; 257]).unwrap_err();
        assert!(err.starts_with("bad_request:"), "{err}");
        assert!(err.contains("exceeds"), "{err}");
        server.stop();
    }

    #[test]
    fn multiple_clients_interleave() {
        let (server, _coord) = start_server();
        let addr = server.addr();
        let handles: Vec<_> = (0..4)
            .map(|i| {
                std::thread::spawn(move || {
                    let mut c = NetClient::connect(addr).unwrap();
                    for j in 0..10 {
                        let x = (i * 10 + j) as f32 * 0.07 - 1.0;
                        let out = c.evaluate("taylor1", &[x]).unwrap();
                        assert!((out[0] - x.tanh()).abs() < 1e-3, "x={x}");
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        server.stop();
    }

    #[test]
    fn hw_backend_serves_over_the_wire_with_cycle_metrics() {
        use crate::backend::HwBackend;
        // The multi-backend acceptance at the net layer: an hw-backed
        // coordinator answers the same protocol, bit-identical to a
        // golden-backed one, and its metrics carry nonzero sim_cycles.
        let specs = vec![MethodSpec::table1(MethodId::Pwl)];
        let cfg = CoordinatorConfig {
            specs: specs.clone(),
            ..CoordinatorConfig::with_batch(64)
        };
        let hw = Arc::new(
            Coordinator::start(Arc::new(HwBackend::new()), cfg.clone()).unwrap(),
        );
        let golden = Arc::new(
            Coordinator::start(Arc::new(GoldenBackend::new()), cfg).unwrap(),
        );
        let hw_srv = NetServer::start(hw.clone(), "127.0.0.1:0").unwrap();
        let golden_srv = NetServer::start(golden.clone(), "127.0.0.1:0").unwrap();
        let mut hw_client = NetClient::connect(hw_srv.addr()).unwrap();
        let mut golden_client = NetClient::connect(golden_srv.addr()).unwrap();
        let xs = [0.5f32, -0.5, 0.125, 3.75, -6.5];
        let a = hw_client.evaluate("pwl", &xs).unwrap();
        let b = golden_client.evaluate("pwl", &xs).unwrap();
        for (x, (ya, yb)) in xs.iter().zip(a.iter().zip(&b)) {
            assert_eq!(ya.to_bits(), yb.to_bits(), "x={x}: hw {ya} vs golden {yb}");
        }
        // Backend-pinned requests: accepted when the pin matches the
        // deployment, refused with backend_unavailable otherwise.
        let pinned = Json::obj(vec![
            ("backend", Json::s("hw")),
            ("method", Json::s("pwl")),
            ("values", Json::arr(vec![Json::n(0.5)])),
        ]);
        let resp = hw_client.call(&pinned).unwrap();
        assert_eq!(resp.get("ok"), Some(&Json::Bool(true)), "{resp:?}");
        let resp = golden_client.call(&pinned).unwrap();
        assert_code(&resp, "backend_unavailable");
        // The pin is honored on command requests too.
        let pinned_cmd =
            Json::obj(vec![("cmd", Json::s("metrics")), ("backend", Json::s("golden"))]);
        let resp = hw_client.call(&pinned_cmd).unwrap();
        assert_code(&resp, "backend_unavailable");
        let resp = golden_client.call(&pinned_cmd).unwrap();
        assert_eq!(resp.get("ok"), Some(&Json::Bool(true)), "{resp:?}");
        let m = hw_client.call(&Json::obj(vec![("cmd", Json::s("metrics"))])).unwrap();
        assert_eq!(m.get("backend").and_then(|b| b.str()), Some("hw"));
        assert!(m.get("sim_cycles").unwrap().num().unwrap() > 0.0, "{m:?}");
        hw_srv.stop();
        golden_srv.stop();
    }
}
