//! TCP front-end: the coordinator as a network service.
//!
//! A single nonblocking **event thread** owns the listener and every
//! connection (std::net; tokio/epoll are not in the offline crate
//! set). Each connection is a small state machine — read buffer →
//! decoded-work queue → in-flight reply queue → write buffer — so one
//! thread serves many concurrent clients with **pipelined** requests:
//! a client may write any number of requests before reading; replies
//! always come back in request order.
//!
//! ## Framing
//!
//! The **first byte of the connection** negotiates the framing: a
//! [`BIN_REQUEST_MAGIC`] byte (`0xB7`, never the start of a JSON
//! document) switches the connection to binary frames; anything else
//! is the JSON line protocol.
//!
//! **JSON lines** — one request document per `\n`-terminated line:
//!
//! ```text
//! → {"method": "pwl", "values": [0.5, -1.25]}
//! ← {"ok": true, "values": [0.4621, -0.8482], "latency_us": 412}
//! → {"spec": "pwl:step=1/32:in=s2.13:out=s.15", "values": [0.5]}
//! ← {"ok": true, "values": [0.4621], "latency_us": 80}
//! → {"backend": "hw", "spec": "pwl:step=1/64:in=S3.12:out=S.15", "values": [0.5]}
//! ← {"ok": true, "values": [0.4621], "latency_us": 95}
//! → {"cmd": "metrics"}
//! ← {"ok": true, "backend": "golden", "requests": 2, "active_conns": 1, ...}
//! ```
//!
//! A `"spec"` key addresses any served design point by its spec string;
//! `"method"` remains the short form for the method's first served
//! spec. An optional `"backend"` key pins any request to an execution
//! backend (a coordinator runs exactly one backend per deployment, so
//! a request naming a *different* backend is refused with
//! `backend_unavailable`). Every `values` entry must be a finite JSON
//! number; a non-numeric or non-finite entry is rejected with
//! `bad_request` naming the offending index — never silently dropped
//! (dropping would misalign the reply with the request).
//!
//! **Binary frames** — length-prefixed raw words, no per-request
//! serde cost. Specs are pre-registered: id `k` is the k-th entry of
//! the coordinator's served-spec list (the order the `metrics`
//! command's `specs` array reports). All integers little-endian:
//!
//! ```text
//! request:  0xB7 | body_len: u32 | spec_id: u16 | reserved: u16 | N × input raw: i64
//! reply ok: 0xB8 | body_len: u32 | status 0x00  | N × output raw: i64
//! reply err:0xB8 | body_len: u32 | status: u8   | utf-8 error detail
//! ```
//!
//! Input raws are validated against the spec's input-format range
//! (`bad_request` naming the offending index on overflow); output raws
//! are the served outputs re-quantized with the shared golden
//! conventions, bit-exact for the ≤ 24-bit formats the paper's design
//! points use. The error `status` byte is
//! [`crate::backend::ErrorCode::as_u8`] (0 is reserved for ok).
//! Binary connections carry evals and session frames; commands stay on
//! the JSON protocol.
//!
//! ## Streaming sessions
//!
//! Both framings speak the session protocol
//! ([`super::session`]): open once, pulse a long sequence through
//! warm server-side state, close to flush the delay-window tail.
//! Session payloads are **raw fixed-point words in both framings**
//! (JSON wraps them in integer-valued numbers) — streaming is the
//! raw-addressed fast path, and a cell session's gate pre-activations
//! have no single float format to decode against. Out-of-range raws
//! saturate to the format range (the substrate's own convention),
//! unlike eval frames, which reject them.
//!
//! JSON commands:
//!
//! ```text
//! → {"cmd": "open", "spec": "pwl:step=1/32:in=s2.13:out=s.15"}
//! ← {"ok": true, "session": 7, "delay": 3}
//! → {"cmd": "open", "cell": "lstm", "lanes": 64}
//! ← {"ok": true, "session": 8, "delay": 0}
//! → {"cmd": "pulse", "session": 7, "values": [4096, -8192]}
//! ← {"ok": true, "values": [...], "issued": 2, "delivered": 0}
//! → {"cmd": "close", "session": 7}
//! ← {"ok": true, "values": [...], "issued": 2, "delivered": 2}
//! ```
//!
//! Binary session frames (all integers little-endian; replies use the
//! eval reply framing, ok payloads below):
//!
//! ```text
//! open:   0xB9 | body_len: u32 | spec_id: u16 | reserved: u16
//!     ok reply payload: session id: u64 | delay: u64
//! pulse:  0xBA | body_len: u32 | session id: u64 | N × input raw: i64
//!     ok reply payload: M × output raw: i64   (delay window applied)
//! close:  0xBB | body_len: u32 | session id: u64
//!     ok reply payload: M × output raw: i64   (the flushed tail)
//! ```
//!
//! Any of the four request magics as the first byte of a connection
//! selects binary mode (cell sessions open over JSON only — they are
//! not spec-addressed). A connection owns the sessions it opened:
//! when it drops without closing them, the server aborts them
//! (flushing nothing to nobody), so state cannot leak. Sessions also
//! die by idle timeout ([`super::SessionConfig`]); the `metrics`
//! command reports both gauges (`sessions_open`, `sessions_evicted`).
//!
//! ## Backpressure & frame caps
//!
//! Per-connection backpressure is tied to the shard queues: when the
//! coordinator answers `overloaded`, the request stays at the head of
//! the connection's work queue and is retried next tick (order
//! preserved), and once `work + inflight` reaches
//! [`NetConfig::max_inflight_per_conn`] — or the write buffer exceeds
//! [`NetConfig::max_write_buffer`] — the loop stops *reading* that
//! connection, so a flooding client is throttled by TCP instead of
//! buffering without bound. A request stuck in overload longer than
//! [`NetConfig::overload_give_up`] gets an `overloaded` error reply.
//! Any single frame (JSON line or binary body) larger than
//! [`NetConfig::max_frame_bytes`] is answered with `bad_request` and
//! the connection closes after the reply flushes.
//!
//! ## Error responses
//!
//! JSON failures are structured — `{"ok": false, "code": "<code>",
//! "error": "<detail>"}` — with **stable codes** (the `error` text is
//! human-facing and may change; the `code` is the protocol). Binary
//! failures carry the same codes as the status byte:
//!
//! | code                  | u8 | meaning                                                        | retry?            |
//! |-----------------------|----|----------------------------------------------------------------|-------------------|
//! | `bad_request`         | 3  | malformed input: bad JSON, unknown key/cmd, spec-grammar error, unknown method name, non-numeric/non-finite or out-of-range values, empty or oversized `values`, oversized frame | no — fix the request |
//! | `unknown_spec`        | 1  | well-formed spec/method/spec-id that this coordinator does not serve | no — pick a served spec (`cmd: metrics` lists them) |
//! | `backend_unavailable` | 2  | the execution backend cannot run in this build/environment, or the request's `"backend"` pin names one this deployment does not run | no — redeploy with the substrate present, or drop/fix the pin |
//! | `overloaded`          | 4  | backpressure: the routed shard queue stayed full past the give-up deadline | yes — after a backoff |
//! | `internal`            | 5  | unexpected failure (execution fault, worker race)              | maybe — and report it |
//!
//! The codes are [`crate::backend::ErrorCode`]; request-path failures
//! additionally distinguish *where* they happened
//! ([`crate::coordinator::RequestErrorKind`]) in the server metrics
//! (`backend_failed_requests` vs `admission_failed_requests`).

use std::collections::VecDeque;
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

use crate::approx::{MethodId, MethodSpec};
use crate::backend::{quantize_input, ErrorCode};
use crate::fixed::QFormat;
use crate::util::json::{self, Json};

use super::metrics::MetricsSnapshot;
use super::request::{RequestError, RequestResult};
use super::server::Coordinator;
use super::session::PulseOutcome;

/// First byte of every binary request frame — and, as the first byte
/// of a connection, the framing negotiation: no JSON document starts
/// with `0xB7`, so its presence selects binary mode.
pub const BIN_REQUEST_MAGIC: u8 = 0xB7;
/// First byte of every binary reply frame.
pub const BIN_REPLY_MAGIC: u8 = 0xB8;
/// First byte of a binary session-open frame.
pub const BIN_OPEN_MAGIC: u8 = 0xB9;
/// First byte of a binary session-pulse frame.
pub const BIN_PULSE_MAGIC: u8 = 0xBA;
/// First byte of a binary session-close frame.
pub const BIN_CLOSE_MAGIC: u8 = 0xBB;

/// Bytes of frame header (magic + u32 body length).
const BIN_HEADER: usize = 5;

/// Hard ceiling on a binary frame body: the length prefix is a `u32`,
/// so a larger body cannot be framed at all. The checked builders
/// enforce it (or a smaller injected limit) **before** the `as u32`
/// cast — the unchecked cast used to truncate silently, emitting a
/// frame whose length prefix disagreed with its payload and
/// desynchronizing every later frame on the stream.
pub const BIN_MAX_BODY: usize = u32::MAX as usize;

/// Tuning knobs for the event loop. The defaults suit the scenario
/// harness and production-ish loads; tests shrink them to exercise the
/// guard rails.
#[derive(Clone, Copy, Debug)]
pub struct NetConfig {
    /// Hard cap on a single request frame: a JSON line (bytes before
    /// the newline) or a binary frame body. Overflow answers
    /// `bad_request` and closes the connection — the guard against one
    /// client streaming a multi-GB line into server memory.
    pub max_frame_bytes: usize,
    /// Per-connection cap on decoded-but-unanswered requests
    /// (work queue + in-flight). Reads pause at the cap.
    pub max_inflight_per_conn: usize,
    /// Per-connection cap on buffered reply bytes. Reads pause while a
    /// slow reader's write buffer sits above it.
    pub max_write_buffer: usize,
    /// How long a request may sit at the head of the work queue
    /// retrying `overloaded` before the error is returned to the
    /// client.
    pub overload_give_up: Duration,
}

impl Default for NetConfig {
    fn default() -> Self {
        NetConfig {
            max_frame_bytes: 1 << 20,
            max_inflight_per_conn: 128,
            max_write_buffer: 4 << 20,
            overload_give_up: Duration::from_secs(5),
        }
    }
}

/// Connection/byte gauges owned by the event loop (atomics; the
/// `metrics` command and [`NetServer::gauges`] snapshot them).
#[derive(Debug, Default)]
struct NetGauges {
    accepted: AtomicU64,
    active: AtomicU64,
    bytes_in: AtomicU64,
    bytes_out: AtomicU64,
}

/// A point-in-time copy of the net front-end gauges.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct NetGaugesSnapshot {
    /// Connections accepted since the server started.
    pub accepted_conns: u64,
    /// Connections currently open.
    pub active_conns: u64,
    /// Request bytes read off sockets.
    pub bytes_in: u64,
    /// Reply bytes written to sockets.
    pub bytes_out: u64,
}

impl NetGauges {
    fn snapshot(&self) -> NetGaugesSnapshot {
        NetGaugesSnapshot {
            accepted_conns: self.accepted.load(Ordering::Relaxed),
            active_conns: self.active.load(Ordering::Relaxed),
            bytes_in: self.bytes_in.load(Ordering::Relaxed),
            bytes_out: self.bytes_out.load(Ordering::Relaxed),
        }
    }
}

impl NetGaugesSnapshot {
    /// Copies the gauges into a [`MetricsSnapshot`] (they merge by
    /// max there, like the kernel-cache gauges).
    pub fn fill(&self, m: &mut MetricsSnapshot) {
        m.accepted_conns = self.accepted_conns;
        m.active_conns = self.active_conns;
        m.net_bytes_in = self.bytes_in;
        m.net_bytes_out = self.bytes_out;
    }
}

/// A running TCP server wrapping a coordinator.
pub struct NetServer {
    addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    gauges: Arc<NetGauges>,
    loop_thread: Option<std::thread::JoinHandle<()>>,
}

impl NetServer {
    /// Binds `addr` (use port 0 for an ephemeral port) and starts the
    /// event loop with default [`NetConfig`].
    pub fn start(coord: Arc<Coordinator>, addr: &str) -> std::io::Result<NetServer> {
        NetServer::start_with(coord, addr, NetConfig::default())
    }

    /// [`NetServer::start`] with explicit tuning.
    pub fn start_with(
        coord: Arc<Coordinator>,
        addr: &str,
        cfg: NetConfig,
    ) -> std::io::Result<NetServer> {
        let listener = TcpListener::bind(addr)?;
        let local = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let stop = Arc::new(AtomicBool::new(false));
        let gauges = Arc::new(NetGauges::default());
        let stop2 = stop.clone();
        let gauges2 = gauges.clone();
        let loop_thread = std::thread::Builder::new()
            .name("tanh-net-loop".into())
            .spawn(move || event_loop(listener, coord, cfg, stop2, gauges2))?;
        Ok(NetServer { addr: local, stop, gauges, loop_thread: Some(loop_thread) })
    }

    /// The bound address (for clients when started on port 0).
    pub fn addr(&self) -> std::net::SocketAddr {
        self.addr
    }

    /// Snapshot of the connection/byte gauges.
    pub fn gauges(&self) -> NetGaugesSnapshot {
        self.gauges.snapshot()
    }

    /// Stops the event loop and joins it; open connections close
    /// (clients see EOF). Safe to call with clients still connected.
    pub fn stop(mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(t) = self.loop_thread.take() {
            let _ = t.join();
        }
    }
}

/// The event loop: accept, then pump every connection's state machine.
/// Sleeps briefly only when a full pass made no progress, so stop()
/// joins in ~a millisecond and a busy loop never sleeps at all.
fn event_loop(
    listener: TcpListener,
    coord: Arc<Coordinator>,
    cfg: NetConfig,
    stop: Arc<AtomicBool>,
    gauges: Arc<NetGauges>,
) {
    let mut conns: Vec<Conn> = Vec::new();
    while !stop.load(Ordering::Relaxed) {
        let mut progressed = false;
        loop {
            match listener.accept() {
                Ok((stream, _peer)) => {
                    if stream.set_nonblocking(true).is_err() {
                        continue;
                    }
                    let _ = stream.set_nodelay(true);
                    gauges.accepted.fetch_add(1, Ordering::Relaxed);
                    conns.push(Conn::new(stream));
                    progressed = true;
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                Err(_) => break,
            }
        }
        for conn in conns.iter_mut() {
            progressed |= conn.pump(&coord, &cfg, &gauges);
        }
        // Reap finished connections, aborting any streaming sessions
        // they still own — the connection IS the session's lease.
        let mut i = 0;
        while i < conns.len() {
            if conns[i].done() {
                let mut conn = conns.swap_remove(i);
                for id in conn.sessions.drain(..) {
                    coord.session_abort(id);
                }
            } else {
                i += 1;
            }
        }
        gauges.active.store(conns.len() as u64, Ordering::Relaxed);
        if !progressed {
            std::thread::sleep(Duration::from_micros(500));
        }
    }
    // Dropping the listener and connections closes every socket;
    // in-flight coordinator replies are dropped with them.
}

/// Connection framing, decided by the first byte received.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Mode {
    Undecided,
    Json,
    Binary,
}

/// One decoded request, in arrival order. `Reply` items (command
/// responses, decode errors) are pre-rendered so a deferred eval ahead
/// of them still answers first — replies stay in request order.
enum Work {
    Reply(Vec<u8>),
    Eval(EvalReq),
    Pulse(PulseReq),
    Close(CloseReq),
}

struct EvalReq {
    spec: MethodSpec,
    values: Vec<f32>,
    binary: bool,
    /// Set on the first `overloaded` rejection; drives the give-up
    /// deadline.
    first_try: Option<Instant>,
}

struct PulseReq {
    id: u64,
    input: Vec<i64>,
    binary: bool,
    /// Same overload give-up dance as [`EvalReq::first_try`].
    first_try: Option<Instant>,
}

struct CloseReq {
    id: u64,
    binary: bool,
}

/// A submitted-or-rendered reply waiting its turn on the wire.
enum Pending {
    Ready(Vec<u8>),
    Wait { rx: mpsc::Receiver<RequestResult>, out_fmt: QFormat, binary: bool },
    WaitPulse { rx: mpsc::Receiver<Result<PulseOutcome, RequestError>>, binary: bool },
}

/// Per-connection state machine.
struct Conn {
    stream: TcpStream,
    mode: Mode,
    rbuf: Vec<u8>,
    work: VecDeque<Work>,
    inflight: VecDeque<Pending>,
    wbuf: Vec<u8>,
    /// Streaming sessions this connection opened and has not yet
    /// closed — aborted by the event loop when the connection dies, so
    /// a vanished client cannot leak server-side state.
    sessions: Vec<u64>,
    /// Peer closed its write side; drain what we have, then close.
    eof: bool,
    /// Fatal protocol error queued; close once everything flushes.
    closing: bool,
    /// Transport error; drop immediately.
    dead: bool,
}

impl Conn {
    fn new(stream: TcpStream) -> Conn {
        Conn {
            stream,
            mode: Mode::Undecided,
            rbuf: Vec::new(),
            work: VecDeque::new(),
            inflight: VecDeque::new(),
            wbuf: Vec::new(),
            sessions: Vec::new(),
            eof: false,
            closing: false,
            dead: false,
        }
    }

    fn done(&self) -> bool {
        if self.dead {
            return true;
        }
        (self.eof || self.closing)
            && self.work.is_empty()
            && self.inflight.is_empty()
            && self.wbuf.is_empty()
    }

    /// One tick of the state machine; true if anything moved.
    fn pump(&mut self, coord: &Coordinator, cfg: &NetConfig, gauges: &NetGauges) -> bool {
        let mut progressed = false;
        progressed |= self.fill_read(cfg, gauges);
        progressed |= self.decode(coord, cfg, gauges);
        progressed |= self.submit(coord, cfg);
        progressed |= self.reap();
        progressed |= self.flush(gauges);
        progressed
    }

    /// Reads pause at the in-flight / write-buffer caps: the client's
    /// TCP window fills instead of server memory (per-connection
    /// backpressure).
    fn paused(&self, cfg: &NetConfig) -> bool {
        self.work.len() + self.inflight.len() >= cfg.max_inflight_per_conn
            || self.wbuf.len() >= cfg.max_write_buffer
    }

    fn fill_read(&mut self, cfg: &NetConfig, gauges: &NetGauges) -> bool {
        if self.dead || self.closing || self.eof || self.paused(cfg) {
            return false;
        }
        let mut progressed = false;
        let mut chunk = [0u8; 8192];
        loop {
            // Leave an oversized frame to decode's overflow guard
            // instead of buffering past the cap.
            if self.rbuf.len() > cfg.max_frame_bytes + BIN_HEADER {
                break;
            }
            match self.stream.read(&mut chunk) {
                Ok(0) => {
                    self.eof = true;
                    break;
                }
                Ok(n) => {
                    self.rbuf.extend_from_slice(&chunk[..n]);
                    gauges.bytes_in.fetch_add(n as u64, Ordering::Relaxed);
                    progressed = true;
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                Err(_) => {
                    self.dead = true;
                    break;
                }
            }
        }
        progressed
    }

    fn decode(&mut self, coord: &Coordinator, cfg: &NetConfig, gauges: &NetGauges) -> bool {
        if self.dead || self.closing || self.rbuf.is_empty() {
            return false;
        }
        if self.mode == Mode::Undecided {
            self.mode = if is_bin_request_magic(self.rbuf[0]) { Mode::Binary } else { Mode::Json };
        }
        match self.mode {
            Mode::Json => self.decode_json(coord, cfg, gauges),
            Mode::Binary => self.decode_binary(coord, cfg),
            Mode::Undecided => unreachable!(),
        }
    }

    fn decode_json(&mut self, coord: &Coordinator, cfg: &NetConfig, gauges: &NetGauges) -> bool {
        let mut progressed = false;
        while let Some(pos) = self.rbuf.iter().position(|&b| b == b'\n') {
            let mut line: Vec<u8> = self.rbuf.drain(..=pos).collect();
            line.pop(); // the newline
            if line.last() == Some(&b'\r') {
                line.pop();
            }
            progressed = true;
            if line.iter().all(|b| b.is_ascii_whitespace()) {
                continue;
            }
            if line.len() > cfg.max_frame_bytes {
                self.protocol_error(cfg, false);
                return true;
            }
            let work = match std::str::from_utf8(&line) {
                Ok(text) => classify_line(text, coord, gauges, &mut self.sessions),
                Err(_) => Work::Reply(json_reply(&err(
                    ErrorCode::BadRequest,
                    "request line is not valid utf-8".into(),
                ))),
            };
            self.work.push_back(work);
        }
        // No newline yet: an incomplete line may not grow past the
        // frame cap (the unbounded-buffering bugfix).
        if !self.closing && self.rbuf.len() > cfg.max_frame_bytes {
            self.protocol_error(cfg, false);
            progressed = true;
        }
        progressed
    }

    fn decode_binary(&mut self, coord: &Coordinator, cfg: &NetConfig) -> bool {
        let mut progressed = false;
        while self.rbuf.len() >= BIN_HEADER {
            let magic = self.rbuf[0];
            if !is_bin_request_magic(magic) {
                self.protocol_error(cfg, true);
                return true;
            }
            let len =
                u32::from_le_bytes([self.rbuf[1], self.rbuf[2], self.rbuf[3], self.rbuf[4]])
                    as usize;
            if len > cfg.max_frame_bytes {
                self.protocol_error(cfg, true);
                return true;
            }
            if self.rbuf.len() < BIN_HEADER + len {
                break;
            }
            let frame: Vec<u8> = self.rbuf.drain(..BIN_HEADER + len).collect();
            let body = &frame[BIN_HEADER..];
            let work = match magic {
                BIN_OPEN_MAGIC => self.classify_open(body, coord),
                BIN_PULSE_MAGIC => classify_pulse(body),
                BIN_CLOSE_MAGIC => classify_close(body),
                _ => classify_binary(body, coord),
            };
            self.work.push_back(work);
            progressed = true;
        }
        progressed
    }

    /// Decodes and executes a binary session-open frame (body:
    /// `spec_id u16 | reserved u16`). Open is synchronous on the
    /// coordinator, so the reply (ok payload `session id u64 |
    /// delay u64`) renders at decode time; the id is recorded for
    /// connection-drop teardown.
    fn classify_open(&mut self, body: &[u8], coord: &Coordinator) -> Work {
        if body.len() != 4 {
            return Work::Reply(bin_err_frame(
                ErrorCode::BadRequest,
                &format!(
                    "open frame body must be 4 bytes (spec_id u16 + reserved u16), got {}",
                    body.len()
                ),
            ));
        }
        let spec_id = u16::from_le_bytes([body[0], body[1]]) as usize;
        let specs = coord.specs();
        let Some(spec) = specs.get(spec_id) else {
            return Work::Reply(bin_err_frame(
                ErrorCode::UnknownSpec,
                &format!(
                    "spec id {spec_id} is not registered (serving {} specs, ids in the \
                     metrics 'specs' order)",
                    specs.len()
                ),
            ));
        };
        match coord.open_session(spec) {
            Ok(info) => {
                self.sessions.push(info.id);
                let mut payload = Vec::with_capacity(16);
                payload.extend_from_slice(&info.id.to_le_bytes());
                payload.extend_from_slice(&(info.delay as u64).to_le_bytes());
                Work::Reply(bin_frame(0, &payload))
            }
            Err(e) => Work::Reply(bin_err_frame(e.code, &e.message)),
        }
    }

    /// Queues the oversized-frame `bad_request` reply and flags the
    /// connection to close once it flushes.
    fn protocol_error(&mut self, cfg: &NetConfig, binary: bool) {
        let msg = format!(
            "request frame exceeds the {}-byte limit; closing connection",
            cfg.max_frame_bytes
        );
        let bytes = if binary {
            bin_err_frame(ErrorCode::BadRequest, &msg)
        } else {
            json_reply(&err(ErrorCode::BadRequest, msg))
        };
        self.work.push_back(Work::Reply(bytes));
        self.rbuf.clear();
        self.closing = true;
    }

    /// Drains the work queue head-first into the in-flight queue.
    /// `overloaded` keeps the head in place (retried next tick) until
    /// the give-up deadline — backpressure propagates from the shard
    /// queue to the client connection without reordering replies.
    fn submit(&mut self, coord: &Coordinator, cfg: &NetConfig) -> bool {
        let mut progressed = false;
        loop {
            match self.work.front_mut() {
                None => break,
                Some(Work::Reply(_)) => {
                    let Some(Work::Reply(bytes)) = self.work.pop_front() else { unreachable!() };
                    self.inflight.push_back(Pending::Ready(bytes));
                    progressed = true;
                }
                Some(Work::Pulse(req)) => {
                    if self.inflight.len() >= cfg.max_inflight_per_conn {
                        break;
                    }
                    match coord.session_pulse(req.id, req.input.clone()) {
                        Ok(rx) => {
                            let Some(Work::Pulse(req)) = self.work.pop_front() else {
                                unreachable!()
                            };
                            self.inflight
                                .push_back(Pending::WaitPulse { rx, binary: req.binary });
                            progressed = true;
                        }
                        Err(e) if e.code == ErrorCode::Overloaded => {
                            let give_up = match req.first_try {
                                None => {
                                    req.first_try = Some(Instant::now());
                                    false
                                }
                                Some(t) => t.elapsed() >= cfg.overload_give_up,
                            };
                            if !give_up {
                                break;
                            }
                            let Some(Work::Pulse(req)) = self.work.pop_front() else {
                                unreachable!()
                            };
                            self.inflight.push_back(Pending::Ready(render_error(
                                req.binary, e.code, &e.message,
                            )));
                            progressed = true;
                        }
                        Err(e) => {
                            let Some(Work::Pulse(req)) = self.work.pop_front() else {
                                unreachable!()
                            };
                            self.inflight.push_back(Pending::Ready(render_error(
                                req.binary, e.code, &e.message,
                            )));
                            progressed = true;
                        }
                    }
                }
                Some(Work::Close(_)) => {
                    if self.inflight.len() >= cfg.max_inflight_per_conn {
                        break;
                    }
                    let Some(Work::Close(req)) = self.work.pop_front() else { unreachable!() };
                    // The id stops being this connection's to abort
                    // whether or not the close lands (an already-dead
                    // id stays dead).
                    self.sessions.retain(|&s| s != req.id);
                    let pending = match coord.session_close(req.id) {
                        Ok(rx) => Pending::WaitPulse { rx, binary: req.binary },
                        Err(e) => {
                            Pending::Ready(render_error(req.binary, e.code, &e.message))
                        }
                    };
                    self.inflight.push_back(pending);
                    progressed = true;
                }
                Some(Work::Eval(req)) => {
                    if self.inflight.len() >= cfg.max_inflight_per_conn {
                        break;
                    }
                    match coord.submit_spec(&req.spec, req.values.clone()) {
                        Ok(rx) => {
                            let Some(Work::Eval(req)) = self.work.pop_front() else {
                                unreachable!()
                            };
                            self.inflight.push_back(Pending::Wait {
                                rx,
                                out_fmt: req.spec.io.output,
                                binary: req.binary,
                            });
                            progressed = true;
                        }
                        Err(e) if e.code == ErrorCode::Overloaded => {
                            let give_up = match req.first_try {
                                None => {
                                    req.first_try = Some(Instant::now());
                                    false
                                }
                                Some(t) => t.elapsed() >= cfg.overload_give_up,
                            };
                            if !give_up {
                                break;
                            }
                            let Some(Work::Eval(req)) = self.work.pop_front() else {
                                unreachable!()
                            };
                            self.inflight.push_back(Pending::Ready(render_error(
                                req.binary, e.code, &e.message,
                            )));
                            progressed = true;
                        }
                        Err(e) => {
                            let Some(Work::Eval(req)) = self.work.pop_front() else {
                                unreachable!()
                            };
                            self.inflight.push_back(Pending::Ready(render_error(
                                req.binary, e.code, &e.message,
                            )));
                            progressed = true;
                        }
                    }
                }
            }
        }
        progressed
    }

    /// Moves finished replies (in order) into the write buffer.
    fn reap(&mut self) -> bool {
        let mut progressed = false;
        loop {
            match self.inflight.front() {
                None => break,
                Some(Pending::Ready(_)) => {
                    let Some(Pending::Ready(bytes)) = self.inflight.pop_front() else {
                        unreachable!()
                    };
                    self.wbuf.extend_from_slice(&bytes);
                    progressed = true;
                }
                Some(Pending::Wait { rx, .. }) => match rx.try_recv() {
                    Err(mpsc::TryRecvError::Empty) => break,
                    Ok(result) => {
                        let Some(Pending::Wait { out_fmt, binary, .. }) =
                            self.inflight.pop_front()
                        else {
                            unreachable!()
                        };
                        self.wbuf.extend_from_slice(&render_result(out_fmt, binary, result));
                        progressed = true;
                    }
                    Err(mpsc::TryRecvError::Disconnected) => {
                        let Some(Pending::Wait { binary, .. }) = self.inflight.pop_front()
                        else {
                            unreachable!()
                        };
                        self.wbuf.extend_from_slice(&render_error(
                            binary,
                            ErrorCode::Internal,
                            "worker dropped reply",
                        ));
                        progressed = true;
                    }
                },
                Some(Pending::WaitPulse { rx, .. }) => match rx.try_recv() {
                    Err(mpsc::TryRecvError::Empty) => break,
                    Ok(result) => {
                        let Some(Pending::WaitPulse { binary, .. }) = self.inflight.pop_front()
                        else {
                            unreachable!()
                        };
                        self.wbuf.extend_from_slice(&render_pulse(binary, result));
                        progressed = true;
                    }
                    Err(mpsc::TryRecvError::Disconnected) => {
                        let Some(Pending::WaitPulse { binary, .. }) = self.inflight.pop_front()
                        else {
                            unreachable!()
                        };
                        self.wbuf.extend_from_slice(&render_error(
                            binary,
                            ErrorCode::Internal,
                            "worker dropped reply",
                        ));
                        progressed = true;
                    }
                },
            }
        }
        progressed
    }

    fn flush(&mut self, gauges: &NetGauges) -> bool {
        if self.dead || self.wbuf.is_empty() {
            return false;
        }
        let mut progressed = false;
        loop {
            if self.wbuf.is_empty() {
                break;
            }
            match self.stream.write(&self.wbuf) {
                Ok(0) => {
                    self.dead = true;
                    break;
                }
                Ok(n) => {
                    self.wbuf.drain(..n);
                    gauges.bytes_out.fetch_add(n as u64, Ordering::Relaxed);
                    progressed = true;
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                Err(_) => {
                    self.dead = true;
                    break;
                }
            }
        }
        progressed
    }
}

/// Classifies one JSON request line into deferred work: commands and
/// malformed requests render immediately; evals and session pulses
/// carry their resolved addressing to the submit step. `sessions` is
/// the connection's owned-session list — `open` records ids there for
/// connection-drop teardown.
fn classify_line(
    line: &str,
    coord: &Coordinator,
    gauges: &NetGauges,
    sessions: &mut Vec<u64>,
) -> Work {
    let reply = |j: Json| Work::Reply(json_reply(&j));
    let doc = match json::parse(line) {
        Ok(d) => d,
        Err(e) => return reply(err(ErrorCode::BadRequest, format!("bad json: {e}"))),
    };
    // Optional backend pin, honored on EVERY request kind (commands
    // included): one backend per deployment, so a request naming a
    // different one is a deployment mismatch, not a routable request.
    // A malformed pin is rejected, never silently treated as absent.
    if let Some(pin) = doc.get("backend") {
        match pin.str() {
            Some(want) if want == coord.backend_name() => {}
            Some(want) => {
                return reply(err(
                    ErrorCode::BackendUnavailable,
                    format!(
                        "this deployment serves backend '{}', not '{want}'",
                        coord.backend_name()
                    ),
                ))
            }
            None => {
                return reply(err(
                    ErrorCode::BadRequest,
                    "'backend' must be a backend-name string".into(),
                ))
            }
        }
    }
    if let Some(cmd) = doc.get("cmd").and_then(|c| c.str()) {
        return match cmd {
            "metrics" => reply(metrics_doc(coord, gauges)),
            "ping" => reply(Json::obj(vec![("ok", Json::Bool(true)), ("pong", Json::Bool(true))])),
            "open" => classify_open_json(&doc, coord, sessions),
            "pulse" => classify_pulse_json(&doc),
            "close" => match session_id_field(&doc) {
                Ok(id) => Work::Close(CloseReq { id, binary: false }),
                Err(e) => reply(err(ErrorCode::BadRequest, e)),
            },
            other => reply(err(ErrorCode::BadRequest, format!("unknown cmd '{other}'"))),
        };
    }
    let Some(raw_values) = doc.get("values").and_then(|v| v.as_arr()) else {
        return reply(err(ErrorCode::BadRequest, "missing 'values' array".into()));
    };
    // Every entry must be a finite number. filter_map-style skipping
    // would silently misalign the reply with the request — the client
    // would get N−k outputs for N inputs with no error.
    let mut values = Vec::with_capacity(raw_values.len());
    for (i, v) in raw_values.iter().enumerate() {
        match v.num() {
            Some(x) if x.is_finite() => values.push(x as f32),
            Some(x) => {
                return reply(err(
                    ErrorCode::BadRequest,
                    format!("values[{i}] is not finite ({x})"),
                ))
            }
            None => {
                return reply(err(
                    ErrorCode::BadRequest,
                    format!("values[{i}] is not a number"),
                ))
            }
        }
    }
    // "spec" addresses an exact design point; "method" is the short
    // form for that method's first served spec. Grammar failures are
    // bad_request; a parsed-but-unserved spec/method is unknown_spec
    // (the same split the coordinator applies).
    let spec = if let Some(spec_str) = doc.get("spec").and_then(|s| s.str()) {
        match MethodSpec::parse(spec_str) {
            Ok(spec) => spec,
            Err(e) => return reply(err(ErrorCode::BadRequest, e)),
        }
    } else if let Some(name) = doc.get("method").and_then(|m| m.str()) {
        let method = match MethodId::parse_or_err(name) {
            Ok(m) => m,
            Err(e) => return reply(err(ErrorCode::BadRequest, e)),
        };
        match coord.specs().iter().find(|s| s.method_id() == method) {
            Some(spec) => *spec,
            None => {
                return reply(err(
                    ErrorCode::UnknownSpec,
                    format!("no served spec for method {}", method.name()),
                ))
            }
        }
    } else {
        return reply(err(ErrorCode::BadRequest, "missing 'method' or 'spec'".into()));
    };
    Work::Eval(EvalReq { spec, values, binary: false, first_try: None })
}

/// Classifies one binary frame body: `spec_id u16 | reserved u16 |
/// N × i64 input raws`, validated against the spec's input format.
fn classify_binary(body: &[u8], coord: &Coordinator) -> Work {
    let reply = |code: ErrorCode, msg: String| Work::Reply(bin_err_frame(code, &msg));
    if body.len() < 4 {
        return reply(
            ErrorCode::BadRequest,
            format!("binary frame body of {} bytes is shorter than the 4-byte header", body.len()),
        );
    }
    let spec_id = u16::from_le_bytes([body[0], body[1]]) as usize;
    let payload = &body[4..];
    if payload.len() % 8 != 0 {
        return reply(
            ErrorCode::BadRequest,
            format!("binary payload of {} bytes is not a whole number of i64 words", payload.len()),
        );
    }
    let specs = coord.specs();
    let Some(spec) = specs.get(spec_id) else {
        return reply(
            ErrorCode::UnknownSpec,
            format!(
                "spec id {spec_id} is not registered (serving {} specs, ids in the \
                 metrics 'specs' order)",
                specs.len()
            ),
        );
    };
    let in_fmt = spec.io.input;
    let ulp = in_fmt.ulp();
    let mut values = Vec::with_capacity(payload.len() / 8);
    for (i, word) in payload.chunks_exact(8).enumerate() {
        let raw = i64::from_le_bytes(word.try_into().unwrap());
        if raw < in_fmt.min_raw() || raw > in_fmt.max_raw() {
            return reply(
                ErrorCode::BadRequest,
                format!("values[{i}] raw {raw} is out of range for input format {in_fmt}"),
            );
        }
        values.push((raw as f64 * ulp) as f32);
    }
    Work::Eval(EvalReq { spec: *spec, values, binary: true, first_try: None })
}

/// True for the four request magics that select (and are valid in)
/// binary mode.
fn is_bin_request_magic(b: u8) -> bool {
    matches!(b, BIN_REQUEST_MAGIC | BIN_OPEN_MAGIC | BIN_PULSE_MAGIC | BIN_CLOSE_MAGIC)
}

/// Classifies one binary pulse frame body: `session id u64 |
/// N × input raw i64`.
fn classify_pulse(body: &[u8]) -> Work {
    if body.len() < 8 || (body.len() - 8) % 8 != 0 {
        return Work::Reply(bin_err_frame(
            ErrorCode::BadRequest,
            &format!(
                "pulse frame body must be a session id u64 plus whole i64 words, got {} bytes",
                body.len()
            ),
        ));
    }
    let id = u64::from_le_bytes(body[..8].try_into().unwrap());
    let input: Vec<i64> = body[8..]
        .chunks_exact(8)
        .map(|w| i64::from_le_bytes(w.try_into().unwrap()))
        .collect();
    Work::Pulse(PulseReq { id, input, binary: true, first_try: None })
}

/// Classifies one binary close frame body: `session id u64`.
fn classify_close(body: &[u8]) -> Work {
    if body.len() != 8 {
        return Work::Reply(bin_err_frame(
            ErrorCode::BadRequest,
            &format!("close frame body must be 8 bytes (session id u64), got {}", body.len()),
        ));
    }
    Work::Close(CloseReq {
        id: u64::from_le_bytes(body.try_into().unwrap()),
        binary: true,
    })
}

/// Handles the JSON `open` command: `"spec"` opens a spec stream,
/// `"cell": "lstm"` + `"lanes"` opens a cell session. Open is
/// synchronous, so the reply renders here; the id is recorded in the
/// connection's owned-session list.
fn classify_open_json(doc: &Json, coord: &Coordinator, sessions: &mut Vec<u64>) -> Work {
    let reply = |j: Json| Work::Reply(json_reply(&j));
    let opened = if let Some(spec_str) = doc.get("spec").and_then(|s| s.str()) {
        match MethodSpec::parse(spec_str) {
            Ok(spec) => coord.open_session(&spec),
            Err(e) => return reply(err(ErrorCode::BadRequest, e)),
        }
    } else if let Some(cell) = doc.get("cell").and_then(|c| c.str()) {
        if cell != "lstm" {
            return reply(err(
                ErrorCode::BadRequest,
                format!("unknown cell kind '{cell}' (serving: lstm)"),
            ));
        }
        let lanes = match doc.get("lanes").and_then(|l| l.num()) {
            Some(l) if l >= 1.0 && l <= 65536.0 && l.fract() == 0.0 => l as usize,
            _ => {
                return reply(err(
                    ErrorCode::BadRequest,
                    "'lanes' must be an integer in 1..=65536".into(),
                ))
            }
        };
        coord.open_cell_session(lanes)
    } else {
        return reply(err(
            ErrorCode::BadRequest,
            "open needs a 'spec' string or 'cell': \"lstm\"".into(),
        ));
    };
    match opened {
        Ok(info) => {
            sessions.push(info.id);
            reply(Json::obj(vec![
                ("ok", Json::Bool(true)),
                ("session", Json::i(info.id as i64)),
                ("delay", Json::i(info.delay as i64)),
            ]))
        }
        Err(e) => reply(err(e.code, e.message)),
    }
}

/// Extracts the `"session"` id field of a pulse/close command.
fn session_id_field(doc: &Json) -> Result<u64, String> {
    match doc.get("session").and_then(|s| s.num()) {
        Some(x) if x >= 0.0 && x.fract() == 0.0 => Ok(x as u64),
        _ => Err("'session' must be a non-negative integer session id".into()),
    }
}

/// Handles the JSON `pulse` command: raw integer words in `values`
/// (session payloads are raw-addressed in both framings — see the
/// module doc).
fn classify_pulse_json(doc: &Json) -> Work {
    let reply = |j: Json| Work::Reply(json_reply(&j));
    let id = match session_id_field(doc) {
        Ok(id) => id,
        Err(e) => return reply(err(ErrorCode::BadRequest, e)),
    };
    let Some(raw_values) = doc.get("values").and_then(|v| v.as_arr()) else {
        return reply(err(ErrorCode::BadRequest, "missing 'values' array".into()));
    };
    let mut input = Vec::with_capacity(raw_values.len());
    for (i, v) in raw_values.iter().enumerate() {
        match v.num() {
            Some(x) if x.is_finite() && x.fract() == 0.0 => input.push(x as i64),
            _ => {
                return reply(err(
                    ErrorCode::BadRequest,
                    format!("values[{i}] must be an integer raw word"),
                ))
            }
        }
    }
    Work::Pulse(PulseReq { id, input, binary: false, first_try: None })
}

/// The `cmd: metrics` reply document: coordinator snapshot (with the
/// net gauges folded in) + served spec list.
fn metrics_doc(coord: &Coordinator, gauges: &NetGauges) -> Json {
    let mut m = coord.metrics();
    gauges.snapshot().fill(&mut m);
    Json::obj(vec![
        ("ok", Json::Bool(true)),
        ("backend", Json::s(coord.backend_name())),
        ("submitted", Json::i(m.submitted as i64)),
        ("requests", Json::i(m.requests as i64)),
        ("failed_requests", Json::i(m.failed_requests as i64)),
        ("backend_failed_requests", Json::i(m.backend_failed_requests as i64)),
        ("admission_failed_requests", Json::i(m.admission_failed_requests as i64)),
        ("elements", Json::i(m.elements as i64)),
        ("batches", Json::i(m.batches as i64)),
        ("packed_batches", Json::i(m.packed_batches as i64)),
        ("rejected", Json::i(m.rejected as i64)),
        ("errors", Json::i(m.errors as i64)),
        ("mean_latency_us", Json::n(m.mean_latency_us())),
        ("p50_us", Json::n(m.p50_us())),
        ("p95_us", Json::n(m.p95_us())),
        ("p99_us", Json::n(m.p99_us())),
        ("max_latency_us", Json::i(m.latency_us_max() as i64)),
        ("sim_cycles", Json::i(m.sim_cycles as i64)),
        ("sim_cycles_per_element", Json::n(m.sim_cycles_per_element())),
        ("shards_per_method", Json::i(coord.shards_per_method() as i64)),
        ("batch_efficiency", Json::n(m.batch_efficiency())),
        ("batch_fill_rate", Json::n(m.fill_rate())),
        ("padded_elements", Json::i(m.padded_elements as i64)),
        ("kernel_cache_hits", Json::i(m.kernel_cache_hits as i64)),
        ("kernel_compiles", Json::i(m.kernel_compiles as i64)),
        ("accepted_conns", Json::i(m.accepted_conns as i64)),
        ("active_conns", Json::i(m.active_conns as i64)),
        ("bytes_in", Json::i(m.net_bytes_in as i64)),
        ("bytes_out", Json::i(m.net_bytes_out as i64)),
        ("sessions_open", Json::i(m.sessions_open as i64)),
        ("sessions_evicted", Json::i(m.sessions_evicted as i64)),
        (
            "specs",
            Json::arr(coord.specs().iter().map(|s| Json::s(s.to_string())).collect()),
        ),
    ])
}

fn err(code: ErrorCode, msg: String) -> Json {
    Json::obj(vec![
        ("ok", Json::Bool(false)),
        ("code", Json::s(code.as_str())),
        ("error", Json::s(msg)),
    ])
}

/// Serializes one JSON reply line (document + newline).
fn json_reply(doc: &Json) -> Vec<u8> {
    let mut text = doc.to_string_compact();
    text.push('\n');
    text.into_bytes()
}

/// Renders a finished eval in the connection's framing. Binary ok
/// replies carry output raws re-quantized with the shared golden
/// conventions ([`quantize_input`] on the output format) — exact for
/// the ≤ 24-bit output formats the served design points use.
fn render_result(out_fmt: QFormat, binary: bool, result: RequestResult) -> Vec<u8> {
    match result.outcome {
        Ok(out) => {
            if binary {
                bin_ok_frame(&quantize_input(&out, out_fmt))
            } else {
                json_reply(&Json::obj(vec![
                    ("ok", Json::Bool(true)),
                    ("values", Json::arr(out.into_iter().map(|v| Json::n(v as f64)).collect())),
                    ("latency_us", Json::i(result.latency_us as i64)),
                ]))
            }
        }
        Err(e) => render_error(binary, e.code, &e.message),
    }
}

fn render_error(binary: bool, code: ErrorCode, msg: &str) -> Vec<u8> {
    if binary {
        bin_err_frame(code, msg)
    } else {
        json_reply(&err(code, msg.to_string()))
    }
}

/// Renders a finished pulse (or close flush) in the connection's
/// framing: the released raw output words, plus the cumulative
/// `issued`/`delivered` counters on the JSON side.
fn render_pulse(binary: bool, result: Result<PulseOutcome, RequestError>) -> Vec<u8> {
    match result {
        Ok(out) => {
            if binary {
                bin_ok_frame(&out.outputs)
            } else {
                json_reply(&Json::obj(vec![
                    ("ok", Json::Bool(true)),
                    ("values", Json::arr(out.outputs.iter().map(|&r| Json::i(r)).collect())),
                    ("issued", Json::i(out.issued as i64)),
                    ("delivered", Json::i(out.delivered as i64)),
                ]))
            }
        }
        Err(e) => render_error(binary, e.code, &e.message),
    }
}

/// Checked encoder for one binary reply frame: enforces `limit` (and
/// the `u32` length-prefix ceiling, [`BIN_MAX_BODY`]) on the body
/// **before** the length cast. Regression: the unchecked `as u32`
/// cast truncated oversize bodies silently, so the emitted length
/// prefix disagreed with the payload and every later frame on the
/// stream desynchronized. Production passes [`BIN_MAX_BODY`]; tests
/// inject a small limit (a > 4 GiB body is unallocatable in a test).
pub fn try_bin_reply_frame(status: u8, payload: &[u8], limit: usize) -> Result<Vec<u8>, String> {
    let body_len = 1 + payload.len();
    let cap = limit.min(BIN_MAX_BODY);
    if body_len > cap {
        return Err(format!("reply frame body of {body_len} bytes exceeds the {cap}-byte limit"));
    }
    let mut out = Vec::with_capacity(BIN_HEADER + body_len);
    out.push(BIN_REPLY_MAGIC);
    out.extend_from_slice(&(body_len as u32).to_le_bytes());
    out.push(status);
    out.extend_from_slice(payload);
    Ok(out)
}

/// Server-side reply framing: an unframeable body degrades to a typed
/// `bad_request` error frame naming the limit, never to a frame with a
/// truncated length prefix.
fn bin_frame(status: u8, payload: &[u8]) -> Vec<u8> {
    match try_bin_reply_frame(status, payload, BIN_MAX_BODY) {
        Ok(frame) => frame,
        Err(msg) => try_bin_reply_frame(ErrorCode::BadRequest.as_u8(), msg.as_bytes(), BIN_MAX_BODY)
            .expect("error detail always fits a frame"),
    }
}

fn bin_ok_frame(raws: &[i64]) -> Vec<u8> {
    let mut payload = Vec::with_capacity(raws.len() * 8);
    for r in raws {
        payload.extend_from_slice(&r.to_le_bytes());
    }
    bin_frame(0, &payload)
}

fn bin_err_frame(code: ErrorCode, msg: &str) -> Vec<u8> {
    bin_frame(code.as_u8(), msg.as_bytes())
}

/// Checked encoder for one binary eval request frame — same length
/// discipline as [`try_bin_reply_frame`].
pub fn try_bin_request_frame(
    spec_id: u16,
    raws: &[i64],
    limit: usize,
) -> Result<Vec<u8>, String> {
    let body_len = 4 + raws.len() * 8;
    let cap = limit.min(BIN_MAX_BODY);
    if body_len > cap {
        return Err(format!(
            "request frame body of {body_len} bytes exceeds the {cap}-byte limit"
        ));
    }
    let mut out = Vec::with_capacity(BIN_HEADER + body_len);
    out.push(BIN_REQUEST_MAGIC);
    out.extend_from_slice(&(body_len as u32).to_le_bytes());
    out.extend_from_slice(&spec_id.to_le_bytes());
    out.extend_from_slice(&[0u8, 0u8]); // reserved
    for r in raws {
        out.extend_from_slice(&r.to_le_bytes());
    }
    Ok(out)
}

/// Encodes one binary request frame (shared by [`BinClient`] and the
/// socket driver).
pub fn bin_request_frame(spec_id: u16, raws: &[i64]) -> Vec<u8> {
    try_bin_request_frame(spec_id, raws, BIN_MAX_BODY)
        .expect("request body exceeds the u32 length-prefix ceiling")
}

/// Encodes one binary session-open frame (body: `spec_id u16 |
/// reserved u16`).
pub fn bin_open_frame(spec_id: u16) -> Vec<u8> {
    let mut out = Vec::with_capacity(BIN_HEADER + 4);
    out.push(BIN_OPEN_MAGIC);
    out.extend_from_slice(&4u32.to_le_bytes());
    out.extend_from_slice(&spec_id.to_le_bytes());
    out.extend_from_slice(&[0u8, 0u8]); // reserved
    out
}

/// Checked encoder for one binary session-pulse frame (body:
/// `session id u64 | N × input raw i64`) — same length discipline as
/// [`try_bin_reply_frame`].
pub fn try_bin_pulse_frame(session: u64, raws: &[i64], limit: usize) -> Result<Vec<u8>, String> {
    let body_len = 8 + raws.len() * 8;
    let cap = limit.min(BIN_MAX_BODY);
    if body_len > cap {
        return Err(format!("pulse frame body of {body_len} bytes exceeds the {cap}-byte limit"));
    }
    let mut out = Vec::with_capacity(BIN_HEADER + body_len);
    out.push(BIN_PULSE_MAGIC);
    out.extend_from_slice(&(body_len as u32).to_le_bytes());
    out.extend_from_slice(&session.to_le_bytes());
    for r in raws {
        out.extend_from_slice(&r.to_le_bytes());
    }
    Ok(out)
}

/// Encodes one binary session-close frame (body: `session id u64`).
pub fn bin_close_frame(session: u64) -> Vec<u8> {
    let mut out = Vec::with_capacity(BIN_HEADER + 8);
    out.push(BIN_CLOSE_MAGIC);
    out.extend_from_slice(&8u32.to_le_bytes());
    out.extend_from_slice(&session.to_le_bytes());
    out
}

/// Minimal blocking client for the JSON line protocol (used by the
/// example, the tests and the socket driver).
pub struct NetClient {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl NetClient {
    /// Connects to a server.
    pub fn connect(addr: std::net::SocketAddr) -> std::io::Result<NetClient> {
        let stream = TcpStream::connect(addr)?;
        let _ = stream.set_nodelay(true);
        let writer = stream.try_clone()?;
        Ok(NetClient { reader: BufReader::new(stream), writer })
    }

    /// Sends one request document without waiting (pipelining).
    pub fn send(&mut self, req: &Json) -> Result<(), String> {
        let mut text = req.to_string_compact();
        text.push('\n');
        self.writer.write_all(text.as_bytes()).map_err(|e| e.to_string())
    }

    /// Reads the next response line.
    pub fn recv(&mut self) -> Result<Json, String> {
        let mut line = String::new();
        let n = self.reader.read_line(&mut line).map_err(|e| e.to_string())?;
        if n == 0 {
            return Err("connection closed by server".into());
        }
        json::parse(line.trim_end())
    }

    /// Sends one request document, waits for the response line.
    pub fn call(&mut self, req: &Json) -> Result<Json, String> {
        self.send(req)?;
        self.recv()
    }

    /// Evaluates a batch of activations. Failures format as
    /// `"<code>: <detail>"` using the stable protocol codes.
    pub fn evaluate(&mut self, method: &str, values: &[f32]) -> Result<Vec<f32>, String> {
        let req = Json::obj(vec![
            ("method", Json::s(method)),
            ("values", Json::arr(values.iter().map(|v| Json::n(*v as f64)).collect())),
        ]);
        let resp = self.call(&req)?;
        reply_values(&resp)
    }

    /// Opens a streaming session against a served spec string; returns
    /// `(session id, delay)`.
    pub fn open_session(&mut self, spec: &str) -> Result<(u64, u64), String> {
        let req = Json::obj(vec![("cmd", Json::s("open")), ("spec", Json::s(spec))]);
        let resp = self.call(&req)?;
        session_info(&resp)
    }

    /// Opens an LSTM cell-graph session `lanes` cells wide; returns
    /// `(session id, delay)` (delay is always 0 for cells).
    pub fn open_cell_session(&mut self, lanes: usize) -> Result<(u64, u64), String> {
        let req = Json::obj(vec![
            ("cmd", Json::s("open")),
            ("cell", Json::s("lstm")),
            ("lanes", Json::i(lanes as i64)),
        ]);
        let resp = self.call(&req)?;
        session_info(&resp)
    }

    /// Feeds one pulse of raw input words; returns the released output
    /// raws (delay window applied).
    pub fn pulse(&mut self, session: u64, raws: &[i64]) -> Result<Vec<i64>, String> {
        let req = Json::obj(vec![
            ("cmd", Json::s("pulse")),
            ("session", Json::i(session as i64)),
            ("values", Json::arr(raws.iter().map(|&r| Json::i(r)).collect())),
        ]);
        let resp = self.call(&req)?;
        reply_raws(&resp)
    }

    /// Closes a session; returns the flushed delay-window tail.
    pub fn close_session(&mut self, session: u64) -> Result<Vec<i64>, String> {
        let req =
            Json::obj(vec![("cmd", Json::s("close")), ("session", Json::i(session as i64))]);
        let resp = self.call(&req)?;
        reply_raws(&resp)
    }
}

/// Extracts `(session id, delay)` from a successful `open` reply.
fn session_info(resp: &Json) -> Result<(u64, u64), String> {
    if resp.get("ok").map(|o| *o == Json::Bool(true)) != Some(true) {
        let code = resp.get("code").and_then(|c| c.str()).unwrap_or("internal");
        let detail = resp.get("error").and_then(|e| e.str()).unwrap_or("unknown error");
        return Err(format!("{code}: {detail}"));
    }
    let id = resp.get("session").and_then(|v| v.num()).ok_or("open reply missing 'session'")?;
    let delay = resp.get("delay").and_then(|v| v.num()).ok_or("open reply missing 'delay'")?;
    Ok((id as u64, delay as u64))
}

/// Extracts the raw-word `values` of a successful session reply (the
/// integer-valued mirror of [`reply_values`]).
pub fn reply_raws(resp: &Json) -> Result<Vec<i64>, String> {
    if resp.get("ok").map(|o| *o == Json::Bool(true)) != Some(true) {
        let code = resp.get("code").and_then(|c| c.str()).unwrap_or("internal");
        let detail = resp.get("error").and_then(|e| e.str()).unwrap_or("unknown error");
        return Err(format!("{code}: {detail}"));
    }
    let arr = resp.get("values").and_then(|v| v.as_arr()).ok_or("missing values")?;
    let mut out = Vec::with_capacity(arr.len());
    for (i, v) in arr.iter().enumerate() {
        match v.num() {
            Some(x) if x.fract() == 0.0 => out.push(x as i64),
            _ => return Err(format!("reply values[{i}] is not an integer raw word")),
        }
    }
    Ok(out)
}

/// Extracts the `values` of a successful JSON reply, strictly: every
/// entry must be a number (the reply-side mirror of the request-side
/// validation — skipping entries would silently misalign results).
pub fn reply_values(resp: &Json) -> Result<Vec<f32>, String> {
    if resp.get("ok").map(|o| *o == Json::Bool(true)) != Some(true) {
        let code = resp.get("code").and_then(|c| c.str()).unwrap_or("internal");
        let detail = resp.get("error").and_then(|e| e.str()).unwrap_or("unknown error");
        return Err(format!("{code}: {detail}"));
    }
    let arr = resp.get("values").and_then(|v| v.as_arr()).ok_or("missing values")?;
    let mut out = Vec::with_capacity(arr.len());
    for (i, v) in arr.iter().enumerate() {
        match v.num() {
            Some(x) => out.push(x as f32),
            None => return Err(format!("reply values[{i}] is not a number")),
        }
    }
    Ok(out)
}

/// Minimal blocking client for the binary frame protocol: raw i64
/// words in the spec's I/O formats, addressed by registered spec id.
pub struct BinClient {
    stream: TcpStream,
}

impl BinClient {
    /// Connects; the first frame written switches the connection to
    /// binary mode.
    pub fn connect(addr: std::net::SocketAddr) -> std::io::Result<BinClient> {
        let stream = TcpStream::connect(addr)?;
        let _ = stream.set_nodelay(true);
        Ok(BinClient { stream })
    }

    /// Writes one request frame without waiting (pipelining).
    pub fn send(&mut self, spec_id: u16, raws: &[i64]) -> Result<(), String> {
        self.stream
            .write_all(&bin_request_frame(spec_id, raws))
            .map_err(|e| e.to_string())
    }

    /// Reads the next reply frame. Server failures format as
    /// `"<code>: <detail>"` like [`NetClient::evaluate`].
    pub fn recv(&mut self) -> Result<Vec<i64>, String> {
        let mut header = [0u8; BIN_HEADER];
        self.stream.read_exact(&mut header).map_err(|e| e.to_string())?;
        if header[0] != BIN_REPLY_MAGIC {
            return Err(format!("bad reply magic 0x{:02x}", header[0]));
        }
        let len = u32::from_le_bytes([header[1], header[2], header[3], header[4]]) as usize;
        if len == 0 {
            return Err("empty reply frame".into());
        }
        let mut body = vec![0u8; len];
        self.stream.read_exact(&mut body).map_err(|e| e.to_string())?;
        let status = body[0];
        let payload = &body[1..];
        if status != 0 {
            let code = ErrorCode::from_u8(status)
                .map(|c| c.as_str())
                .unwrap_or("internal");
            let detail = String::from_utf8_lossy(payload);
            return Err(format!("{code}: {detail}"));
        }
        if payload.len() % 8 != 0 {
            return Err(format!("reply payload of {} bytes is not i64-aligned", payload.len()));
        }
        Ok(payload
            .chunks_exact(8)
            .map(|w| i64::from_le_bytes(w.try_into().unwrap()))
            .collect())
    }

    /// Evaluates one batch of raw input words, blocking for the reply.
    pub fn evaluate_raw(&mut self, spec_id: u16, raws: &[i64]) -> Result<Vec<i64>, String> {
        self.send(spec_id, raws)?;
        self.recv()
    }

    /// Opens a streaming session against a registered spec id; returns
    /// `(session id, delay)`.
    pub fn open(&mut self, spec_id: u16) -> Result<(u64, u64), String> {
        self.stream.write_all(&bin_open_frame(spec_id)).map_err(|e| e.to_string())?;
        let words = self.recv()?;
        if words.len() != 2 {
            return Err(format!(
                "open reply carried {} words, want 2 (session id, delay)",
                words.len()
            ));
        }
        Ok((words[0] as u64, words[1] as u64))
    }

    /// Feeds one pulse of raw input words; returns the released output
    /// raws (delay window applied).
    pub fn pulse(&mut self, session: u64, raws: &[i64]) -> Result<Vec<i64>, String> {
        let frame = try_bin_pulse_frame(session, raws, BIN_MAX_BODY)?;
        self.stream.write_all(&frame).map_err(|e| e.to_string())?;
        self.recv()
    }

    /// Closes a session; returns the flushed delay-window tail.
    pub fn close(&mut self, session: u64) -> Result<Vec<i64>, String> {
        self.stream.write_all(&bin_close_frame(session)).map_err(|e| e.to_string())?;
        self.recv()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::GoldenBackend;
    use crate::coordinator::CoordinatorConfig;
    use crate::fixed::Fx;

    fn start_server() -> (NetServer, Arc<Coordinator>) {
        let coord = Arc::new(
            Coordinator::start(
                Arc::new(GoldenBackend::new()),
                CoordinatorConfig::with_batch(256),
            )
            .unwrap(),
        );
        let server = NetServer::start(coord.clone(), "127.0.0.1:0").unwrap();
        (server, coord)
    }

    fn assert_code(resp: &Json, code: &str) {
        assert_eq!(resp.get("ok"), Some(&Json::Bool(false)), "{resp:?}");
        assert_eq!(resp.get("code").and_then(|c| c.str()), Some(code), "{resp:?}");
        assert!(
            resp.get("error").and_then(|e| e.str()).is_some_and(|e| !e.is_empty()),
            "{resp:?}"
        );
    }

    /// Writes raw bytes on a fresh connection and reads reply lines —
    /// for payloads the Json builder cannot express (invalid JSON,
    /// oversized lines).
    fn raw_call(addr: std::net::SocketAddr, bytes: &[u8]) -> (TcpStream, BufReader<TcpStream>) {
        let stream = TcpStream::connect(addr).unwrap();
        let reader = BufReader::new(stream.try_clone().unwrap());
        let mut w = stream.try_clone().unwrap();
        w.write_all(bytes).unwrap();
        (stream, reader)
    }

    #[test]
    fn roundtrip_evaluate() {
        let (server, _coord) = start_server();
        let mut client = NetClient::connect(server.addr()).unwrap();
        let out = client.evaluate("pwl", &[0.5, -0.5, 0.0]).unwrap();
        assert_eq!(out.len(), 3);
        assert!((out[0] - 0.4621f32).abs() < 1e-3);
        assert_eq!(out[2], 0.0);
        server.stop();
    }

    #[test]
    fn metrics_and_ping() {
        let (server, _coord) = start_server();
        let mut client = NetClient::connect(server.addr()).unwrap();
        let pong = client.call(&Json::obj(vec![("cmd", Json::s("ping"))])).unwrap();
        assert_eq!(pong.get("pong"), Some(&Json::Bool(true)));
        client.evaluate("lambert", &[1.0]).unwrap();
        let m = client.call(&Json::obj(vec![("cmd", Json::s("metrics"))])).unwrap();
        assert!(m.get("requests").unwrap().num().unwrap() >= 1.0);
        assert!(m.get("submitted").unwrap().num().unwrap() >= 1.0);
        assert!(m.get("p50_us").is_some() && m.get("p99_us").is_some());
        assert!(m.get("shards_per_method").unwrap().num().unwrap() >= 2.0);
        // Backend-era observables: which backend served, the failure
        // split, and the simulated-cycle column (zero on golden).
        assert_eq!(m.get("backend").and_then(|b| b.str()), Some("golden"));
        assert_eq!(m.get("backend_failed_requests").unwrap().num(), Some(0.0));
        assert_eq!(m.get("admission_failed_requests").unwrap().num(), Some(0.0));
        assert_eq!(m.get("sim_cycles").unwrap().num(), Some(0.0));
        // The shared-cache observables and the served spec list are on
        // the metrics endpoint.
        assert!(m.get("kernel_compiles").unwrap().num().unwrap() >= 6.0);
        assert!(m.get("kernel_cache_hits").is_some());
        assert_eq!(m.get("specs").unwrap().as_arr().unwrap().len(), 6);
        // Net-layer gauges: this connection is accepted and active,
        // and traffic has flowed both ways.
        assert!(m.get("accepted_conns").unwrap().num().unwrap() >= 1.0, "{m:?}");
        assert!(m.get("active_conns").unwrap().num().unwrap() >= 1.0, "{m:?}");
        assert!(m.get("bytes_in").unwrap().num().unwrap() > 0.0, "{m:?}");
        assert!(m.get("bytes_out").unwrap().num().unwrap() > 0.0, "{m:?}");
        let g = server.gauges();
        assert!(g.accepted_conns >= 1 && g.bytes_in > 0 && g.bytes_out > 0, "{g:?}");
        server.stop();
    }

    #[test]
    fn spec_addressed_requests_roundtrip() {
        let (server, _coord) = start_server();
        let mut client = NetClient::connect(server.addr()).unwrap();
        let req = Json::obj(vec![
            ("spec", Json::s("pwl:step=1/64:in=S3.12:out=S.15")),
            ("values", Json::arr(vec![Json::n(0.5)])),
        ]);
        let resp = client.call(&req).unwrap();
        assert_eq!(resp.get("ok"), Some(&Json::Bool(true)), "{resp:?}");
        // A valid but unserved spec fails with unknown_spec + the
        // served list.
        let req = Json::obj(vec![
            ("spec", Json::s("pwl:step=1/32")),
            ("values", Json::arr(vec![Json::n(0.5)])),
        ]);
        let resp = client.call(&req).unwrap();
        assert_code(&resp, "unknown_spec");
        assert!(resp.get("error").unwrap().str().unwrap().contains("not served"));
        // A malformed spec fails with bad_request + a grammar-ish error.
        let req = Json::obj(vec![
            ("spec", Json::s("pwl:step=1/3")),
            ("values", Json::arr(vec![Json::n(0.5)])),
        ]);
        let resp = client.call(&req).unwrap();
        assert_code(&resp, "bad_request");
        server.stop();
    }

    #[test]
    fn error_paths_carry_stable_codes() {
        let (server, _coord) = start_server();
        let mut client = NetClient::connect(server.addr()).unwrap();
        // bad json
        let resp = client.call(&Json::s("not an object")).unwrap();
        assert_code(&resp, "bad_request");
        // unknown cmd
        let resp = client.call(&Json::obj(vec![("cmd", Json::s("reboot"))])).unwrap();
        assert_code(&resp, "bad_request");
        // missing values
        let resp = client.call(&Json::obj(vec![("method", Json::s("pwl"))])).unwrap();
        assert_code(&resp, "bad_request");
        // unknown method (the client folds code + detail into the Err)
        let err = client.evaluate("sinh", &[1.0]).unwrap_err();
        assert!(err.starts_with("bad_request:"), "{err}");
        assert!(err.contains("method"), "{err}");
        // empty values
        let err = client.evaluate("pwl", &[]).unwrap_err();
        assert!(err.starts_with("bad_request:"), "{err}");
        assert!(err.contains("empty"), "{err}");
        // oversized values → bad_request from admission
        let err = client.evaluate("pwl", &vec![0.0; 257]).unwrap_err();
        assert!(err.starts_with("bad_request:"), "{err}");
        assert!(err.contains("exceeds"), "{err}");
        server.stop();
    }

    #[test]
    fn non_numeric_values_rejected_by_index_not_dropped() {
        // Regression: filter_map used to silently drop the "x",
        // returning 2 outputs for 3 inputs — a misaligned reply.
        let (server, _coord) = start_server();
        let mut client = NetClient::connect(server.addr()).unwrap();
        let req = Json::obj(vec![
            ("method", Json::s("pwl")),
            ("values", Json::arr(vec![Json::n(1.0), Json::s("x"), Json::n(2.0)])),
        ]);
        let resp = client.call(&req).unwrap();
        assert_code(&resp, "bad_request");
        assert!(
            resp.get("error").unwrap().str().unwrap().contains("values[1]"),
            "error must name the offending index: {resp:?}"
        );
        // Mixed null / bool entries are rejected the same way.
        let req = Json::obj(vec![
            ("method", Json::s("pwl")),
            ("values", Json::arr(vec![Json::Null])),
        ]);
        let resp = client.call(&req).unwrap();
        assert_code(&resp, "bad_request");
        assert!(resp.get("error").unwrap().str().unwrap().contains("values[0]"), "{resp:?}");
        // The connection stays usable after a rejected request.
        assert_eq!(client.evaluate("pwl", &[0.0]).unwrap().len(), 1);
        server.stop();
    }

    #[test]
    fn nan_payload_rejected_as_bad_request() {
        // Regression companion: a raw `[NaN]` payload (which the Json
        // builder can no longer even express) must answer bad_request,
        // not evaluate a silently-shortened batch.
        let (server, _coord) = start_server();
        let (_s, mut reader) =
            raw_call(server.addr(), b"{\"method\":\"pwl\",\"values\":[NaN]}\n");
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        let resp = json::parse(line.trim_end()).unwrap();
        assert_code(&resp, "bad_request");
        server.stop();
    }

    #[test]
    fn oversized_line_answers_bad_request_and_closes() {
        // Regression: lines used to buffer without bound. With a small
        // frame cap, a long line (no newline yet) must answer
        // bad_request and close the connection.
        let coord = Arc::new(
            Coordinator::start(
                Arc::new(GoldenBackend::new()),
                CoordinatorConfig::with_batch(64),
            )
            .unwrap(),
        );
        let cfg = NetConfig { max_frame_bytes: 1024, ..NetConfig::default() };
        let server = NetServer::start_with(coord.clone(), "127.0.0.1:0", cfg).unwrap();
        let big = vec![b'{'; 8 * 1024];
        let (_s, mut reader) = raw_call(server.addr(), &big);
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        let resp = json::parse(line.trim_end()).unwrap();
        assert_code(&resp, "bad_request");
        assert!(resp.get("error").unwrap().str().unwrap().contains("1024"), "{resp:?}");
        // …and the server closes the connection: next read hits EOF.
        let mut rest = String::new();
        assert_eq!(reader.read_line(&mut rest).unwrap(), 0, "expected EOF, got {rest:?}");
        // A complete (newline-terminated) line over the cap is refused
        // the same way.
        let mut big = vec![b'x'; 4 * 1024];
        big.push(b'\n');
        let (_s2, mut reader2) = raw_call(server.addr(), &big);
        let mut line = String::new();
        reader2.read_line(&mut line).unwrap();
        assert_code(&json::parse(line.trim_end()).unwrap(), "bad_request");
        server.stop();
    }

    #[test]
    fn binary_roundtrip_is_bit_exact_and_oversized_frames_close() {
        let coord = Arc::new(
            Coordinator::start(
                Arc::new(GoldenBackend::new()),
                CoordinatorConfig::with_batch(64),
            )
            .unwrap(),
        );
        let cfg = NetConfig { max_frame_bytes: 4096, ..NetConfig::default() };
        let server = NetServer::start_with(coord.clone(), "127.0.0.1:0", cfg).unwrap();
        // Spec id 0 is the first served spec (Table I PWL).
        let spec = coord.specs()[0];
        let xs = [0.5f64, -0.5, 0.125, 3.75, -6.5, 0.0];
        let raws: Vec<i64> = xs.iter().map(|&x| Fx::from_f64(x, spec.io.input).raw()).collect();
        let mut client = BinClient::connect(server.addr()).unwrap();
        let out = client.evaluate_raw(0, &raws).unwrap();
        // Bit-exact vs a freshly compiled golden kernel on raw words.
        let kernel = spec.build().compile(spec.io);
        let mut want = vec![0i64; raws.len()];
        kernel.eval_slice_raw(&raws, &mut want);
        assert_eq!(out, want);
        // Unregistered spec id → unknown_spec, connection stays open.
        let err = client.evaluate_raw(999, &raws).unwrap_err();
        assert!(err.starts_with("unknown_spec:"), "{err}");
        // Out-of-range input raw → bad_request naming the index.
        let err = client.evaluate_raw(0, &[0, i64::MAX]).unwrap_err();
        assert!(err.starts_with("bad_request:"), "{err}");
        assert!(err.contains("values[1]"), "{err}");
        // Still serving after the errors.
        assert_eq!(client.evaluate_raw(0, &raws).unwrap(), want);
        // A frame whose header advertises an oversized body answers
        // bad_request and closes.
        let mut huge = vec![BIN_REQUEST_MAGIC];
        huge.extend_from_slice(&(1u32 << 24).to_le_bytes());
        let mut raw = TcpStream::connect(server.addr()).unwrap();
        raw.write_all(&huge).unwrap();
        let mut header = [0u8; BIN_HEADER];
        raw.read_exact(&mut header).unwrap();
        assert_eq!(header[0], BIN_REPLY_MAGIC);
        let len = u32::from_le_bytes([header[1], header[2], header[3], header[4]]) as usize;
        let mut body = vec![0u8; len];
        raw.read_exact(&mut body).unwrap();
        assert_eq!(ErrorCode::from_u8(body[0]), Some(ErrorCode::BadRequest));
        assert_eq!(raw.read(&mut header).unwrap(), 0, "expected EOF after overflow");
        server.stop();
    }

    #[test]
    fn pipelined_requests_reply_in_order() {
        let (server, _coord) = start_server();
        let mut client = NetClient::connect(server.addr()).unwrap();
        // Write a window of requests before reading anything; each
        // carries a distinct input so reply order is observable.
        let xs: Vec<f32> = (0..32).map(|i| i as f32 * 0.17 - 2.5).collect();
        for &x in &xs {
            let req = Json::obj(vec![
                ("method", Json::s("pwl")),
                ("values", Json::arr(vec![Json::n(x as f64)])),
            ]);
            client.send(&req).unwrap();
        }
        for &x in &xs {
            let resp = client.recv().unwrap();
            let out = reply_values(&resp).unwrap();
            assert_eq!(out.len(), 1);
            assert!(
                (out[0] - x.tanh()).abs() < 1e-3,
                "reply out of order? x={x} got {}",
                out[0]
            );
        }
        server.stop();
    }

    #[test]
    fn multiple_clients_interleave() {
        let (server, _coord) = start_server();
        let addr = server.addr();
        let handles: Vec<_> = (0..4)
            .map(|i| {
                std::thread::spawn(move || {
                    let mut c = NetClient::connect(addr).unwrap();
                    for j in 0..10 {
                        let x = (i * 10 + j) as f32 * 0.07 - 1.0;
                        let out = c.evaluate("taylor1", &[x]).unwrap();
                        assert!((out[0] - x.tanh()).abs() < 1e-3, "x={x}");
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        server.stop();
    }

    #[test]
    fn hw_backend_serves_over_the_wire_with_cycle_metrics() {
        use crate::backend::HwBackend;
        // The multi-backend acceptance at the net layer: an hw-backed
        // coordinator answers the same protocol, bit-identical to a
        // golden-backed one, and its metrics carry nonzero sim_cycles.
        let specs = vec![MethodSpec::table1(MethodId::Pwl)];
        let cfg = CoordinatorConfig {
            specs: specs.clone(),
            ..CoordinatorConfig::with_batch(64)
        };
        let hw = Arc::new(
            Coordinator::start(Arc::new(HwBackend::new()), cfg.clone()).unwrap(),
        );
        let golden = Arc::new(
            Coordinator::start(Arc::new(GoldenBackend::new()), cfg).unwrap(),
        );
        let hw_srv = NetServer::start(hw.clone(), "127.0.0.1:0").unwrap();
        let golden_srv = NetServer::start(golden.clone(), "127.0.0.1:0").unwrap();
        let mut hw_client = NetClient::connect(hw_srv.addr()).unwrap();
        let mut golden_client = NetClient::connect(golden_srv.addr()).unwrap();
        let xs = [0.5f32, -0.5, 0.125, 3.75, -6.5];
        let a = hw_client.evaluate("pwl", &xs).unwrap();
        let b = golden_client.evaluate("pwl", &xs).unwrap();
        for (x, (ya, yb)) in xs.iter().zip(a.iter().zip(&b)) {
            assert_eq!(ya.to_bits(), yb.to_bits(), "x={x}: hw {ya} vs golden {yb}");
        }
        // Backend-pinned requests: accepted when the pin matches the
        // deployment, refused with backend_unavailable otherwise.
        let pinned = Json::obj(vec![
            ("backend", Json::s("hw")),
            ("method", Json::s("pwl")),
            ("values", Json::arr(vec![Json::n(0.5)])),
        ]);
        let resp = hw_client.call(&pinned).unwrap();
        assert_eq!(resp.get("ok"), Some(&Json::Bool(true)), "{resp:?}");
        let resp = golden_client.call(&pinned).unwrap();
        assert_code(&resp, "backend_unavailable");
        // The pin is honored on command requests too.
        let pinned_cmd =
            Json::obj(vec![("cmd", Json::s("metrics")), ("backend", Json::s("golden"))]);
        let resp = hw_client.call(&pinned_cmd).unwrap();
        assert_code(&resp, "backend_unavailable");
        let resp = golden_client.call(&pinned_cmd).unwrap();
        assert_eq!(resp.get("ok"), Some(&Json::Bool(true)), "{resp:?}");
        let m = hw_client.call(&Json::obj(vec![("cmd", Json::s("metrics"))])).unwrap();
        assert_eq!(m.get("backend").and_then(|b| b.str()), Some("hw"));
        assert!(m.get("sim_cycles").unwrap().num().unwrap() > 0.0, "{m:?}");
        // Binary framing works against the hw backend too, bit-exact
        // with the golden coordinator's binary replies.
        let spec = specs[0];
        let raws: Vec<i64> =
            xs.iter().map(|&x| Fx::from_f64(x as f64, spec.io.input).raw()).collect();
        let mut hw_bin = BinClient::connect(hw_srv.addr()).unwrap();
        let mut golden_bin = BinClient::connect(golden_srv.addr()).unwrap();
        assert_eq!(
            hw_bin.evaluate_raw(0, &raws).unwrap(),
            golden_bin.evaluate_raw(0, &raws).unwrap()
        );
        hw_srv.stop();
        golden_srv.stop();
    }

    #[test]
    fn frame_builders_enforce_the_length_prefix_cap() {
        // Regression: `body_len as u32` used to truncate oversize
        // bodies silently, emitting a frame whose length prefix
        // disagreed with its payload. A > 4 GiB body is unallocatable
        // in a test, so the checked builders take the limit as a
        // parameter; production passes BIN_MAX_BODY.
        let raws = vec![0i64; 16];
        let err = try_bin_request_frame(0, &raws, 64).unwrap_err();
        assert!(err.contains("64-byte"), "must name the limit: {err}");
        assert!(err.contains("132"), "must name the body size: {err}");
        let err = try_bin_pulse_frame(1, &raws, 64).unwrap_err();
        assert!(err.contains("64-byte"), "{err}");
        let err = try_bin_reply_frame(0, &[0u8; 100], 64).unwrap_err();
        assert!(err.contains("64-byte"), "{err}");
        // At the limit, the frames encode with an honest prefix.
        let frame = try_bin_request_frame(3, &raws, 132).unwrap();
        assert_eq!(frame[0], BIN_REQUEST_MAGIC);
        assert_eq!(u32::from_le_bytes(frame[1..5].try_into().unwrap()), 132);
        assert_eq!(frame.len(), BIN_HEADER + 132);
        let frame = try_bin_pulse_frame(7, &raws, 136).unwrap();
        assert_eq!(frame[0], BIN_PULSE_MAGIC);
        assert_eq!(u32::from_le_bytes(frame[1..5].try_into().unwrap()), 136);
        let frame = try_bin_reply_frame(0, &[0u8; 63], 64).unwrap();
        assert_eq!(frame[0], BIN_REPLY_MAGIC);
        assert_eq!(u32::from_le_bytes(frame[1..5].try_into().unwrap()), 64);
    }

    #[test]
    fn json_session_open_pulse_close_roundtrip() {
        let (server, coord) = start_server();
        let mut client = NetClient::connect(server.addr()).unwrap();
        let spec = coord.specs()[0];
        let (id, delay) = client.open_session(&spec.to_string()).unwrap();
        assert_eq!(delay, 0, "golden streams are unbuffered");
        assert_eq!(coord.sessions_open(), 1);
        let raws: Vec<i64> = [0.5f64, -0.5, 0.125, 3.75]
            .iter()
            .map(|&x| Fx::from_f64(x, spec.io.input).raw())
            .collect();
        let kernel = spec.build().compile(spec.io);
        let mut want = vec![0i64; raws.len()];
        kernel.eval_slice_raw(&raws, &mut want);
        // Two pulses on the same session, each released in full
        // (delay 0), bit-exact vs the golden kernel.
        assert_eq!(client.pulse(id, &raws).unwrap(), want);
        assert_eq!(client.pulse(id, &raws).unwrap(), want);
        // Session gauges ride the metrics command.
        let m = client.call(&Json::obj(vec![("cmd", Json::s("metrics"))])).unwrap();
        assert!(m.get("sessions_open").unwrap().num().unwrap() >= 1.0, "{m:?}");
        assert_eq!(m.get("sessions_evicted").unwrap().num(), Some(0.0), "{m:?}");
        // Close flushes an empty tail (nothing was held back) and
        // unbinds the id.
        assert!(client.close_session(id).unwrap().is_empty());
        assert_eq!(coord.sessions_open(), 0);
        let err = client.pulse(id, &raws).unwrap_err();
        assert!(err.starts_with("bad_request:"), "{err}");
        assert!(err.contains("unknown session"), "{err}");
        // Cell sessions speak the same commands: one pulse is a step
        // of 4·lanes gate pre-activations owing `lanes` h words.
        let (cid, cdelay) = client.open_cell_session(4).unwrap();
        assert_eq!(cdelay, 0);
        let h = client.pulse(cid, &vec![0i64; 16]).unwrap();
        assert_eq!(h.len(), 4);
        // A wrong-width pulse is a typed bad_request, not a hang.
        let err = client.pulse(cid, &vec![0i64; 3]).unwrap_err();
        assert!(err.starts_with("bad_request:"), "{err}");
        client.close_session(cid).unwrap();
        // Open-side errors carry the stable codes too.
        let err = client.open_session("pwl:step=1/32").unwrap_err();
        assert!(err.starts_with("unknown_spec:"), "{err}");
        let err = client.open_session("pwl:step=1/3").unwrap_err();
        assert!(err.starts_with("bad_request:"), "{err}");
        server.stop();
    }

    #[test]
    fn binary_session_streams_with_delay_accounting() {
        use crate::backend::HwBackend;
        let specs = vec![MethodSpec::table1(MethodId::Pwl)];
        let cfg = CoordinatorConfig { specs: specs.clone(), ..CoordinatorConfig::with_batch(64) };
        let coord = Arc::new(Coordinator::start(Arc::new(HwBackend::new()), cfg).unwrap());
        let server = NetServer::start(coord.clone(), "127.0.0.1:0").unwrap();
        let spec = specs[0];
        let mut client = BinClient::connect(server.addr()).unwrap();
        let (id, delay) = client.open(0).unwrap();
        let delay = delay as usize;
        assert!(
            (1..32).contains(&delay),
            "hw pipeline must report a positive reply lag, got {delay}"
        );
        // 4 pulses of 8 through one warm session: replies lag the feed
        // by exactly `delay` elements, and close releases the tail.
        let xs: Vec<i64> = (0..8)
            .map(|i| Fx::from_f64(i as f64 * 0.31 - 1.2, spec.io.input).raw())
            .collect();
        let mut got = Vec::new();
        for _ in 0..4 {
            got.extend(client.pulse(id, &xs).unwrap());
        }
        assert_eq!(got.len(), 32 - delay, "delay window must hold back the tail");
        let tail = client.close(id).unwrap();
        assert_eq!(tail.len(), delay, "close must flush exactly the delay window");
        got.extend(tail);
        // The whole released sequence is the bit-exact output of the
        // concatenated feed.
        let flat: Vec<i64> = (0..4).flat_map(|_| xs.clone()).collect();
        let kernel = spec.build().compile(spec.io);
        let mut want = vec![0i64; flat.len()];
        kernel.eval_slice_raw(&flat, &mut want);
        assert_eq!(got, want, "pulse replies must be the exact output prefix");
        // A closed id answers bad_request; an unregistered spec id
        // cannot open; the connection survives both.
        let err = client.pulse(id, &xs).unwrap_err();
        assert!(err.starts_with("bad_request:"), "{err}");
        let err = client.open(99).unwrap_err();
        assert!(err.starts_with("unknown_spec:"), "{err}");
        let (id2, _) = client.open(0).unwrap();
        assert_eq!(client.pulse(id2, &xs).unwrap().len(), 8 - delay.min(8));
        server.stop();
    }

    #[test]
    fn connection_drop_tears_down_owned_sessions() {
        let (server, coord) = start_server();
        let spec = coord.specs()[0].to_string();
        {
            let mut client = NetClient::connect(server.addr()).unwrap();
            client.open_session(&spec).unwrap();
            client.open_session(&spec).unwrap();
            assert_eq!(coord.sessions_open(), 2);
        } // both TcpStreams drop here without close commands
        let deadline = Instant::now() + Duration::from_secs(10);
        while coord.sessions_open() != 0 {
            assert!(
                Instant::now() < deadline,
                "sessions not torn down after connection drop ({} still open)",
                coord.sessions_open()
            );
            std::thread::sleep(Duration::from_millis(5));
        }
        server.stop();
    }
}
