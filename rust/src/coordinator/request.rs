//! Request/response types crossing the coordinator boundary.

use std::sync::mpsc;
use std::time::Instant;

use crate::approx::MethodSpec;

/// A tanh-activation request: a vector of f32 inputs to be evaluated
/// with a given approximation configuration.
#[derive(Debug)]
pub struct Request {
    /// Monotonic id assigned by the coordinator.
    pub id: u64,
    /// Which design point to evaluate with.
    pub spec: MethodSpec,
    /// Input activations.
    pub values: Vec<f32>,
    /// Enqueue timestamp (for latency metrics).
    pub enqueued_at: Instant,
    /// Completion channel.
    pub reply: mpsc::Sender<RequestResult>,
}

/// The outcome delivered on the reply channel.
#[derive(Clone, Debug)]
pub struct RequestResult {
    /// Request id (matches [`Request::id`]).
    pub id: u64,
    /// Outputs, in input order, or the error message.
    pub outcome: Result<Vec<f32>, String>,
    /// Queue + execute latency in microseconds.
    pub latency_us: u64,
}

impl RequestResult {
    /// Unwraps the outputs, panicking on a failed request (tests).
    pub fn expect_values(self) -> Vec<f32> {
        match self.outcome {
            Ok(v) => v,
            Err(e) => panic!("request {} failed: {e}", self.id),
        }
    }
}
