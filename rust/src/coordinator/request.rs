//! Request/response types crossing the coordinator boundary.

use std::fmt;
use std::sync::mpsc;
use std::time::Instant;

use crate::approx::MethodSpec;
use crate::backend::ErrorCode;

/// A tanh-activation request: a vector of f32 inputs to be evaluated
/// with a given approximation configuration.
#[derive(Debug)]
pub struct Request {
    /// Monotonic id assigned by the coordinator.
    pub id: u64,
    /// Which design point to evaluate with.
    pub spec: MethodSpec,
    /// Input activations.
    pub values: Vec<f32>,
    /// Enqueue timestamp (for latency metrics).
    pub enqueued_at: Instant,
    /// Completion channel.
    pub reply: mpsc::Sender<RequestResult>,
}

/// Where in the serving stack a request failed — the axis
/// [`crate::coordinator::ServerMetrics`] counts failures on.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RequestErrorKind {
    /// Rejected before execution: router/batcher admission (unknown
    /// spec, empty/oversized input, backpressure, shutdown race).
    Admission,
    /// The worker's backend failed the batch this request rode in
    /// (execution fault, unavailable substrate).
    Backend,
}

/// A typed request failure: where it happened
/// ([`RequestErrorKind`]) + the stable wire code
/// ([`ErrorCode`], what the net protocol reports) + detail. Replaces
/// the old bare `String`, which made worker-side backend faults
/// indistinguishable from admission rejections in tests and metrics.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct RequestError {
    /// Which layer failed the request.
    pub kind: RequestErrorKind,
    /// Stable wire code (`unknown_spec`, `backend_unavailable`,
    /// `bad_request`, `overloaded`, `internal`).
    pub code: ErrorCode,
    /// Human-readable detail.
    pub message: String,
}

impl RequestError {
    /// An admission-side failure (router or batcher, pre-execution).
    pub fn admission(code: ErrorCode, message: impl Into<String>) -> RequestError {
        RequestError { kind: RequestErrorKind::Admission, code, message: message.into() }
    }

    /// A worker-side backend failure.
    pub fn backend(code: ErrorCode, message: impl Into<String>) -> RequestError {
        RequestError { kind: RequestErrorKind::Backend, code, message: message.into() }
    }
}

impl fmt::Display for RequestError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}: {}", self.code.as_str(), self.message)
    }
}

impl std::error::Error for RequestError {}

/// The outcome delivered on the reply channel.
#[derive(Clone, Debug)]
pub struct RequestResult {
    /// Request id (matches [`Request::id`]).
    pub id: u64,
    /// Outputs, in input order, or the typed failure.
    pub outcome: Result<Vec<f32>, RequestError>,
    /// Queue + execute latency in microseconds.
    pub latency_us: u64,
}

impl RequestResult {
    /// Unwraps the outputs, panicking on a failed request (tests). The
    /// panic names the failing layer and code, so a backend fault mid-
    /// test reads as such instead of an anonymous error string.
    pub fn expect_values(self) -> Vec<f32> {
        match self.outcome {
            Ok(v) => v,
            Err(e) => panic!(
                "request {} failed at {:?} [{}]: {}",
                self.id,
                e.kind,
                e.code.as_str(),
                e.message
            ),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_errors_carry_kind_and_stable_code() {
        let a = RequestError::admission(ErrorCode::Overloaded, "backpressure: queue full");
        assert_eq!(a.kind, RequestErrorKind::Admission);
        assert_eq!(a.code.as_str(), "overloaded");
        assert_eq!(a.to_string(), "overloaded: backpressure: queue full");
        let b = RequestError::backend(ErrorCode::Internal, "injected");
        assert_eq!(b.kind, RequestErrorKind::Backend);
        assert_ne!(a, b);
    }

    #[test]
    #[should_panic(expected = "Backend [internal]")]
    fn expect_values_names_the_failing_layer() {
        let r = RequestResult {
            id: 7,
            outcome: Err(RequestError::backend(ErrorCode::Internal, "boom")),
            latency_us: 1,
        };
        let _ = r.expect_values();
    }
}
