//! The coordinator: router + per-spec worker-shard pools.
//!
//! Serving is keyed by [`MethodSpec`], not by method: the coordinator
//! runs `CoordinatorConfig::shards` batcher/worker pairs for **every
//! spec in `CoordinatorConfig::specs`** (default: the six Table I
//! rows), so one deployment can serve any mix of (method × parameter ×
//! I/O-format) design points. The router steers a request to one shard
//! of its spec — round-robin or least-loaded ([`RoutePolicy`]) — and
//! every shard owns its queue, its [`PendingBatch`], and its own
//! [`ServerMetrics`], so the submit hot path touches no cross-shard
//! state. `metrics()` folds the per-shard snapshots into one exact
//! merged view (plus the global kernel-cache counters);
//! `shard_metrics()` exposes the unmerged per-shard counters for
//! imbalance diagnostics.
//!
//! Shards never compile: backends resolve kernels through the shared
//! [`Registry`](crate::approx::Registry), so a spec is compiled once
//! per process no matter how many shards serve it.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

use crate::approx::{MethodId, MethodSpec, Registry};

use super::batcher::{BatcherConfig, PendingBatch};
use super::metrics::{MetricsSnapshot, ServerMetrics};
use super::request::{Request, RequestResult};

/// Something that can evaluate a fixed-size flat batch for a spec.
/// Implemented by the PJRT [`super::GraphBackend`] and the golden-model
/// fallback ([`super::worker::GoldenBackend`]).
pub trait ExecBackend: Send + Sync + 'static {
    /// Evaluates a full batch (length == `batch_elements`).
    fn execute(&self, spec: &MethodSpec, flat: &[f32]) -> Result<Vec<f32>, String>;
    /// The fixed batch size the backend was compiled for.
    fn batch_elements(&self) -> usize;
}

/// How the router picks a shard within a method's pool.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum RoutePolicy {
    /// Cycle through the shards in order (uniform spread).
    #[default]
    RoundRobin,
    /// Pick the shard with the fewest queued elements.
    LeastLoaded,
}

impl RoutePolicy {
    /// Parses a CLI spelling.
    pub fn parse(s: &str) -> Option<RoutePolicy> {
        match s {
            "rr" | "round-robin" => Some(RoutePolicy::RoundRobin),
            "ll" | "least-loaded" => Some(RoutePolicy::LeastLoaded),
            _ => None,
        }
    }
}

/// Coordinator tuning knobs.
#[derive(Clone, Debug)]
pub struct CoordinatorConfig {
    /// Batching policy (batch size is overridden by the backend's).
    pub batcher: BatcherConfig,
    /// Worker shards per spec (clamped to ≥ 1).
    pub shards: usize,
    /// Shard selection policy.
    pub route: RoutePolicy,
    /// The design points this coordinator serves, in routing order.
    /// Duplicates are dropped; an empty list falls back to the six
    /// Table I specs.
    pub specs: Vec<MethodSpec>,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig {
            batcher: BatcherConfig::default(),
            shards: 2,
            route: RoutePolicy::RoundRobin,
            specs: MethodSpec::table1_all(),
        }
    }
}

/// One batcher/worker pair: its queue sender, queued-element gauge and
/// private metrics.
struct Shard {
    tx: mpsc::Sender<Request>,
    depth: Arc<AtomicUsize>,
    metrics: Arc<ServerMetrics>,
}

/// A spec's shard pool plus its round-robin cursor.
struct SpecShards {
    shards: Vec<Shard>,
    rr: AtomicUsize,
}

/// The activation-accelerator service.
pub struct Coordinator {
    /// Served specs, in config order (deduplicated).
    specs: Vec<MethodSpec>,
    pools: HashMap<MethodSpec, SpecShards>,
    next_id: AtomicU64,
    cfg: BatcherConfig,
    route: RoutePolicy,
    workers: Mutex<Vec<JoinHandle<()>>>,
}

impl Coordinator {
    /// Starts `cfg.shards` batcher/worker threads per served spec over
    /// the backend.
    pub fn start(backend: Arc<dyn ExecBackend>, cfg: CoordinatorConfig) -> Coordinator {
        let mut batcher_cfg = cfg.batcher;
        batcher_cfg.batch_elements = backend.batch_elements();
        let shards = cfg.shards.max(1);
        let mut specs: Vec<MethodSpec> = Vec::with_capacity(cfg.specs.len());
        for s in &cfg.specs {
            if !specs.contains(s) {
                specs.push(*s);
            }
        }
        if specs.is_empty() {
            specs = MethodSpec::table1_all();
        }
        let mut pools = HashMap::new();
        let mut workers = Vec::new();
        for &spec in &specs {
            let mut pool = Vec::with_capacity(shards);
            for shard_idx in 0..shards {
                let (tx, rx) = mpsc::channel::<Request>();
                let depth = Arc::new(AtomicUsize::new(0));
                let metrics = Arc::new(ServerMetrics::default());
                let handle = spawn_worker(
                    spec,
                    shard_idx,
                    rx,
                    depth.clone(),
                    backend.clone(),
                    batcher_cfg,
                    metrics.clone(),
                );
                pool.push(Shard { tx, depth, metrics });
                workers.push(handle);
            }
            pools.insert(spec, SpecShards { shards: pool, rr: AtomicUsize::new(0) });
        }
        Coordinator {
            specs,
            pools,
            next_id: AtomicU64::new(0),
            cfg: batcher_cfg,
            route: cfg.route,
            workers: Mutex::new(workers),
        }
    }

    /// Submits a request for an explicit design point; the reply
    /// arrives on the returned channel. Fails fast under backpressure,
    /// oversized input, or a spec this coordinator does not serve.
    pub fn submit_spec(
        &self,
        spec: &MethodSpec,
        values: Vec<f32>,
    ) -> Result<mpsc::Receiver<RequestResult>, String> {
        if values.is_empty() {
            return Err("empty request".into());
        }
        if values.len() > self.cfg.batch_elements {
            return Err(format!(
                "request of {} elements exceeds the compiled batch {}",
                values.len(),
                self.cfg.batch_elements
            ));
        }
        let pool = self.pools.get(spec).ok_or_else(|| {
            let served: Vec<String> = self.specs.iter().map(|s| s.to_string()).collect();
            format!("spec '{spec}' is not served (serving: {})", served.join(", "))
        })?;
        let shard = match self.route {
            RoutePolicy::RoundRobin => {
                let i = pool.rr.fetch_add(1, Ordering::Relaxed) % pool.shards.len();
                &pool.shards[i]
            }
            RoutePolicy::LeastLoaded => pool
                .shards
                .iter()
                .min_by_key(|s| s.depth.load(Ordering::Relaxed))
                .expect("spec pool is never empty"),
        };
        let depth = shard.depth.load(Ordering::Relaxed);
        if depth + values.len() > self.cfg.max_queue {
            shard.metrics.record_rejected();
            return Err(format!("backpressure: shard queue at {depth} elements"));
        }
        let (reply_tx, reply_rx) = mpsc::channel();
        let len = values.len();
        let req = Request {
            id: self.next_id.fetch_add(1, Ordering::Relaxed),
            spec: *spec,
            values,
            enqueued_at: Instant::now(),
            reply: reply_tx,
        };
        shard.depth.fetch_add(len, Ordering::Relaxed);
        match shard.tx.send(req) {
            Ok(()) => {
                shard.metrics.record_submitted();
                Ok(reply_rx)
            }
            Err(_) => {
                shard.depth.fetch_sub(len, Ordering::Relaxed);
                Err("worker shut down".to_string())
            }
        }
    }

    /// Method-addressed submit: routes to the first served spec of
    /// `method` (for a default coordinator, its Table I row). The
    /// spec-addressed [`Coordinator::submit_spec`] is the general form.
    pub fn submit(
        &self,
        method: MethodId,
        values: Vec<f32>,
    ) -> Result<mpsc::Receiver<RequestResult>, String> {
        let spec = *self
            .specs
            .iter()
            .find(|s| s.method_id() == method)
            .ok_or_else(|| format!("no served spec for method {}", method.name()))?;
        self.submit_spec(&spec, values)
    }

    /// Blocking convenience: submit by method and wait.
    pub fn evaluate(&self, method: MethodId, values: Vec<f32>) -> Result<Vec<f32>, String> {
        let rx = self.submit(method, values)?;
        let result = rx.recv().map_err(|_| "worker dropped reply".to_string())?;
        result.outcome
    }

    /// Blocking convenience: submit by spec and wait.
    pub fn evaluate_spec(&self, spec: &MethodSpec, values: Vec<f32>) -> Result<Vec<f32>, String> {
        let rx = self.submit_spec(spec, values)?;
        let result = rx.recv().map_err(|_| "worker dropped reply".to_string())?;
        result.outcome
    }

    /// Merged metrics across every shard of every spec (exact fold of
    /// the per-shard snapshots, histogram included), plus the global
    /// kernel-cache counters ([`Registry::global`]) — the observable
    /// for the shared-cache win (compiles == distinct specs, not
    /// shards × specs).
    pub fn metrics(&self) -> MetricsSnapshot {
        let mut merged = MetricsSnapshot::default();
        for pool in self.pools.values() {
            for shard in &pool.shards {
                merged = merged.merge(&shard.metrics.snapshot());
            }
        }
        let cache = Registry::global().stats();
        merged.kernel_cache_hits = cache.hits;
        merged.kernel_compiles = cache.compiles;
        merged
    }

    /// Per-shard snapshots as `(spec, shard index, snapshot)`, in
    /// served-spec order.
    pub fn shard_metrics(&self) -> Vec<(MethodSpec, usize, MetricsSnapshot)> {
        let mut out = Vec::new();
        for spec in &self.specs {
            if let Some(pool) = self.pools.get(spec) {
                for (i, shard) in pool.shards.iter().enumerate() {
                    out.push((*spec, i, shard.metrics.snapshot()));
                }
            }
        }
        out
    }

    /// The design points this coordinator serves, in routing order.
    pub fn specs(&self) -> &[MethodSpec] {
        &self.specs
    }

    /// The number of worker shards each spec runs.
    pub fn shards_per_method(&self) -> usize {
        self.pools.values().next().map_or(0, |pool| pool.shards.len())
    }

    /// Shuts down the workers. Dropping the senders lets every shard
    /// drain its queued requests and flush its partial batch before the
    /// thread exits, so all in-flight replies are still delivered.
    pub fn shutdown(self) {
        drop(self.pools);
        let mut workers = self.workers.lock().unwrap();
        for h in workers.drain(..) {
            let _ = h.join();
        }
    }
}

fn spawn_worker(
    spec: MethodSpec,
    shard_idx: usize,
    rx: mpsc::Receiver<Request>,
    depth: Arc<AtomicUsize>,
    backend: Arc<dyn ExecBackend>,
    cfg: BatcherConfig,
    metrics: Arc<ServerMetrics>,
) -> JoinHandle<()> {
    std::thread::Builder::new()
        .name(format!("tanh-worker-{}-{shard_idx}", spec.method_id().label()))
        .spawn(move || {
            let mut pending = PendingBatch::default();
            loop {
                // Wait for work: block when idle, poll with the flush
                // deadline when a partial batch is open.
                let timeout = if pending.is_empty() { cfg.max_wait * 50 } else { cfg.max_wait };
                match rx.recv_timeout(timeout) {
                    Ok(req) => {
                        admit(req, &mut pending, &spec, &backend, &cfg, &metrics, &depth);
                        // Greedy drain: requests that queued up while
                        // the previous batch executed are packed NOW
                        // rather than one-per-loop — without this,
                        // their queue age exceeds max_wait and every
                        // request flushes as its own batch (perf log
                        // iteration 1: batch efficiency 6% → see
                        // EXPERIMENTS.md §Perf).
                        while let Ok(req) = rx.try_recv() {
                            admit(req, &mut pending, &spec, &backend, &cfg, &metrics, &depth);
                        }
                    }
                    Err(mpsc::RecvTimeoutError::Timeout) => {}
                    Err(mpsc::RecvTimeoutError::Disconnected) => {
                        flush(&mut pending, &spec, &backend, &cfg, &metrics, &depth);
                        return;
                    }
                }
                if pending.should_flush(&cfg, Instant::now()) {
                    flush(&mut pending, &spec, &backend, &cfg, &metrics, &depth);
                }
            }
        })
        .expect("spawning worker thread")
}

/// Adds a request to the shard's pending batch, flushing first when it
/// would not fit.
fn admit(
    req: Request,
    pending: &mut PendingBatch,
    spec: &MethodSpec,
    backend: &Arc<dyn ExecBackend>,
    cfg: &BatcherConfig,
    metrics: &Arc<ServerMetrics>,
    depth: &Arc<AtomicUsize>,
) {
    // Defense in depth: `submit` already rejects oversized requests, but
    // a request larger than the batch can never satisfy `fits`, so if
    // one ever reached the queue it would starve forever behind an
    // always-flushing loop. Fail it deterministically instead.
    if req.values.len() > cfg.batch_elements {
        depth.fetch_sub(req.values.len(), Ordering::Relaxed);
        let latency_us = req.enqueued_at.elapsed().as_micros() as u64;
        metrics.record_failed_request(latency_us);
        let _ = req.reply.send(RequestResult {
            id: req.id,
            outcome: Err(format!(
                "request of {} elements exceeds the compiled batch {}",
                req.values.len(),
                cfg.batch_elements
            )),
            latency_us,
        });
        return;
    }
    if !pending.fits(&req, cfg.batch_elements) {
        flush(pending, spec, backend, cfg, metrics, depth);
    }
    pending.push(req);
}

fn flush(
    pending: &mut PendingBatch,
    spec: &MethodSpec,
    backend: &Arc<dyn ExecBackend>,
    cfg: &BatcherConfig,
    metrics: &Arc<ServerMetrics>,
    depth: &Arc<AtomicUsize>,
) {
    if pending.is_empty() {
        return;
    }
    let batch = pending.take();
    let (flat, spans) = batch.pack(cfg.batch_elements);
    metrics.record_batch(batch.elements, cfg.batch_elements);
    depth.fetch_sub(batch.elements, Ordering::Relaxed);
    let result = backend.execute(spec, &flat);
    let now = Instant::now();
    match result {
        Ok(outputs) => {
            for (req, (off, len)) in batch.requests.into_iter().zip(spans) {
                let latency_us = now.duration_since(req.enqueued_at).as_micros() as u64;
                metrics.record_request(len, latency_us);
                let _ = req.reply.send(RequestResult {
                    id: req.id,
                    outcome: Ok(outputs[off..off + len].to_vec()),
                    latency_us,
                });
            }
        }
        Err(e) => {
            metrics.record_error();
            for req in batch.requests {
                let latency_us = now.duration_since(req.enqueued_at).as_micros() as u64;
                metrics.record_failed_request(latency_us);
                let _ = req.reply.send(RequestResult {
                    id: req.id,
                    outcome: Err(e.clone()),
                    latency_us,
                });
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::worker::GoldenBackend;

    fn start_golden(batch: usize) -> Coordinator {
        Coordinator::start(Arc::new(GoldenBackend::table1(batch)), CoordinatorConfig::default())
    }

    #[test]
    fn evaluate_roundtrip_all_methods() {
        let c = start_golden(64);
        assert_eq!(c.shards_per_method(), 2);
        for method in MethodId::all() {
            let out = c.evaluate(method, vec![0.5, -0.5, 3.0]).unwrap();
            assert_eq!(out.len(), 3);
            assert!((out[0] - 0.462).abs() < 1e-3, "{method:?}");
            assert_eq!(out[0], -out[1]);
        }
        let m = c.metrics();
        assert_eq!(m.requests, 6);
        assert_eq!(m.submitted, 6);
        assert_eq!(m.failed_requests, 0);
        assert!(m.batches >= 1);
        c.shutdown();
    }

    #[test]
    fn concurrent_submissions_all_complete() {
        let c = Arc::new(start_golden(256));
        let mut handles = Vec::new();
        for i in 0..8 {
            let c = c.clone();
            handles.push(std::thread::spawn(move || {
                let method = MethodId::all()[i % 6];
                let values: Vec<f32> = (0..50).map(|j| (j as f32) * 0.1 - 2.5).collect();
                let out = c.evaluate(method, values.clone()).unwrap();
                for (x, y) in values.iter().zip(&out) {
                    assert!((x.tanh() - y).abs() < 2e-4, "{method:?} x={x} y={y}");
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let m = c.metrics();
        assert_eq!(m.requests, 8);
        assert_eq!(m.errors, 0);
    }

    #[test]
    fn oversized_request_rejected() {
        let c = start_golden(16);
        let err = c.submit(MethodId::Pwl, vec![0.0; 17]).unwrap_err();
        assert!(err.contains("exceeds"));
        // Deterministic: the same oversized submit yields the same error.
        let err2 = c.submit(MethodId::Pwl, vec![0.0; 17]).unwrap_err();
        assert_eq!(err, err2);
        c.shutdown();
    }

    #[test]
    fn empty_request_rejected() {
        let c = start_golden(16);
        assert!(c.submit(MethodId::Pwl, vec![]).is_err());
        c.shutdown();
    }

    #[test]
    fn batching_packs_multiple_requests() {
        let c = start_golden(1024);
        // Submit many tiny requests quickly: they should share batches.
        let rxs: Vec<_> =
            (0..64).map(|_| c.submit(MethodId::Pwl, vec![0.1, 0.2]).unwrap()).collect();
        for rx in rxs {
            rx.recv().unwrap().expect_values();
        }
        let m = c.metrics();
        assert_eq!(m.requests, 64);
        assert!(m.batches < 64, "batching collapsed {} batches", m.batches);
        c.shutdown();
    }

    #[test]
    fn round_robin_spreads_across_shards() {
        let c = Coordinator::start(
            Arc::new(GoldenBackend::table1(128)),
            CoordinatorConfig { shards: 3, ..Default::default() },
        );
        let rxs: Vec<_> =
            (0..9).map(|_| c.submit(MethodId::Lambert, vec![0.5; 4]).unwrap()).collect();
        for rx in rxs {
            rx.recv().unwrap().expect_values();
        }
        let lambert_shards: Vec<_> = c
            .shard_metrics()
            .into_iter()
            .filter(|(s, _, _)| s.method_id() == MethodId::Lambert)
            .collect();
        assert_eq!(lambert_shards.len(), 3);
        for (_, idx, s) in &lambert_shards {
            assert_eq!(s.submitted, 3, "shard {idx} got {} of 9 round-robin submits", s.submitted);
        }
        c.shutdown();
    }

    #[test]
    fn merged_metrics_equal_fold_of_shard_metrics() {
        let c = start_golden(64);
        for i in 0..30 {
            let _ = c.evaluate(MethodId::all()[i % 6], vec![0.25; 3]).unwrap();
        }
        let merged = c.metrics();
        let mut fold = c
            .shard_metrics()
            .into_iter()
            .fold(MetricsSnapshot::default(), |acc, (_, _, s)| acc.merge(&s));
        // The kernel-cache counters are process-global (set by
        // `metrics()`, not folded from shards); align them before the
        // exactness check on everything else.
        fold.kernel_cache_hits = merged.kernel_cache_hits;
        fold.kernel_compiles = merged.kernel_compiles;
        assert_eq!(merged, fold);
        assert_eq!(merged.submitted, 30);
        assert_eq!(merged.requests + merged.failed_requests, merged.submitted);
        c.shutdown();
    }

    #[test]
    fn least_loaded_routes_to_empty_shard() {
        // With least-loaded routing and sequential evaluate calls the
        // queue is empty at each submit, so every shard stays usable and
        // all requests complete.
        let c = Coordinator::start(
            Arc::new(GoldenBackend::table1(64)),
            CoordinatorConfig { route: RoutePolicy::LeastLoaded, shards: 2, ..Default::default() },
        );
        for _ in 0..10 {
            let out = c.evaluate(MethodId::Pwl, vec![1.0, -1.0]).unwrap();
            assert_eq!(out.len(), 2);
        }
        assert_eq!(c.metrics().requests, 10);
        c.shutdown();
    }

    #[test]
    fn spec_routing_serves_non_table1_points_and_rejects_unserved() {
        use crate::coordinator::worker::GoldenBackend;
        let table1_pwl = MethodSpec::table1(MethodId::Pwl);
        let custom = MethodSpec::parse("pwl:step=1/32:in=s2.13:out=s.15").unwrap();
        let specs = vec![table1_pwl, custom];
        let c = Coordinator::start(
            Arc::new(GoldenBackend::for_specs(&specs, 32)),
            CoordinatorConfig { specs: specs.clone(), ..Default::default() },
        );
        assert_eq!(c.specs(), &specs[..]);
        // Both design points answer, through their own kernels.
        let a = c.evaluate_spec(&table1_pwl, vec![0.5]).unwrap();
        let b = c.evaluate_spec(&custom, vec![0.5]).unwrap();
        assert!((a[0] - 0.462f32).abs() < 1e-3);
        assert!((b[0] - 0.462f32).abs() < 2e-3);
        // Method-addressed submit resolves to the FIRST served pwl spec.
        let via_method = c.evaluate(MethodId::Pwl, vec![0.5]).unwrap();
        assert_eq!(via_method[0].to_bits(), a[0].to_bits());
        // A spec outside the served set fails fast with a useful error.
        let unserved = MethodSpec::table1(MethodId::Lambert);
        let err = c.submit_spec(&unserved, vec![0.5]).unwrap_err();
        assert!(err.contains("not served"), "{err}");
        let err = c.submit(MethodId::Lambert, vec![0.5]).unwrap_err();
        assert!(err.contains("no served spec"), "{err}");
        // Duplicate specs in the config collapse into one pool.
        assert_eq!(c.shard_metrics().len(), 2 * c.shards_per_method());
        c.shutdown();
    }

    #[test]
    fn duplicate_and_empty_spec_lists_are_handled() {
        use crate::coordinator::worker::GoldenBackend;
        let s = MethodSpec::table1(MethodId::Pwl);
        let c = Coordinator::start(
            Arc::new(GoldenBackend::for_specs(&[s], 16)),
            CoordinatorConfig { specs: vec![s, s, s], shards: 1, ..Default::default() },
        );
        assert_eq!(c.specs().len(), 1);
        c.shutdown();
        // Empty spec list falls back to the Table I suite.
        let c = Coordinator::start(
            Arc::new(GoldenBackend::table1(16)),
            CoordinatorConfig { specs: vec![], shards: 1, ..Default::default() },
        );
        assert_eq!(c.specs().len(), 6);
        c.shutdown();
    }

    #[test]
    fn route_policy_parses() {
        assert_eq!(RoutePolicy::parse("rr"), Some(RoutePolicy::RoundRobin));
        assert_eq!(RoutePolicy::parse("round-robin"), Some(RoutePolicy::RoundRobin));
        assert_eq!(RoutePolicy::parse("ll"), Some(RoutePolicy::LeastLoaded));
        assert_eq!(RoutePolicy::parse("least-loaded"), Some(RoutePolicy::LeastLoaded));
        assert_eq!(RoutePolicy::parse("nope"), None);
    }
}
