//! The coordinator: router + per-method worker-shard pools.
//!
//! Each method runs a configurable pool of batcher/worker shards
//! (`CoordinatorConfig::shards`). The router steers a request to one
//! shard of its method — round-robin or least-loaded
//! ([`RoutePolicy`]) — and every shard owns its queue, its
//! [`PendingBatch`], and its own [`ServerMetrics`], so the submit hot
//! path touches no cross-shard state. `metrics()` folds the per-shard
//! snapshots into one exact merged view; `shard_metrics()` exposes the
//! unmerged per-shard counters for imbalance diagnostics.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

use crate::approx::MethodId;

use super::batcher::{BatcherConfig, PendingBatch};
use super::metrics::{MetricsSnapshot, ServerMetrics};
use super::request::{Request, RequestResult};

/// Something that can evaluate a fixed-size flat batch for a method.
/// Implemented by the PJRT [`super::GraphBackend`] and the golden-model
/// fallback ([`super::worker::GoldenBackend`]).
pub trait ExecBackend: Send + Sync + 'static {
    /// Evaluates a full batch (length == `batch_elements`).
    fn execute(&self, method: MethodId, flat: &[f32]) -> Result<Vec<f32>, String>;
    /// The fixed batch size the backend was compiled for.
    fn batch_elements(&self) -> usize;
}

/// How the router picks a shard within a method's pool.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum RoutePolicy {
    /// Cycle through the shards in order (uniform spread).
    #[default]
    RoundRobin,
    /// Pick the shard with the fewest queued elements.
    LeastLoaded,
}

impl RoutePolicy {
    /// Parses a CLI spelling.
    pub fn parse(s: &str) -> Option<RoutePolicy> {
        match s {
            "rr" | "round-robin" => Some(RoutePolicy::RoundRobin),
            "ll" | "least-loaded" => Some(RoutePolicy::LeastLoaded),
            _ => None,
        }
    }
}

/// Coordinator tuning knobs.
#[derive(Clone, Debug)]
pub struct CoordinatorConfig {
    /// Batching policy (batch size is overridden by the backend's).
    pub batcher: BatcherConfig,
    /// Worker shards per method (clamped to ≥ 1).
    pub shards: usize,
    /// Shard selection policy.
    pub route: RoutePolicy,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig {
            batcher: BatcherConfig::default(),
            shards: 2,
            route: RoutePolicy::RoundRobin,
        }
    }
}

/// One batcher/worker pair: its queue sender, queued-element gauge and
/// private metrics.
struct Shard {
    tx: mpsc::Sender<Request>,
    depth: Arc<AtomicUsize>,
    metrics: Arc<ServerMetrics>,
}

/// A method's shard pool plus its round-robin cursor.
struct MethodShards {
    shards: Vec<Shard>,
    rr: AtomicUsize,
}

/// The activation-accelerator service.
pub struct Coordinator {
    methods: HashMap<MethodId, MethodShards>,
    next_id: AtomicU64,
    cfg: BatcherConfig,
    route: RoutePolicy,
    workers: Mutex<Vec<JoinHandle<()>>>,
}

impl Coordinator {
    /// Starts `cfg.shards` batcher/worker threads per method over the
    /// backend.
    pub fn start(backend: Arc<dyn ExecBackend>, cfg: CoordinatorConfig) -> Coordinator {
        let mut batcher_cfg = cfg.batcher;
        batcher_cfg.batch_elements = backend.batch_elements();
        let shards = cfg.shards.max(1);
        let mut methods = HashMap::new();
        let mut workers = Vec::new();
        for method in MethodId::all() {
            let mut pool = Vec::with_capacity(shards);
            for shard_idx in 0..shards {
                let (tx, rx) = mpsc::channel::<Request>();
                let depth = Arc::new(AtomicUsize::new(0));
                let metrics = Arc::new(ServerMetrics::default());
                let handle = spawn_worker(
                    method,
                    shard_idx,
                    rx,
                    depth.clone(),
                    backend.clone(),
                    batcher_cfg,
                    metrics.clone(),
                );
                pool.push(Shard { tx, depth, metrics });
                workers.push(handle);
            }
            methods.insert(method, MethodShards { shards: pool, rr: AtomicUsize::new(0) });
        }
        Coordinator {
            methods,
            next_id: AtomicU64::new(0),
            cfg: batcher_cfg,
            route: cfg.route,
            workers: Mutex::new(workers),
        }
    }

    /// Submits a request; the reply arrives on the returned channel.
    /// Fails fast under backpressure or oversized input.
    pub fn submit(
        &self,
        method: MethodId,
        values: Vec<f32>,
    ) -> Result<mpsc::Receiver<RequestResult>, String> {
        if values.is_empty() {
            return Err("empty request".into());
        }
        if values.len() > self.cfg.batch_elements {
            return Err(format!(
                "request of {} elements exceeds the compiled batch {}",
                values.len(),
                self.cfg.batch_elements
            ));
        }
        let pool = self.methods.get(&method).ok_or("unknown method")?;
        let shard = match self.route {
            RoutePolicy::RoundRobin => {
                let i = pool.rr.fetch_add(1, Ordering::Relaxed) % pool.shards.len();
                &pool.shards[i]
            }
            RoutePolicy::LeastLoaded => pool
                .shards
                .iter()
                .min_by_key(|s| s.depth.load(Ordering::Relaxed))
                .expect("method pool is never empty"),
        };
        let depth = shard.depth.load(Ordering::Relaxed);
        if depth + values.len() > self.cfg.max_queue {
            shard.metrics.record_rejected();
            return Err(format!("backpressure: shard queue at {depth} elements"));
        }
        let (reply_tx, reply_rx) = mpsc::channel();
        let len = values.len();
        let req = Request {
            id: self.next_id.fetch_add(1, Ordering::Relaxed),
            method,
            values,
            enqueued_at: Instant::now(),
            reply: reply_tx,
        };
        shard.depth.fetch_add(len, Ordering::Relaxed);
        match shard.tx.send(req) {
            Ok(()) => {
                shard.metrics.record_submitted();
                Ok(reply_rx)
            }
            Err(_) => {
                shard.depth.fetch_sub(len, Ordering::Relaxed);
                Err("worker shut down".to_string())
            }
        }
    }

    /// Blocking convenience: submit and wait.
    pub fn evaluate(&self, method: MethodId, values: Vec<f32>) -> Result<Vec<f32>, String> {
        let rx = self.submit(method, values)?;
        let result = rx.recv().map_err(|_| "worker dropped reply".to_string())?;
        result.outcome
    }

    /// Merged metrics across every shard of every method (exact fold of
    /// the per-shard snapshots, histogram included).
    pub fn metrics(&self) -> MetricsSnapshot {
        let mut merged = MetricsSnapshot::default();
        for pool in self.methods.values() {
            for shard in &pool.shards {
                merged = merged.merge(&shard.metrics.snapshot());
            }
        }
        merged
    }

    /// Per-shard snapshots as `(method, shard index, snapshot)`, in
    /// `MethodId::all()` order.
    pub fn shard_metrics(&self) -> Vec<(MethodId, usize, MetricsSnapshot)> {
        let mut out = Vec::new();
        for method in MethodId::all() {
            if let Some(pool) = self.methods.get(&method) {
                for (i, shard) in pool.shards.iter().enumerate() {
                    out.push((method, i, shard.metrics.snapshot()));
                }
            }
        }
        out
    }

    /// The number of worker shards each method runs.
    pub fn shards_per_method(&self) -> usize {
        self.methods.values().next().map_or(0, |pool| pool.shards.len())
    }

    /// Shuts down the workers. Dropping the senders lets every shard
    /// drain its queued requests and flush its partial batch before the
    /// thread exits, so all in-flight replies are still delivered.
    pub fn shutdown(self) {
        drop(self.methods);
        let mut workers = self.workers.lock().unwrap();
        for h in workers.drain(..) {
            let _ = h.join();
        }
    }
}

fn spawn_worker(
    method: MethodId,
    shard_idx: usize,
    rx: mpsc::Receiver<Request>,
    depth: Arc<AtomicUsize>,
    backend: Arc<dyn ExecBackend>,
    cfg: BatcherConfig,
    metrics: Arc<ServerMetrics>,
) -> JoinHandle<()> {
    std::thread::Builder::new()
        .name(format!("tanh-worker-{}-{shard_idx}", method.label()))
        .spawn(move || {
            let mut pending = PendingBatch::default();
            loop {
                // Wait for work: block when idle, poll with the flush
                // deadline when a partial batch is open.
                let timeout = if pending.is_empty() { cfg.max_wait * 50 } else { cfg.max_wait };
                match rx.recv_timeout(timeout) {
                    Ok(req) => {
                        admit(req, &mut pending, method, &backend, &cfg, &metrics, &depth);
                        // Greedy drain: requests that queued up while
                        // the previous batch executed are packed NOW
                        // rather than one-per-loop — without this,
                        // their queue age exceeds max_wait and every
                        // request flushes as its own batch (perf log
                        // iteration 1: batch efficiency 6% → see
                        // EXPERIMENTS.md §Perf).
                        while let Ok(req) = rx.try_recv() {
                            admit(req, &mut pending, method, &backend, &cfg, &metrics, &depth);
                        }
                    }
                    Err(mpsc::RecvTimeoutError::Timeout) => {}
                    Err(mpsc::RecvTimeoutError::Disconnected) => {
                        flush(&mut pending, method, &backend, &cfg, &metrics, &depth);
                        return;
                    }
                }
                if pending.should_flush(&cfg, Instant::now()) {
                    flush(&mut pending, method, &backend, &cfg, &metrics, &depth);
                }
            }
        })
        .expect("spawning worker thread")
}

/// Adds a request to the shard's pending batch, flushing first when it
/// would not fit.
fn admit(
    req: Request,
    pending: &mut PendingBatch,
    method: MethodId,
    backend: &Arc<dyn ExecBackend>,
    cfg: &BatcherConfig,
    metrics: &Arc<ServerMetrics>,
    depth: &Arc<AtomicUsize>,
) {
    // Defense in depth: `submit` already rejects oversized requests, but
    // a request larger than the batch can never satisfy `fits`, so if
    // one ever reached the queue it would starve forever behind an
    // always-flushing loop. Fail it deterministically instead.
    if req.values.len() > cfg.batch_elements {
        depth.fetch_sub(req.values.len(), Ordering::Relaxed);
        let latency_us = req.enqueued_at.elapsed().as_micros() as u64;
        metrics.record_failed_request(latency_us);
        let _ = req.reply.send(RequestResult {
            id: req.id,
            outcome: Err(format!(
                "request of {} elements exceeds the compiled batch {}",
                req.values.len(),
                cfg.batch_elements
            )),
            latency_us,
        });
        return;
    }
    if !pending.fits(&req, cfg.batch_elements) {
        flush(pending, method, backend, cfg, metrics, depth);
    }
    pending.push(req);
}

fn flush(
    pending: &mut PendingBatch,
    method: MethodId,
    backend: &Arc<dyn ExecBackend>,
    cfg: &BatcherConfig,
    metrics: &Arc<ServerMetrics>,
    depth: &Arc<AtomicUsize>,
) {
    if pending.is_empty() {
        return;
    }
    let batch = pending.take();
    let (flat, spans) = batch.pack(cfg.batch_elements);
    metrics.record_batch(batch.elements, cfg.batch_elements);
    depth.fetch_sub(batch.elements, Ordering::Relaxed);
    let result = backend.execute(method, &flat);
    let now = Instant::now();
    match result {
        Ok(outputs) => {
            for (req, (off, len)) in batch.requests.into_iter().zip(spans) {
                let latency_us = now.duration_since(req.enqueued_at).as_micros() as u64;
                metrics.record_request(len, latency_us);
                let _ = req.reply.send(RequestResult {
                    id: req.id,
                    outcome: Ok(outputs[off..off + len].to_vec()),
                    latency_us,
                });
            }
        }
        Err(e) => {
            metrics.record_error();
            for req in batch.requests {
                let latency_us = now.duration_since(req.enqueued_at).as_micros() as u64;
                metrics.record_failed_request(latency_us);
                let _ = req.reply.send(RequestResult {
                    id: req.id,
                    outcome: Err(e.clone()),
                    latency_us,
                });
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::worker::GoldenBackend;

    fn start_golden(batch: usize) -> Coordinator {
        Coordinator::start(Arc::new(GoldenBackend::table1(batch)), CoordinatorConfig::default())
    }

    #[test]
    fn evaluate_roundtrip_all_methods() {
        let c = start_golden(64);
        assert_eq!(c.shards_per_method(), 2);
        for method in MethodId::all() {
            let out = c.evaluate(method, vec![0.5, -0.5, 3.0]).unwrap();
            assert_eq!(out.len(), 3);
            assert!((out[0] - 0.462).abs() < 1e-3, "{method:?}");
            assert_eq!(out[0], -out[1]);
        }
        let m = c.metrics();
        assert_eq!(m.requests, 6);
        assert_eq!(m.submitted, 6);
        assert_eq!(m.failed_requests, 0);
        assert!(m.batches >= 1);
        c.shutdown();
    }

    #[test]
    fn concurrent_submissions_all_complete() {
        let c = Arc::new(start_golden(256));
        let mut handles = Vec::new();
        for i in 0..8 {
            let c = c.clone();
            handles.push(std::thread::spawn(move || {
                let method = MethodId::all()[i % 6];
                let values: Vec<f32> = (0..50).map(|j| (j as f32) * 0.1 - 2.5).collect();
                let out = c.evaluate(method, values.clone()).unwrap();
                for (x, y) in values.iter().zip(&out) {
                    assert!((x.tanh() - y).abs() < 2e-4, "{method:?} x={x} y={y}");
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let m = c.metrics();
        assert_eq!(m.requests, 8);
        assert_eq!(m.errors, 0);
    }

    #[test]
    fn oversized_request_rejected() {
        let c = start_golden(16);
        let err = c.submit(MethodId::Pwl, vec![0.0; 17]).unwrap_err();
        assert!(err.contains("exceeds"));
        // Deterministic: the same oversized submit yields the same error.
        let err2 = c.submit(MethodId::Pwl, vec![0.0; 17]).unwrap_err();
        assert_eq!(err, err2);
        c.shutdown();
    }

    #[test]
    fn empty_request_rejected() {
        let c = start_golden(16);
        assert!(c.submit(MethodId::Pwl, vec![]).is_err());
        c.shutdown();
    }

    #[test]
    fn batching_packs_multiple_requests() {
        let c = start_golden(1024);
        // Submit many tiny requests quickly: they should share batches.
        let rxs: Vec<_> =
            (0..64).map(|_| c.submit(MethodId::Pwl, vec![0.1, 0.2]).unwrap()).collect();
        for rx in rxs {
            rx.recv().unwrap().expect_values();
        }
        let m = c.metrics();
        assert_eq!(m.requests, 64);
        assert!(m.batches < 64, "batching collapsed {} batches", m.batches);
        c.shutdown();
    }

    #[test]
    fn round_robin_spreads_across_shards() {
        let c = Coordinator::start(
            Arc::new(GoldenBackend::table1(128)),
            CoordinatorConfig { shards: 3, ..Default::default() },
        );
        let rxs: Vec<_> =
            (0..9).map(|_| c.submit(MethodId::Lambert, vec![0.5; 4]).unwrap()).collect();
        for rx in rxs {
            rx.recv().unwrap().expect_values();
        }
        let lambert_shards: Vec<_> = c
            .shard_metrics()
            .into_iter()
            .filter(|(m, _, _)| *m == MethodId::Lambert)
            .collect();
        assert_eq!(lambert_shards.len(), 3);
        for (_, idx, s) in &lambert_shards {
            assert_eq!(s.submitted, 3, "shard {idx} got {} of 9 round-robin submits", s.submitted);
        }
        c.shutdown();
    }

    #[test]
    fn merged_metrics_equal_fold_of_shard_metrics() {
        let c = start_golden(64);
        for i in 0..30 {
            let _ = c.evaluate(MethodId::all()[i % 6], vec![0.25; 3]).unwrap();
        }
        let merged = c.metrics();
        let fold = c
            .shard_metrics()
            .into_iter()
            .fold(MetricsSnapshot::default(), |acc, (_, _, s)| acc.merge(&s));
        assert_eq!(merged, fold);
        assert_eq!(merged.submitted, 30);
        assert_eq!(merged.requests + merged.failed_requests, merged.submitted);
        c.shutdown();
    }

    #[test]
    fn least_loaded_routes_to_empty_shard() {
        // With least-loaded routing and sequential evaluate calls the
        // queue is empty at each submit, so every shard stays usable and
        // all requests complete.
        let c = Coordinator::start(
            Arc::new(GoldenBackend::table1(64)),
            CoordinatorConfig { route: RoutePolicy::LeastLoaded, shards: 2, ..Default::default() },
        );
        for _ in 0..10 {
            let out = c.evaluate(MethodId::Pwl, vec![1.0, -1.0]).unwrap();
            assert_eq!(out.len(), 2);
        }
        assert_eq!(c.metrics().requests, 10);
        c.shutdown();
    }

    #[test]
    fn route_policy_parses() {
        assert_eq!(RoutePolicy::parse("rr"), Some(RoutePolicy::RoundRobin));
        assert_eq!(RoutePolicy::parse("round-robin"), Some(RoutePolicy::RoundRobin));
        assert_eq!(RoutePolicy::parse("ll"), Some(RoutePolicy::LeastLoaded));
        assert_eq!(RoutePolicy::parse("least-loaded"), Some(RoutePolicy::LeastLoaded));
        assert_eq!(RoutePolicy::parse("nope"), None);
    }
}
