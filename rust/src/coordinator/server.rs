//! The coordinator: router + per-spec worker-shard pools.
//!
//! Serving is keyed by [`MethodSpec`], not by method: the coordinator
//! runs `CoordinatorConfig::shards` batcher/worker pairs for **every
//! spec in `CoordinatorConfig::specs`** (default: the six Table I
//! rows), so one deployment can serve any mix of (method × parameter ×
//! I/O-format) design points. The router steers a request to one shard
//! of its spec — round-robin or least-loaded ([`RoutePolicy`]) — and
//! every shard owns its queue, its [`PendingBatch`], and its own
//! [`ServerMetrics`], so the submit hot path touches no cross-shard
//! state. `metrics()` folds the per-shard snapshots into one exact
//! merged view (plus the global kernel-cache counters);
//! `shard_metrics()` exposes the unmerged per-shard counters for
//! imbalance diagnostics.
//!
//! Execution is backend-addressed: workers drive any
//! [`EvalBackend`] — golden kernels, the cycle-accurate hw datapaths,
//! or PJRT graphs — through the one trait, and
//! [`Coordinator::start`] fails fast (typed
//! [`BackendError`]) when the backend is unavailable in this build or
//! cannot express a served spec, instead of discovering it
//! request-by-request. Workers never compile: backends resolve their
//! per-spec state in `ensure`, once per served spec, before traffic is
//! accepted.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

use crate::approx::{MethodId, MethodSpec, Registry};
use crate::backend::{eval_f32, open_stream, Availability, BackendError, ErrorCode, EvalBackend};
use crate::graph::cell::CellConfig;
use crate::graph::serve::CellSession;

use super::batcher::{BatcherConfig, PendingBatch};
use super::metrics::{MetricsSnapshot, ServerMetrics};
use super::request::{Request, RequestError, RequestResult};
use super::session::{
    PulseOutcome, SessionConfig, SessionEntry, SessionInfo, SessionKind, SessionManager,
};

/// How the router picks a shard within a method's pool.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum RoutePolicy {
    /// Cycle through the shards in order (uniform spread).
    #[default]
    RoundRobin,
    /// Pick the shard with the fewest queued elements.
    LeastLoaded,
}

impl RoutePolicy {
    /// Parses a CLI spelling.
    pub fn parse(s: &str) -> Option<RoutePolicy> {
        match s {
            "rr" | "round-robin" => Some(RoutePolicy::RoundRobin),
            "ll" | "least-loaded" => Some(RoutePolicy::LeastLoaded),
            _ => None,
        }
    }
}

/// Coordinator tuning knobs.
#[derive(Clone, Debug)]
pub struct CoordinatorConfig {
    /// Batching policy; `batcher.batch_elements` is the fixed batch
    /// shape workers pack into (and, for PJRT, must match the shape
    /// the graphs were AOT'd for).
    pub batcher: BatcherConfig,
    /// Worker shards per spec (clamped to ≥ 1).
    pub shards: usize,
    /// Shard selection policy.
    pub route: RoutePolicy,
    /// The design points this coordinator serves, in routing order.
    /// Duplicates are dropped; an empty list falls back to the six
    /// Table I specs.
    pub specs: Vec<MethodSpec>,
    /// Streaming-session table limits (cap + idle eviction).
    pub sessions: SessionConfig,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig {
            batcher: BatcherConfig::default(),
            shards: 2,
            route: RoutePolicy::RoundRobin,
            specs: MethodSpec::table1_all(),
            sessions: SessionConfig::default(),
        }
    }
}

impl CoordinatorConfig {
    /// The default config with an explicit batch shape — the common
    /// test/bench spelling.
    pub fn with_batch(batch_elements: usize) -> CoordinatorConfig {
        let mut cfg = CoordinatorConfig::default();
        cfg.batcher.batch_elements = batch_elements;
        cfg
    }
}

/// Everything a shard worker can be asked to do. Eval requests batch;
/// session jobs execute immediately (their state is private, so there
/// is nothing to pack) and carry the session entry with them, keeping
/// the worker loop allocation-free on the routing side.
enum ShardJob {
    Eval(Request),
    Pulse {
        entry: Arc<SessionEntry>,
        input: Vec<i64>,
        enqueued_at: Instant,
        reply: mpsc::Sender<Result<PulseOutcome, RequestError>>,
    },
    Close {
        entry: Arc<SessionEntry>,
        enqueued_at: Instant,
        reply: mpsc::Sender<Result<PulseOutcome, RequestError>>,
    },
}

/// One batcher/worker pair: its queue sender, queued-element gauge and
/// private metrics.
struct Shard {
    tx: mpsc::Sender<ShardJob>,
    depth: Arc<AtomicUsize>,
    metrics: Arc<ServerMetrics>,
}

/// A spec's shard pool plus its round-robin cursor.
struct SpecShards {
    shards: Vec<Shard>,
    rr: AtomicUsize,
}

/// The activation-accelerator service.
pub struct Coordinator {
    /// Served specs, in config order (deduplicated).
    specs: Vec<MethodSpec>,
    pools: HashMap<MethodSpec, SpecShards>,
    next_id: AtomicU64,
    cfg: BatcherConfig,
    route: RoutePolicy,
    backend: Arc<dyn EvalBackend>,
    backend_name: &'static str,
    sessions: SessionManager,
    workers: Mutex<Vec<JoinHandle<()>>>,
}

impl Coordinator {
    /// Starts `cfg.shards` batcher/worker threads per served spec over
    /// the backend.
    ///
    /// Fails fast — before any thread spawns or request is accepted —
    /// when the backend is [`Availability::Unavailable`] in this build
    /// (`backend_unavailable`: e.g. PJRT under the xla shim) or when
    /// [`EvalBackend::ensure`] rejects a served spec (`unknown_spec`:
    /// e.g. a config the hw block diagrams cannot express, or a
    /// non-Table-I spec on PJRT).
    pub fn start(
        backend: Arc<dyn EvalBackend>,
        cfg: CoordinatorConfig,
    ) -> Result<Coordinator, BackendError> {
        if let Availability::Unavailable(reason) = backend.availability() {
            return Err(BackendError::unavailable(format!(
                "backend '{}' cannot serve: {reason}",
                backend.name()
            )));
        }
        let mut batcher_cfg = cfg.batcher;
        // Fixed-shape substrates (PJRT) dictate the batch: align the
        // batcher at startup so a shape mismatch is impossible instead
        // of failing every request at flush time.
        if let Some(batch) = backend.fixed_batch() {
            batcher_cfg.batch_elements = batch;
        }
        let shards = cfg.shards.max(1);
        let mut specs: Vec<MethodSpec> = Vec::with_capacity(cfg.specs.len());
        for s in &cfg.specs {
            if !specs.contains(s) {
                specs.push(*s);
            }
        }
        if specs.is_empty() {
            specs = MethodSpec::table1_all();
        }
        for spec in &specs {
            backend.ensure(spec).map_err(|e| {
                BackendError::new(
                    e.code,
                    format!("backend '{}' cannot serve '{spec}': {}", backend.name(), e.message),
                )
            })?;
        }
        let backend_name = backend.name();
        let mut pools = HashMap::new();
        let mut workers = Vec::new();
        for &spec in &specs {
            let mut pool = Vec::with_capacity(shards);
            for shard_idx in 0..shards {
                let (tx, rx) = mpsc::channel::<ShardJob>();
                let depth = Arc::new(AtomicUsize::new(0));
                let metrics = Arc::new(ServerMetrics::default());
                let handle = spawn_worker(
                    spec,
                    shard_idx,
                    rx,
                    depth.clone(),
                    backend.clone(),
                    batcher_cfg,
                    metrics.clone(),
                );
                pool.push(Shard { tx, depth, metrics });
                workers.push(handle);
            }
            pools.insert(spec, SpecShards { shards: pool, rr: AtomicUsize::new(0) });
        }
        Ok(Coordinator {
            specs,
            pools,
            next_id: AtomicU64::new(0),
            cfg: batcher_cfg,
            route: cfg.route,
            backend,
            backend_name,
            sessions: SessionManager::new(cfg.sessions),
            workers: Mutex::new(workers),
        })
    }

    /// Submits a request for an explicit design point; the reply
    /// arrives on the returned channel. Fails fast with a typed
    /// [`RequestError`] under backpressure (`overloaded`), malformed
    /// input (`bad_request`), or a spec this coordinator does not
    /// serve (`unknown_spec`).
    pub fn submit_spec(
        &self,
        spec: &MethodSpec,
        values: Vec<f32>,
    ) -> Result<mpsc::Receiver<RequestResult>, RequestError> {
        if values.is_empty() {
            return Err(RequestError::admission(ErrorCode::BadRequest, "empty request"));
        }
        if values.len() > self.cfg.batch_elements {
            return Err(RequestError::admission(
                ErrorCode::BadRequest,
                format!(
                    "request of {} elements exceeds the compiled batch {}",
                    values.len(),
                    self.cfg.batch_elements
                ),
            ));
        }
        let pool = self.pools.get(spec).ok_or_else(|| {
            let served: Vec<String> = self.specs.iter().map(|s| s.to_string()).collect();
            RequestError::admission(
                ErrorCode::UnknownSpec,
                format!("spec '{spec}' is not served (serving: {})", served.join(", ")),
            )
        })?;
        let shard = match self.route {
            RoutePolicy::RoundRobin => {
                let i = pool.rr.fetch_add(1, Ordering::Relaxed) % pool.shards.len();
                &pool.shards[i]
            }
            RoutePolicy::LeastLoaded => pool
                .shards
                .iter()
                .min_by_key(|s| s.depth.load(Ordering::Relaxed))
                .expect("spec pool is never empty"),
        };
        let depth = shard.depth.load(Ordering::Relaxed);
        if depth + values.len() > self.cfg.max_queue {
            shard.metrics.record_rejected();
            return Err(RequestError::admission(
                ErrorCode::Overloaded,
                format!("backpressure: shard queue at {depth} elements"),
            ));
        }
        let (reply_tx, reply_rx) = mpsc::channel();
        let len = values.len();
        let req = Request {
            id: self.next_id.fetch_add(1, Ordering::Relaxed),
            spec: *spec,
            values,
            enqueued_at: Instant::now(),
            reply: reply_tx,
        };
        shard.depth.fetch_add(len, Ordering::Relaxed);
        match shard.tx.send(ShardJob::Eval(req)) {
            Ok(()) => {
                shard.metrics.record_submitted();
                Ok(reply_rx)
            }
            Err(_) => {
                shard.depth.fetch_sub(len, Ordering::Relaxed);
                Err(RequestError::admission(ErrorCode::Internal, "worker shut down"))
            }
        }
    }

    /// Method-addressed submit: routes to the first served spec of
    /// `method` (for a default coordinator, its Table I row). The
    /// spec-addressed [`Coordinator::submit_spec`] is the general form.
    pub fn submit(
        &self,
        method: MethodId,
        values: Vec<f32>,
    ) -> Result<mpsc::Receiver<RequestResult>, RequestError> {
        let spec = *self
            .specs
            .iter()
            .find(|s| s.method_id() == method)
            .ok_or_else(|| {
                RequestError::admission(
                    ErrorCode::UnknownSpec,
                    format!("no served spec for method {}", method.name()),
                )
            })?;
        self.submit_spec(&spec, values)
    }

    /// Blocking convenience: submit by method and wait.
    pub fn evaluate(&self, method: MethodId, values: Vec<f32>) -> Result<Vec<f32>, RequestError> {
        let rx = self.submit(method, values)?;
        // A dropped reply means the worker died AFTER accepting the
        // request — a worker-side failure, not an admission rejection.
        let result = rx.recv().map_err(|_| {
            RequestError::backend(ErrorCode::Internal, "worker dropped reply")
        })?;
        result.outcome
    }

    /// Blocking convenience: submit by spec and wait.
    pub fn evaluate_spec(
        &self,
        spec: &MethodSpec,
        values: Vec<f32>,
    ) -> Result<Vec<f32>, RequestError> {
        let rx = self.submit_spec(spec, values)?;
        let result = rx.recv().map_err(|_| {
            RequestError::backend(ErrorCode::Internal, "worker dropped reply")
        })?;
        result.outcome
    }

    /// Opens a streaming session against a served spec: subsequent
    /// [`Coordinator::session_pulse`] calls continue one warm backend
    /// stream ([`open_stream`]), pinned to shard `id % shards` of the
    /// spec's pool so the state never migrates. The returned
    /// [`SessionInfo::delay`] is how many output elements replies lag
    /// the feed until close flushes.
    pub fn open_session(&self, spec: &MethodSpec) -> Result<SessionInfo, RequestError> {
        let pool = self.pools.get(spec).ok_or_else(|| {
            let served: Vec<String> = self.specs.iter().map(|s| s.to_string()).collect();
            RequestError::admission(
                ErrorCode::UnknownSpec,
                format!("spec '{spec}' is not served (serving: {})", served.join(", ")),
            )
        })?;
        let stream = open_stream(&self.backend, spec)
            .map_err(|e| RequestError::admission(e.code, e.message))?;
        let delay = stream.delay();
        let id = self.sessions.next_id();
        let shard = (id as usize) % pool.shards.len();
        let entry = Arc::new(SessionEntry::new(id, *spec, shard, delay, SessionKind::Spec(stream)));
        self.sessions.insert(entry)?;
        Ok(SessionInfo { id, delay })
    }

    /// Opens an LSTM cell-graph session (Table I operating point):
    /// each pulse is one cell step of `4·lanes` gate pre-activations
    /// (`i|f|g|o` concatenated, raw words), each reply the step's
    /// `lanes` of `h_next`; the cell state `c` is carried server-side.
    /// Zero delay.
    pub fn open_cell_session(&self, lanes: usize) -> Result<SessionInfo, RequestError> {
        let cell = CellSession::open(self.backend.as_ref(), &CellConfig::table1_lstm(), lanes)
            .map_err(|e| RequestError::admission(e.code, e.message))?;
        let id = self.sessions.next_id();
        // Cell steps run directly over the backend, so any pool's
        // worker can host them; the first served pool provides the
        // stable executor thread.
        let pool_spec = self.specs[0];
        let shard = (id as usize) % self.pools[&pool_spec].shards.len();
        let entry = Arc::new(SessionEntry::new(id, pool_spec, shard, 0, SessionKind::Cell(cell)));
        self.sessions.insert(entry)?;
        Ok(SessionInfo { id, delay: 0 })
    }

    /// Feeds one pulse of raw input words to a session; the reply (the
    /// released continuation of the output sequence, delay window
    /// applied) arrives on the returned channel. Backpressure and
    /// shutdown mirror [`Coordinator::submit_spec`].
    pub fn session_pulse(
        &self,
        id: u64,
        input: Vec<i64>,
    ) -> Result<mpsc::Receiver<Result<PulseOutcome, RequestError>>, RequestError> {
        if input.is_empty() {
            return Err(RequestError::admission(ErrorCode::BadRequest, "empty pulse"));
        }
        let entry = self.sessions.get(id)?;
        let shard = &self.pools[&entry.pool].shards[entry.shard];
        let depth = shard.depth.load(Ordering::Relaxed);
        if depth + input.len() > self.cfg.max_queue {
            shard.metrics.record_rejected();
            return Err(RequestError::admission(
                ErrorCode::Overloaded,
                format!("backpressure: shard queue at {depth} elements"),
            ));
        }
        let (tx, rx) = mpsc::channel();
        let len = input.len();
        shard.depth.fetch_add(len, Ordering::Relaxed);
        let job = ShardJob::Pulse { entry, input, enqueued_at: Instant::now(), reply: tx };
        match shard.tx.send(job) {
            Ok(()) => {
                shard.metrics.record_submitted();
                Ok(rx)
            }
            Err(_) => {
                shard.depth.fetch_sub(len, Ordering::Relaxed);
                Err(RequestError::admission(ErrorCode::Internal, "worker shut down"))
            }
        }
    }

    /// Closes a session: unbinds the id immediately (new pulses see
    /// `unknown session`) and flushes the delay-window tail on the
    /// pinned worker, **after** any still-queued pulses — the reply
    /// carries the final outputs.
    pub fn session_close(
        &self,
        id: u64,
    ) -> Result<mpsc::Receiver<Result<PulseOutcome, RequestError>>, RequestError> {
        let entry = self.sessions.remove(id).ok_or_else(|| {
            RequestError::admission(
                ErrorCode::BadRequest,
                format!("unknown session {id} (closed, evicted, or never opened)"),
            )
        })?;
        let shard = &self.pools[&entry.pool].shards[entry.shard];
        let (tx, rx) = mpsc::channel();
        match shard.tx.send(ShardJob::Close { entry, enqueued_at: Instant::now(), reply: tx }) {
            Ok(()) => {
                shard.metrics.record_submitted();
                Ok(rx)
            }
            Err(_) => Err(RequestError::admission(ErrorCode::Internal, "worker shut down")),
        }
    }

    /// Blocking convenience: pulse and wait for the released outputs.
    pub fn session_pulse_blocking(
        &self,
        id: u64,
        input: Vec<i64>,
    ) -> Result<PulseOutcome, RequestError> {
        let rx = self.session_pulse(id, input)?;
        rx.recv()
            .map_err(|_| RequestError::backend(ErrorCode::Internal, "worker dropped reply"))?
    }

    /// Blocking convenience: close and wait for the flushed tail.
    pub fn session_close_blocking(&self, id: u64) -> Result<PulseOutcome, RequestError> {
        let rx = self.session_close(id)?;
        rx.recv()
            .map_err(|_| RequestError::backend(ErrorCode::Internal, "worker dropped reply"))?
    }

    /// Connection-drop teardown: close without waiting for the tail.
    /// A no-op for ids already closed or evicted.
    pub fn session_abort(&self, id: u64) {
        // Dropping the receiver is deliberate: the worker's flush
        // reply goes nowhere, which is exactly right for a vanished
        // client.
        let _ = self.session_close(id);
    }

    /// Currently open streaming sessions (the `sessions_open` gauge).
    pub fn sessions_open(&self) -> usize {
        self.sessions.open_count()
    }

    /// Sessions evicted by the idle timeout since start.
    pub fn sessions_evicted(&self) -> u64 {
        self.sessions.evicted()
    }

    /// Runs the idle-eviction sweep now (it also runs lazily on every
    /// open); returns how many sessions were evicted.
    pub fn sweep_sessions(&self) -> usize {
        self.sessions.sweep(Instant::now())
    }

    /// Merged metrics across every shard of every spec (exact fold of
    /// the per-shard snapshots, histogram included), plus the global
    /// kernel-cache counters ([`Registry::global`]) — the observable
    /// for the shared-cache win (compiles == distinct specs, not
    /// shards × specs) — and the coordinator-global session gauges.
    pub fn metrics(&self) -> MetricsSnapshot {
        let mut merged = MetricsSnapshot::default();
        for pool in self.pools.values() {
            for shard in &pool.shards {
                merged = merged.merge(&shard.metrics.snapshot());
            }
        }
        let cache = Registry::global().stats();
        merged.kernel_cache_hits = cache.hits;
        merged.kernel_compiles = cache.compiles;
        merged.sessions_open = self.sessions.open_count() as u64;
        merged.sessions_evicted = self.sessions.evicted();
        merged
    }

    /// Per-shard snapshots as `(spec, shard index, snapshot)`, in
    /// served-spec order.
    pub fn shard_metrics(&self) -> Vec<(MethodSpec, usize, MetricsSnapshot)> {
        let mut out = Vec::new();
        for spec in &self.specs {
            if let Some(pool) = self.pools.get(spec) {
                for (i, shard) in pool.shards.iter().enumerate() {
                    out.push((*spec, i, shard.metrics.snapshot()));
                }
            }
        }
        out
    }

    /// The design points this coordinator serves, in routing order.
    pub fn specs(&self) -> &[MethodSpec] {
        &self.specs
    }

    /// Name of the backend the workers execute on (`golden`, `hw`,
    /// `pjrt`) — reported by the metrics endpoint and the serve rows.
    pub fn backend_name(&self) -> &'static str {
        self.backend_name
    }

    /// The number of worker shards each spec runs.
    pub fn shards_per_method(&self) -> usize {
        self.pools.values().next().map_or(0, |pool| pool.shards.len())
    }

    /// Shuts down the workers. Dropping the senders lets every shard
    /// drain its queued requests and flush its partial batch before the
    /// thread exits, so all in-flight replies are still delivered.
    pub fn shutdown(self) {
        drop(self.pools);
        let mut workers = self.workers.lock().unwrap();
        for h in workers.drain(..) {
            let _ = h.join();
        }
    }
}

fn spawn_worker(
    spec: MethodSpec,
    shard_idx: usize,
    rx: mpsc::Receiver<ShardJob>,
    depth: Arc<AtomicUsize>,
    backend: Arc<dyn EvalBackend>,
    cfg: BatcherConfig,
    metrics: Arc<ServerMetrics>,
) -> JoinHandle<()> {
    std::thread::Builder::new()
        .name(format!("tanh-worker-{}-{shard_idx}", spec.method_id().label()))
        .spawn(move || {
            let mut pending = PendingBatch::default();
            loop {
                // Wait for work: block when idle, poll with the flush
                // deadline when a partial batch is open.
                let timeout = if pending.is_empty() { cfg.max_wait * 50 } else { cfg.max_wait };
                match rx.recv_timeout(timeout) {
                    Ok(job) => {
                        handle(job, shard_idx, &mut pending, &spec, &backend, &cfg, &metrics, &depth);
                        // Greedy drain: requests that queued up while
                        // the previous batch executed are packed NOW
                        // rather than one-per-loop — without this,
                        // their queue age exceeds max_wait and every
                        // request flushes as its own batch (perf log
                        // iteration 1: batch efficiency 6% → see
                        // EXPERIMENTS.md §Perf).
                        while let Ok(job) = rx.try_recv() {
                            handle(
                                job, shard_idx, &mut pending, &spec, &backend, &cfg, &metrics,
                                &depth,
                            );
                        }
                    }
                    Err(mpsc::RecvTimeoutError::Timeout) => {}
                    Err(mpsc::RecvTimeoutError::Disconnected) => {
                        flush(&mut pending, &spec, &backend, &cfg, &metrics, &depth);
                        return;
                    }
                }
                if pending.should_flush(&cfg, Instant::now()) {
                    flush(&mut pending, &spec, &backend, &cfg, &metrics, &depth);
                }
            }
        })
        .expect("spawning worker thread")
}

/// Dispatches one shard job. Eval requests batch through the pending
/// buffer; session pulses and closes execute immediately — their state
/// is session-private, so batching buys nothing, and the session's
/// total order is the queue order.
#[allow(clippy::too_many_arguments)]
fn handle(
    job: ShardJob,
    shard_idx: usize,
    pending: &mut PendingBatch,
    spec: &MethodSpec,
    backend: &Arc<dyn EvalBackend>,
    cfg: &BatcherConfig,
    metrics: &Arc<ServerMetrics>,
    depth: &Arc<AtomicUsize>,
) {
    match job {
        ShardJob::Eval(req) => admit(req, pending, spec, backend, cfg, metrics, depth),
        ShardJob::Pulse { entry, input, enqueued_at, reply } => {
            depth.fetch_sub(input.len(), Ordering::Relaxed);
            // A pulse is a fully-packed single-request batch: capacity
            // == useful elements, so the fill-rate and
            // cycles-per-element observables stay meaningful.
            metrics.record_batch(input.len(), input.len());
            match entry.pulse(backend, &input, shard_idx) {
                Ok(out) => {
                    let latency_us = enqueued_at.elapsed().as_micros() as u64;
                    metrics.record_sim_cycles(out.sim_cycles);
                    metrics.record_request(input.len(), latency_us);
                    let _ = reply.send(Ok(out));
                }
                Err(e) => {
                    let latency_us = enqueued_at.elapsed().as_micros() as u64;
                    metrics.record_error();
                    metrics.record_backend_failed_request(latency_us);
                    let _ = reply.send(Err(RequestError::backend(e.code, e.message)));
                }
            }
        }
        ShardJob::Close { entry, enqueued_at, reply } => {
            let out = entry.flush(shard_idx);
            let latency_us = enqueued_at.elapsed().as_micros() as u64;
            // Zero elements: the tail's elements were counted by the
            // pulses that fed them.
            metrics.record_request(0, latency_us);
            let _ = reply.send(Ok(out));
        }
    }
}

/// Adds a request to the shard's pending batch, flushing first when it
/// would not fit.
fn admit(
    req: Request,
    pending: &mut PendingBatch,
    spec: &MethodSpec,
    backend: &Arc<dyn EvalBackend>,
    cfg: &BatcherConfig,
    metrics: &Arc<ServerMetrics>,
    depth: &Arc<AtomicUsize>,
) {
    // Defense in depth: `submit` already rejects oversized requests, but
    // a request larger than the batch can never satisfy `fits`, so if
    // one ever reached the queue it would starve forever behind an
    // always-flushing loop. Fail it deterministically instead — as an
    // admission error, distinct from backend faults.
    if req.values.len() > cfg.batch_elements {
        depth.fetch_sub(req.values.len(), Ordering::Relaxed);
        let latency_us = req.enqueued_at.elapsed().as_micros() as u64;
        metrics.record_admission_failed_request(latency_us);
        let _ = req.reply.send(RequestResult {
            id: req.id,
            outcome: Err(RequestError::admission(
                ErrorCode::BadRequest,
                format!(
                    "request of {} elements exceeds the compiled batch {}",
                    req.values.len(),
                    cfg.batch_elements
                ),
            )),
            latency_us,
        });
        return;
    }
    if !pending.fits(&req, cfg.batch_elements) {
        flush(pending, spec, backend, cfg, metrics, depth);
    }
    pending.push(req);
}

fn flush(
    pending: &mut PendingBatch,
    spec: &MethodSpec,
    backend: &Arc<dyn EvalBackend>,
    cfg: &BatcherConfig,
    metrics: &Arc<ServerMetrics>,
    depth: &Arc<AtomicUsize>,
) {
    if pending.is_empty() {
        return;
    }
    let batch = pending.take();
    let (flat, spans) = batch.pack(cfg.batch_elements);
    metrics.record_batch(batch.elements, cfg.batch_elements);
    depth.fetch_sub(batch.elements, Ordering::Relaxed);
    let result = eval_f32(backend.as_ref(), spec, &flat);
    let now = Instant::now();
    match result {
        Ok((outputs, stats)) => {
            metrics.record_sim_cycles(stats.sim_cycles);
            if stats.packed {
                metrics.record_packed_batch();
            }
            for (req, (off, len)) in batch.requests.into_iter().zip(spans) {
                let latency_us = now.duration_since(req.enqueued_at).as_micros() as u64;
                metrics.record_request(len, latency_us);
                let _ = req.reply.send(RequestResult {
                    id: req.id,
                    outcome: Ok(outputs[off..off + len].to_vec()),
                    latency_us,
                });
            }
        }
        Err(e) => {
            metrics.record_error();
            for req in batch.requests {
                let latency_us = now.duration_since(req.enqueued_at).as_micros() as u64;
                metrics.record_backend_failed_request(latency_us);
                let _ = req.reply.send(RequestResult {
                    id: req.id,
                    outcome: Err(RequestError::backend(e.code, e.message.clone())),
                    latency_us,
                });
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::GoldenBackend;

    fn start_golden(batch: usize) -> Coordinator {
        Coordinator::start(
            Arc::new(GoldenBackend::new()),
            CoordinatorConfig::with_batch(batch),
        )
        .unwrap()
    }

    #[test]
    fn evaluate_roundtrip_all_methods() {
        let c = start_golden(64);
        assert_eq!(c.shards_per_method(), 2);
        assert_eq!(c.backend_name(), "golden");
        for method in MethodId::all() {
            let out = c.evaluate(method, vec![0.5, -0.5, 3.0]).unwrap();
            assert_eq!(out.len(), 3);
            assert!((out[0] - 0.462).abs() < 1e-3, "{method:?}");
            assert_eq!(out[0], -out[1]);
        }
        let m = c.metrics();
        assert_eq!(m.requests, 6);
        assert_eq!(m.submitted, 6);
        assert_eq!(m.failed_requests, 0);
        assert!(m.batches >= 1);
        c.shutdown();
    }

    #[test]
    fn concurrent_submissions_all_complete() {
        let c = Arc::new(start_golden(256));
        let mut handles = Vec::new();
        for i in 0..8 {
            let c = c.clone();
            handles.push(std::thread::spawn(move || {
                let method = MethodId::all()[i % 6];
                let values: Vec<f32> = (0..50).map(|j| (j as f32) * 0.1 - 2.5).collect();
                let out = c.evaluate(method, values.clone()).unwrap();
                for (x, y) in values.iter().zip(&out) {
                    assert!((x.tanh() - y).abs() < 2e-4, "{method:?} x={x} y={y}");
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let m = c.metrics();
        assert_eq!(m.requests, 8);
        assert_eq!(m.errors, 0);
    }

    #[test]
    fn oversized_request_rejected() {
        let c = start_golden(16);
        let err = c.submit(MethodId::Pwl, vec![0.0; 17]).unwrap_err();
        assert_eq!(err.code, ErrorCode::BadRequest);
        assert!(err.message.contains("exceeds"), "{err}");
        // Deterministic: the same oversized submit yields the same error.
        let err2 = c.submit(MethodId::Pwl, vec![0.0; 17]).unwrap_err();
        assert_eq!(err, err2);
        c.shutdown();
    }

    #[test]
    fn empty_request_rejected() {
        let c = start_golden(16);
        let err = c.submit(MethodId::Pwl, vec![]).unwrap_err();
        assert_eq!(err.code, ErrorCode::BadRequest);
        c.shutdown();
    }

    #[test]
    fn batching_packs_multiple_requests() {
        let c = start_golden(1024);
        // Submit many tiny requests quickly: they should share batches.
        let rxs: Vec<_> =
            (0..64).map(|_| c.submit(MethodId::Pwl, vec![0.1, 0.2]).unwrap()).collect();
        for rx in rxs {
            rx.recv().unwrap().expect_values();
        }
        let m = c.metrics();
        assert_eq!(m.requests, 64);
        assert!(m.batches < 64, "batching collapsed {} batches", m.batches);
        c.shutdown();
    }

    #[test]
    fn round_robin_spreads_across_shards() {
        let c = Coordinator::start(
            Arc::new(GoldenBackend::new()),
            CoordinatorConfig { shards: 3, ..CoordinatorConfig::with_batch(128) },
        )
        .unwrap();
        let rxs: Vec<_> =
            (0..9).map(|_| c.submit(MethodId::Lambert, vec![0.5; 4]).unwrap()).collect();
        for rx in rxs {
            rx.recv().unwrap().expect_values();
        }
        let lambert_shards: Vec<_> = c
            .shard_metrics()
            .into_iter()
            .filter(|(s, _, _)| s.method_id() == MethodId::Lambert)
            .collect();
        assert_eq!(lambert_shards.len(), 3);
        for (_, idx, s) in &lambert_shards {
            assert_eq!(s.submitted, 3, "shard {idx} got {} of 9 round-robin submits", s.submitted);
        }
        c.shutdown();
    }

    #[test]
    fn merged_metrics_equal_fold_of_shard_metrics() {
        let c = start_golden(64);
        for i in 0..30 {
            let _ = c.evaluate(MethodId::all()[i % 6], vec![0.25; 3]).unwrap();
        }
        let merged = c.metrics();
        let mut fold = c
            .shard_metrics()
            .into_iter()
            .fold(MetricsSnapshot::default(), |acc, (_, _, s)| acc.merge(&s));
        // The kernel-cache counters are process-global (set by
        // `metrics()`, not folded from shards); align them before the
        // exactness check on everything else.
        fold.kernel_cache_hits = merged.kernel_cache_hits;
        fold.kernel_compiles = merged.kernel_compiles;
        assert_eq!(merged, fold);
        assert_eq!(merged.submitted, 30);
        assert_eq!(merged.requests + merged.failed_requests, merged.submitted);
        c.shutdown();
    }

    #[test]
    fn least_loaded_routes_to_empty_shard() {
        // With least-loaded routing and sequential evaluate calls the
        // queue is empty at each submit, so every shard stays usable and
        // all requests complete.
        let c = Coordinator::start(
            Arc::new(GoldenBackend::new()),
            CoordinatorConfig {
                route: RoutePolicy::LeastLoaded,
                shards: 2,
                ..CoordinatorConfig::with_batch(64)
            },
        )
        .unwrap();
        for _ in 0..10 {
            let out = c.evaluate(MethodId::Pwl, vec![1.0, -1.0]).unwrap();
            assert_eq!(out.len(), 2);
        }
        assert_eq!(c.metrics().requests, 10);
        c.shutdown();
    }

    #[test]
    fn spec_routing_serves_non_table1_points_and_rejects_unserved() {
        let table1_pwl = MethodSpec::table1(MethodId::Pwl);
        let custom = MethodSpec::parse("pwl:step=1/32:in=s2.13:out=s.15").unwrap();
        let specs = vec![table1_pwl, custom];
        let c = Coordinator::start(
            Arc::new(GoldenBackend::new()),
            CoordinatorConfig { specs: specs.clone(), ..CoordinatorConfig::with_batch(32) },
        )
        .unwrap();
        assert_eq!(c.specs(), &specs[..]);
        // Both design points answer, through their own kernels.
        let a = c.evaluate_spec(&table1_pwl, vec![0.5]).unwrap();
        let b = c.evaluate_spec(&custom, vec![0.5]).unwrap();
        assert!((a[0] - 0.462f32).abs() < 1e-3);
        assert!((b[0] - 0.462f32).abs() < 2e-3);
        // Method-addressed submit resolves to the FIRST served pwl spec.
        let via_method = c.evaluate(MethodId::Pwl, vec![0.5]).unwrap();
        assert_eq!(via_method[0].to_bits(), a[0].to_bits());
        // A spec outside the served set fails fast with a typed error.
        let unserved = MethodSpec::table1(MethodId::Lambert);
        let err = c.submit_spec(&unserved, vec![0.5]).unwrap_err();
        assert_eq!(err.code, ErrorCode::UnknownSpec);
        assert!(err.message.contains("not served"), "{err}");
        let err = c.submit(MethodId::Lambert, vec![0.5]).unwrap_err();
        assert_eq!(err.code, ErrorCode::UnknownSpec);
        assert!(err.message.contains("no served spec"), "{err}");
        // Duplicate specs in the config collapse into one pool.
        assert_eq!(c.shard_metrics().len(), 2 * c.shards_per_method());
        c.shutdown();
    }

    #[test]
    fn duplicate_and_empty_spec_lists_are_handled() {
        let s = MethodSpec::table1(MethodId::Pwl);
        let c = Coordinator::start(
            Arc::new(GoldenBackend::new()),
            CoordinatorConfig {
                specs: vec![s, s, s],
                shards: 1,
                ..CoordinatorConfig::with_batch(16)
            },
        )
        .unwrap();
        assert_eq!(c.specs().len(), 1);
        c.shutdown();
        // Empty spec list falls back to the Table I suite.
        let c = Coordinator::start(
            Arc::new(GoldenBackend::new()),
            CoordinatorConfig { specs: vec![], shards: 1, ..CoordinatorConfig::with_batch(16) },
        )
        .unwrap();
        assert_eq!(c.specs().len(), 6);
        c.shutdown();
    }

    #[test]
    fn start_fails_fast_on_unavailable_backend_and_unsupported_spec() {
        use crate::backend::PjrtBackend;
        // PJRT under the xla shim: start returns backend_unavailable
        // without spawning a single worker (no panic, no half-started
        // coordinator). With real bindings + artifacts present, start
        // succeeds instead — either way, nothing panics.
        let pjrt = Arc::new(PjrtBackend::with_default_artifacts(64));
        let available = pjrt.availability().is_available();
        match Coordinator::start(pjrt, CoordinatorConfig::with_batch(64)) {
            Ok(c) => {
                assert!(available, "start must fail when the backend is unavailable");
                c.shutdown();
            }
            Err(e) => assert_eq!(e.code, ErrorCode::BackendUnavailable, "{e}"),
        }

        // A structurally bogus spec fails ensure at startup with a
        // typed unknown_spec naming the spec — never a constructor
        // panic mid-start.
        use crate::approx::{IoSpec, MethodParams};
        let bogus = MethodSpec {
            params: MethodParams::Taylor { step: 1.0 / 8.0, terms: 9 },
            io: IoSpec::table1(),
            domain: 6.0,
        };
        let err = Coordinator::start(
            Arc::new(crate::backend::HwBackend::new()),
            CoordinatorConfig {
                specs: vec![bogus],
                ..CoordinatorConfig::with_batch(64)
            },
        )
        .unwrap_err();
        assert_eq!(err.code, ErrorCode::UnknownSpec);
        assert!(err.message.contains("cannot serve"), "{err}");
        assert!(err.message.contains("invalid spec"), "{err}");
    }

    #[test]
    fn route_policy_parses() {
        assert_eq!(RoutePolicy::parse("rr"), Some(RoutePolicy::RoundRobin));
        assert_eq!(RoutePolicy::parse("round-robin"), Some(RoutePolicy::RoundRobin));
        assert_eq!(RoutePolicy::parse("ll"), Some(RoutePolicy::LeastLoaded));
        assert_eq!(RoutePolicy::parse("least-loaded"), Some(RoutePolicy::LeastLoaded));
        assert_eq!(RoutePolicy::parse("nope"), None);
    }
}
