//! The coordinator: router + per-method batcher/worker threads.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

use crate::approx::MethodId;

use super::batcher::{BatcherConfig, PendingBatch};
use super::metrics::{MetricsSnapshot, ServerMetrics};
use super::request::{Request, RequestResult};

/// Something that can evaluate a fixed-size flat batch for a method.
/// Implemented by the PJRT [`super::GraphBackend`] and the golden-model
/// fallback ([`super::worker::GoldenBackend`]).
pub trait ExecBackend: Send + Sync + 'static {
    /// Evaluates a full batch (length == `batch_elements`).
    fn execute(&self, method: MethodId, flat: &[f32]) -> Result<Vec<f32>, String>;
    /// The fixed batch size the backend was compiled for.
    fn batch_elements(&self) -> usize;
}

/// Coordinator tuning knobs.
#[derive(Clone, Debug, Default)]
pub struct CoordinatorConfig {
    /// Batching policy (batch size is overridden by the backend's).
    pub batcher: BatcherConfig,
}

struct MethodQueue {
    tx: mpsc::Sender<Request>,
    depth: Arc<AtomicUsize>,
}

/// The activation-accelerator service.
pub struct Coordinator {
    queues: HashMap<MethodId, MethodQueue>,
    metrics: Arc<ServerMetrics>,
    next_id: AtomicU64,
    cfg: BatcherConfig,
    workers: Mutex<Vec<JoinHandle<()>>>,
}

impl Coordinator {
    /// Starts one batcher/worker thread per method over the backend.
    pub fn start(backend: Arc<dyn ExecBackend>, cfg: CoordinatorConfig) -> Coordinator {
        let mut batcher_cfg = cfg.batcher;
        batcher_cfg.batch_elements = backend.batch_elements();
        let metrics = Arc::new(ServerMetrics::default());
        let mut queues = HashMap::new();
        let mut workers = Vec::new();
        for method in MethodId::all() {
            let (tx, rx) = mpsc::channel::<Request>();
            let depth = Arc::new(AtomicUsize::new(0));
            let handle = spawn_worker(
                method,
                rx,
                depth.clone(),
                backend.clone(),
                batcher_cfg,
                metrics.clone(),
            );
            queues.insert(method, MethodQueue { tx, depth });
            workers.push(handle);
        }
        Coordinator {
            queues,
            metrics,
            next_id: AtomicU64::new(0),
            cfg: batcher_cfg,
            workers: Mutex::new(workers),
        }
    }

    /// Submits a request; the reply arrives on the returned channel.
    /// Fails fast under backpressure or oversized input.
    pub fn submit(
        &self,
        method: MethodId,
        values: Vec<f32>,
    ) -> Result<mpsc::Receiver<RequestResult>, String> {
        if values.is_empty() {
            return Err("empty request".into());
        }
        if values.len() > self.cfg.batch_elements {
            return Err(format!(
                "request of {} elements exceeds the compiled batch {}",
                values.len(),
                self.cfg.batch_elements
            ));
        }
        let q = self.queues.get(&method).ok_or("unknown method")?;
        let depth = q.depth.load(Ordering::Relaxed);
        if depth + values.len() > self.cfg.max_queue {
            self.metrics.record_rejected();
            return Err(format!("backpressure: queue at {depth} elements"));
        }
        let (reply_tx, reply_rx) = mpsc::channel();
        let req = Request {
            id: self.next_id.fetch_add(1, Ordering::Relaxed),
            method,
            values,
            enqueued_at: Instant::now(),
            reply: reply_tx,
        };
        q.depth.fetch_add(req.values.len(), Ordering::Relaxed);
        q.tx.send(req).map_err(|_| "worker shut down".to_string())?;
        Ok(reply_rx)
    }

    /// Blocking convenience: submit and wait.
    pub fn evaluate(&self, method: MethodId, values: Vec<f32>) -> Result<Vec<f32>, String> {
        let rx = self.submit(method, values)?;
        let result = rx.recv().map_err(|_| "worker dropped reply".to_string())?;
        result.outcome
    }

    /// Current metrics.
    pub fn metrics(&self) -> MetricsSnapshot {
        self.metrics.snapshot()
    }

    /// Shuts down the workers (drops the senders, joins the threads).
    pub fn shutdown(self) {
        drop(self.queues);
        let mut workers = self.workers.lock().unwrap();
        for h in workers.drain(..) {
            let _ = h.join();
        }
    }
}

fn spawn_worker(
    method: MethodId,
    rx: mpsc::Receiver<Request>,
    depth: Arc<AtomicUsize>,
    backend: Arc<dyn ExecBackend>,
    cfg: BatcherConfig,
    metrics: Arc<ServerMetrics>,
) -> JoinHandle<()> {
    std::thread::Builder::new()
        .name(format!("tanh-worker-{}", method.label()))
        .spawn(move || {
            let mut pending = PendingBatch::default();
            loop {
                // Wait for work: block when idle, poll with the flush
                // deadline when a partial batch is open.
                let timeout = if pending.is_empty() { cfg.max_wait * 50 } else { cfg.max_wait };
                match rx.recv_timeout(timeout) {
                    Ok(req) => {
                        if !pending.fits(&req, cfg.batch_elements) {
                            flush(&mut pending, method, &backend, &cfg, &metrics, &depth);
                        }
                        pending.push(req);
                        // Greedy drain: requests that queued up while
                        // the previous batch executed are packed NOW
                        // rather than one-per-loop — without this,
                        // their queue age exceeds max_wait and every
                        // request flushes as its own batch (perf log
                        // iteration 1: batch efficiency 6% → see
                        // EXPERIMENTS.md §Perf).
                        while let Ok(req) = rx.try_recv() {
                            if !pending.fits(&req, cfg.batch_elements) {
                                flush(&mut pending, method, &backend, &cfg, &metrics, &depth);
                            }
                            pending.push(req);
                        }
                    }
                    Err(mpsc::RecvTimeoutError::Timeout) => {}
                    Err(mpsc::RecvTimeoutError::Disconnected) => {
                        flush(&mut pending, method, &backend, &cfg, &metrics, &depth);
                        return;
                    }
                }
                if pending.should_flush(&cfg, Instant::now()) {
                    flush(&mut pending, method, &backend, &cfg, &metrics, &depth);
                }
            }
        })
        .expect("spawning worker thread")
}

fn flush(
    pending: &mut PendingBatch,
    method: MethodId,
    backend: &Arc<dyn ExecBackend>,
    cfg: &BatcherConfig,
    metrics: &Arc<ServerMetrics>,
    depth: &Arc<AtomicUsize>,
) {
    if pending.is_empty() {
        return;
    }
    let batch = pending.take();
    let (flat, spans) = batch.pack(cfg.batch_elements);
    metrics.record_batch(batch.elements, cfg.batch_elements);
    depth.fetch_sub(batch.elements, Ordering::Relaxed);
    let result = backend.execute(method, &flat);
    let now = Instant::now();
    match result {
        Ok(outputs) => {
            for (req, (off, len)) in batch.requests.into_iter().zip(spans) {
                let latency_us = now.duration_since(req.enqueued_at).as_micros() as u64;
                metrics.record_request(len, latency_us);
                let _ = req.reply.send(RequestResult {
                    id: req.id,
                    outcome: Ok(outputs[off..off + len].to_vec()),
                    latency_us,
                });
            }
        }
        Err(e) => {
            metrics.record_error();
            for req in batch.requests {
                let latency_us = now.duration_since(req.enqueued_at).as_micros() as u64;
                let _ = req.reply.send(RequestResult {
                    id: req.id,
                    outcome: Err(e.clone()),
                    latency_us,
                });
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::worker::GoldenBackend;

    fn start_golden(batch: usize) -> Coordinator {
        Coordinator::start(Arc::new(GoldenBackend::table1(batch)), CoordinatorConfig::default())
    }

    #[test]
    fn evaluate_roundtrip_all_methods() {
        let c = start_golden(64);
        for method in MethodId::all() {
            let out = c.evaluate(method, vec![0.5, -0.5, 3.0]).unwrap();
            assert_eq!(out.len(), 3);
            assert!((out[0] - 0.462).abs() < 1e-3, "{method:?}");
            assert_eq!(out[0], -out[1]);
        }
        let m = c.metrics();
        assert_eq!(m.requests, 6);
        assert!(m.batches >= 1);
        c.shutdown();
    }

    #[test]
    fn concurrent_submissions_all_complete() {
        let c = Arc::new(start_golden(256));
        let mut handles = Vec::new();
        for i in 0..8 {
            let c = c.clone();
            handles.push(std::thread::spawn(move || {
                let method = MethodId::all()[i % 6];
                let values: Vec<f32> = (0..50).map(|j| (j as f32) * 0.1 - 2.5).collect();
                let out = c.evaluate(method, values.clone()).unwrap();
                for (x, y) in values.iter().zip(&out) {
                    assert!((x.tanh() - y).abs() < 2e-4, "{method:?} x={x} y={y}");
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let m = c.metrics();
        assert_eq!(m.requests, 8);
        assert_eq!(m.errors, 0);
    }

    #[test]
    fn oversized_request_rejected() {
        let c = start_golden(16);
        let err = c.submit(MethodId::Pwl, vec![0.0; 17]).unwrap_err();
        assert!(err.contains("exceeds"));
        c.shutdown();
    }

    #[test]
    fn empty_request_rejected() {
        let c = start_golden(16);
        assert!(c.submit(MethodId::Pwl, vec![]).is_err());
        c.shutdown();
    }

    #[test]
    fn batching_packs_multiple_requests() {
        let c = start_golden(1024);
        // Submit many tiny requests quickly: they should share batches.
        let rxs: Vec<_> =
            (0..64).map(|_| c.submit(MethodId::Pwl, vec![0.1, 0.2]).unwrap()).collect();
        for rx in rxs {
            rx.recv().unwrap().expect_values();
        }
        let m = c.metrics();
        assert_eq!(m.requests, 64);
        assert!(m.batches < 64, "batching collapsed {} batches", m.batches);
        c.shutdown();
    }
}
