//! Streaming sessions: server-held per-client state, pulsed.
//!
//! A session binds a client to warm state on the server — an
//! [`EvalStream`] for a served spec (the hw backend keeps its pipeline
//! registers filled between pulses), or an LSTM cell recurrence's
//! `(h, c)` state ([`CellSession`]) — and every pulse of a long
//! sequence continues where the previous one left off, so fill cost is
//! paid once per session instead of once per request. This is tract's
//! pulse model applied to the serving layer: an explicit pulse axis
//! with **delay accounting**.
//!
//! Delay accounting: a pipelined substrate cannot answer the last
//! `delay` elements of what it has been fed until more input (or a
//! flush) pushes them out, so a session tracks `issued` (output
//! elements owed) against `delivered` (elements released), and each
//! pulse releases exactly `issued − delay − delivered` elements —
//! replies lag the feed by the pipeline depth, and `close` flushes the
//! tail at zero extra cycles. A flushed session that fed `k` pulses of
//! `P` elements through a depth-`stages` pipeline cost exactly
//! `stages + k·P − 1` simulated cycles (fill once, then one retire per
//! cycle) — the identity the streaming tests pin.
//!
//! Lifecycle: `open` (lazy idle sweep, then a hard cap answering
//! `overloaded`) → `pulse`* → `close` (or connection-drop teardown, or
//! idle-timeout eviction). All of a session's work executes on one
//! pinned shard worker — `id % shards` — so the state never migrates
//! across threads and pulses of one session are totally ordered.

use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::approx::MethodSpec;
use crate::backend::{BackendError, ErrorCode, EvalBackend, EvalStream};
use crate::graph::serve::CellSession;

use super::request::RequestError;

/// Session-table tuning knobs ([`super::CoordinatorConfig::sessions`]).
#[derive(Clone, Copy, Debug)]
pub struct SessionConfig {
    /// Hard cap on concurrently open sessions; `open` answers
    /// `overloaded` past it (after the idle sweep has run).
    pub max_sessions: usize,
    /// Sessions idle longer than this are evicted by the lazy sweep
    /// (runs on every open, and on demand via
    /// `Coordinator::sweep_sessions`).
    pub idle_timeout: Duration,
}

impl Default for SessionConfig {
    fn default() -> Self {
        SessionConfig { max_sessions: 4096, idle_timeout: Duration::from_secs(60) }
    }
}

/// What a client learns when its session opens.
#[derive(Clone, Copy, Debug)]
pub struct SessionInfo {
    /// Session id — the address every pulse/close carries.
    pub id: u64,
    /// How many output elements replies lag behind the feed until
    /// `close` flushes (pipeline depth − 1 on hw; 0 on stateless
    /// substrates and cell sessions).
    pub delay: usize,
}

/// One pulse (or flush) reply.
#[derive(Clone, Debug, Default)]
pub struct PulseOutcome {
    /// Output words released by this pulse, delay window applied: the
    /// continuation of the session's output sequence, in order.
    pub outputs: Vec<i64>,
    /// Output elements the session owes replies for, cumulative.
    pub issued: u64,
    /// Output elements released to the client, cumulative
    /// (`issued − delay` while streaming; `issued` after a flush).
    pub delivered: u64,
    /// Incremental simulated cycles this pulse occupied the backend
    /// (zero for a flush: the tail was already computed).
    pub sim_cycles: u64,
    /// Shard index that executed the pulse — stable for a session's
    /// whole life (asserted by the no-migration tests).
    pub shard: usize,
}

/// What a session computes per pulse.
pub(crate) enum SessionKind {
    /// A backend evaluation stream over one served spec: pulse in,
    /// same number of output elements owed.
    Spec(Box<dyn EvalStream>),
    /// An LSTM cell recurrence: a pulse is one step of `4·lanes` gate
    /// pre-activations, owing `lanes` elements of `h_next`.
    Cell(CellSession),
}

struct SessionCore {
    kind: SessionKind,
    issued: u64,
    delivered: u64,
    /// Produced-but-unreleased outputs (the delay window tail).
    pending: VecDeque<i64>,
    last_used: Instant,
}

/// One open session. Shared (`Arc`) between the table and in-flight
/// shard jobs; the `core` mutex is uncontended in steady state because
/// all of a session's jobs execute on its one pinned worker.
pub(crate) struct SessionEntry {
    pub id: u64,
    /// Pool key whose `shard`-th worker the session is pinned to.
    pub pool: MethodSpec,
    pub shard: usize,
    pub delay: usize,
    core: Mutex<SessionCore>,
}

impl SessionEntry {
    pub(crate) fn new(
        id: u64,
        pool: MethodSpec,
        shard: usize,
        delay: usize,
        kind: SessionKind,
    ) -> SessionEntry {
        SessionEntry {
            id,
            pool,
            shard,
            delay,
            core: Mutex::new(SessionCore {
                kind,
                issued: 0,
                delivered: 0,
                pending: VecDeque::new(),
                last_used: Instant::now(),
            }),
        }
    }

    /// Executes one pulse (on the pinned worker thread): feeds the
    /// substrate, then releases output up to `issued − delay`.
    pub(crate) fn pulse(
        &self,
        backend: &Arc<dyn EvalBackend>,
        input: &[i64],
        shard: usize,
    ) -> Result<PulseOutcome, BackendError> {
        let mut core = self.core.lock().unwrap();
        core.last_used = Instant::now();
        let (owed, sim_cycles) = match &mut core.kind {
            SessionKind::Spec(stream) => {
                let mut produced = Vec::with_capacity(input.len());
                let stats = stream.feed(input, &mut produced)?;
                let owed = produced.len() as u64;
                core.pending.extend(produced);
                (owed, stats.sim_cycles)
            }
            SessionKind::Cell(cell) => {
                // Cell steps execute directly over the backend on this
                // worker thread — NOT back through the coordinator,
                // which would deadlock the worker on its own queue.
                let (h, cycles) = cell
                    .pulse(backend.as_ref(), input)
                    .map_err(|e| BackendError::new(ErrorCode::BadRequest, e))?;
                let owed = h.len() as u64;
                core.pending.extend(h);
                (owed, cycles)
            }
        };
        core.issued += owed;
        let target = core.issued.saturating_sub(self.delay as u64);
        let n = (target.saturating_sub(core.delivered) as usize).min(core.pending.len());
        let outputs: Vec<i64> = core.pending.drain(..n).collect();
        core.delivered += outputs.len() as u64;
        Ok(PulseOutcome {
            outputs,
            issued: core.issued,
            delivered: core.delivered,
            sim_cycles,
            shard,
        })
    }

    /// Releases the delay-window tail (close). Zero extra cycles: the
    /// tail was computed when its pulse fed the pipeline.
    pub(crate) fn flush(&self, shard: usize) -> PulseOutcome {
        let mut core = self.core.lock().unwrap();
        core.last_used = Instant::now();
        let outputs: Vec<i64> = core.pending.drain(..).collect();
        core.delivered += outputs.len() as u64;
        PulseOutcome {
            outputs,
            issued: core.issued,
            delivered: core.delivered,
            sim_cycles: 0,
            shard,
        }
    }

    fn last_used(&self) -> Instant {
        self.core.lock().unwrap().last_used
    }
}

/// The coordinator's session table.
pub(crate) struct SessionManager {
    cfg: SessionConfig,
    next: AtomicU64,
    evicted: AtomicU64,
    map: Mutex<HashMap<u64, Arc<SessionEntry>>>,
}

impl SessionManager {
    pub(crate) fn new(cfg: SessionConfig) -> SessionManager {
        SessionManager {
            cfg,
            next: AtomicU64::new(1),
            evicted: AtomicU64::new(0),
            map: Mutex::new(HashMap::new()),
        }
    }

    /// Allocates the next session id (the pin `id % shards` needs it
    /// before the entry exists).
    pub(crate) fn next_id(&self) -> u64 {
        self.next.fetch_add(1, Ordering::Relaxed)
    }

    /// Admits an opened session: lazy idle sweep first, then the hard
    /// cap — a full table answers `overloaded`, the retryable code.
    pub(crate) fn insert(&self, entry: Arc<SessionEntry>) -> Result<(), RequestError> {
        self.sweep(Instant::now());
        let mut map = self.map.lock().unwrap();
        if map.len() >= self.cfg.max_sessions {
            return Err(RequestError::admission(
                ErrorCode::Overloaded,
                format!(
                    "session table full ({} open, cap {})",
                    map.len(),
                    self.cfg.max_sessions
                ),
            ));
        }
        map.insert(entry.id, entry);
        Ok(())
    }

    pub(crate) fn get(&self, id: u64) -> Result<Arc<SessionEntry>, RequestError> {
        self.map.lock().unwrap().get(&id).cloned().ok_or_else(|| {
            RequestError::admission(
                ErrorCode::BadRequest,
                format!("unknown session {id} (closed, evicted, or never opened)"),
            )
        })
    }

    /// Unbinds an id (close path). Jobs already queued with the entry
    /// `Arc` still complete in order; new pulses see `unknown session`.
    pub(crate) fn remove(&self, id: u64) -> Option<Arc<SessionEntry>> {
        self.map.lock().unwrap().remove(&id)
    }

    /// Evicts sessions idle past the timeout; returns how many.
    pub(crate) fn sweep(&self, now: Instant) -> usize {
        let timeout = self.cfg.idle_timeout;
        let mut map = self.map.lock().unwrap();
        let before = map.len();
        map.retain(|_, e| now.saturating_duration_since(e.last_used()) < timeout);
        let evicted = before - map.len();
        if evicted > 0 {
            self.evicted.fetch_add(evicted as u64, Ordering::Relaxed);
        }
        evicted
    }

    /// Currently open sessions (the `sessions_open` gauge).
    pub(crate) fn open_count(&self) -> usize {
        self.map.lock().unwrap().len()
    }

    /// Idle-timeout evictions since start (the `sessions_evicted`
    /// gauge).
    pub(crate) fn evicted(&self) -> u64 {
        self.evicted.load(Ordering::Relaxed)
    }
}
