//! Execution backends the coordinator's workers drive.

use std::collections::HashMap;
use std::sync::Arc;

use crate::approx::{CompiledKernel, MethodId, MethodSpec, Registry};
use crate::fixed::Fx;
use crate::rt_err;
use crate::runtime::EngineServer;
use crate::util::error::RtResult;

use super::server::ExecBackend;

/// Evaluates a flat f32 slice through a compiled kernel with the
/// golden quantization conventions: inputs quantize via `Fx::from_f64`
/// (round half away from zero, saturating) so the conversion matches
/// the scalar datapath bit-for-bit; output raws are ≤ 16 bits and
/// therefore exact in f32. Shared by [`GoldenBackend`] and the
/// scenario verifier ([`crate::bench::scenario::GoldenVerifier`]) so
/// the serving path and its checker cannot diverge in conversion.
pub fn kernel_eval_f32(kernel: &CompiledKernel, flat: &[f32]) -> Vec<f32> {
    let in_fmt = kernel.input();
    let raws: Vec<i64> = flat.iter().map(|&v| Fx::from_f64(v as f64, in_fmt).raw()).collect();
    let mut out_raws = vec![0i64; raws.len()];
    kernel.eval_slice_raw(&raws, &mut out_raws);
    let inv = kernel.output().ulp() as f32;
    out_raws.iter().map(|&r| r as f32 * inv).collect()
}

/// PJRT-backed execution: each Table I method maps to one compiled
/// activation graph (`tanh_<method>_<batch>`), preloaded at startup so
/// the hot path never compiles. Execution goes through the engine
/// thread ([`EngineServer`]) because PJRT handles are not `Send`.
/// Only the six Table I specs have AOT'd graphs; any other spec is an
/// execution error (use the golden backend for arbitrary specs).
pub struct GraphBackend {
    engine: Arc<EngineServer>,
    batch: usize,
}

impl GraphBackend {
    /// Artifact name for a method's activation graph.
    pub fn artifact_name(method: MethodId, batch: usize) -> String {
        let key = match method {
            MethodId::Pwl => "pwl",
            MethodId::TaylorQuadratic => "taylor1",
            MethodId::TaylorCubic => "taylor2",
            MethodId::CatmullRom => "catmull_rom",
            MethodId::Velocity => "velocity",
            MethodId::Lambert => "lambert",
        };
        format!("tanh_{key}_{batch}")
    }

    /// Preloads all six method graphs at the given batch size.
    pub fn load_all(engine: Arc<EngineServer>, batch: usize) -> RtResult<GraphBackend> {
        let names: Vec<String> =
            MethodId::all().iter().map(|m| Self::artifact_name(*m, batch)).collect();
        let refs: Vec<&str> = names.iter().map(|s| s.as_str()).collect();
        engine.preload(&refs).map_err(|e| rt_err!("preload: {e}"))?;
        Ok(GraphBackend { engine, batch })
    }

    /// The compiled batch size.
    pub fn batch(&self) -> usize {
        self.batch
    }
}

impl ExecBackend for GraphBackend {
    fn execute(&self, spec: &MethodSpec, flat: &[f32]) -> Result<Vec<f32>, String> {
        if flat.len() != self.batch {
            return Err(format!("batch mismatch: {} vs {}", flat.len(), self.batch));
        }
        let method = spec.method_id();
        if *spec != MethodSpec::table1(method) {
            return Err(format!(
                "pjrt backend only ships AOT graphs for the Table I specs, not '{spec}'"
            ));
        }
        let name = Self::artifact_name(method, self.batch);
        self.engine.run_f32(&name, flat.to_vec())
    }

    fn batch_elements(&self) -> usize {
        self.batch
    }
}

/// Golden-model execution: the rust fixed-point datapaths, served
/// through the compiled integer kernels for **any** set of specs.
/// Kernels are resolved through the shared [`Registry`] cache, so a
/// spec is compiled once per process regardless of how many backends,
/// coordinators or shards serve it (the old per-backend compile made
/// that shards × methods compiles). Used by tests and as the
/// no-artifacts fallback; also the numerically authoritative path the
/// PJRT outputs are compared to.
pub struct GoldenBackend {
    kernels: HashMap<MethodSpec, Arc<CompiledKernel>>,
    batch: usize,
}

impl GoldenBackend {
    /// Builds the Table I suite as the backend.
    pub fn table1(batch: usize) -> GoldenBackend {
        GoldenBackend::for_specs(&MethodSpec::table1_all(), batch)
    }

    /// Builds a backend serving an arbitrary spec set, resolving every
    /// kernel through [`Registry::global`] (cache hit when any earlier
    /// backend, sweep or coordinator already compiled the spec).
    pub fn for_specs(specs: &[MethodSpec], batch: usize) -> GoldenBackend {
        let kernels =
            specs.iter().map(|s| (*s, Registry::global().kernel(s))).collect();
        GoldenBackend { kernels, batch }
    }
}

impl ExecBackend for GoldenBackend {
    fn execute(&self, spec: &MethodSpec, flat: &[f32]) -> Result<Vec<f32>, String> {
        let kernel =
            self.kernels.get(spec).ok_or_else(|| format!("no kernel for spec '{spec}'"))?;
        Ok(kernel_eval_f32(kernel, flat))
    }

    fn batch_elements(&self) -> usize {
        self.batch
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::approx::TanhApprox;
    use crate::fixed::QFormat;

    #[test]
    fn golden_backend_evaluates_all_methods() {
        let b = GoldenBackend::table1(8);
        for method in MethodId::all() {
            let spec = MethodSpec::table1(method);
            let out =
                b.execute(&spec, &[0.0, 0.5, -0.5, 2.0, -2.0, 6.5, -6.5, 0.1]).unwrap();
            assert_eq!(out.len(), 8);
            assert_eq!(out[0], 0.0);
            assert!((out[1] - 0.46).abs() < 0.01, "{method:?}: {}", out[1]);
            assert_eq!(out[1], -out[2]);
            assert!(out[5] > 0.9999);
        }
    }

    #[test]
    fn golden_backend_matches_scalar_datapath() {
        // Slice-wise kernel execution must agree with per-element
        // eval_fx (including the f32 → S3.12 quantization step).
        let b = GoldenBackend::table1(16);
        let inputs: Vec<f32> =
            (0..16).map(|i| (i as f32) * 0.41 - 3.3).collect();
        for m in crate::approx::table1_suite() {
            let out = b.execute(&MethodSpec::table1(m.id()), &inputs).unwrap();
            for (&v, &y) in inputs.iter().zip(&out) {
                let x = Fx::from_f64(v as f64, QFormat::S3_12);
                let want = m.eval_fx(x, QFormat::S_15).to_f64() as f32;
                assert_eq!(y, want, "{:?} x={v}", m.id());
            }
        }
    }

    #[test]
    fn golden_backend_serves_non_table1_specs() {
        let spec = MethodSpec::parse("catmull:step=1/8:in=s2.13:out=s.15:dom=4").unwrap();
        let b = GoldenBackend::for_specs(&[spec], 4);
        let golden = spec.build();
        let inputs = [0.25f32, -1.5, 3.9, 0.0];
        let out = b.execute(&spec, &inputs).unwrap();
        for (&v, &y) in inputs.iter().zip(&out) {
            let x = Fx::from_f64(v as f64, spec.io.input);
            let want = golden.eval_fx(x, spec.io.output).to_f64() as f32;
            assert_eq!(y, want, "x={v}");
        }
        // Specs outside the backend's set are execution errors.
        let other = MethodSpec::table1(MethodId::Pwl);
        assert!(b.execute(&other, &inputs).unwrap_err().contains("no kernel"));
    }

    #[test]
    fn artifact_names_match_aot_convention() {
        assert_eq!(GraphBackend::artifact_name(MethodId::Pwl, 1024), "tanh_pwl_1024");
        assert_eq!(
            GraphBackend::artifact_name(MethodId::CatmullRom, 1024),
            "tanh_catmull_rom_1024"
        );
    }
}
