//! Execution backends the coordinator's workers drive.

use std::collections::HashMap;
use std::sync::Arc;

use crate::approx::{table1_suite, MethodId, TanhApprox};
use crate::fixed::{Fx, QFormat};
use crate::runtime::EngineServer;

use super::server::ExecBackend;

/// PJRT-backed execution: each method maps to one compiled activation
/// graph (`tanh_<method>_<batch>`), preloaded at startup so the hot
/// path never compiles. Execution goes through the engine thread
/// ([`EngineServer`]) because PJRT handles are not `Send`.
pub struct GraphBackend {
    engine: Arc<EngineServer>,
    batch: usize,
}

impl GraphBackend {
    /// Artifact name for a method's activation graph.
    pub fn artifact_name(method: MethodId, batch: usize) -> String {
        let key = match method {
            MethodId::Pwl => "pwl",
            MethodId::TaylorQuadratic => "taylor1",
            MethodId::TaylorCubic => "taylor2",
            MethodId::CatmullRom => "catmull_rom",
            MethodId::Velocity => "velocity",
            MethodId::Lambert => "lambert",
        };
        format!("tanh_{key}_{batch}")
    }

    /// Preloads all six method graphs at the given batch size.
    pub fn load_all(engine: Arc<EngineServer>, batch: usize) -> anyhow::Result<GraphBackend> {
        let names: Vec<String> =
            MethodId::all().iter().map(|m| Self::artifact_name(*m, batch)).collect();
        let refs: Vec<&str> = names.iter().map(|s| s.as_str()).collect();
        engine.preload(&refs).map_err(|e| anyhow::anyhow!("preload: {e}"))?;
        Ok(GraphBackend { engine, batch })
    }

    /// The compiled batch size.
    pub fn batch(&self) -> usize {
        self.batch
    }
}

impl ExecBackend for GraphBackend {
    fn execute(&self, method: MethodId, flat: &[f32]) -> Result<Vec<f32>, String> {
        if flat.len() != self.batch {
            return Err(format!("batch mismatch: {} vs {}", flat.len(), self.batch));
        }
        let name = Self::artifact_name(method, self.batch);
        self.engine.run_f32(&name, flat.to_vec())
    }

    fn batch_elements(&self) -> usize {
        self.batch
    }
}

/// Golden-model execution: the rust fixed-point datapaths (S3.12 →
/// S.15). Used by tests and as a no-artifacts fallback; also the
/// numerically authoritative path the PJRT outputs are compared to.
pub struct GoldenBackend {
    methods: HashMap<MethodId, Box<dyn TanhApprox>>,
    /// Compiled integer fast path for PWL (EXPERIMENTS.md §Perf iter 5:
    /// 182 M evals/s vs 34 M through the generic Fx path).
    pwl_fast: Box<dyn Fn(i64) -> i64 + Send + Sync>,
    batch: usize,
}

impl GoldenBackend {
    /// Builds the Table I suite as the backend.
    pub fn table1(batch: usize) -> GoldenBackend {
        let methods: HashMap<_, _> = table1_suite().into_iter().map(|m| (m.id(), m)).collect();
        let pwl_fast = Box::new(crate::approx::pwl::Pwl::table1().compile_raw());
        GoldenBackend { methods, pwl_fast, batch }
    }
}

impl ExecBackend for GoldenBackend {
    fn execute(&self, method: MethodId, flat: &[f32]) -> Result<Vec<f32>, String> {
        if method == MethodId::Pwl {
            // f32 → S3.12 raw → compiled path → S.15 raw → f32.
            let scale = (1i64 << 12) as f32;
            let inv = 1.0 / (1i64 << 15) as f32;
            return Ok(flat
                .iter()
                .map(|&v| {
                    let raw = (v * scale).round() as i64; // half-away, like Fx::from_f64
                    let raw = raw.clamp(QFormat::S3_12.min_raw(), QFormat::S3_12.max_raw());
                    (self.pwl_fast)(raw) as f32 * inv
                })
                .collect());
        }
        let m = self.methods.get(&method).ok_or_else(|| format!("no model for {method:?}"))?;
        Ok(flat
            .iter()
            .map(|&v| {
                let x = Fx::from_f64(v as f64, QFormat::S3_12);
                m.eval_fx(x, QFormat::S_15).to_f64() as f32
            })
            .collect())
    }

    fn batch_elements(&self) -> usize {
        self.batch
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn golden_backend_evaluates_all_methods() {
        let b = GoldenBackend::table1(8);
        for method in MethodId::all() {
            let out = b.execute(method, &[0.0, 0.5, -0.5, 2.0, -2.0, 6.5, -6.5, 0.1]).unwrap();
            assert_eq!(out.len(), 8);
            assert_eq!(out[0], 0.0);
            assert!((out[1] - 0.46).abs() < 0.01, "{method:?}: {}", out[1]);
            assert_eq!(out[1], -out[2]);
            assert!(out[5] > 0.9999);
        }
    }

    #[test]
    fn artifact_names_match_aot_convention() {
        assert_eq!(GraphBackend::artifact_name(MethodId::Pwl, 1024), "tanh_pwl_1024");
        assert_eq!(
            GraphBackend::artifact_name(MethodId::CatmullRom, 1024),
            "tanh_catmull_rom_1024"
        );
    }
}
