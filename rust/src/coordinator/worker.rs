//! Execution backends the coordinator's workers drive.

use std::collections::HashMap;
use std::sync::Arc;

use crate::approx::{table1_suite, CompiledKernel, IoSpec, MethodId};
use crate::fixed::Fx;
use crate::rt_err;
use crate::runtime::EngineServer;
use crate::util::error::RtResult;

use super::server::ExecBackend;

/// PJRT-backed execution: each method maps to one compiled activation
/// graph (`tanh_<method>_<batch>`), preloaded at startup so the hot
/// path never compiles. Execution goes through the engine thread
/// ([`EngineServer`]) because PJRT handles are not `Send`.
pub struct GraphBackend {
    engine: Arc<EngineServer>,
    batch: usize,
}

impl GraphBackend {
    /// Artifact name for a method's activation graph.
    pub fn artifact_name(method: MethodId, batch: usize) -> String {
        let key = match method {
            MethodId::Pwl => "pwl",
            MethodId::TaylorQuadratic => "taylor1",
            MethodId::TaylorCubic => "taylor2",
            MethodId::CatmullRom => "catmull_rom",
            MethodId::Velocity => "velocity",
            MethodId::Lambert => "lambert",
        };
        format!("tanh_{key}_{batch}")
    }

    /// Preloads all six method graphs at the given batch size.
    pub fn load_all(engine: Arc<EngineServer>, batch: usize) -> RtResult<GraphBackend> {
        let names: Vec<String> =
            MethodId::all().iter().map(|m| Self::artifact_name(*m, batch)).collect();
        let refs: Vec<&str> = names.iter().map(|s| s.as_str()).collect();
        engine.preload(&refs).map_err(|e| rt_err!("preload: {e}"))?;
        Ok(GraphBackend { engine, batch })
    }

    /// The compiled batch size.
    pub fn batch(&self) -> usize {
        self.batch
    }
}

impl ExecBackend for GraphBackend {
    fn execute(&self, method: MethodId, flat: &[f32]) -> Result<Vec<f32>, String> {
        if flat.len() != self.batch {
            return Err(format!("batch mismatch: {} vs {}", flat.len(), self.batch));
        }
        let name = Self::artifact_name(method, self.batch);
        self.engine.run_f32(&name, flat.to_vec())
    }

    fn batch_elements(&self) -> usize {
        self.batch
    }
}

/// Golden-model execution: the rust fixed-point datapaths (S3.12 →
/// S.15), served through the compiled integer kernels. Used by tests
/// and as a no-artifacts fallback; also the numerically authoritative
/// path the PJRT outputs are compared to.
///
/// All six methods are compiled once at startup
/// ([`crate::approx::TanhApprox::compile`]) and batches are processed
/// slice-wise — this replaced the old per-element `dyn eval_fx` loop
/// with a PWL-only fast path (EXPERIMENTS.md §Perf: 182 M evals/s
/// compiled vs 34 M generic; the compiled kernels bring every method to
/// the compiled tier, bit-exact vs the scalar golden models).
pub struct GoldenBackend {
    kernels: HashMap<MethodId, CompiledKernel>,
    batch: usize,
}

impl GoldenBackend {
    /// Builds the Table I suite as the backend, compiling every method.
    pub fn table1(batch: usize) -> GoldenBackend {
        let io = IoSpec::table1();
        let kernels: HashMap<_, _> =
            table1_suite().into_iter().map(|m| (m.id(), m.compile(io))).collect();
        GoldenBackend { kernels, batch }
    }
}

impl ExecBackend for GoldenBackend {
    fn execute(&self, method: MethodId, flat: &[f32]) -> Result<Vec<f32>, String> {
        let kernel =
            self.kernels.get(&method).ok_or_else(|| format!("no kernel for {method:?}"))?;
        let in_fmt = kernel.input();
        // Quantize through Fx::from_f64 (round half away from zero,
        // saturating) so the input conversion matches the golden scalar
        // path bit-for-bit.
        let raws: Vec<i64> =
            flat.iter().map(|&v| Fx::from_f64(v as f64, in_fmt).raw()).collect();
        let mut out_raws = vec![0i64; raws.len()];
        kernel.eval_slice_raw(&raws, &mut out_raws);
        // Output raws are ≤ 16 bits: exact in f32.
        let inv = kernel.output().ulp() as f32;
        Ok(out_raws.iter().map(|&r| r as f32 * inv).collect())
    }

    fn batch_elements(&self) -> usize {
        self.batch
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::approx::TanhApprox;
    use crate::fixed::QFormat;

    #[test]
    fn golden_backend_evaluates_all_methods() {
        let b = GoldenBackend::table1(8);
        for method in MethodId::all() {
            let out = b.execute(method, &[0.0, 0.5, -0.5, 2.0, -2.0, 6.5, -6.5, 0.1]).unwrap();
            assert_eq!(out.len(), 8);
            assert_eq!(out[0], 0.0);
            assert!((out[1] - 0.46).abs() < 0.01, "{method:?}: {}", out[1]);
            assert_eq!(out[1], -out[2]);
            assert!(out[5] > 0.9999);
        }
    }

    #[test]
    fn golden_backend_matches_scalar_datapath() {
        // Slice-wise kernel execution must agree with per-element
        // eval_fx (including the f32 → S3.12 quantization step).
        let b = GoldenBackend::table1(16);
        let inputs: Vec<f32> =
            (0..16).map(|i| (i as f32) * 0.41 - 3.3).collect();
        for m in crate::approx::table1_suite() {
            let out = b.execute(m.id(), &inputs).unwrap();
            for (&v, &y) in inputs.iter().zip(&out) {
                let x = Fx::from_f64(v as f64, QFormat::S3_12);
                let want = m.eval_fx(x, QFormat::S_15).to_f64() as f32;
                assert_eq!(y, want, "{:?} x={v}", m.id());
            }
        }
    }

    #[test]
    fn artifact_names_match_aot_convention() {
        assert_eq!(GraphBackend::artifact_name(MethodId::Pwl, 1024), "tanh_pwl_1024");
        assert_eq!(
            GraphBackend::artifact_name(MethodId::CatmullRom, 1024),
            "tanh_catmull_rom_1024"
        );
    }
}
