//! Pricing an [`Inventory`](super::Inventory) into area / delay /
//! latency — the quantitative form of the paper's §IV.H assessment.

use super::{Inventory, UnitLibrary};

/// Priced hardware cost for one tanh unit.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct CostEstimate {
    /// Total NAND2-equivalent area.
    pub area_ge: f64,
    /// Area of LUT storage alone (the paper's scaling concern).
    pub lut_area_ge: f64,
    /// Critical combinational-path delay per pipeline stage, FO4 units —
    /// the reciprocal of achievable frequency.
    pub stage_delay_fo4: f64,
    /// Latency in cycles (pipeline depth).
    pub latency_cycles: u32,
    /// Throughput in results per cycle (1 for all pipelined designs).
    pub throughput_per_cycle: f64,
}

impl CostEstimate {
    /// Area-delay product — the figure of merit used for Pareto ranking.
    pub fn area_delay(&self) -> f64 {
        self.area_ge * self.stage_delay_fo4
    }
}

/// Prices inventories with a given unit library.
#[derive(Clone, Debug, Default)]
pub struct CostModel {
    /// The unit library in effect.
    pub lib: UnitLibrary,
}

impl CostModel {
    /// Builds a model with the default (textbook) library.
    pub fn new() -> CostModel {
        CostModel::default()
    }

    /// Prices one inventory.
    pub fn price(&self, inv: &Inventory) -> CostEstimate {
        let lib = &self.lib;
        let mw = inv.mult_width.max(16);
        let aw = inv.add_width.max(16);
        let lut_area = lib.lut_ge_per_bit * inv.lut_bits as f64;
        let area = inv.adders as f64 * lib.adder_area(aw)
            + inv.multipliers as f64 * lib.mult_area(mw)
            + inv.squarers as f64 * lib.squarer_area(mw)
            + inv.dividers as f64 * lib.divider_area(mw)
            + lut_area
            + inv.mux2 as f64 * lib.mux2_ge_per_bit * mw as f64
            + inv.mux4 as f64 * lib.mux4_ge_per_bit * mw as f64
            + inv.pipeline_stages as f64 * lib.reg_ge_per_bit * aw as f64;
        // Stage delay: the slowest single block on the path (pipelined
        // designs register between blocks). LUT fetch, multiplier, adder.
        let mut stage = lib.adder_delay(aw);
        if inv.multipliers + inv.squarers + inv.dividers > 0 {
            stage = stage.max(lib.mult_delay(mw));
        }
        if inv.lut_entries > 0 {
            stage = stage.max(lib.lut_delay(inv.lut_entries));
        }
        let latency = inv.pipeline_stages.max(1)
            + inv.dividers * 0; // divider stages already folded into pipeline_stages
        CostEstimate {
            area_ge: area,
            lut_area_ge: lut_area,
            stage_delay_fo4: stage,
            latency_cycles: latency,
            throughput_per_cycle: 1.0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::approx::{table1_suite, IoSpec, MethodId};

    #[test]
    fn paper_iv_h_orderings_hold() {
        // Quantitative form of the paper's assessment:
        //  - PWL has the largest LUT area of the polynomial methods;
        //  - rational methods (D, E) have higher latency than polynomial;
        //  - Taylor-quadratic LUT is smaller than PWL's.
        let io = IoSpec::table1();
        let model = CostModel::new();
        let mut by_id = std::collections::HashMap::new();
        for m in table1_suite() {
            by_id.insert(m.id(), model.price(&m.inventory(io)));
        }
        let pwl = &by_id[&MethodId::Pwl];
        let b1 = &by_id[&MethodId::TaylorQuadratic];
        let b2 = &by_id[&MethodId::TaylorCubic];
        let cr = &by_id[&MethodId::CatmullRom];
        let vf = &by_id[&MethodId::Velocity];
        let lam = &by_id[&MethodId::Lambert];

        assert!(pwl.lut_area_ge > b1.lut_area_ge, "PWL LUT > Taylor LUT");
        assert!(pwl.lut_area_ge > b2.lut_area_ge);
        assert!(pwl.lut_area_ge > cr.lut_area_ge);
        assert!(vf.latency_cycles > pwl.latency_cycles, "rational latency higher");
        assert!(lam.latency_cycles > b1.latency_cycles);
        // Rational methods burn more total area (wide multipliers + divider).
        assert!(lam.area_ge > b1.area_ge, "Lambert area > Taylor area");
        assert!(vf.area_ge > b1.area_ge);
    }

    #[test]
    fn price_is_monotone_in_components() {
        let model = CostModel::new();
        let base = Inventory { adders: 1, mult_width: 16, add_width: 16, pipeline_stages: 1, ..Default::default() };
        let more = Inventory { adders: 2, multipliers: 1, ..base };
        assert!(model.price(&more).area_ge > model.price(&base).area_ge);
    }

    #[test]
    fn area_delay_product_positive() {
        let model = CostModel::new();
        for m in table1_suite() {
            let c = model.price(&m.inventory(IoSpec::table1()));
            assert!(c.area_delay() > 0.0, "{}", m.describe());
            assert!(c.latency_cycles >= 1);
        }
    }
}
