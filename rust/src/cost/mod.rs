//! Hardware cost model — reproduces the paper's §IV design-complexity
//! analysis.
//!
//! Each approximation reports an [`Inventory`] of datapath components
//! (the paper counts adders, multipliers, LUT entries, multiplexers and
//! dividers); [`UnitLibrary`] prices those into gate-equivalent area and
//! critical-path delay so the §IV.H qualitative ranking becomes a
//! quantitative table. The unit library is a standard-cell-flavoured
//! model (ripple/booth multiplier gate counts), not a signoff flow — see
//! DESIGN.md §3 for the substitution rationale.

mod estimate;
mod unit_library;

pub use estimate::{CostEstimate, CostModel};
pub use unit_library::UnitLibrary;

/// Datapath component inventory for one tanh unit (paper §IV).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct Inventory {
    /// Two-operand adders/subtractors.
    pub adders: u32,
    /// General multipliers (width × width).
    pub multipliers: u32,
    /// Squaring units (≈ half a multiplier in area).
    pub squarers: u32,
    /// Newton-Raphson reciprocal dividers (each ≈ `nr_iters` multiplier
    /// stages + control).
    pub dividers: u32,
    /// Total LUT entries across all tables.
    pub lut_entries: u32,
    /// Total LUT storage in bits.
    pub lut_bits: u32,
    /// 2-to-1 multiplexers (velocity-factor selection network).
    pub mux2: u32,
    /// 4-to-1 multiplexers (Table II multi-bit lookup optimization).
    pub mux4: u32,
    /// Operand width in bits of the widest multiplier.
    pub mult_width: u32,
    /// Adder operand width in bits.
    pub add_width: u32,
    /// Pipeline depth in stages (latency in cycles at full throughput).
    pub pipeline_stages: u32,
}

impl Inventory {
    /// Component-wise sum (for composite datapaths).
    pub fn plus(mut self, other: Inventory) -> Inventory {
        self.adders += other.adders;
        self.multipliers += other.multipliers;
        self.squarers += other.squarers;
        self.dividers += other.dividers;
        self.lut_entries += other.lut_entries;
        self.lut_bits += other.lut_bits;
        self.mux2 += other.mux2;
        self.mux4 += other.mux4;
        self.mult_width = self.mult_width.max(other.mult_width);
        self.add_width = self.add_width.max(other.add_width);
        self.pipeline_stages += other.pipeline_stages;
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plus_sums_counts_and_maxes_widths() {
        let a = Inventory { adders: 2, multipliers: 1, mult_width: 16, pipeline_stages: 2, ..Default::default() };
        let b = Inventory { adders: 1, dividers: 1, mult_width: 32, pipeline_stages: 3, ..Default::default() };
        let c = a.plus(b);
        assert_eq!(c.adders, 3);
        assert_eq!(c.multipliers, 1);
        assert_eq!(c.dividers, 1);
        assert_eq!(c.mult_width, 32);
        assert_eq!(c.pipeline_stages, 5);
    }
}
