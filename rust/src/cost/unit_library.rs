//! Standard-cell-flavoured unit cost library.
//!
//! The paper reports complexity in component counts and qualitative
//! area/frequency statements; to turn those into comparable numbers the
//! library prices each component in NAND2-equivalent gate area (GE) and
//! FO4-normalized delay, using the classic textbook figures for
//! ripple-carry adders, Wallace/Booth array multipliers, and mux trees.
//! Absolute values are not meant to match any particular node — ratios
//! and orderings are what the reproduction checks (DESIGN.md §3).

/// Unit cost parameters (NAND2-equivalent gate counts / FO4 delays).
#[derive(Clone, Debug)]
pub struct UnitLibrary {
    /// GE per full-adder bit (carry-lookahead amortized).
    pub adder_ge_per_bit: f64,
    /// GE per multiplier bit² (array multiplier ≈ 1 FA per bit pair).
    pub mult_ge_per_bit2: f64,
    /// Squarer discount vs general multiplier (symmetry halves the array).
    pub squarer_factor: f64,
    /// GE per stored LUT bit (hardwired bitmapping logic, §IV.B).
    pub lut_ge_per_bit: f64,
    /// GE per 2-to-1 mux per bit.
    pub mux2_ge_per_bit: f64,
    /// GE per 4-to-1 mux per bit.
    pub mux4_ge_per_bit: f64,
    /// GE per pipeline register bit.
    pub reg_ge_per_bit: f64,
    /// FO4 delay of an n-bit adder: `adder_delay_base + log2(n)·adder_delay_log`.
    pub adder_delay_base: f64,
    /// Log coefficient of adder delay.
    pub adder_delay_log: f64,
    /// FO4 delay of an n-bit multiplier: `mult_delay_base + log2(n)·mult_delay_log`.
    pub mult_delay_base: f64,
    /// Log coefficient of multiplier delay.
    pub mult_delay_log: f64,
    /// FO4 delay of a LUT with n entries: `log2(n)·lut_delay_log` (mux tree).
    pub lut_delay_log: f64,
    /// Newton-Raphson divider: iterations modeled as `2·iters` dependent
    /// multiplies; this is the iteration count.
    pub nr_iterations: u32,
}

impl Default for UnitLibrary {
    fn default() -> Self {
        UnitLibrary {
            adder_ge_per_bit: 3.0,
            mult_ge_per_bit2: 1.2,
            squarer_factor: 0.55,
            lut_ge_per_bit: 0.35,
            mux2_ge_per_bit: 1.6,
            mux4_ge_per_bit: 2.8,
            reg_ge_per_bit: 4.5,
            adder_delay_base: 4.0,
            adder_delay_log: 2.0,
            mult_delay_base: 8.0,
            mult_delay_log: 3.5,
            lut_delay_log: 1.2,
            nr_iterations: 3,
        }
    }
}

impl UnitLibrary {
    /// GE area of an n-bit adder.
    pub fn adder_area(&self, bits: u32) -> f64 {
        self.adder_ge_per_bit * bits as f64
    }

    /// GE area of an n×n multiplier.
    pub fn mult_area(&self, bits: u32) -> f64 {
        self.mult_ge_per_bit2 * (bits as f64) * (bits as f64)
    }

    /// GE area of an n-bit squarer.
    pub fn squarer_area(&self, bits: u32) -> f64 {
        self.squarer_factor * self.mult_area(bits)
    }

    /// GE area of an NR divider built from 2·iters multiplies worth of
    /// hardware (iterative reuse assumed: 2 multipliers + control).
    pub fn divider_area(&self, bits: u32) -> f64 {
        2.0 * self.mult_area(bits) + self.adder_area(bits)
    }

    /// GE area of a LUT storing `entries` words of `word_bits` bits.
    pub fn lut_area(&self, entries: u32, word_bits: u32) -> f64 {
        self.lut_ge_per_bit * entries as f64 * word_bits as f64
    }

    /// GE area of an n-bit barrel shifter (≈ log2(n) 2:1-mux levels).
    pub fn shifter_area(&self, bits: u32) -> f64 {
        self.mux2_ge_per_bit * bits as f64 * (bits.max(2) as f64).log2()
    }

    /// FO4 delay of an n-bit adder.
    pub fn adder_delay(&self, bits: u32) -> f64 {
        self.adder_delay_base + self.adder_delay_log * (bits.max(2) as f64).log2()
    }

    /// FO4 delay of an n×n multiplier.
    pub fn mult_delay(&self, bits: u32) -> f64 {
        self.mult_delay_base + self.mult_delay_log * (bits.max(2) as f64).log2()
    }

    /// FO4 delay of a LUT fetch (mux-tree depth).
    pub fn lut_delay(&self, entries: u32) -> f64 {
        self.lut_delay_log * (entries.max(2) as f64).log2()
    }

    /// Latency in dependent-multiply units of the NR divider.
    pub fn divider_latency_mults(&self) -> u32 {
        2 * self.nr_iterations + 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn areas_scale_sanely() {
        let lib = UnitLibrary::default();
        // Multiplier grows quadratically, adder linearly.
        assert!(lib.mult_area(32) / lib.mult_area(16) > 3.9);
        assert!((lib.adder_area(32) / lib.adder_area(16) - 2.0).abs() < 1e-12);
        // A 16-bit multiplier dwarfs a 16-bit adder.
        assert!(lib.mult_area(16) > 5.0 * lib.adder_area(16));
        // Squarer cheaper than multiplier.
        assert!(lib.squarer_area(16) < lib.mult_area(16));
    }

    #[test]
    fn delays_grow_with_width() {
        let lib = UnitLibrary::default();
        assert!(lib.mult_delay(32) > lib.mult_delay(16));
        assert!(lib.adder_delay(32) > lib.adder_delay(16));
        assert!(lib.lut_delay(1024) > lib.lut_delay(64));
    }

    #[test]
    fn bigger_lut_slower_paper_claim() {
        // §IV.B: "Increasing LUT size results in reduced operating
        // frequency" — delay must be monotone in entries.
        let lib = UnitLibrary::default();
        let mut prev = 0.0;
        for entries in [16u32, 64, 256, 1024, 4096] {
            let d = lib.lut_delay(entries);
            assert!(d > prev);
            prev = d;
        }
    }
}
