//! Exhaustive fixed-point input grids.

use crate::fixed::{Fx, QFormat};

/// An exhaustive sweep specification over a fixed-point input format,
/// optionally restricted to a symmetric range (the paper's analyses use
/// either the full format range or ±range).
#[derive(Clone, Copy, Debug)]
pub struct InputGrid {
    /// Input format.
    pub fmt: QFormat,
    /// Symmetric range bound: sweep |x| ≤ range (inclusive of the raws
    /// that quantize into it). `None` sweeps the full format.
    pub range: Option<f64>,
}

impl InputGrid {
    /// Full-format grid.
    pub fn full(fmt: QFormat) -> InputGrid {
        InputGrid { fmt, range: None }
    }

    /// Grid restricted to |x| ≤ range.
    pub fn ranged(fmt: QFormat, range: f64) -> InputGrid {
        InputGrid { fmt, range: Some(range) }
    }

    /// The Table I grid: S3.12 over (−6, 6).
    pub fn table1() -> InputGrid {
        InputGrid::ranged(QFormat::S3_12, 6.0)
    }

    /// Raw bounds of the sweep (inclusive).
    pub fn raw_bounds(&self) -> (i64, i64) {
        match self.range {
            None => (self.fmt.min_raw(), self.fmt.max_raw()),
            Some(r) => {
                let hi = ((r * (1i64 << self.fmt.frac_bits) as f64).floor() as i64)
                    .min(self.fmt.max_raw());
                (-hi, hi)
            }
        }
    }

    /// Number of grid points.
    pub fn len(&self) -> usize {
        let (lo, hi) = self.raw_bounds();
        (hi - lo + 1) as usize
    }

    /// True if the grid is empty (cannot happen for valid formats).
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Iterates every grid point.
    pub fn iter(&self) -> impl Iterator<Item = Fx> + '_ {
        let (lo, hi) = self.raw_bounds();
        let fmt = self.fmt;
        (lo..=hi).map(move |raw| Fx::from_raw(raw, fmt))
    }

    /// Iterates a strided subsample (for quick sweeps in benches).
    pub fn iter_strided(&self, stride: usize) -> impl Iterator<Item = Fx> + '_ {
        let (lo, hi) = self.raw_bounds();
        let fmt = self.fmt;
        (lo..=hi).step_by(stride.max(1)).map(move |raw| Fx::from_raw(raw, fmt))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_grid_spans_pm6() {
        let g = InputGrid::table1();
        let (lo, hi) = g.raw_bounds();
        assert_eq!(hi, 6 * 4096);
        assert_eq!(lo, -6 * 4096);
        assert_eq!(g.len(), 2 * 6 * 4096 + 1);
    }

    #[test]
    fn full_grid_covers_format() {
        let g = InputGrid::full(QFormat::S2_5);
        assert_eq!(g.len(), 256);
        let first = g.iter().next().unwrap();
        assert_eq!(first.raw(), QFormat::S2_5.min_raw());
    }

    #[test]
    fn ranged_grid_clamps_to_format() {
        // range beyond the format max clamps.
        let g = InputGrid::ranged(QFormat::S2_13, 100.0);
        let (lo, hi) = g.raw_bounds();
        assert_eq!(hi, QFormat::S2_13.max_raw());
        assert_eq!(lo, -QFormat::S2_13.max_raw());
    }

    #[test]
    fn strided_iter_subsamples() {
        let g = InputGrid::table1();
        let n_full = g.iter().count();
        let n_strided = g.iter_strided(16).count();
        assert!(n_strided <= n_full / 16 + 1);
    }
}
