//! Error-distribution analysis: histograms and per-region statistics.
//!
//! The paper's related work (Zamanlooy [5]) splits tanh into pass /
//! processing / saturation regions; this module measures where each
//! approximation actually spends its error budget, which is what
//! motivates the [`crate::approx::regions`] baseline and explains the
//! Fig 2 curves (error concentrates where |f''| peaks, x ≈ 0.66).

use crate::approx::reference::tanh_ref;
use crate::approx::TanhApprox;
use crate::fixed::QFormat;

use super::InputGrid;

/// Error statistics for one region of the input domain.
#[derive(Clone, Copy, Debug, Default)]
pub struct RegionStats {
    /// Max abs error within the region.
    pub max_abs: f64,
    /// RMS error within the region.
    pub rms: f64,
    /// Points in the region.
    pub points: usize,
}

/// Per-region error split.
#[derive(Clone, Debug)]
pub struct RegionBreakdown {
    /// |x| < pass_bound.
    pub pass: RegionStats,
    /// pass_bound ≤ |x| < sat_bound.
    pub processing: RegionStats,
    /// |x| ≥ sat_bound.
    pub saturation: RegionStats,
    /// The bounds used.
    pub bounds: (f64, f64),
}

/// A log-scale error histogram: bucket i counts errors in
/// [2^(i-shift), 2^(i-shift+1)) ulps.
#[derive(Clone, Debug)]
pub struct ErrorHistogram {
    /// Bucket counts; bucket 0 is "exact (0 error)".
    pub buckets: Vec<usize>,
    /// Output ulp used for normalization.
    pub ulp: f64,
}

impl ErrorHistogram {
    /// Fraction of points with error ≤ `ulps`.
    pub fn fraction_within(&self, ulps: f64) -> f64 {
        let total: usize = self.buckets.iter().sum();
        if total == 0 {
            return 1.0;
        }
        // bucket b (≥1) spans (2^(b-2), 2^(b-1)] ulps
        let mut acc = self.buckets[0];
        for (b, &c) in self.buckets.iter().enumerate().skip(1) {
            let upper = (2f64).powi(b as i32 - 1);
            if upper <= ulps {
                acc += c;
            }
        }
        acc as f64 / total as f64
    }

    /// Renders a text bar chart.
    pub fn render(&self) -> String {
        let total: usize = self.buckets.iter().sum::<usize>().max(1);
        let mut out = String::new();
        for (b, &c) in self.buckets.iter().enumerate() {
            let label = if b == 0 {
                "exact    ".to_string()
            } else {
                format!("≤{:>5.2} ulp", (2f64).powi(b as i32 - 1))
            };
            let bar = "#".repeat((60 * c / total).max(usize::from(c > 0)));
            out.push_str(&format!("{label} {c:>7} {bar}\n"));
        }
        out
    }
}

/// Computes the log-ulp error histogram of a method over a grid.
pub fn histogram(m: &dyn TanhApprox, grid: InputGrid, out: QFormat) -> ErrorHistogram {
    let ulp = out.ulp();
    let mut buckets = vec![0usize; 12];
    for x in grid.iter() {
        let y = m.eval_fx(x, out);
        let err = (y.to_f64() - tanh_ref(x.to_f64())).abs() / ulp;
        let b = if err == 0.0 {
            0
        } else {
            // err in (2^(b-2), 2^(b-1)] → bucket b
            (err.log2().floor() as i32 + 2).clamp(1, buckets.len() as i32 - 1) as usize
        };
        buckets[b] += 1;
    }
    ErrorHistogram { buckets, ulp }
}

/// Splits error stats into the three Zamanlooy-style regions.
pub fn region_breakdown(
    m: &dyn TanhApprox,
    grid: InputGrid,
    out: QFormat,
    pass_bound: f64,
    sat_bound: f64,
) -> RegionBreakdown {
    let mut acc = [(0f64, 0f64, 0usize); 3];
    for x in grid.iter() {
        let v = x.to_f64().abs();
        let idx = if v < pass_bound {
            0
        } else if v < sat_bound {
            1
        } else {
            2
        };
        let y = m.eval_fx(x, out);
        let err = y.to_f64() - tanh_ref(x.to_f64());
        acc[idx].0 = acc[idx].0.max(err.abs());
        acc[idx].1 += err * err;
        acc[idx].2 += 1;
    }
    let stats = |(max_abs, sq, n): (f64, f64, usize)| RegionStats {
        max_abs,
        rms: (sq / n.max(1) as f64).sqrt(),
        points: n,
    };
    RegionBreakdown {
        pass: stats(acc[0]),
        processing: stats(acc[1]),
        saturation: stats(acc[2]),
        bounds: (pass_bound, sat_bound),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::approx::pwl::Pwl;

    #[test]
    fn histogram_covers_all_points() {
        let m = Pwl::table1();
        let grid = InputGrid::table1();
        let h = histogram(&m, grid, QFormat::S_15);
        assert_eq!(h.buckets.iter().sum::<usize>(), grid.len());
        // the Table I PWL config stays within 2 ulp everywhere
        assert!(h.fraction_within(2.0) > 0.999, "{}", h.fraction_within(2.0));
        // and the chart renders
        assert!(h.render().contains("ulp"));
    }

    #[test]
    fn most_error_lives_in_the_processing_region() {
        // tanh's curvature peaks at x≈0.66: the processing region must
        // hold the max error; the saturation region is almost exact.
        let m = Pwl::table1();
        let b = region_breakdown(&m, InputGrid::table1(), QFormat::S_15, 0.1, 5.2);
        assert!(b.processing.max_abs >= b.saturation.max_abs);
        assert!(b.processing.max_abs >= b.pass.max_abs);
        assert!(b.saturation.max_abs < 2.0 * QFormat::S_15.ulp());
        assert_eq!(
            b.pass.points + b.processing.points + b.saturation.points,
            InputGrid::table1().len()
        );
    }

    #[test]
    fn fraction_within_monotone() {
        let m = Pwl::table1();
        let h = histogram(&m, InputGrid::table1(), QFormat::S_15);
        assert!(h.fraction_within(0.5) <= h.fraction_within(1.0));
        assert!(h.fraction_within(1.0) <= h.fraction_within(4.0));
        assert_eq!(h.fraction_within(1e9), 1.0);
    }
}
