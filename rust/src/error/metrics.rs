//! Error metrics vs the tanh reference (paper §III.C).
//!
//! Exhaustive sweeps are the workhorse of the whole comparison (Fig 2,
//! Tables I & III are all built from them), so [`measure`] runs on the
//! compiled integer kernels ([`crate::approx::CompiledKernel`]) and
//! chunks the grid across threads. Chunking is *fixed-size* and the
//! per-chunk accumulators are merged in chunk order, so the result is
//! bit-identical regardless of thread count (asserted by the property
//! tests) — parallelism changes wall-clock only, never the numbers.

use std::sync::atomic::{AtomicUsize, Ordering};

use super::InputGrid;
use crate::approx::compiled::worker_threads;
use crate::approx::reference::tanh_ref;
use crate::approx::{CompiledKernel, IoSpec, MethodSpec, Registry, TanhApprox};
use crate::fixed::QFormat;

/// Fixed accumulation chunk (grid points). Chunk boundaries — not the
/// thread count — determine the floating-point summation order, which
/// is what makes parallel and sequential sweeps return identical
/// metrics.
const CHUNK: usize = 4096;

/// Error statistics of one approximation configuration over a grid.
#[derive(Clone, Copy, Debug, Default)]
pub struct ErrorMetrics {
    /// Maximum absolute error (the paper's "Max Error").
    pub max_abs: f64,
    /// Input value at which the maximum occurs.
    pub argmax: f64,
    /// True mean squared error.
    pub mse: f64,
    /// Root-mean-square error (what Table I's "MSE" column actually
    /// matches — see module docs).
    pub rms: f64,
    /// Mean absolute error.
    pub mean_abs: f64,
    /// Max error expressed in output ulps.
    pub max_ulp: f64,
    /// Number of grid points evaluated.
    pub points: usize,
}

/// Measures the *datapath* model of `m` over `grid`, quantizing outputs
/// to `out` — via the compiled kernel (bit-exact vs `eval_fx`), chunked
/// across all available threads.
pub fn measure(m: &dyn TanhApprox, grid: InputGrid, out: QFormat) -> ErrorMetrics {
    measure_with_threads(m, grid, out, worker_threads())
}

/// [`measure`] with an explicit worker count for the grid sweep
/// (1 = sequential sweep). Any `threads` value returns identical
/// metrics — exposed so tests can assert that. Note the bound covers
/// the *sweep* only: kernel compilation happens through the
/// thread-count-free `TanhApprox::compile`, so methods that tabulate
/// densely (Lambert, fallback impls) still parallelize the table build
/// internally.
pub fn measure_with_threads(
    m: &dyn TanhApprox,
    grid: InputGrid,
    out: QFormat,
    threads: usize,
) -> ErrorMetrics {
    let kernel = m.compile(IoSpec { input: grid.fmt, output: out });
    measure_kernel_with_threads(&kernel, grid, threads)
}

/// Measures a named design point through the **shared kernel cache**
/// ([`Registry::global`]): the spec's grid is derived from its own
/// input format and domain, and its kernel is compiled at most once
/// per process no matter how many sweeps, reports or explorers ask.
/// This is what lets `explore`, Fig 2 and Table III stop paying one
/// compile per sweep point when they revisit a configuration.
pub fn measure_spec(spec: &MethodSpec) -> ErrorMetrics {
    measure_spec_with_threads(spec, worker_threads())
}

/// [`measure_spec`] with an explicit worker count for the grid sweep.
pub fn measure_spec_with_threads(spec: &MethodSpec, threads: usize) -> ErrorMetrics {
    let kernel = Registry::global().kernel(spec);
    let grid = InputGrid::ranged(spec.io.input, spec.domain);
    measure_kernel_with_threads(&kernel, grid, threads)
}

/// Sweeps an already-compiled kernel over a grid (the kernel's input
/// format must be the grid's format). The shared core under
/// [`measure`] (fresh compile) and [`measure_spec`] (cached kernel).
pub fn measure_kernel_with_threads(
    kernel: &CompiledKernel,
    grid: InputGrid,
    threads: usize,
) -> ErrorMetrics {
    debug_assert_eq!(kernel.input(), grid.fmt, "kernel/grid format mismatch");
    let out = kernel.output();
    let in_ulp = grid.fmt.ulp();
    let out_ulp = out.ulp();
    sweep_chunks(grid, out, threads, |clo, chi, acc| {
        let xs: Vec<i64> = (clo..=chi).collect();
        let mut ys = vec![0i64; xs.len()];
        kernel.eval_slice_raw(&xs, &mut ys);
        for (&raw, &y) in xs.iter().zip(&ys) {
            let x = raw as f64 * in_ulp;
            acc.push(x, y as f64 * out_ulp - tanh_ref(x));
        }
    })
}

/// Measures a design point through an arbitrary execution backend
/// ([`crate::backend::EvalBackend`]) — the `sweep --backend hw` path:
/// the full grid streams through the backend's `eval_raw` in the same
/// fixed chunks as [`measure_kernel_with_threads`], so for a bit-exact
/// backend (golden, hw) the metrics are bit-identical to
/// [`measure_spec`], and for a lossy one (PJRT) they quantify the
/// implementation's own error. Errors if the backend is unavailable or
/// cannot express the spec (`ensure` fails).
pub fn measure_backend(
    spec: &MethodSpec,
    backend: &dyn crate::backend::EvalBackend,
    threads: usize,
) -> Result<ErrorMetrics, String> {
    backend.ensure(spec).map_err(|e| e.to_string())?;
    let grid = InputGrid::ranged(spec.io.input, spec.domain);
    let in_ulp = grid.fmt.ulp();
    let out_ulp = spec.io.output.ulp();
    // eval_raw may legitimately fail mid-grid (the trait allows it);
    // chunk closures cannot return Err, so the first failure is
    // captured and surfaced after the sweep instead of panicking the
    // worker thread.
    let failure: std::sync::Mutex<Option<String>> = std::sync::Mutex::new(None);
    let failed = std::sync::atomic::AtomicBool::new(false);
    let metrics = sweep_chunks(grid, spec.io.output, threads, |clo, chi, acc| {
        // Once any chunk failed the sweep's result is discarded anyway
        // — skip the remaining (potentially expensive, e.g.
        // cycle-simulated) chunks instead of burning through them.
        if failed.load(Ordering::Relaxed) {
            return;
        }
        let xs: Vec<i64> = (clo..=chi).collect();
        let mut ys = vec![0i64; xs.len()];
        match backend.eval_raw(spec, &xs, &mut ys) {
            Ok(_) => {
                for (&raw, &y) in xs.iter().zip(&ys) {
                    let x = raw as f64 * in_ulp;
                    acc.push(x, y as f64 * out_ulp - tanh_ref(x));
                }
            }
            Err(e) => {
                failed.store(true, Ordering::Relaxed);
                let mut slot = failure.lock().unwrap();
                if slot.is_none() {
                    *slot = Some(e.to_string());
                }
            }
        }
    });
    match failure.into_inner().unwrap() {
        Some(e) => Err(format!("sweeping '{spec}' on backend '{}': {e}", backend.name())),
        None => Ok(metrics),
    }
}

/// Measures the f64 *math* model (`eval_f64`) over the same grid —
/// isolates algorithmic error from quantization (used by the Fig 2
/// discussion and the ablation benches). Same fixed chunking.
pub fn measure_f64_model(m: &dyn TanhApprox, grid: InputGrid, out: QFormat) -> ErrorMetrics {
    measure_f64_model_with_threads(m, grid, out, worker_threads())
}

/// [`measure_f64_model`] with an explicit worker count.
pub fn measure_f64_model_with_threads(
    m: &dyn TanhApprox,
    grid: InputGrid,
    out: QFormat,
    threads: usize,
) -> ErrorMetrics {
    let in_ulp = grid.fmt.ulp();
    sweep_chunks(grid, out, threads, |clo, chi, acc| {
        for raw in clo..=chi {
            let x = raw as f64 * in_ulp;
            acc.push(x, m.eval_f64(x) - tanh_ref(x));
        }
    })
}

/// Strided (sub-sampled) datapath sweep through the scalar golden
/// model. For sparse strides the compile cost would exceed the sweep,
/// so this intentionally stays scalar and sequential; used by
/// [`crate::explore`]'s quick mode.
pub fn measure_strided(
    m: &dyn TanhApprox,
    grid: InputGrid,
    out: QFormat,
    stride: usize,
) -> ErrorMetrics {
    let mut acc = Accum::default();
    for x in grid.iter_strided(stride) {
        let y = m.eval_fx(x, out);
        acc.push(x.to_f64(), y.to_f64() - tanh_ref(x.to_f64()));
    }
    acc.finish(out)
}

/// Runs `per_chunk` over fixed-size chunks of the grid on `threads`
/// workers (dynamic chunk stealing), then merges the per-chunk
/// accumulators **in chunk order**.
fn sweep_chunks(
    grid: InputGrid,
    out: QFormat,
    threads: usize,
    per_chunk: impl Fn(i64, i64, &mut Accum) + Sync,
) -> ErrorMetrics {
    let (lo, hi) = grid.raw_bounds();
    let n_chunks = grid.len().div_ceil(CHUNK).max(1);
    let chunk_bounds = |ci: usize| {
        let clo = lo + (ci * CHUNK) as i64;
        (clo, (clo + CHUNK as i64 - 1).min(hi))
    };
    let workers = threads.clamp(1, n_chunks);
    let mut accs: Vec<(usize, Accum)> = if workers == 1 {
        (0..n_chunks)
            .map(|ci| {
                let (clo, chi) = chunk_bounds(ci);
                let mut a = Accum::default();
                per_chunk(clo, chi, &mut a);
                (ci, a)
            })
            .collect()
    } else {
        let next = AtomicUsize::new(0);
        std::thread::scope(|s| {
            let handles: Vec<_> = (0..workers)
                .map(|_| {
                    s.spawn(|| {
                        let mut local = Vec::new();
                        loop {
                            let ci = next.fetch_add(1, Ordering::Relaxed);
                            if ci >= n_chunks {
                                break;
                            }
                            let (clo, chi) = chunk_bounds(ci);
                            let mut a = Accum::default();
                            per_chunk(clo, chi, &mut a);
                            local.push((ci, a));
                        }
                        local
                    })
                })
                .collect();
            handles.into_iter().flat_map(|h| h.join().unwrap()).collect()
        })
    };
    accs.sort_by_key(|&(ci, _)| ci);
    let mut total = Accum::default();
    for (_, a) in &accs {
        total.merge(a);
    }
    total.finish(out)
}

/// Mergeable error accumulator: one per chunk, combined in chunk order
/// so parallel sweeps are deterministic.
#[derive(Default)]
struct Accum {
    max_abs: f64,
    argmax: f64,
    sum_sq: f64,
    sum_abs: f64,
    n: usize,
}

impl Accum {
    #[inline]
    fn push(&mut self, x: f64, err: f64) {
        let a = err.abs();
        if a > self.max_abs {
            self.max_abs = a;
            self.argmax = x;
        }
        self.sum_sq += err * err;
        self.sum_abs += a;
        self.n += 1;
    }

    /// Folds a later chunk in. The strict `>` keeps the *first* argmax
    /// on ties, matching a sequential left-to-right sweep.
    fn merge(&mut self, o: &Accum) {
        if o.max_abs > self.max_abs {
            self.max_abs = o.max_abs;
            self.argmax = o.argmax;
        }
        self.sum_sq += o.sum_sq;
        self.sum_abs += o.sum_abs;
        self.n += o.n;
    }

    fn finish(self, out: QFormat) -> ErrorMetrics {
        let n = self.n.max(1) as f64;
        let mse = self.sum_sq / n;
        ErrorMetrics {
            max_abs: self.max_abs,
            argmax: self.argmax,
            mse,
            rms: mse.sqrt(),
            mean_abs: self.sum_abs / n,
            max_ulp: self.max_abs / out.ulp(),
            points: self.n,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::approx::pwl::Pwl;
    use crate::approx::table1_suite;
    use crate::fixed::Fx;

    #[test]
    fn rms_le_max_and_mse_is_rms_squared() {
        let m = Pwl::table1();
        let e = measure(&m, InputGrid::table1(), QFormat::S_15);
        assert!(e.rms <= e.max_abs);
        assert!((e.mse - e.rms * e.rms).abs() < 1e-20);
        assert!(e.mean_abs <= e.rms + 1e-15); // AM-QM inequality
        assert_eq!(e.points, InputGrid::table1().len());
    }

    #[test]
    fn table1_all_methods_in_paper_error_band() {
        // Table I reports max errors between 3.2e-5 and 4.9e-5 and RMS
        // ("MSE" column) around 1e-5. Our datapaths must land in the
        // same band: max < 1e-4, rms < 3e-5.
        for m in table1_suite() {
            let e = measure(m.as_ref(), InputGrid::table1(), QFormat::S_15);
            assert!(e.max_abs < 1.0e-4, "{}: max {}", m.describe(), e.max_abs);
            assert!(e.rms < 3.0e-5, "{}: rms {}", m.describe(), e.rms);
            assert!(e.max_ulp < 3.5, "{}: {} ulp", m.describe(), e.max_ulp);
        }
    }

    #[test]
    fn math_model_error_below_datapath_error() {
        // Quantization can only add error on top of the algorithmic one
        // (up to one rounding quantum of slack).
        let m = Pwl::table1();
        let grid = InputGrid::table1();
        let fx = measure(&m, grid, QFormat::S_15);
        let f64m = measure_f64_model(&m, grid, QFormat::S_15);
        assert!(f64m.max_abs <= fx.max_abs + QFormat::S_15.ulp());
    }

    #[test]
    fn kernel_sweep_matches_scalar_sweep() {
        // The compiled-kernel sweep must reproduce a plain scalar
        // eval_fx loop with the same chunked accumulation: spot-check
        // the order-independent fields (max/argmax/points) exactly.
        let m = Pwl::table1();
        let grid = InputGrid::table1();
        let out = QFormat::S_15;
        let e = measure(&m, grid, out);
        let mut max_abs: f64 = 0.0;
        let mut argmax = 0.0;
        for x in grid.iter() {
            let err = (m.eval_fx(x, out).to_f64() - tanh_ref(x.to_f64())).abs();
            if err > max_abs {
                max_abs = err;
                argmax = x.to_f64();
            }
        }
        assert_eq!(e.max_abs, max_abs);
        assert_eq!(e.argmax, argmax);
        assert_eq!(e.points, grid.len());
    }

    #[test]
    fn thread_count_does_not_change_metrics() {
        // Fixed chunking ⇒ identical merged Accum for any worker count.
        let m = Pwl::table1();
        let grid = InputGrid::table1();
        let out = QFormat::S_15;
        let seq = measure_with_threads(&m, grid, out, 1);
        for threads in [2, 3, 8] {
            let par = measure_with_threads(&m, grid, out, threads);
            assert_eq!(seq.max_abs, par.max_abs, "{threads} threads");
            assert_eq!(seq.argmax, par.argmax, "{threads} threads");
            assert_eq!(seq.mse, par.mse, "{threads} threads");
            assert_eq!(seq.mean_abs, par.mean_abs, "{threads} threads");
            assert_eq!(seq.points, par.points, "{threads} threads");
        }
    }

    #[test]
    fn measure_spec_is_bit_identical_to_measure() {
        // The cached-kernel path must not change a single bit of the
        // metrics vs a fresh per-call compile (the fixture guarantee).
        let spec = MethodSpec::table1(crate::approx::MethodId::Pwl);
        let via_spec = measure_spec(&spec);
        let via_fresh = measure(&*spec.build(), InputGrid::table1(), QFormat::S_15);
        assert_eq!(via_spec.max_abs, via_fresh.max_abs);
        assert_eq!(via_spec.argmax, via_fresh.argmax);
        assert_eq!(via_spec.mse, via_fresh.mse);
        assert_eq!(via_spec.mean_abs, via_fresh.mean_abs);
        assert_eq!(via_spec.points, via_fresh.points);
        // Second call hits the cache and still agrees.
        let again = measure_spec(&spec);
        assert_eq!(again.max_abs, via_spec.max_abs);
        assert_eq!(again.mse, via_spec.mse);
    }

    #[test]
    fn strided_measure_underreports_full() {
        let m = Pwl::table1();
        let grid = InputGrid::table1();
        let full = measure(&m, grid, QFormat::S_15);
        let strided = measure_strided(&m, grid, QFormat::S_15, 7);
        assert!(strided.max_abs <= full.max_abs + 1e-15);
        assert!(strided.points < full.points);
        // Sanity: a raw the strided sweep visits scores the same error.
        let x = Fx::from_raw(grid.raw_bounds().0, grid.fmt);
        let _ = m.eval_fx(x, QFormat::S_15);
    }
}
