//! Error metrics vs the tanh reference (paper §III.C).

use super::InputGrid;
use crate::approx::reference::tanh_ref;
use crate::approx::TanhApprox;
use crate::fixed::QFormat;

/// Error statistics of one approximation configuration over a grid.
#[derive(Clone, Copy, Debug, Default)]
pub struct ErrorMetrics {
    /// Maximum absolute error (the paper's "Max Error").
    pub max_abs: f64,
    /// Input value at which the maximum occurs.
    pub argmax: f64,
    /// True mean squared error.
    pub mse: f64,
    /// Root-mean-square error (what Table I's "MSE" column actually
    /// matches — see module docs).
    pub rms: f64,
    /// Mean absolute error.
    pub mean_abs: f64,
    /// Max error expressed in output ulps.
    pub max_ulp: f64,
    /// Number of grid points evaluated.
    pub points: usize,
}

/// Measures the *datapath* model (`eval_fx`) of `m` over `grid`,
/// quantizing outputs to `out`.
pub fn measure(m: &dyn TanhApprox, grid: InputGrid, out: QFormat) -> ErrorMetrics {
    let mut acc = Accum::default();
    for x in grid.iter() {
        let y = m.eval_fx(x, out);
        let want = tanh_ref(x.to_f64());
        acc.push(x.to_f64(), y.to_f64() - want);
    }
    acc.finish(out)
}

/// Measures the f64 *math* model (`eval_f64`) over the same grid —
/// isolates algorithmic error from quantization (used by the Fig 2
/// discussion and the ablation benches).
pub fn measure_f64_model(m: &dyn TanhApprox, grid: InputGrid, out: QFormat) -> ErrorMetrics {
    let mut acc = Accum::default();
    for x in grid.iter() {
        let y = m.eval_f64(x.to_f64());
        let want = tanh_ref(x.to_f64());
        acc.push(x.to_f64(), y - want);
    }
    acc.finish(out)
}

#[derive(Default)]
struct Accum {
    max_abs: f64,
    argmax: f64,
    sum_sq: f64,
    sum_abs: f64,
    n: usize,
}

impl Accum {
    #[inline]
    fn push(&mut self, x: f64, err: f64) {
        let a = err.abs();
        if a > self.max_abs {
            self.max_abs = a;
            self.argmax = x;
        }
        self.sum_sq += err * err;
        self.sum_abs += a;
        self.n += 1;
    }

    fn finish(self, out: QFormat) -> ErrorMetrics {
        let n = self.n.max(1) as f64;
        let mse = self.sum_sq / n;
        ErrorMetrics {
            max_abs: self.max_abs,
            argmax: self.argmax,
            mse,
            rms: mse.sqrt(),
            mean_abs: self.sum_abs / n,
            max_ulp: self.max_abs / out.ulp(),
            points: self.n,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::approx::pwl::Pwl;
    use crate::approx::table1_suite;

    #[test]
    fn rms_le_max_and_mse_is_rms_squared() {
        let m = Pwl::table1();
        let e = measure(&m, InputGrid::table1(), QFormat::S_15);
        assert!(e.rms <= e.max_abs);
        assert!((e.mse - e.rms * e.rms).abs() < 1e-20);
        assert!(e.mean_abs <= e.rms + 1e-15); // AM-QM inequality
        assert_eq!(e.points, InputGrid::table1().len());
    }

    #[test]
    fn table1_all_methods_in_paper_error_band() {
        // Table I reports max errors between 3.2e-5 and 4.9e-5 and RMS
        // ("MSE" column) around 1e-5. Our datapaths must land in the
        // same band: max < 1e-4, rms < 3e-5.
        for m in table1_suite() {
            let e = measure(m.as_ref(), InputGrid::table1(), QFormat::S_15);
            assert!(e.max_abs < 1.0e-4, "{}: max {}", m.describe(), e.max_abs);
            assert!(e.rms < 3.0e-5, "{}: rms {}", m.describe(), e.rms);
            assert!(e.max_ulp < 3.5, "{}: {} ulp", m.describe(), e.max_ulp);
        }
    }

    #[test]
    fn math_model_error_below_datapath_error() {
        // Quantization can only add error on top of the algorithmic one
        // (up to one rounding quantum of slack).
        let m = Pwl::table1();
        let grid = InputGrid::table1();
        let fx = measure(&m, grid, QFormat::S_15);
        let f64m = measure_f64_model(&m, grid, QFormat::S_15);
        assert!(f64m.max_abs <= fx.max_abs + QFormat::S_15.ulp());
    }
}
