//! Error-analysis engine (paper §III).
//!
//! Computes the paper's metrics — maximum absolute error and MSE against
//! the f64 tanh reference — by *exhaustively* sweeping the fixed-point
//! input grid (§III.C "the code was written in python and the maximum
//! absolute error and mean square error (MSE) is computed"), plus the
//! ulp-denominated variants Table III's 1-ulp search needs.
//!
//! Note on the paper's "MSE" column: Table I reports e.g. PWL
//! MSE 1.24×10⁻⁵ alongside max error 4.65×10⁻⁵. A true mean-*squared*
//! error can never exceed max_err² ≈ 2×10⁻⁹, so the column is consistent
//! with the *root*-mean-square error instead; we therefore report both
//! `mse` and `rms` and compare the paper's column against `rms`
//! (EXPERIMENTS.md discusses the discrepancy).
//!
//! Exhaustive sweeps ([`measure`]) run on the compiled integer kernels
//! and are chunked across threads with deterministic merging — see
//! [`metrics`](self) and EXPERIMENTS.md §Perf. The Fig 2 sweeps
//! ([`sweep_fig2`]) and the Table III 1-ulp search
//! ([`search_1ulp_param`]) inherit both for free since they are built
//! on `measure`.

mod grid;
pub mod histogram;
mod metrics;
mod sweep;
pub mod ulp_search;

pub use grid::InputGrid;
pub use histogram::{histogram, region_breakdown, ErrorHistogram, RegionBreakdown};
pub use metrics::{
    measure, measure_backend, measure_f64_model, measure_f64_model_with_threads,
    measure_kernel_with_threads, measure_spec, measure_spec_with_threads, measure_strided,
    measure_with_threads, ErrorMetrics,
};
pub use sweep::{fig2_params, sweep_fig2, Fig2Point, Fig2Series};
pub use ulp_search::{search_1ulp_param, table3_rows, Table3Row, Table3Spec};
