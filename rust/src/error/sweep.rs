//! Fig 2 parameter sweeps: max-abs error and MSE as a function of each
//! method's tunable parameter (paper §III.D).

use super::{measure_kernel_with_threads, ErrorMetrics, InputGrid};
use crate::approx::compiled::worker_threads;
use crate::approx::{IoSpec, MethodId, MethodSpec, Registry};
use crate::fixed::QFormat;

/// One point of a Fig 2 panel.
#[derive(Clone, Copy, Debug)]
pub struct Fig2Point {
    /// The method's tunable parameter (step / threshold / K).
    pub param: f64,
    /// Measured error metrics at this parameter.
    pub metrics: ErrorMetrics,
}

/// One Fig 2 panel: a method's error-vs-parameter curve.
#[derive(Clone, Debug)]
pub struct Fig2Series {
    /// Which method.
    pub id: MethodId,
    /// Axis label for the parameter (paper uses "step size", "threshold",
    /// "number of fractions").
    pub param_name: &'static str,
    /// Curve points, ordered as swept.
    pub points: Vec<Fig2Point>,
}

/// The parameter grids the paper's Fig 2 panels sweep: step sizes (or
/// thresholds) 1/8 … 1/256 for A–D, fraction counts 2…10 for E.
pub fn fig2_params(id: MethodId) -> (&'static str, Vec<f64>) {
    match id {
        MethodId::Pwl | MethodId::CatmullRom => (
            "step size",
            vec![1.0 / 8.0, 1.0 / 16.0, 1.0 / 32.0, 1.0 / 64.0, 1.0 / 128.0, 1.0 / 256.0],
        ),
        MethodId::TaylorQuadratic | MethodId::TaylorCubic => (
            "step size",
            vec![1.0 / 4.0, 1.0 / 8.0, 1.0 / 16.0, 1.0 / 32.0, 1.0 / 64.0],
        ),
        MethodId::Velocity => (
            "threshold",
            vec![1.0 / 16.0, 1.0 / 32.0, 1.0 / 64.0, 1.0 / 128.0, 1.0 / 256.0, 1.0 / 512.0],
        ),
        MethodId::Lambert => ("number of fractions", (2..=10).map(|k| k as f64).collect()),
    }
}

/// Sweeps one method's Fig 2 panel over the given grid/output format.
/// Each sweep point is a [`MethodSpec`] resolved through the shared
/// kernel cache, so regenerating Fig 2 after an `explore` (or twice in
/// one process) compiles nothing the second time. Parameters the input
/// format cannot address (a step finer than the grid's ulp) are
/// skipped, like [`super::search_1ulp_param`] does — a coarse grid
/// yields a shorter panel, not a panic.
pub fn sweep_fig2(id: MethodId, grid: InputGrid, out: QFormat) -> Fig2Series {
    let (param_name, params) = fig2_params(id);
    let domain = grid.range.unwrap_or(grid.fmt.max_value());
    let io = IoSpec { input: grid.fmt, output: out };
    let points = params
        .into_iter()
        .filter_map(|param| {
            let spec = MethodSpec::with_param(id, param, io, domain).ok()?;
            let kernel = Registry::global().kernel(&spec);
            Some(Fig2Point {
                param,
                metrics: measure_kernel_with_threads(&kernel, grid, worker_threads()),
            })
        })
        .collect();
    Fig2Series { id, param_name, points }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick_grid() -> InputGrid {
        // Strided-equivalent small grid: 8-bit-ish resolution keeps the
        // sweep tests fast while preserving orderings.
        InputGrid::ranged(QFormat::new(3, 8), 6.0)
    }

    #[test]
    fn error_decreases_with_finer_step_pwl() {
        let s = sweep_fig2(MethodId::Pwl, quick_grid(), QFormat::S_15);
        // max error must be non-increasing as the step shrinks (up to the
        // quantization floor — allow a 1.5 ulp slack band).
        let slack = 1.5 * QFormat::S_15.ulp();
        for w in s.points.windows(2) {
            assert!(
                w[1].metrics.max_abs <= w[0].metrics.max_abs + slack,
                "step {} -> {}: {} -> {}",
                w[0].param,
                w[1].param,
                w[0].metrics.max_abs,
                w[1].metrics.max_abs
            );
        }
        // And strictly improves from the coarsest to the finest point.
        assert!(s.points.last().unwrap().metrics.max_abs < s.points[0].metrics.max_abs / 4.0);
    }

    #[test]
    fn error_decreases_with_terms_lambert() {
        let s = sweep_fig2(MethodId::Lambert, quick_grid(), QFormat::S_15);
        let first = s.points.first().unwrap().metrics.max_abs;
        let last = s.points.last().unwrap().metrics.max_abs;
        assert!(last < first / 10.0, "K=2: {first} vs K=10: {last}");
    }

    #[test]
    fn all_panels_have_points() {
        for id in MethodId::all() {
            let s = sweep_fig2(id, quick_grid(), QFormat::S_15);
            assert!(s.points.len() >= 5, "{:?}", id);
            assert!(!s.param_name.is_empty());
        }
    }
}
