//! Table III: the coarsest (cheapest) parameter per method that keeps
//! the maximum error within 1 output ulp, across I/O formats and ranges
//! (paper §IV.G "Tolerance to precision and input range").

use super::{measure_kernel_with_threads, InputGrid};
use crate::approx::compiled::worker_threads;
use crate::approx::{IoSpec, MethodId, MethodSpec, Registry};
use crate::fixed::QFormat;

/// One Table III row specification: I/O formats and the input range.
#[derive(Clone, Copy, Debug)]
pub struct Table3Spec {
    /// Input fixed-point format.
    pub input: QFormat,
    /// Output fixed-point format.
    pub output: QFormat,
    /// Symmetric input range bound.
    pub range: f64,
}

/// The paper's four Table III rows.
pub fn table3_rows() -> Vec<Table3Spec> {
    vec![
        Table3Spec { input: QFormat::S2_13, output: QFormat::S2_13, range: 4.0 },
        Table3Spec { input: QFormat::S2_13, output: QFormat::S_15, range: 4.0 },
        Table3Spec { input: QFormat::S3_12, output: QFormat::S_15, range: 6.0 },
        Table3Spec { input: QFormat::S2_5, output: QFormat::S_7, range: 4.0 },
    ]
}

/// A computed Table III row: per-method cheapest parameter meeting the
/// 1-ulp target (`None` if no candidate parameter achieves it).
#[derive(Clone, Debug)]
pub struct Table3Row {
    /// The row spec.
    pub spec: Table3Spec,
    /// Cheapest passing parameter per method, in `MethodId::all()` order.
    pub params: [Option<f64>; 6],
}

/// Candidate parameters from cheapest to most precise for a method,
/// bounded by what the input format can address (a step of 2^-k needs
/// k ≤ frac_bits).
fn candidates(id: MethodId, input: QFormat) -> Vec<f64> {
    match id {
        MethodId::Lambert => (1..=14).map(|k| k as f64).collect(),
        _ => (1..=input.frac_bits)
            .map(|k| (2f64).powi(-(k as i32)))
            .collect(),
    }
}

/// Finds the cheapest parameter of `id` whose exhaustive max error is
/// ≤ `ulp_budget` output ulps for the given spec. Candidates resolve
/// through the shared kernel cache; a candidate the typed validation
/// rejects (e.g. a Taylor step equal to the input ulp, which leaves no
/// expansion bits — previously a latent panic) is skipped.
pub fn search_1ulp_param(id: MethodId, spec: Table3Spec, ulp_budget: f64) -> Option<f64> {
    let grid = InputGrid::ranged(spec.input, spec.range);
    let io = IoSpec { input: spec.input, output: spec.output };
    for param in candidates(id, spec.input) {
        let Ok(mspec) = MethodSpec::with_param(id, param, io, spec.range) else {
            continue;
        };
        let kernel = Registry::global().kernel(&mspec);
        let e = measure_kernel_with_threads(&kernel, grid, worker_threads());
        if e.max_ulp <= ulp_budget {
            return Some(param);
        }
    }
    None
}

/// Computes a full Table III row.
pub fn compute_table3_row(spec: Table3Spec, ulp_budget: f64) -> Table3Row {
    let mut params = [None; 6];
    for (i, id) in MethodId::all().into_iter().enumerate() {
        params[i] = search_1ulp_param(id, spec, ulp_budget);
    }
    Table3Row { spec, params }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eight_bit_row_is_cheap() {
        // Paper Table III row 4 (S2.5 → S.7, ±4): coarse parameters
        // (1/8-ish steps) already reach 1 ulp of a 7-bit output.
        let spec = Table3Spec { input: QFormat::S2_5, output: QFormat::S_7, range: 4.0 };
        let p = search_1ulp_param(MethodId::Pwl, spec, 1.0).expect("PWL must pass");
        assert!(p >= 1.0 / 32.0, "paper: 1/8, got {p}");
        let k = search_1ulp_param(MethodId::Lambert, spec, 1.0).expect("Lambert must pass");
        assert!(k <= 6.0, "paper: 4 terms, got {k}");
    }

    #[test]
    fn sixteen_bit_rows_need_finer_params() {
        // Row 2 targets a 15-bit output: every polynomial method needs a
        // much finer step than the 8-bit row.
        let spec8 = Table3Spec { input: QFormat::S2_5, output: QFormat::S_7, range: 4.0 };
        let spec16 = Table3Spec { input: QFormat::S2_13, output: QFormat::S_15, range: 4.0 };
        for id in [MethodId::Pwl, MethodId::CatmullRom] {
            let p8 = search_1ulp_param(id, spec8, 1.0).unwrap();
            let p16 = search_1ulp_param(id, spec16, 1.0).unwrap_or(0.0);
            assert!(p16 < p8, "{id:?}: 16-bit param {p16} not finer than 8-bit {p8}");
        }
    }

    #[test]
    fn taylor_cubic_passes_with_coarser_step_than_quadratic() {
        // Paper rows 1-3: B2's step (1/16) is coarser than B1's (1/32).
        let spec = Table3Spec { input: QFormat::S2_13, output: QFormat::S2_13, range: 4.0 };
        let b1 = search_1ulp_param(MethodId::TaylorQuadratic, spec, 1.0);
        let b2 = search_1ulp_param(MethodId::TaylorCubic, spec, 1.0);
        if let (Some(b1), Some(b2)) = (b1, b2) {
            assert!(b2 >= b1, "B2 {b2} should be ≥ B1 {b1}");
        }
    }
}
