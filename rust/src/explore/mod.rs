//! Design-space exploration: the quantitative version of the paper's
//! §IV.H assessment. Sweeps (method × parameter), measures error,
//! prices hardware, and extracts the Pareto frontier over
//! (max error, area, latency).

mod pareto;
mod space;

pub use pareto::{pareto_frontier, DesignPoint};
pub use space::{explore, explore_specs, ExploreConfig};
