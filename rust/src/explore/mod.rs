//! Design-space exploration: the quantitative version of the paper's
//! §IV.H assessment. Sweeps (method × parameter), measures error,
//! resolves hardware cost through a [`crate::backend::CostProbe`]
//! (analytic §IV model on golden, lowered-pipeline measurements on
//! hw), and extracts the Pareto frontier over a configurable objective
//! set (default: max error × area × latency; see [`Objective`]).

mod pareto;
mod space;

pub use pareto::{dominates_by, pareto_frontier, pareto_frontier_by, DesignPoint, Objective};
pub use space::{explore, explore_specs, explore_specs_probed, sweep_specs, ExploreConfig};
