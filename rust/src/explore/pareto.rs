//! Pareto frontier over (error, area, latency).

use crate::approx::{MethodId, MethodSpec};

/// One evaluated design: a named design point ([`MethodSpec`]) with
/// its measured error and priced hardware cost. `id`/`param` are
/// derived from the spec and kept as columns for the table renderers.
#[derive(Clone, Debug)]
pub struct DesignPoint {
    /// The full design-point name (method × parameter × I/O × domain) —
    /// paste it into `tanh-vlsi sweep/serve --spec` to reproduce.
    pub spec: MethodSpec,
    /// Method.
    pub id: MethodId,
    /// Tunable parameter (step/threshold/K).
    pub param: f64,
    /// Exhaustive max abs error on the analysis grid.
    pub max_err: f64,
    /// RMS error.
    pub rms: f64,
    /// Priced area in gate equivalents.
    pub area_ge: f64,
    /// Pipeline latency in cycles.
    pub latency_cycles: u32,
    /// Critical stage delay (FO4) — reciprocal of frequency.
    pub stage_delay_fo4: f64,
}

impl DesignPoint {
    /// True if `self` dominates `other` (≤ in every objective, < in one).
    pub fn dominates(&self, other: &DesignPoint) -> bool {
        let le = self.max_err <= other.max_err
            && self.area_ge <= other.area_ge
            && self.latency_cycles <= other.latency_cycles;
        let lt = self.max_err < other.max_err
            || self.area_ge < other.area_ge
            || self.latency_cycles < other.latency_cycles;
        le && lt
    }
}

/// Extracts the non-dominated subset, sorted by error.
pub fn pareto_frontier(points: &[DesignPoint]) -> Vec<DesignPoint> {
    let mut frontier: Vec<DesignPoint> = points
        .iter()
        .filter(|p| !points.iter().any(|q| q.dominates(p)))
        .cloned()
        .collect();
    frontier.sort_by(|a, b| a.max_err.partial_cmp(&b.max_err).unwrap());
    frontier
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pt(err: f64, area: f64, lat: u32) -> DesignPoint {
        DesignPoint {
            spec: MethodSpec::table1(MethodId::Pwl),
            id: MethodId::Pwl,
            param: 0.0,
            max_err: err,
            rms: err / 3.0,
            area_ge: area,
            latency_cycles: lat,
            stage_delay_fo4: 10.0,
        }
    }

    #[test]
    fn dominated_points_removed() {
        let points = vec![
            pt(1e-5, 100.0, 5),
            pt(1e-5, 200.0, 5), // dominated (more area, same rest)
            pt(1e-4, 50.0, 5),  // frontier (cheaper)
            pt(1e-6, 500.0, 10), // frontier (more accurate)
        ];
        let f = pareto_frontier(&points);
        assert_eq!(f.len(), 3);
        assert!(f.iter().all(|p| p.area_ge != 200.0));
        // sorted by error ascending
        assert!(f.windows(2).all(|w| w[0].max_err <= w[1].max_err));
    }

    #[test]
    fn identical_points_both_survive() {
        // Neither strictly dominates the other.
        let points = vec![pt(1e-5, 100.0, 5), pt(1e-5, 100.0, 5)];
        assert_eq!(pareto_frontier(&points).len(), 2);
    }
}
