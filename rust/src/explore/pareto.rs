//! Pareto frontier over a configurable objective set (default:
//! max error × area × latency).

use std::cmp::Ordering;

use crate::approx::{MethodId, MethodSpec};
use crate::backend::CostSource;

/// One evaluated design: a named design point ([`MethodSpec`]) with
/// its measured error and hardware cost. `id`/`param` are derived from
/// the spec and kept as columns for the table renderers. The cost
/// columns come from a [`crate::backend::CostProbe`] — `cost_source`
/// says whether they are the analytic §IV model or measurements off
/// the lowered pipeline.
#[derive(Clone, Debug)]
pub struct DesignPoint {
    /// The full design-point name (method × parameter × I/O × domain) —
    /// paste it into `tanh-vlsi sweep/serve --spec` to reproduce.
    pub spec: MethodSpec,
    /// Method.
    pub id: MethodId,
    /// Tunable parameter (step/threshold/K).
    pub param: f64,
    /// Exhaustive max abs error on the analysis grid.
    pub max_err: f64,
    /// RMS error.
    pub rms: f64,
    /// Area in gate equivalents (priced inventory, or the unit library
    /// summed over the lowered pipeline's instantiated units).
    pub area_ge: f64,
    /// Pipeline latency in cycles (inventory stages, or the lowered
    /// pipeline's actual depth).
    pub latency_cycles: u32,
    /// Critical stage delay (FO4) — reciprocal of frequency.
    pub stage_delay_fo4: f64,
    /// Steady-state cycles per element: 1.0 assumed by the analytic
    /// model, measured by streaming a warm batch on the hw backend.
    pub cycles_per_element: f64,
    /// Where the cost columns came from (`analytic` | `measured`).
    pub cost_source: CostSource,
}

impl DesignPoint {
    /// True if `self` dominates `other` on the default objective set
    /// (≤ in every objective, < in one).
    pub fn dominates(&self, other: &DesignPoint) -> bool {
        dominates_by(self, other, &Objective::DEFAULT)
    }
}

/// One minimized axis of the exploration (`--objectives` grammar:
/// a comma-separated subset of the [`Objective::NAMES`] spellings,
/// e.g. `err,cycles,area`).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Objective {
    /// Max abs error (`err`).
    MaxErr,
    /// RMS error (`rms`).
    Rms,
    /// Area in GE (`area`).
    Area,
    /// Pipeline latency in cycles (`cycles`).
    Cycles,
    /// Steady-state cycles per element (`cyc/elt`).
    CyclesPerElement,
    /// Critical stage delay in FO4 (`delay`).
    Delay,
}

impl Objective {
    /// The classic frontier axes (error × area × latency).
    pub const DEFAULT: [Objective; 3] = [Objective::MaxErr, Objective::Area, Objective::Cycles];

    /// Canonical CLI spellings, in enum order.
    pub const NAMES: [&'static str; 6] = ["err", "rms", "area", "cycles", "cyc/elt", "delay"];

    /// The axis value of a design point (all objectives minimize).
    pub fn value(self, p: &DesignPoint) -> f64 {
        match self {
            Objective::MaxErr => p.max_err,
            Objective::Rms => p.rms,
            Objective::Area => p.area_ge,
            Objective::Cycles => p.latency_cycles as f64,
            Objective::CyclesPerElement => p.cycles_per_element,
            Objective::Delay => p.stage_delay_fo4,
        }
    }

    /// Canonical spelling.
    pub fn name(self) -> &'static str {
        match self {
            Objective::MaxErr => "err",
            Objective::Rms => "rms",
            Objective::Area => "area",
            Objective::Cycles => "cycles",
            Objective::CyclesPerElement => "cyc/elt",
            Objective::Delay => "delay",
        }
    }

    /// Parses one axis name (accepts a few aliases).
    pub fn parse(s: &str) -> Option<Objective> {
        match s.to_ascii_lowercase().as_str() {
            "err" | "maxerr" | "max-err" => Some(Objective::MaxErr),
            "rms" => Some(Objective::Rms),
            "area" => Some(Objective::Area),
            "cycles" | "lat" | "latency" => Some(Objective::Cycles),
            "cyc/elt" | "cpe" | "cycles-per-element" => Some(Objective::CyclesPerElement),
            "delay" | "fo4" => Some(Objective::Delay),
            _ => None,
        }
    }

    /// Parses a comma-separated objective list (the `--objectives`
    /// argument); duplicates are dropped, an empty list is an error.
    pub fn parse_list(s: &str) -> Result<Vec<Objective>, String> {
        let mut out = Vec::new();
        for part in s.split(',').map(str::trim).filter(|p| !p.is_empty()) {
            let o = Objective::parse(part).ok_or_else(|| {
                format!("unknown objective '{part}' (have: {})", Objective::NAMES.join("|"))
            })?;
            if !out.contains(&o) {
                out.push(o);
            }
        }
        if out.is_empty() {
            return Err(format!("--objectives needs at least one of {}", Objective::NAMES.join("|")));
        }
        Ok(out)
    }
}

/// True if `a` dominates `b` over the given axes: ≤ everywhere, < on
/// at least one. A constant axis contributes nothing (never blocks,
/// never strictly wins), so dominance degrades gracefully to the
/// remaining axes.
pub fn dominates_by(a: &DesignPoint, b: &DesignPoint, objectives: &[Objective]) -> bool {
    let mut strictly = false;
    for o in objectives {
        let (va, vb) = (o.value(a), o.value(b));
        if va > vb {
            return false;
        }
        if va < vb {
            strictly = true;
        }
    }
    strictly
}

/// Extracts the non-dominated subset over an explicit objective set,
/// sorted by the first objective (remaining axes break ties).
pub fn pareto_frontier_by(points: &[DesignPoint], objectives: &[Objective]) -> Vec<DesignPoint> {
    let mut frontier: Vec<DesignPoint> = points
        .iter()
        .filter(|p| !points.iter().any(|q| dominates_by(q, p, objectives)))
        .cloned()
        .collect();
    frontier.sort_by(|a, b| {
        for o in objectives {
            match o.value(a).partial_cmp(&o.value(b)).unwrap_or(Ordering::Equal) {
                Ordering::Equal => continue,
                other => return other,
            }
        }
        Ordering::Equal
    });
    frontier
}

/// Extracts the non-dominated subset over the default axes
/// (error × area × latency), sorted by error.
pub fn pareto_frontier(points: &[DesignPoint]) -> Vec<DesignPoint> {
    pareto_frontier_by(points, &Objective::DEFAULT)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pt(err: f64, area: f64, lat: u32) -> DesignPoint {
        DesignPoint {
            spec: MethodSpec::table1(MethodId::Pwl),
            id: MethodId::Pwl,
            param: 0.0,
            max_err: err,
            rms: err / 3.0,
            area_ge: area,
            latency_cycles: lat,
            stage_delay_fo4: 10.0,
            cycles_per_element: 1.0,
            cost_source: CostSource::Analytic,
        }
    }

    #[test]
    fn dominated_points_removed() {
        let points = vec![
            pt(1e-5, 100.0, 5),
            pt(1e-5, 200.0, 5), // dominated (more area, same rest)
            pt(1e-4, 50.0, 5),  // frontier (cheaper)
            pt(1e-6, 500.0, 10), // frontier (more accurate)
        ];
        let f = pareto_frontier(&points);
        assert_eq!(f.len(), 3);
        assert!(f.iter().all(|p| p.area_ge != 200.0));
        // sorted by error ascending
        assert!(f.windows(2).all(|w| w[0].max_err <= w[1].max_err));
    }

    #[test]
    fn identical_points_both_survive() {
        // Neither strictly dominates the other.
        let points = vec![pt(1e-5, 100.0, 5), pt(1e-5, 100.0, 5)];
        assert_eq!(pareto_frontier(&points).len(), 2);
    }

    #[test]
    fn objective_subset_changes_the_frontier() {
        // On (err, area) the slow-but-small point joins the frontier;
        // on (err, cycles) it is dominated.
        let points = vec![
            pt(1e-5, 100.0, 5),
            pt(1e-5, 50.0, 20), // smaller but slower
        ];
        let ea = pareto_frontier_by(&points, &[Objective::MaxErr, Objective::Area]);
        assert_eq!(ea.len(), 1);
        assert_eq!(ea[0].area_ge, 50.0);
        let ec = pareto_frontier_by(&points, &[Objective::MaxErr, Objective::Cycles]);
        assert_eq!(ec.len(), 1);
        assert_eq!(ec[0].latency_cycles, 5);
        let both = pareto_frontier_by(
            &points,
            &[Objective::MaxErr, Objective::Area, Objective::Cycles],
        );
        assert_eq!(both.len(), 2);
    }

    #[test]
    fn constant_axis_degrades_gracefully() {
        // err is constant across the set: the frontier is decided by
        // the remaining axes alone.
        let points = vec![pt(1e-5, 100.0, 5), pt(1e-5, 50.0, 5), pt(1e-5, 60.0, 4)];
        let f = pareto_frontier_by(&points, &[Objective::MaxErr, Objective::Area, Objective::Cycles]);
        assert_eq!(f.len(), 2);
        assert!(f.iter().all(|p| p.area_ge != 100.0));
    }

    #[test]
    fn objective_grammar_parses_and_rejects() {
        assert_eq!(
            Objective::parse_list("err,cycles,area").unwrap(),
            vec![Objective::MaxErr, Objective::Cycles, Objective::Area]
        );
        // Aliases, case, duplicates, stray commas.
        assert_eq!(
            Objective::parse_list("ERR, latency,, err,cpe").unwrap(),
            vec![Objective::MaxErr, Objective::Cycles, Objective::CyclesPerElement]
        );
        let err = Objective::parse_list("err,wattage").unwrap_err();
        assert!(err.contains("wattage") && err.contains("cyc/elt"), "{err}");
        assert!(Objective::parse_list(" , ").is_err());
        // Round trip: every canonical name parses back to itself.
        for (name, o) in Objective::NAMES.iter().zip([
            Objective::MaxErr,
            Objective::Rms,
            Objective::Area,
            Objective::Cycles,
            Objective::CyclesPerElement,
            Objective::Delay,
        ]) {
            assert_eq!(Objective::parse(name), Some(o));
            assert_eq!(o.name(), *name);
        }
    }
}
