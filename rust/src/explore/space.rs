//! The sweep itself: evaluate design-point specs against error and
//! hardware cost.
//!
//! The sweep space is spec-shaped: (method × Fig 2 parameter × output
//! format) — output-format variation is new with the spec API; the old
//! `(id, f64)` sweep could not express it. Exhaustive error
//! measurement resolves kernels through the shared
//! [`Registry`](crate::approx::Registry) cache, so a configuration
//! that Fig 2 (or an earlier explore) already measured is not
//! recompiled.

use super::pareto::DesignPoint;
use crate::approx::compiled::worker_threads;
use crate::approx::{IoSpec, MethodId, MethodSpec, Registry};
use crate::backend::{analytic_cost, CostProbe, GoldenBackend};
use crate::error::{fig2_params, measure_kernel_with_threads, measure_strided, InputGrid};
use crate::fixed::QFormat;

/// Exploration configuration.
#[derive(Clone, Debug)]
pub struct ExploreConfig {
    /// Input grid (domain + precision).
    pub grid: InputGrid,
    /// Output formats to sweep (each parameter point is measured once
    /// per output format). Default: `[S.15]`, the paper's column.
    pub outputs: Vec<QFormat>,
    /// Grid stride (>1 subsamples for speed; 1 = exhaustive).
    pub stride: usize,
}

impl Default for ExploreConfig {
    fn default() -> Self {
        ExploreConfig { grid: InputGrid::table1(), outputs: vec![QFormat::S_15], stride: 1 }
    }
}

/// The design points an [`ExploreConfig`] sweeps: every method over
/// its Fig 2 parameter range × every configured output format.
pub fn sweep_specs(cfg: &ExploreConfig) -> Vec<MethodSpec> {
    let domain = cfg.grid.range.unwrap_or(cfg.grid.fmt.max_value());
    let mut specs = Vec::new();
    for id in MethodId::all() {
        let (_, params) = fig2_params(id);
        for param in params {
            for &out in &cfg.outputs {
                let io = IoSpec { input: cfg.grid.fmt, output: out };
                // Parameters the grid cannot address (step finer than
                // its ulp) are skipped, not panicked on — a coarse
                // exploration grid just has fewer points per method.
                if let Ok(spec) = MethodSpec::with_param(id, param, io, domain) {
                    specs.push(spec);
                }
            }
        }
    }
    specs
}

/// Sweeps every method over its Fig 2 parameter range (× every
/// configured output format), measuring error and pricing the
/// inventory with the analytic §IV model.
pub fn explore(cfg: ExploreConfig) -> Vec<DesignPoint> {
    let specs = sweep_specs(&cfg);
    explore_specs(&specs, cfg.stride)
}

/// Evaluates an explicit list of design points (the `--spec` path of
/// `tanh-vlsi explore`) with the analytic §IV cost model — a thin
/// wrapper over [`explore_specs_probed`] with the golden backend's
/// probe, byte-identical to the pre-probe explorer's numbers.
pub fn explore_specs(specs: &[MethodSpec], stride: usize) -> Vec<DesignPoint> {
    explore_specs_probed(specs, stride, &GoldenBackend::new())
        .expect("the analytic probe prices every valid spec")
}

/// Evaluates an explicit list of design points, resolving the cost
/// columns through a [`CostProbe`]: the golden backend answers with
/// the analytic §IV model, the hw backend with measurements off the
/// lowered pipeline (`explore --backend hw`). Error metrics always
/// come from exhaustive/strided sweeps of the golden kernels —
/// backends are bit-exact, so there is nothing backend-specific to
/// measure on the error axis; exhaustive sweeps ride the shared kernel
/// cache, sparse strides stay on the scalar path (compiling would cost
/// more than the subsampled sweep saves).
///
/// A spec the probe cannot express (`unknown_spec`) falls back to the
/// analytic model **labeled as such**: the point's
/// [`DesignPoint::cost_source`] reports
/// [`crate::backend::CostSource::Analytic`], so a frontier mixing
/// measured and fallback rows can never pass the fallback off as a
/// measurement. Any *other* probe failure — above all the hw backend's
/// lowering-audit divergence (`internal`) — is a real defect, not a
/// coverage gap, and aborts the exploration instead of being masked as
/// an analytic row.
///
/// Note the hw probe's cost: its `ensure` compiles each spec's golden
/// kernel for the lowering audit, so a sparse-stride hw exploration
/// pays one compile per spec that the pure analytic path avoids —
/// that is the price of never measuring an unaudited datapath.
pub fn explore_specs_probed(
    specs: &[MethodSpec],
    stride: usize,
    probe: &dyn CostProbe,
) -> Result<Vec<DesignPoint>, String> {
    specs
        .iter()
        .map(|&spec| {
            let grid = InputGrid::ranged(spec.io.input, spec.domain);
            let m = spec.build();
            let e = if stride <= 1 {
                let kernel = Registry::global().kernel(&spec);
                measure_kernel_with_threads(&kernel, grid, worker_threads())
            } else {
                measure_strided(m.as_ref(), grid, spec.io.output, stride)
            };
            let cost = match probe.probe_cost(&spec) {
                Ok(cost) => cost,
                // Typed fallback (satellite fix): unsupported specs are
                // costed analytically and *labeled* analytic — never
                // silently mixed in as measured. The spec built above,
                // so it is structurally valid and the analytic model
                // always prices it.
                Err(e) if e.code == crate::backend::ErrorCode::UnknownSpec => {
                    analytic_cost(&spec).expect("explore specs are validated")
                }
                Err(e) => return Err(format!("probing cost of '{spec}': {e}")),
            };
            Ok(DesignPoint {
                spec,
                id: spec.method_id(),
                param: spec.param(),
                max_err: e.max_abs,
                rms: e.rms,
                area_ge: cost.area_ge,
                latency_cycles: cost.latency_cycles,
                stage_delay_fo4: cost.stage_delay_fo4,
                cycles_per_element: cost.cycles_per_element,
                cost_source: cost.source,
            })
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::CostSource;
    use crate::explore::pareto_frontier;

    fn quick_cfg() -> ExploreConfig {
        ExploreConfig {
            grid: InputGrid::ranged(QFormat::new(3, 8), 6.0),
            outputs: vec![QFormat::S_15],
            stride: 1,
        }
    }

    #[test]
    fn explores_all_methods() {
        let points = explore(quick_cfg());
        assert!(points.len() >= 30);
        for id in MethodId::all() {
            assert!(points.iter().any(|p| p.id == id), "{id:?} missing");
        }
        // Every point is addressable: its spec round-trips and agrees
        // with the derived columns.
        for p in &points {
            assert_eq!(MethodSpec::parse(&p.spec.to_string()).unwrap(), p.spec);
            assert_eq!(p.id, p.spec.method_id());
            assert_eq!(p.param, p.spec.param());
        }
    }

    #[test]
    fn output_format_variation_expands_the_space() {
        // The spec API's new axis: the same parameter grid measured at
        // two output precisions doubles the point count, and a
        // fine-step configuration is dominated by the output
        // quantization floor — visible only because output format is
        // now part of the swept space.
        let mut cfg = quick_cfg();
        let single = explore(cfg.clone());
        cfg.outputs = vec![QFormat::S_15, QFormat::S_7];
        let double = explore(cfg);
        assert_eq!(double.len(), 2 * single.len());
        let pwl_fine = |out: QFormat| {
            double
                .iter()
                .find(|p| {
                    p.id == MethodId::Pwl
                        && p.param == 1.0 / 256.0
                        && p.spec.io.output == out
                })
                .expect("PWL 1/256 point")
                .max_err
        };
        // ½ S.7 ulp ≈ 3.9e-3 vs ½ S.15 ulp ≈ 1.5e-5: the 7-bit output
        // floor towers over the fine PWL's algorithmic error.
        assert!(
            pwl_fine(QFormat::S_7) > 10.0 * pwl_fine(QFormat::S_15),
            "S.7 {} vs S.15 {}",
            pwl_fine(QFormat::S_7),
            pwl_fine(QFormat::S_15)
        );
    }

    #[test]
    fn explore_specs_evaluates_an_explicit_list() {
        let specs = vec![
            MethodSpec::parse("pwl:step=1/16:in=s3.8:out=s.15").unwrap(),
            MethodSpec::parse("lambert:terms=4:in=s3.8:out=s.15").unwrap(),
        ];
        let points = explore_specs(&specs, 1);
        assert_eq!(points.len(), 2);
        assert_eq!(points[0].spec, specs[0]);
        assert!(points[0].max_err > 0.0 && points[0].area_ge > 0.0);
        // The default (golden) probe is the analytic §IV model.
        assert!(points.iter().all(|p| p.cost_source == CostSource::Analytic));
        assert!(points.iter().all(|p| p.cycles_per_element == 1.0));
    }

    #[test]
    fn hw_probe_yields_measured_points_with_lowered_depths() {
        use crate::backend::HwBackend;
        use crate::hw::pipeline_for;
        let specs = vec![
            MethodSpec::parse("pwl:step=1/16").unwrap(),
            MethodSpec::parse("velocity:threshold=1/32").unwrap(),
        ];
        let hw = HwBackend::new();
        let points = explore_specs_probed(&specs, 16, &hw).unwrap();
        let analytic = explore_specs(&specs, 16);
        for (p, a) in points.iter().zip(&analytic) {
            assert_eq!(p.cost_source, CostSource::Measured, "{}", p.spec);
            // Latency/critical path come from the lowered pipeline,
            // not the inventory model.
            let pipe = pipeline_for(&p.spec).unwrap();
            assert_eq!(p.latency_cycles as usize, pipe.latency(), "{}", p.spec);
            // Error metrics are probe-independent (same golden sweep).
            assert_eq!(p.max_err, a.max_err, "{}", p.spec);
            assert_eq!(p.rms, a.rms, "{}", p.spec);
            // Measured steady-state throughput: one result per cycle.
            assert_eq!(p.cycles_per_element, 1.0, "{}", p.spec);
        }
    }

    #[test]
    fn unsupported_specs_fall_back_labeled_analytic_not_mislabeled() {
        use crate::backend::{BackendError, DesignCost};
        // A probe that measures PWL but rejects everything else — the
        // shape of a backend that cannot express part of the space.
        struct PwlOnlyProbe;
        impl CostProbe for PwlOnlyProbe {
            fn probe_cost(&self, spec: &MethodSpec) -> Result<DesignCost, BackendError> {
                if spec.method_id() != MethodId::Pwl {
                    return Err(BackendError::unknown_spec(format!(
                        "spec '{spec}' unsupported by this probe"
                    )));
                }
                Ok(DesignCost { source: CostSource::Measured, ..analytic_cost(spec)? })
            }
        }
        let specs = vec![
            MethodSpec::parse("pwl:step=1/16:in=s3.8:out=s.15").unwrap(),
            MethodSpec::parse("lambert:terms=4:in=s3.8:out=s.15").unwrap(),
        ];
        let points = explore_specs_probed(&specs, 4, &PwlOnlyProbe).unwrap();
        assert_eq!(points[0].cost_source, CostSource::Measured);
        // The unsupported spec is still explored, costed analytically,
        // and says so — the silent-fallback bug this guards against
        // would label it Measured.
        assert_eq!(points[1].cost_source, CostSource::Analytic);
        let analytic = explore_specs(&specs[1..], 4);
        assert_eq!(points[1].area_ge, analytic[0].area_ge);
        assert_eq!(points[1].latency_cycles, analytic[0].latency_cycles);

        // Only unknown_spec may fall back: a probe failing with any
        // other code (the shape of an hw lowering-audit divergence)
        // aborts the exploration instead of masquerading as analytic.
        struct BrokenProbe;
        impl CostProbe for BrokenProbe {
            fn probe_cost(&self, spec: &MethodSpec) -> Result<DesignCost, BackendError> {
                Err(BackendError::internal(format!("lowering of '{spec}' diverges")))
            }
        }
        let err = explore_specs_probed(&specs, 4, &BrokenProbe).unwrap_err();
        assert!(err.contains("probing cost"), "{err}");
        assert!(err.contains("diverges"), "{err}");
    }

    #[test]
    fn frontier_reflects_paper_iv_h() {
        // §IV.H: "For reasonable accuracy, the polynomial approximation
        // such as PWL and Taylor series expansion yield good results" —
        // the low-latency end of the frontier must be polynomial, and
        // the frontier must include at least one Taylor point.
        let points = explore(quick_cfg());
        let frontier = pareto_frontier(&points);
        assert!(!frontier.is_empty());
        let min_latency = frontier.iter().min_by_key(|p| p.latency_cycles).unwrap();
        assert!(
            matches!(
                min_latency.id,
                MethodId::Pwl | MethodId::TaylorQuadratic | MethodId::TaylorCubic
                    | MethodId::CatmullRom
            ),
            "lowest-latency frontier point is {:?}",
            min_latency.id
        );
    }

    #[test]
    fn strided_measure_close_to_full() {
        use crate::error::measure;
        let cfg = quick_cfg();
        let m = crate::approx::pwl::Pwl::table1();
        let full = measure(&m, cfg.grid, cfg.outputs[0]);
        let strided = measure_strided(&m, cfg.grid, cfg.outputs[0], 7);
        assert!((full.max_abs - strided.max_abs).abs() < full.max_abs * 0.5);
    }
}
