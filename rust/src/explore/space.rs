//! The sweep itself: evaluate (method × parameter) against error and
//! hardware cost.

use super::pareto::DesignPoint;
use crate::approx::{build, IoSpec, MethodId};
use crate::cost::CostModel;
use crate::error::{fig2_params, measure, measure_strided, InputGrid};
use crate::fixed::QFormat;

/// Exploration configuration.
#[derive(Clone, Copy, Debug)]
pub struct ExploreConfig {
    /// Input grid (domain + precision).
    pub grid: InputGrid,
    /// Output format.
    pub out: QFormat,
    /// Grid stride (>1 subsamples for speed; 1 = exhaustive).
    pub stride: usize,
}

impl Default for ExploreConfig {
    fn default() -> Self {
        ExploreConfig { grid: InputGrid::table1(), out: QFormat::S_15, stride: 1 }
    }
}

/// Sweeps every method over its Fig 2 parameter range, measuring error
/// and pricing the inventory.
pub fn explore(cfg: ExploreConfig) -> Vec<DesignPoint> {
    let io = IoSpec { input: cfg.grid.fmt, output: cfg.out };
    let model = CostModel::new();
    let domain = cfg.grid.range.unwrap_or(cfg.grid.fmt.max_value());
    let mut points = Vec::new();
    for id in MethodId::all() {
        let (_, params) = fig2_params(id);
        for param in params {
            let m = build(id, param, domain);
            // Exhaustive mode rides the compiled-kernel parallel sweep;
            // sparse strides stay on the scalar path (compiling would
            // cost more than the subsampled sweep saves).
            let e = if cfg.stride <= 1 {
                measure(m.as_ref(), cfg.grid, cfg.out)
            } else {
                measure_strided(m.as_ref(), cfg.grid, cfg.out, cfg.stride)
            };
            let inv = m.inventory(io);
            let cost = model.price(&inv);
            points.push(DesignPoint {
                id,
                param,
                max_err: e.max_abs,
                rms: e.rms,
                area_ge: cost.area_ge,
                latency_cycles: inv.pipeline_stages.max(1),
                stage_delay_fo4: cost.stage_delay_fo4,
            });
        }
    }
    points
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::explore::pareto_frontier;

    fn quick_cfg() -> ExploreConfig {
        ExploreConfig {
            grid: InputGrid::ranged(QFormat::new(3, 8), 6.0),
            out: QFormat::S_15,
            stride: 1,
        }
    }

    #[test]
    fn explores_all_methods() {
        let points = explore(quick_cfg());
        assert!(points.len() >= 30);
        for id in MethodId::all() {
            assert!(points.iter().any(|p| p.id == id), "{id:?} missing");
        }
    }

    #[test]
    fn frontier_reflects_paper_iv_h() {
        // §IV.H: "For reasonable accuracy, the polynomial approximation
        // such as PWL and Taylor series expansion yield good results" —
        // the low-latency end of the frontier must be polynomial, and
        // the frontier must include at least one Taylor point.
        let points = explore(quick_cfg());
        let frontier = pareto_frontier(&points);
        assert!(!frontier.is_empty());
        let min_latency = frontier.iter().min_by_key(|p| p.latency_cycles).unwrap();
        assert!(
            matches!(
                min_latency.id,
                MethodId::Pwl | MethodId::TaylorQuadratic | MethodId::TaylorCubic
                    | MethodId::CatmullRom
            ),
            "lowest-latency frontier point is {:?}",
            min_latency.id
        );
    }

    #[test]
    fn strided_measure_close_to_full() {
        let cfg = quick_cfg();
        let m = crate::approx::pwl::Pwl::table1();
        let full = measure(&m, cfg.grid, cfg.out);
        let strided = measure_strided(&m, cfg.grid, cfg.out, 7);
        assert!((full.max_abs - strided.max_abs).abs() < full.max_abs * 0.5);
    }
}
