//! Signed Q-format descriptors.

use std::fmt;

/// A signed fixed-point format: one sign bit, `int_bits` integer bits and
/// `frac_bits` fractional bits, two's complement, total width
/// `1 + int_bits + frac_bits`.
///
/// The paper writes these as `S<int>.<frac>` — e.g. `S3.12` is a 16-bit
/// word holding values in `[-8, 8)` with resolution `2^-12`; `S.15` is a
/// 16-bit fraction-only word holding `[-1, 1)` with resolution `2^-15`.
#[derive(Clone, Copy, PartialEq, Eq, Hash)]
pub struct QFormat {
    /// Number of integer (magnitude) bits, excluding the sign bit.
    pub int_bits: u32,
    /// Number of fractional bits.
    pub frac_bits: u32,
}

impl QFormat {
    /// `S2.13`: 16-bit, range (-4, 4), resolution 2^-13 (paper Table III rows 1-2).
    pub const S2_13: QFormat = QFormat::new(2, 13);
    /// `S3.12`: 16-bit, range (-8, 8), resolution 2^-12 (paper Table I / §IV.A).
    pub const S3_12: QFormat = QFormat::new(3, 12);
    /// `S.15`: 16-bit fraction-only output format, resolution 2^-15.
    pub const S_15: QFormat = QFormat::new(0, 15);
    /// `S2.5`: 8-bit input format of Table III row 4.
    pub const S2_5: QFormat = QFormat::new(2, 5);
    /// `S.7`: 8-bit fraction-only output format of Table III row 4.
    pub const S_7: QFormat = QFormat::new(0, 7);
    /// `S4.11`: 16-bit wide-range format used by internal VF datapaths.
    pub const S4_11: QFormat = QFormat::new(4, 11);
    /// `S7.24`: 32-bit extended internal format for rational intermediates
    /// (the paper's "larger multipliers" remark in §IV.H).
    pub const S7_24: QFormat = QFormat::new(7, 24);

    /// Builds a format with the given integer/fraction widths.
    pub const fn new(int_bits: u32, frac_bits: u32) -> Self {
        QFormat { int_bits, frac_bits }
    }

    /// Total word width in bits, including the sign bit.
    pub const fn width(&self) -> u32 {
        1 + self.int_bits + self.frac_bits
    }

    /// Largest representable raw value: `2^(int+frac) - 1`.
    pub const fn max_raw(&self) -> i64 {
        (1i64 << (self.int_bits + self.frac_bits)) - 1
    }

    /// Smallest representable raw value: `-2^(int+frac)`.
    pub const fn min_raw(&self) -> i64 {
        -(1i64 << (self.int_bits + self.frac_bits))
    }

    /// Value of one least-significant bit, `2^-frac_bits`.
    ///
    /// Constructed directly from the IEEE-754 exponent bits — this is
    /// on the `Fx::to_f64` hot path, where `powi` showed up at ~4% of
    /// the exhaustive-sweep profile (EXPERIMENTS.md §Perf iter 4).
    #[inline]
    pub fn ulp(&self) -> f64 {
        debug_assert!(self.frac_bits < 1023);
        f64::from_bits((1023 - self.frac_bits as u64) << 52)
    }

    /// Largest representable value as f64: `2^int - 2^-frac`.
    pub fn max_value(&self) -> f64 {
        self.max_raw() as f64 * self.ulp()
    }

    /// Smallest representable value as f64: `-2^int`.
    pub fn min_value(&self) -> f64 {
        self.min_raw() as f64 * self.ulp()
    }

    /// The largest |x| for which tanh(x) is still distinguishable from the
    /// saturated output in this output format: `atanh(1 - 2^-frac)`.
    ///
    /// Paper §III.A: beyond this the error of simply emitting the max
    /// representable value is below one LSB. For S.15 this is ±5.55;
    /// for S.7 it is ±2.77.
    pub fn tanh_saturation_domain(&self) -> f64 {
        let b = 1.0 - self.ulp();
        // atanh(b) = 0.5 * ln((1+b)/(1-b))
        0.5 * ((1.0 + b) / (1.0 - b)).ln()
    }

    /// Parses `"S3.12"` / `"s.15"`-style names.
    pub fn parse(s: &str) -> Option<QFormat> {
        let s = s.trim();
        let rest = s.strip_prefix('S').or_else(|| s.strip_prefix('s'))?;
        let (int_part, frac_part) = rest.split_once('.')?;
        let int_bits: u32 = if int_part.is_empty() { 0 } else { int_part.parse().ok()? };
        let frac_bits: u32 = frac_part.parse().ok()?;
        if frac_bits == 0 || int_bits + frac_bits + 1 > 63 {
            return None;
        }
        Some(QFormat::new(int_bits, frac_bits))
    }
}

impl fmt::Display for QFormat {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if self.int_bits == 0 {
            write!(f, "S.{}", self.frac_bits)
        } else {
            write!(f, "S{}.{}", self.int_bits, self.frac_bits)
        }
    }
}

impl fmt::Debug for QFormat {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        fmt::Display::fmt(self, f)
    }
}
