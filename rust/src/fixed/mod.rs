//! Fixed-point (Q-format) arithmetic substrate.
//!
//! The paper's entire analysis is phrased in signed fixed-point formats:
//! `S3.12` (1 sign + 3 integer + 12 fraction bits = 16-bit input used for
//! Table I), `S2.13`, `S.15` (fraction-only 16-bit output), `S2.5` and
//! `S.7` (8-bit formats of Table III). This module provides:
//!
//! - [`QFormat`] — a signed Q-format descriptor (integer/fraction widths),
//! - [`Fx`] — a raw-integer fixed-point value tagged with its format,
//! - [`Round`] — the rounding modes hardware datapaths actually use,
//! - saturating arithmetic that models what a synthesized datapath does
//!   on overflow (clamp to the format's min/max rather than wrap).
//!
//! All datapath golden models in [`crate::approx`] are built exclusively
//! from these primitives so that the rust model, the Pallas kernel (which
//! emulates the same ops with int32 words) and a hypothetical RTL
//! implementation agree bit-for-bit.

mod format;
mod ops;
mod round;
mod value;

pub use format::QFormat;
pub use ops::{fx_add, fx_mul, fx_mul_wide, fx_sub, FxWide};
pub use round::Round;
pub use value::Fx;

#[cfg(test)]
mod tests;
