//! Saturating fixed-point arithmetic primitives.
//!
//! These model the arithmetic blocks a synthesized datapath is built
//! from: same-format saturating adders and format-aware multipliers whose
//! product is renormalized (shifted + rounded) back into a destination
//! format. Wide intermediates use `i128` so no host-side overflow can
//! hide a modelling bug.

use super::{Fx, QFormat, Round};

/// A double-width product before renormalization: `raw * 2^-frac`.
///
/// Exposed so datapath models can keep full precision across a
/// multiply-accumulate chain and round exactly once — the paper's MAC
/// blocks (Catmull-Rom §IV.D) do this.
#[derive(Clone, Copy, Debug)]
pub struct FxWide {
    /// Full-precision raw value.
    pub raw: i128,
    /// Fractional bits of `raw`.
    pub frac: u32,
}

impl FxWide {
    /// Wraps an `Fx` without any precision change.
    #[inline]
    pub fn from_fx(v: Fx) -> FxWide {
        FxWide { raw: v.raw() as i128, frac: v.format().frac_bits }
    }

    /// Exact wide addition; operands are aligned to the larger fraction.
    #[inline]
    pub fn add(self, other: FxWide) -> FxWide {
        let frac = self.frac.max(other.frac);
        let a = self.raw << (frac - self.frac);
        let b = other.raw << (frac - other.frac);
        FxWide { raw: a + b, frac }
    }

    /// Exact wide multiplication (fractions add).
    #[inline]
    pub fn mul(self, other: FxWide) -> FxWide {
        FxWide { raw: self.raw * other.raw, frac: self.frac + other.frac }
    }

    /// Renormalizes into `dst`, rounding once and saturating.
    #[inline]
    pub fn narrow(self, dst: QFormat, round: Round) -> Fx {
        let raw = if self.frac >= dst.frac_bits {
            round.shift_right(self.raw, self.frac - dst.frac_bits)
        } else {
            self.raw << (dst.frac_bits - self.frac)
        };
        let raw = raw.clamp(dst.min_raw() as i128, dst.max_raw() as i128) as i64;
        Fx::from_raw_unchecked(raw, dst)
    }

    /// Exact value as f64 (may lose precision past 2^53 — fine for
    /// debugging, never used in the datapath).
    pub fn to_f64(self) -> f64 {
        self.raw as f64 * (2f64).powi(-(self.frac as i32))
    }
}

/// Saturating same-format addition: the paper's "adder" block.
/// Operands in different formats are first aligned to `dst`.
#[inline]
pub fn fx_add(a: Fx, b: Fx, dst: QFormat, round: Round) -> Fx {
    let a = a.convert(dst, round);
    let b = b.convert(dst, round);
    Fx::from_raw(a.raw() + b.raw(), dst)
}

/// Saturating subtraction `a - b` into `dst`.
#[inline]
pub fn fx_sub(a: Fx, b: Fx, dst: QFormat, round: Round) -> Fx {
    let a = a.convert(dst, round);
    let b = b.convert(dst, round);
    Fx::from_raw(a.raw() - b.raw(), dst)
}

/// Fixed-point multiplication with single renormalization into `dst`:
/// the paper's "multiplier" block.
#[inline]
pub fn fx_mul(a: Fx, b: Fx, dst: QFormat, round: Round) -> Fx {
    FxWide::from_fx(a).mul(FxWide::from_fx(b)).narrow(dst, round)
}

/// Full-precision multiplication kept wide (for MAC chains).
#[inline]
pub fn fx_mul_wide(a: Fx, b: Fx) -> FxWide {
    FxWide::from_fx(a).mul(FxWide::from_fx(b))
}
