//! Rounding modes for fixed-point right shifts and f64 quantization.
//!
//! Hardware datapaths almost never round the IEEE way; the cheap options
//! are truncation (drop LSBs — zero extra gates) and round-half-up (one
//! adder on the guard bit). Round-to-nearest-even is what numpy uses when
//! quantizing, so it is also provided for apples-to-apples comparisons
//! with the python reference pipeline.

/// A rounding rule applied when discarding low-order bits.
#[derive(Clone, Copy, PartialEq, Eq, Debug, Hash, Default)]
pub enum Round {
    /// Truncate toward negative infinity (arithmetic shift right).
    /// Free in hardware; adds a -0.5ulp bias.
    Trunc,
    /// Round half away from zero ("add guard bit then shift").
    /// One extra adder; what the paper's datapaths assume.
    #[default]
    NearestAway,
    /// Round half to even (banker's rounding, numpy `np.round` semantics).
    NearestEven,
}

impl Round {
    /// Shifts `v` right by `sh` bits applying this rounding rule.
    /// `sh == 0` returns `v` unchanged. Works on wide intermediates.
    #[inline]
    pub fn shift_right(self, v: i128, sh: u32) -> i128 {
        if sh == 0 {
            return v;
        }
        match self {
            Round::Trunc => v >> sh,
            Round::NearestAway => {
                let half = 1i128 << (sh - 1);
                if v >= 0 {
                    (v + half) >> sh
                } else {
                    // Round half away from zero for negatives: -x.5 -> -(x+1)
                    -(((-v) + half) >> sh)
                }
            }
            Round::NearestEven => {
                let floor = v >> sh;
                let rem = v - (floor << sh);
                let half = 1i128 << (sh - 1);
                if rem > half || (rem == half && (floor & 1) == 1) {
                    floor + 1
                } else {
                    floor
                }
            }
        }
    }

    /// Rounds an f64 to an integer under this rule.
    #[inline]
    pub fn round_f64(self, v: f64) -> f64 {
        match self {
            Round::Trunc => v.trunc(),
            Round::NearestAway => v.round(), // f64::round is half-away-from-zero
            Round::NearestEven => {
                let r = v.round();
                if (v - v.trunc()).abs() == 0.5 {
                    // Exactly halfway: pick the even neighbour.
                    let f = v.floor();
                    if (f as i64) % 2 == 0 {
                        f
                    } else {
                        f + 1.0
                    }
                } else {
                    r
                }
            }
        }
    }

    /// Human-readable name (used by the CLI / reports).
    pub fn name(self) -> &'static str {
        match self {
            Round::Trunc => "trunc",
            Round::NearestAway => "nearest-away",
            Round::NearestEven => "nearest-even",
        }
    }

    /// Parses a rounding-mode name as accepted by the CLI.
    pub fn parse(s: &str) -> Option<Round> {
        match s {
            "trunc" | "truncate" => Some(Round::Trunc),
            "nearest" | "nearest-away" | "rna" => Some(Round::NearestAway),
            "nearest-even" | "rne" => Some(Round::NearestEven),
            _ => None,
        }
    }
}
