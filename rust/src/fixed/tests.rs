//! Unit + property tests for the fixed-point substrate.

use super::*;
use crate::util::proptest::{prop_check, Prng};

#[test]
fn format_widths_match_paper() {
    assert_eq!(QFormat::S3_12.width(), 16);
    assert_eq!(QFormat::S2_13.width(), 16);
    assert_eq!(QFormat::S_15.width(), 16);
    assert_eq!(QFormat::S2_5.width(), 8);
    assert_eq!(QFormat::S_7.width(), 8);
}

#[test]
fn format_ranges() {
    // S3.12 covers (-8, 8)
    assert_eq!(QFormat::S3_12.min_value(), -8.0);
    assert!((QFormat::S3_12.max_value() - (8.0 - 2f64.powi(-12))).abs() < 1e-15);
    // S.15 covers (-1, 1)
    assert_eq!(QFormat::S_15.min_value(), -1.0);
    assert!((QFormat::S_15.max_value() - (1.0 - 2f64.powi(-15))).abs() < 1e-18);
}

#[test]
fn saturation_domain_matches_paper_section_iii_a() {
    // Paper §III.A: atanh(1 - 2^-b) for b = 7, 11..? It quotes
    // ±2.77 for 8-bit, ±4.16 for 12-bit, ±5.55 for 16-bit fraction-only.
    let d7 = QFormat::S_7.tanh_saturation_domain();
    assert!((d7 - 2.77).abs() < 0.01, "S.7 domain {d7}");
    let d15 = QFormat::S_15.tanh_saturation_domain();
    assert!((d15 - 5.55).abs() < 0.01, "S.15 domain {d15}");
    let d11 = QFormat::new(0, 11).tanh_saturation_domain();
    assert!((d11 - 4.16).abs() < 0.01, "S.11 domain {d11}");
}

#[test]
fn parse_roundtrip() {
    for s in ["S3.12", "S2.13", "S.15", "S2.5", "S.7", "S4.11"] {
        let f = QFormat::parse(s).unwrap();
        assert_eq!(format!("{f}"), s);
    }
    assert!(QFormat::parse("").is_none());
    assert!(QFormat::parse("3.12").is_none());
    assert!(QFormat::parse("S3").is_none());
    assert!(QFormat::parse("S3.0").is_none());
}

#[test]
fn from_f64_quantizes_and_saturates() {
    let f = QFormat::S_15;
    assert_eq!(Fx::from_f64(0.0, f).raw(), 0);
    assert_eq!(Fx::from_f64(1.0, f).raw(), f.max_raw()); // saturates: 1.0 not representable
    assert_eq!(Fx::from_f64(-1.0, f).raw(), f.min_raw());
    assert_eq!(Fx::from_f64(2.0, f).raw(), f.max_raw());
    assert_eq!(Fx::from_f64(0.5, f).raw(), 1 << 14);
}

#[test]
fn rounding_modes_on_halfway() {
    // 2.5 ulp in S.15 context: raw 5 shifted right by 1.
    assert_eq!(Round::Trunc.shift_right(5, 1), 2);
    assert_eq!(Round::NearestAway.shift_right(5, 1), 3);
    assert_eq!(Round::NearestEven.shift_right(5, 1), 2); // 2.5 -> 2 (even)
    assert_eq!(Round::NearestEven.shift_right(7, 1), 4); // 3.5 -> 4 (even)
    // Negative halfway
    assert_eq!(Round::Trunc.shift_right(-5, 1), -3); // floor
    assert_eq!(Round::NearestAway.shift_right(-5, 1), -3); // -2.5 -> -3
    assert_eq!(Round::NearestEven.shift_right(-5, 1), -2); // -2.5 -> -2 (even)
}

#[test]
fn convert_widening_is_exact() {
    let x = Fx::from_f64(0.3, QFormat::S3_12);
    let wide = x.convert(QFormat::S7_24, Round::Trunc);
    assert_eq!(wide.to_f64(), x.to_f64());
    // and converting back loses nothing
    let back = wide.convert(QFormat::S3_12, Round::NearestAway);
    assert_eq!(back.raw(), x.raw());
}

#[test]
fn add_saturates() {
    let f = QFormat::S_15;
    let big = Fx::from_f64(0.9, f);
    let s = fx_add(big, big, f, Round::NearestAway);
    assert_eq!(s.raw(), f.max_raw());
    let neg = Fx::from_f64(-0.9, f);
    let s = fx_add(neg, neg, f, Round::NearestAway);
    assert_eq!(s.raw(), f.min_raw());
}

#[test]
fn mul_basics() {
    let f = QFormat::S3_12;
    let half = Fx::from_f64(0.5, f);
    let q = fx_mul(half, half, f, Round::NearestAway);
    assert_eq!(q.to_f64(), 0.25);
    // sign handling
    let q = fx_mul(half.neg(), half, f, Round::NearestAway);
    assert_eq!(q.to_f64(), -0.25);
}

#[test]
fn wide_mac_rounds_once() {
    // 3-term MAC in wide precision vs naive per-step rounding:
    // wide must equal the exact f64 computation to 1 narrow-rounding.
    let f = QFormat::S3_12;
    let a = Fx::from_f64(1.234, f);
    let b = Fx::from_f64(-0.777, f);
    let c = Fx::from_f64(0.333, f);
    let acc = fx_mul_wide(a, b).add(FxWide::from_fx(c));
    let exact = a.to_f64() * b.to_f64() + c.to_f64();
    let narrowed = acc.narrow(f, Round::NearestAway);
    assert!((narrowed.to_f64() - exact).abs() <= f.ulp() / 2.0 + 1e-15);
}

#[test]
fn one_saturates_in_fraction_only_formats() {
    assert_eq!(Fx::one(QFormat::S_15).raw(), QFormat::S_15.max_raw());
    assert_eq!(Fx::one(QFormat::S3_12).to_f64(), 1.0);
}

// ---------- property tests ----------

#[test]
fn prop_quantization_error_bounded_by_half_ulp() {
    prop_check("quantization error ≤ ulp/2", 5000, |g: &mut Prng| {
        let f = QFormat::S3_12;
        let v = g.f64_in(-7.9, 7.9);
        let q = Fx::from_f64(v, f);
        let err = (q.to_f64() - v).abs();
        if err > f.ulp() / 2.0 + 1e-12 {
            return Err(format!("v={v} q={} err={err}", q.to_f64()));
        }
        Ok(())
    });
}

#[test]
fn prop_convert_narrow_error_bounded() {
    prop_check("narrowing error ≤ dst ulp/2", 5000, |g: &mut Prng| {
        let src = QFormat::S7_24;
        let dst = QFormat::S3_12;
        let v = g.f64_in(-7.9, 7.9);
        let x = Fx::from_f64(v, src);
        let y = x.convert(dst, Round::NearestAway);
        let err = (y.to_f64() - x.to_f64()).abs();
        if err > dst.ulp() / 2.0 + 1e-12 {
            return Err(format!("x={} y={} err={err}", x.to_f64(), y.to_f64()));
        }
        Ok(())
    });
}

#[test]
fn prop_add_matches_f64_when_in_range() {
    prop_check("fx_add == f64 add (in range)", 5000, |g: &mut Prng| {
        let f = QFormat::S3_12;
        let a = Fx::from_f64(g.f64_in(-3.9, 3.9), f);
        let b = Fx::from_f64(g.f64_in(-3.9, 3.9), f);
        let s = fx_add(a, b, f, Round::NearestAway);
        let exact = a.to_f64() + b.to_f64();
        if (s.to_f64() - exact).abs() > 1e-12 {
            return Err(format!("a={a} b={b} s={s} exact={exact}"));
        }
        Ok(())
    });
}

#[test]
fn prop_mul_error_bounded_by_half_ulp() {
    prop_check("fx_mul error ≤ ulp/2", 5000, |g: &mut Prng| {
        let f = QFormat::S3_12;
        let a = Fx::from_f64(g.f64_in(-2.0, 2.0), f);
        let b = Fx::from_f64(g.f64_in(-2.0, 2.0), f);
        let p = fx_mul(a, b, f, Round::NearestAway);
        let exact = a.to_f64() * b.to_f64();
        if (p.to_f64() - exact).abs() > f.ulp() / 2.0 + 1e-12 {
            return Err(format!("a={a} b={b} p={p} exact={exact}"));
        }
        Ok(())
    });
}

#[test]
fn prop_neg_involution() {
    prop_check("neg(neg(x)) == x except at min", 2000, |g: &mut Prng| {
        let f = QFormat::S2_13;
        let raw = g.i64_in(f.min_raw() + 1, f.max_raw());
        let x = Fx::from_raw(raw, f);
        if x.neg().neg().raw() != x.raw() {
            return Err(format!("x={x:?}"));
        }
        Ok(())
    });
}

#[test]
fn prop_convert_roundtrip_widening() {
    prop_check("widen->narrow is identity", 2000, |g: &mut Prng| {
        let src = QFormat::S2_13;
        let raw = g.i64_in(src.min_raw(), src.max_raw());
        let x = Fx::from_raw(raw, src);
        let rt = x.convert(QFormat::S7_24, Round::Trunc).convert(src, Round::Trunc);
        if rt.raw() != x.raw() {
            return Err(format!("x={x:?} rt={rt:?}"));
        }
        Ok(())
    });
}
