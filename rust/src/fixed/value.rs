//! The tagged fixed-point value type.

use std::cmp::Ordering;
use std::fmt;

use super::{QFormat, Round};

/// A fixed-point value: a raw two's-complement integer `raw` interpreted
/// as `raw * 2^-fmt.frac_bits`, saturating at the format bounds.
///
/// `Fx` is deliberately *not* `Copy`-generic over the format: the format
/// travels with the value so that datapath models can't accidentally mix
/// Q-formats without an explicit [`Fx::convert`] (exactly the bug a
/// fixed-point RTL review is looking for).
#[derive(Clone, Copy, PartialEq, Eq, Hash)]
pub struct Fx {
    raw: i64,
    fmt: QFormat,
}

impl Fx {
    /// Builds from a raw integer, saturating to the format's range.
    #[inline]
    pub fn from_raw(raw: i64, fmt: QFormat) -> Fx {
        Fx { raw: raw.clamp(fmt.min_raw(), fmt.max_raw()), fmt }
    }

    /// Builds from a raw integer that is known to be in range.
    ///
    /// Debug-asserts the invariant; use [`Fx::from_raw`] when the value
    /// may overflow (e.g. datapath adder outputs).
    #[inline]
    pub fn from_raw_unchecked(raw: i64, fmt: QFormat) -> Fx {
        debug_assert!(
            raw >= fmt.min_raw() && raw <= fmt.max_raw(),
            "raw {raw} out of range for {fmt}"
        );
        Fx { raw, fmt }
    }

    /// Quantizes an f64 under the given rounding rule, saturating.
    #[inline]
    pub fn from_f64_round(v: f64, fmt: QFormat, round: Round) -> Fx {
        let scaled = v * (1i64 << fmt.frac_bits) as f64;
        let r = round.round_f64(scaled);
        let raw = if r >= fmt.max_raw() as f64 {
            fmt.max_raw()
        } else if r <= fmt.min_raw() as f64 {
            fmt.min_raw()
        } else {
            r as i64
        };
        Fx { raw, fmt }
    }

    /// Quantizes an f64 with round-to-nearest (half away from zero).
    #[inline]
    pub fn from_f64(v: f64, fmt: QFormat) -> Fx {
        Fx::from_f64_round(v, fmt, Round::NearestAway)
    }

    /// Zero in the given format.
    #[inline]
    pub fn zero(fmt: QFormat) -> Fx {
        Fx { raw: 0, fmt }
    }

    /// One (1.0) in the given format, saturated if 1.0 is not
    /// representable (e.g. `S.15` tops out at `1 - 2^-15`).
    #[inline]
    pub fn one(fmt: QFormat) -> Fx {
        Fx::from_raw(1i64 << fmt.frac_bits, fmt)
    }

    /// The format's largest value (`1 - 2^-b` for fraction-only formats —
    /// the paper's saturation output).
    #[inline]
    pub fn max(fmt: QFormat) -> Fx {
        Fx { raw: fmt.max_raw(), fmt }
    }

    /// The format's smallest (most negative) value.
    #[inline]
    pub fn min(fmt: QFormat) -> Fx {
        Fx { raw: fmt.min_raw(), fmt }
    }

    /// The raw two's-complement integer.
    #[inline]
    pub fn raw(self) -> i64 {
        self.raw
    }

    /// The format tag.
    #[inline]
    pub fn format(self) -> QFormat {
        self.fmt
    }

    /// Converts to f64 exactly (every Fx is exactly representable in f64
    /// for widths ≤ 52 bits).
    #[inline]
    pub fn to_f64(self) -> f64 {
        self.raw as f64 * self.fmt.ulp()
    }

    /// Re-quantizes into another format (saturating, rounded).
    ///
    /// This is the "width adapter" block of a datapath: widening is exact,
    /// narrowing rounds the dropped fraction bits with `round` and clamps
    /// into the destination range.
    #[inline]
    pub fn convert(self, dst: QFormat, round: Round) -> Fx {
        if dst == self.fmt {
            return self;
        }
        let raw = if dst.frac_bits >= self.fmt.frac_bits {
            let sh = dst.frac_bits - self.fmt.frac_bits;
            (self.raw as i128) << sh
        } else {
            let sh = self.fmt.frac_bits - dst.frac_bits;
            round.shift_right(self.raw as i128, sh)
        };
        let raw = raw.clamp(dst.min_raw() as i128, dst.max_raw() as i128) as i64;
        Fx { raw, fmt: dst }
    }

    /// Negation (saturating: `-min` clamps to `max`).
    #[inline]
    pub fn neg(self) -> Fx {
        Fx::from_raw(-self.raw, self.fmt)
    }

    /// Absolute value (saturating).
    #[inline]
    pub fn abs(self) -> Fx {
        Fx::from_raw(self.raw.abs(), self.fmt)
    }

    /// True if the value is negative. Datapaths use this as the sign bit
    /// to exploit tanh's odd symmetry (paper §IV: "the main algorithm can
    /// be implemented for positive values only").
    #[inline]
    pub fn is_negative(self) -> bool {
        self.raw < 0
    }

    /// One ulp of this value's format as f64.
    #[inline]
    pub fn ulp(self) -> f64 {
        self.fmt.ulp()
    }
}

impl fmt::Display for Fx {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.to_f64())
    }
}

impl fmt::Debug for Fx {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Fx({} = {} {})", self.raw, self.to_f64(), self.fmt)
    }
}

impl PartialOrd for Fx {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        if self.fmt == other.fmt {
            self.raw.partial_cmp(&other.raw)
        } else {
            self.to_f64().partial_cmp(&other.to_f64())
        }
    }
}
