//! Canonical LSTM and GRU cell-step graphs.
//!
//! Both constructors take pre-activations as inputs — the matrix
//! products `W·x + U·h + b` per gate are the accelerator's MAC array's
//! job; what this crate serves is the nonlinear tail the paper studies:
//! the gate activations plus the fixed-point elementwise state update.
//!
//! LSTM step (inputs `i_pre f_pre g_pre o_pre` in the gate spec's input
//! format, `c_prev` in the state format):
//!
//! ```text
//! i = σ(i_pre)   f = σ(f_pre)   g = tanh(g_pre)   o = σ(o_pre)
//! c_next = f·c_prev + i·g          (state format, saturating)
//! h_next = o·tanh(c_next)          (gate output format)
//! ```
//!
//! GRU step (inputs `z_pre r_pre n_pre` plus `h_prev`):
//!
//! ```text
//! z = σ(z_pre)   r = σ(r_pre)
//! n = tanh(n_pre)                  (candidate pre-activation fed in;
//!                                   the r·(U·h) product happens in the
//!                                   MAC array feeding n_pre)
//! h_next = z·h_prev + (1 − z)·n
//! ```
//!
//! The `r` gate is still computed and exported — it is traffic the
//! accelerator serves (it feeds the MAC array of the next layer) and it
//! makes the dedup rewrite earn its keep when `z_pre == r_pre` routing
//! collapses gates.

use crate::approx::{IoSpec, MethodId, MethodSpec};
use crate::fixed::{QFormat, Round};

use super::CellGraph;

/// Configuration for a cell-step graph: the gate activation spec, the
/// cell-state format, the elementwise rounding mode, and the per-gate
/// error budget the serving path enforces against the f64 reference.
#[derive(Clone, Copy, Debug)]
pub struct CellConfig {
    /// Gate activation design point (tanh spec; sigmoids derive from it).
    pub spec: MethodSpec,
    /// Cell-state format (`c` for LSTM, `h` for GRU).
    pub state_fmt: QFormat,
    /// Rounding mode for the elementwise mul/add datapath.
    pub round: Round,
    /// Per-gate max |fixed − f64 reference| budget, in value units.
    pub budget: f64,
}

impl CellConfig {
    /// The Table I operating point: PWL row A gates (S3.12 → S.15),
    /// S2.13 cell state, round-to-nearest-away elementwise datapath.
    /// The 2e-3 budget is ~6× the worst-case accumulated error of this
    /// configuration (PWL gate error ~4e-5; the dominant term is
    /// `|σ_err(f)|·|c|max ≈ 1.2e-4` through the state update) — tight
    /// enough that a misrouted gate or a broken rewrite (errors ≥1e-2)
    /// trips it instantly.
    pub fn table1_lstm() -> CellConfig {
        CellConfig {
            spec: MethodSpec::table1(MethodId::Pwl),
            state_fmt: QFormat::S2_13,
            round: Round::NearestAway,
            budget: 2e-3,
        }
    }

    /// Table I state/rounding around an arbitrary gate spec. The budget
    /// is loosened to 5e-2: coarse methods (e.g. `taylor1`, Table I max
    /// error 2.2e-2) are legitimate gate design points, and 5e-2 still
    /// catches wiring bugs, which cost ≥ 1e-1.
    pub fn with_spec(spec: MethodSpec) -> CellConfig {
        CellConfig { spec, budget: 5e-2, ..CellConfig::table1_lstm() }
    }

    /// The tanh spec applied to the cell state (`tanh(c_next)`): same
    /// method parameters and domain as the gate spec, but reading the
    /// state format.
    pub fn state_tanh_spec(&self) -> Result<MethodSpec, String> {
        MethodSpec::new(
            self.spec.params,
            IoSpec { input: self.state_fmt, output: self.spec.io.output },
            self.spec.domain,
        )
        .map_err(|e| format!("state tanh spec for {}: {e}", self.spec))
    }
}

/// Builds the LSTM cell-step graph (unfused: sigmoid gates are
/// `Op::Activation` sigmoid nodes; run `rewrite::optimize` to lower
/// them onto shared tanh kernels). Outputs: `i f g o c_next h_next`.
pub fn lstm_cell(cfg: &CellConfig) -> Result<CellGraph, String> {
    let spec = cfg.spec;
    let gate_out = spec.io.output;
    let r = cfg.round;
    let mut g = CellGraph::new("lstm");

    let i_pre = g.input("i_pre", spec.io.input);
    let f_pre = g.input("f_pre", spec.io.input);
    let g_pre = g.input("g_pre", spec.io.input);
    let o_pre = g.input("o_pre", spec.io.input);
    let c_prev = g.input("c_prev", cfg.state_fmt);

    let i = g.sigmoid("i", i_pre, spec);
    let f = g.sigmoid("f", f_pre, spec);
    let gg = g.tanh("g", g_pre, spec);
    let o = g.sigmoid("o", o_pre, spec);

    let fc = g.mul("f*c_prev", f, c_prev, cfg.state_fmt, r);
    let ig = g.mul("i*g", i, gg, cfg.state_fmt, r);
    let c_next = g.add("c_next", fc, ig, cfg.state_fmt, r);
    let c_act = g.tanh("tanh_c", c_next, cfg.state_tanh_spec()?);
    let h_next = g.mul("h_next", o, c_act, gate_out, r);

    g.mark_output("i", i);
    g.mark_output("f", f);
    g.mark_output("g", gg);
    g.mark_output("o", o);
    g.mark_output("c_next", c_next);
    g.mark_output("h_next", h_next);
    g.validate()?;
    Ok(g)
}

/// Builds the GRU cell-step graph. Inputs: `z_pre r_pre n_pre h_prev`;
/// outputs: `z r n h_next`.
pub fn gru_cell(cfg: &CellConfig) -> Result<CellGraph, String> {
    let spec = cfg.spec;
    let r = cfg.round;
    let mut g = CellGraph::new("gru");

    let z_pre = g.input("z_pre", spec.io.input);
    let r_pre = g.input("r_pre", spec.io.input);
    let n_pre = g.input("n_pre", spec.io.input);
    let h_prev = g.input("h_prev", cfg.state_fmt);

    let z = g.sigmoid("z", z_pre, spec);
    let rr = g.sigmoid("r", r_pre, spec);
    let n = g.tanh("n", n_pre, spec);

    let zh = g.mul("z*h_prev", z, h_prev, cfg.state_fmt, r);
    let one_minus_z = g.one_minus("1-z", z, spec.io.output, r);
    let zn = g.mul("(1-z)*n", one_minus_z, n, cfg.state_fmt, r);
    let h_next = g.add("h_next", zh, zn, cfg.state_fmt, r);

    g.mark_output("z", z);
    g.mark_output("r", rr);
    g.mark_output("n", n);
    g.mark_output("h_next", h_next);
    g.validate()?;
    Ok(g)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::approx::ActKind;
    use crate::graph::Op;

    #[test]
    fn lstm_graph_validates_and_names_everything() {
        let g = lstm_cell(&CellConfig::table1_lstm()).unwrap();
        let input_names: Vec<&str> = g.inputs().iter().map(|&(n, _, _)| n).collect();
        assert_eq!(input_names, ["i_pre", "f_pre", "g_pre", "o_pre", "c_prev"]);
        let out_names: Vec<&str> = g.outputs().iter().map(|(n, _)| n.as_str()).collect();
        assert_eq!(out_names, ["i", "f", "g", "o", "c_next", "h_next"]);
        // Two distinct tanh specs: the gate spec and the state-format one.
        assert_eq!(g.activation_specs().len(), 2);
        // Three unfused sigmoid gates.
        let sigmoids = g
            .nodes()
            .iter()
            .filter(|n| matches!(&n.op, Op::Activation { act, .. } if act.kind == ActKind::Sigmoid))
            .count();
        assert_eq!(sigmoids, 3);
    }

    #[test]
    fn gru_graph_validates() {
        let g = gru_cell(&CellConfig::table1_lstm()).unwrap();
        let out_names: Vec<&str> = g.outputs().iter().map(|(n, _)| n.as_str()).collect();
        assert_eq!(out_names, ["z", "r", "n", "h_next"]);
        assert_eq!(g.activation_specs().len(), 1);
    }

    #[test]
    fn state_tanh_spec_reads_the_state_format() {
        let cfg = CellConfig::table1_lstm();
        let s = cfg.state_tanh_spec().unwrap();
        assert_eq!(s.io.input, cfg.state_fmt);
        assert_eq!(s.io.output, cfg.spec.io.output);
        assert_eq!(s.method_id(), cfg.spec.method_id());
        assert_eq!(s.param(), cfg.spec.param());
        assert_eq!(s.domain, cfg.spec.domain);
    }
}
