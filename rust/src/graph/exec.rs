//! Graph execution: raw integer lanes through any activation sink,
//! plus the f64 float reference the per-gate error budgets are measured
//! against.
//!
//! The executor is a single forward scan (nodes are stored in
//! topological order) over `Vec<i64>` lanes. Elementwise ops run
//! locally through [`super::ops`]; activation nodes are delegated to an
//! [`ActivationSink`], which is where the execution substrates differ:
//!
//! - [`FreshKernelSink`] — compiles private kernels for the graph's
//!   specs, bypassing the [`Registry`](crate::approx::Registry): the
//!   cache-independent golden reference (same role as
//!   [`crate::bench::scenario::GoldenVerifier`] for flat traffic).
//! - [`BackendSink`] — any [`EvalBackend`] (golden shares the registry
//!   cache; hw runs the lowered pipelines).
//! - [`CoordinatorSink`](super::serve::CoordinatorSink) — round-trips
//!   every activation batch through the sharded coordinator, making a
//!   cell step an end-to-end served workload.
//!
//! The f64 reference ([`execute_ref`]) computes every node in double
//! precision with *declared-range saturation*: elementwise results are
//! clamped to their node format's representable range, exactly as the
//! saturating fixed-point datapath clamps. This keeps the error budget
//! measuring what it should — approximation + quantization error — and
//! not dynamic-range clipping, which is a property of the chosen
//! `QFormat`s that fixed and reference datapaths share by design.

use std::collections::HashMap;

use crate::approx::{ActKind, CompiledKernel, MethodSpec, SigmoidFromTanh};
use crate::backend::EvalBackend;
use crate::fixed::Fx;

use super::{ops, CellGraph, Op};

/// Where activation nodes evaluate. `ensure` is called once per
/// distinct tanh spec before any `eval`.
pub trait ActivationSink {
    fn ensure(&self, spec: &MethodSpec) -> Result<(), String>;
    fn eval(&self, spec: &MethodSpec, input: &[i64], output: &mut [i64]) -> Result<(), String>;
}

/// Sink over any [`EvalBackend`].
pub struct BackendSink<'a> {
    backend: &'a dyn EvalBackend,
}

impl<'a> BackendSink<'a> {
    pub fn new(backend: &'a dyn EvalBackend) -> BackendSink<'a> {
        BackendSink { backend }
    }
}

impl ActivationSink for BackendSink<'_> {
    fn ensure(&self, spec: &MethodSpec) -> Result<(), String> {
        self.backend.ensure(spec).map_err(|e| e.to_string())
    }

    fn eval(&self, spec: &MethodSpec, input: &[i64], output: &mut [i64]) -> Result<(), String> {
        self.backend.eval_raw(spec, input, output).map(|_| ()).map_err(|e| e.to_string())
    }
}

/// Cache-bypassing golden sink: compiles a private kernel per spec at
/// construction, so a poisoned registry entry cannot vouch for itself.
pub struct FreshKernelSink {
    kernels: HashMap<MethodSpec, CompiledKernel>,
}

impl FreshKernelSink {
    /// Compiles kernels for every tanh spec the graph references.
    pub fn for_graph(g: &CellGraph) -> FreshKernelSink {
        let kernels = g
            .activation_specs()
            .into_iter()
            .map(|s| {
                let k = s.build().compile(s.io);
                (s, k)
            })
            .collect();
        FreshKernelSink { kernels }
    }
}

impl ActivationSink for FreshKernelSink {
    fn ensure(&self, spec: &MethodSpec) -> Result<(), String> {
        if self.kernels.contains_key(spec) {
            Ok(())
        } else {
            Err(format!("spec '{spec}' was not compiled for this graph"))
        }
    }

    fn eval(&self, spec: &MethodSpec, input: &[i64], output: &mut [i64]) -> Result<(), String> {
        let k = self
            .kernels
            .get(spec)
            .ok_or_else(|| format!("spec '{spec}' was not compiled for this graph"))?;
        k.eval_slice_raw(input, output);
        Ok(())
    }
}

fn batch_len<T>(inputs: &[(&str, Vec<T>)]) -> Result<usize, String> {
    let batch = inputs.first().map(|(_, v)| v.len()).unwrap_or(0);
    if batch == 0 {
        return Err("execute: need at least one non-empty input".to_string());
    }
    for (name, v) in inputs {
        if v.len() != batch {
            return Err(format!(
                "input '{name}' carries {} lanes, expected {batch}",
                v.len()
            ));
        }
    }
    Ok(batch)
}

/// Executes `g` over raw lanes. `inputs` must name every `Op::Input`
/// node (same lane count each); returns the outputs in declaration
/// order. Unfused sigmoid activations evaluate through a fresh scalar
/// [`SigmoidFromTanh`] per node — the pre-rewrite reference semantics
/// that `rewrite::fuse_sigmoid` lowers onto shared tanh kernels.
pub fn execute_raw(
    g: &CellGraph,
    inputs: &[(&str, Vec<i64>)],
    sink: &dyn ActivationSink,
) -> Result<Vec<(String, Vec<i64>)>, String> {
    g.validate()?;
    for spec in g.activation_specs() {
        sink.ensure(&spec)?;
    }
    let batch = batch_len(inputs)?;
    let mut lanes: Vec<Vec<i64>> = Vec::with_capacity(g.len());
    for node in g.nodes() {
        let vals: Vec<i64> = match &node.op {
            Op::Input => inputs
                .iter()
                .find(|(n, _)| *n == node.label)
                .map(|(_, v)| v.clone())
                .ok_or_else(|| format!("missing input '{}'", node.label))?,
            Op::Activation { input, act } => {
                let x = &lanes[input.index()];
                let mut out = vec![0i64; batch];
                match act.kind {
                    ActKind::Tanh => sink.eval(&act.spec, x, &mut out)?,
                    ActKind::Sigmoid => {
                        let sig = SigmoidFromTanh::new(act.spec.build());
                        for (o, &raw) in out.iter_mut().zip(x) {
                            *o = sig
                                .eval_fx(Fx::from_raw(raw, act.spec.io.input), act.spec.io.output)
                                .raw();
                        }
                    }
                }
                out
            }
            Op::Mul { a, b, round } => {
                let (af, bf) = (g.fmt_of(*a), g.fmt_of(*b));
                lanes[a.index()]
                    .iter()
                    .zip(&lanes[b.index()])
                    .map(|(&x, &y)| ops::mul_raw(x, af, y, bf, node.fmt, *round))
                    .collect()
            }
            Op::Add { a, b, round } => {
                let (af, bf) = (g.fmt_of(*a), g.fmt_of(*b));
                lanes[a.index()]
                    .iter()
                    .zip(&lanes[b.index()])
                    .map(|(&x, &y)| ops::add_raw(x, af, y, bf, node.fmt, *round))
                    .collect()
            }
            Op::OneMinus { input, round } => {
                let src = g.fmt_of(*input);
                lanes[input.index()]
                    .iter()
                    .map(|&v| ops::one_minus_raw(v, src, node.fmt, *round))
                    .collect()
            }
            Op::Requant { input, round } => {
                let src = g.fmt_of(*input);
                lanes[input.index()]
                    .iter()
                    .map(|&v| ops::requant_raw(v, src, node.fmt, *round))
                    .collect()
            }
            // Pure reinterpretation: same raw words, finer format.
            Op::Halve { input } => lanes[input.index()].clone(),
            Op::SigmoidPost { input } => {
                let t_fmt = g.fmt_of(*input);
                lanes[input.index()]
                    .iter()
                    .map(|&t| ops::sigmoid_post_raw(t, t_fmt, node.fmt))
                    .collect()
            }
        };
        lanes.push(vals);
    }
    Ok(g.outputs().iter().map(|(name, id)| (name.clone(), lanes[id.index()].clone())).collect())
}

/// The f64 reference datapath: exact arithmetic, ideal nonlinearities,
/// declared-range saturation at every node (see module docs).
pub fn execute_ref(
    g: &CellGraph,
    inputs: &[(&str, Vec<f64>)],
) -> Result<Vec<(String, Vec<f64>)>, String> {
    g.validate()?;
    let _ = batch_len(inputs)?;
    let mut lanes: Vec<Vec<f64>> = Vec::with_capacity(g.len());
    for node in g.nodes() {
        let clamp = |v: f64| v.clamp(node.fmt.min_value(), node.fmt.max_value());
        let vals: Vec<f64> = match &node.op {
            Op::Input => inputs
                .iter()
                .find(|(n, _)| *n == node.label)
                .map(|(_, v)| v.clone())
                .ok_or_else(|| format!("missing input '{}'", node.label))?,
            Op::Activation { input, act } => {
                lanes[input.index()].iter().map(|&x| clamp(act.reference(x))).collect()
            }
            Op::Mul { a, b, .. } => lanes[a.index()]
                .iter()
                .zip(&lanes[b.index()])
                .map(|(&x, &y)| clamp(x * y))
                .collect(),
            Op::Add { a, b, .. } => lanes[a.index()]
                .iter()
                .zip(&lanes[b.index()])
                .map(|(&x, &y)| clamp(x + y))
                .collect(),
            Op::OneMinus { input, .. } => {
                lanes[input.index()].iter().map(|&v| clamp(1.0 - v)).collect()
            }
            Op::Requant { input, .. } => lanes[input.index()].iter().map(|&v| clamp(v)).collect(),
            Op::Halve { input } => lanes[input.index()].iter().map(|&v| 0.5 * v).collect(),
            Op::SigmoidPost { input } => {
                lanes[input.index()].iter().map(|&t| clamp(0.5 * (1.0 + t))).collect()
            }
        };
        lanes.push(vals);
    }
    Ok(g.outputs().iter().map(|(name, id)| (name.clone(), lanes[id.index()].clone())).collect())
}

/// Per-output max |fixed − reference| in value units, matched by output
/// name. `fixed` raws are interpreted in each output's node format.
pub fn gate_errors(
    g: &CellGraph,
    fixed: &[(String, Vec<i64>)],
    reference: &[(String, Vec<f64>)],
) -> Result<Vec<(String, f64)>, String> {
    let mut out = Vec::with_capacity(fixed.len());
    for (name, raws) in fixed {
        let id = g
            .output(name)
            .ok_or_else(|| format!("'{name}' is not an output of graph '{}'", g.name()))?;
        let ulp = g.fmt_of(id).ulp();
        let refs = reference
            .iter()
            .find(|(n, _)| n == name)
            .map(|(_, v)| v)
            .ok_or_else(|| format!("reference run lacks output '{name}'"))?;
        if refs.len() != raws.len() {
            return Err(format!("output '{name}': lane count mismatch"));
        }
        let mut max_err = 0.0f64;
        for (&r, &x) in raws.iter().zip(refs) {
            max_err = max_err.max((r as f64 * ulp - x).abs());
        }
        out.push((name.clone(), max_err));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::GoldenBackend;
    use crate::graph::cell::{gru_cell, lstm_cell, CellConfig};
    use crate::graph::rewrite::optimize;
    use crate::util::prng::Prng;

    fn lstm_inputs(g: &CellGraph, seed: u64, lanes: usize) -> Vec<(&'static str, Vec<i64>)> {
        let cfg = CellConfig::table1_lstm();
        let mut prng = Prng::new(seed);
        let pre = |p: &mut Prng| -> Vec<i64> {
            (0..lanes).map(|_| Fx::from_f64(p.f64_in(-6.0, 6.0), cfg.spec.io.input).raw()).collect()
        };
        let c: Vec<i64> =
            (0..lanes).map(|_| Fx::from_f64(prng.f64_in(-1.5, 1.5), cfg.state_fmt).raw()).collect();
        vec![
            ("i_pre", pre(&mut prng)),
            ("f_pre", pre(&mut prng)),
            ("g_pre", pre(&mut prng)),
            ("o_pre", pre(&mut prng)),
            ("c_prev", c),
        ]
    }

    #[test]
    fn lstm_outputs_stay_within_budget_of_the_reference() {
        let cfg = CellConfig::table1_lstm();
        let g = lstm_cell(&cfg).unwrap();
        let sink = FreshKernelSink::for_graph(&g);
        let inputs = lstm_inputs(&g, 0xCE11, 64);
        let fixed = execute_raw(&g, &inputs, &sink).unwrap();
        let ref_inputs: Vec<(&str, Vec<f64>)> = inputs
            .iter()
            .map(|(n, v)| {
                let fmt = g.fmt_of(g.inputs().iter().find(|(gn, _, _)| gn == n).unwrap().1);
                (*n, v.iter().map(|&r| r as f64 * fmt.ulp()).collect())
            })
            .collect();
        let reference = execute_ref(&g, &ref_inputs).unwrap();
        let errs = gate_errors(&g, &fixed, &reference).unwrap();
        for (name, err) in &errs {
            assert!(*err <= cfg.budget, "gate '{name}' err {err:.3e} > budget {:.1e}", cfg.budget);
        }
        // Guard against comparing fixed to itself: quantization must
        // leave a nonzero residue somewhere.
        assert!(errs.iter().any(|(_, e)| *e > 0.0), "all gates exact: {errs:?}");
    }

    #[test]
    fn fused_graph_is_bit_identical_through_a_backend() {
        let cfg = CellConfig::table1_lstm();
        let g = lstm_cell(&cfg).unwrap();
        let (fused, stats) = optimize(&g).unwrap();
        assert_eq!(stats.fused_sigmoids, 3);
        let inputs = lstm_inputs(&g, 0xFACE, 48);
        let unfused_out = execute_raw(&g, &inputs, &FreshKernelSink::for_graph(&g)).unwrap();
        let backend = GoldenBackend::new();
        let sink = BackendSink::new(&backend);
        let fused_out = execute_raw(&fused, &inputs, &sink).unwrap();
        assert_eq!(unfused_out, fused_out, "fusion must not change a single bit");
    }

    #[test]
    fn gru_runs_and_tracks_reference() {
        let cfg = CellConfig::table1_lstm();
        let g = gru_cell(&cfg).unwrap();
        let (fused, _) = optimize(&g).unwrap();
        let mut prng = Prng::new(7);
        let lanes = 32;
        let pre = |p: &mut Prng| -> Vec<i64> {
            (0..lanes).map(|_| Fx::from_f64(p.f64_in(-6.0, 6.0), cfg.spec.io.input).raw()).collect()
        };
        let h: Vec<i64> =
            (0..lanes).map(|_| Fx::from_f64(prng.f64_in(-0.9, 0.9), cfg.state_fmt).raw()).collect();
        let inputs = vec![
            ("z_pre", pre(&mut prng)),
            ("r_pre", pre(&mut prng)),
            ("n_pre", pre(&mut prng)),
            ("h_prev", h),
        ];
        let sink = FreshKernelSink::for_graph(&fused);
        let fixed = execute_raw(&fused, &inputs, &sink).unwrap();
        let ref_inputs: Vec<(&str, Vec<f64>)> = inputs
            .iter()
            .map(|(n, v)| {
                let fmt = fused.fmt_of(fused.inputs().iter().find(|(gn, _, _)| gn == n).unwrap().1);
                (*n, v.iter().map(|&r| r as f64 * fmt.ulp()).collect())
            })
            .collect();
        let reference = execute_ref(&fused, &ref_inputs).unwrap();
        for (name, err) in gate_errors(&fused, &fixed, &reference).unwrap() {
            assert!(err <= cfg.budget, "gate '{name}' err {err:.3e}");
        }
    }

    #[test]
    fn missing_and_ragged_inputs_are_rejected() {
        let g = lstm_cell(&CellConfig::table1_lstm()).unwrap();
        let sink = FreshKernelSink::for_graph(&g);
        let mut inputs = lstm_inputs(&g, 1, 8);
        inputs.pop(); // drop c_prev
        assert!(execute_raw(&g, &inputs, &sink).unwrap_err().contains("missing input"));
        let mut ragged = lstm_inputs(&g, 1, 8);
        ragged[2].1.pop();
        assert!(execute_raw(&g, &ragged, &sink).unwrap_err().contains("lanes"));
    }
}
