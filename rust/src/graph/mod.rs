//! Typed LSTM/GRU cell dataflow graphs over `MethodSpec` kernels.
//!
//! The coordinator has so far served flat scalar-tanh batches; real
//! accelerator traffic — the paper's own §I motivation — is *gate
//! stacks*: four activations plus a handful of fixed-point elementwise
//! ops per LSTM cell step. This module makes the cell step a
//! first-class workload:
//!
//! - [`CellGraph`] — a small typed dataflow IR. Nodes are
//!   [`MethodSpec`]-addressed activation kernels (tanh, and sigmoid via
//!   the `σ(x) = (1 + tanh(x/2)) / 2` identity from
//!   `approx/sigmoid.rs`) plus fixed-point elementwise ops
//!   ([`Op::Mul`], [`Op::Add`], [`Op::Requant`], …). Every edge carries
//!   an explicit [`QFormat`]; `nodes` is stored in topological order
//!   (operands always precede their users), so execution is a single
//!   forward scan and [`CellGraph::validate`] enforces acyclicity by
//!   index ordering alone.
//! - [`cell`] — constructors for canonical LSTM and GRU cell steps.
//! - [`rewrite`] — functional graph-to-graph passes in the spirit of
//!   tract's `ModelPatch`: fuse sigmoid-into-tanh (so all gates share
//!   one compiled tanh kernel through the process-wide [`Registry`]),
//!   merge adjacent requantizations, deduplicate identical nodes, and
//!   prune dead ones.
//! - [`exec`] — executes a graph over raw `i64` lanes against any
//!   activation sink: a fresh-kernel golden sink, an [`EvalBackend`],
//!   or the sharded coordinator ([`serve`]), with an f64 float
//!   reference (`execute_ref`) for per-gate error budgets.
//!
//! [`MethodSpec`]: crate::approx::MethodSpec
//! [`Registry`]: crate::approx::Registry
//! [`EvalBackend`]: crate::backend::EvalBackend

pub mod cell;
pub mod exec;
pub mod ops;
pub mod rewrite;
pub mod serve;

pub use cell::{gru_cell, lstm_cell, CellConfig};
pub use exec::{execute_raw, execute_ref, gate_errors, ActivationSink, BackendSink, FreshKernelSink};
pub use rewrite::{optimize, RewriteStats};
pub use serve::{run_lstm_cells, CellRunConfig, CellRunStats, CoordinatorSink};

use std::fmt;

use crate::approx::{ActKind, ActSpec, MethodSpec};
use crate::fixed::{QFormat, Round};

use self::ops::halve_fmt;

/// Index of a node inside one [`CellGraph`]. Ids are dense and equal to
/// the node's position in [`CellGraph::nodes`]; they are only
/// meaningful within the graph that issued them.
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug)]
pub struct NodeId(pub(crate) usize);

impl NodeId {
    /// Position of this node in [`CellGraph::nodes`].
    pub fn index(self) -> usize {
        self.0
    }
}

impl fmt::Display for NodeId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "%{}", self.0)
    }
}

/// One dataflow operation. Operand `NodeId`s always point at
/// lower-indexed nodes (checked by [`CellGraph::validate`]).
#[derive(Clone, Debug, PartialEq)]
pub enum Op {
    /// External input; its name is the node label.
    Input,
    /// A `MethodSpec`-addressed nonlinearity. `act.kind` selects tanh
    /// (served straight from the kernel cache / backend) or sigmoid
    /// (scalar `SigmoidFromTanh` wrapper until `rewrite::fuse_sigmoid`
    /// lowers it onto a shared tanh kernel).
    Activation { input: NodeId, act: ActSpec },
    /// Fixed-point multiply: exact wide product, one rounding into the
    /// node format ([`ops::mul_raw`]).
    Mul { a: NodeId, b: NodeId, round: Round },
    /// Fixed-point add: operands converted to the node format, then a
    /// saturating add ([`ops::add_raw`]).
    Add { a: NodeId, b: NodeId, round: Round },
    /// `1 − x` through an exact widened intermediate
    /// ([`ops::one_minus_raw`]) — the GRU update-gate complement.
    OneMinus { input: NodeId, round: Round },
    /// Format conversion ([`ops::requant_raw`]).
    Requant { input: NodeId, round: Round },
    /// Reinterpret the raw word as `halve_fmt(input)` — an exact `x/2`
    /// with zero hardware; the fused sigmoid's input shift.
    Halve { input: NodeId },
    /// The `(1 + t) / 2` sigmoid tail ([`ops::sigmoid_post_raw`]);
    /// `input` must be a `S1.(out_frac+1)` tanh value.
    SigmoidPost { input: NodeId },
}

impl Op {
    /// The operand ids, in order.
    pub fn operands(&self) -> Vec<NodeId> {
        match *self {
            Op::Input => Vec::new(),
            Op::Activation { input, .. }
            | Op::OneMinus { input, .. }
            | Op::Requant { input, .. }
            | Op::Halve { input }
            | Op::SigmoidPost { input } => vec![input],
            Op::Mul { a, b, .. } | Op::Add { a, b, .. } => vec![a, b],
        }
    }

    /// Short op-kind name for diagnostics.
    pub fn kind_name(&self) -> &'static str {
        match self {
            Op::Input => "input",
            Op::Activation { .. } => "activation",
            Op::Mul { .. } => "mul",
            Op::Add { .. } => "add",
            Op::OneMinus { .. } => "one_minus",
            Op::Requant { .. } => "requant",
            Op::Halve { .. } => "halve",
            Op::SigmoidPost { .. } => "sigmoid_post",
        }
    }

    /// Same op with every operand id pushed through `map` (old index →
    /// new id) — the rewrite passes' node transplant.
    pub(crate) fn remap(&self, map: &[NodeId]) -> Op {
        let m = |id: NodeId| map[id.0];
        match *self {
            Op::Input => Op::Input,
            Op::Activation { input, act } => Op::Activation { input: m(input), act },
            Op::Mul { a, b, round } => Op::Mul { a: m(a), b: m(b), round },
            Op::Add { a, b, round } => Op::Add { a: m(a), b: m(b), round },
            Op::OneMinus { input, round } => Op::OneMinus { input: m(input), round },
            Op::Requant { input, round } => Op::Requant { input: m(input), round },
            Op::Halve { input } => Op::Halve { input: m(input) },
            Op::SigmoidPost { input } => Op::SigmoidPost { input: m(input) },
        }
    }
}

/// One node: an op, the [`QFormat`] of the value it produces, and a
/// human-readable label (for inputs, the label is the input name).
#[derive(Clone, Debug)]
pub struct Node {
    pub op: Op,
    pub fmt: QFormat,
    pub label: String,
}

/// A typed dataflow graph for one cell step. Build with the `input` /
/// `tanh` / `sigmoid` / `mul` / … methods (each returns the new
/// [`NodeId`]), name the results with [`mark_output`], then
/// [`validate`] before executing.
///
/// [`mark_output`]: CellGraph::mark_output
/// [`validate`]: CellGraph::validate
#[derive(Clone, Debug)]
pub struct CellGraph {
    name: String,
    nodes: Vec<Node>,
    outputs: Vec<(String, NodeId)>,
}

impl CellGraph {
    pub fn new(name: impl Into<String>) -> CellGraph {
        CellGraph { name: name.into(), nodes: Vec::new(), outputs: Vec::new() }
    }

    pub(crate) fn push(&mut self, op: Op, fmt: QFormat, label: impl Into<String>) -> NodeId {
        let label = label.into();
        for d in op.operands() {
            assert!(
                d.0 < self.nodes.len(),
                "operand {d} of '{label}' is not in the graph yet"
            );
        }
        self.nodes.push(Node { op, fmt, label });
        NodeId(self.nodes.len() - 1)
    }

    /// External input named `name`, carrying `fmt` raw words.
    pub fn input(&mut self, name: impl Into<String>, fmt: QFormat) -> NodeId {
        self.push(Op::Input, fmt, name)
    }

    /// Generic activation node; the node format is the spec's output.
    pub fn activation(&mut self, label: impl Into<String>, input: NodeId, act: ActSpec) -> NodeId {
        self.push(Op::Activation { input, act }, act.spec.io.output, label)
    }

    pub fn tanh(&mut self, label: impl Into<String>, input: NodeId, spec: MethodSpec) -> NodeId {
        self.activation(label, input, ActSpec::tanh(spec))
    }

    pub fn sigmoid(&mut self, label: impl Into<String>, input: NodeId, spec: MethodSpec) -> NodeId {
        self.activation(label, input, ActSpec::sigmoid(spec))
    }

    pub fn mul(
        &mut self,
        label: impl Into<String>,
        a: NodeId,
        b: NodeId,
        dst: QFormat,
        round: Round,
    ) -> NodeId {
        self.push(Op::Mul { a, b, round }, dst, label)
    }

    pub fn add(
        &mut self,
        label: impl Into<String>,
        a: NodeId,
        b: NodeId,
        dst: QFormat,
        round: Round,
    ) -> NodeId {
        self.push(Op::Add { a, b, round }, dst, label)
    }

    pub fn one_minus(
        &mut self,
        label: impl Into<String>,
        input: NodeId,
        dst: QFormat,
        round: Round,
    ) -> NodeId {
        self.push(Op::OneMinus { input, round }, dst, label)
    }

    pub fn requant(
        &mut self,
        label: impl Into<String>,
        input: NodeId,
        dst: QFormat,
        round: Round,
    ) -> NodeId {
        self.push(Op::Requant { input, round }, dst, label)
    }

    /// Exact `x/2` by reinterpretation; the node format is forced to
    /// `halve_fmt` of the operand's.
    pub fn halve(&mut self, label: impl Into<String>, input: NodeId) -> NodeId {
        let fmt = halve_fmt(self.fmt_of(input));
        self.push(Op::Halve { input }, fmt, label)
    }

    /// `(1 + t) / 2` into `out`; `input` must produce `S1.(out_frac+1)`.
    pub fn sigmoid_post(
        &mut self,
        label: impl Into<String>,
        input: NodeId,
        out: QFormat,
    ) -> NodeId {
        self.push(Op::SigmoidPost { input }, out, label)
    }

    /// Name `id` as a graph output.
    pub fn mark_output(&mut self, name: impl Into<String>, id: NodeId) {
        assert!(id.0 < self.nodes.len(), "output id {id} is not in the graph");
        self.outputs.push((name.into(), id));
    }

    pub fn name(&self) -> &str {
        &self.name
    }

    pub fn len(&self) -> usize {
        self.nodes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty()
    }

    pub fn node(&self, id: NodeId) -> &Node {
        &self.nodes[id.0]
    }

    pub fn nodes(&self) -> &[Node] {
        &self.nodes
    }

    pub fn fmt_of(&self, id: NodeId) -> QFormat {
        self.nodes[id.0].fmt
    }

    pub fn outputs(&self) -> &[(String, NodeId)] {
        &self.outputs
    }

    /// The id of the output named `name`, if any.
    pub fn output(&self, name: &str) -> Option<NodeId> {
        self.outputs.iter().find(|(n, _)| n == name).map(|&(_, id)| id)
    }

    /// External inputs in node order: `(name, id, format)`.
    pub fn inputs(&self) -> Vec<(&str, NodeId, QFormat)> {
        self.nodes
            .iter()
            .enumerate()
            .filter(|(_, n)| matches!(n.op, Op::Input))
            .map(|(i, n)| (n.label.as_str(), NodeId(i), n.fmt))
            .collect()
    }

    /// The distinct *tanh* `MethodSpec`s the graph needs from a backend
    /// (a coordinator must serve exactly these). Unfused sigmoid nodes
    /// are excluded: they evaluate through the scalar golden wrapper
    /// until `rewrite::fuse_sigmoid` lowers them onto tanh kernels.
    pub fn activation_specs(&self) -> Vec<MethodSpec> {
        let mut specs: Vec<MethodSpec> = Vec::new();
        for node in &self.nodes {
            if let Op::Activation { act, .. } = &node.op {
                if act.kind == ActKind::Tanh && !specs.contains(&act.spec) {
                    specs.push(act.spec);
                }
            }
        }
        specs
    }

    /// Structural validation: topological operand order (which rules
    /// out cycles), per-op format agreement, spec well-formedness,
    /// unique input/output names, and at least one output.
    pub fn validate(&self) -> Result<(), String> {
        let mut input_names: Vec<&str> = Vec::new();
        for (i, node) in self.nodes.iter().enumerate() {
            for d in node.op.operands() {
                if d.0 >= i {
                    return Err(format!(
                        "node {i} '{}' depends on {d}: operands must precede users \
                         (cycle or forward reference)",
                        node.label
                    ));
                }
            }
            match &node.op {
                Op::Input => {
                    if node.label.is_empty() {
                        return Err(format!("input node {i} has an empty name"));
                    }
                    if input_names.contains(&node.label.as_str()) {
                        return Err(format!("duplicate input name '{}'", node.label));
                    }
                    input_names.push(node.label.as_str());
                }
                Op::Activation { input, act } => {
                    MethodSpec::new(act.spec.params, act.spec.io, act.spec.domain)
                        .map_err(|e| format!("activation '{}': bad spec: {e}", node.label))?;
                    let got = self.fmt_of(*input);
                    if got != act.spec.io.input {
                        return Err(format!(
                            "activation '{}' expects {} input, operand {input} carries {got}",
                            node.label, act.spec.io.input
                        ));
                    }
                    if node.fmt != act.spec.io.output {
                        return Err(format!(
                            "activation '{}' node format {} != spec output {}",
                            node.label, node.fmt, act.spec.io.output
                        ));
                    }
                }
                Op::OneMinus { input, .. } => {
                    let src = self.fmt_of(*input);
                    if src.width() > 61 {
                        return Err(format!(
                            "one_minus '{}': operand format {src} too wide for the exact \
                             widened complement (width {} > 61)",
                            node.label,
                            src.width()
                        ));
                    }
                }
                Op::Halve { input } => {
                    let want = halve_fmt(self.fmt_of(*input));
                    if node.fmt != want {
                        return Err(format!(
                            "halve '{}' must carry {want}, declared {}",
                            node.label, node.fmt
                        ));
                    }
                }
                Op::SigmoidPost { input } => {
                    let want = QFormat::new(1, node.fmt.frac_bits + 1);
                    let got = self.fmt_of(*input);
                    if got != want {
                        return Err(format!(
                            "sigmoid_post '{}' expects a {want} tanh operand, got {got}",
                            node.label
                        ));
                    }
                }
                Op::Mul { .. } | Op::Add { .. } | Op::Requant { .. } => {}
            }
        }
        if self.outputs.is_empty() {
            return Err(format!("graph '{}' has no outputs", self.name));
        }
        let mut out_names: Vec<&str> = Vec::new();
        for (name, id) in &self.outputs {
            if id.0 >= self.nodes.len() {
                return Err(format!("output '{name}' points at missing node {id}"));
            }
            if out_names.contains(&name.as_str()) {
                return Err(format!("duplicate output name '{name}'"));
            }
            out_names.push(name.as_str());
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::approx::MethodId;

    fn spec() -> MethodSpec {
        MethodSpec::table1(MethodId::Pwl)
    }

    #[test]
    fn builder_produces_a_valid_graph() {
        let s = spec();
        let mut g = CellGraph::new("t");
        let x = g.input("x", s.io.input);
        let t = g.tanh("t", x, s);
        let y = g.input("y", s.io.output);
        let p = g.mul("p", t, y, s.io.output, Round::NearestAway);
        g.mark_output("p", p);
        assert_eq!(g.len(), 4);
        assert_eq!(g.inputs().len(), 2);
        assert_eq!(g.output("p"), Some(p));
        assert_eq!(g.fmt_of(t), s.io.output);
        g.validate().expect("valid graph");
        assert_eq!(g.activation_specs(), vec![s]);
    }

    #[test]
    fn validate_rejects_format_mismatch_and_missing_output() {
        let s = spec();
        let mut g = CellGraph::new("bad");
        // Feed the activation an output-format operand: input mismatch.
        let x = g.input("x", s.io.output);
        let t = g.push(Op::Activation { input: x, act: ActSpec::tanh(s) }, s.io.output, "t");
        g.mark_output("t", t);
        let err = g.validate().unwrap_err();
        assert!(err.contains("expects"), "unexpected error: {err}");

        let g2 = CellGraph::new("empty-out");
        // No outputs at all (and no nodes): must refuse.
        assert!(g2.validate().unwrap_err().contains("no outputs"));
    }

    #[test]
    fn validate_rejects_forward_references() {
        let s = spec();
        let mut g = CellGraph::new("fwd");
        let x = g.input("x", s.io.input);
        let t = g.tanh("t", x, s);
        g.mark_output("t", t);
        // Corrupt the activation to point at itself.
        g.nodes[t.0].op = Op::Activation { input: t, act: ActSpec::tanh(s) };
        let err = g.validate().unwrap_err();
        assert!(err.contains("precede"), "unexpected error: {err}");
    }

    #[test]
    fn validate_rejects_duplicate_names() {
        let s = spec();
        let mut g = CellGraph::new("dup");
        let a = g.input("x", s.io.input);
        let _b = g.input("x", s.io.input);
        let t = g.tanh("t", a, s);
        g.mark_output("t", t);
        assert!(g.validate().unwrap_err().contains("duplicate input"));
    }

    #[test]
    fn sigmoid_nodes_do_not_demand_backend_specs() {
        let s = spec();
        let mut g = CellGraph::new("sig");
        let x = g.input("x", s.io.input);
        let y = g.sigmoid("y", x, s);
        g.mark_output("y", y);
        g.validate().expect("valid");
        assert!(g.activation_specs().is_empty());
    }
}
