//! Raw-word fixed-point elementwise primitives for cell graphs.
//!
//! The graph executor carries bare `i64` raw words between nodes (the
//! same representation the compiled kernels and the backends speak), so
//! the elementwise ops need raw-in/raw-out forms. Every helper here is
//! a thin wrapper over the scalar [`crate::fixed`] reference semantics
//! — [`fx_mul`], [`fx_add`], [`Fx::convert`] — and is therefore
//! bit-exact against them *by construction*; `tests/property.rs` pins
//! each one against its `Fx` reference over full-format grids anyway,
//! including the saturation edges and every rounding mode.
//!
//! Two helpers have no one-call `Fx` equivalent and are documented
//! where they differ:
//!
//! - [`one_minus_raw`] computes `1 − x` through a two-integer-bit-wider
//!   intermediate. `fx_sub(Fx::one(dst), x, …)` would be wrong for
//!   fraction-only formats: `Fx::one(S.15)` already saturates to
//!   `1 − 2⁻¹⁵` *before* the subtract. Widening first keeps the
//!   subtraction exact; the single rounding/clamp happens at the final
//!   conversion, like every other op.
//! - [`sigmoid_post_raw`] is the `(1 + t) / 2` tail of the
//!   sigmoid-from-tanh identity, bit-identical to the corresponding
//!   lines of [`crate::approx::sigmoid::SigmoidFromTanh::eval_fx`].

use crate::fixed::{fx_add, fx_mul, Fx, QFormat, Round};

/// Fixed-point multiply on raw words: exact wide product, one
/// rounding/saturation into `dst` — precisely [`fx_mul`].
#[inline]
pub fn mul_raw(a: i64, a_fmt: QFormat, b: i64, b_fmt: QFormat, dst: QFormat, round: Round) -> i64 {
    fx_mul(Fx::from_raw(a, a_fmt), Fx::from_raw(b, b_fmt), dst, round).raw()
}

/// Fixed-point add on raw words: both operands converted to `dst`,
/// then a saturating add — precisely [`fx_add`].
#[inline]
pub fn add_raw(a: i64, a_fmt: QFormat, b: i64, b_fmt: QFormat, dst: QFormat, round: Round) -> i64 {
    fx_add(Fx::from_raw(a, a_fmt), Fx::from_raw(b, b_fmt), dst, round).raw()
}

/// Format conversion on raw words — precisely [`Fx::convert`]: exact
/// when widening, one rounding + clamp when narrowing, identity when
/// `src == dst`.
#[inline]
pub fn requant_raw(v: i64, src: QFormat, dst: QFormat, round: Round) -> i64 {
    Fx::from_raw(v, src).convert(dst, round).raw()
}

/// `1 − x` on raw words (the GRU update-gate complement). The
/// subtraction runs in `S(int+2).(frac)` where it is exact for every
/// representable `x` (including `x = min_raw`, whose complement exceeds
/// one extra integer bit); the only rounding/clamp is the final
/// conversion into `dst`. Requires `src.width() ≤ 61` (validated by
/// [`super::CellGraph::validate`]).
#[inline]
pub fn one_minus_raw(v: i64, src: QFormat, dst: QFormat, round: Round) -> i64 {
    let wide = QFormat::new(src.int_bits + 2, src.frac_bits);
    let diff = (1i64 << src.frac_bits) - v;
    Fx::from_raw(diff, wide).convert(dst, round).raw()
}

/// The format an `x/2` reinterpretation produces: one integer bit
/// traded for one fraction bit, same raw word — the sigmoid identity's
/// input shift, exact with zero hardware
/// ([`crate::approx::sigmoid::SigmoidFromTanh::eval_fx`]).
#[inline]
pub fn halve_fmt(fmt: QFormat) -> QFormat {
    QFormat::new(fmt.int_bits.saturating_sub(1), fmt.frac_bits + 1)
}

/// The `(1 + t) / 2` tail of `σ(x) = (1 + tanh(x/2)) / 2`: increment by
/// 1.0 in `t_fmt`, then one round-to-nearest-even shift into `out` —
/// line-for-line the integer steps of
/// [`crate::approx::sigmoid::SigmoidFromTanh::eval_fx`], so the fused
/// graph form is bit-identical to the scalar wrapper. Requires
/// `t_fmt.frac_bits + 1 ≥ out.frac_bits` (holds for the validated
/// `t_fmt = S1.(out.frac+1)` by construction).
#[inline]
pub fn sigmoid_post_raw(t: i64, t_fmt: QFormat, out: QFormat) -> i64 {
    debug_assert!(t_fmt.frac_bits + 1 >= out.frac_bits);
    let raw = (1i64 << t_fmt.frac_bits) + t;
    let shifted =
        Round::NearestEven.shift_right(raw as i128, 1 + t_fmt.frac_bits - out.frac_bits) as i64;
    Fx::from_raw(shifted, out).raw()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mul_and_add_match_fx_spot_checks() {
        let (a, b, dst) = (QFormat::S_15, QFormat::S2_13, QFormat::S2_13);
        for round in [Round::Trunc, Round::NearestAway, Round::NearestEven] {
            for (x, y) in [(0, 0), (1, -1), (12345, 6789), (a.max_raw(), b.min_raw())] {
                let fx = fx_mul(Fx::from_raw(x, a), Fx::from_raw(y, b), dst, round);
                assert_eq!(mul_raw(x, a, y, b, dst, round), fx.raw());
                let fa = fx_add(Fx::from_raw(x, a), Fx::from_raw(y, b), dst, round);
                assert_eq!(add_raw(x, a, y, b, dst, round), fa.raw());
            }
        }
    }

    #[test]
    fn one_minus_is_exact_where_the_naive_fx_form_saturates() {
        // 1 − 0 = 1.0 saturates in S.15 (to max_raw) — but only at the
        // final conversion, not before the subtract.
        let f = QFormat::S_15;
        assert_eq!(one_minus_raw(0, f, f, Round::NearestAway), f.max_raw());
        // 1 − max = one ulp: exact.
        assert_eq!(one_minus_raw(f.max_raw(), f, f, Round::NearestAway), 1);
        // 1 − (−1.0) = 2.0: needs the wide intermediate, clamps at dst.
        assert_eq!(one_minus_raw(f.min_raw(), f, f, Round::NearestAway), f.max_raw());
        // In a roomier destination the same complement is exact.
        let d = QFormat::S2_13;
        assert_eq!(
            one_minus_raw(f.min_raw(), f, d, Round::NearestAway),
            2 << d.frac_bits
        );
    }

    #[test]
    fn requant_round_trips_when_widening() {
        let (narrow, wide) = (QFormat::S_7, QFormat::S3_12);
        for v in narrow.min_raw()..=narrow.max_raw() {
            let up = requant_raw(v, narrow, wide, Round::Trunc);
            assert_eq!(requant_raw(up, wide, narrow, Round::Trunc), v);
        }
    }

    #[test]
    fn halve_fmt_preserves_the_raw_range_for_signed_int_formats() {
        let f = QFormat::S3_12;
        let h = halve_fmt(f);
        assert_eq!(h, QFormat::new(2, 13));
        assert_eq!(h.max_raw(), f.max_raw());
        assert_eq!(h.min_raw(), f.min_raw());
        // Reinterpreting the same raw halves the value exactly.
        let x = Fx::from_f64(3.5, f);
        assert_eq!(Fx::from_raw(x.raw(), h).to_f64(), 1.75);
    }

    #[test]
    fn sigmoid_post_maps_tanh_range_into_0_1() {
        let out = QFormat::S_15;
        let t_fmt = QFormat::new(1, out.frac_bits + 1);
        // t = 0 → σ = 0.5 exactly.
        assert_eq!(sigmoid_post_raw(0, t_fmt, out), 1 << (out.frac_bits - 1));
        // t = −1.0 → σ = 0; t = +max → σ ≈ 1 (clamped to max).
        assert_eq!(sigmoid_post_raw(-(1 << t_fmt.frac_bits), t_fmt, out), 0);
        let hi = sigmoid_post_raw(t_fmt.max_raw(), t_fmt, out);
        assert_eq!(hi, out.max_raw());
    }
}
