//! Graph rewrite passes, in the spirit of tract's `ModelPatch`:
//! functional graph-to-graph transforms that rebuild the node list with
//! an old-id → new-id map, so the input graph is never mutated and
//! every pass preserves topological order by construction.
//!
//! The passes are semantics-preserving at the *bit* level:
//!
//! - [`fuse_sigmoid`] lowers each sigmoid activation onto the shared
//!   tanh kernel path: `Halve` (exact reinterpretation) → tanh
//!   `Activation` on the derived spec
//!   ([`SigmoidKernel::derived_tanh_spec`]) → [`super::Op::SigmoidPost`]. The
//!   expansion is line-for-line the integer datapath of
//!   `SigmoidFromTanh::eval_fx`, so fused and unfused graphs are
//!   bit-identical (asserted in `tests/property.rs`) — but the fused
//!   form's tanh goes through the backend / [`Registry`] instead of a
//!   fresh scalar model per node per execute.
//! - [`merge_requants`] drops identity conversions and collapses
//!   requant chains whose inner step is exact (widening both fields):
//!   only there is `convert(convert(x, mid), dst)` guaranteed equal to
//!   `convert(x, dst)` — a lossy inner step would legitimize double
//!   rounding, so it is left alone.
//! - [`dedup`] merges structurally identical non-input nodes (same op
//!   after remapping, same format) — e.g. all three LSTM sigmoid gates
//!   share one fused `Halve` shape per distinct operand, and identical
//!   pre-activation routings collapse to one activation evaluation.
//! - [`prune`] removes nodes no output (transitively) uses.
//!
//! [`optimize`] runs all four in that order and re-validates.
//!
//! [`SigmoidKernel::derived_tanh_spec`]: crate::approx::SigmoidKernel::derived_tanh_spec
//! [`Registry`]: crate::approx::Registry

use crate::approx::{ActKind, SigmoidKernel};

use super::{CellGraph, NodeId, Op};

/// What `optimize` did, for logs and tests.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct RewriteStats {
    /// Sigmoid activations lowered onto shared tanh kernels.
    pub fused_sigmoids: usize,
    /// Requant nodes dropped (identity) or collapsed (exact chains).
    pub merged_requants: usize,
    /// Structurally identical nodes merged.
    pub deduped_nodes: usize,
    /// Dead nodes removed.
    pub pruned_nodes: usize,
}

/// Lowers every sigmoid activation onto the tanh kernel path. Returns
/// the rewritten graph and the number of sigmoids fused.
pub fn fuse_sigmoid(g: &CellGraph) -> Result<(CellGraph, usize), String> {
    let mut out = CellGraph::new(g.name());
    let mut map: Vec<NodeId> = Vec::with_capacity(g.len());
    let mut fused = 0;
    for node in g.nodes() {
        let id = match &node.op {
            Op::Activation { input, act } if act.kind == ActKind::Sigmoid => {
                let derived = SigmoidKernel::derived_tanh_spec(&act.spec)
                    .map_err(|e| format!("fusing sigmoid '{}': {e}", node.label))?;
                let x = map[input.index()];
                let h = out.halve(format!("{}.half", node.label), x);
                let t = out.tanh(format!("{}.tanh", node.label), h, derived);
                fused += 1;
                out.sigmoid_post(node.label.clone(), t, node.fmt)
            }
            op => out.push(op.remap(&map), node.fmt, node.label.clone()),
        };
        map.push(id);
    }
    for (name, id) in g.outputs() {
        out.mark_output(name.clone(), map[id.index()]);
    }
    Ok((out, fused))
}

/// Drops identity requants and collapses requant-of-requant chains
/// whose inner conversion is exact (destination widens both bit
/// fields). Returns the rewritten graph and the number of requants
/// eliminated.
pub fn merge_requants(g: &CellGraph) -> (CellGraph, usize) {
    let mut out = CellGraph::new(g.name());
    let mut map: Vec<NodeId> = Vec::with_capacity(g.len());
    let mut merged = 0;
    for node in g.nodes() {
        let id = match &node.op {
            Op::Requant { input, round } => {
                let src = map[input.index()];
                if out.fmt_of(src) == node.fmt {
                    // Identity conversion: forward users to the operand.
                    merged += 1;
                    src
                } else {
                    // If the operand is itself a requant that only
                    // widened (exact), read through it.
                    let through = match &out.node(src).op {
                        Op::Requant { input: grand, .. } => {
                            let (gf, sf) = (out.fmt_of(*grand), out.fmt_of(src));
                            let exact_inner =
                                sf.int_bits >= gf.int_bits && sf.frac_bits >= gf.frac_bits;
                            if exact_inner {
                                Some(*grand)
                            } else {
                                None
                            }
                        }
                        _ => None,
                    };
                    match through {
                        Some(grand) => {
                            merged += 1;
                            out.push(
                                Op::Requant { input: grand, round: *round },
                                node.fmt,
                                node.label.clone(),
                            )
                        }
                        None => out.push(
                            Op::Requant { input: src, round: *round },
                            node.fmt,
                            node.label.clone(),
                        ),
                    }
                }
            }
            op => out.push(op.remap(&map), node.fmt, node.label.clone()),
        };
        map.push(id);
    }
    for (name, id) in g.outputs() {
        out.mark_output(name.clone(), map[id.index()]);
    }
    (out, merged)
}

/// Merges structurally identical non-input nodes: same post-remap op,
/// same format. Inputs are the external interface and never merge.
pub fn dedup(g: &CellGraph) -> (CellGraph, usize) {
    let mut out = CellGraph::new(g.name());
    let mut map: Vec<NodeId> = Vec::with_capacity(g.len());
    let mut deduped = 0;
    for node in g.nodes() {
        let op = node.op.remap(&map);
        let existing = if matches!(op, Op::Input) {
            None
        } else {
            out.nodes().iter().position(|n| n.op == op && n.fmt == node.fmt)
        };
        let id = match existing {
            Some(i) => {
                deduped += 1;
                NodeId(i)
            }
            None => out.push(op, node.fmt, node.label.clone()),
        };
        map.push(id);
    }
    for (name, id) in g.outputs() {
        out.mark_output(name.clone(), map[id.index()]);
    }
    (out, deduped)
}

/// Removes nodes no output transitively uses (inputs are kept: they are
/// the graph's external interface even when ignored).
pub fn prune(g: &CellGraph) -> (CellGraph, usize) {
    let mut live = vec![false; g.len()];
    for (_, id) in g.outputs() {
        live[id.index()] = true;
    }
    // Operands precede users, so one reverse scan propagates liveness.
    for i in (0..g.len()).rev() {
        if live[i] {
            for d in g.nodes()[i].op.operands() {
                live[d.index()] = true;
            }
        }
    }
    for (i, n) in g.nodes().iter().enumerate() {
        if matches!(n.op, Op::Input) {
            live[i] = true;
        }
    }
    let mut out = CellGraph::new(g.name());
    let mut map: Vec<NodeId> = Vec::with_capacity(g.len());
    let mut pruned = 0;
    for (i, node) in g.nodes().iter().enumerate() {
        if live[i] {
            let id = out.push(node.op.remap(&map), node.fmt, node.label.clone());
            map.push(id);
        } else {
            pruned += 1;
            // Never read: only live nodes' operands are dereferenced,
            // and operands of live nodes are live.
            map.push(NodeId(usize::MAX));
        }
    }
    for (name, id) in g.outputs() {
        out.mark_output(name.clone(), map[id.index()]);
    }
    (out, pruned)
}

/// The full pass pipeline: fuse sigmoids, merge requants, dedup, prune,
/// then re-validate the result.
pub fn optimize(g: &CellGraph) -> Result<(CellGraph, RewriteStats), String> {
    let (g, fused_sigmoids) = fuse_sigmoid(g)?;
    let (g, merged_requants) = merge_requants(&g);
    let (g, deduped_nodes) = dedup(&g);
    let (g, pruned_nodes) = prune(&g);
    g.validate()?;
    Ok((g, RewriteStats { fused_sigmoids, merged_requants, deduped_nodes, pruned_nodes }))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::approx::{MethodId, MethodSpec};
    use crate::fixed::{QFormat, Round};
    use crate::graph::cell::{lstm_cell, CellConfig};

    fn spec() -> MethodSpec {
        MethodSpec::table1(MethodId::Pwl)
    }

    #[test]
    fn fuse_replaces_sigmoids_with_tanh_triplets() {
        let g = lstm_cell(&CellConfig::table1_lstm()).unwrap();
        let (f, stats) = optimize(&g).unwrap();
        assert_eq!(stats.fused_sigmoids, 3);
        // No sigmoid activations remain; the derived tanh spec joins
        // the backend-facing spec set.
        for n in f.nodes() {
            if let Op::Activation { act, .. } = &n.op {
                assert_eq!(act.kind, crate::approx::ActKind::Tanh, "node '{}'", n.label);
            }
        }
        // gate tanh + state tanh + derived sigmoid tanh = 3 specs.
        assert_eq!(f.activation_specs().len(), 3);
        f.validate().unwrap();
    }

    #[test]
    fn dedup_collapses_identical_gate_routings() {
        // Route the same pre-activation into two sigmoid gates: after
        // fusion + dedup they must share one halve/tanh/post chain.
        let s = spec();
        let mut g = CellGraph::new("twin");
        let x = g.input("x", s.io.input);
        let a = g.sigmoid("a", x, s);
        let b = g.sigmoid("b", x, s);
        g.mark_output("a", a);
        g.mark_output("b", b);
        let (opt, stats) = optimize(&g).unwrap();
        assert_eq!(stats.fused_sigmoids, 2);
        assert_eq!(stats.deduped_nodes, 3, "halve + tanh + post all merge");
        assert_eq!(opt.output("a"), opt.output("b"));
        assert_eq!(opt.len(), 4);
    }

    #[test]
    fn merge_drops_identity_and_collapses_exact_chains() {
        let s = spec();
        let mut g = CellGraph::new("rq");
        let x = g.input("x", QFormat::S_15);
        // Identity requant.
        let a = g.requant("same", x, QFormat::S_15, Round::NearestAway);
        // Exact widening then narrowing: collapses to one conversion.
        let w = g.requant("widen", a, QFormat::new(2, 16), Round::Trunc);
        let n = g.requant("narrow", w, QFormat::S_7, Round::NearestAway);
        g.mark_output("y", n);
        let (opt, stats) = optimize(&g).unwrap();
        assert_eq!(stats.merged_requants, 2);
        assert_eq!(stats.pruned_nodes, 1, "the read-through widen goes dead");
        // input + the single surviving requant.
        assert_eq!(opt.len(), 2);
        // Lossy inner steps must NOT collapse (double rounding).
        let mut h = CellGraph::new("lossy");
        let x = h.input("x", QFormat::S3_12);
        let mid = h.requant("narrow1", x, QFormat::S_7, Round::NearestAway);
        let fin = h.requant("narrow2", mid, QFormat::S_15, Round::NearestAway);
        h.mark_output("y", fin);
        let (opt2, merged) = merge_requants(&h);
        assert_eq!(merged, 0, "lossy chains stay as-is");
        assert_eq!(opt2.len(), 3);
    }

    #[test]
    fn prune_removes_dead_nodes_but_keeps_inputs() {
        let s = spec();
        let mut g = CellGraph::new("dead");
        let x = g.input("x", s.io.input);
        let y = g.input("y", s.io.input);
        let t = g.tanh("t", x, s);
        let _dead = g.tanh("dead", y, s);
        g.mark_output("t", t);
        let (opt, pruned) = prune(&g);
        assert_eq!(pruned, 1);
        assert_eq!(opt.inputs().len(), 2, "unused inputs survive");
        opt.validate().unwrap();
    }

    #[test]
    fn optimize_is_idempotent_on_the_lstm_graph() {
        let g = lstm_cell(&CellConfig::table1_lstm()).unwrap();
        let (once, _) = optimize(&g).unwrap();
        let (twice, stats) = optimize(&once).unwrap();
        assert_eq!(stats, RewriteStats::default(), "second pass finds nothing");
        assert_eq!(once.len(), twice.len());
    }
}
