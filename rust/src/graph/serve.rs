//! Cell graphs as served workloads: every activation batch of a cell
//! step round-trips through the sharded [`Coordinator`], so graph
//! traffic exercises admission control, batching, sharding and the
//! spec-keyed kernel cache exactly like flat tanh traffic does — this
//! is the `lstm` bench scenario's engine.
//!
//! Verification protocol (per step, per sequence, deterministic):
//!
//! 1. the step's served outputs are compared **bit-for-bit** against a
//!    direct [`FreshKernelSink`] execution of the same graph on the
//!    same raw inputs (cache-bypassing golden kernels — the coordinator
//!    round trip must be lossless);
//! 2. every gate output is compared against the f64 reference
//!    ([`execute_ref`]) of the same quantized inputs, under the
//!    [`CellConfig::budget`]. The reference reads the *served previous
//!    state* each step, so the budget bounds per-step error without
//!    letting float/fixed trajectories drift apart over long sequences.
//!
//! The carried cell state is the served `c_next`, making consecutive
//! steps a genuine recurrence over served values.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

use crate::approx::MethodSpec;
use crate::backend::{dequantize_output, quantize_input, BackendError, ErrorCode, EvalBackend};
use crate::coordinator::Coordinator;
use crate::fixed::{Fx, QFormat};
use crate::util::prng::Prng;

use super::cell::{lstm_cell, CellConfig};
use super::exec::{execute_raw, execute_ref, ActivationSink, FreshKernelSink};
use super::rewrite::optimize;
use super::CellGraph;

/// How many times one activation batch retries `Overloaded` admission
/// before giving up (20 µs backoff per retry, matching the scenario
/// runner's pacing).
const OVERLOAD_RETRIES: usize = 500_000;

/// [`ActivationSink`] that evaluates through a live [`Coordinator`]:
/// raw lanes are dequantized to the f32 wire form, submitted as a
/// normal request, and the reply is re-quantized to raw words. Both
/// hops are exact for every format the spec layer admits (raw
/// magnitudes < 2²⁴ round-trip through f32 losslessly), so serving
/// adds no numeric error — asserted by the bit-identity check in
/// [`run_lstm_cells`].
pub struct CoordinatorSink<'a> {
    coord: &'a Coordinator,
    requests: AtomicU64,
    elements: AtomicU64,
    retries: AtomicU64,
}

impl<'a> CoordinatorSink<'a> {
    pub fn new(coord: &'a Coordinator) -> CoordinatorSink<'a> {
        CoordinatorSink {
            coord,
            requests: AtomicU64::new(0),
            elements: AtomicU64::new(0),
            retries: AtomicU64::new(0),
        }
    }

    /// Requests successfully served through the coordinator.
    pub fn requests(&self) -> u64 {
        self.requests.load(Ordering::Relaxed)
    }

    /// Elements (lanes × activations) served.
    pub fn elements(&self) -> u64 {
        self.elements.load(Ordering::Relaxed)
    }

    /// Overloaded admissions that were retried.
    pub fn retries(&self) -> u64 {
        self.retries.load(Ordering::Relaxed)
    }
}

impl ActivationSink for CoordinatorSink<'_> {
    fn ensure(&self, spec: &MethodSpec) -> Result<(), String> {
        if self.coord.specs().contains(spec) {
            Ok(())
        } else {
            Err(format!(
                "coordinator does not serve spec '{spec}' (serving: {})",
                self.coord
                    .specs()
                    .iter()
                    .map(|s| s.to_string())
                    .collect::<Vec<_>>()
                    .join(", ")
            ))
        }
    }

    fn eval(&self, spec: &MethodSpec, input: &[i64], output: &mut [i64]) -> Result<(), String> {
        let values = dequantize_output(input, spec.io.input);
        let mut reply = None;
        for _ in 0..OVERLOAD_RETRIES {
            match self.coord.evaluate_spec(spec, values.clone()) {
                Ok(v) => {
                    reply = Some(v);
                    break;
                }
                Err(e) if e.code == ErrorCode::Overloaded => {
                    self.retries.fetch_add(1, Ordering::Relaxed);
                    std::thread::sleep(Duration::from_micros(20));
                }
                Err(e) => return Err(format!("serving '{spec}': {e}")),
            }
        }
        let reply = reply.ok_or_else(|| format!("serving '{spec}': overload retry budget spent"))?;
        if reply.len() != input.len() {
            return Err(format!(
                "serving '{spec}': reply carries {} lanes, expected {}",
                reply.len(),
                input.len()
            ));
        }
        output.copy_from_slice(&quantize_input(&reply, spec.io.output));
        self.requests.fetch_add(1, Ordering::Relaxed);
        self.elements.fetch_add(input.len() as u64, Ordering::Relaxed);
        Ok(())
    }
}

/// Server-held LSTM cell state for one streaming session: the client
/// feeds one cell step per pulse as `4·lanes` raw gate pre-activations
/// (`i|f|g|o` concatenated, gate input format), the server carries the
/// cell state `c` across pulses, and each pulse replies with the
/// step's `h_next` lanes (gate output format). Zero delay: the
/// recurrence is sequential, so every pulse's reply is complete —
/// there is no pipeline skew to account for.
pub struct CellSession {
    graph: CellGraph,
    lanes: usize,
    c: Vec<i64>,
    steps: u64,
}

impl CellSession {
    /// Builds the optimized LSTM step graph for `cfg` (sigmoid gates
    /// fused onto shared tanh kernels) and ensures its activation
    /// specs on `backend`. Typed failure when the backend cannot
    /// express a spec, so wire clients see `unknown_spec`, not a
    /// mangled string.
    pub fn open(
        backend: &dyn EvalBackend,
        cfg: &CellConfig,
        lanes: usize,
    ) -> Result<CellSession, BackendError> {
        if lanes == 0 {
            return Err(BackendError::bad_request("cell session needs at least one lane"));
        }
        let graph = lstm_cell(cfg).map_err(BackendError::bad_request)?;
        let (fused, _) = optimize(&graph).map_err(BackendError::internal)?;
        for spec in fused.activation_specs() {
            backend.ensure(&spec).map_err(|e| {
                BackendError::new(e.code, format!("cell session spec '{spec}': {}", e.message))
            })?;
        }
        Ok(CellSession { graph: fused, lanes, c: vec![0; lanes], steps: 0 })
    }

    /// Lanes per step — each pulse must carry `4·lanes` words and each
    /// reply carries `lanes`.
    pub fn lanes(&self) -> usize {
        self.lanes
    }

    /// Cell steps executed so far.
    pub fn steps(&self) -> u64 {
        self.steps
    }

    /// The carried cell state (raw words in the state format) — what
    /// cold-replay verification compares against.
    pub fn state(&self) -> &[i64] {
        &self.c
    }

    /// One pulse = one cell step over `backend`. Returns the served
    /// `h_next` lanes plus the simulated cycles the step's activation
    /// batches occupied the backend, and advances the carried state to
    /// the served `c_next`.
    pub fn pulse(
        &mut self,
        backend: &dyn EvalBackend,
        pre: &[i64],
    ) -> Result<(Vec<i64>, u64), String> {
        if pre.len() != 4 * self.lanes {
            return Err(format!(
                "cell pulse carries {} words, expected 4·lanes = {}",
                pre.len(),
                4 * self.lanes
            ));
        }
        let l = self.lanes;
        let inputs: Vec<(&str, Vec<i64>)> = vec![
            ("i_pre", pre[..l].to_vec()),
            ("f_pre", pre[l..2 * l].to_vec()),
            ("g_pre", pre[2 * l..3 * l].to_vec()),
            ("o_pre", pre[3 * l..].to_vec()),
            ("c_prev", self.c.clone()),
        ];
        let sink = TallySink { backend, sim_cycles: std::cell::Cell::new(0) };
        let out = execute_raw(&self.graph, &inputs, &sink)?;
        let mut h = None;
        for (name, v) in out {
            match name.as_str() {
                "c_next" => self.c = v,
                "h_next" => h = Some(v),
                _ => {}
            }
        }
        self.steps += 1;
        Ok((h.expect("lstm graph exports h_next"), sink.sim_cycles.get()))
    }
}

/// [`super::exec::BackendSink`] variant that tallies the backend's
/// reported simulated cycles, so streamed cell steps land in the
/// coordinator's `sim_cycles` accounting like flat spec pulses do.
struct TallySink<'a> {
    backend: &'a dyn EvalBackend,
    sim_cycles: std::cell::Cell<u64>,
}

impl ActivationSink for TallySink<'_> {
    fn ensure(&self, spec: &MethodSpec) -> Result<(), String> {
        self.backend.ensure(spec).map_err(|e| e.to_string())
    }

    fn eval(&self, spec: &MethodSpec, input: &[i64], output: &mut [i64]) -> Result<(), String> {
        let stats = self.backend.eval_raw(spec, input, output).map_err(|e| e.to_string())?;
        self.sim_cycles.set(self.sim_cycles.get() + stats.sim_cycles);
        Ok(())
    }
}

/// Shape of an `lstm` scenario run: `sequences` independent cell-state
/// recurrences, each stepped `steps` times over `lanes` parallel cells.
#[derive(Clone, Copy, Debug)]
pub struct CellRunConfig {
    pub sequences: usize,
    pub steps: usize,
    pub lanes: usize,
    pub seed: u64,
}

impl CellRunConfig {
    /// The bench-default shape, scaled like the flat scenarios: `scale`
    /// multiplies the step count (0.1 in smoke runs, 1.0 in full runs).
    pub fn scaled(seed: u64, scale: f64) -> CellRunConfig {
        CellRunConfig {
            sequences: 4,
            steps: (((32.0 * scale) as usize).max(1)).min(10_000),
            lanes: 64,
            seed,
        }
    }
}

/// Aggregated result of [`run_lstm_cells`].
#[derive(Clone, Copy, Debug, Default)]
pub struct CellRunStats {
    /// Cell steps executed (sequences × steps).
    pub cell_steps: u64,
    /// Steps that passed both verification layers (== cell_steps on
    /// success; the run errors out otherwise).
    pub verified: u64,
    /// Max |served − f64 reference| over every gate, lane and step.
    pub gate_max_err: f64,
    /// Coordinator requests issued (activation batches).
    pub requests: u64,
    /// Elements served (lanes × activations).
    pub elements: u64,
    /// Overloaded admissions retried.
    pub retries: u64,
}

/// Drives `run.sequences` concurrent LSTM recurrences through the
/// coordinator, verifying every step (see module docs). The
/// coordinator must be serving `graph.activation_specs()`; pass the
/// *rewritten* graph ([`super::rewrite::optimize`]) so sigmoid gates
/// ride the shared tanh kernels.
pub fn run_lstm_cells(
    coord: &Coordinator,
    cfg: &CellConfig,
    graph: &CellGraph,
    run: &CellRunConfig,
) -> Result<CellRunStats, String> {
    graph.validate()?;
    if run.lanes == 0 || run.steps == 0 || run.sequences == 0 {
        return Err("lstm run needs nonzero sequences, steps and lanes".to_string());
    }
    let sink = CoordinatorSink::new(coord);
    let fresh = FreshKernelSink::for_graph(graph);
    let in_fmts: HashMap<&str, QFormat> =
        graph.inputs().into_iter().map(|(n, _, f)| (n, f)).collect();
    for name in ["i_pre", "f_pre", "g_pre", "o_pre", "c_prev"] {
        if !in_fmts.contains_key(name) {
            return Err(format!("graph '{}' lacks LSTM input '{name}'", graph.name()));
        }
    }
    if graph.output("c_next").is_none() {
        return Err(format!("graph '{}' lacks a c_next output", graph.name()));
    }
    let pre_fmt = in_fmts["i_pre"];

    let per_seq: Vec<Result<(u64, f64), String>> = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..run.sequences)
            .map(|t| {
                let (sink, fresh, in_fmts) = (&sink, &fresh, &in_fmts);
                scope.spawn(move || -> Result<(u64, f64), String> {
                    let mut prng = Prng::new(
                        run.seed ^ 0x9E3779B97F4A7C15u64.wrapping_mul(t as u64 + 1),
                    );
                    let mut c: Vec<i64> = vec![0; run.lanes];
                    let mut max_err = 0.0f64;
                    let mut steps = 0u64;
                    for _ in 0..run.steps {
                        let draw = |p: &mut Prng| -> Vec<i64> {
                            (0..run.lanes)
                                .map(|_| Fx::from_f64(p.f64_in(-6.0, 6.0), pre_fmt).raw())
                                .collect()
                        };
                        let inputs: Vec<(&str, Vec<i64>)> = vec![
                            ("i_pre", draw(&mut prng)),
                            ("f_pre", draw(&mut prng)),
                            ("g_pre", draw(&mut prng)),
                            ("o_pre", draw(&mut prng)),
                            ("c_prev", c.clone()),
                        ];
                        let served = execute_raw(graph, &inputs, sink)?;
                        let direct = execute_raw(graph, &inputs, fresh)?;
                        for ((name, a), (_, b)) in served.iter().zip(&direct) {
                            if a != b {
                                return Err(format!(
                                    "served output '{name}' diverges bit-wise from the \
                                     direct golden execution"
                                ));
                            }
                        }
                        let ref_inputs: Vec<(&str, Vec<f64>)> = inputs
                            .iter()
                            .map(|(n, v)| {
                                let ulp = in_fmts[n].ulp();
                                (*n, v.iter().map(|&r| r as f64 * ulp).collect())
                            })
                            .collect();
                        let reference = execute_ref(graph, &ref_inputs)?;
                        for ((name, raws), (_, refs)) in served.iter().zip(&reference) {
                            let ulp = graph.fmt_of(graph.output(name).unwrap()).ulp();
                            for (&r, &x) in raws.iter().zip(refs) {
                                let err = (r as f64 * ulp - x).abs();
                                if err > cfg.budget {
                                    return Err(format!(
                                        "gate '{name}' err {err:.3e} exceeds budget {:.1e} \
                                         (seq {t}, step {steps})",
                                        cfg.budget
                                    ));
                                }
                                max_err = max_err.max(err);
                            }
                        }
                        c = served
                            .iter()
                            .find(|(n, _)| n.as_str() == "c_next")
                            .map(|(_, v)| v.clone())
                            .expect("checked above");
                        steps += 1;
                    }
                    Ok((steps, max_err))
                })
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().unwrap_or_else(|_| Err("cell sequence worker panicked".into())))
            .collect()
    });

    let mut stats = CellRunStats {
        requests: sink.requests(),
        elements: sink.elements(),
        retries: sink.retries(),
        ..CellRunStats::default()
    };
    for r in per_seq {
        let (steps, err) = r?;
        stats.cell_steps += steps;
        stats.verified += steps;
        stats.gate_max_err = stats.gate_max_err.max(err);
    }
    Ok(stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{CoordinatorConfig, RoutePolicy};
    use crate::graph::cell::lstm_cell;
    use crate::graph::rewrite::optimize;

    #[test]
    fn lstm_cells_serve_end_to_end_through_the_coordinator() {
        let cfg = CellConfig::table1_lstm();
        let graph = lstm_cell(&cfg).unwrap();
        let (fused, stats) = optimize(&graph).unwrap();
        assert_eq!(stats.fused_sigmoids, 3);
        let backend = crate::backend::by_name("golden", 256).unwrap();
        let coord = Coordinator::start(
            backend,
            CoordinatorConfig {
                shards: 2,
                route: RoutePolicy::RoundRobin,
                specs: fused.activation_specs(),
                ..CoordinatorConfig::default()
            },
        )
        .unwrap();
        let run = CellRunConfig { sequences: 2, steps: 3, lanes: 16, seed: 0xC0FFEE };
        let out = run_lstm_cells(&coord, &cfg, &fused, &run).unwrap();
        assert_eq!(out.cell_steps, 6);
        assert_eq!(out.verified, 6);
        assert!(out.gate_max_err > 0.0 && out.gate_max_err <= cfg.budget);
        // 5 activation nodes per step (i/f/o sigmoid-tanh, g, tanh_c).
        assert_eq!(out.requests, 6 * 5);
        assert_eq!(out.elements, 6 * 5 * 16);
        coord.shutdown();
    }

    #[test]
    fn cell_session_carries_state_and_matches_direct_recurrence() {
        let cfg = CellConfig::table1_lstm();
        let backend = crate::backend::GoldenBackend::new();
        let lanes = 8usize;
        let mut sess = CellSession::open(&backend, &cfg, lanes).unwrap();
        assert_eq!(sess.lanes(), lanes);
        assert_eq!(sess.state(), &vec![0i64; lanes][..]);
        // Cold replay reference: the same fused graph over fresh
        // kernels with an explicitly-carried c.
        let graph = optimize(&lstm_cell(&cfg).unwrap()).unwrap().0;
        let fresh = FreshKernelSink::for_graph(&graph);
        let mut c = vec![0i64; lanes];
        let mut prng = Prng::new(0xBEEF);
        for step in 0..5 {
            let pre: Vec<i64> = (0..4 * lanes)
                .map(|_| Fx::from_f64(prng.f64_in(-6.0, 6.0), cfg.spec.io.input).raw())
                .collect();
            let (h, _cycles) = sess.pulse(&backend, &pre).unwrap();
            let inputs: Vec<(&str, Vec<i64>)> = vec![
                ("i_pre", pre[..lanes].to_vec()),
                ("f_pre", pre[lanes..2 * lanes].to_vec()),
                ("g_pre", pre[2 * lanes..3 * lanes].to_vec()),
                ("o_pre", pre[3 * lanes..].to_vec()),
                ("c_prev", c.clone()),
            ];
            let direct = execute_raw(&graph, &inputs, &fresh).unwrap();
            let want_h = direct.iter().find(|(n, _)| n == "h_next").unwrap().1.clone();
            c = direct.iter().find(|(n, _)| n == "c_next").unwrap().1.clone();
            assert_eq!(h, want_h, "step {step}: session h_next diverges from cold replay");
            assert_eq!(sess.state(), &c[..], "step {step}: carried state diverges");
        }
        assert_eq!(sess.steps(), 5);
        // A wrong-size pulse is rejected without touching the state.
        let before = sess.state().to_vec();
        assert!(sess.pulse(&backend, &[0i64; 3]).unwrap_err().contains("4·lanes"));
        assert_eq!(sess.state(), &before[..]);
        // Zero lanes is a typed bad_request at open.
        let err = CellSession::open(&backend, &cfg, 0).unwrap_err();
        assert_eq!(err.code, ErrorCode::BadRequest);
    }

    #[test]
    fn unserved_specs_are_reported_not_mangled() {
        let cfg = CellConfig::table1_lstm();
        let graph = lstm_cell(&cfg).unwrap();
        let (fused, _) = optimize(&graph).unwrap();
        // Coordinator serving only the default Table I specs: the
        // derived sigmoid/state specs are missing.
        let backend = crate::backend::by_name("golden", 256).unwrap();
        let coord = Coordinator::start(backend, CoordinatorConfig::default()).unwrap();
        let run = CellRunConfig { sequences: 1, steps: 1, lanes: 4, seed: 1 };
        let err = run_lstm_cells(&coord, &cfg, &fused, &run).unwrap_err();
        assert!(err.contains("does not serve"), "{err}");
        coord.shutdown();
    }
}
