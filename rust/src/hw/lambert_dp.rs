//! Pipelined datapath for the Lambert continued fraction — the paper's
//! Fig 5 ("High level block diagram of iterative continuous fraction
//! method"): one identical recurrence stage per fraction term feeding a
//! final multiplier + Newton-Raphson divider. This is the structure the
//! paper highlights as "quite suitable for pipelined implementation".

use super::pipeline::{
    passthrough_ctl, sign_merge_stage, sign_split_input, BlockKind, Pipeline, Stage,
};
use super::signal::{sig, SignalMap, Value};
use crate::approx::lambert::Lambert;
use crate::approx::newton::{finish_div, normalize_den, nr_seed, nr_step, NR_ITERS};
use crate::approx::TanhApprox;
use crate::fixed::{fx_mul, fx_mul_wide, Fx, QFormat, Round};

/// Builds the Fig 5 pipeline:
/// `square → cf-stage ×K → numerator → normalize → nr-seed →
///  nr-iter ×i → finish → sign`.
pub fn lambert_pipeline(l: Lambert, out: QFormat) -> Pipeline {
    let domain = l.domain_max();
    let wf = l.wide_format();
    let w = wf.width();
    let k_terms = l.terms();
    let kk = 2 * k_terms as i64 + 1;

    let mut stages: Vec<Stage> = Vec::new();

    // x² + constant initialization (T_{-1} = 1, T_0 = 2K+1).
    stages.push(Stage::new("square", vec![BlockKind::Square(w)], move |r| {
        let mag = sig(r, "mag").fx();
        let x2 = fx_mul_wide(mag, mag).narrow(wf, Round::NearestAway);
        let mut m = SignalMap::new();
        m.insert("x", Value::Fx(mag));
        m.insert("x2", Value::Fx(x2));
        m.insert("tm1", Value::Fx(Fx::one(wf)));
        m.insert("t0", Value::Fx(Fx::from_f64(kk as f64, wf)));
        passthrough_ctl(r, &mut m);
        m
    }));

    // K identical recurrence stages: T_n = c_n·T_{n−1} + x²·T_{n−2}.
    for n in 1..=k_terms {
        let c = (kk - 2 * n as i64) as f64;
        stages.push(Stage::new(
            format!("cf[{n}]"),
            vec![BlockKind::Mul(w), BlockKind::Mul(w), BlockKind::Add(w)],
            move |r| {
                let x2 = sig(r, "x2").fx();
                let tm1 = sig(r, "tm1").fx();
                let t0 = sig(r, "t0").fx();
                let cfx = Fx::from_f64(c, wf);
                let t = fx_mul_wide(cfx, t0)
                    .add(fx_mul_wide(x2, tm1))
                    .narrow(wf, Round::NearestAway);
                let mut m = SignalMap::new();
                m.insert("x", sig(r, "x"));
                m.insert("x2", sig(r, "x2"));
                m.insert("tm1", Value::Fx(t0));
                m.insert("t0", Value::Fx(t));
                passthrough_ctl(r, &mut m);
                m
            },
        ));
    }

    // Numerator x·T_{K−1}; flag the (unreachable in-domain) T_K ≤ 0 case
    // the golden model clamps defensively.
    stages.push(Stage::new("numerator", vec![BlockKind::Mul(w)], move |r| {
        let x = sig(r, "x").fx();
        let tm1 = sig(r, "tm1").fx();
        let t0 = sig(r, "t0").fx();
        let mut m = SignalMap::new();
        m.insert("num", Value::Fx(fx_mul(x, tm1, wf, Round::NearestAway)));
        m.insert("den", Value::Fx(t0));
        m.insert("den_bad", Value::Flag(t0.raw() <= 0));
        passthrough_ctl(r, &mut m);
        m
    }));

    // Divider decomposition identical to `fx_div`.
    stages.push(Stage::new("normalize", vec![BlockKind::Shift(w)], move |r| {
        let den = sig(r, "den").fx();
        let bad = sig(r, "den_bad").flag();
        let (mant, e) = if bad { (Fx::from_f64(0.5, crate::approx::newton::NR_FMT), 1) } else { normalize_den(den) };
        let mut m = r.clone();
        m.insert("mant", Value::Fx(mant));
        m.insert("exp", Value::Raw(e as i64));
        m
    }));
    stages.push(Stage::new("nr-seed", vec![BlockKind::Mul(32), BlockKind::Add(32)], move |r| {
        let mut m = r.clone();
        m.insert("recip", Value::Fx(nr_seed(sig(r, "mant").fx())));
        m
    }));
    for i in 0..NR_ITERS {
        stages.push(Stage::new(
            format!("nr-iter{i}"),
            vec![BlockKind::Mul(32), BlockKind::Mul(32), BlockKind::Add(32)],
            move |r| {
                let mut m = r.clone();
                m.insert("recip", Value::Fx(nr_step(sig(r, "mant").fx(), sig(r, "recip").fx())));
                m
            },
        ));
    }
    stages.push(Stage::new("finish", vec![BlockKind::Mul(w)], move |r| {
        let bad = sig(r, "den_bad").flag();
        let y = if bad {
            Fx::max(out)
        } else {
            finish_div(sig(r, "num").fx(), sig(r, "recip").fx(), sig(r, "exp").raw() as i32, out)
        };
        let mut m = SignalMap::new();
        m.insert("y", Value::Fx(y));
        passthrough_ctl(r, &mut m);
        m
    }));
    stages.push(Stage::new("sign", vec![BlockKind::Mux(out.width())], sign_merge_stage(out)));

    Pipeline::new("lambert/fig5", move |x| sign_split_input(x, domain), stages, "y")
}

#[cfg(test)]
mod tests {
    use super::*;

    const INP: QFormat = QFormat::S3_12;
    const OUT: QFormat = QFormat::S_15;

    #[test]
    fn lambert_pipeline_matches_golden_sampled() {
        let golden = Lambert::table1();
        let pipe = lambert_pipeline(golden.clone(), OUT);
        for raw in (-(INP.max_raw())..=INP.max_raw()).step_by(173) {
            let x = Fx::from_raw(raw, INP);
            assert_eq!(
                pipe.eval(x).raw(),
                golden.eval_fx(x, OUT).raw(),
                "raw {raw} x={}",
                x.to_f64()
            );
        }
    }

    #[test]
    fn depth_is_k_plus_divider_overhead() {
        // square + K cf stages + numerator + (normalize, seed, iters,
        // finish) + sign.
        let l = Lambert::table1();
        let k = l.terms();
        let pipe = lambert_pipeline(l, OUT);
        assert_eq!(pipe.latency(), 1 + k + 1 + (3 + NR_ITERS) + 1);
    }

    #[test]
    fn scaling_k_adds_exactly_one_stage_per_term() {
        let p5 = lambert_pipeline(Lambert::new(5, 6.0), OUT);
        let p9 = lambert_pipeline(Lambert::new(9, 6.0), OUT);
        assert_eq!(p9.latency() - p5.latency(), 4);
    }
}
