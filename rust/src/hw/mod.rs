//! Cycle-level pipelined datapath simulator — the "VLSI implementation"
//! substrate of the paper's §IV, simulating the block diagrams of Fig 3
//! (polynomial methods), Fig 4 (velocity-factor method) and Fig 5
//! (iterative continued fraction).
//!
//! Each method is lowered to a [`Pipeline`] of combinational [`Stage`]s
//! separated by registers. The simulator:
//!
//! - produces **bit-exact** outputs (each stage is built from the same
//!   [`crate::fixed`] primitives as the golden `eval_fx` models, and the
//!   test suite asserts equality input-by-input);
//! - accounts **latency** (pipeline depth) and **throughput** (one
//!   result per cycle once full — the paper's §IV.H remark that rational
//!   methods hide their latency "if many back-to-back computations [are]
//!   required");
//! - reports per-stage **critical-path delay** via the cost library so
//!   the achievable frequency claim of §IV.H ("the circuit runs faster
//!   if LUTs are used") is checkable;
//! - supports **warm streaming** across batches ([`Pipeline::feed`] +
//!   [`StreamState`]): the next batch's issue cycles absorb the
//!   previous batch's drain, so a served stream pays the fill latency
//!   once instead of per batch — the hw backend's steady-state
//!   cycles/element observable;
//! - prices the **instantiated units** ([`Pipeline::area_ge`]) so the
//!   measured-cost explorer can put lowered area next to the analytic
//!   §IV inventory model.

mod lambert_dp;
mod pipeline;
mod poly_dp;
mod signal;
mod vf_dp;
pub mod verilog;

pub use lambert_dp::lambert_pipeline;
pub use pipeline::{BlockKind, FeedResult, Pipeline, SimResult, Stage, StreamState};
pub use poly_dp::{catmull_rom_pipeline, pwl_pipeline, taylor_pipeline};
pub use signal::{SignalMap, Value};
pub use vf_dp::velocity_pipeline;

use crate::approx::{MethodId, MethodParams, MethodSpec};
use crate::fixed::QFormat;

/// True when `v` is a reciprocal power of two — the structural
/// precondition of every Fig 3/4 LUT/register index extraction (the
/// index is a bit-field of the input, not a divider output).
fn recip_pow2(v: f64) -> bool {
    if !v.is_finite() || v <= 0.0 || v > 1.0 {
        return false;
    }
    let inv = 1.0 / v;
    inv.fract() == 0.0 && (inv as u64).is_power_of_two()
}

/// Lowers any design point to its pipelined Fig 3/4/5 datapath —
/// the general form of [`table1_pipeline`]: non-Table-I PWL/Taylor
/// step and Lambert/Taylor term variants lower to datapaths with the
/// matching LUT sizes, chain lengths and Horner depths.
///
/// Errors (with an "unsupported by hw backend" message naming the
/// structural reason) for specs the block diagrams cannot express —
/// e.g. a Taylor term count the fixed Horner chain is not wired for,
/// or a step that is not a reciprocal power of two (the LUT index is a
/// bit-field of the input, not a divider output). Validated specs
/// ([`MethodSpec::new`]/[`MethodSpec::parse`]) always lower; the
/// guards exist because `MethodSpec`'s fields are public and the hw
/// lowering trusts structure only validation establishes. Surfaced to
/// servers through
/// [`EvalBackend::ensure`](crate::backend::EvalBackend::ensure) on the
/// hw backend.
pub fn pipeline_for(spec: &MethodSpec) -> Result<Pipeline, String> {
    let out = spec.io.output;
    let unsupported =
        |what: String| format!("spec '{spec}' unsupported by hw backend: {what}");
    let check_pow2 = |name: &str, v: f64| {
        if recip_pow2(v) {
            Ok(())
        } else {
            Err(unsupported(format!(
                "{name} {v} is not a reciprocal power of two, so the Fig 3/4 \
                 index extraction (a bit-field select) cannot address it"
            )))
        }
    };
    Ok(match spec.params {
        MethodParams::Pwl { step } => {
            check_pow2("step", step)?;
            pwl_pipeline(crate::approx::pwl::Pwl::new(step, spec.domain), out)
        }
        MethodParams::Taylor { step, terms } => {
            if !(3..=4).contains(&terms) {
                return Err(unsupported(format!(
                    "the Fig 3 Horner chain is wired for 3-term (B1) or 4-term (B2) \
                     expansions, not {terms}"
                )));
            }
            check_pow2("step", step)?;
            taylor_pipeline(crate::approx::taylor::Taylor::new(step, terms, spec.domain), out)
        }
        MethodParams::CatmullRom { step } => {
            check_pow2("step", step)?;
            catmull_rom_pipeline(
                crate::approx::catmull_rom::CatmullRom::new(step, spec.domain),
                out,
            )
        }
        MethodParams::Velocity { threshold } => {
            check_pow2("threshold", threshold)?;
            velocity_pipeline(crate::approx::velocity::Velocity::new(threshold, spec.domain), out)
        }
        MethodParams::Lambert { terms } => {
            if !(1..=16).contains(&terms) {
                return Err(unsupported(format!(
                    "Fig 5 unrolls one recurrence stage per fraction term (1..=16), \
                     not {terms}"
                )));
            }
            lambert_pipeline(crate::approx::lambert::Lambert::new(terms, spec.domain), out)
        }
    })
}

/// Builds the pipelined datapath for any Table I configuration — a
/// thin wrapper over [`pipeline_for`].
pub fn table1_pipeline(id: MethodId, out: QFormat) -> Pipeline {
    let mut spec = MethodSpec::table1(id);
    spec.io.output = out;
    pipeline_for(&spec).expect("Table I specs always lower to datapaths")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::approx::table1_suite;
    use crate::fixed::Fx;

    #[test]
    fn every_pipeline_bit_matches_golden_model() {
        // The load-bearing test of the hw layer: the cycle-level
        // pipeline must agree with the golden datapath model on every
        // probed input, including negatives and the saturated region.
        let out = QFormat::S_15;
        let inp = QFormat::S3_12;
        for golden in table1_suite() {
            let pipe = table1_pipeline(golden.id(), out);
            for raw in (-(inp.max_raw())..=inp.max_raw()).step_by(997) {
                let x = Fx::from_raw(raw, inp);
                let want = golden.eval_fx(x, out);
                let got = pipe.eval(x);
                assert_eq!(
                    got.raw(),
                    want.raw(),
                    "{} at x={} ({raw}): pipeline {} vs golden {}",
                    golden.describe(),
                    x.to_f64(),
                    got.to_f64(),
                    want.to_f64()
                );
            }
        }
    }

    #[test]
    fn rational_pipelines_are_deeper_than_polynomial() {
        // §IV.H: "the area and latency is more than the polynomial
        // implementation".
        let out = QFormat::S_15;
        let poly = table1_pipeline(MethodId::Pwl, out).latency();
        let taylor = table1_pipeline(MethodId::TaylorQuadratic, out).latency();
        let vf = table1_pipeline(MethodId::Velocity, out).latency();
        let lam = table1_pipeline(MethodId::Lambert, out).latency();
        assert!(vf > poly && vf > taylor, "vf {vf} poly {poly} taylor {taylor}");
        assert!(lam > poly && lam > taylor, "lambert {lam}");
    }

    #[test]
    fn pipeline_for_lowers_non_table1_variants_bit_exact() {
        // The generalization satellite: PWL/Taylor step and Lambert
        // term variants the old table1-only entry point could not
        // express lower to datapaths that still bit-match their golden
        // models.
        for text in [
            "pwl:step=1/32:in=s2.13:out=s.15",
            "taylor1:step=1/32",
            "taylor2:step=1/16:out=s.7",
            "catmull:step=1/8:dom=4",
            "velocity:threshold=1/64",
            "lambert:terms=9",
        ] {
            let spec = crate::approx::MethodSpec::parse(text).unwrap();
            let pipe = pipeline_for(&spec).unwrap_or_else(|e| panic!("{text}: {e}"));
            let golden = spec.build();
            let inp = spec.io.input;
            for raw in (-(inp.max_raw())..=inp.max_raw()).step_by(509) {
                let x = Fx::from_raw(raw, inp);
                assert_eq!(
                    pipe.eval(x).raw(),
                    golden.eval_fx(x, spec.io.output).raw(),
                    "{text} at raw {raw}"
                );
            }
        }
    }

    #[test]
    fn pipeline_for_rejects_inexpressible_specs_with_reason() {
        use crate::approx::{IoSpec, MethodParams, MethodSpec};
        // MethodSpec fields are public, so structurally impossible
        // configurations can exist; the lowering must name what the
        // block diagrams cannot express, not panic mid-construction.
        let bogus_terms = MethodSpec {
            params: MethodParams::Taylor { step: 1.0 / 8.0, terms: 9 },
            io: IoSpec::table1(),
            domain: 6.0,
        };
        let err = pipeline_for(&bogus_terms).unwrap_err();
        assert!(err.contains("unsupported by hw backend"), "{err}");
        assert!(err.contains("Horner"), "{err}");

        let bogus_step = MethodSpec {
            params: MethodParams::Pwl { step: 0.3 },
            io: IoSpec::table1(),
            domain: 6.0,
        };
        let err = pipeline_for(&bogus_step).unwrap_err();
        assert!(err.contains("reciprocal power of two"), "{err}");

        let bogus_k = MethodSpec {
            params: MethodParams::Lambert { terms: 40 },
            io: IoSpec::table1(),
            domain: 6.0,
        };
        let err = pipeline_for(&bogus_k).unwrap_err();
        assert!(err.contains("1..=16"), "{err}");
    }

    #[test]
    fn streaming_throughput_is_one_per_cycle() {
        // Pipelined: N inputs complete in latency + N − 1 cycles.
        let out = QFormat::S_15;
        let pipe = table1_pipeline(MethodId::Lambert, out);
        let inputs: Vec<Fx> =
            (0..64).map(|i| Fx::from_f64(i as f64 * 0.09 - 3.0, QFormat::S3_12)).collect();
        let res = pipe.simulate(&inputs);
        assert_eq!(res.outputs.len(), inputs.len());
        assert_eq!(res.cycles, pipe.latency() + inputs.len() - 1);
    }
}
