//! Cycle-level pipelined datapath simulator — the "VLSI implementation"
//! substrate of the paper's §IV, simulating the block diagrams of Fig 3
//! (polynomial methods), Fig 4 (velocity-factor method) and Fig 5
//! (iterative continued fraction).
//!
//! Each method is lowered to a [`Pipeline`] of combinational [`Stage`]s
//! separated by registers. The simulator:
//!
//! - produces **bit-exact** outputs (each stage is built from the same
//!   [`crate::fixed`] primitives as the golden `eval_fx` models, and the
//!   test suite asserts equality input-by-input);
//! - accounts **latency** (pipeline depth) and **throughput** (one
//!   result per cycle once full — the paper's §IV.H remark that rational
//!   methods hide their latency "if many back-to-back computations [are]
//!   required");
//! - reports per-stage **critical-path delay** via the cost library so
//!   the achievable frequency claim of §IV.H ("the circuit runs faster
//!   if LUTs are used") is checkable.

mod lambert_dp;
mod pipeline;
mod poly_dp;
mod signal;
mod vf_dp;
pub mod verilog;

pub use lambert_dp::lambert_pipeline;
pub use pipeline::{Pipeline, SimResult, Stage};
pub use poly_dp::{catmull_rom_pipeline, pwl_pipeline, taylor_pipeline};
pub use signal::{SignalMap, Value};
pub use vf_dp::velocity_pipeline;

use crate::approx::MethodId;
use crate::fixed::QFormat;

/// Builds the pipelined datapath for any Table I configuration.
pub fn table1_pipeline(id: MethodId, out: QFormat) -> Pipeline {
    match id {
        MethodId::Pwl => pwl_pipeline(crate::approx::pwl::Pwl::table1(), out),
        MethodId::TaylorQuadratic => {
            taylor_pipeline(crate::approx::taylor::Taylor::table1_quadratic(), out)
        }
        MethodId::TaylorCubic => {
            taylor_pipeline(crate::approx::taylor::Taylor::table1_cubic(), out)
        }
        MethodId::CatmullRom => {
            catmull_rom_pipeline(crate::approx::catmull_rom::CatmullRom::table1(), out)
        }
        MethodId::Velocity => velocity_pipeline(crate::approx::velocity::Velocity::table1(), out),
        MethodId::Lambert => lambert_pipeline(crate::approx::lambert::Lambert::table1(), out),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::approx::table1_suite;
    use crate::fixed::Fx;

    #[test]
    fn every_pipeline_bit_matches_golden_model() {
        // The load-bearing test of the hw layer: the cycle-level
        // pipeline must agree with the golden datapath model on every
        // probed input, including negatives and the saturated region.
        let out = QFormat::S_15;
        let inp = QFormat::S3_12;
        for golden in table1_suite() {
            let pipe = table1_pipeline(golden.id(), out);
            for raw in (-(inp.max_raw())..=inp.max_raw()).step_by(997) {
                let x = Fx::from_raw(raw, inp);
                let want = golden.eval_fx(x, out);
                let got = pipe.eval(x);
                assert_eq!(
                    got.raw(),
                    want.raw(),
                    "{} at x={} ({raw}): pipeline {} vs golden {}",
                    golden.describe(),
                    x.to_f64(),
                    got.to_f64(),
                    want.to_f64()
                );
            }
        }
    }

    #[test]
    fn rational_pipelines_are_deeper_than_polynomial() {
        // §IV.H: "the area and latency is more than the polynomial
        // implementation".
        let out = QFormat::S_15;
        let poly = table1_pipeline(MethodId::Pwl, out).latency();
        let taylor = table1_pipeline(MethodId::TaylorQuadratic, out).latency();
        let vf = table1_pipeline(MethodId::Velocity, out).latency();
        let lam = table1_pipeline(MethodId::Lambert, out).latency();
        assert!(vf > poly && vf > taylor, "vf {vf} poly {poly} taylor {taylor}");
        assert!(lam > poly && lam > taylor, "lambert {lam}");
    }

    #[test]
    fn streaming_throughput_is_one_per_cycle() {
        // Pipelined: N inputs complete in latency + N − 1 cycles.
        let out = QFormat::S_15;
        let pipe = table1_pipeline(MethodId::Lambert, out);
        let inputs: Vec<Fx> =
            (0..64).map(|i| Fx::from_f64(i as f64 * 0.09 - 3.0, QFormat::S3_12)).collect();
        let res = pipe.simulate(&inputs);
        assert_eq!(res.outputs.len(), inputs.len());
        assert_eq!(res.cycles, pipe.latency() + inputs.len() - 1);
    }
}
